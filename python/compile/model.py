"""Layer-2 JAX model: the tensor-parallel MLP around the allgather.

The end-to-end workload (DESIGN.md) is Megatron-style tensor parallelism,
which is exactly the setting where an allgather sits on the inference hot
path: with ``W1`` column-sharded over ``tp`` workers, each worker computes
a partial activation ``h_i = gelu(x @ W1_i)`` (the Pallas kernel), the
**Rust coordinator allgathers** the ``h_i`` across workers using the
paper's locality-aware Bruck, and every worker finishes with the dense
projection ``y = h @ W2``.

Python never runs at serving time: the two halves of the forward pass are
AOT-lowered by :mod:`compile.aot` into ``artifacts/*.hlo.txt`` and executed
from Rust via PJRT. This module is the single source of truth for the
computation and the shard math; its reference forward is what the Rust
integration test validates against.
"""

from dataclasses import dataclass

import jax.numpy as jnp

from .kernels import bruck_pack, gathered_matmul, matmul_gelu, ref


@dataclass(frozen=True)
class ModelConfig:
    """Shapes of the TP-MLP and the tensor-parallel degree."""

    batch: int = 8
    d_model: int = 256
    d_hidden: int = 1024
    d_out: int = 256
    tp: int = 4  # tensor-parallel workers == allgather participants

    @property
    def hidden_shard(self) -> int:
        assert self.d_hidden % self.tp == 0, "d_hidden must divide by tp"
        return self.d_hidden // self.tp

    def param_count(self) -> int:
        return self.d_model * self.d_hidden + self.d_hidden * self.d_out


# The configuration baked into the default artifacts.
DEFAULT_CONFIG = ModelConfig()


def shard_w1(w1, i: int, tp: int):
    """Column shard ``i`` of ``W1`` (the piece worker ``i`` owns)."""
    d_hidden = w1.shape[1]
    assert d_hidden % tp == 0
    s = d_hidden // tp
    return w1[:, i * s : (i + 1) * s]


def tp_partial_forward(x, w1_shard):
    """Worker-local half of the forward pass: ``gelu(x @ W1_i)``.

    Calls the Layer-1 Pallas kernel so the fused tile loop lowers into the
    same HLO module. Output shape ``(batch, hidden_shard)``.
    """
    return matmul_gelu.matmul_gelu(x, w1_shard)


def tp_final_forward(h_full, w2):
    """Post-allgather half: dense projection of the full activation.

    ``h_full`` is the rank-order concatenation the allgather produced,
    shape ``(batch, d_hidden)``; output ``(batch, d_out)``.
    """
    return jnp.matmul(h_full, w2)


def fused_final_forward(gathered_flat, w2, *, tp: int, batch: int):
    """Post-allgather projection consuming the rank-order gathered buffer
    directly (Layer-1 ``gathered_matmul`` kernel) -- no h_full assembly."""
    return gathered_matmul.gathered_matmul(gathered_flat, w2, tp=tp, batch=batch)


def rotate_blocks(data_flat, shift, *, p: int):
    """The Bruck final rotation as an XLA computation (Layer-1 kernel),
    exported so the Rust side can offload the pack step of Algorithm 1."""
    return bruck_pack.bruck_rotate_flat(data_flat, shift, p=p)


def reference_forward(x, w1, w2):
    """Unsharded oracle for the whole model: what the TP pipeline must
    reproduce bit-for-bit up to float tolerance."""
    return jnp.matmul(ref.matmul_gelu_ref(x, w1), w2)


def tp_forward_reference(x, w1, w2, tp: int):
    """Pure-jnp simulation of the full TP pipeline, allgather included
    (``jnp.concatenate`` plays the collective). Used by tests to show the
    shard math composes before anything touches Rust."""
    parts = [ref.matmul_gelu_ref(x, shard_w1(w1, i, tp)) for i in range(tp)]
    h_full = jnp.concatenate(parts, axis=1)
    return tp_final_forward(h_full, w2)


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic, well-conditioned parameters (no RNG dependency in the
    build path): low-amplitude trigonometric lattices."""
    d, h, o = cfg.d_model, cfg.d_hidden, cfg.d_out
    ii = jnp.arange(d, dtype=jnp.float32)[:, None]
    jj = jnp.arange(h, dtype=jnp.float32)[None, :]
    w1 = 0.05 * jnp.sin(0.7 * ii + 1.3 * jj + seed) / jnp.sqrt(d)
    kk = jnp.arange(h, dtype=jnp.float32)[:, None]
    ll = jnp.arange(o, dtype=jnp.float32)[None, :]
    w2 = 0.05 * jnp.cos(0.9 * kk - 0.4 * ll + seed) / jnp.sqrt(h)
    return w1.astype(jnp.float32), w2.astype(jnp.float32)


def example_batch(cfg: ModelConfig, seed: int = 1):
    """Deterministic input batch with the artifact shapes."""
    bb = jnp.arange(cfg.batch, dtype=jnp.float32)[:, None]
    dd = jnp.arange(cfg.d_model, dtype=jnp.float32)[None, :]
    return (jnp.sin(0.3 * bb + 0.11 * dd + seed)).astype(jnp.float32)
