"""Layer-1 Pallas kernel: the Bruck algorithm's data-movement hot spot.

Algorithm 1 ends with ``rotate data down by id positions``: the working
buffer holds rank ``(id + j) mod p``'s block at position ``j`` and must be
rotated so block ``r`` lands at position ``r``. On the Rust side this is
``collectives::bruck::rotate_down``; here the same movement is expressed as
a Pallas kernel so the packing can run fused inside the XLA computation
that consumes the gathered data.

The rotation amount is a *runtime* input (each rank rotates by its own id),
so it cannot live in a ``BlockSpec`` index map (those are resolved at
compile time). Instead the kernel reads the shift from a scalar ref and
performs a dynamically-indexed row copy per grid step — on TPU this is a
VMEM-to-VMEM row gather; under ``interpret=True`` it is executed by the
CPU backend.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(shift_ref, d_ref, o_ref, *, p: int):
    """Grid step k writes output row k from input row (k - shift) mod p."""
    k = pl.program_id(0)
    src = jax.lax.rem(k - shift_ref[0] + p, p)
    o_ref[...] = d_ref[pl.dslice(src, 1), :]


def bruck_rotate(data, shift):
    """Rotate ``data`` (shape ``(p, n)``) down by ``shift`` positions along
    axis 0: ``out[k] = data[(k - shift) mod p]``.

    ``shift`` is a scalar int32 array (each rank passes its own id).
    """
    p, n = data.shape
    shift_arr = jnp.asarray(shift, dtype=jnp.int32).reshape((1,))
    return pl.pallas_call(
        functools.partial(_kernel, p=p),
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1,), lambda k: (0,)),  # the scalar shift
            pl.BlockSpec((p, n), lambda k: (0, 0)),  # full buffer
        ],
        out_specs=pl.BlockSpec((1, n), lambda k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((p, n), data.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(shift_arr, data)


def bruck_rotate_flat(data_flat, shift, *, p: int):
    """Flat-buffer convenience used by the AOT artifact: rotates a
    ``(p*n,)`` buffer of ``p`` equal blocks. Mirrors the layout the Rust
    coordinator holds after the Bruck exchange steps."""
    n = data_flat.shape[0] // p
    return bruck_rotate(data_flat.reshape((p, n)), shift).reshape((-1,))
