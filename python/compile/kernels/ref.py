"""Pure-jnp oracles for the Pallas kernels (Layer 1 correctness contract).

Every kernel in this package must match its reference here to float
tolerance (checked by ``python/tests/``); the references are also what the
L2 model uses in its own unit tests.
"""

import jax.numpy as jnp


def gelu(x):
    """tanh-approximated GeLU — the exact formula the kernel implements.

    Matches ``jax.nn.gelu(x, approximate=True)``.
    """
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def matmul_gelu_ref(x, w):
    """Reference for ``matmul_gelu``: ``gelu(x @ w)`` in float32 accumulation."""
    acc = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    return gelu(acc).astype(x.dtype)


def bruck_rotate_ref(data, shift):
    """Reference for ``bruck_rotate``: Algorithm 1's final ``rotate data
    down by id positions`` — ``out[k] = data[(k - shift) mod p]`` over the
    leading axis, i.e. ``jnp.roll`` by ``shift``.
    """
    return jnp.roll(data, shift, axis=0)
