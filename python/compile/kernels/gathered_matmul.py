"""Layer-1 Pallas kernel: fused post-allgather projection.

After the allgather, the coordinator holds the partial activations as
``tp`` rank-order blocks — ``gathered[i*B*Hs + b*Hs + j] = h_i[b, j]`` —
while the final projection wants ``h_full[b, i*Hs + j]``. Materializing
``h_full`` costs an extra pass over the activation tensor.

This kernel fuses the permutation into the matmul: shard ``i`` of the
gathered buffer multiplies rows ``[i·Hs, (i+1)·Hs)`` of ``W2`` directly,
accumulating over a shard-indexed grid axis — the gathered blocks never
get rearranged in memory. This mirrors how Megatron-style runtimes consume
allgathered activations.

``y[b, o] = Σ_i  gathered_i[b, :] @ W2[i·Hs:(i+1)·Hs, o]``
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, w_ref, o_ref, *, nshards: int):
    """Grid step i accumulates shard i's contribution to the output."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # g block: (1, B, Hs); w block: (1, Hs, O)
    o_ref[...] += jnp.dot(
        g_ref[0], w_ref[0], preferred_element_type=o_ref.dtype
    )
    del nshards


def gathered_matmul(gathered_flat, w2, *, tp: int, batch: int):
    """Fused assemble+matmul over the allgathered activation buffer.

    * ``gathered_flat``: shape ``(tp * batch * Hs,)`` — the rank-order
      allgather output;
    * ``w2``: shape ``(H, O)`` with ``H = tp * Hs``;
    * returns ``(batch, O)`` float32.
    """
    h, o = w2.shape
    assert h % tp == 0, f"H={h} not divisible by tp={tp}"
    hs = h // tp
    assert gathered_flat.shape == (tp * batch * hs,), (
        f"gathered shape {gathered_flat.shape} != ({tp * batch * hs},)"
    )
    g = gathered_flat.reshape((tp, batch, hs)).astype(jnp.float32)
    w = w2.reshape((tp, hs, o)).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_kernel, nshards=tp),
        grid=(tp,),
        in_specs=[
            pl.BlockSpec((1, batch, hs), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, hs, o), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((batch, o), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, o), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(g, w)


def gathered_matmul_ref(gathered_flat, w2, *, tp: int, batch: int):
    """Oracle: materialize ``h_full`` then matmul."""
    h, _ = w2.shape
    hs = h // tp
    g = gathered_flat.reshape((tp, batch, hs))
    h_full = jnp.concatenate([g[i] for i in range(tp)], axis=1)
    return jnp.matmul(h_full, w2)
