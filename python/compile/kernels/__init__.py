"""Layer-1 Pallas kernels and their pure-jnp references.

* ``matmul_gelu`` -- fused tiled matmul + GeLU (the TP-MLP partial forward);
* ``bruck_pack`` -- the Bruck allgather's final rotation as a kernel;
* ``gathered_matmul`` -- fused post-allgather projection;
* ``ref`` -- oracles both are tested against.
"""

from . import bruck_pack, gathered_matmul, matmul_gelu, ref  # noqa: F401
