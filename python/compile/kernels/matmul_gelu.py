"""Layer-1 Pallas kernel: fused tiled ``gelu(x @ w)``.

This is the compute hot-spot of the tensor-parallel MLP whose activations
the locality-aware allgather transports (see DESIGN.md). The kernel is
tiled for the TPU MXU: ``(block_m × block_k) @ (block_k × block_n)`` tiles
accumulated over a K-grid axis, with the GeLU epilogue fused into the final
K step — one pass over HBM for the output.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):

* tiles default to 128×128×128 — the MXU systolic-array shape;
* the accumulator lives in the output block (revisited across the K axis),
  the standard Pallas pattern that keeps VMEM footprint to
  ``bm·bk + bk·bn + bm·bn`` elements (≈192 KiB at f32 defaults);
* ``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
  custom-calls, so lowering must stay in plain HLO (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# MXU-shaped default tiles.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128


def _kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One (i, j, k) grid cell: accumulate a tile product; epilogue on the
    last K step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = ref.gelu(o_ref[...])


def matmul_gelu_strict(x, w, *, block_m=DEFAULT_BLOCK_M, block_n=DEFAULT_BLOCK_N,
                       block_k=DEFAULT_BLOCK_K):
    """Tiled fused matmul+GeLU; all dimensions must divide the block sizes.

    ``x: (M, K)``, ``w: (K, N)`` → ``(M, N)`` in float32.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert m % block_m == 0, f"M={m} not divisible by block_m={block_m}"
    assert n % block_n == 0, f"N={n} not divisible by block_n={block_n}"
    assert k % block_k == 0, f"K={k} not divisible by block_k={block_k}"
    nk = k // block_k
    grid = (m // block_m, n // block_n, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x.astype(jnp.float32), w.astype(jnp.float32))


def _pad_to(v: int, b: int) -> int:
    return (v + b - 1) // b * b


def matmul_gelu(x, w, *, block_m=DEFAULT_BLOCK_M, block_n=DEFAULT_BLOCK_N,
                block_k=DEFAULT_BLOCK_K):
    """Shape-general wrapper: zero-pads to tile multiples and slices back.

    Zero padding is exact here: padded K contributes 0 to the dot product
    and padded M/N rows/columns are sliced away after the epilogue.
    """
    m, k = x.shape
    _, n = w.shape
    bm = min(block_m, _pad_to(m, 8))
    bn = min(block_n, _pad_to(n, 8))
    bk = min(block_k, _pad_to(k, 8))
    mp, np_, kp = _pad_to(m, bm), _pad_to(n, bn), _pad_to(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    out = matmul_gelu_strict(xp, wp, block_m=bm, block_n=bn, block_k=bk)
    return out[:m, :n]


def vmem_footprint_bytes(block_m=DEFAULT_BLOCK_M, block_n=DEFAULT_BLOCK_N,
                         block_k=DEFAULT_BLOCK_K, dtype_bytes=4) -> int:
    """Static VMEM estimate per grid cell (x-tile + w-tile + out-tile).

    Used by DESIGN.md §Perf-estimates; at the 128³ f32 defaults this is
    196 608 B ≈ 192 KiB, leaving room for 2-stage double buffering within
    the 16 MiB/core VMEM budget.
    """
    return dtype_bytes * (block_m * block_k + block_k * block_n + block_m * block_n)
