"""AOT lowering: JAX (L2, calling L1 Pallas kernels) → HLO **text** → Rust.

Interchange format is HLO text, *not* a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written to ``--out-dir`` (default ``../artifacts``):

* ``partial_fwd.hlo.txt`` — ``tp_partial_forward(x, w1_shard)``;
* ``final_fwd.hlo.txt``   — ``tp_final_forward(h_full, w2)``;
* ``rotate.hlo.txt``      — ``rotate_blocks(buf, shift)`` (Bruck pack step);
* ``manifest.json``       — shapes/dtypes per artifact + model config, read
  by ``rust/src/runtime/artifact.rs``.

Every computation is lowered with ``return_tuple=True`` and unwrapped with
``to_tuple1()`` on the Rust side.

Usage: ``python -m compile.aot [--out-dir DIR] [--tp N]``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, *example_args) -> str:
    """Lower a jittable function to HLO text via StableHLO."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def shape_entry(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def build_artifacts(cfg: model.ModelConfig):
    """Return {name: (hlo_text, manifest_entry)} for every artifact."""
    b, d, hs, h, o = (
        cfg.batch,
        cfg.d_model,
        cfg.hidden_shard,
        cfg.d_hidden,
        cfg.d_out,
    )
    arts = {}

    # L2 partial forward (contains the L1 matmul_gelu Pallas kernel).
    arts["partial_fwd"] = (
        to_hlo_text(
            lambda x, w: (model.tp_partial_forward(x, w),),
            spec((b, d)),
            spec((d, hs)),
        ),
        {
            "inputs": [shape_entry((b, d)), shape_entry((d, hs))],
            "output": shape_entry((b, hs)),
            "doc": "gelu(x @ w1_shard) — fused Pallas kernel",
        },
    )

    # L2 final forward (dense projection after the allgather).
    arts["final_fwd"] = (
        to_hlo_text(
            lambda hh, w2: (model.tp_final_forward(hh, w2),),
            spec((b, h)),
            spec((h, o)),
        ),
        {
            "inputs": [shape_entry((b, h)), shape_entry((h, o))],
            "output": shape_entry((b, o)),
            "doc": "h_full @ w2 after the allgather",
        },
    )

    # L1 fused post-allgather projection (no h_full assembly pass).
    p = cfg.tp
    arts["fused_final"] = (
        to_hlo_text(
            lambda gg, w2: (model.fused_final_forward(gg, w2, tp=p, batch=b),),
            spec((p * b * hs,)),
            spec((h, o)),
        ),
        {
            "inputs": [shape_entry((p * b * hs,)), shape_entry((h, o))],
            "output": shape_entry((b, o)),
            "doc": "fused gathered-activations @ w2 (Pallas kernel)",
        },
    )

    # L1 Bruck rotation kernel over the coordinator's flat u32-as-f32
    # buffer: p = tp blocks of (batch * hidden_shard) elements.
    n_flat = p * b * hs
    arts["rotate"] = (
        to_hlo_text(
            lambda buf, s: (model.rotate_blocks(buf, s, p=p),),
            spec((n_flat,)),
            spec((), jnp.int32),
        ),
        {
            "inputs": [shape_entry((n_flat,)), shape_entry((), "s32")],
            "output": shape_entry((n_flat,)),
            "doc": f"Bruck rotate-down over {p} blocks (Pallas kernel)",
        },
    )
    return arts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--tp", type=int, default=model.DEFAULT_CONFIG.tp)
    ap.add_argument("--batch", type=int, default=model.DEFAULT_CONFIG.batch)
    args = ap.parse_args()

    cfg = model.ModelConfig(
        batch=args.batch,
        d_model=model.DEFAULT_CONFIG.d_model,
        d_hidden=model.DEFAULT_CONFIG.d_hidden,
        d_out=model.DEFAULT_CONFIG.d_out,
        tp=args.tp,
    )
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {
        "model": {
            "batch": cfg.batch,
            "d_model": cfg.d_model,
            "d_hidden": cfg.d_hidden,
            "d_out": cfg.d_out,
            "tp": cfg.tp,
            "params": cfg.param_count(),
        },
        "artifacts": {},
    }
    for name, (text, entry) in build_artifacts(cfg).items():
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entry["file"] = fname
        manifest["artifacts"][name] = entry
        print(f"wrote {path} ({len(text)} chars)")
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
