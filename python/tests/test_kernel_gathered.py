"""L1 correctness: the fused gathered-matmul kernel vs its oracle.

This kernel consumes the allgather's rank-order output directly, fusing the
shard permutation into the projection — the permutation must be exactly the
inverse of how the Rust coordinator lays out the gathered blocks.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import gathered_matmul as gm
from compile.kernels import ref


def _mk(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=np.float32)


@settings(max_examples=20, deadline=None)
@given(
    tp=st.sampled_from([1, 2, 4, 8]),
    batch=st.integers(1, 8),
    hs=st.integers(1, 24),
    o=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_oracle(tp, batch, hs, o, seed):
    g = _mk((tp * batch * hs,), seed)
    w2 = _mk((tp * hs, o), seed + 1)
    got = gm.gathered_matmul(g, w2, tp=tp, batch=batch)
    want = gm.gathered_matmul_ref(g, w2, tp=tp, batch=batch)
    assert got.shape == (batch, o)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_equals_unfused_pipeline():
    """fused(gathered) == final_forward(assembled h_full): the contract the
    coordinator's --fused flag relies on."""
    cfg = model.ModelConfig(batch=4, d_model=32, d_hidden=64, d_out=16, tp=4)
    w1, w2 = model.init_params(cfg)
    x = model.example_batch(cfg)
    # build the gathered buffer exactly as the rust allgather would:
    # rank-order concatenation of (batch, hs) blocks
    parts = [
        ref.matmul_gelu_ref(x, model.shard_w1(w1, i, cfg.tp)) for i in range(cfg.tp)
    ]
    gathered = jnp.concatenate([p.reshape(-1) for p in parts])
    fused = model.fused_final_forward(gathered, w2, tp=cfg.tp, batch=cfg.batch)
    h_full = jnp.concatenate(parts, axis=1)
    unfused = model.tp_final_forward(h_full, w2)
    np.testing.assert_allclose(fused, unfused, rtol=1e-5, atol=1e-5)


def test_tp1_is_plain_matmul():
    g = _mk((3 * 10,), 0)
    w2 = _mk((10, 5), 1)
    got = gm.gathered_matmul(g, w2, tp=1, batch=3)
    want = jnp.matmul(g.reshape(3, 10), w2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
