"""L2 correctness: the tensor-parallel decomposition composes.

The sharded pipeline (partial forwards + concatenate-as-allgather + final
forward) must reproduce the unsharded reference — this is the contract the
Rust coordinator relies on when it runs the same pieces via PJRT with the
locality-aware allgather in between.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model


def test_default_config_shapes():
    cfg = model.DEFAULT_CONFIG
    assert cfg.d_hidden % cfg.tp == 0
    assert cfg.hidden_shard == cfg.d_hidden // cfg.tp
    assert cfg.param_count() == cfg.d_model * cfg.d_hidden + cfg.d_hidden * cfg.d_out


def test_tp_pipeline_matches_reference():
    cfg = model.ModelConfig(batch=4, d_model=64, d_hidden=128, d_out=32, tp=4)
    w1, w2 = model.init_params(cfg)
    x = model.example_batch(cfg)
    got = model.tp_forward_reference(x, w1, w2, cfg.tp)
    want = model.reference_forward(x, w1, w2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(tp=st.sampled_from([1, 2, 4, 8]))
def test_tp_degree_invariance(tp):
    """Any tensor-parallel degree produces the same function."""
    cfg = model.ModelConfig(batch=2, d_model=32, d_hidden=64, d_out=16, tp=tp)
    w1, w2 = model.init_params(cfg)
    x = model.example_batch(cfg)
    got = model.tp_forward_reference(x, w1, w2, tp)
    want = model.reference_forward(x, w1, w2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_shards_tile_w1_exactly():
    cfg = model.ModelConfig(batch=2, d_model=16, d_hidden=32, d_out=8, tp=4)
    w1, _ = model.init_params(cfg)
    back = jnp.concatenate(
        [model.shard_w1(w1, i, cfg.tp) for i in range(cfg.tp)], axis=1
    )
    np.testing.assert_array_equal(back, w1)


def test_partial_forward_uses_kernel_and_matches_ref():
    from compile.kernels import ref as kref

    cfg = model.ModelConfig(batch=4, d_model=64, d_hidden=128, d_out=32, tp=4)
    w1, _ = model.init_params(cfg)
    x = model.example_batch(cfg)
    shard = model.shard_w1(w1, 1, cfg.tp)
    got = model.tp_partial_forward(x, shard)
    want = kref.matmul_gelu_ref(x, shard)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_init_params_deterministic():
    cfg = model.ModelConfig()
    a1, a2 = model.init_params(cfg, seed=3)
    b1, b2 = model.init_params(cfg, seed=3)
    np.testing.assert_array_equal(a1, b1)
    np.testing.assert_array_equal(a2, b2)
    c1, _ = model.init_params(cfg, seed=4)
    assert not np.array_equal(a1, c1)


def test_bad_tp_rejected():
    cfg = model.ModelConfig(d_hidden=100, tp=3)
    with pytest.raises(AssertionError):
        _ = cfg.hidden_shard
