"""L1 correctness: the Bruck rotation Pallas kernel vs ``jnp.roll``.

The rotation is Algorithm 1's final reorder; the Rust implementation
(`collectives::bruck::rotate_down`) and this kernel must agree with the
same oracle.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import bruck_pack, ref


def _data(p, n, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((p, n)), dtype=dtype)


def test_identity_rotation():
    d = _data(4, 8)
    out = bruck_pack.bruck_rotate(d, 0)
    np.testing.assert_array_equal(out, d)


def test_single_step_rotation():
    d = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
    out = bruck_pack.bruck_rotate(d, 1)
    # out[k] = d[(k-1) mod 4]
    np.testing.assert_array_equal(out[0], d[3])
    np.testing.assert_array_equal(out[1], d[0])


@settings(max_examples=30, deadline=None)
@given(
    p=st.integers(1, 16),
    n=st.integers(1, 32),
    shift=st.integers(-20, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_roll_oracle(p, n, shift, seed):
    d = _data(p, n, seed=seed)
    got = bruck_pack.bruck_rotate(d, shift % p)
    want = ref.bruck_rotate_ref(d, shift % p)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(p=st.integers(1, 8), n=st.integers(1, 16), shift=st.integers(0, 7))
def test_int32_payloads(p, n, shift):
    """The paper gathers integers; the kernel must be dtype-generic."""
    d = jnp.arange(p * n, dtype=jnp.int32).reshape(p, n)
    got = bruck_pack.bruck_rotate(d, shift % p)
    want = ref.bruck_rotate_ref(d, shift % p)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(p=st.integers(1, 8), n=st.integers(1, 16), shift=st.integers(0, 7))
def test_flat_wrapper(p, n, shift):
    d = jnp.arange(p * n, dtype=jnp.float32)
    got = bruck_pack.bruck_rotate_flat(d, shift % p, p=p)
    want = ref.bruck_rotate_ref(d.reshape(p, n), shift % p).reshape(-1)
    np.testing.assert_array_equal(got, want)


def test_composition_is_group_action():
    """Rotating by a then b equals rotating by a+b (mod p)."""
    d = _data(6, 5, seed=42)
    ab = bruck_pack.bruck_rotate(bruck_pack.bruck_rotate(d, 2), 3)
    direct = bruck_pack.bruck_rotate(d, 5)
    np.testing.assert_array_equal(ab, direct)
