"""L1 correctness: the fused matmul+GeLU Pallas kernel vs the jnp oracle.

Hypothesis sweeps shapes (including non-tile-multiple ones through the
padding wrapper) and dtypes; this is the CORE correctness signal for the
compute kernel that the AOT artifacts embed.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_gelu, ref


def _mk(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def test_exact_tile_shape():
    x = _mk((128, 128), 0)
    w = _mk((128, 128), 1)
    got = matmul_gelu.matmul_gelu_strict(x, w)
    want = ref.matmul_gelu_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_multi_tile_grid():
    x = _mk((256, 384), 2)
    w = _mk((384, 256), 3)
    got = matmul_gelu.matmul_gelu_strict(x, w)
    want = ref.matmul_gelu_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_strict_rejects_ragged():
    x = _mk((100, 128), 4)
    w = _mk((128, 128), 5)
    with pytest.raises(AssertionError):
        matmul_gelu.matmul_gelu_strict(x, w)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_padding_wrapper_matches_ref(m, k, n, seed):
    x = _mk((m, k), seed)
    w = _mk((k, n), seed + 1)
    got = matmul_gelu.matmul_gelu(x, w)
    want = ref.matmul_gelu_ref(x, w)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
)
def test_block_shape_invariance(bm, bn, bk):
    """The tiling schedule must not change the numerics."""
    x = _mk((64, 64), 7)
    w = _mk((64, 64), 8)
    got = matmul_gelu.matmul_gelu_strict(x, w, block_m=bm, block_n=bn, block_k=bk)
    want = ref.matmul_gelu_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_bf16_inputs_upcast():
    x = _mk((32, 32), 9).astype(jnp.bfloat16)
    w = _mk((32, 32), 10).astype(jnp.bfloat16)
    got = matmul_gelu.matmul_gelu(x, w)
    want = ref.matmul_gelu_ref(
        x.astype(jnp.float32), w.astype(jnp.float32)
    )
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_gelu_matches_jax_nn():
    import jax

    x = _mk((64,), 11)
    np.testing.assert_allclose(
        ref.gelu(x), jax.nn.gelu(x, approximate=True), rtol=1e-6, atol=1e-6
    )


def test_vmem_footprint_default_under_budget():
    # 192 KiB at the 128^3 f32 defaults — far below 16 MiB/core.
    fp = matmul_gelu.vmem_footprint_bytes()
    assert fp == 4 * (128 * 128 * 3)
    assert fp < 16 * 1024 * 1024
