"""Build-path tests: AOT lowering produces loadable HLO text artifacts.

These guard the interchange contract with the Rust runtime: HLO *text*
(xla_extension 0.5.1 rejects jax's 64-bit-id protos), tuple returns, and a
manifest whose shapes match what `rust/src/runtime/artifact.rs` expects.
"""

import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    cfg = model.ModelConfig(batch=4, d_model=32, d_hidden=64, d_out=16, tp=2)
    arts = aot.build_artifacts(cfg)
    manifest = {"model": {"tp": cfg.tp}, "artifacts": {}}
    for name, (text, entry) in arts.items():
        path = out / f"{name}.hlo.txt"
        path.write_text(text)
        entry["file"] = f"{name}.hlo.txt"
        manifest["artifacts"][name] = entry
    (out / "manifest.json").write_text(json.dumps(manifest))
    return out, cfg, arts


def test_all_artifacts_emitted(artifacts):
    out, _, arts = artifacts
    assert set(arts) == {"partial_fwd", "final_fwd", "fused_final", "rotate"}
    for name in arts:
        assert (out / f"{name}.hlo.txt").exists()


def test_hlo_text_is_parseable_hlo(artifacts):
    _, _, arts = artifacts
    for name, (text, _) in arts.items():
        assert "HloModule" in text, name
        assert "ENTRY" in text, name
        # tuple return contract for the rust side's to_tuple1()
        assert "tuple" in text.lower(), name


def test_manifest_shapes_consistent(artifacts):
    out, cfg, _ = artifacts
    m = json.loads((out / "manifest.json").read_text())
    arts = m["artifacts"]
    pf = arts["partial_fwd"]
    assert pf["inputs"][0]["shape"] == [cfg.batch, cfg.d_model]
    assert pf["inputs"][1]["shape"] == [cfg.d_model, cfg.hidden_shard]
    assert pf["output"]["shape"] == [cfg.batch, cfg.hidden_shard]
    ff = arts["final_fwd"]
    assert ff["inputs"][0]["shape"] == [cfg.batch, cfg.d_hidden]
    assert ff["output"]["shape"] == [cfg.batch, cfg.d_out]
    rot = arts["rotate"]
    n_flat = cfg.tp * cfg.batch * cfg.hidden_shard
    assert rot["inputs"][0]["shape"] == [n_flat]


def test_lowered_partial_matches_eager(artifacts):
    """Executing the lowered computation through jax must equal eager —
    guards against lowering-time shape/dtype drift."""
    _, cfg, _ = artifacts
    import jax

    w1, _ = model.init_params(cfg)
    x = model.example_batch(cfg)
    shard = model.shard_w1(w1, 0, cfg.tp)
    lowered = jax.jit(
        lambda a, b: (model.tp_partial_forward(a, b),)
    ).lower(x, shard)
    compiled = lowered.compile()
    (got,) = compiled(x, shard)
    want = model.tp_partial_forward(x, shard)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_cli_writes_outdir(tmp_path):
    """The Makefile entry point works end to end (small config)."""
    env = dict(os.environ)
    repo_python = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = tmp_path / "arts"
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--tp", "2", "--batch", "2"],
        cwd=repo_python,
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert res.returncode == 0, res.stderr
    m = json.loads((out / "manifest.json").read_text())
    assert m["model"]["tp"] == 2
    for entry in m["artifacts"].values():
        assert (out / entry["file"]).exists()


def test_rotate_artifact_semantics(artifacts):
    """The rotate computation lowered into HLO behaves like the kernel."""
    _, cfg, _ = artifacts
    import jax

    p = cfg.tp
    n_flat = p * cfg.batch * cfg.hidden_shard
    buf = jnp.arange(n_flat, dtype=jnp.float32)
    f = jax.jit(lambda b, s: model.rotate_blocks(b, s, p=p))
    got = f(buf, jnp.int32(1))
    want = jnp.roll(buf.reshape(p, -1), 1, axis=0).reshape(-1)
    np.testing.assert_array_equal(got, want)
