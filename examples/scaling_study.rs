//! Scaling study: every allgather algorithm across region counts and
//! ranks-per-region — the shape of the paper's Figures 9/10 as a table.
//!
//! Modeled (virtual-clock) times come from executing the *real* message
//! schedules under the Quartz machine parameters; correctness is verified
//! on every data point.
//!
//! Run with: `cargo run --release --example scaling_study [max_ranks]`

use locag::collectives::Algorithm;
use locag::model::MachineParams;
use locag::sim;
use locag::topology::Topology;
use locag::util::fmt::seconds;

fn main() {
    let max_p: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);
    let machine = MachineParams::quartz();
    let algos = [
        Algorithm::SystemDefault,
        Algorithm::Bruck,
        Algorithm::Ring,
        Algorithm::Hierarchical,
        Algorithm::Multilane,
        Algorithm::LocalityBruck,
    ];

    for ppn in [4usize, 8, 16] {
        println!("\n=== {ppn} ranks per region (PPN={ppn}), 2 u32 values per rank ===");
        print!("{:>8}", "regions");
        for a in algos {
            print!(" {:>16}", a.name());
        }
        println!();
        let mut regions = 2usize;
        while regions * ppn <= max_p {
            print!("{regions:>8}");
            let topo = Topology::regions(regions, ppn);
            let mut best = (f64::MAX, "");
            for a in algos {
                let rep = sim::run_allgather(a, &topo, &machine, 2);
                assert!(rep.verified, "{a} failed at {regions}x{ppn}: {:?}", rep.errors);
                if rep.vtime < best.0 {
                    best = (rep.vtime, a.name());
                }
                print!(" {:>16}", seconds(rep.vtime));
            }
            println!("   <- best: {}", best.1);
            regions *= 2;
        }
    }

    println!(
        "\nExpected shape (paper Figs. 9/10): loc-bruck wins for small data as\n\
         regions grow, and the gap widens with PPN."
    );
}
