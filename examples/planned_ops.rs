//! One plan framework, four operations.
//!
//! PR 1 introduced persistent plans for the allgather; the framework now
//! covers allreduce, alltoall and reduce-scatter through the same
//! machinery: per-op registries of named algorithms, `plan()` once per
//! (communicator, shape), `execute()` many times into caller-owned
//! buffers with zero setup, zero allocation and zero tag consumption.
//!
//! Run with: `cargo run --release --example planned_ops`

use locag::collectives::{
    self, AllreduceRegistry, AlltoallRegistry, OpKind, ReduceScatterRegistry, Registry, Shape,
};
use locag::comm::{CommWorld, Timing};
use locag::topology::Topology;

fn main() {
    let topo = Topology::regions(8, 4); // 32 ranks, 8 regions of 4
    let p = topo.size();
    let n = 64usize;
    let iters = 500u64;

    println!("{p} ranks (8 regions x 4), {n} u64 values/rank, {iters} executions per plan\n");
    println!("registered algorithms:");
    println!("  allgather: {}", Registry::<u64>::standard().names().join(", "));
    println!("  allreduce: {}", AllreduceRegistry::<u64>::standard().names().join(", "));
    println!("  alltoall:  {}", AlltoallRegistry::<u64>::standard().names().join(", "));
    println!(
        "  reduce-scatter: {}",
        ReduceScatterRegistry::<u64>::standard().names().join(", ")
    );
    println!();

    // Every op: plan once (by name, through its registry), execute many
    // times with shifting inputs, verify against a naive expectation.
    let ok = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let rank = c.rank() as u64;

        // --- allgather -------------------------------------------------
        let mut ag = collectives::plan_allgather::<u64>(
            collectives::Algorithm::LocalityBruck,
            c,
            Shape::elems(n),
        )
        .expect("allgather plan");
        let mut gathered = vec![0u64; n * p];

        // --- allreduce -------------------------------------------------
        let mut ar =
            collectives::plan_allreduce::<u64>("loc-aware", c, Shape::elems(n)).expect("ar plan");
        let mut summed = vec![0u64; n];

        // --- alltoall --------------------------------------------------
        let mut a2a =
            collectives::plan_alltoall::<u64>("loc-aware", c, Shape::elems(n)).expect("a2a plan");
        let send: Vec<u64> = (0..n * p).map(|x| rank * 1_000 + x as u64).collect();
        let mut exchanged = vec![0u64; n * p];

        // --- reduce-scatter --------------------------------------------
        let mut rs = collectives::plan_reduce_scatter::<u64>("loc-aware", c, Shape::elems(n))
            .expect("rs plan");
        let mut scattered = vec![0u64; n];

        for round in 0..iters {
            let mine: Vec<u64> = (0..n as u64).map(|j| rank + j + round).collect();
            ag.execute(&mine, &mut gathered).expect("allgather");
            assert_eq!(gathered[(p - 1) * n], (p as u64 - 1) + round);

            ar.execute(&mine, &mut summed).expect("allreduce");
            // sum over ranks of (rank + j + round)
            let want0 = (0..p as u64).sum::<u64>() + (round * p as u64);
            assert_eq!(summed[0], want0);

            a2a.execute(&send, &mut exchanged).expect("alltoall");
            // output block 0 is rank 0's block destined for us
            assert_eq!(exchanged[0], (c.rank() * n) as u64);

            rs.execute(&send, &mut scattered).expect("reduce-scatter");
            // element 0: sum over ranks r of (r*1000 + rank*n)
            let base: u64 = (0..p as u64).map(|r| r * 1_000).sum();
            assert_eq!(scattered[0], base + (p * c.rank() * n) as u64);
        }
        true
    });
    assert!(ok.results.iter().all(|&b| b));
    println!(
        "all four ops: plan-once / execute-{iters} verified on every rank \
         (sub-comms built: {}, all at plan time)",
        locag::comm::sub_comms_built()
    );
    for op in OpKind::ALL {
        println!("  {op}: plans live behind the shared CollectivePlan trait");
    }
}
