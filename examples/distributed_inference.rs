//! **End-to-end driver**: tensor-parallel inference served through the
//! full three-layer stack, with the paper's allgather on the hot path.
//!
//! Layer 1/2 (build time): `make artifacts` lowered the TP-MLP halves —
//! `gelu(x @ W1_i)` as a tiled Pallas kernel and the post-gather projection
//! — to HLO text. Layer 3 (this binary): worker threads load the artifacts
//! via PJRT, and every batched request runs
//!
//! ```text
//! bcast(x) → PJRT partial_fwd → ALLGATHER(h_i) → PJRT final_fwd
//! ```
//!
//! Outputs are verified against an in-Rust reference forward pass, and the
//! same workload is served once per allgather algorithm so the serving-
//! level effect of the paper's contribution is visible as latency.
//!
//! Run with: `cargo run --release --example distributed_inference`
//! (requires `make artifacts` first).

use locag::collectives::Algorithm;
use locag::coordinator::{serve, ServeConfig};
use locag::runtime::Manifest;
use locag::util::fmt::seconds;

fn main() {
    let dir = Manifest::default_dir();
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            eprintln!("hint: run `make artifacts` first");
            std::process::exit(2);
        }
    };
    let dims = manifest.model;
    println!(
        "TP-MLP: batch={} d_model={} d_hidden={} d_out={} tp={} ({} params)\n",
        dims.batch, dims.d_model, dims.d_hidden, dims.d_out, dims.tp, dims.params
    );

    println!(
        "{:<18} {:>10} {:>10} {:>10} {:>12} {:>9}",
        "allgather", "p50", "p99", "ag p50", "batches/s", "verified"
    );
    let mut rows = Vec::new();
    for algo in [
        Algorithm::Bruck,
        Algorithm::Ring,
        Algorithm::Hierarchical,
        Algorithm::Multilane,
        Algorithm::LocalityBruck,
    ] {
        let cfg = ServeConfig {
            artifact_dir: dir.clone(),
            algo,
            regions: 2,
            requests: 24,
            warmup: 3,
            check: true,
            fused: false,
            consensus: true,
            fuse_batch: 1,
            ..ServeConfig::default()
        };
        let rep = serve(&cfg).expect("serve");
        assert!(
            rep.verified,
            "{algo}: served outputs diverged from reference (max err {})",
            rep.max_err
        );
        let lat = rep.metrics.latency().expect("latency");
        let ag = rep.metrics.allgather().expect("allgather");
        println!(
            "{:<18} {:>10} {:>10} {:>10} {:>12.1} {:>9}",
            algo.name(),
            seconds(lat.p50),
            seconds(lat.p99),
            seconds(ag.p50),
            rep.metrics.throughput,
            rep.verified
        );
        rows.push((algo, rep));
    }

    println!("\nAll outputs matched the in-Rust reference forward pass —");
    println!("the Pallas kernel, the JAX lowering, the PJRT runtime and the");
    println!("allgather implementations compose end to end.");
    println!("\n(Latency differences across algorithms are small here: all");
    println!("workers share one machine, so real locality deltas do not");
    println!("apply — see `locag figure 9/10` for the modeled topology runs.)");
}
