//! Persistent plans: the serving-loop shape of the collective API.
//!
//! A tensor-parallel server issues the *same* allgather — same
//! communicator, same shape — for every request. The one-shot API pays
//! group derivation, sub-communicator construction, schedule computation
//! and output allocation on every call; a persistent `AllgatherPlan` pays
//! them once. This example measures both forms over the identical
//! workload and shows the registry route for name-based planning.
//!
//! Run with: `cargo run --release --example persistent_plan`

use std::time::Instant;

use locag::prelude::*;

fn main() {
    let topo = Topology::regions(8, 4); // 32 ranks, 8 regions
    let p = topo.size();
    let n = 256usize; // u64 elements per rank
    let iters = 2000u32;

    println!("{p} ranks ({} regions x 4), {n} u64/rank, {iters} operations\n", 8);

    for algo in [Algorithm::Bruck, Algorithm::LocalityBruck] {
        // --- one-shot: plan + allocate every call ------------------------
        let t = Instant::now();
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mine = vec![c.rank() as u64; n];
            let mut last = 0u64;
            for _ in 0..iters {
                let out = locag::collectives::allgather(algo, c, &mine).expect("allgather");
                last = out[out.len() - 1];
            }
            last
        });
        let one_shot = t.elapsed().as_secs_f64();
        assert!(run.results.iter().all(|&x| x == (p - 1) as u64));

        // --- persistent: plan once, execute per iteration ----------------
        let subs_before = locag::comm::sub_comms_built();
        let t = Instant::now();
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan = locag::collectives::plan_allgather::<u64>(algo, c, Shape::elems(n))
                .expect("plan");
            let mut out = vec![0u64; n * p];
            let mine = vec![c.rank() as u64; n];
            for _ in 0..iters {
                plan.execute(&mine, &mut out).expect("execute");
            }
            out[n * p - 1]
        });
        let planned = t.elapsed().as_secs_f64();
        assert!(run.results.iter().all(|&x| x == (p - 1) as u64));
        let subs_built = locag::comm::sub_comms_built() - subs_before;

        println!(
            "{:<12} one-shot {:>8.1} ms   planned {:>8.1} ms   ({:.2}x)   sub-comms built: {}",
            algo.name(),
            one_shot * 1e3,
            planned * 1e3,
            one_shot / planned,
            subs_built,
        );
    }

    // --- the registry route: plan by name, extensible without dispatch ---
    println!("\nregistry names: {}", Registry::<u64>::standard().names().join(", "));
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let registry = Registry::<u64>::standard();
        // names are case-insensitive
        let mut plan =
            registry.plan_uniform("LOC-BRUCK", c, Shape::elems(4)).expect("plan by name");
        let mut out = vec![0u64; 4 * p];
        plan.execute(&[9, 9, 9, c.rank() as u64], &mut out).expect("execute");
        out[4 * c.rank() + 3]
    });
    for (rank, &v) in run.results.iter().enumerate() {
        assert_eq!(v, rank as u64);
    }
    println!("planned by registry name \"LOC-BRUCK\" (case-insensitive) ✓");
}
