//! Multilevel hierarchy: the paper's §3 node-aware + socket-aware nesting.
//!
//! "…the locality-aware Bruck algorithm naturally extends to additional
//! levels of hierarchy by replacing all calls to bruck in Algorithm 2 with
//! an additional layer of loc_bruck."
//!
//! We build machines with two sockets per node and compare three variants
//! on a Lassen-like cost model (where inter-socket traffic is much more
//! expensive than intra-socket):
//!
//! * standard Bruck (locality-oblivious),
//! * single-level node-aware loc-bruck (treats whole nodes as regions, so
//!   its local gathers still cross sockets),
//! * two-level loc-bruck (node-aware outer, socket-aware inner).
//!
//! Run with: `cargo run --release --example multilevel`

use locag::collectives::Algorithm;
use locag::model::MachineParams;
use locag::sim;
use locag::topology::{Placement, RegionKind, Topology};
use locag::util::fmt::seconds;

fn main() {
    let machine = MachineParams::lassen();
    println!("machines with 2 sockets/node; Lassen cost model; 2 u32 values/rank\n");
    println!(
        "{:>6} {:>6} {:>5} | {:>12} {:>14} {:>14}",
        "nodes", "ranks", "", "bruck", "loc (1-level)", "loc (2-level)"
    );
    for (nodes, cores_per_socket) in [(4usize, 4usize), (8, 4), (8, 8), (16, 8)] {
        let topo = Topology::machine(
            nodes,
            2,
            cores_per_socket,
            RegionKind::Node,
            Placement::Block,
        )
        .expect("topology");
        let p = topo.size();
        let mut times = Vec::new();
        for algo in [
            Algorithm::Bruck,
            Algorithm::LocalityBruck,
            Algorithm::LocalityBruckMultilevel,
        ] {
            let rep = sim::run_allgather(algo, &topo, &machine, 2);
            assert!(rep.verified, "{algo} @ {nodes} nodes: {:?}", rep.errors);
            times.push(rep.vtime);
        }
        println!(
            "{:>6} {:>6} {:>5} | {:>12} {:>14} {:>14}",
            nodes,
            p,
            "",
            seconds(times[0]),
            seconds(times[1]),
            seconds(times[2])
        );
        assert!(
            times[2] < times[0],
            "two-level must beat locality-oblivious bruck"
        );
    }
    println!(
        "\nThe two-level variant additionally restructures intra-node gathers\n\
         to stay intra-socket, which pays off when inter-socket traffic is\n\
         expensive (the paper's Lassen case)."
    );
}
