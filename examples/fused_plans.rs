//! Fused multi-plan execution: the serving loop's collectives — `K`
//! micro-batched allgathers plus the consensus allreduce — executed as
//! ONE round-merged, message-coalesced schedule.
//!
//! Sequential execution pays one non-local postal `α` per collective per
//! exchange; the fused schedule coalesces same-round, same-peer sends
//! into a single wire message, so the whole bundle pays one. This is the
//! paper's aggregation idea (locality-aware Bruck, §3–§4) lifted across
//! collective boundaries.
//!
//! Run with: `cargo run --example fused_plans`

use locag::collectives::{FuseSpec, OpKind};
use locag::prelude::*;
use locag::util::fmt::seconds;

fn main() {
    // The serving topology: 2 regions of 8 tensor-parallel workers.
    let topo = Topology::regions(2, 8);
    let m = MachineParams::lassen();
    println!("fused (K·allgather ⊕ consensus allreduce) on 16 ranks (2 regions x 8):\n");
    for batch in [1usize, 2, 4] {
        let mut specs: Vec<FuseSpec> =
            (0..batch).map(|_| FuseSpec::new(OpKind::Allgather, "loc-bruck", 4)).collect();
        specs.push(FuseSpec::new(OpKind::Allreduce, "loc-aware", 2));
        let rep = run_fused(&specs, &topo, &m);
        assert!(rep.verified, "{:?}", rep.errors);
        println!(
            "  K={batch}: fused {} / {:>2} non-local msgs  vs  sequential {} / {:>2}",
            seconds(rep.fused_vtime),
            rep.fused_trace.max_nonlocal_msgs(),
            seconds(rep.seq_vtime),
            rep.seq_trace.max_nonlocal_msgs()
        );
        // The IR prices fused schedules exactly, like any schedule.
        assert!((rep.fused_predicted - rep.fused_vtime).abs() < 1e-12);
    }
    println!("\n(`locag fuse` prints the per-message coalescing table.)");
}
