//! Placement study: the paper's §3 reproducibility claim.
//!
//! "The performance of the standard Bruck algorithm varies with process
//! placement … As locality-aware communication splits the communicators
//! into local and non-local, the ordering of the processes has no impact
//! on non-local communication requirements."
//!
//! We run both algorithms under block, round-robin and random placements
//! of 128 ranks over 8 nodes and compare the *maximum non-local messages
//! and bytes per rank* plus the modeled time.
//!
//! Run with: `cargo run --release --example placement_study`

use locag::collectives::Algorithm;
use locag::model::MachineParams;
use locag::sim;
use locag::topology::{Placement, RegionKind, Topology};
use locag::util::fmt::seconds;

fn main() {
    let machine = MachineParams::quartz();
    let placements: [(&str, Placement); 4] = [
        ("block", Placement::Block),
        ("round-robin", Placement::RoundRobin),
        ("random(7)", Placement::Random { seed: 7 }),
        ("random(99)", Placement::Random { seed: 99 }),
    ];

    println!("128 ranks over 8 nodes (16 per node), 2 u32 values per rank\n");
    for algo in [Algorithm::Bruck, Algorithm::LocalityBruck] {
        println!("--- {} ---", algo.name());
        println!(
            "{:<13} {:>12} {:>14} {:>13}",
            "placement", "max NL msgs", "max NL bytes", "modeled time"
        );
        let mut nl_msgs = Vec::new();
        for (name, placement) in placements {
            let topo =
                Topology::machine(8, 1, 16, RegionKind::Node, placement).expect("topology");
            let rep = sim::run_allgather(algo, &topo, &machine, 2);
            assert!(rep.verified, "{algo} must verify under {name}");
            println!(
                "{:<13} {:>12} {:>14} {:>13}",
                name,
                rep.trace.max_nonlocal_msgs(),
                rep.trace.max_nonlocal_bytes(),
                seconds(rep.vtime)
            );
            nl_msgs.push(rep.trace.max_nonlocal_msgs());
        }
        if algo == Algorithm::LocalityBruck {
            // The §3 claim, asserted: identical non-local load per placement.
            assert!(
                nl_msgs.windows(2).all(|w| w[0] == w[1]),
                "loc-bruck non-local msgs must be placement-invariant: {nl_msgs:?}"
            );
            println!("placement-invariant non-local traffic ✓");
        } else {
            println!(
                "(standard Bruck: non-local traffic varies with placement: {nl_msgs:?})"
            );
        }
        println!();
    }
}
