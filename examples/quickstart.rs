//! Quickstart: the paper's Example 2.1, straight from the public API —
//! one-shot first, then the persistent-plan form.
//!
//! 16 processes in 4 regions of 4 each hold one value; after the allgather
//! every process holds all 16. We run the standard Bruck (Algorithm 1) and
//! the locality-aware Bruck (Algorithm 2), print the traffic each rank
//! generated, and check the paper's §3 claims:
//!
//! * standard Bruck: 4 non-local messages, 15 values non-local per rank;
//! * locality-aware: 1 non-local message, 4 values non-local per rank.
//!
//! ## One-shot vs. persistent
//!
//! `collectives::allgather(algo, comm, local)` is the one-shot door: it
//! plans, allocates the output and executes, every call — fine for a
//! script like this. A serving loop issuing the same-shape collective
//! millions of times should call `collectives::plan_allgather` once and
//! `AllgatherPlan::execute` per iteration: groups, sub-communicators,
//! schedules, tags and scratch are computed once at plan time (the second
//! half of this example; see also `examples/persistent_plan.rs`).
//!
//! Run with: `cargo run --release --example quickstart`

use locag::prelude::*;

fn main() {
    let topo = Topology::regions(4, 4);
    let machine = MachineParams::lassen();

    println!("=== Example 2.1: 16 ranks, 4 regions, 1 u32 value each ===\n");
    for algo in [Algorithm::Bruck, Algorithm::LocalityBruck] {
        let report = locag::sim::run_allgather(algo, &topo, &machine, 1);
        assert!(report.verified, "{algo} must verify: {:?}", report.errors);
        println!(
            "{}: modeled {:.2} us, max non-local msgs {}, max non-local bytes {}",
            algo,
            report.vtime * 1e6,
            report.trace.max_nonlocal_msgs(),
            report.trace.max_nonlocal_bytes()
        );
        print!("{}", report.trace.table());
        println!();
    }

    // The paper's §3 claims, asserted:
    let std = locag::sim::run_allgather(Algorithm::Bruck, &topo, &machine, 1);
    let loc = locag::sim::run_allgather(Algorithm::LocalityBruck, &topo, &machine, 1);
    assert_eq!(std.trace.max_nonlocal_msgs(), 4);
    assert_eq!(std.trace.max_nonlocal_bytes(), 15 * 4); // 15 u32 values
    assert_eq!(loc.trace.max_nonlocal_msgs(), 1);
    assert_eq!(loc.trace.max_nonlocal_bytes(), 4 * 4); // 4 u32 values
    assert!(loc.vtime < std.vtime);
    println!(
        "speedup (modeled, Lassen parameters): {:.2}x",
        std.vtime / loc.vtime
    );

    // Extended case (paper Fig. 6): 64 ranks, 16 regions -> 2 non-local steps.
    let topo64 = Topology::regions(16, 4);
    let loc64 = locag::sim::run_allgather(Algorithm::LocalityBruck, &topo64, &machine, 1);
    assert!(loc64.verified);
    assert_eq!(loc64.trace.max_nonlocal_msgs(), 2);
    println!("\n64 ranks / 16 regions: loc-bruck max non-local msgs = 2  (paper Fig. 6) ✓");

    // === The persistent form: plan once, execute many =====================
    //
    // The paper times its allgathers with communicators "created once
    // outside the timed region" (§5). `plan_allgather` is exactly that:
    // every rank plans once (collectively), then the loop body is pure
    // communication into caller-owned buffers.
    println!("\n=== Persistent plan: 1 plan, 1000 executions ===");
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let mut plan = locag::collectives::plan_allgather::<u32>(
            Algorithm::LocalityBruck,
            c,
            Shape::elems(1),
        )
        .expect("plan");
        let mut out = vec![0u32; 16];
        for round in 0..1000u32 {
            plan.execute(&[c.rank() as u32 + round], &mut out).expect("execute");
            // the gathered array shifts with the inputs, every time
            assert_eq!(out[15], 15 + round);
        }
        out[0]
    });
    assert!(run.results.iter().all(|&x| x == 999));
    println!("1000 executions of one LocalityBruck plan: all verified ✓");
    println!("(setup — groups, sub-communicators, schedules, tags, scratch — ran once)");
}
