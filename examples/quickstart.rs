//! Quickstart: the paper's Example 2.1, straight from the public API.
//!
//! 16 processes in 4 regions of 4 each hold one value; after the allgather
//! every process holds all 16. We run the standard Bruck (Algorithm 1) and
//! the locality-aware Bruck (Algorithm 2), print the traffic each rank
//! generated, and check the paper's §3 claims:
//!
//! * standard Bruck: 4 non-local messages, 15 values non-local per rank;
//! * locality-aware: 1 non-local message, 4 values non-local per rank.
//!
//! Run with: `cargo run --release --example quickstart`

use locag::prelude::*;

fn main() {
    let topo = Topology::regions(4, 4);
    let machine = MachineParams::lassen();

    println!("=== Example 2.1: 16 ranks, 4 regions, 1 u32 value each ===\n");
    for algo in [Algorithm::Bruck, Algorithm::LocalityBruck] {
        let report = locag::sim::run_allgather(algo, &topo, &machine, 1);
        assert!(report.verified, "{algo} must verify: {:?}", report.errors);
        println!(
            "{}: modeled {:.2} us, max non-local msgs {}, max non-local bytes {}",
            algo,
            report.vtime * 1e6,
            report.trace.max_nonlocal_msgs(),
            report.trace.max_nonlocal_bytes()
        );
        print!("{}", report.trace.table());
        println!();
    }

    // The paper's §3 claims, asserted:
    let std = locag::sim::run_allgather(Algorithm::Bruck, &topo, &machine, 1);
    let loc = locag::sim::run_allgather(Algorithm::LocalityBruck, &topo, &machine, 1);
    assert_eq!(std.trace.max_nonlocal_msgs(), 4);
    assert_eq!(std.trace.max_nonlocal_bytes(), 15 * 4); // 15 u32 values
    assert_eq!(loc.trace.max_nonlocal_msgs(), 1);
    assert_eq!(loc.trace.max_nonlocal_bytes(), 4 * 4); // 4 u32 values
    assert!(loc.vtime < std.vtime);
    println!(
        "speedup (modeled, Lassen parameters): {:.2}x",
        std.vtime / loc.vtime
    );

    // Extended case (paper Fig. 6): 64 ranks, 16 regions -> 2 non-local steps.
    let topo64 = Topology::regions(16, 4);
    let loc64 = locag::sim::run_allgather(Algorithm::LocalityBruck, &topo64, &machine, 1);
    assert!(loc64.verified);
    assert_eq!(loc64.trace.max_nonlocal_msgs(), 2);
    println!("\n64 ranks / 16 regions: loc-bruck max non-local msgs = 2  (paper Fig. 6) ✓");
}
