//! Bench: regenerate paper **Figure 7** — modeled standard vs
//! locality-aware Bruck across node counts for PPN ∈ {4, 8, 16, 32},
//! with the per-series speedup table the paper's discussion quotes.
//!
//! Run: `cargo bench --bench fig7_model`

use locag::bench_harness::figures;
use locag::model::closed_form::ModelConfig;

fn main() {
    std::fs::create_dir_all("results").expect("mkdir results");
    let fig = figures::fig7("results/fig7.csv").expect("fig7");
    println!("{}", fig.plot());
    println!("CSV: results/fig7.csv\n");

    // The paper's headline discussion: improvement amplifies with PPN.
    let cfg = ModelConfig::lassen();
    println!("modeled speedup (bruck / loc-bruck), m/p = 4 bytes:");
    println!("{:>8} {:>8} {:>8} {:>8} {:>8}", "nodes", "ppn=4", "ppn=8", "ppn=16", "ppn=32");
    let mut nodes = 4usize;
    while nodes <= 1 << 14 {
        print!("{nodes:>8}");
        for ppn in [4usize, 8, 16, 32] {
            let p = nodes * ppn;
            let s = cfg.bruck(p, 4) / cfg.loc_bruck(p, ppn, 4);
            print!(" {s:>8.2}");
        }
        println!();
        nodes *= 4;
    }
}
