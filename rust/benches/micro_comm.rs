//! Micro-bench: the mini-MPI transport hot paths — the perf-pass targets
//! for Layer 3 (EXPERIMENTS.md §Perf).
//!
//! * mailbox send→recv round trip (matching + wakeup cost)
//! * typed byte conversion (Pod fast path)
//! * world spawn/join overhead per rank
//! * sub-communicator construction
//!
//! Run: `cargo bench --bench micro_comm`

use locag::bench_harness::{measure_budget, Measurement};
use locag::comm::{from_bytes, to_bytes, CommWorld, Timing};
use locag::topology::Topology;

fn report(m: &Measurement) {
    println!("{}", m.report_line());
}

fn main() {
    // 1. byte conversion throughput
    for elems in [16usize, 1024, 65536] {
        let xs: Vec<u64> = (0..elems as u64).collect();
        let m = measure_budget(&format!("pod/to_bytes+from/{elems}x8B"), 10, 0.25, 50, || {
            let b = to_bytes(&xs);
            let back: Vec<u64> = from_bytes(&b).unwrap();
            std::hint::black_box(back.len());
        });
        report(&m);
    }

    // 2. send/recv round trips inside a live world (pair of ranks),
    //    measured from inside the closure to exclude spawn cost.
    for size in [8usize, 4096, 262144] {
        let topo = Topology::regions(1, 2);
        let payload = vec![1u8; size];
        let m = measure_budget(&format!("mailbox/roundtrip/{size}B"), 2, 0.3, 10, || {
            let p = payload.clone();
            let run = CommWorld::run(&topo, Timing::Wallclock, move |c| {
                let mut acc = 0usize;
                for tag in 0..64u64 {
                    if c.rank() == 0 {
                        c.send(&p, 1, tag).unwrap();
                        acc += c.recv::<u8>(1, tag).unwrap().len();
                    } else {
                        let got: Vec<u8> = c.recv(0, tag).unwrap();
                        c.send(&got, 0, tag).unwrap();
                    }
                }
                acc
            });
            std::hint::black_box(run.results[0]);
        });
        // 64 round trips per iteration
        println!("{}   (/64 = per round trip)", m.report_line());
    }

    // 3. world spawn/join overhead
    for ranks in [4usize, 64, 256] {
        let topo = Topology::regions(1, ranks);
        let m = measure_budget(&format!("world/spawn_join/{ranks}r"), 1, 0.4, 5, || {
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| c.rank());
            std::hint::black_box(run.results.len());
        });
        report(&m);
    }

    // 4. sub-communicator construction inside a 64-rank world
    let topo = Topology::regions(8, 8);
    let m = measure_budget("comm/split_regions/64r", 1, 0.4, 5, || {
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            for _ in 0..16 {
                let local = c.split_regions().unwrap();
                std::hint::black_box(local.size());
            }
        });
        std::hint::black_box(run.results.len());
    });
    println!("{}   (/16 = per split)", m.report_line());
}
