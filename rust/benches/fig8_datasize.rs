//! Bench: regenerate paper **Figure 8** — modeled cost vs per-process
//! data size on 1024 regions × 16 ppn.
//!
//! The paper's observation: "The size of data has no notable modeled
//! effect on the improvements" — printed as the ratio column.
//!
//! Run: `cargo bench --bench fig8_datasize`

use locag::bench_harness::figures;
use locag::model::closed_form::ModelConfig;
use locag::util::fmt::bytes;

fn main() {
    std::fs::create_dir_all("results").expect("mkdir results");
    let fig = figures::fig8("results/fig8.csv").expect("fig8");
    println!("{}", fig.plot());
    println!("CSV: results/fig8.csv\n");

    let cfg = ModelConfig::lassen();
    let (regions, ppn) = (1024usize, 16usize);
    let p = regions * ppn;
    println!("{:>12} {:>12} {:>12} {:>8}", "bytes/proc", "bruck", "loc-bruck", "ratio");
    let mut n = 4usize;
    while n <= 64 * 1024 {
        let a = cfg.bruck(p, n);
        let b = cfg.loc_bruck(p, ppn, n);
        println!(
            "{:>12} {:>12} {:>12} {:>8.2}",
            bytes(n),
            format!("{a:.3e}"),
            format!("{b:.3e}"),
            a / b
        );
        n *= 4;
    }
}
