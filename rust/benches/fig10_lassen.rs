//! Bench: regenerate paper **Figure 10** — measured allgather cost on
//! Lassen (socket regions, one socket per node used): all algorithms vs
//! the system-MPI baseline.
//!
//! Same virtual-time methodology as Figure 9, under the Lassen machine
//! model whose inter-node/intra-socket gap is wider — the paper's setting
//! where locality-awareness pays the most.
//!
//! Run: `cargo bench --bench fig10_lassen` (env `LOCAG_MAX_P` to extend)

use locag::bench_harness::figures;
use locag::collectives::Algorithm;
use locag::model::MachineParams;
use locag::sim;
use locag::topology::Topology;
use locag::transport::Backend;

fn main() {
    std::fs::create_dir_all("results").expect("mkdir results");
    let max_p = std::env::var("LOCAG_MAX_P")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024);
    let fig = figures::fig10("results/fig10.csv", max_p, Backend::Sim).expect("fig10");
    println!("{}", fig.plot());
    println!("CSV: results/fig10.csv");

    // Speedup of loc-bruck over the system default at the largest scale
    // per ppn — the number the paper's conclusion cites.
    println!("\nloc-bruck speedup over system-default (largest region count per ppn):");
    for ppn in [4usize, 16] {
        let regions = {
            let mut r = 2usize;
            while r * 2 * ppn <= max_p {
                r *= 2;
            }
            r
        };
        let topo = Topology::regions(regions, ppn);
        let m = MachineParams::lassen();
        let sys = sim::run_allgather(Algorithm::SystemDefault, &topo, &m, 2);
        let loc = sim::run_allgather(Algorithm::LocalityBruck, &topo, &m, 2);
        assert!(sys.verified && loc.verified);
        println!(
            "  ppn={ppn:<3} regions={regions:<5} speedup {:.2}x",
            sys.vtime / loc.vtime
        );
    }
}
