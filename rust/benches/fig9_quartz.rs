//! Bench: regenerate paper **Figure 9** — measured allgather cost on
//! Quartz (node regions): MVAPICH2-default vs Bruck vs hierarchical vs
//! multi-lane vs locality-aware, PPN ∈ {4, 16}, two 4-byte ints/proc.
//!
//! "Measured" here = virtual-time execution of the real `Isend/Irecv`
//! implementations under the Quartz machine model (the off-testbed
//! substitution; DESIGN.md §Hardware-Adaptation). Every data point is
//! correctness-verified before its time is reported.
//!
//! Run: `cargo bench --bench fig9_quartz` (env `LOCAG_MAX_P` to extend)

use locag::bench_harness::figures;
use locag::transport::Backend;

fn main() {
    std::fs::create_dir_all("results").expect("mkdir results");
    let max_p = std::env::var("LOCAG_MAX_P")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024);
    let fig = figures::fig9("results/fig9.csv", max_p, Backend::Sim).expect("fig9");
    println!("{}", fig.plot());
    println!("CSV: results/fig9.csv");

    // Winner table per (ppn, regions): the paper's qualitative claim is
    // that loc-bruck wins at scale and the margin grows with ppn.
    println!("\nfastest algorithm per configuration:");
    for (label, pts) in &fig.series {
        let last = pts.last().map(|&(x, y)| format!("{y:.2e}s @ {x} regions")).unwrap_or_default();
        println!("  {label:<28} {last}");
    }
}
