//! Micro-bench: wall-clock cost of each allgather implementation on the
//! in-process transport — the Layer-3 perf-pass scoreboard
//! (EXPERIMENTS.md §Perf). Virtual-time figures live in fig9/fig10; this
//! bench measures what the *implementations themselves* cost.
//!
//! Run: `cargo bench --bench micro_collectives`

use locag::bench_harness::measure_budget;
use locag::collectives::{self, Algorithm, FuseSpec, OpKind, Shape};
use locag::comm::{CommWorld, Timing};
use locag::topology::Topology;

fn main() {
    let shapes = [(8usize, 4usize, 2usize), (8, 4, 1024), (16, 8, 2)];
    for (regions, ppr, n) in shapes {
        let topo = Topology::regions(regions, ppr);
        println!(
            "== {} ranks ({regions} regions x {ppr}), {n} u64/rank ==",
            topo.size()
        );
        for algo in [
            Algorithm::Bruck,
            Algorithm::Ring,
            Algorithm::Dissemination,
            Algorithm::Hierarchical,
            Algorithm::Multilane,
            Algorithm::LocalityBruck,
        ] {
            let m = measure_budget(
                &format!("{}/{}x{}x{}", algo.name(), regions, ppr, n),
                1,
                0.3,
                5,
                || {
                    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                        let mine = collectives::canonical_contribution(c.rank(), n);
                        collectives::allgather(algo, c, &mine).unwrap().len()
                    });
                    std::hint::black_box(run.results[0]);
                },
            );
            println!("{}", m.report_line());
        }
        println!();
    }

    // Planned vs one-shot: the amortization the persistent API buys. Each
    // iteration runs EXECS operations inside a live world; the planned
    // variant plans once outside the measured loop shape (per world), the
    // one-shot variant re-plans and re-allocates per operation.
    const EXECS: usize = 64;
    for (regions, ppr, n) in [(8usize, 4usize, 2usize), (8, 4, 1024)] {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        for algo in [Algorithm::Bruck, Algorithm::LocalityBruck] {
            let m = measure_budget(
                &format!("one-shot/{}/{}x{}x{}x{}ops", algo.name(), regions, ppr, n, EXECS),
                1,
                0.3,
                5,
                || {
                    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                        let mine = collectives::canonical_contribution(c.rank(), n);
                        let mut acc = 0usize;
                        for _ in 0..EXECS {
                            acc += collectives::allgather(algo, c, &mine).unwrap().len();
                        }
                        acc
                    });
                    std::hint::black_box(run.results[0]);
                },
            );
            println!("{}", m.report_line());
            let m = measure_budget(
                &format!("planned /{}/{}x{}x{}x{}ops", algo.name(), regions, ppr, n, EXECS),
                1,
                0.3,
                5,
                || {
                    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                        let mine = collectives::canonical_contribution(c.rank(), n);
                        let mut plan = collectives::plan_allgather::<u64>(
                            algo,
                            c,
                            Shape::elems(n),
                        )
                        .unwrap();
                        let mut out = vec![0u64; n * p];
                        for _ in 0..EXECS {
                            plan.execute(&mine, &mut out).unwrap();
                        }
                        out.len()
                    });
                    std::hint::black_box(run.results[0]);
                },
            );
            println!("{}", m.report_line());
        }
        println!();
    }

    // The other planned operations: one-shot vs planned for allreduce and
    // alltoall (the PR-2 op-generic framework on the same scoreboard).
    for (regions, ppr, n) in [(8usize, 4usize, 2usize), (8, 4, 1024)] {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        for (op, algo) in [("allreduce", "loc-aware"), ("alltoall", "loc-aware")] {
            let m = measure_budget(
                &format!("one-shot/{op}-{algo}/{regions}x{ppr}x{n}x{EXECS}ops"),
                1,
                0.3,
                5,
                || {
                    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                        let mut acc = 0usize;
                        if op == "allreduce" {
                            let mine = vec![c.rank() as u64; n];
                            for _ in 0..EXECS {
                                acc += locag::collectives::allreduce::allreduce_locality_aware(
                                    c, &mine,
                                )
                                .unwrap()
                                .len();
                            }
                        } else {
                            let mine = vec![c.rank() as u64; n * p];
                            for _ in 0..EXECS {
                                acc += locag::collectives::alltoall::loc_aware(c, &mine)
                                    .unwrap()
                                    .len();
                            }
                        }
                        acc
                    });
                    std::hint::black_box(run.results[0]);
                },
            );
            println!("{}", m.report_line());
            let m = measure_budget(
                &format!("planned /{op}-{algo}/{regions}x{ppr}x{n}x{EXECS}ops"),
                1,
                0.3,
                5,
                || {
                    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                        if op == "allreduce" {
                            let mut plan = locag::collectives::plan_allreduce::<u64>(
                                algo,
                                c,
                                Shape::elems(n),
                            )
                            .unwrap();
                            let mine = vec![c.rank() as u64; n];
                            let mut out = vec![0u64; n];
                            for _ in 0..EXECS {
                                plan.execute(&mine, &mut out).unwrap();
                            }
                            out.len()
                        } else {
                            let mut plan = locag::collectives::plan_alltoall::<u64>(
                                algo,
                                c,
                                Shape::elems(n),
                            )
                            .unwrap();
                            let mine = vec![c.rank() as u64; n * p];
                            let mut out = vec![0u64; n * p];
                            for _ in 0..EXECS {
                                plan.execute(&mine, &mut out).unwrap();
                            }
                            out.len()
                        }
                    });
                    std::hint::black_box(run.results[0]);
                },
            );
            println!("{}", m.report_line());
        }
        println!();
    }

    // Staged vs zero-copy execution of one fused serving-shaped plan
    // (K allgathers ⊕ reduce-scatter shard ⊕ consensus allreduce): the
    // identical schedule, executed through the composite staging buffers
    // vs through segmented views of the caller's buffers. The delta is
    // purely the staging memcpys the view path eliminates.
    for (regions, ppr, k, n) in [(2usize, 2usize, 4usize, 1024usize), (4, 4, 4, 1024)] {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        let mut specs: Vec<FuseSpec> =
            (0..k).map(|_| FuseSpec::new(OpKind::Allgather, "loc-bruck", n)).collect();
        specs.push(FuseSpec::new(OpKind::ReduceScatter, "ring", 16));
        specs.push(FuseSpec::new(OpKind::Allreduce, "loc-aware", 2 * k));
        for staged in [true, false] {
            let label = if staged { "fused-staged " } else { "fused-zerocopy" };
            let m = measure_budget(
                &format!("{label}/{regions}x{ppr}x{n}x{k}batch-{EXECS}ops"),
                1,
                0.3,
                5,
                || {
                    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                        let mut plan = collectives::plan_fused::<u64>(c, &specs).unwrap();
                        let ins: Vec<Vec<u64>> = specs
                            .iter()
                            .map(|s| {
                                let il = match s.op {
                                    OpKind::Allgather | OpKind::Allreduce => s.n,
                                    OpKind::Alltoall | OpKind::ReduceScatter => s.n * p,
                                };
                                vec![c.rank() as u64 + 1; il]
                            })
                            .collect();
                        let mut outs: Vec<Vec<u64>> = specs
                            .iter()
                            .map(|s| {
                                let ol = match s.op {
                                    OpKind::Allgather | OpKind::Alltoall => s.n * p,
                                    OpKind::Allreduce | OpKind::ReduceScatter => s.n,
                                };
                                vec![0u64; ol]
                            })
                            .collect();
                        for _ in 0..EXECS {
                            let in_refs: Vec<&[u64]> = ins.iter().map(|v| v.as_slice()).collect();
                            let mut out_refs: Vec<&mut [u64]> =
                                outs.iter_mut().map(|v| v.as_mut_slice()).collect();
                            if staged {
                                plan.execute(&in_refs, &mut out_refs).unwrap();
                            } else {
                                plan.execute_view(&in_refs, &mut out_refs).unwrap();
                            }
                        }
                        outs[0][0]
                    });
                    std::hint::black_box(run.results[0]);
                },
            );
            println!("{}", m.report_line());
        }
        println!();
    }

    // The rotation hot spot on its own (the L1 kernel's Rust twin).
    for (p, n) in [(64usize, 1024usize), (1024, 64)] {
        let data: Vec<u64> = (0..(p * n) as u64).collect();
        let m = measure_budget(&format!("rotate_down/{p}x{n}"), 10, 0.25, 50, || {
            let out = collectives::bruck::rotate_down(&data, n, p / 3);
            std::hint::black_box(out.len());
        });
        println!("{}", m.report_line());
    }
}
