//! Bench: regenerate paper **Figure 3** — ping-pong cost by locality class.
//!
//! Prints the modeled (machine-preset) series that parameterize every
//! other experiment, and additionally wall-clock-measures a real 2-rank
//! mailbox ping-pong at each size so the transport's own overhead is on
//! record (EXPERIMENTS.md §Fig3).
//!
//! Run: `cargo bench --bench fig3_pingpong`

use locag::bench_harness::{figures, measure_budget};
use locag::comm::{CommWorld, Timing};
use locag::topology::Topology;

fn main() {
    std::fs::create_dir_all("results").expect("mkdir results");
    let fig = figures::fig3("results/fig3.csv").expect("fig3");
    println!("{}", fig.plot());
    println!("CSV: results/fig3.csv\n");

    // Wall-clock transport ping-pong (single machine — one series).
    println!("transport wall-clock ping-pong (shared-memory mailboxes, 8 round trips/iter):");
    let topo = Topology::regions(1, 2);
    for size in [4usize, 64, 1024, 16 * 1024, 256 * 1024, 1024 * 1024] {
        let payload = vec![0u8; size];
        let m = measure_budget(&format!("pingpong/{size}B"), 3, 0.2, 10, || {
            let p = payload.clone();
            let run = CommWorld::run(&topo, Timing::Wallclock, move |c| {
                for tag in 0..8u64 {
                    if c.rank() == 0 {
                        c.send(&p, 1, tag).unwrap();
                        c.recv::<u8>(1, tag).unwrap();
                    } else {
                        let got: Vec<u8> = c.recv(0, tag).unwrap();
                        c.send(&got, 0, tag).unwrap();
                    }
                }
            });
            std::hint::black_box(run.vtimes.len());
        });
        println!("{}", m.report_line());
    }
}
