//! Fusion conformance: executing collectives through a fused,
//! message-coalesced schedule is **bit-identical** to executing them
//! sequentially — for every registered (operation, algorithm) pair over
//! the conformance grid, for heterogeneous combinations, and for `n = 0`
//! constituents.
//!
//! Pairs that legitimately reject a shape (power-of-two preconditions)
//! must reject fused planning too, at plan time, with the same
//! precondition — rejection parity between the fused and sequential
//! paths. The suite fails if any registered pair was never successfully
//! executed fused (100% registry coverage, like the per-op conformance
//! suite).

use std::collections::BTreeSet;

use locag::collectives::{
    self, AllreduceRegistry, AlltoallRegistry, FuseSpec, OpKind, ReduceScatterRegistry, Registry,
    Shape,
};
use locag::comm::{Comm, CommWorld, Timing};
use locag::topology::Topology;

/// (regions, ranks-per-region): powers of two, non-powers, degenerate —
/// the same grid as `collective_conformance`.
const SHAPES: &[(usize, usize)] = &[
    (1, 1),
    (1, 4),
    (2, 2),
    (4, 4),
    (3, 2),
    (5, 2),
    (2, 3),
    (3, 3),
    (8, 4),
];

const NS: &[usize] = &[0, 1, 3];

/// Salted canonical inputs: two fused instances of the same pair carry
/// different data, so block placement mistakes across the composite
/// buffer space are visible.
fn input_for(op: OpKind, rank: usize, p: usize, n: usize, salt: usize) -> Vec<u64> {
    match op {
        OpKind::Allgather => {
            (0..n).map(|j| (rank * 1_000_003 + j + salt * 7919) as u64).collect()
        }
        OpKind::Allreduce => (0..n).map(|j| (rank * 131_071 + j + salt * 13) as u64).collect(),
        OpKind::Alltoall | OpKind::ReduceScatter => {
            let b = n.max(1);
            (0..p * n)
                .map(|x| (rank * 1_000_003 + (x / b) * 1_009 + x % b + salt * 7919) as u64)
                .collect()
        }
    }
}

fn out_len(op: OpKind, p: usize, n: usize) -> usize {
    match op {
        OpKind::Allgather | OpKind::Alltoall => n * p,
        OpKind::Allreduce | OpKind::ReduceScatter => n,
    }
}

/// Execute one (op, algo) pair sequentially through its registry plan.
fn run_sequential(
    c: &Comm,
    op: OpKind,
    name: &str,
    n: usize,
    input: &[u64],
    out: &mut [u64],
) -> locag::error::Result<()> {
    match op {
        OpKind::Allgather => {
            let mut plan = Registry::<u64>::standard().plan_uniform(name, c, Shape::elems(n))?;
            plan.execute(input, out)
        }
        OpKind::Allreduce => {
            let mut plan =
                AllreduceRegistry::<u64>::standard().plan_uniform(name, c, Shape::elems(n))?;
            plan.execute(input, out)
        }
        OpKind::Alltoall => {
            let mut plan =
                AlltoallRegistry::<u64>::standard().plan_uniform(name, c, Shape::elems(n))?;
            plan.execute(input, out)
        }
        OpKind::ReduceScatter => {
            let mut plan =
                ReduceScatterRegistry::<u64>::standard().plan_uniform(name, c, Shape::elems(n))?;
            plan.execute(input, out)
        }
    }
}

/// Fused-vs-sequential execution of `specs` (salted per constituent) in
/// one world. Returns the plan-time rejection message, if any — asserting
/// in-world that fused and sequential agree bit-for-bit when both plan,
/// and that they reject together when they don't.
fn run_specs(topo: &Topology, specs: &[FuseSpec]) -> Vec<Option<String>> {
    let p = topo.size();
    let run = CommWorld::run(topo, Timing::Wallclock, |c| -> Option<String> {
        let fused = collectives::plan_fused::<u64>(c, specs);
        // Sequential side: plan every constituent through its registry.
        let mut seq_outs: Vec<Vec<u64>> = Vec::new();
        let mut seq_err: Option<String> = None;
        for (i, s) in specs.iter().enumerate() {
            let input = input_for(s.op, c.rank(), p, s.n, i);
            let mut out = vec![0u64; out_len(s.op, p, s.n)];
            match run_sequential(c, s.op, &s.algo, s.n, &input, &mut out) {
                Ok(()) => seq_outs.push(out),
                Err(e) => {
                    seq_err = Some(e.to_string());
                    break;
                }
            }
        }
        match (fused, seq_err) {
            (Ok(mut plan), None) => {
                let ins: Vec<Vec<u64>> = specs
                    .iter()
                    .enumerate()
                    .map(|(i, s)| input_for(s.op, c.rank(), p, s.n, i))
                    .collect();
                let mut outs: Vec<Vec<u64>> =
                    specs.iter().map(|s| vec![0u64; out_len(s.op, p, s.n)]).collect();
                {
                    let in_refs: Vec<&[u64]> = ins.iter().map(|v| v.as_slice()).collect();
                    let mut out_refs: Vec<&mut [u64]> =
                        outs.iter_mut().map(|v| v.as_mut_slice()).collect();
                    plan.execute(&in_refs, &mut out_refs).unwrap();
                }
                assert_eq!(outs, seq_outs, "fused != sequential (rank {})", c.rank());
                None
            }
            (Err(fe), Some(se)) => {
                // Rejection parity: both reject, both for the documented
                // power-of-two precondition.
                let fe = fe.to_string();
                assert!(fe.contains("power-of-two"), "fused rejection: {fe} (seq: {se})");
                assert!(se.contains("power-of-two"), "sequential rejection: {se}");
                Some(fe)
            }
            (Ok(_), Some(se)) => panic!("sequential rejected but fused planned: {se}"),
            (Err(fe), None) => panic!("fused rejected but sequential planned: {fe}"),
        }
    });
    run.results
}

#[test]
fn fused_pair_matches_sequential_for_every_registered_algorithm() {
    let mut covered: BTreeSet<String> = BTreeSet::new();
    let pairs: Vec<(OpKind, &'static str)> = {
        let mut v = Vec::new();
        for name in Registry::<u64>::standard().names() {
            v.push((OpKind::Allgather, name));
        }
        for name in AllreduceRegistry::<u64>::standard().names() {
            v.push((OpKind::Allreduce, name));
        }
        for name in AlltoallRegistry::<u64>::standard().names() {
            v.push((OpKind::Alltoall, name));
        }
        for name in ReduceScatterRegistry::<u64>::standard().names() {
            v.push((OpKind::ReduceScatter, name));
        }
        v
    };
    for &(regions, ppr) in SHAPES {
        let topo = Topology::regions(regions, ppr);
        for &n in NS {
            for &(op, name) in &pairs {
                // Two instances of the pair, fused, with distinct data.
                let specs = vec![FuseSpec::new(op, name, n), FuseSpec::new(op, name, n)];
                let results = run_specs(&topo, &specs);
                for (rank, r) in results.iter().enumerate() {
                    assert_eq!(r, &results[0], "rank {rank} diverged: {op}/{name}");
                }
                if results[0].is_none() {
                    covered.insert(format!("{op}/{name}"));
                }
            }
        }
    }
    let missing: Vec<String> = pairs
        .iter()
        .map(|(op, name)| format!("{op}/{name}"))
        .filter(|k| !covered.contains(k))
        .collect();
    assert!(missing.is_empty(), "pairs never executed fused: {missing:?}");
}

#[test]
fn heterogeneous_fusion_matches_sequential() {
    // The serving-loop shape (allgather ⊕ allreduce) and a three-op mix.
    for &(regions, ppr) in &[(2usize, 8usize), (4, 4), (8, 4)] {
        let topo = Topology::regions(regions, ppr);
        let specs = vec![
            FuseSpec::new(OpKind::Allgather, "loc-bruck", 4),
            FuseSpec::new(OpKind::Allreduce, "loc-aware", 2),
        ];
        for r in run_specs(&topo, &specs) {
            assert!(r.is_none(), "unexpected rejection at {regions}x{ppr}: {r:?}");
        }
    }
    for &(regions, ppr) in &[(2usize, 2usize), (4, 4)] {
        let topo = Topology::regions(regions, ppr);
        let specs = vec![
            FuseSpec::new(OpKind::Allgather, "bruck", 3),
            FuseSpec::new(OpKind::Allreduce, "recursive-doubling", 2),
            FuseSpec::new(OpKind::Alltoall, "pairwise", 1),
        ];
        for r in run_specs(&topo, &specs) {
            assert!(r.is_none(), "unexpected rejection at {regions}x{ppr}: {r:?}");
        }
    }
    // The inverse-sibling pairing: an allgather fused with the
    // reduce-scatter that mirrors it, plus the any-size Rabenseifner.
    for &(regions, ppr) in &[(4usize, 4usize), (3, 3), (2, 8)] {
        let topo = Topology::regions(regions, ppr);
        let specs = vec![
            FuseSpec::new(OpKind::Allgather, "loc-bruck", 2),
            FuseSpec::new(OpKind::ReduceScatter, "loc-aware", 2),
            FuseSpec::new(OpKind::Allreduce, "rabenseifner", 3),
        ];
        for r in run_specs(&topo, &specs) {
            assert!(r.is_none(), "unexpected rejection at {regions}x{ppr}: {r:?}");
        }
    }
}

#[test]
fn zero_length_constituents_are_uniform_no_ops() {
    // n = 0 constituents ride along with empty buffers and no messages.
    let topo = Topology::regions(3, 3);
    let specs = vec![
        FuseSpec::new(OpKind::Allgather, "bruck", 2),
        FuseSpec::new(OpKind::Allreduce, "recursive-doubling", 0),
        FuseSpec::new(OpKind::Alltoall, "bruck", 0),
    ];
    for r in run_specs(&topo, &specs) {
        assert!(r.is_none(), "{r:?}");
    }

    // All-zero fusion sends nothing at all.
    let specs = vec![
        FuseSpec::new(OpKind::Allgather, "loc-bruck", 0),
        FuseSpec::new(OpKind::Allreduce, "loc-aware", 0),
    ];
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let mut plan = collectives::plan_fused::<u64>(c, &specs).unwrap();
        let ins: Vec<Vec<u64>> = vec![Vec::new(), Vec::new()];
        let mut outs: Vec<Vec<u64>> = vec![Vec::new(), Vec::new()];
        let in_refs: Vec<&[u64]> = ins.iter().map(|v| v.as_slice()).collect();
        let mut out_refs: Vec<&mut [u64]> = outs.iter_mut().map(|v| v.as_mut_slice()).collect();
        plan.execute(&in_refs, &mut out_refs).unwrap();
        outs.iter().all(|o| o.is_empty())
    });
    assert!(run.results.iter().all(|&ok| ok));
    let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
    assert_eq!(total, 0, "all-zero fusion must send no messages");
}

#[test]
fn fused_plan_validates_buffer_counts_and_lengths() {
    let topo = Topology::regions(2, 2);
    let specs = vec![
        FuseSpec::new(OpKind::Allgather, "bruck", 2),
        FuseSpec::new(OpKind::Allreduce, "recursive-doubling", 1),
    ];
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let mut plan = collectives::plan_fused::<u64>(c, &specs).unwrap();
        let a = [1u64; 2];
        let b = [1u64; 1];
        let mut ga = [0u64; 8];
        let mut gb = [0u64; 1];
        // wrong arity
        let mut bad = 0usize;
        bad += plan.execute(&[&a], &mut [&mut ga, &mut gb]).is_err() as usize;
        // wrong input length for constituent 0
        bad += plan.execute(&[&b, &b], &mut [&mut ga, &mut gb]).is_err() as usize;
        // wrong output length for constituent 1
        bad += plan.execute(&[&a, &b], &mut [&mut ga, &mut [0u64; 2][..]]).is_err() as usize;
        // and the correct call still succeeds afterwards
        plan.execute(&[&a, &b], &mut [&mut ga, &mut gb]).unwrap();
        bad
    });
    assert!(run.results.iter().all(|&b| b == 3));
}
