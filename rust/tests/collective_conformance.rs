//! Conformance: every registered (operation, algorithm) pair, executed
//! against a naive reference over a grid of world shapes and payload
//! sizes.
//!
//! The grid covers `n = 0` (the uniform no-op contract), `n = 1`, a
//! multi-element payload, power-of-two and non-power-of-two rank counts,
//! single-rank and single-region degenerate topologies. Algorithms that
//! legitimately reject a shape (recursive doubling and its allreduce /
//! fallback twins on non-power-of-two sizes) must reject **at plan time**,
//! uniformly on every rank, with a precondition error naming the
//! power-of-two requirement — and must still plan the `n = 0` no-op.
//!
//! The suite fails if any registered pair was never successfully executed
//! (100% registry coverage), so registering a new algorithm without
//! conformance coverage is impossible.

use std::collections::BTreeSet;

use locag::collectives::{
    canonical_contribution, expected_result, AllreduceRegistry, AlltoallRegistry, OpKind,
    ReduceScatterRegistry, Registry, Schedule, Shape,
};
use locag::comm::{CommWorld, Timing};
use locag::model::cost;
use locag::topology::Topology;
use locag::trace::RankTrace;

/// (regions, ranks-per-region): powers of two, non-powers, degenerate.
const SHAPES: &[(usize, usize)] = &[
    (1, 1),
    (1, 4),
    (2, 2),
    (4, 4),
    (3, 2),
    (5, 2),
    (2, 3),
    (3, 3),
    (8, 4),
];

/// Payload sizes, including the zero-length contract and a single element.
const NS: &[usize] = &[0, 1, 3];

fn ar_contribution(rank: usize, n: usize) -> Vec<u64> {
    (0..n).map(|j| (rank * 131_071 + j) as u64).collect()
}

fn ar_expected(p: usize, n: usize) -> Vec<u64> {
    (0..n)
        .map(|j| (0..p).map(|r| (r * 131_071 + j) as u64).sum())
        .collect()
}

fn a2a_send(rank: usize, p: usize, n: usize) -> Vec<u64> {
    (0..p * n)
        .map(|x| (rank * 1_000_003 + (x / n.max(1)) * 1_009 + x % n.max(1)) as u64)
        .collect()
}

fn a2a_expected(rank: usize, p: usize, n: usize) -> Vec<u64> {
    (0..p * n)
        .map(|x| ((x / n.max(1)) * 1_000_003 + rank * 1_009 + x % n.max(1)) as u64)
        .collect()
}

/// Reduce-scatter consumes the same `n·p` block layout as alltoall
/// ([`a2a_send`]); rank `i` receives the sum over ranks of block `i`.
fn rs_expected(rank: usize, p: usize, n: usize) -> Vec<u64> {
    (0..n)
        .map(|j| (0..p).map(|r| (r * 1_000_003 + rank * 1_009 + j) as u64).sum())
        .collect()
}

/// Outcome of one (op, algorithm) attempt on one rank: registry key plus
/// the plan-time rejection message, if any.
type Outcome = (String, Option<String>);

/// Run every registered pair of every op over one world; execution
/// results are asserted in-world against the naive references.
fn run_grid_point(regions: usize, ppr: usize, n: usize) -> Vec<Vec<Outcome>> {
    let topo = Topology::regions(regions, ppr);
    let p = topo.size();
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| -> Vec<Outcome> {
        let mut outcomes = Vec::new();

        let reg = Registry::<u64>::standard();
        for name in reg.names() {
            let err = match reg.plan(name, c, Shape::elems(n)) {
                Err(e) => Some(e.to_string()),
                Ok(mut plan) => {
                    assert_eq!(plan.algorithm(), name);
                    assert_eq!(plan.shape(), Shape::elems(n));
                    assert_eq!(plan.comm_size(), p);
                    let mine = canonical_contribution(c.rank(), n);
                    let mut out = vec![0u64; n * p];
                    plan.execute(&mine, &mut out).unwrap();
                    assert_eq!(
                        out,
                        expected_result(p, n),
                        "allgather/{name} {regions}x{ppr} n={n} rank {}",
                        c.rank()
                    );
                    None
                }
            };
            outcomes.push((format!("allgather/{name}"), err));
        }

        let reg = AllreduceRegistry::<u64>::standard();
        for name in reg.names() {
            let err = match reg.plan(name, c, Shape::elems(n)) {
                Err(e) => Some(e.to_string()),
                Ok(mut plan) => {
                    assert_eq!(plan.algorithm(), name);
                    assert_eq!(plan.comm_size(), p);
                    let mine = ar_contribution(c.rank(), n);
                    let mut out = vec![0u64; n];
                    plan.execute(&mine, &mut out).unwrap();
                    assert_eq!(
                        out,
                        ar_expected(p, n),
                        "allreduce/{name} {regions}x{ppr} n={n} rank {}",
                        c.rank()
                    );
                    None
                }
            };
            outcomes.push((format!("allreduce/{name}"), err));
        }

        let reg = AlltoallRegistry::<u64>::standard();
        for name in reg.names() {
            let err = match reg.plan(name, c, Shape::elems(n)) {
                Err(e) => Some(e.to_string()),
                Ok(mut plan) => {
                    assert_eq!(plan.algorithm(), name);
                    assert_eq!(plan.comm_size(), p);
                    let mine = a2a_send(c.rank(), p, n);
                    let mut out = vec![0u64; n * p];
                    plan.execute(&mine, &mut out).unwrap();
                    assert_eq!(
                        out,
                        a2a_expected(c.rank(), p, n),
                        "alltoall/{name} {regions}x{ppr} n={n} rank {}",
                        c.rank()
                    );
                    None
                }
            };
            outcomes.push((format!("alltoall/{name}"), err));
        }

        let reg = ReduceScatterRegistry::<u64>::standard();
        for name in reg.names() {
            let err = match reg.plan(name, c, Shape::elems(n)) {
                Err(e) => Some(e.to_string()),
                Ok(mut plan) => {
                    assert_eq!(plan.algorithm(), name);
                    assert_eq!(plan.comm_size(), p);
                    let mine = a2a_send(c.rank(), p, n);
                    let mut out = vec![0u64; n];
                    plan.execute(&mine, &mut out).unwrap();
                    assert_eq!(
                        out,
                        rs_expected(c.rank(), p, n),
                        "reduce-scatter/{name} {regions}x{ppr} n={n} rank {}",
                        c.rank()
                    );
                    None
                }
            };
            outcomes.push((format!("reduce-scatter/{name}"), err));
        }
        outcomes
    });
    run.results
}

/// Every registry name, keyed `op/name` — the 100%-coverage target.
fn all_registered_pairs() -> BTreeSet<String> {
    let mut want = BTreeSet::new();
    for name in Registry::<u64>::standard().names() {
        want.insert(format!("allgather/{name}"));
    }
    for name in AllreduceRegistry::<u64>::standard().names() {
        want.insert(format!("allreduce/{name}"));
    }
    for name in AlltoallRegistry::<u64>::standard().names() {
        want.insert(format!("alltoall/{name}"));
    }
    for name in ReduceScatterRegistry::<u64>::standard().names() {
        want.insert(format!("reduce-scatter/{name}"));
    }
    want
}

#[test]
fn every_registered_pair_conforms_over_the_grid() {
    let mut covered: BTreeSet<String> = BTreeSet::new();
    for &(regions, ppr) in SHAPES {
        let p = regions * ppr;
        for &n in NS {
            let results = run_grid_point(regions, ppr, n);
            // Plan outcomes (including error text) are identical on every
            // rank: planning is collective and deterministic.
            for (rank, r) in results.iter().enumerate() {
                assert_eq!(
                    r, &results[0],
                    "rank {rank} diverged at {regions}x{ppr} n={n}"
                );
            }
            for (key, err) in &results[0] {
                match err {
                    None => {
                        covered.insert(key.clone());
                    }
                    Some(msg) => {
                        // A legitimate rejection: explicit, plan-time, and
                        // only for the documented precondition.
                        assert!(n > 0, "{key} rejected the n=0 no-op: {msg}");
                        assert!(
                            msg.contains("power-of-two"),
                            "{key} @ {regions}x{ppr} n={n}: unexpected rejection: {msg}"
                        );
                        assert!(
                            !p.is_power_of_two(),
                            "{key} @ {regions}x{ppr} (p={p} IS a power of two) n={n}: {msg}"
                        );
                    }
                }
            }
        }
    }
    // 100% of registered (op, algorithm) pairs executed successfully on
    // at least one grid shape.
    let want = all_registered_pairs();
    let missing: Vec<&String> = want.difference(&covered).collect();
    assert!(missing.is_empty(), "pairs never successfully executed: {missing:?}");
}

/// Execute one planned (op, algorithm) pair once in a fresh world and
/// return, per rank, the plan's schedule next to nothing else — the
/// world's trace is the measured side of the comparison.
fn run_one_pair(
    topo: &Topology,
    op: OpKind,
    name: &str,
    n: usize,
) -> Option<(Vec<Schedule>, Vec<RankTrace>)> {
    let p = topo.size();
    let run = CommWorld::run(topo, Timing::Wallclock, |c| -> Option<Schedule> {
        match op {
            OpKind::Allgather => {
                let reg = Registry::<u64>::standard();
                let mut plan = reg.plan(name, c, Shape::elems(n)).ok()?;
                let sched = plan.schedule().expect("n > 0 plans carry a schedule").clone();
                let mine = canonical_contribution(c.rank(), n);
                let mut out = vec![0u64; n * p];
                plan.execute(&mine, &mut out).unwrap();
                Some(sched)
            }
            OpKind::Allreduce => {
                let reg = AllreduceRegistry::<u64>::standard();
                let mut plan = reg.plan(name, c, Shape::elems(n)).ok()?;
                let sched = plan.schedule().expect("n > 0 plans carry a schedule").clone();
                let mine = ar_contribution(c.rank(), n);
                let mut out = vec![0u64; n];
                plan.execute(&mine, &mut out).unwrap();
                Some(sched)
            }
            OpKind::Alltoall => {
                let reg = AlltoallRegistry::<u64>::standard();
                let mut plan = reg.plan(name, c, Shape::elems(n)).ok()?;
                let sched = plan.schedule().expect("n > 0 plans carry a schedule").clone();
                let mine = a2a_send(c.rank(), p, n);
                let mut out = vec![0u64; n * p];
                plan.execute(&mine, &mut out).unwrap();
                Some(sched)
            }
            OpKind::ReduceScatter => {
                let reg = ReduceScatterRegistry::<u64>::standard();
                let mut plan = reg.plan(name, c, Shape::elems(n)).ok()?;
                let sched = plan.schedule().expect("n > 0 plans carry a schedule").clone();
                let mine = a2a_send(c.rank(), p, n);
                let mut out = vec![0u64; n];
                plan.execute(&mine, &mut out).unwrap();
                Some(sched)
            }
        }
    });
    let scheds: Option<Vec<Schedule>> = run.results.into_iter().collect();
    scheds.map(|s| (s, run.trace.per_rank))
}

/// The tentpole invariant: for every registered (op, algorithm) pair, the
/// **static** message/byte counts derived from the schedule IR equal the
/// tracer's **measured** counts, per rank and per locality class — the
/// schedule and the execution can never drift, because the execution *is*
/// the schedule.
#[test]
fn schedule_counts_match_traced_execution() {
    let ops =
        [OpKind::Allgather, OpKind::Allreduce, OpKind::Alltoall, OpKind::ReduceScatter];
    for &(regions, ppr) in SHAPES {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        let world: Vec<usize> = (0..p).collect();
        for &n in &[1usize, 3] {
            for op in ops {
                let names: Vec<&'static str> = match op {
                    OpKind::Allgather => Registry::<u64>::standard().names(),
                    OpKind::Allreduce => AllreduceRegistry::<u64>::standard().names(),
                    OpKind::Alltoall => AlltoallRegistry::<u64>::standard().names(),
                    OpKind::ReduceScatter => ReduceScatterRegistry::<u64>::standard().names(),
                };
                for name in names {
                    let Some((scheds, traced)) = run_one_pair(&topo, op, name, n) else {
                        continue; // legitimate plan-time rejection, covered above
                    };
                    for rank in 0..p {
                        let derived = cost::counts(&scheds[rank], rank, &topo, &world);
                        assert_eq!(
                            derived, traced[rank],
                            "{op}/{name} @ {regions}x{ppr} n={n} rank {rank}: \
                             IR-derived counts diverge from traced execution"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn rejections_send_no_messages() {
    // Plan-time rejection is communication-free: nothing is half-sent.
    let topo = Topology::regions(3, 2); // p = 6, non-power-of-two
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let ag = Registry::<u64>::standard()
            .plan("recursive-doubling", c, Shape::elems(2))
            .is_err();
        let ar = AllreduceRegistry::<u64>::standard()
            .plan("recursive-doubling", c, Shape::elems(2))
            .is_err();
        ag && ar
    });
    assert!(run.results.iter().all(|&b| b));
    let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
    assert_eq!(total, 0);
}

#[test]
fn non_uniform_payload_shapes_are_rejected() {
    let topo = Topology::regions(2, 2);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let p = c.size();
        let mut bad = 0usize;
        // Wrong-length buffers at execute time, per op.
        let mut plan = Registry::<u64>::standard().plan("bruck", c, Shape::elems(3)).unwrap();
        bad += plan.execute(&[1u64; 2], &mut vec![0u64; 3 * p]).is_err() as usize;
        bad += plan.execute(&[1u64; 3], &mut vec![0u64; 3 * p - 1]).is_err() as usize;
        let mut plan = AllreduceRegistry::<u64>::standard()
            .plan("recursive-doubling", c, Shape::elems(3))
            .unwrap();
        bad += plan.execute(&[1u64; 4], &mut vec![0u64; 3]).is_err() as usize;
        bad += plan.execute(&[1u64; 3], &mut vec![0u64; 2]).is_err() as usize;
        let mut plan = AlltoallRegistry::<u64>::standard()
            .plan("pairwise", c, Shape::elems(3))
            .unwrap();
        bad += plan.execute(&vec![1u64; 3 * p - 1], &mut vec![0u64; 3 * p]).is_err() as usize;
        bad += plan.execute(&vec![1u64; 3 * p], &mut vec![0u64; 3 * p + 1]).is_err() as usize;
        // Ragged one-shot alltoall (send not a multiple of p).
        bad += locag::collectives::alltoall::bruck(c, &[1u64; 7]).is_err() as usize;
        bad
    });
    assert!(run.results.iter().all(|&b| b == 7));
    // and none of the rejected calls leaked a message
    let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
    assert_eq!(total, 0);
}

/// The reduce-scatter grid, runnable by name in CI
/// (`cargo test --test collective_conformance reduce_scatter`): every
/// registered algorithm over every shape — including non-power-of-two `p`
/// where the algorithm admits it — plus the `n = 0` no-op and 100%
/// registry coverage.
#[test]
fn reduce_scatter_grid_conforms() {
    let mut covered: BTreeSet<String> = BTreeSet::new();
    for &(regions, ppr) in SHAPES {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        for &n in NS {
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| -> Vec<Outcome> {
                let reg = ReduceScatterRegistry::<u64>::standard();
                let mut outcomes = Vec::new();
                for name in reg.names() {
                    let err = match reg.plan(name, c, Shape::elems(n)) {
                        Err(e) => Some(e.to_string()),
                        Ok(mut plan) => {
                            let mine = a2a_send(c.rank(), p, n);
                            let mut out = vec![0u64; n];
                            plan.execute(&mine, &mut out).unwrap();
                            assert_eq!(
                                out,
                                rs_expected(c.rank(), p, n),
                                "reduce-scatter/{name} {regions}x{ppr} n={n} rank {}",
                                c.rank()
                            );
                            None
                        }
                    };
                    outcomes.push((name.to_string(), err));
                }
                outcomes
            });
            for (rank, r) in run.results.iter().enumerate() {
                assert_eq!(r, &run.results[0], "rank {rank} diverged at {regions}x{ppr} n={n}");
            }
            for (name, err) in &run.results[0] {
                match err {
                    None => {
                        covered.insert(name.clone());
                    }
                    Some(msg) => {
                        // only recursive halving may reject, only for the
                        // documented precondition, never the n=0 no-op
                        assert!(n > 0, "{name} rejected the n=0 no-op: {msg}");
                        assert!(msg.contains("power-of-two"), "{name}: {msg}");
                        assert!(!p.is_power_of_two(), "{name} @ p={p}: {msg}");
                    }
                }
            }
        }
    }
    let want: BTreeSet<String> = ReduceScatterRegistry::<u64>::standard()
        .names()
        .into_iter()
        .map(str::to_string)
        .collect();
    let missing: Vec<&String> = want.difference(&covered).collect();
    assert!(missing.is_empty(), "reduce-scatter algorithms never executed: {missing:?}");
}

/// Wrong-shape rejection for the new op, by name for CI: mis-sized
/// buffers error at execute time and leak no messages.
#[test]
fn reduce_scatter_wrong_shape_rejects() {
    let topo = Topology::regions(2, 2);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let p = c.size();
        let reg = ReduceScatterRegistry::<u64>::standard();
        let mut bad = 0usize;
        let mut plan = reg.plan("ring", c, Shape::elems(3)).unwrap();
        bad += plan.execute(&vec![1u64; 3 * p - 1], &mut vec![0u64; 3]).is_err() as usize;
        bad += plan.execute(&vec![1u64; 3 * p], &mut vec![0u64; 4]).is_err() as usize;
        bad += plan.execute(&vec![1u64; 3 * p], &mut vec![0u64; 2]).is_err() as usize;
        // ragged one-shot (send not a multiple of p)
        bad += locag::collectives::reduce_scatter::ring(c, &[1u64; 7]).is_err() as usize;
        bad
    });
    assert!(run.results.iter().all(|&b| b == 4));
    let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
    assert_eq!(total, 0, "rejected calls must not leak messages");
}

/// Rabenseifner allreduce passes the allreduce grid at non-power-of-two
/// sizes with no plan-time precondition — by name for CI
/// (`cargo test --test collective_conformance rabenseifner`). The
/// model-tuned allreduce dispatcher therefore admits those sizes too.
#[test]
fn rabenseifner_allreduce_non_power_of_two_conforms() {
    for &(regions, ppr) in &[(3usize, 2usize), (5, 2), (2, 3), (3, 3), (1, 1), (4, 4)] {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        for &n in NS {
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                for name in ["rabenseifner", "model-tuned"] {
                    let mut plan = AllreduceRegistry::<u64>::standard()
                        .plan(name, c, Shape::elems(n))
                        .unwrap_or_else(|e| {
                            panic!("{name} rejected {regions}x{ppr} n={n}: {e}")
                        });
                    let mine = ar_contribution(c.rank(), n);
                    let mut out = vec![0u64; n];
                    plan.execute(&mine, &mut out).unwrap();
                    assert_eq!(
                        out,
                        ar_expected(p, n),
                        "{name} {regions}x{ppr} n={n} rank {}",
                        c.rank()
                    );
                }
                true
            });
            assert!(run.results.iter().all(|&ok| ok));
        }
    }
}

/// PAT conforms on both its ops over the full grid — by name for CI
/// (`cargo test --test collective_conformance pat`). PAT has no shape
/// precondition: every p (power-of-two or not, down to p = 1) and every
/// payload size including n = 0 must plan and execute.
#[test]
fn pat_allgather_and_reduce_scatter_grid_conforms() {
    for &(regions, ppr) in SHAPES {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        for &n in NS {
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                let mut plan = Registry::<u64>::standard()
                    .plan("pat", c, Shape::elems(n))
                    .unwrap_or_else(|e| {
                        panic!("pat allgather rejected {regions}x{ppr} n={n}: {e}")
                    });
                let mine = canonical_contribution(c.rank(), n);
                let mut out = vec![0u64; n * p];
                plan.execute(&mine, &mut out).unwrap();
                assert_eq!(
                    out,
                    expected_result(p, n),
                    "pat allgather {regions}x{ppr} n={n} rank {}",
                    c.rank()
                );
                let mut rs = ReduceScatterRegistry::<u64>::standard()
                    .plan("pat", c, Shape::elems(n))
                    .unwrap_or_else(|e| {
                        panic!("pat reduce-scatter rejected {regions}x{ppr} n={n}: {e}")
                    });
                let mine = a2a_send(c.rank(), p, n);
                let mut out = vec![0u64; n];
                rs.execute(&mine, &mut out).unwrap();
                assert_eq!(
                    out,
                    rs_expected(c.rank(), p, n),
                    "pat reduce-scatter {regions}x{ppr} n={n} rank {}",
                    c.rank()
                );
                true
            });
            assert!(run.results.iter().all(|&ok| ok));
        }
    }
}

/// The fully hierarchical Rabenseifner conforms across aligned, ragged
/// (n not a multiple of ppr), and degenerate shapes — by name for CI
/// (`cargo test --test collective_conformance loc_rabenseifner`). Like
/// plain Rabenseifner it folds to the nearest power of two, so it has no
/// shape precondition either.
#[test]
fn loc_rabenseifner_allreduce_grid_conforms() {
    for &(regions, ppr) in SHAPES {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        for &n in NS {
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                let mut plan = AllreduceRegistry::<u64>::standard()
                    .plan("loc-rabenseifner", c, Shape::elems(n))
                    .unwrap_or_else(|e| {
                        panic!("loc-rabenseifner rejected {regions}x{ppr} n={n}: {e}")
                    });
                let mine = ar_contribution(c.rank(), n);
                let mut out = vec![0u64; n];
                plan.execute(&mine, &mut out).unwrap();
                assert_eq!(
                    out,
                    ar_expected(p, n),
                    "loc-rabenseifner {regions}x{ppr} n={n} rank {}",
                    c.rank()
                );
                true
            });
            assert!(run.results.iter().all(|&ok| ok));
        }
    }
}

#[test]
fn zero_length_plans_are_uniform_across_ops_and_algorithms() {
    // 3x3 (p = 9, non-power-of-two): even shape-rejecting algorithms must
    // produce the n = 0 no-op plan.
    let topo = Topology::regions(3, 3);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        for name in Registry::<u64>::standard().names() {
            let mut plan = Registry::<u64>::standard().plan(name, c, Shape::elems(0)).unwrap();
            let mut out: Vec<u64> = Vec::new();
            plan.execute(&[], &mut out).unwrap();
            assert!(out.is_empty(), "allgather/{name}");
        }
        for name in AllreduceRegistry::<u64>::standard().names() {
            let mut plan =
                AllreduceRegistry::<u64>::standard().plan(name, c, Shape::elems(0)).unwrap();
            let mut out: Vec<u64> = Vec::new();
            plan.execute(&[], &mut out).unwrap();
            assert!(out.is_empty(), "allreduce/{name}");
        }
        for name in AlltoallRegistry::<u64>::standard().names() {
            let mut plan =
                AlltoallRegistry::<u64>::standard().plan(name, c, Shape::elems(0)).unwrap();
            let mut out: Vec<u64> = Vec::new();
            plan.execute(&[], &mut out).unwrap();
            assert!(out.is_empty(), "alltoall/{name}");
        }
        for name in ReduceScatterRegistry::<u64>::standard().names() {
            let mut plan =
                ReduceScatterRegistry::<u64>::standard().plan(name, c, Shape::elems(0)).unwrap();
            let mut out: Vec<u64> = Vec::new();
            plan.execute(&[], &mut out).unwrap();
            assert!(out.is_empty(), "reduce-scatter/{name}");
        }
        true
    });
    assert!(run.results.iter().all(|&ok| ok));
    let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
    assert_eq!(total, 0, "zero-length plans must send no messages");
}
