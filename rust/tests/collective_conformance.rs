//! Conformance: every registered (operation, algorithm) pair, executed
//! against a naive reference over a grid of world shapes and payload
//! sizes.
//!
//! The grid covers `n = 0` (the uniform no-op contract), `n = 1`, a
//! multi-element payload, power-of-two and non-power-of-two rank counts,
//! single-rank and single-region degenerate topologies. Algorithms that
//! legitimately reject a shape (recursive doubling and its allreduce /
//! fallback twins on non-power-of-two sizes) must reject **at plan time**,
//! uniformly on every rank, with a precondition error naming the
//! power-of-two requirement — and must still plan the `n = 0` no-op.
//!
//! The suite fails if any registered pair was never successfully executed
//! (100% registry coverage), so registering a new algorithm without
//! conformance coverage is impossible.

use std::collections::BTreeSet;

use locag::collectives::{
    canonical_contribution, expected_result, AllgathervRegistry, AllreduceRegistry,
    AlltoallRegistry, Counts, OpKind, PlanSpec, ReduceScatterRegistry, ReduceScattervRegistry,
    Registry, Schedule, Shape,
};
use locag::comm::{CommWorld, Timing};
use locag::model::{cost, MachineParams};
use locag::topology::Topology;
use locag::trace::RankTrace;

/// (regions, ranks-per-region): powers of two, non-powers, degenerate.
const SHAPES: &[(usize, usize)] = &[
    (1, 1),
    (1, 4),
    (2, 2),
    (4, 4),
    (3, 2),
    (5, 2),
    (2, 3),
    (3, 3),
    (8, 4),
];

/// Payload sizes, including the zero-length contract and a single element.
const NS: &[usize] = &[0, 1, 3];

fn ar_contribution(rank: usize, n: usize) -> Vec<u64> {
    (0..n).map(|j| (rank * 131_071 + j) as u64).collect()
}

fn ar_expected(p: usize, n: usize) -> Vec<u64> {
    (0..n)
        .map(|j| (0..p).map(|r| (r * 131_071 + j) as u64).sum())
        .collect()
}

fn a2a_send(rank: usize, p: usize, n: usize) -> Vec<u64> {
    (0..p * n)
        .map(|x| (rank * 1_000_003 + (x / n.max(1)) * 1_009 + x % n.max(1)) as u64)
        .collect()
}

fn a2a_expected(rank: usize, p: usize, n: usize) -> Vec<u64> {
    (0..p * n)
        .map(|x| ((x / n.max(1)) * 1_000_003 + rank * 1_009 + x % n.max(1)) as u64)
        .collect()
}

/// Reduce-scatter consumes the same `n·p` block layout as alltoall
/// ([`a2a_send`]); rank `i` receives the sum over ranks of block `i`.
fn rs_expected(rank: usize, p: usize, n: usize) -> Vec<u64> {
    (0..n)
        .map(|j| (0..p).map(|r| (r * 1_000_003 + rank * 1_009 + j) as u64).sum())
        .collect()
}

/// Outcome of one (op, algorithm) attempt on one rank: registry key plus
/// the plan-time rejection message, if any.
type Outcome = (String, Option<String>);

/// Run every registered pair of every op over one world; execution
/// results are asserted in-world against the naive references.
fn run_grid_point(regions: usize, ppr: usize, n: usize) -> Vec<Vec<Outcome>> {
    let topo = Topology::regions(regions, ppr);
    let p = topo.size();
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| -> Vec<Outcome> {
        let mut outcomes = Vec::new();

        let reg = Registry::<u64>::standard();
        for name in reg.names() {
            let err = match reg.plan_uniform(name, c, Shape::elems(n)) {
                Err(e) => Some(e.to_string()),
                Ok(mut plan) => {
                    assert_eq!(plan.algorithm(), name);
                    assert_eq!(plan.shape(), Shape::elems(n));
                    assert_eq!(plan.comm_size(), p);
                    let mine = canonical_contribution(c.rank(), n);
                    let mut out = vec![0u64; n * p];
                    plan.execute(&mine, &mut out).unwrap();
                    assert_eq!(
                        out,
                        expected_result(p, n),
                        "allgather/{name} {regions}x{ppr} n={n} rank {}",
                        c.rank()
                    );
                    None
                }
            };
            outcomes.push((format!("allgather/{name}"), err));
        }

        let reg = AllreduceRegistry::<u64>::standard();
        for name in reg.names() {
            let err = match reg.plan_uniform(name, c, Shape::elems(n)) {
                Err(e) => Some(e.to_string()),
                Ok(mut plan) => {
                    assert_eq!(plan.algorithm(), name);
                    assert_eq!(plan.comm_size(), p);
                    let mine = ar_contribution(c.rank(), n);
                    let mut out = vec![0u64; n];
                    plan.execute(&mine, &mut out).unwrap();
                    assert_eq!(
                        out,
                        ar_expected(p, n),
                        "allreduce/{name} {regions}x{ppr} n={n} rank {}",
                        c.rank()
                    );
                    None
                }
            };
            outcomes.push((format!("allreduce/{name}"), err));
        }

        let reg = AlltoallRegistry::<u64>::standard();
        for name in reg.names() {
            let err = match reg.plan_uniform(name, c, Shape::elems(n)) {
                Err(e) => Some(e.to_string()),
                Ok(mut plan) => {
                    assert_eq!(plan.algorithm(), name);
                    assert_eq!(plan.comm_size(), p);
                    let mine = a2a_send(c.rank(), p, n);
                    let mut out = vec![0u64; n * p];
                    plan.execute(&mine, &mut out).unwrap();
                    assert_eq!(
                        out,
                        a2a_expected(c.rank(), p, n),
                        "alltoall/{name} {regions}x{ppr} n={n} rank {}",
                        c.rank()
                    );
                    None
                }
            };
            outcomes.push((format!("alltoall/{name}"), err));
        }

        let reg = ReduceScatterRegistry::<u64>::standard();
        for name in reg.names() {
            let err = match reg.plan_uniform(name, c, Shape::elems(n)) {
                Err(e) => Some(e.to_string()),
                Ok(mut plan) => {
                    assert_eq!(plan.algorithm(), name);
                    assert_eq!(plan.comm_size(), p);
                    let mine = a2a_send(c.rank(), p, n);
                    let mut out = vec![0u64; n];
                    plan.execute(&mine, &mut out).unwrap();
                    assert_eq!(
                        out,
                        rs_expected(c.rank(), p, n),
                        "reduce-scatter/{name} {regions}x{ppr} n={n} rank {}",
                        c.rank()
                    );
                    None
                }
            };
            outcomes.push((format!("reduce-scatter/{name}"), err));
        }
        outcomes
    });
    run.results
}

/// Every registry name, keyed `op/name` — the 100%-coverage target.
fn all_registered_pairs() -> BTreeSet<String> {
    let mut want = BTreeSet::new();
    for name in Registry::<u64>::standard().names() {
        want.insert(format!("allgather/{name}"));
    }
    for name in AllreduceRegistry::<u64>::standard().names() {
        want.insert(format!("allreduce/{name}"));
    }
    for name in AlltoallRegistry::<u64>::standard().names() {
        want.insert(format!("alltoall/{name}"));
    }
    for name in ReduceScatterRegistry::<u64>::standard().names() {
        want.insert(format!("reduce-scatter/{name}"));
    }
    want
}

#[test]
fn every_registered_pair_conforms_over_the_grid() {
    let mut covered: BTreeSet<String> = BTreeSet::new();
    for &(regions, ppr) in SHAPES {
        let p = regions * ppr;
        for &n in NS {
            let results = run_grid_point(regions, ppr, n);
            // Plan outcomes (including error text) are identical on every
            // rank: planning is collective and deterministic.
            for (rank, r) in results.iter().enumerate() {
                assert_eq!(
                    r, &results[0],
                    "rank {rank} diverged at {regions}x{ppr} n={n}"
                );
            }
            for (key, err) in &results[0] {
                match err {
                    None => {
                        covered.insert(key.clone());
                    }
                    Some(msg) => {
                        // A legitimate rejection: explicit, plan-time, and
                        // only for the documented precondition.
                        assert!(n > 0, "{key} rejected the n=0 no-op: {msg}");
                        assert!(
                            msg.contains("power-of-two"),
                            "{key} @ {regions}x{ppr} n={n}: unexpected rejection: {msg}"
                        );
                        assert!(
                            !p.is_power_of_two(),
                            "{key} @ {regions}x{ppr} (p={p} IS a power of two) n={n}: {msg}"
                        );
                    }
                }
            }
        }
    }
    // 100% of registered (op, algorithm) pairs executed successfully on
    // at least one grid shape.
    let want = all_registered_pairs();
    let missing: Vec<&String> = want.difference(&covered).collect();
    assert!(missing.is_empty(), "pairs never successfully executed: {missing:?}");
}

/// Execute one planned (op, algorithm) pair once in a fresh world and
/// return, per rank, the plan's schedule next to nothing else — the
/// world's trace is the measured side of the comparison.
fn run_one_pair(
    topo: &Topology,
    op: OpKind,
    name: &str,
    n: usize,
) -> Option<(Vec<Schedule>, Vec<RankTrace>)> {
    let p = topo.size();
    let run = CommWorld::run(topo, Timing::Wallclock, |c| -> Option<Schedule> {
        match op {
            OpKind::Allgather => {
                let reg = Registry::<u64>::standard();
                let mut plan = reg.plan_uniform(name, c, Shape::elems(n)).ok()?;
                let sched = plan.schedule().expect("n > 0 plans carry a schedule").clone();
                let mine = canonical_contribution(c.rank(), n);
                let mut out = vec![0u64; n * p];
                plan.execute(&mine, &mut out).unwrap();
                Some(sched)
            }
            OpKind::Allreduce => {
                let reg = AllreduceRegistry::<u64>::standard();
                let mut plan = reg.plan_uniform(name, c, Shape::elems(n)).ok()?;
                let sched = plan.schedule().expect("n > 0 plans carry a schedule").clone();
                let mine = ar_contribution(c.rank(), n);
                let mut out = vec![0u64; n];
                plan.execute(&mine, &mut out).unwrap();
                Some(sched)
            }
            OpKind::Alltoall => {
                let reg = AlltoallRegistry::<u64>::standard();
                let mut plan = reg.plan_uniform(name, c, Shape::elems(n)).ok()?;
                let sched = plan.schedule().expect("n > 0 plans carry a schedule").clone();
                let mine = a2a_send(c.rank(), p, n);
                let mut out = vec![0u64; n * p];
                plan.execute(&mine, &mut out).unwrap();
                Some(sched)
            }
            OpKind::ReduceScatter => {
                let reg = ReduceScatterRegistry::<u64>::standard();
                let mut plan = reg.plan_uniform(name, c, Shape::elems(n)).ok()?;
                let sched = plan.schedule().expect("n > 0 plans carry a schedule").clone();
                let mine = a2a_send(c.rank(), p, n);
                let mut out = vec![0u64; n];
                plan.execute(&mine, &mut out).unwrap();
                Some(sched)
            }
        }
    });
    let scheds: Option<Vec<Schedule>> = run.results.into_iter().collect();
    scheds.map(|s| (s, run.trace.per_rank))
}

/// The tentpole invariant: for every registered (op, algorithm) pair, the
/// **static** message/byte counts derived from the schedule IR equal the
/// tracer's **measured** counts, per rank and per locality class — the
/// schedule and the execution can never drift, because the execution *is*
/// the schedule.
#[test]
fn schedule_counts_match_traced_execution() {
    let ops =
        [OpKind::Allgather, OpKind::Allreduce, OpKind::Alltoall, OpKind::ReduceScatter];
    for &(regions, ppr) in SHAPES {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        let world: Vec<usize> = (0..p).collect();
        for &n in &[1usize, 3] {
            for op in ops {
                let names: Vec<&'static str> = match op {
                    OpKind::Allgather => Registry::<u64>::standard().names(),
                    OpKind::Allreduce => AllreduceRegistry::<u64>::standard().names(),
                    OpKind::Alltoall => AlltoallRegistry::<u64>::standard().names(),
                    OpKind::ReduceScatter => ReduceScatterRegistry::<u64>::standard().names(),
                };
                for name in names {
                    let Some((scheds, traced)) = run_one_pair(&topo, op, name, n) else {
                        continue; // legitimate plan-time rejection, covered above
                    };
                    for rank in 0..p {
                        let derived = cost::counts(&scheds[rank], rank, &topo, &world);
                        assert_eq!(
                            derived, traced[rank],
                            "{op}/{name} @ {regions}x{ppr} n={n} rank {rank}: \
                             IR-derived counts diverge from traced execution"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn rejections_send_no_messages() {
    // Plan-time rejection is communication-free: nothing is half-sent.
    let topo = Topology::regions(3, 2); // p = 6, non-power-of-two
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let ag = Registry::<u64>::standard()
            .plan_uniform("recursive-doubling", c, Shape::elems(2))
            .is_err();
        let ar = AllreduceRegistry::<u64>::standard()
            .plan_uniform("recursive-doubling", c, Shape::elems(2))
            .is_err();
        ag && ar
    });
    assert!(run.results.iter().all(|&b| b));
    let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
    assert_eq!(total, 0);
}

#[test]
fn non_uniform_payload_shapes_are_rejected() {
    let topo = Topology::regions(2, 2);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let p = c.size();
        let mut bad = 0usize;
        // Wrong-length buffers at execute time, per op.
        let mut plan =
            Registry::<u64>::standard().plan_uniform("bruck", c, Shape::elems(3)).unwrap();
        bad += plan.execute(&[1u64; 2], &mut vec![0u64; 3 * p]).is_err() as usize;
        bad += plan.execute(&[1u64; 3], &mut vec![0u64; 3 * p - 1]).is_err() as usize;
        let mut plan = AllreduceRegistry::<u64>::standard()
            .plan_uniform("recursive-doubling", c, Shape::elems(3))
            .unwrap();
        bad += plan.execute(&[1u64; 4], &mut vec![0u64; 3]).is_err() as usize;
        bad += plan.execute(&[1u64; 3], &mut vec![0u64; 2]).is_err() as usize;
        let mut plan = AlltoallRegistry::<u64>::standard()
            .plan_uniform("pairwise", c, Shape::elems(3))
            .unwrap();
        bad += plan.execute(&vec![1u64; 3 * p - 1], &mut vec![0u64; 3 * p]).is_err() as usize;
        bad += plan.execute(&vec![1u64; 3 * p], &mut vec![0u64; 3 * p + 1]).is_err() as usize;
        // Ragged one-shot alltoall (send not a multiple of p).
        bad += locag::collectives::alltoall::bruck(c, &[1u64; 7]).is_err() as usize;
        bad
    });
    assert!(run.results.iter().all(|&b| b == 7));
    // and none of the rejected calls leaked a message
    let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
    assert_eq!(total, 0);
}

/// The reduce-scatter grid, runnable by name in CI
/// (`cargo test --test collective_conformance reduce_scatter`): every
/// registered algorithm over every shape — including non-power-of-two `p`
/// where the algorithm admits it — plus the `n = 0` no-op and 100%
/// registry coverage.
#[test]
fn reduce_scatter_grid_conforms() {
    let mut covered: BTreeSet<String> = BTreeSet::new();
    for &(regions, ppr) in SHAPES {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        for &n in NS {
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| -> Vec<Outcome> {
                let reg = ReduceScatterRegistry::<u64>::standard();
                let mut outcomes = Vec::new();
                for name in reg.names() {
                    let err = match reg.plan_uniform(name, c, Shape::elems(n)) {
                        Err(e) => Some(e.to_string()),
                        Ok(mut plan) => {
                            let mine = a2a_send(c.rank(), p, n);
                            let mut out = vec![0u64; n];
                            plan.execute(&mine, &mut out).unwrap();
                            assert_eq!(
                                out,
                                rs_expected(c.rank(), p, n),
                                "reduce-scatter/{name} {regions}x{ppr} n={n} rank {}",
                                c.rank()
                            );
                            None
                        }
                    };
                    outcomes.push((name.to_string(), err));
                }
                outcomes
            });
            for (rank, r) in run.results.iter().enumerate() {
                assert_eq!(r, &run.results[0], "rank {rank} diverged at {regions}x{ppr} n={n}");
            }
            for (name, err) in &run.results[0] {
                match err {
                    None => {
                        covered.insert(name.clone());
                    }
                    Some(msg) => {
                        // only recursive halving may reject, only for the
                        // documented precondition, never the n=0 no-op
                        assert!(n > 0, "{name} rejected the n=0 no-op: {msg}");
                        assert!(msg.contains("power-of-two"), "{name}: {msg}");
                        assert!(!p.is_power_of_two(), "{name} @ p={p}: {msg}");
                    }
                }
            }
        }
    }
    let want: BTreeSet<String> = ReduceScatterRegistry::<u64>::standard()
        .names()
        .into_iter()
        .map(str::to_string)
        .collect();
    let missing: Vec<&String> = want.difference(&covered).collect();
    assert!(missing.is_empty(), "reduce-scatter algorithms never executed: {missing:?}");
}

/// Wrong-shape rejection for the new op, by name for CI: mis-sized
/// buffers error at execute time and leak no messages.
#[test]
fn reduce_scatter_wrong_shape_rejects() {
    let topo = Topology::regions(2, 2);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let p = c.size();
        let reg = ReduceScatterRegistry::<u64>::standard();
        let mut bad = 0usize;
        let mut plan = reg.plan_uniform("ring", c, Shape::elems(3)).unwrap();
        bad += plan.execute(&vec![1u64; 3 * p - 1], &mut vec![0u64; 3]).is_err() as usize;
        bad += plan.execute(&vec![1u64; 3 * p], &mut vec![0u64; 4]).is_err() as usize;
        bad += plan.execute(&vec![1u64; 3 * p], &mut vec![0u64; 2]).is_err() as usize;
        // ragged one-shot (send not a multiple of p)
        bad += locag::collectives::reduce_scatter::ring(c, &[1u64; 7]).is_err() as usize;
        bad
    });
    assert!(run.results.iter().all(|&b| b == 4));
    let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
    assert_eq!(total, 0, "rejected calls must not leak messages");
}

/// Rabenseifner allreduce passes the allreduce grid at non-power-of-two
/// sizes with no plan-time precondition — by name for CI
/// (`cargo test --test collective_conformance rabenseifner`). The
/// model-tuned allreduce dispatcher therefore admits those sizes too.
#[test]
fn rabenseifner_allreduce_non_power_of_two_conforms() {
    for &(regions, ppr) in &[(3usize, 2usize), (5, 2), (2, 3), (3, 3), (1, 1), (4, 4)] {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        for &n in NS {
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                for name in ["rabenseifner", "model-tuned"] {
                    let mut plan = AllreduceRegistry::<u64>::standard()
                        .plan_uniform(name, c, Shape::elems(n))
                        .unwrap_or_else(|e| {
                            panic!("{name} rejected {regions}x{ppr} n={n}: {e}")
                        });
                    let mine = ar_contribution(c.rank(), n);
                    let mut out = vec![0u64; n];
                    plan.execute(&mine, &mut out).unwrap();
                    assert_eq!(
                        out,
                        ar_expected(p, n),
                        "{name} {regions}x{ppr} n={n} rank {}",
                        c.rank()
                    );
                }
                true
            });
            assert!(run.results.iter().all(|&ok| ok));
        }
    }
}

/// PAT conforms on both its ops over the full grid — by name for CI
/// (`cargo test --test collective_conformance pat`). PAT has no shape
/// precondition: every p (power-of-two or not, down to p = 1) and every
/// payload size including n = 0 must plan and execute.
#[test]
fn pat_allgather_and_reduce_scatter_grid_conforms() {
    for &(regions, ppr) in SHAPES {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        for &n in NS {
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                let mut plan = Registry::<u64>::standard()
                    .plan_uniform("pat", c, Shape::elems(n))
                    .unwrap_or_else(|e| {
                        panic!("pat allgather rejected {regions}x{ppr} n={n}: {e}")
                    });
                let mine = canonical_contribution(c.rank(), n);
                let mut out = vec![0u64; n * p];
                plan.execute(&mine, &mut out).unwrap();
                assert_eq!(
                    out,
                    expected_result(p, n),
                    "pat allgather {regions}x{ppr} n={n} rank {}",
                    c.rank()
                );
                let mut rs = ReduceScatterRegistry::<u64>::standard()
                    .plan_uniform("pat", c, Shape::elems(n))
                    .unwrap_or_else(|e| {
                        panic!("pat reduce-scatter rejected {regions}x{ppr} n={n}: {e}")
                    });
                let mine = a2a_send(c.rank(), p, n);
                let mut out = vec![0u64; n];
                rs.execute(&mine, &mut out).unwrap();
                assert_eq!(
                    out,
                    rs_expected(c.rank(), p, n),
                    "pat reduce-scatter {regions}x{ppr} n={n} rank {}",
                    c.rank()
                );
                true
            });
            assert!(run.results.iter().all(|&ok| ok));
        }
    }
}

/// The fully hierarchical Rabenseifner conforms across aligned, ragged
/// (n not a multiple of ppr), and degenerate shapes — by name for CI
/// (`cargo test --test collective_conformance loc_rabenseifner`). Like
/// plain Rabenseifner it folds to the nearest power of two, so it has no
/// shape precondition either.
#[test]
fn loc_rabenseifner_allreduce_grid_conforms() {
    for &(regions, ppr) in SHAPES {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        for &n in NS {
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                let mut plan = AllreduceRegistry::<u64>::standard()
                    .plan_uniform("loc-rabenseifner", c, Shape::elems(n))
                    .unwrap_or_else(|e| {
                        panic!("loc-rabenseifner rejected {regions}x{ppr} n={n}: {e}")
                    });
                let mine = ar_contribution(c.rank(), n);
                let mut out = vec![0u64; n];
                plan.execute(&mine, &mut out).unwrap();
                assert_eq!(
                    out,
                    ar_expected(p, n),
                    "loc-rabenseifner {regions}x{ppr} n={n} rank {}",
                    c.rank()
                );
                true
            });
            assert!(run.results.iter().all(|&ok| ok));
        }
    }
}

#[test]
fn zero_length_plans_are_uniform_across_ops_and_algorithms() {
    // 3x3 (p = 9, non-power-of-two): even shape-rejecting algorithms must
    // produce the n = 0 no-op plan.
    let topo = Topology::regions(3, 3);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        for name in Registry::<u64>::standard().names() {
            let mut plan =
                Registry::<u64>::standard().plan_uniform(name, c, Shape::elems(0)).unwrap();
            let mut out: Vec<u64> = Vec::new();
            plan.execute(&[], &mut out).unwrap();
            assert!(out.is_empty(), "allgather/{name}");
        }
        for name in AllreduceRegistry::<u64>::standard().names() {
            let mut plan = AllreduceRegistry::<u64>::standard()
                .plan_uniform(name, c, Shape::elems(0))
                .unwrap();
            let mut out: Vec<u64> = Vec::new();
            plan.execute(&[], &mut out).unwrap();
            assert!(out.is_empty(), "allreduce/{name}");
        }
        for name in AlltoallRegistry::<u64>::standard().names() {
            let mut plan =
                AlltoallRegistry::<u64>::standard().plan_uniform(name, c, Shape::elems(0)).unwrap();
            let mut out: Vec<u64> = Vec::new();
            plan.execute(&[], &mut out).unwrap();
            assert!(out.is_empty(), "alltoall/{name}");
        }
        for name in ReduceScatterRegistry::<u64>::standard().names() {
            let mut plan = ReduceScatterRegistry::<u64>::standard()
                .plan_uniform(name, c, Shape::elems(0))
                .unwrap();
            let mut out: Vec<u64> = Vec::new();
            plan.execute(&[], &mut out).unwrap();
            assert!(out.is_empty(), "reduce-scatter/{name}");
        }
        true
    });
    assert!(run.results.iter().all(|&ok| ok));
    let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
    assert_eq!(total, 0, "zero-length plans must send no messages");
}

// ---------------------------------------------------------------------------
// Ragged conformance: allgatherv / reduce-scatter-v
// ---------------------------------------------------------------------------

/// Ragged per-rank count patterns for a `p`-rank world: all-zero (the
/// ragged no-op contract), a single holder, skewed counts with zero-count
/// ranks mixed in, and uniform counts through the ragged path.
fn ragged_patterns(p: usize) -> Vec<Counts> {
    vec![
        Counts::uniform(0, p),
        Counts::new((0..p).map(|r| if r == p / 2 { 5 } else { 0 }).collect()),
        Counts::new((0..p).map(|r| r % 3).collect()),
        Counts::uniform(2, p),
    ]
}

/// Allgatherv input for `rank`: its `counts[rank]` canonical elements.
fn agv_contribution(rank: usize, counts: &Counts) -> Vec<u64> {
    canonical_contribution(rank, counts.get(rank))
}

/// Naive allgatherv reference: every contribution at its prefix offset.
fn agv_expected(counts: &Counts) -> Vec<u64> {
    (0..counts.len()).flat_map(|r| agv_contribution(r, counts)).collect()
}

/// Reduce-scatter-v input for `rank`: block `b` holds the `counts[b]`
/// elements destined for rank `b` (the ragged [`a2a_send`] layout).
fn rsv_send(rank: usize, counts: &Counts) -> Vec<u64> {
    (0..counts.len())
        .flat_map(|b| (0..counts.get(b)).map(move |j| (rank * 1_000_003 + b * 1_009 + j) as u64))
        .collect()
}

/// Naive reduce-scatter-v reference: this rank's block summed over ranks.
fn rsv_expected(rank: usize, p: usize, counts: &Counts) -> Vec<u64> {
    (0..counts.get(rank))
        .map(|j| (0..p).map(|r| (r * 1_000_003 + rank * 1_009 + j) as u64).sum())
        .collect()
}

/// Every registered ragged pair over every shape and count pattern — by
/// name for CI (`cargo test --test collective_conformance ragged`):
/// byte-identical to the naive ragged references, including zero-count
/// ranks, a single holder, non-power-of-two `p` and the all-zero no-op,
/// with 100% registry coverage.
#[test]
fn ragged_grid_conforms() {
    let mut covered: BTreeSet<String> = BTreeSet::new();
    for &(regions, ppr) in SHAPES {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        for counts in ragged_patterns(p) {
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| -> Vec<String> {
                let mut ran = Vec::new();
                let spec = PlanSpec::ragged(counts.clone());
                let reg = AllgathervRegistry::<u64>::standard();
                for name in reg.names() {
                    let mut plan = reg.plan(name, c, &spec).unwrap_or_else(|e| {
                        panic!("allgatherv/{name} rejected {regions}x{ppr} [{counts}]: {e}")
                    });
                    assert_eq!(plan.algorithm(), name);
                    assert_eq!(plan.comm_size(), p);
                    let mine = agv_contribution(c.rank(), &counts);
                    let mut out = vec![0u64; counts.total()];
                    plan.execute(&mine, &mut out).unwrap();
                    assert_eq!(
                        out,
                        agv_expected(&counts),
                        "allgatherv/{name} {regions}x{ppr} [{counts}] rank {}",
                        c.rank()
                    );
                    ran.push(format!("allgatherv/{name}"));
                }
                let reg = ReduceScattervRegistry::<u64>::standard();
                for name in reg.names() {
                    let mut plan = reg.plan(name, c, &spec).unwrap_or_else(|e| {
                        panic!("reduce-scatter-v/{name} rejected {regions}x{ppr} [{counts}]: {e}")
                    });
                    assert_eq!(plan.algorithm(), name);
                    assert_eq!(plan.comm_size(), p);
                    let mine = rsv_send(c.rank(), &counts);
                    let mut out = vec![0u64; counts.get(c.rank())];
                    plan.execute(&mine, &mut out).unwrap();
                    assert_eq!(
                        out,
                        rsv_expected(c.rank(), p, &counts),
                        "reduce-scatter-v/{name} {regions}x{ppr} [{counts}] rank {}",
                        c.rank()
                    );
                    ran.push(format!("reduce-scatter-v/{name}"));
                }
                ran
            });
            for (rank, r) in run.results.iter().enumerate() {
                assert_eq!(
                    r,
                    &run.results[0],
                    "rank {rank} diverged at {regions}x{ppr} [{counts}]"
                );
            }
            covered.extend(run.results[0].iter().cloned());
            if counts.total() == 0 {
                let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
                assert_eq!(total, 0, "all-zero counts must send no messages");
            }
        }
    }
    let mut want = BTreeSet::new();
    for name in AllgathervRegistry::<u64>::standard().names() {
        want.insert(format!("allgatherv/{name}"));
    }
    for name in ReduceScattervRegistry::<u64>::standard().names() {
        want.insert(format!("reduce-scatter-v/{name}"));
    }
    let missing: Vec<&String> = want.difference(&covered).collect();
    assert!(missing.is_empty(), "ragged pairs never successfully executed: {missing:?}");
}

/// Execute one ragged (op, algorithm) pair once in a fresh world; returns
/// the per-rank schedules next to the world's measured trace.
fn run_one_ragged(
    topo: &Topology,
    op: OpKind,
    name: &str,
    counts: &Counts,
) -> (Vec<Schedule>, Vec<RankTrace>) {
    let p = topo.size();
    let run = CommWorld::run(topo, Timing::Wallclock, |c| -> Schedule {
        let spec = PlanSpec::ragged(counts.clone());
        match op {
            OpKind::Allgatherv => {
                let reg = AllgathervRegistry::<u64>::standard();
                let mut plan = reg.plan(name, c, &spec).unwrap();
                let sched =
                    plan.schedule().expect("non-zero ragged plans carry a schedule").clone();
                let mine = agv_contribution(c.rank(), counts);
                let mut out = vec![0u64; counts.total()];
                plan.execute(&mine, &mut out).unwrap();
                assert_eq!(out, agv_expected(counts), "allgatherv/{name} rank {}", c.rank());
                sched
            }
            OpKind::ReduceScatterV => {
                let reg = ReduceScattervRegistry::<u64>::standard();
                let mut plan = reg.plan(name, c, &spec).unwrap();
                let sched =
                    plan.schedule().expect("non-zero ragged plans carry a schedule").clone();
                let mine = rsv_send(c.rank(), counts);
                let mut out = vec![0u64; counts.get(c.rank())];
                plan.execute(&mine, &mut out).unwrap();
                assert_eq!(
                    out,
                    rsv_expected(c.rank(), p, counts),
                    "reduce-scatter-v/{name} rank {}",
                    c.rank()
                );
                sched
            }
            other => panic!("{other} is not a ragged operation"),
        }
    });
    (run.results, run.trace.per_rank)
}

/// Ragged twin of [`schedule_counts_match_traced_execution`]: for every
/// registered ragged pair the IR-derived message/byte counts equal the
/// tracer's measured counts per rank and locality class, on skewed counts
/// with zero-count ranks.
#[test]
fn ragged_schedule_counts_match_traced_execution() {
    for &(regions, ppr) in &[(2usize, 2usize), (4, 4), (3, 2), (2, 3), (8, 4)] {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        let world: Vec<usize> = (0..p).collect();
        let counts = Counts::new((0..p).map(|r| r % 3).collect());
        for op in [OpKind::Allgatherv, OpKind::ReduceScatterV] {
            let names = match op {
                OpKind::Allgatherv => AllgathervRegistry::<u64>::standard().names(),
                _ => ReduceScattervRegistry::<u64>::standard().names(),
            };
            for name in names {
                let (scheds, traced) = run_one_ragged(&topo, op, name, &counts);
                for rank in 0..p {
                    let derived = cost::counts(&scheds[rank], rank, &topo, &world);
                    assert_eq!(
                        derived, traced[rank],
                        "{op}/{name} @ {regions}x{ppr} [{counts}] rank {rank}: \
                         IR-derived counts diverge from traced execution"
                    );
                }
            }
        }
    }
}

/// The ragged cost-model invariant: the postal-model prediction from the
/// schedule IR equals the virtual-clock completion time of the actual
/// execution, for every registered ragged pair.
#[test]
fn ragged_prediction_matches_virtual_time() {
    let machine = MachineParams::lassen();
    for &(regions, ppr) in &[(4usize, 4usize), (2, 3)] {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        let world: Vec<usize> = (0..p).collect();
        let counts = Counts::new((0..p).map(|r| (r * 7) % 5).collect());
        for op in [OpKind::Allgatherv, OpKind::ReduceScatterV] {
            let names = match op {
                OpKind::Allgatherv => AllgathervRegistry::<u64>::standard().names(),
                _ => ReduceScattervRegistry::<u64>::standard().names(),
            };
            for name in names {
                let run = CommWorld::run(&topo, Timing::Virtual(machine.clone()), |c| {
                    let spec = PlanSpec::ragged(counts.clone());
                    let sched = match op {
                        OpKind::Allgatherv => {
                            let reg = AllgathervRegistry::<u64>::standard();
                            let mut plan = reg.plan(name, c, &spec).unwrap();
                            let sched = plan.schedule().unwrap().clone();
                            let mine = agv_contribution(c.rank(), &counts);
                            let mut out = vec![0u64; counts.total()];
                            plan.execute(&mine, &mut out).unwrap();
                            sched
                        }
                        _ => {
                            let reg = ReduceScattervRegistry::<u64>::standard();
                            let mut plan = reg.plan(name, c, &spec).unwrap();
                            let sched = plan.schedule().unwrap().clone();
                            let mine = rsv_send(c.rank(), &counts);
                            let mut out = vec![0u64; counts.get(c.rank())];
                            plan.execute(&mine, &mut out).unwrap();
                            sched
                        }
                    };
                    (sched, c.clock())
                });
                let scheds: Vec<Schedule> = run.results.iter().map(|(s, _)| s.clone()).collect();
                let predicted = cost::predict(&scheds, &topo, &world, &machine).unwrap();
                let vtime = run.results.iter().map(|&(_, t)| t).fold(0.0, f64::max);
                assert!(
                    (predicted - vtime).abs() < 1e-12,
                    "{op}/{name} @ {regions}x{ppr} [{counts}]: predicted {predicted} vs \
                     virtual time {vtime}"
                );
            }
        }
    }
}

/// Ragged plans are persistent: plan once, execute repeatedly with
/// identical results and no drift (the plan-reuse contract of the uniform
/// ops carried over to the counts-aware API).
#[test]
fn ragged_plans_are_reusable() {
    let topo = Topology::regions(2, 3);
    let p = topo.size();
    let counts = Counts::new(vec![3, 0, 2, 1, 0, 4]);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let spec = PlanSpec::ragged(counts.clone());
        let mut ag = AllgathervRegistry::<u64>::standard().plan("loc-aware", c, &spec).unwrap();
        let mut rs = ReduceScattervRegistry::<u64>::standard().plan("ring", c, &spec).unwrap();
        for _ in 0..3 {
            c.barrier().unwrap();
            let mine = agv_contribution(c.rank(), &counts);
            let mut out = vec![0u64; counts.total()];
            ag.execute(&mine, &mut out).unwrap();
            assert_eq!(out, agv_expected(&counts), "allgatherv reuse rank {}", c.rank());
            let mine = rsv_send(c.rank(), &counts);
            let mut out = vec![0u64; counts.get(c.rank())];
            rs.execute(&mine, &mut out).unwrap();
            assert_eq!(out, rsv_expected(c.rank(), p, &counts), "rsv reuse rank {}", c.rank());
        }
        true
    });
    assert!(run.results.iter().all(|&ok| ok));
}

/// Ragged wrong shapes reject cleanly: a counts list whose length is not
/// the communicator size rejects at plan time, mis-sized buffers reject
/// at execute time, and none of the rejected calls leak a message.
#[test]
fn ragged_wrong_shapes_are_rejected() {
    let topo = Topology::regions(2, 2);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let p = c.size();
        let mut bad = 0usize;
        let agv = AllgathervRegistry::<u64>::standard();
        let rsv = ReduceScattervRegistry::<u64>::standard();
        let short = PlanSpec::ragged(Counts::new(vec![1; p - 1]));
        bad += agv.plan("ring", c, &short).is_err() as usize;
        bad += rsv.plan("loc-aware", c, &short).is_err() as usize;
        let counts = Counts::new(vec![3, 0, 2, 1]);
        let spec = PlanSpec::ragged(counts.clone());
        let mut ag = agv.plan("bruck", c, &spec).unwrap();
        let mine = vec![1u64; counts.get(c.rank()) + 1];
        bad += ag.execute(&mine, &mut vec![0u64; counts.total()]).is_err() as usize;
        let mine = vec![1u64; counts.get(c.rank())];
        bad += ag.execute(&mine, &mut vec![0u64; counts.total() - 1]).is_err() as usize;
        let mut rs = rsv.plan("ring", c, &spec).unwrap();
        let mine = vec![1u64; counts.total() - 1];
        bad += rs.execute(&mine, &mut vec![0u64; counts.get(c.rank())]).is_err() as usize;
        let mine = vec![1u64; counts.total()];
        bad += rs.execute(&mine, &mut vec![0u64; counts.get(c.rank()) + 1]).is_err() as usize;
        bad
    });
    assert!(run.results.iter().all(|&b| b == 6));
    let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
    assert_eq!(total, 0, "rejected ragged calls must not leak messages");
}
