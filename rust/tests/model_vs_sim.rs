//! Integration: the closed-form models (§4, Eq. 3/4) agree with the
//! virtual-time execution of the real implementations on power-of-two
//! configurations — the two views of "cost" in the paper must be one.

use locag::collectives::Algorithm;
use locag::model::closed_form::ModelConfig;
use locag::model::MachineParams;
use locag::sim;
use locag::topology::Topology;

fn vtime(algo: Algorithm, regions: usize, ppr: usize, n_vals: usize) -> f64 {
    let topo = Topology::regions(regions, ppr);
    let rep = sim::run_allgather(algo, &topo, &MachineParams::lassen(), n_vals);
    assert!(rep.verified, "{algo} {regions}x{ppr}: {:?}", rep.errors);
    rep.vtime
}

fn model() -> ModelConfig {
    ModelConfig::lassen()
}

const TOL: f64 = 1e-9; // seconds; both sides are exact f64 sums

#[test]
fn bruck_matches_eq3_exactly() {
    for (regions, ppr, n_vals) in [
        (4usize, 4usize, 1usize),
        (4, 4, 2),
        (16, 4, 2),
        (8, 8, 4),
        (2, 2, 1),
    ] {
        let p = regions * ppr;
        let m = model().bruck(p, n_vals * 4);
        let v = vtime(Algorithm::Bruck, regions, ppr, n_vals);
        assert!(
            (m - v).abs() < TOL,
            "bruck p={p}: model {m:.3e} vs sim {v:.3e}"
        );
    }
}

#[test]
fn loc_bruck_matches_eq4_exactly_on_power_cases() {
    for (regions, ppr, n_vals) in [
        (4usize, 4usize, 1usize),
        (16, 4, 2),
        (64, 4, 1),
        (8, 8, 2),
        (64, 8, 2),
    ] {
        let p = regions * ppr;
        let m = model().loc_bruck(p, ppr, n_vals * 4);
        let v = vtime(Algorithm::LocalityBruck, regions, ppr, n_vals);
        assert!(
            (m - v).abs() < TOL,
            "loc-bruck {regions}x{ppr}: model {m:.3e} vs sim {v:.3e}"
        );
    }
}

#[test]
fn ring_matches_model() {
    for (regions, ppr) in [(4usize, 4usize), (8, 2)] {
        let p = regions * ppr;
        let m = model().ring(p, 8);
        let v = vtime(Algorithm::Ring, regions, ppr, 2);
        // ring model charges every step at non-local cost; the execution's
        // critical path crosses region boundaries on every step with block
        // placement, so these agree exactly too
        assert!(
            (m - v).abs() < TOL,
            "ring p={p}: model {m:.3e} vs sim {v:.3e}"
        );
    }
}

#[test]
fn recursive_doubling_matches_model() {
    for (regions, ppr) in [(4usize, 4usize), (8, 4), (4, 8)] {
        let p = regions * ppr;
        let m = model().recursive_doubling(p, ppr, 8);
        let v = vtime(Algorithm::RecursiveDoubling, regions, ppr, 2);
        assert!(
            (m - v).abs() < TOL,
            "rd p={p}: model {m:.3e} vs sim {v:.3e}"
        );
    }
}

#[test]
fn multilane_matches_model() {
    for (regions, ppr) in [(4usize, 4usize), (8, 4)] {
        let p = regions * ppr;
        let m = model().multilane(p, ppr, 8);
        let v = vtime(Algorithm::Multilane, regions, ppr, 2);
        assert!(
            (m - v).abs() < TOL,
            "multilane p={p}: model {m:.3e} vs sim {v:.3e}"
        );
    }
}

#[test]
fn hierarchical_model_tracks_sim_within_slack() {
    // The closed form charges the gather serially at the master; the
    // execution's arrival-time max can be slightly cheaper. Tolerate 30%.
    for (regions, ppr) in [(4usize, 4usize), (8, 8)] {
        let p = regions * ppr;
        let m = model().hierarchical(p, ppr, 8);
        let v = vtime(Algorithm::Hierarchical, regions, ppr, 2);
        let rel = (m - v).abs() / m.max(v);
        assert!(
            rel < 0.3,
            "hierarchical p={p}: model {m:.3e} vs sim {v:.3e} (rel {rel:.2})"
        );
    }
}

#[test]
fn eager_rendezvous_transition_visible_in_both() {
    // Crossing the 8 KiB threshold must bend both curves the same way.
    let cfg = model();
    let small = cfg.bruck(16, 1024); // blocks < 8 KiB
    let large = cfg.bruck(16, 4096); // later blocks > 8 KiB
    assert!(large > small);
    let v_small = vtime(Algorithm::Bruck, 4, 4, 256); // 1 KiB per rank
    let v_large = vtime(Algorithm::Bruck, 4, 4, 1024); // 4 KiB per rank
    assert!(
        (v_small - cfg.bruck(16, 1024)).abs() < TOL,
        "{v_small} vs {}",
        cfg.bruck(16, 1024)
    );
    assert!((v_large - cfg.bruck(16, 4096)).abs() < TOL);
}

#[test]
fn uniform_machine_collapses_locality_gap() {
    // On a machine with no locality (Eq. 2 == Eq. 1) the locality-aware
    // algorithm must NOT beat bruck — its benefit comes only from the
    // class split.
    let m = MachineParams::uniform(1e-6, 1e-9);
    let topo = Topology::regions(16, 4);
    let std = sim::run_allgather(Algorithm::Bruck, &topo, &m, 2);
    let loc = sim::run_allgather(Algorithm::LocalityBruck, &topo, &m, 2);
    assert!(std.verified && loc.verified);
    assert!(
        loc.vtime >= std.vtime * 0.99,
        "no-locality machine: loc {} must not beat bruck {}",
        loc.vtime,
        std.vtime
    );
}

#[test]
fn schedule_ir_prediction_equals_virtual_time() {
    // The third view of "cost": the IR cost model (model::cost::predict)
    // replays the transport's postal clock algebra over the planned
    // schedules, so its prediction must equal the virtual-time execution
    // exactly — for every algorithm, not just the closed-form cases.
    let m = MachineParams::lassen();
    for (regions, ppr) in [(4usize, 4usize), (8, 4), (6, 4), (3, 2)] {
        let topo = Topology::regions(regions, ppr);
        for algo in Algorithm::ALL {
            if algo == Algorithm::RecursiveDoubling && !topo.size().is_power_of_two() {
                continue; // documented precondition
            }
            let rep = sim::run_allgather(algo, &topo, &m, 2);
            assert!(rep.verified, "{algo} {regions}x{ppr}: {:?}", rep.errors);
            assert!(
                (rep.predicted - rep.vtime).abs() < TOL,
                "{algo} {regions}x{ppr}: predicted {:.6e} vs vtime {:.6e}",
                rep.predicted,
                rep.vtime
            );
        }
    }
}
