//! Integration: the full serving pipeline (leader + TP workers + PJRT +
//! allgather) end to end, for several allgather algorithms and region
//! splits. Requires `make artifacts`; skips loudly otherwise.

use locag::collectives::Algorithm;
use locag::coordinator::{serve, ServeConfig};
use locag::runtime::Manifest;

fn have_artifacts() -> bool {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP coordinator_integration: built without the `pjrt` feature");
        return false;
    }
    match Manifest::load(Manifest::default_dir()) {
        Ok(_) => true,
        Err(e) => {
            eprintln!("SKIP coordinator_integration: {e}");
            false
        }
    }
}

fn cfg(algo: Algorithm, regions: usize, requests: usize) -> ServeConfig {
    ServeConfig {
        artifact_dir: Manifest::default_dir(),
        algo,
        regions,
        requests,
        warmup: 1,
        check: true,
        fused: false,
        consensus: true,
        fuse_batch: 1,
        ..ServeConfig::default()
    }
}

#[test]
fn serve_verifies_with_loc_bruck() {
    if !have_artifacts() {
        return;
    }
    let rep = serve(&cfg(Algorithm::LocalityBruck, 2, 4)).expect("serve");
    assert!(rep.verified, "max err {}", rep.max_err);
    assert!(rep.max_err < 1e-3);
    assert_eq!(rep.metrics.timings.len(), 4);
    assert!(rep.metrics.throughput > 0.0);
    assert!(!rep.output_sample.is_empty());
}

#[test]
fn serve_verifies_with_standard_bruck_and_ring() {
    if !have_artifacts() {
        return;
    }
    for algo in [Algorithm::Bruck, Algorithm::Ring] {
        let rep = serve(&cfg(algo, 2, 3)).expect("serve");
        assert!(rep.verified, "{algo}: max err {}", rep.max_err);
    }
}

#[test]
fn serve_single_region_topology() {
    if !have_artifacts() {
        return;
    }
    // all workers in one region: loc-bruck degenerates to a local bruck
    let rep = serve(&cfg(Algorithm::LocalityBruck, 1, 3)).expect("serve");
    assert!(rep.verified);
    assert_eq!(rep.trace.max_nonlocal_msgs(), 0);
}

#[test]
fn serve_rejects_bad_region_split() {
    if !have_artifacts() {
        return;
    }
    // tp=4 workers cannot split into 3 regions
    let err = serve(&cfg(Algorithm::LocalityBruck, 3, 2)).unwrap_err();
    assert!(err.to_string().contains("divide"));
}

#[test]
fn serve_traffic_depends_on_algorithm() {
    if !have_artifacts() {
        return;
    }
    let std = serve(&cfg(Algorithm::Bruck, 2, 3)).expect("serve");
    let loc = serve(&cfg(Algorithm::LocalityBruck, 2, 3)).expect("serve");
    assert!(std.verified && loc.verified);
    // loc-bruck must send strictly fewer non-local bytes per rank
    assert!(
        loc.trace.max_nonlocal_bytes() < std.trace.max_nonlocal_bytes(),
        "loc {} vs std {}",
        loc.trace.max_nonlocal_bytes(),
        std.trace.max_nonlocal_bytes()
    );
}

#[test]
fn fused_path_matches_reference_and_unfused() {
    if !have_artifacts() {
        return;
    }
    let mut fused_cfg = cfg(Algorithm::LocalityBruck, 2, 3);
    fused_cfg.fused = true;
    let fused = match serve(&fused_cfg) {
        Ok(r) => r,
        Err(e) if e.to_string().contains("fused_final") => {
            eprintln!("SKIP fused test: artifacts predate fused_final ({e})");
            return;
        }
        Err(e) => panic!("{e}"),
    };
    assert!(fused.verified, "fused max err {}", fused.max_err);
    let unfused = serve(&cfg(Algorithm::LocalityBruck, 2, 3)).expect("serve");
    // both pipelines answer the same final request
    let diff: f32 = fused
        .output_sample
        .iter()
        .zip(&unfused.output_sample)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(diff < 1e-4, "fused vs unfused sample diff {diff}");
}

#[test]
fn serve_with_request_microbatching() {
    if !have_artifacts() {
        return;
    }
    // fuse-batch 2: the chunk's two allgathers and the consensus
    // allreduce execute as one coalesced schedule; results must match the
    // unbatched pipeline. 5 requests also exercises final-chunk padding.
    let mut batched = cfg(Algorithm::LocalityBruck, 2, 4);
    batched.fuse_batch = 2;
    let rep = serve(&batched).expect("serve");
    assert!(rep.verified, "max err {}", rep.max_err);
    assert_eq!(rep.metrics.timings.len(), 4);
    let unbatched = serve(&cfg(Algorithm::LocalityBruck, 2, 4)).expect("serve");
    let diff: f32 = rep
        .output_sample
        .iter()
        .zip(&unbatched.output_sample)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(diff < 1e-4, "batched vs unbatched sample diff {diff}");

    let mut odd = cfg(Algorithm::LocalityBruck, 2, 4);
    odd.fuse_batch = 2;
    odd.requests = 5; // warmup 1 + 5 = 6 requests → 3 full chunks
    let rep = serve(&odd).expect("serve");
    assert!(rep.verified, "max err {}", rep.max_err);
    assert_eq!(rep.metrics.timings.len(), 5);
}

#[test]
fn serve_missing_artifacts_is_clean_error() {
    let cfg = ServeConfig {
        artifact_dir: "/nonexistent/locag_artifacts".into(),
        algo: Algorithm::LocalityBruck,
        regions: 2,
        requests: 1,
        warmup: 0,
        check: false,
        fused: false,
        consensus: true,
        fuse_batch: 1,
        ..ServeConfig::default()
    };
    let err = serve(&cfg).unwrap_err();
    assert!(err.to_string().contains("manifest"));
}
