//! Cross-backend conformance for the multi-process transport backend.
//!
//! `harness = false`: this binary doubles as the worker executable. The
//! proc backend re-execs `current_exe()` with a hidden `__worker` argv to
//! spawn one OS process per rank, so the test's `main` must dispatch that
//! entry before running any scenario — exactly like `src/main.rs` does for
//! the `locag` binary. (The library's `#[test]` unit tests never call
//! `run_proc` for the same reason: under libtest, `current_exe()` is the
//! libtest runner.)
//!
//! Scenarios, run sequentially:
//!
//! 1. an (op, algorithm) grid on small shapes where every rank's output
//!    bytes from the proc backend (shm rings + Unix sockets) must be
//!    **identical** to the in-process sim backend,
//! 2. a fused multi-collective plan (including an n=0 constituent),
//! 3. an n=0 single collective,
//! 4. a worker killed mid-run surfaces as a typed `Error::Transport` with
//!    the failing rank, within the configured deadline — never a hang.

use std::time::{Duration, Instant};

use locag::cli::Args;
use locag::collectives::{FuseSpec, OpKind};
use locag::error::Error;
use locag::model::MachineParams;
use locag::transport::{run_proc, run_sim_bytes, worker_main, ProcConfig, ProcJob};

fn main() {
    let mut args = Args::parse(std::env::args().skip(1).collect());
    if args.positional.first().map(String::as_str) == Some("__worker") {
        args.positional.remove(0);
        std::process::exit(worker_main(&args));
    }
    conformance_grid();
    fused_plan_conformance();
    empty_payload_conformance();
    killed_worker_surfaces_typed_error();
    println!("proc_backend: all scenarios passed");
}

/// Run `job` on both backends and require byte-identical per-rank outputs.
fn assert_conformance(regions: usize, ppr: usize, job: &ProcJob, what: &str) {
    let sim = run_sim_bytes(regions, ppr, job, &MachineParams::lassen())
        .unwrap_or_else(|e| panic!("{what}: sim backend failed: {e}"));
    let proc_rep = run_proc(regions, ppr, job, "lassen", &ProcConfig::default())
        .unwrap_or_else(|e| panic!("{what}: proc backend failed: {e}"));
    assert_eq!(proc_rep.outputs.len(), sim.len(), "{what}: rank count differs");
    for (rank, (got, want)) in proc_rep.outputs.iter().zip(&sim).enumerate() {
        assert_eq!(got, want, "{what}: rank {rank} output bytes differ across backends");
    }
}

fn single(op: OpKind, algo: &str, n: usize, elem_bytes: usize) -> ProcJob {
    ProcJob::Single { op, algo: algo.to_string(), n, elem_bytes }
}

fn conformance_grid() {
    // (2,2): mixed shm + socket traffic; (1,4): pure shm (one region);
    // (2,3): non-power shape. Kept small — each point spawns `p` OS
    // processes.
    let ag_shapes = [(2usize, 2usize), (1, 4), (2, 3)];
    let op_shapes = [(2usize, 2usize), (1, 4)];
    let ns = [1usize, 3];
    let ag_algos = ["bruck", "ring", "dissemination", "loc-bruck", "system-default", "model-tuned"];
    let ar_algos = ["recursive-doubling", "loc-aware", "rabenseifner"];
    let a2a_algos = ["pairwise", "bruck", "loc-aware"];
    let rs_algos = ["ring", "loc-aware"];
    let mut points = 0usize;
    for (regions, ppr) in ag_shapes {
        for n in ns {
            for algo in ag_algos {
                let what = format!("allgather/{algo} {regions}x{ppr} n={n}");
                assert_conformance(regions, ppr, &single(OpKind::Allgather, algo, n, 8), &what);
                points += 1;
            }
        }
    }
    for (regions, ppr) in op_shapes {
        for n in ns {
            for algo in ar_algos {
                let what = format!("allreduce/{algo} {regions}x{ppr} n={n}");
                assert_conformance(regions, ppr, &single(OpKind::Allreduce, algo, n, 8), &what);
                points += 1;
            }
            for algo in a2a_algos {
                let what = format!("alltoall/{algo} {regions}x{ppr} n={n}");
                assert_conformance(regions, ppr, &single(OpKind::Alltoall, algo, n, 8), &what);
                points += 1;
            }
            for algo in rs_algos {
                let what = format!("reduce-scatter/{algo} {regions}x{ppr} n={n}");
                assert_conformance(
                    regions,
                    ppr,
                    &single(OpKind::ReduceScatter, algo, n, 8),
                    &what,
                );
                points += 1;
            }
        }
    }
    // One 4-byte-element point: the wire format carries raw bytes, but the
    // canonical generators and reduction must agree on u32 too.
    assert_conformance(2, 2, &single(OpKind::Allgather, "bruck", 2, 4), "allgather/bruck u32");
    assert_conformance(2, 2, &single(OpKind::Allreduce, "loc-aware", 2, 4), "allreduce u32");
    points += 2;
    println!("proc_backend: conformance grid passed ({points} points, all byte-identical)");
}

fn fused_plan_conformance() {
    // The serving-loop shape: an allgather fused with the consensus
    // allreduce, plus an n=0 constituent that must fuse away cleanly.
    let specs = vec![
        FuseSpec::new(OpKind::Allgather, "loc-bruck", 2),
        FuseSpec::new(OpKind::Allreduce, "loc-aware", 1),
        FuseSpec::new(OpKind::Alltoall, "pairwise", 0),
    ];
    assert_conformance(2, 2, &ProcJob::Fused { specs }, "fused loc-bruck+loc-aware+empty");
    println!("proc_backend: fused plan conformance passed");
}

fn empty_payload_conformance() {
    let job = single(OpKind::Allgather, "bruck", 0, 8);
    assert_conformance(2, 2, &job, "allgather/bruck n=0");
    let rep = run_proc(2, 2, &job, "lassen", &ProcConfig::default()).unwrap();
    assert!(rep.outputs.iter().all(Vec::is_empty), "n=0 must produce empty outputs");
    println!("proc_backend: n=0 conformance passed");
}

fn killed_worker_surfaces_typed_error() {
    let cfg = ProcConfig { deadline: Duration::from_secs(5), kill_rank: Some(1) };
    let started = Instant::now();
    let res = run_proc(2, 2, &single(OpKind::Allgather, "bruck", 2, 8), "lassen", &cfg);
    let elapsed = started.elapsed();
    match res {
        Ok(_) => panic!("run with a killed worker must not succeed"),
        Err(Error::Transport { rank, round, ref what }) => {
            assert_eq!(rank, 1, "the killed rank must be attributed: {what}");
            assert_eq!(round, 0, "death before execution is round 0: {what}");
        }
        Err(other) => panic!("expected Error::Transport, got: {other}"),
    }
    // The whole point of the deadline: a dead peer is an error, not a hang.
    assert!(
        elapsed < Duration::from_secs(20),
        "error took {elapsed:?}; deadline did not bound the wait"
    );
    println!("proc_backend: killed-worker error path passed ({elapsed:?})");
}
