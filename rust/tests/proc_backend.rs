//! Cross-backend conformance for the multi-process transport backend.
//!
//! `harness = false`: this binary doubles as the worker executable. The
//! proc backend re-execs `current_exe()` with a hidden `__worker` argv to
//! spawn one OS process per rank, so the test's `main` must dispatch that
//! entry before running any scenario — exactly like `src/main.rs` does for
//! the `locag` binary. (The library's `#[test]` unit tests never call
//! `run_proc` for the same reason: under libtest, `current_exe()` is the
//! libtest runner.)
//!
//! Scenarios, run sequentially:
//!
//! 1. an (op, algorithm) grid on small shapes where every rank's output
//!    bytes from the proc backend (shm rings + Unix sockets) must be
//!    **identical** to the in-process sim backend,
//! 1b. the PAT aggregated-tree schedules (allgather + reduce-scatter) and
//!     the hierarchical `loc-rabenseifner` allreduce across OS processes,
//!     on mixed-channel and non-power-of-two shapes at a payload the grid
//!     doesn't cover — the new builders are pure `Schedule` data, so the
//!     workers' byte interpreter must reproduce sim exactly,
//! 1c. the ragged collectives (`allgatherv` / `reduce-scatter-v`) as
//!     [`ProcJob::SingleV`]: the job spec ships the full per-rank counts
//!     vector (zeros included), every worker rebuilds its counts-aware
//!     schedule, and per-rank ragged buffer sizes cross both channel
//!     classes byte-identical to sim — including the all-zero no-op,
//! 2. a fused multi-collective plan (including an n=0 constituent),
//! 3. an n=0 single collective,
//! 4. the persistent-pool contract: one spawn + handshake serves 100
//!    executes byte-identical to the sim backend, with the lifecycle
//!    counters proving zero re-spawns and a single schedule ship,
//! 5. input deltas between executes (only the delta crosses the control
//!    path) match the sim backend run on the same overridden inputs,
//! 6. a stale schedule id is a typed error that does NOT poison the pool,
//! 7. a worker killed mid-run surfaces as a typed `Error::Transport` with
//!    the failing rank, within the configured deadline — never a hang,
//! 8. a worker killed BETWEEN executes fails the next execute with
//!    `Error::Transport`, poisons the pool (fail-fast thereafter), and a
//!    freshly spawned pool fully recovers,
//! 9. a `PoolGate` serving thread-per-rank exchanges of a fused f32 plan
//!    (the coordinator's hot path) matches the sim backend,
//! 10. a mixed-element-type fused job (`f32` allgather ⊕ `u64` allreduce
//!     ⊕ `f32` reduce-scatter), run byte-scaled through the workers'
//!     segmented-view interpreter, matches the sim backend,
//! 11. the full serving-chunk shape (K allgathers ⊕ reduce-scatter
//!     shards ⊕ consensus allreduce, f32) matches the sim backend.

use std::sync::Arc;
use std::time::{Duration, Instant};

use locag::cli::Args;
use locag::collectives::{FuseSpec, OpKind};
use locag::error::Error;
use locag::model::MachineParams;
use locag::transport::{
    run_proc, run_sim_bytes, run_sim_bytes_with_inputs, worker_main, DType, PoolGate, ProcConfig,
    ProcJob, ProcPool,
};

fn main() {
    let mut args = Args::parse(std::env::args().skip(1).collect());
    if args.positional.first().map(String::as_str) == Some("__worker") {
        args.positional.remove(0);
        std::process::exit(worker_main(&args));
    }
    conformance_grid();
    pat_cross_backend_conformance();
    ragged_cross_backend_conformance();
    fused_plan_conformance();
    empty_payload_conformance();
    persistent_pool_repeat_execute();
    input_deltas_between_executes();
    stale_schedule_id_is_typed_and_non_poisoning();
    killed_worker_surfaces_typed_error();
    killed_worker_between_executes_then_fresh_pool_recovers();
    pool_gate_serves_thread_per_rank_exchanges();
    fused_mixed_cross_backend_conformance();
    serving_chunk_shape_conformance();
    println!("proc_backend: all scenarios passed");
}

/// Run `job` on both backends and require byte-identical per-rank outputs.
fn assert_conformance(regions: usize, ppr: usize, job: &ProcJob, what: &str) {
    let sim = run_sim_bytes(regions, ppr, job, &MachineParams::lassen())
        .unwrap_or_else(|e| panic!("{what}: sim backend failed: {e}"));
    let proc_rep = run_proc(regions, ppr, job, "lassen", &ProcConfig::default())
        .unwrap_or_else(|e| panic!("{what}: proc backend failed: {e}"));
    assert_eq!(proc_rep.outputs.len(), sim.len(), "{what}: rank count differs");
    for (rank, (got, want)) in proc_rep.outputs.iter().zip(&sim).enumerate() {
        assert_eq!(got, want, "{what}: rank {rank} output bytes differ across backends");
    }
}

fn single(op: OpKind, algo: &str, n: usize, elem_bytes: usize) -> ProcJob {
    ProcJob::Single { op, algo: algo.to_string(), n, elem_bytes }
}

fn conformance_grid() {
    // (2,2): mixed shm + socket traffic; (1,4): pure shm (one region);
    // (2,3): non-power shape. Kept small — each point spawns `p` OS
    // processes.
    let ag_shapes = [(2usize, 2usize), (1, 4), (2, 3)];
    let op_shapes = [(2usize, 2usize), (1, 4)];
    let ns = [1usize, 3];
    let ag_algos =
        ["bruck", "pat", "ring", "dissemination", "loc-bruck", "system-default", "model-tuned"];
    let ar_algos = ["recursive-doubling", "loc-aware", "rabenseifner", "loc-rabenseifner"];
    let a2a_algos = ["pairwise", "bruck", "loc-aware"];
    let rs_algos = ["ring", "pat", "loc-aware"];
    let mut points = 0usize;
    for (regions, ppr) in ag_shapes {
        for n in ns {
            for algo in ag_algos {
                let what = format!("allgather/{algo} {regions}x{ppr} n={n}");
                assert_conformance(regions, ppr, &single(OpKind::Allgather, algo, n, 8), &what);
                points += 1;
            }
        }
    }
    for (regions, ppr) in op_shapes {
        for n in ns {
            for algo in ar_algos {
                let what = format!("allreduce/{algo} {regions}x{ppr} n={n}");
                assert_conformance(regions, ppr, &single(OpKind::Allreduce, algo, n, 8), &what);
                points += 1;
            }
            for algo in a2a_algos {
                let what = format!("alltoall/{algo} {regions}x{ppr} n={n}");
                assert_conformance(regions, ppr, &single(OpKind::Alltoall, algo, n, 8), &what);
                points += 1;
            }
            for algo in rs_algos {
                let what = format!("reduce-scatter/{algo} {regions}x{ppr} n={n}");
                assert_conformance(
                    regions,
                    ppr,
                    &single(OpKind::ReduceScatter, algo, n, 8),
                    &what,
                );
                points += 1;
            }
        }
    }
    // One 4-byte-element point: the wire format carries raw bytes, but the
    // canonical generators and reduction must agree on u32 too.
    assert_conformance(2, 2, &single(OpKind::Allgather, "bruck", 2, 4), "allgather/bruck u32");
    assert_conformance(2, 2, &single(OpKind::Allreduce, "loc-aware", 2, 4), "allreduce u32");
    points += 2;
    println!("proc_backend: conformance grid passed ({points} points, all byte-identical)");
}

/// Scenario 1b: the PR's new builders across real OS processes. PAT's
/// wrap-around ring-distance peers exercise both channel classes on
/// (2,2) (shm within a region, sockets across) and the non-power-of-two
/// path on (2,3); `loc-rabenseifner` adds the ragged-chunk hierarchy
/// (n = 5 not a multiple of ppr). Byte-identical to sim on every rank.
fn pat_cross_backend_conformance() {
    for (regions, ppr) in [(2usize, 2usize), (2, 3)] {
        let what = format!("pat allgather {regions}x{ppr} n=5");
        assert_conformance(regions, ppr, &single(OpKind::Allgather, "pat", 5, 8), &what);
        let what = format!("pat reduce-scatter {regions}x{ppr} n=5");
        assert_conformance(regions, ppr, &single(OpKind::ReduceScatter, "pat", 5, 8), &what);
        let what = format!("loc-rabenseifner {regions}x{ppr} n=5");
        let job = single(OpKind::Allreduce, "loc-rabenseifner", 5, 8);
        assert_conformance(regions, ppr, &job, &what);
    }
    println!("proc_backend: PAT + loc-rabenseifner cross-backend conformance passed");
}

/// Scenario 1c: ragged collectives across real OS processes. The
/// `singlev` job spec carries the full per-rank counts vector (zeros
/// allowed); every worker rebuilds its own counts-aware schedule from it,
/// so rank `r` contributes `counts[r]` elements (allgatherv) or receives
/// them (reduce-scatter-v) — byte-identical to the sim backend on every
/// rank, for every registered algorithm including the model-tuned
/// dispatcher.
fn ragged_cross_backend_conformance() {
    for (regions, ppr, counts) in
        [(2usize, 2usize, vec![3usize, 0, 2, 1]), (2, 3, vec![0, 4, 1, 0, 2, 5])]
    {
        for algo in ["ring", "bruck", "loc-aware", "model-tuned"] {
            let job = ProcJob::SingleV {
                op: OpKind::Allgatherv,
                algo: algo.to_string(),
                counts: counts.clone(),
                elem_bytes: 8,
            };
            let what = format!("allgatherv/{algo} {regions}x{ppr} {counts:?}");
            assert_conformance(regions, ppr, &job, &what);
        }
        for algo in ["ring", "loc-aware", "model-tuned"] {
            let job = ProcJob::SingleV {
                op: OpKind::ReduceScatterV,
                algo: algo.to_string(),
                counts: counts.clone(),
                elem_bytes: 8,
            };
            let what = format!("reduce-scatter-v/{algo} {regions}x{ppr} {counts:?}");
            assert_conformance(regions, ppr, &job, &what);
        }
    }
    // One 4-byte-element ragged point: the u32 generators must agree too.
    let job = ProcJob::SingleV {
        op: OpKind::ReduceScatterV,
        algo: "ring".to_string(),
        counts: vec![3, 0, 2, 1],
        elem_bytes: 4,
    };
    assert_conformance(2, 2, &job, "reduce-scatter-v/ring u32 [3,0,2,1]");
    // The ragged zero-length contract: all-zero counts ship no schedule,
    // move no bytes, and produce empty outputs on every rank.
    let job = ProcJob::SingleV {
        op: OpKind::Allgatherv,
        algo: "loc-aware".to_string(),
        counts: vec![0; 4],
        elem_bytes: 8,
    };
    assert_conformance(2, 2, &job, "allgatherv/loc-aware all-zero counts");
    let rep = run_proc(2, 2, &job, "lassen", &ProcConfig::default()).unwrap();
    assert!(rep.outputs.iter().all(Vec::is_empty), "all-zero counts must yield empty outputs");
    println!("proc_backend: ragged cross-backend conformance passed");
}

fn fused_plan_conformance() {
    // The serving-loop shape: an allgather fused with the consensus
    // allreduce, plus an n=0 constituent that must fuse away cleanly.
    let specs = vec![
        FuseSpec::new(OpKind::Allgather, "loc-bruck", 2),
        FuseSpec::new(OpKind::Allreduce, "loc-aware", 1),
        FuseSpec::new(OpKind::Alltoall, "pairwise", 0),
    ];
    assert_conformance(2, 2, &ProcJob::fused(specs), "fused loc-bruck+loc-aware+empty");
    println!("proc_backend: fused plan conformance passed");
}

fn empty_payload_conformance() {
    let job = single(OpKind::Allgather, "bruck", 0, 8);
    assert_conformance(2, 2, &job, "allgather/bruck n=0");
    let rep = run_proc(2, 2, &job, "lassen", &ProcConfig::default()).unwrap();
    assert!(rep.outputs.iter().all(Vec::is_empty), "n=0 must produce empty outputs");
    println!("proc_backend: n=0 conformance passed");
}

/// The tentpole contract: spawn + handshake ONCE, ship the schedule ONCE,
/// then serve many executes over the same channels. 100 repeats must stay
/// byte-identical to the sim backend, and the lifecycle counters must
/// prove no re-spawn, no re-handshake, and no re-plan happened.
fn persistent_pool_repeat_execute() {
    const REPEATS: usize = 100;
    let job = single(OpKind::Allgather, "loc-bruck", 3, 8);
    let want = run_sim_bytes(2, 2, &job, &MachineParams::lassen()).expect("sim reference");
    let mut pool = ProcPool::spawn(2, 2, "lassen", &ProcConfig::default()).expect("spawn");
    let sid = pool.load(&job).expect("load");
    for i in 0..REPEATS {
        let rep = pool.execute(sid).unwrap_or_else(|e| panic!("execute #{i}: {e}"));
        assert_eq!(rep.outputs, want, "execute #{i} diverged from the sim backend");
    }
    let stats = pool.stats();
    assert_eq!(stats.workers_spawned, 4, "repeat executes must not re-spawn workers");
    assert_eq!(stats.handshakes, 4, "repeat executes must not re-handshake");
    assert_eq!(stats.loads, 1, "the schedule must ship exactly once");
    assert_eq!(stats.executes, REPEATS);
    pool.shutdown().expect("shutdown");
    println!("proc_backend: persistent pool served {REPEATS} executes on one spawn/load");
}

/// Between executes only the input delta crosses the control path; the
/// workers' resident schedule and buffers are reused. Mutated inputs must
/// be reflected in the outputs, matching the sim backend run on the same
/// overridden inputs. A wrong-size delta is a parent-side precondition
/// error that leaves the pool fully usable.
fn input_deltas_between_executes() {
    let machine = MachineParams::lassen();
    let (regions, ppr) = (2usize, 2usize);
    let p = regions * ppr;
    let n = 2usize;
    let job = single(OpKind::Allreduce, "loc-aware", n, 8);
    let mut pool = ProcPool::spawn(regions, ppr, "lassen", &ProcConfig::default()).expect("spawn");
    let sid = pool.load(&job).expect("load");
    // Canonical inputs first, then three rounds of distinct overrides.
    let rep = pool.execute(sid).expect("canonical execute");
    assert_eq!(rep.outputs, run_sim_bytes(regions, ppr, &job, &machine).unwrap());
    for trial in 0..3u64 {
        let inputs: Vec<Vec<u8>> = (0..p)
            .map(|r| {
                (0..n as u64)
                    .flat_map(|j| ((r as u64) * 7919 + j + trial * 104_729).to_ne_bytes())
                    .collect()
            })
            .collect();
        let want = run_sim_bytes_with_inputs(regions, ppr, &job, &machine, &inputs)
            .expect("sim with inputs");
        let rep = pool.execute_with_inputs(sid, &inputs).expect("execute with inputs");
        assert_eq!(rep.outputs, want, "trial {trial}: mutated inputs not reflected in outputs");
    }
    let undersized = vec![vec![0u8; 1]; p];
    assert!(
        pool.execute_with_inputs(sid, &undersized).is_err(),
        "a wrong-size input delta must be rejected"
    );
    assert!(pool.execute(sid).is_ok(), "a rejected delta must not poison the pool");
    pool.shutdown().expect("shutdown");
    println!("proc_backend: input deltas between executes passed");
}

/// A schedule id that was never loaded is caught parent-side: a typed
/// `Error::Transport` that does not poison the pool, so a valid load +
/// execute right after must succeed.
fn stale_schedule_id_is_typed_and_non_poisoning() {
    let mut pool = ProcPool::spawn(1, 2, "lassen", &ProcConfig::default()).expect("spawn");
    match pool.execute(42) {
        Err(Error::Transport { ref what, .. }) => {
            assert!(what.contains("stale schedule id"), "unexpected message: {what}");
        }
        Ok(_) => panic!("a never-loaded schedule id must not execute"),
        Err(other) => panic!("expected Error::Transport, got: {other}"),
    }
    let sid = pool.load(&single(OpKind::Allgather, "ring", 1, 8)).expect("load after stale id");
    assert!(pool.execute(sid).is_ok(), "a stale schedule id must not poison the pool");
    pool.shutdown().expect("shutdown");
    println!("proc_backend: stale schedule id path passed");
}

fn killed_worker_surfaces_typed_error() {
    let cfg = ProcConfig {
        deadline: Duration::from_secs(5),
        kill_rank: Some(1),
        ..ProcConfig::default()
    };
    let started = Instant::now();
    let res = run_proc(2, 2, &single(OpKind::Allgather, "bruck", 2, 8), "lassen", &cfg);
    let elapsed = started.elapsed();
    match res {
        Ok(_) => panic!("run with a killed worker must not succeed"),
        Err(Error::Transport { rank, round, ref what }) => {
            assert_eq!(rank, 1, "the killed rank must be attributed: {what}");
            assert_eq!(round, 0, "death before execution is round 0: {what}");
        }
        Err(other) => panic!("expected Error::Transport, got: {other}"),
    }
    // The whole point of the deadline: a dead peer is an error, not a hang.
    assert!(
        elapsed < Duration::from_secs(20),
        "error took {elapsed:?}; deadline did not bound the wait"
    );
    println!("proc_backend: killed-worker error path passed ({elapsed:?})");
}

/// A worker that dies BETWEEN executes fails the next execute fast with a
/// typed error, leaves the pool poisoned (every later call fails fast and
/// points at respawning), and a fresh pool spawned afterwards fully
/// recovers the same job.
fn killed_worker_between_executes_then_fresh_pool_recovers() {
    let cfg = ProcConfig { deadline: Duration::from_secs(5), ..ProcConfig::default() };
    let job = single(OpKind::Allgather, "bruck", 2, 8);
    let mut pool = ProcPool::spawn(2, 2, "lassen", &cfg).expect("spawn");
    let sid = pool.load(&job).expect("load");
    pool.execute(sid).expect("execute before the kill");
    pool.kill_worker(1).expect("kill worker 1");
    let started = Instant::now();
    match pool.execute(sid) {
        Ok(_) => panic!("execute after a worker death must not succeed"),
        Err(Error::Transport { .. }) => {}
        Err(other) => panic!("expected Error::Transport, got: {other}"),
    }
    let elapsed = started.elapsed();
    assert!(elapsed < Duration::from_secs(20), "death detection took {elapsed:?}");
    // The data channels are in an unknown state: the pool is poisoned and
    // every later call fails fast with the respawn hint.
    match pool.execute(sid) {
        Err(Error::Transport { ref what, .. }) => {
            assert!(what.contains("fresh ProcPool"), "missing respawn hint: {what}");
        }
        Ok(_) => panic!("poisoned pool must fail fast"),
        Err(other) => panic!("poisoned pool must fail with Error::Transport, got: {other}"),
    }
    drop(pool);
    let mut fresh = ProcPool::spawn(2, 2, "lassen", &cfg).expect("fresh spawn after poison");
    let sid = fresh.load(&job).expect("fresh load");
    let rep = fresh.execute(sid).expect("fresh execute");
    assert_eq!(rep.outputs, run_sim_bytes(2, 2, &job, &MachineParams::lassen()).unwrap());
    fresh.shutdown().expect("fresh shutdown");
    println!("proc_backend: worker death between executes + recovery passed ({elapsed:?})");
}

/// The coordinator's hot path: thread-per-rank callers share one pool via
/// a `PoolGate`, exchanging a fused f32 plan (allgather ⊕ consensus
/// allreduce). Integer-valued floats keep f32 sums exact under any
/// summation order, so the outputs must be byte-identical to the sim
/// backend on the same inputs.
fn pool_gate_serves_thread_per_rank_exchanges() {
    let (regions, ppr) = (2usize, 2usize);
    let p = regions * ppr;
    let specs = vec![
        FuseSpec::new(OpKind::Allgather, "loc-bruck", 2),
        FuseSpec::new(OpKind::Allreduce, "loc-aware", 1),
    ];
    let job = ProcJob::Fused { specs, dtype: DType::F32 };
    let machine = MachineParams::lassen();
    let mut pool = ProcPool::spawn(regions, ppr, "lassen", &ProcConfig::default()).expect("spawn");
    let sid = pool.load(&job).expect("load");
    let gate = Arc::new(PoolGate::new(pool, sid));
    for round in 0..3u32 {
        // Per-rank composite input in spec order: 2 allgather elems, then
        // the 1 consensus elem. All values are small integers.
        let inputs: Vec<Vec<u8>> = (0..p)
            .map(|r| {
                let consensus = (r + 1) as f32 * (round + 1) as f32;
                let vals = [(r * 100 + 1) as f32, (r * 100 + 2) as f32, consensus];
                vals.iter().flat_map(|v| v.to_ne_bytes()).collect()
            })
            .collect();
        let want = run_sim_bytes_with_inputs(regions, ppr, &job, &machine, &inputs)
            .expect("sim with inputs");
        let handles: Vec<_> = (0..p)
            .map(|r| {
                let gate = Arc::clone(&gate);
                let input = inputs[r].clone();
                std::thread::spawn(move || {
                    let mut out = Vec::new();
                    gate.exchange(r, &input, &mut out).map(|_| out)
                })
            })
            .collect();
        for (r, h) in handles.into_iter().enumerate() {
            let out = h.join().expect("gate thread panicked").expect("gate exchange");
            assert_eq!(out, want[r], "round {round}: rank {r} gate output differs from sim");
        }
    }
    println!("proc_backend: PoolGate thread-per-rank exchanges passed");
}

/// Mixed element types across OS processes: every worker executes the
/// byte-scaled fused schedule through the segmented-view interpreter, so
/// the `f32` constituents reduce as floats and the `u64` ones as
/// integers — byte-identical to the in-process backend. The canonical
/// generators keep float payloads integer-valued, so sums are exact.
fn fused_mixed_cross_backend_conformance() {
    for (regions, ppr) in [(2usize, 2usize), (1, 4)] {
        let job = ProcJob::FusedMixed {
            specs: vec![
                (FuseSpec::new(OpKind::Allgather, "loc-bruck", 2), DType::F32),
                (FuseSpec::new(OpKind::Allreduce, "loc-aware", 1), DType::U64),
                (FuseSpec::new(OpKind::ReduceScatter, "ring", 1), DType::F32),
            ],
        };
        let what = format!("fused-mixed f32+u64 {regions}x{ppr}");
        assert_conformance(regions, ppr, &job, &what);
    }
    println!("proc_backend: mixed-type fused conformance passed");
}

/// The serving loop's per-chunk collective, exactly as `serve` plans it:
/// K request allgathers ⊕ reduce-scatter shards ⊕ the consensus
/// allreduce, all f32, as one fused schedule — byte-identical across
/// backends.
fn serving_chunk_shape_conformance() {
    let k = 4usize;
    let mut specs: Vec<FuseSpec> =
        (0..k).map(|_| FuseSpec::new(OpKind::Allgather, "loc-bruck", 3)).collect();
    specs.push(FuseSpec::new(OpKind::ReduceScatter, "ring", 2));
    specs.push(FuseSpec::new(OpKind::Allreduce, "loc-aware", 2 * k));
    let job = ProcJob::Fused { specs, dtype: DType::F32 };
    assert_conformance(2, 2, &job, "serving chunk shape (4xAG + RS + AR, f32)");
    println!("proc_backend: serving-chunk fused conformance passed");
}
