//! Property tests (in-tree testkit, see DESIGN.md): allgather invariants
//! over randomly generated topologies, payload sizes and placements.

use locag::collectives::{self, Algorithm};
use locag::comm::{CommWorld, Timing};
use locag::model::MachineParams;
use locag::sim;
use locag::testkit::{check, Config};
use locag::topology::{Placement, RegionKind, Topology};
use locag::util::{ilog2_ceil, ilog_ceil};

/// Every algorithm returns the exact expected array on every rank for any
/// (regions, ppr, n) the algorithm supports.
#[test]
fn prop_allgather_correct_on_random_shapes() {
    check(
        Config::default().cases(24).named("allgather-correct"),
        |g| {
            let (regions, ppr) = g.region_shape(64);
            let n = g.payload_len(64);
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let algo = *g.choose(&Algorithm::ALL);
            if algo == Algorithm::RecursiveDoubling && !p.is_power_of_two() {
                return; // documented precondition
            }
            let expect = collectives::expected_result(p, n);
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                let mine = collectives::canonical_contribution(c.rank(), n);
                collectives::allgather(algo, c, &mine)
            });
            for (rank, res) in run.results.iter().enumerate() {
                let got = res
                    .as_ref()
                    .unwrap_or_else(|e| panic!("{algo} {regions}x{ppr} n={n} rank {rank}: {e}"));
                assert_eq!(
                    got, &expect,
                    "{algo} {regions}x{ppr} n={n} rank {rank}"
                );
            }
        },
    );
}

/// Paper §4 message-count invariants hold on every random shape.
#[test]
fn prop_message_count_invariants() {
    check(Config::default().cases(24).named("msg-counts"), |g| {
        let (regions, ppr) = g.region_shape(64);
        let n = g.payload_len(8);
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        let m = MachineParams::lassen();

        let std = sim::run_allgather(Algorithm::Bruck, &topo, &m, n);
        assert!(std.verified);
        assert_eq!(std.trace.max_total_msgs(), ilog2_ceil(p) as u64);
        // all bruck traffic from the worst region-0 rank is bounded by the
        // total data size
        assert!(std.trace.max_nonlocal_bytes() <= (p * n * 4) as u64);

        let loc = sim::run_allgather(Algorithm::LocalityBruck, &topo, &m, n);
        assert!(loc.verified);
        let bound = if regions > 1 && ppr > 1 {
            ilog_ceil(ppr, regions) as u64
        } else if ppr == 1 {
            ilog2_ceil(p) as u64 // bruck fallback
        } else {
            0
        };
        assert!(
            loc.trace.max_nonlocal_msgs() <= bound,
            "{regions}x{ppr}: {} > {bound}",
            loc.trace.max_nonlocal_msgs()
        );
    });
}

/// The virtual clock is monotone in data size: more bytes never model
/// faster, for every algorithm.
#[test]
fn prop_vtime_monotone_in_size() {
    check(Config::default().cases(12).named("vtime-monotone"), |g| {
        let (regions, ppr) = g.region_shape(32);
        let topo = Topology::regions(regions, ppr);
        let m = MachineParams::quartz();
        let algo = *g.choose(&[
            Algorithm::Bruck,
            Algorithm::LocalityBruck,
            Algorithm::Ring,
            Algorithm::Multilane,
        ]);
        let n1 = g.payload_len(32);
        let n2 = n1 * 2;
        let t1 = sim::run_allgather(algo, &topo, &m, n1);
        let t2 = sim::run_allgather(algo, &topo, &m, n2);
        assert!(t1.verified && t2.verified);
        assert!(
            t2.vtime >= t1.vtime - 1e-12,
            "{algo} {regions}x{ppr}: n={n1}→{} but n={n2}→{}",
            t1.vtime,
            t2.vtime
        );
    });
}

/// Placement never changes loc-bruck's non-local traffic (paper §3).
#[test]
fn prop_loc_bruck_placement_invariance() {
    check(Config::default().cases(10).named("placement-invariance"), |g| {
        let nodes = *g.choose(&[2usize, 4, 8]);
        let cores = *g.choose(&[2usize, 4, 8]);
        let seed_a = g.u64();
        let seed_b = g.u64();
        let m = MachineParams::quartz();
        let mk = |pl| Topology::machine(nodes, 1, cores, RegionKind::Node, pl).unwrap();
        let a = sim::run_allgather(
            Algorithm::LocalityBruck,
            &mk(Placement::Random { seed: seed_a }),
            &m,
            2,
        );
        let b = sim::run_allgather(
            Algorithm::LocalityBruck,
            &mk(Placement::Random { seed: seed_b }),
            &m,
            2,
        );
        assert!(a.verified && b.verified);
        assert_eq!(a.trace.max_nonlocal_msgs(), b.trace.max_nonlocal_msgs());
        assert_eq!(a.trace.total_nonlocal_bytes(), b.trace.total_nonlocal_bytes());
        assert!((a.vtime - b.vtime).abs() < 1e-12);
    });
}

/// Total bytes gathered is conserved: every algorithm moves at least the
/// information-theoretic minimum (each rank must receive (p-1)·n elements
/// worth of data from somewhere).
#[test]
fn prop_total_traffic_lower_bound() {
    check(Config::default().cases(12).named("traffic-bound"), |g| {
        let (regions, ppr) = g.region_shape(32);
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        if p == 1 {
            return;
        }
        let n = g.payload_len(8);
        let algo = *g.choose(&[
            Algorithm::Bruck,
            Algorithm::LocalityBruck,
            Algorithm::Ring,
            Algorithm::Hierarchical,
            Algorithm::Multilane,
        ]);
        let rep = sim::run_allgather(algo, &topo, &MachineParams::lassen(), n);
        assert!(rep.verified);
        let min_total = (p * (p - 1) * n * 4) as u64; // bytes received overall
        assert!(
            rep.trace.total_bytes() >= min_total,
            "{algo} {regions}x{ppr} n={n}: moved {} < floor {min_total}",
            rep.trace.total_bytes()
        );
    });
}

/// Alltoall invariants: all three implementations agree with each other
/// on random shapes, and the locality-aware variant never moves more
/// non-local bytes than Bruck alltoall.
#[test]
fn prop_alltoall_agreement() {
    use locag::collectives::alltoall;
    check(Config::default().cases(12).named("alltoall-agree"), |g| {
        let (regions, ppr) = g.region_shape(24);
        let n = g.payload_len(6);
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        let send = |rank: usize| -> Vec<u64> {
            (0..p * n)
                .map(|x| (rank * 10_000 + (x / n) * 100 + x % n) as u64)
                .collect()
        };
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let s = send(c.rank());
            let a = alltoall::pairwise(c, &s).unwrap();
            let b = alltoall::bruck(c, &s).unwrap();
            let l = alltoall::loc_aware(c, &s).unwrap();
            (a == b, b == l)
        });
        for (rank, &(ab, bl)) in run.results.iter().enumerate() {
            assert!(ab && bl, "{regions}x{ppr} n={n} rank {rank}: mismatch");
        }
    });
}

/// The locality-aware Bruck and its allgatherv variant always produce the
/// same result with identical non-local traffic.
#[test]
fn prop_loc_bruck_variants_agree() {
    check(Config::default().cases(12).named("variant-agree"), |g| {
        let (regions, ppr) = g.region_shape(48);
        let n = g.payload_len(16);
        let topo = Topology::regions(regions, ppr);
        let m = MachineParams::lassen();
        let a = sim::run_allgather(Algorithm::LocalityBruck, &topo, &m, n);
        let b = sim::run_allgather(Algorithm::LocalityBruckV, &topo, &m, n);
        assert!(a.verified && b.verified, "{regions}x{ppr} n={n}");
        assert_eq!(
            a.trace.total_nonlocal_bytes(),
            b.trace.total_nonlocal_bytes(),
            "{regions}x{ppr}"
        );
        assert_eq!(a.trace.max_nonlocal_msgs(), b.trace.max_nonlocal_msgs());
        // variant never moves MORE local bytes
        let la: u64 = a.trace.per_rank.iter().map(|t| t.local_bytes).sum();
        let lb: u64 = b.trace.per_rank.iter().map(|t| t.local_bytes).sum();
        assert!(lb <= la, "{regions}x{ppr}: variant {lb} > default {la}");
    });
}

/// The locality-aware allreduce equals recursive doubling on every
/// supported random shape.
#[test]
fn prop_allreduce_agreement() {
    use locag::collectives::allreduce;
    check(Config::default().cases(12).named("allreduce-agree"), |g| {
        let ppr = g.pow2_upto(8);
        let regions = g.usize_in(1, 8);
        let p = regions * ppr;
        if !p.is_power_of_two() && !allreduce::locality_rounds_align(regions, ppr) {
            return; // fallback path requires power-of-two p
        }
        let n = g.payload_len(8);
        let topo = Topology::regions(regions, ppr);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mine: Vec<u64> = (0..n).map(|j| (c.rank() * 7 + j) as u64).collect();
            allreduce::allreduce_locality_aware(c, &mine)
        });
        let expect: Vec<u64> = (0..n)
            .map(|j| (0..p).map(|r| (r * 7 + j) as u64).sum())
            .collect();
        for res in &run.results {
            assert_eq!(res.as_ref().unwrap(), &expect, "{regions}x{ppr} n={n}");
        }
    });
}
