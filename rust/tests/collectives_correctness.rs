//! Integration: every allgather algorithm produces the exact expected
//! gathered array on every rank, across topology shapes, payload sizes and
//! element types.

use locag::collectives::{self, Algorithm};
use locag::comm::{CommWorld, Timing};
use locag::topology::{Placement, RegionKind, Topology};

/// Run one algorithm over a topology with u64 canonical payloads and
/// assert exact results on every rank.
fn check_algo(algo: Algorithm, topo: &Topology, n: usize) {
    let p = topo.size();
    let expect = collectives::expected_result(p, n);
    let run = CommWorld::run(topo, Timing::Wallclock, |c| {
        let mine = collectives::canonical_contribution(c.rank(), n);
        collectives::allgather(algo, c, &mine)
    });
    for (rank, res) in run.results.iter().enumerate() {
        let got = res.as_ref().unwrap_or_else(|e| panic!("{algo} rank {rank}: {e}"));
        assert_eq!(got, &expect, "{algo} rank {rank} wrong result (p={p}, n={n})");
    }
}

fn all_shapes() -> Vec<Topology> {
    vec![
        Topology::regions(1, 1),
        Topology::regions(1, 8),
        Topology::regions(2, 2),
        Topology::regions(4, 4),
        Topology::regions(8, 4),
        Topology::regions(3, 4), // non-power region count
        Topology::regions(6, 4),
        Topology::regions(5, 2),
        Topology::regions(16, 2),
        Topology::regions(2, 16),
    ]
}

#[test]
fn bruck_all_shapes() {
    for topo in all_shapes() {
        check_algo(Algorithm::Bruck, &topo, 3);
    }
}

#[test]
fn ring_all_shapes() {
    for topo in all_shapes() {
        check_algo(Algorithm::Ring, &topo, 2);
    }
}

#[test]
fn dissemination_all_shapes() {
    for topo in all_shapes() {
        check_algo(Algorithm::Dissemination, &topo, 2);
    }
}

#[test]
fn recursive_doubling_power_of_two_shapes() {
    for topo in all_shapes() {
        if topo.size().is_power_of_two() {
            check_algo(Algorithm::RecursiveDoubling, &topo, 2);
        }
    }
}

#[test]
fn hierarchical_all_shapes() {
    for topo in all_shapes() {
        check_algo(Algorithm::Hierarchical, &topo, 2);
    }
}

#[test]
fn multilane_all_shapes() {
    for topo in all_shapes() {
        check_algo(Algorithm::Multilane, &topo, 2);
    }
}

#[test]
fn loc_bruck_all_shapes() {
    for topo in all_shapes() {
        check_algo(Algorithm::LocalityBruck, &topo, 2);
    }
}

#[test]
fn system_default_all_shapes() {
    for topo in all_shapes() {
        check_algo(Algorithm::SystemDefault, &topo, 2);
    }
}

#[test]
fn loc_bruck_multilevel_on_multisocket_machines() {
    for (nodes, sockets, cores) in [(2usize, 2usize, 2usize), (4, 2, 4), (2, 4, 2), (3, 2, 2)] {
        let topo =
            Topology::machine(nodes, sockets, cores, RegionKind::Node, Placement::Block)
                .unwrap();
        check_algo(Algorithm::LocalityBruckMultilevel, &topo, 2);
    }
}

#[test]
fn all_algorithms_under_random_placement() {
    let topo = Topology::machine(4, 1, 4, RegionKind::Node, Placement::Random { seed: 5 })
        .unwrap();
    for algo in Algorithm::ALL {
        check_algo(algo, &topo, 2);
    }
}

#[test]
fn large_payloads_cross_rendezvous_threshold() {
    // 2048 u64 = 16 KiB per rank — above the 8 KiB eager cutoff.
    let topo = Topology::regions(4, 4);
    for algo in [Algorithm::Bruck, Algorithm::LocalityBruck, Algorithm::Ring] {
        check_algo(algo, &topo, 2048);
    }
}

#[test]
fn single_element_payloads() {
    let topo = Topology::regions(4, 4);
    for algo in Algorithm::ALL {
        check_algo(algo, &topo, 1);
    }
}

#[test]
fn f32_payloads_roundtrip_exactly() {
    let topo = Topology::regions(2, 4);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let mine: Vec<f32> = (0..3).map(|j| c.rank() as f32 + j as f32 * 0.25).collect();
        collectives::allgather(Algorithm::LocalityBruck, c, &mine).unwrap()
    });
    for res in &run.results {
        for r in 0..8 {
            for j in 0..3 {
                assert_eq!(res[r * 3 + j], r as f32 + j as f32 * 0.25);
            }
        }
    }
}

#[test]
fn repeated_collectives_on_same_comm_do_not_interfere() {
    // tags must advance so back-to-back collectives stay isolated
    let topo = Topology::regions(4, 2);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let a = collectives::allgather(
            Algorithm::LocalityBruck,
            c,
            &[c.rank() as u64],
        )
        .unwrap();
        let b = collectives::allgather(
            Algorithm::Bruck,
            c,
            &[c.rank() as u64 + 100],
        )
        .unwrap();
        let d = collectives::allgather(
            Algorithm::LocalityBruck,
            c,
            &[c.rank() as u64 + 200],
        )
        .unwrap();
        (a, b, d)
    });
    for (a, b, d) in &run.results {
        assert_eq!(a, &(0..8u64).collect::<Vec<_>>());
        assert_eq!(b, &(100..108u64).collect::<Vec<_>>());
        assert_eq!(d, &(200..208u64).collect::<Vec<_>>());
    }
}
