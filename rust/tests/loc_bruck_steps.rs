//! Integration: the step structure of the locality-aware Bruck matches the
//! paper's worked examples (Figs. 4, 5, 6) message for message.

use locag::collectives::{self, Algorithm};
use locag::comm::{CommWorld, Timing};
use locag::model::MachineParams;
use locag::sim;
use locag::topology::Topology;

/// Example 2.1 (Figs. 4/5): 16 ranks in 4 regions of 4, one value each.
#[test]
fn example_2_1_full_walkthrough() {
    let topo = Topology::regions(4, 4);
    let rep = sim::run_allgather(
        Algorithm::LocalityBruck,
        &topo,
        &MachineParams::lassen(),
        1,
    );
    assert!(rep.verified);

    // Paper: "each process communicate only a single non-local message,
    // compared with the 4 non-local messages required by the standard
    // Bruck algorithm" — but local rank 0 of each region idles.
    for (rank, t) in rep.trace.per_rank.iter().enumerate() {
        if rank % 4 == 0 {
            assert_eq!(t.nonlocal_msgs, 0, "local rank 0 ({rank}) must idle");
        } else {
            assert_eq!(t.nonlocal_msgs, 1, "rank {rank} sends exactly one");
            // "communicate only 4 data values non-locally" = 16 bytes of u32
            assert_eq!(t.nonlocal_bytes, 16, "rank {rank}");
        }
    }

    // Local message structure: two local Bruck allgathers of 4 ranks
    // = 2 steps each → 4 local messages per rank.
    for t in &rep.trace.per_rank {
        assert_eq!(t.local_msgs, 4);
    }
}

/// Fig. 6: 64 processes across 16 regions — the second non-local step
/// exchanges whole groups of 4 regions.
#[test]
fn fig6_second_step_structure() {
    let topo = Topology::regions(16, 4);
    let rep = sim::run_allgather(
        Algorithm::LocalityBruck,
        &topo,
        &MachineParams::lassen(),
        1,
    );
    assert!(rep.verified);
    for (rank, t) in rep.trace.per_rank.iter().enumerate() {
        if rank % 4 == 0 {
            assert_eq!(t.nonlocal_msgs, 0);
        } else {
            // one message per non-local step
            assert_eq!(t.nonlocal_msgs, 2, "rank {rank}");
            // step 0 carries 1 region group (4 values), step 1 carries a
            // 4-region group (16 values): 20 u32 = 80 bytes
            assert_eq!(t.nonlocal_bytes, 80, "rank {rank}");
        }
    }
}

/// The paper's Fig. 6 example senders/receivers: process 5 receives from
/// 21, process 6 from 38, process 7 from 55 at the second step. We verify
/// the equivalent invariant: the gathered array is correct AND rank 5's
/// total received regions cover all 16 — step-level peers are fixed by the
/// formula dist = ℓ·pℓ^{i+1}.
#[test]
fn fig6_peer_formula() {
    // The peers are deterministic: local rank ℓ of region g exchanges with
    // local rank ℓ of region (g + ℓ·4^i) at step i. Check via the comm
    // layer by recording who each rank received non-local data from.
    let topo = Topology::regions(16, 4);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        collectives::allgather(Algorithm::LocalityBruck, c, &[c.rank() as u32]).unwrap()
    });
    // correctness across all 64 ranks is the observable of the right peers
    let expect: Vec<u32> = (0..64).collect();
    for r in &run.results {
        assert_eq!(r, &expect);
    }
}

/// Non-power region count (paper §3 + Fig. 6 discussion): the wrap-around
/// group re-covers region 0's data; assembly must stay exact and idle
/// ranks must not send.
#[test]
fn non_power_wraparound_idles_and_verifies() {
    // 6 regions of 4: step 0 active ℓ=1,2,3 (width 1); step 1 width 4,
    // only ℓ=1 active (4 < 6), its group wraps.
    let topo = Topology::regions(6, 4);
    let rep = sim::run_allgather(
        Algorithm::LocalityBruck,
        &topo,
        &MachineParams::lassen(),
        2,
    );
    assert!(rep.verified, "{:?}", rep.errors);
    for (rank, t) in rep.trace.per_rank.iter().enumerate() {
        let l = rank % 4;
        let expect_msgs = match l {
            0 => 0,
            1 => 2, // active both steps
            _ => 1, // active only in step 0
        };
        assert_eq!(t.nonlocal_msgs, expect_msgs, "rank {rank} (ℓ={l})");
    }
}

/// Multilevel structure: on a 2-socket machine the two-level variant must
/// strictly reduce *inter-socket* messages compared to the node-aware
/// single level (whose local gathers cross sockets blindly).
#[test]
fn multilevel_reduces_intersocket_traffic() {
    use locag::topology::{Locality, Placement, RegionKind};
    let topo = Topology::machine(4, 2, 4, RegionKind::Node, Placement::Block).unwrap();
    let m = MachineParams::lassen();
    let one = sim::run_allgather(Algorithm::LocalityBruck, &topo, &m, 2);
    let two = sim::run_allgather(Algorithm::LocalityBruckMultilevel, &topo, &m, 2);
    assert!(one.verified && two.verified);
    let (one_is_msgs, _) = one.trace.by_class(Locality::InterSocket);
    let (two_is_msgs, _) = two.trace.by_class(Locality::InterSocket);
    assert!(
        two_is_msgs < one_is_msgs,
        "2-level {two_is_msgs} must be < 1-level {one_is_msgs}"
    );
    // and it should be at least as fast on the Lassen-like model
    assert!(two.vtime <= one.vtime * 1.05);
}
