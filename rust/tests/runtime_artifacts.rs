//! Integration: the PJRT runtime loads and executes the AOT artifacts
//! produced by `make artifacts`, and the numerics match the in-Rust
//! reference (which in turn matches the pytest-validated jnp oracle).
//!
//! Skipped gracefully (with a loud message) if artifacts are missing, so
//! `cargo test` works before the first `make artifacts`; `make test`
//! always builds artifacts first.

use locag::coordinator::params::{max_abs_diff, ModelParams};
use locag::runtime::{Engine, Manifest};

fn artifacts_or_skip() -> Option<Manifest> {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP runtime_artifacts: built without the `pjrt` feature");
        return None;
    }
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP runtime_artifacts: {e}");
            None
        }
    }
}

#[test]
fn manifest_lists_all_three_artifacts() {
    let Some(m) = artifacts_or_skip() else { return };
    for name in ["partial_fwd", "final_fwd", "rotate"] {
        assert!(m.artifact(name).is_ok(), "missing {name}");
    }
    assert!(m.model.tp >= 1);
    assert_eq!(m.model.d_hidden % m.model.tp, 0);
}

#[test]
fn partial_forward_matches_reference() {
    let Some(_) = artifacts_or_skip() else { return };
    let engine = Engine::load(Manifest::default_dir()).expect("engine");
    let dims = engine.manifest.model;
    let params = ModelParams::generate(dims, 0.0);
    let x = params.example_batch(1.0);
    let shard = params.w1_shard(0);
    let exe = engine.executable("partial_fwd").unwrap();
    let got = exe.run_f32(&[&x, &shard]).expect("execute");

    // rust reference: gelu(x @ w1_shard)
    let (b, d, hs) = (dims.batch, dims.d_model, dims.hidden_shard());
    let mut want = vec![0f32; b * hs];
    locag::coordinator::params::matmul(&x, &shard, &mut want, b, d, hs);
    for v in want.iter_mut() {
        *v = locag::coordinator::params::gelu(*v);
    }
    let err = max_abs_diff(&got, &want);
    assert!(err < 1e-4, "partial_fwd err {err}");
}

#[test]
fn final_forward_matches_reference() {
    let Some(_) = artifacts_or_skip() else { return };
    let engine = Engine::load(Manifest::default_dir()).expect("engine");
    let dims = engine.manifest.model;
    let params = ModelParams::generate(dims, 0.0);
    let (b, h, o) = (dims.batch, dims.d_hidden, dims.d_out);
    let hbuf: Vec<f32> = (0..b * h).map(|i| ((i % 37) as f32 - 18.0) * 0.05).collect();
    let exe = engine.executable("final_fwd").unwrap();
    let got = exe.run_f32(&[&hbuf, &params.w2]).expect("execute");
    let mut want = vec![0f32; b * o];
    locag::coordinator::params::matmul(&hbuf, &params.w2, &mut want, b, h, o);
    let err = max_abs_diff(&got, &want);
    assert!(err < 1e-4, "final_fwd err {err}");
}

#[test]
fn rotate_artifact_is_bruck_rotation() {
    let Some(_) = artifacts_or_skip() else { return };
    let engine = Engine::load(Manifest::default_dir()).expect("engine");
    let dims = engine.manifest.model;
    let exe = engine.executable("rotate").unwrap();
    let n_flat = exe.spec.inputs[0].elems();
    let p = dims.tp;
    let blk = n_flat / p;
    let buf: Vec<f32> = (0..n_flat).map(|i| i as f32).collect();
    for shift in 0..p {
        let got = exe.run_rotate(&buf, shift as i32).expect("rotate");
        // expected: out[k] = block[(k - shift) mod p] — same as
        // collectives::bruck::rotate_down on f32 blocks
        let want = locag::collectives::bruck::rotate_down(&buf, blk, shift);
        assert_eq!(got, want, "shift {shift}");
    }
}

#[test]
fn shape_validation_errors_cleanly() {
    let Some(_) = artifacts_or_skip() else { return };
    let engine = Engine::load(Manifest::default_dir()).expect("engine");
    let exe = engine.executable("partial_fwd").unwrap();
    // wrong arity
    assert!(exe.run_f32(&[&[0.0]]).is_err());
    // wrong shape
    let dims = engine.manifest.model;
    let x = vec![0f32; dims.batch * dims.d_model];
    assert!(exe.run_f32(&[&x, &[0.0]]).is_err());
}
