//! Integration: persistent-plan reuse semantics.
//!
//! * Executing one plan 100× on shifting canonical inputs yields the
//!   correct result every time.
//! * Executions leak no collective tags: the parent communicator's
//!   `next_coll_tag` sequence is unaffected between executions.
//! * Executions build no sub-communicators (all groups derived at plan
//!   time) — asserted via `comm::sub_comms_built`.
//! * Under `Timing::Virtual`, every execution advances the clocks by the
//!   identical modeled delta (the schedule is deterministic).
//! * Repeated planned executes allocate strictly less than repeated
//!   one-shot calls — measured with a counting global allocator.
//! * The fused zero-copy view path performs **zero** staging copies,
//!   while the staged path tallies exactly its (input + output) bytes
//!   per execute — measured with the process-global staging counter.
//!
//! The tests in this file share process-wide counters (allocator bytes,
//! sub-communicator count), so every test takes `SERIAL` to keep the
//! measurements attributable.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use locag::collectives::{self, Algorithm, Counts, Shape};
use locag::comm::{self, CommWorld, Timing};
use locag::model::MachineParams;
use locag::topology::Topology;

/// Counts cumulative allocated bytes (never decremented).
struct CountingAlloc;

static ALLOCATED: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATED.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Serializes the tests of this binary so the process-wide counters stay
/// attributable to exactly one test at a time.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn shifted_contribution(rank: usize, n: usize, round: u64) -> Vec<u64> {
    (0..n).map(|j| (rank * 1_000_003 + j) as u64 + round * 7_777_777).collect()
}

fn shifted_expected(p: usize, n: usize, round: u64) -> Vec<u64> {
    (0..p).flat_map(|r| shifted_contribution(r, n, round)).collect()
}

/// The headline reuse property, for every built-in algorithm: 100
/// executions of one plan, shifting inputs, exact results, no tag leaks.
#[test]
fn hundred_executions_correct_and_leak_free() {
    let _g = serial();
    let topo = Topology::regions(4, 4);
    let p = topo.size();
    let n = 3usize;
    for algo in Algorithm::ALL {
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan = collectives::plan_allgather::<u64>(algo, c, Shape::elems(n)).unwrap();
            // Tag sequence probe: consuming one tag here tells us where the
            // counter stands after planning.
            let tag_after_plan = c.next_coll_tag();
            let mut out = vec![0u64; n * p];
            for round in 0..100u64 {
                let mine = shifted_contribution(c.rank(), n, round);
                plan.execute(&mine, &mut out).unwrap();
                assert_eq!(out, shifted_expected(p, n, round), "{algo} round {round}");
            }
            // No execution consumed a tag: the next tag is exactly one past
            // the probe.
            let tag_after_100 = c.next_coll_tag();
            assert_eq!(
                tag_after_100,
                tag_after_plan + 1,
                "{algo} leaked collective tags across executions"
            );
            true
        });
        assert!(run.results.iter().all(|&ok| ok), "{algo}");
    }
}

/// Executions construct zero sub-communicators — the groups, region
/// communicators and (for hierarchical) the masters' communicator all
/// exist from plan time.
#[test]
fn executions_build_no_sub_communicators() {
    let _g = serial();
    let topo = Topology::regions(4, 4);
    for algo in [
        Algorithm::LocalityBruck,
        Algorithm::LocalityBruckV,
        Algorithm::Hierarchical,
        Algorithm::Multilane,
    ] {
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan = collectives::plan_allgather::<u64>(algo, c, Shape::elems(2)).unwrap();
            c.barrier().unwrap(); // every rank finished planning
            let built_before = comm::sub_comms_built();
            let mut out = vec![0u64; 2 * 16];
            for round in 0..50u64 {
                let mine = shifted_contribution(c.rank(), 2, round);
                plan.execute(&mine, &mut out).unwrap();
            }
            c.barrier().unwrap(); // every rank finished executing
            comm::sub_comms_built() - built_before
        });
        for &delta in &run.results {
            assert_eq!(delta, 0, "{algo}: execute constructed sub-communicators");
        }
    }
}

/// Virtual clocks advance by the identical delta on every barrier-
/// separated execution: the plan replays the exact same schedule.
#[test]
fn virtual_clock_deltas_identical_per_execution() {
    let _g = serial();
    let topo = Topology::regions(4, 4);
    let machine = MachineParams::lassen();
    for algo in [Algorithm::LocalityBruck, Algorithm::Bruck, Algorithm::Hierarchical] {
        let run = CommWorld::run(&topo, Timing::Virtual(machine.clone()), |c| {
            let mut plan = collectives::plan_allgather::<u32>(algo, c, Shape::elems(2)).unwrap();
            let mut out = vec![0u32; 2 * 16];
            let mine: Vec<u32> = (0..2).map(|j| (c.rank() * 5 + j) as u32).collect();
            let mut deltas = Vec::new();
            for _ in 0..20 {
                c.barrier().unwrap();
                let t0 = c.clock();
                plan.execute(&mine, &mut out).unwrap();
                deltas.push(c.clock() - t0);
            }
            deltas
        });
        for (rank, deltas) in run.results.iter().enumerate() {
            for (i, &d) in deltas.iter().enumerate() {
                assert!(
                    (d - deltas[0]).abs() < 1e-15,
                    "{algo} rank {rank} execution {i}: delta {d} vs first {}",
                    deltas[0]
                );
            }
        }
    }
}

/// The acceptance micro-proof: repeated planned executes allocate strictly
/// less than repeated one-shot calls on the identical workload, because
/// the one-shot path re-derives groups, re-builds sub-communicators,
/// re-allocates schedules, scratch and the output on every call while the
/// plan reuses all of it. (Transport-level message buffers are identical
/// on both sides.)
#[test]
fn planned_executes_allocate_less_than_one_shot() {
    let _g = serial();
    let topo = Topology::regions(4, 4);
    let p = topo.size();
    let n = 128usize;
    let iters = 100u64;

    // Planned: plan once per rank, execute `iters` times.
    let before = ALLOCATED.load(Ordering::Relaxed);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let mut plan =
            collectives::plan_allgather::<u64>(Algorithm::LocalityBruck, c, Shape::elems(n))
                .unwrap();
        let mut out = vec![0u64; n * p];
        let mine = shifted_contribution(c.rank(), n, 0);
        for _ in 0..iters {
            plan.execute(&mine, &mut out).unwrap();
        }
        out[0]
    });
    std::hint::black_box(&run.results);
    let planned_total = ALLOCATED.load(Ordering::Relaxed) - before;

    // One-shot: plan + allocate on every call.
    let before = ALLOCATED.load(Ordering::Relaxed);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let mine = shifted_contribution(c.rank(), n, 0);
        let mut last = 0u64;
        for _ in 0..iters {
            let out = collectives::allgather::<u64>(Algorithm::LocalityBruck, c, &mine).unwrap();
            last = out[0];
        }
        last
    });
    std::hint::black_box(&run.results);
    let one_shot_total = ALLOCATED.load(Ordering::Relaxed) - before;

    assert!(
        planned_total < one_shot_total,
        "planned {planned_total} B must allocate less than one-shot {one_shot_total} B \
         over {iters} executions"
    );
}

/// The headline reuse property for the PR-2 operations: 100 executions of
/// one allreduce / alltoall plan, shifting inputs, exact results, no tag
/// leaks — mirroring `hundred_executions_correct_and_leak_free`.
#[test]
fn allreduce_and_alltoall_hundred_executions_correct_and_leak_free() {
    let _g = serial();
    let topo = Topology::regions(4, 4);
    let p = topo.size();
    let n = 3usize;
    // allreduce: every registered algorithm (4x4 is aligned + power of two)
    for algo in locag::collectives::AllreduceRegistry::<u64>::standard().names() {
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan = collectives::plan_allreduce::<u64>(algo, c, Shape::elems(n)).unwrap();
            let tag_after_plan = c.next_coll_tag();
            let mut out = vec![0u64; n];
            for round in 0..100u64 {
                let mine = shifted_contribution(c.rank(), n, round);
                plan.execute(&mine, &mut out).unwrap();
                let expect: Vec<u64> = (0..n)
                    .map(|j| {
                        (0..p)
                            .map(|r| (r * 1_000_003 + j) as u64 + round * 7_777_777)
                            .sum()
                    })
                    .collect();
                assert_eq!(out, expect, "allreduce/{algo} round {round}");
            }
            let tag_after_100 = c.next_coll_tag();
            assert_eq!(
                tag_after_100,
                tag_after_plan + 1,
                "allreduce/{algo} leaked collective tags across executions"
            );
            true
        });
        assert!(run.results.iter().all(|&ok| ok), "allreduce/{algo}");
    }
    // alltoall: every registered algorithm
    for algo in locag::collectives::AlltoallRegistry::<u64>::standard().names() {
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan = collectives::plan_alltoall::<u64>(algo, c, Shape::elems(n)).unwrap();
            let tag_after_plan = c.next_coll_tag();
            let mut out = vec![0u64; n * p];
            for round in 0..100u64 {
                let mine: Vec<u64> = (0..p * n)
                    .map(|x| (c.rank() * 1_000_003 + (x / n) * 1_009 + x % n) as u64 + round)
                    .collect();
                plan.execute(&mine, &mut out).unwrap();
                let expect: Vec<u64> = (0..p * n)
                    .map(|x| ((x / n) * 1_000_003 + c.rank() * 1_009 + x % n) as u64 + round)
                    .collect();
                assert_eq!(out, expect, "alltoall/{algo} round {round}");
            }
            let tag_after_100 = c.next_coll_tag();
            assert_eq!(
                tag_after_100,
                tag_after_plan + 1,
                "alltoall/{algo} leaked collective tags across executions"
            );
            true
        });
        assert!(run.results.iter().all(|&ok| ok), "alltoall/{algo}");
    }
}

/// The headline reuse property for reduce-scatter: 100 executions of one
/// plan per registered algorithm, shifting inputs, exact results, no tag
/// leaks — mirroring the other ops' reuse tests.
#[test]
fn reduce_scatter_hundred_executions_correct_and_leak_free() {
    let _g = serial();
    let topo = Topology::regions(4, 4);
    let p = topo.size();
    let n = 3usize;
    for algo in locag::collectives::ReduceScatterRegistry::<u64>::standard().names() {
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan =
                collectives::plan_reduce_scatter::<u64>(algo, c, Shape::elems(n)).unwrap();
            let tag_after_plan = c.next_coll_tag();
            let mut out = vec![0u64; n];
            for round in 0..100u64 {
                let mine: Vec<u64> = (0..p * n)
                    .map(|x| (c.rank() * 1_000_003 + (x / n) * 1_009 + x % n) as u64 + round)
                    .collect();
                plan.execute(&mine, &mut out).unwrap();
                let expect: Vec<u64> = (0..n)
                    .map(|j| {
                        (0..p)
                            .map(|r| (r * 1_000_003 + c.rank() * 1_009 + j) as u64 + round)
                            .sum()
                    })
                    .collect();
                assert_eq!(out, expect, "reduce-scatter/{algo} round {round}");
            }
            let tag_after_100 = c.next_coll_tag();
            assert_eq!(
                tag_after_100,
                tag_after_plan + 1,
                "reduce-scatter/{algo} leaked collective tags across executions"
            );
            true
        });
        assert!(run.results.iter().all(|&ok| ok), "reduce-scatter/{algo}");
    }
}

/// The headline reuse property for the ragged ops: 100 executions of one
/// allgatherv / reduce-scatter-v plan per registered algorithm on skewed
/// counts with zero-count ranks, shifting inputs, exact results, no tag
/// leaks — mirroring the uniform ops' reuse tests.
#[test]
fn ragged_hundred_executions_correct_and_leak_free() {
    let _g = serial();
    let topo = Topology::regions(4, 4);
    let p = topo.size();
    let counts = Counts::new((0..p).map(|r| (r * 3) % 5).collect());
    for algo in locag::collectives::AllgathervRegistry::<u64>::standard().names() {
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan = collectives::plan_allgatherv::<u64>(algo, c, &counts).unwrap();
            let tag_after_plan = c.next_coll_tag();
            let mut out = vec![0u64; counts.total()];
            for round in 0..100u64 {
                let mine = shifted_contribution(c.rank(), counts.get(c.rank()), round);
                plan.execute(&mine, &mut out).unwrap();
                let expect: Vec<u64> = (0..p)
                    .flat_map(|r| shifted_contribution(r, counts.get(r), round))
                    .collect();
                assert_eq!(out, expect, "allgatherv/{algo} round {round}");
            }
            let tag_after_100 = c.next_coll_tag();
            assert_eq!(
                tag_after_100,
                tag_after_plan + 1,
                "allgatherv/{algo} leaked collective tags across executions"
            );
            true
        });
        assert!(run.results.iter().all(|&ok| ok), "allgatherv/{algo}");
    }
    for algo in locag::collectives::ReduceScattervRegistry::<u64>::standard().names() {
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan = collectives::plan_reduce_scatter_v::<u64>(algo, c, &counts).unwrap();
            let tag_after_plan = c.next_coll_tag();
            let me = c.rank();
            let mut out = vec![0u64; counts.get(me)];
            for round in 0..100u64 {
                let mine: Vec<u64> = (0..p)
                    .flat_map(|b| {
                        (0..counts.get(b))
                            .map(move |j| (me * 1_000_003 + b * 1_009 + j) as u64 + round)
                    })
                    .collect();
                plan.execute(&mine, &mut out).unwrap();
                let expect: Vec<u64> = (0..counts.get(me))
                    .map(|j| {
                        (0..p)
                            .map(|r| (r * 1_000_003 + me * 1_009 + j) as u64 + round)
                            .sum()
                    })
                    .collect();
                assert_eq!(out, expect, "reduce-scatter-v/{algo} round {round}");
            }
            let tag_after_100 = c.next_coll_tag();
            assert_eq!(
                tag_after_100,
                tag_after_plan + 1,
                "reduce-scatter-v/{algo} leaked collective tags across executions"
            );
            true
        });
        assert!(run.results.iter().all(|&ok| ok), "reduce-scatter-v/{algo}");
    }
}

/// Allocation accounting for reduce-scatter: repeated planned executes
/// allocate strictly less than repeated one-shot calls on the identical
/// workload.
#[test]
fn planned_reduce_scatter_allocates_less_than_one_shot() {
    let _g = serial();
    let topo = Topology::regions(4, 4);
    let p = topo.size();
    let n = 128usize;
    let iters = 100u64;

    let before = ALLOCATED.load(Ordering::Relaxed);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let mut plan =
            collectives::plan_reduce_scatter::<u64>("loc-aware", c, Shape::elems(n)).unwrap();
        let mut out = vec![0u64; n];
        let send = vec![c.rank() as u64; n * p];
        for _ in 0..iters {
            plan.execute(&send, &mut out).unwrap();
        }
        out[0]
    });
    std::hint::black_box(&run.results);
    let planned = ALLOCATED.load(Ordering::Relaxed) - before;

    let before = ALLOCATED.load(Ordering::Relaxed);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let send = vec![c.rank() as u64; n * p];
        let mut last = 0u64;
        for _ in 0..iters {
            last = collectives::reduce_scatter::loc_aware(c, &send).unwrap()[0];
        }
        last
    });
    std::hint::black_box(&run.results);
    let one_shot = ALLOCATED.load(Ordering::Relaxed) - before;
    assert!(
        planned < one_shot,
        "reduce-scatter: planned {planned} B must allocate less than one-shot {one_shot} B"
    );
}

/// The PR-2 operations also construct zero sub-communicators per execute:
/// groups and region communicators exist from plan time.
#[test]
fn new_op_executions_build_no_sub_communicators() {
    let _g = serial();
    let topo = Topology::regions(4, 4);
    let p = topo.size();
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let mut ar = collectives::plan_allreduce::<u64>("loc-aware", c, Shape::elems(2)).unwrap();
        let mut a2a = collectives::plan_alltoall::<u64>("loc-aware", c, Shape::elems(2)).unwrap();
        c.barrier().unwrap(); // every rank finished planning
        let built_before = comm::sub_comms_built();
        let mut sum = vec![0u64; 2];
        let mut exchanged = vec![0u64; 2 * p];
        for round in 0..50u64 {
            let mine = shifted_contribution(c.rank(), 2, round);
            ar.execute(&mine, &mut sum).unwrap();
            let send = vec![c.rank() as u64 + round; 2 * p];
            a2a.execute(&send, &mut exchanged).unwrap();
        }
        c.barrier().unwrap(); // every rank finished executing
        comm::sub_comms_built() - built_before
    });
    for &delta in &run.results {
        assert_eq!(delta, 0, "execute constructed sub-communicators");
    }
}

/// Allocation accounting for the PR-2 operations: repeated planned
/// executes allocate strictly less than repeated one-shot calls on the
/// identical workload.
#[test]
fn planned_allreduce_and_alltoall_allocate_less_than_one_shot() {
    let _g = serial();
    let topo = Topology::regions(4, 4);
    let p = topo.size();
    let n = 128usize;
    let iters = 100u64;

    // --- allreduce ----------------------------------------------------
    let before = ALLOCATED.load(Ordering::Relaxed);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let mut plan = collectives::plan_allreduce::<u64>("loc-aware", c, Shape::elems(n)).unwrap();
        let mut out = vec![0u64; n];
        let mine = shifted_contribution(c.rank(), n, 0);
        for _ in 0..iters {
            plan.execute(&mine, &mut out).unwrap();
        }
        out[0]
    });
    std::hint::black_box(&run.results);
    let planned = ALLOCATED.load(Ordering::Relaxed) - before;
    let before = ALLOCATED.load(Ordering::Relaxed);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let mine = shifted_contribution(c.rank(), n, 0);
        let mut last = 0u64;
        for _ in 0..iters {
            last = collectives::allreduce::allreduce_locality_aware(c, &mine).unwrap()[0];
        }
        last
    });
    std::hint::black_box(&run.results);
    let one_shot = ALLOCATED.load(Ordering::Relaxed) - before;
    assert!(
        planned < one_shot,
        "allreduce: planned {planned} B must allocate less than one-shot {one_shot} B"
    );

    // --- alltoall -----------------------------------------------------
    let before = ALLOCATED.load(Ordering::Relaxed);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let mut plan = collectives::plan_alltoall::<u64>("loc-aware", c, Shape::elems(n)).unwrap();
        let mut out = vec![0u64; n * p];
        let send = vec![c.rank() as u64; n * p];
        for _ in 0..iters {
            plan.execute(&send, &mut out).unwrap();
        }
        out[0]
    });
    std::hint::black_box(&run.results);
    let planned = ALLOCATED.load(Ordering::Relaxed) - before;
    let before = ALLOCATED.load(Ordering::Relaxed);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let send = vec![c.rank() as u64; n * p];
        let mut last = 0u64;
        for _ in 0..iters {
            last = collectives::alltoall::loc_aware(c, &send).unwrap()[0];
        }
        last
    });
    std::hint::black_box(&run.results);
    let one_shot = ALLOCATED.load(Ordering::Relaxed) - before;
    assert!(
        planned < one_shot,
        "alltoall: planned {planned} B must allocate less than one-shot {one_shot} B"
    );
}

/// The uniform `n == 0` contract, via plans: every algorithm yields a
/// no-op plan that executes successfully into an empty output.
#[test]
fn zero_length_plans_are_uniform_no_ops() {
    let _g = serial();
    let topo = Topology::regions(4, 4);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        for algo in Algorithm::ALL {
            let mut plan = collectives::plan_allgather::<f32>(algo, c, Shape::elems(0)).unwrap();
            assert_eq!(plan.shape(), Shape::elems(0), "{algo}");
            let mut out: Vec<f32> = Vec::new();
            plan.execute(&[], &mut out).unwrap();
            assert!(out.is_empty());
        }
        true
    });
    assert!(run.results.iter().all(|&ok| ok));
    let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
    assert_eq!(total, 0, "zero-length plans must send no messages");
}

/// Zero-copy accounting for the serving hot path: fused view executes
/// perform **zero** staging copies, while staged executes tally exactly
/// (input + output) · elem-size bytes per rank per execute on the
/// process-global staging counter — and both paths produce identical
/// bytes on the serving-shaped spec list (allgather ⊕ reduce-scatter ⊕
/// consensus allreduce).
#[test]
fn fused_view_executes_do_zero_staging_copies() {
    let _g = serial();
    use locag::collectives::{staging_bytes_total, FuseSpec, OpKind};
    let topo = Topology::regions(2, 2);
    let p = topo.size();
    let specs = vec![
        FuseSpec::new(OpKind::Allgather, "loc-bruck", 4),
        FuseSpec::new(OpKind::ReduceScatter, "ring", 2),
        FuseSpec::new(OpKind::Allreduce, "loc-aware", 2),
    ];
    let in_elems = 4 + 2 * p + 2;
    let out_elems = 4 * p + 2 + 2;
    let view_iters = 10usize;
    let staged_iters = 3usize;
    let inputs = |rank: usize| -> Vec<Vec<u64>> {
        vec![
            shifted_contribution(rank, 4, 1),
            (0..2 * p).map(|x| (rank * 1_009 + x) as u64).collect(),
            shifted_contribution(rank, 2, 2),
        ]
    };

    // View path: N executes, zero staging bytes.
    let before = staging_bytes_total();
    let view_run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let mut plan = collectives::plan_fused::<u64>(c, &specs).unwrap();
        let ins = inputs(c.rank());
        let mut outs = vec![vec![0u64; 4 * p], vec![0u64; 2], vec![0u64; 2]];
        for _ in 0..view_iters {
            let in_refs: Vec<&[u64]> = ins.iter().map(|v| v.as_slice()).collect();
            let mut out_refs: Vec<&mut [u64]> = outs.iter_mut().map(|v| v.as_mut_slice()).collect();
            plan.execute_view(&in_refs, &mut out_refs).unwrap();
        }
        outs
    });
    assert_eq!(
        staging_bytes_total() - before,
        0,
        "the zero-copy view path must perform no staging copies"
    );

    // Staged path: every execute copies the full composite in and out.
    let before = staging_bytes_total();
    let staged_run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let mut plan = collectives::plan_fused::<u64>(c, &specs).unwrap();
        let ins = inputs(c.rank());
        let mut outs = vec![vec![0u64; 4 * p], vec![0u64; 2], vec![0u64; 2]];
        for _ in 0..staged_iters {
            let in_refs: Vec<&[u64]> = ins.iter().map(|v| v.as_slice()).collect();
            let mut out_refs: Vec<&mut [u64]> = outs.iter_mut().map(|v| v.as_mut_slice()).collect();
            plan.execute(&in_refs, &mut out_refs).unwrap();
        }
        outs
    });
    let staged_bytes = staging_bytes_total() - before;
    let expect = (p * staged_iters * (in_elems + out_elems) * std::mem::size_of::<u64>()) as u64;
    assert_eq!(staged_bytes, expect, "staged path must tally exactly its copied bytes");
    assert_eq!(staged_run.results, view_run.results, "staged and view outputs must agree");
}
