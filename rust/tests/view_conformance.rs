//! Zero-copy view conformance: executing a plan through segmented buffer
//! views ([`IoView`]/[`IoViewMut`]) is **byte-identical** to the staged
//! execute — for every registered (operation, algorithm) pair over the
//! conformance grid, for fused plans with heterogeneous constituents, and
//! for mixed-element-type fused plans (which have no staged path; their
//! oracle is the constituents' sequential staged executes).
//!
//! Inputs and outputs are deliberately split into **two segments at a
//! mid-buffer element boundary** — not at a constituent boundary — so the
//! executor's gather/scatter across segment seams is exercised on every
//! grid point, including the `n = 0` rows (empty segments).
//!
//! Staging-copy *accounting* (the process-global counter) is asserted in
//! `plan_reuse.rs`, which owns the serial-test mutex; this suite only
//! asserts byte-level conformance so its tests can run in parallel.
//!
//! [`IoView`]: locag::collectives::IoView
//! [`IoViewMut`]: locag::collectives::IoViewMut

use std::collections::BTreeSet;

use locag::collectives::{
    self, AllreduceRegistry, AlltoallRegistry, ElemKind, FuseSpec, IoView, IoViewMut, OpKind,
    ReduceScatterRegistry, Registry, Shape,
};
use locag::comm::{Comm, CommWorld, Timing};
use locag::topology::Topology;

/// (regions, ranks-per-region): the same grid as the conformance suites.
const SHAPES: &[(usize, usize)] = &[
    (1, 1),
    (1, 4),
    (2, 2),
    (4, 4),
    (3, 2),
    (5, 2),
    (2, 3),
    (3, 3),
    (8, 4),
];

const NS: &[usize] = &[0, 1, 3];

/// Salted canonical inputs (same family as `fused_conformance`).
fn input_for(op: OpKind, rank: usize, p: usize, n: usize, salt: usize) -> Vec<u64> {
    match op {
        OpKind::Allgather => {
            (0..n).map(|j| (rank * 1_000_003 + j + salt * 7919) as u64).collect()
        }
        OpKind::Allreduce => (0..n).map(|j| (rank * 131_071 + j + salt * 13) as u64).collect(),
        OpKind::Alltoall | OpKind::ReduceScatter => {
            let b = n.max(1);
            (0..p * n)
                .map(|x| (rank * 1_000_003 + (x / b) * 1_009 + x % b + salt * 7919) as u64)
                .collect()
        }
    }
}

fn out_len(op: OpKind, p: usize, n: usize) -> usize {
    match op {
        OpKind::Allgather | OpKind::Alltoall => n * p,
        OpKind::Allreduce | OpKind::ReduceScatter => n,
    }
}

/// Plan one (op, algo) pair once, execute it staged and then through
/// two-segment views, and return both outputs for comparison.
fn run_both(
    c: &Comm,
    op: OpKind,
    name: &str,
    n: usize,
) -> locag::error::Result<(Vec<u64>, Vec<u64>)> {
    let p = c.size();
    let input = input_for(op, c.rank(), p, n, 0);
    let mut staged = vec![0u64; out_len(op, p, n)];
    let mut viewed = vec![0u64; out_len(op, p, n)];
    let isplit = input.len() / 2;
    let osplit = staged.len() / 2;
    macro_rules! both {
        ($plan:expr) => {{
            let mut plan = $plan;
            plan.execute(&input, &mut staged)?;
            let mut iv = IoView::new();
            iv.push::<u64>(&input[..isplit]);
            iv.push::<u64>(&input[isplit..]);
            let (lo, hi) = viewed.split_at_mut(osplit);
            let mut ov = IoViewMut::new();
            ov.push::<u64>(lo);
            ov.push::<u64>(hi);
            plan.execute_view(&iv, &mut ov)?;
        }};
    }
    match op {
        OpKind::Allgather => {
            both!(Registry::<u64>::standard().plan_uniform(name, c, Shape::elems(n))?)
        }
        OpKind::Allreduce => {
            both!(AllreduceRegistry::<u64>::standard().plan_uniform(name, c, Shape::elems(n))?)
        }
        OpKind::Alltoall => {
            both!(AlltoallRegistry::<u64>::standard().plan_uniform(name, c, Shape::elems(n))?)
        }
        OpKind::ReduceScatter => {
            both!(ReduceScatterRegistry::<u64>::standard().plan_uniform(name, c, Shape::elems(n))?)
        }
    }
    Ok((staged, viewed))
}

/// Every registered (op, algorithm) pair executes byte-identically
/// through segmented views, over the full conformance grid, with 100%
/// registry coverage.
#[test]
fn view_matches_staged_for_every_registered_algorithm() {
    let mut covered: BTreeSet<String> = BTreeSet::new();
    let pairs: Vec<(OpKind, &'static str)> = {
        let mut v = Vec::new();
        for name in Registry::<u64>::standard().names() {
            v.push((OpKind::Allgather, name));
        }
        for name in AllreduceRegistry::<u64>::standard().names() {
            v.push((OpKind::Allreduce, name));
        }
        for name in AlltoallRegistry::<u64>::standard().names() {
            v.push((OpKind::Alltoall, name));
        }
        for name in ReduceScatterRegistry::<u64>::standard().names() {
            v.push((OpKind::ReduceScatter, name));
        }
        v
    };
    for &(regions, ppr) in SHAPES {
        let topo = Topology::regions(regions, ppr);
        for &n in NS {
            for &(op, name) in &pairs {
                let run = CommWorld::run(&topo, Timing::Wallclock, |c| -> Option<String> {
                    match run_both(c, op, name, n) {
                        Ok((staged, viewed)) => {
                            assert_eq!(
                                staged,
                                viewed,
                                "view != staged: {op}/{name} {regions}x{ppr} n={n} rank {}",
                                c.rank()
                            );
                            None
                        }
                        Err(e) => Some(e.to_string()),
                    }
                });
                for (rank, r) in run.results.iter().enumerate() {
                    assert_eq!(r, &run.results[0], "rank {rank} diverged: {op}/{name}");
                }
                match &run.results[0] {
                    None => {
                        covered.insert(format!("{op}/{name}"));
                    }
                    Some(msg) => {
                        // Shape rejections are fine (power-of-two
                        // preconditions); anything else is a view-path bug.
                        assert!(
                            msg.contains("power-of-two"),
                            "{op}/{name} {regions}x{ppr} n={n}: {msg}"
                        );
                    }
                }
            }
        }
    }
    let missing: Vec<String> = pairs
        .iter()
        .map(|(op, name)| format!("{op}/{name}"))
        .filter(|k| !covered.contains(k))
        .collect();
    assert!(missing.is_empty(), "pairs never executed through views: {missing:?}");
}

/// A fused plan's `execute_view` matches its staged `execute` on a
/// heterogeneous spec list (serving shape: allgathers ⊕ reduce-scatter ⊕
/// consensus allreduce ⊕ alltoall, plus a zero-length constituent), and
/// stays stable across repeated view executes (scratch reuse).
#[test]
fn fused_view_matches_staged_across_constituent_seams() {
    for &(regions, ppr) in &[(2usize, 2usize), (4, 4), (4, 2), (2, 8)] {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        let specs = vec![
            FuseSpec::new(OpKind::Allgather, "loc-bruck", 3),
            FuseSpec::new(OpKind::ReduceScatter, "ring", 2),
            FuseSpec::new(OpKind::Allreduce, "loc-aware", 2),
            FuseSpec::new(OpKind::Alltoall, "pairwise", 1),
            FuseSpec::new(OpKind::Allgather, "bruck", 0),
        ];
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan = collectives::plan_fused::<u64>(c, &specs).unwrap();
            let ins: Vec<Vec<u64>> = specs
                .iter()
                .enumerate()
                .map(|(i, s)| input_for(s.op, c.rank(), p, s.n, i))
                .collect();
            let mut staged: Vec<Vec<u64>> =
                specs.iter().map(|s| vec![0u64; out_len(s.op, p, s.n)]).collect();
            let mut viewed = staged.clone();
            {
                let in_refs: Vec<&[u64]> = ins.iter().map(|v| v.as_slice()).collect();
                let mut out_refs: Vec<&mut [u64]> =
                    staged.iter_mut().map(|v| v.as_mut_slice()).collect();
                plan.execute(&in_refs, &mut out_refs).unwrap();
            }
            for _ in 0..3 {
                let in_refs: Vec<&[u64]> = ins.iter().map(|v| v.as_slice()).collect();
                let mut out_refs: Vec<&mut [u64]> =
                    viewed.iter_mut().map(|v| v.as_mut_slice()).collect();
                plan.execute_view(&in_refs, &mut out_refs).unwrap();
                assert_eq!(viewed, staged, "rank {} at {regions}x{ppr}", c.rank());
            }
            true
        });
        assert!(run.results.iter().all(|&ok| ok));
    }
}

/// Mixed-element-type fusion (`f32` allgather ⊕ `u64` allreduce ⊕ `f32`
/// reduce-scatter, plus a zero-length `f32` constituent): the view-only
/// executor matches the constituents' sequential staged executes.
/// Float payloads are integer-valued so sums are exact and the
/// comparison is byte-strict.
#[test]
fn mixed_type_fusion_matches_sequential_staged_oracle() {
    for &(regions, ppr) in &[(2usize, 2usize), (4, 4), (2, 8)] {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        let specs = vec![
            (FuseSpec::new(OpKind::Allgather, "loc-bruck", 3), ElemKind::F32),
            (FuseSpec::new(OpKind::Allreduce, "loc-aware", 2), ElemKind::U64),
            (FuseSpec::new(OpKind::ReduceScatter, "ring", 2), ElemKind::F32),
            (FuseSpec::new(OpKind::Allgather, "bruck", 0), ElemKind::F32),
        ];
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = c.rank();
            let ag_in: Vec<f32> = (0..3).map(|j| (r * 100 + j) as f32).collect();
            let ar_in: Vec<u64> = (0..2).map(|j| (r * 1_000_003 + j) as u64).collect();
            let rs_in: Vec<f32> = (0..2 * p).map(|x| ((r * 31 + x) % 97) as f32).collect();
            let empty_in: Vec<f32> = Vec::new();

            // Sequential staged oracle, one registry plan per constituent.
            let mut ag_want = vec![0f32; 3 * p];
            Registry::<f32>::standard()
                .plan_uniform("loc-bruck", c, Shape::elems(3))
                .unwrap()
                .execute(&ag_in, &mut ag_want)
                .unwrap();
            let mut ar_want = vec![0u64; 2];
            AllreduceRegistry::<u64>::standard()
                .plan_uniform("loc-aware", c, Shape::elems(2))
                .unwrap()
                .execute(&ar_in, &mut ar_want)
                .unwrap();
            let mut rs_want = vec![0f32; 2];
            ReduceScatterRegistry::<f32>::standard()
                .plan_uniform("ring", c, Shape::elems(2))
                .unwrap()
                .execute(&rs_in, &mut rs_want)
                .unwrap();

            // Mixed fused execution over typed view segments, spec order.
            let mut plan = collectives::plan_fused_mixed(c, &specs).unwrap();
            let mut ag_out = vec![0f32; 3 * p];
            let mut ar_out = vec![0u64; 2];
            let mut rs_out = vec![0f32; 2];
            let mut empty_out: Vec<f32> = Vec::new();
            for _ in 0..2 {
                let mut iv = IoView::new();
                iv.push::<f32>(&ag_in);
                iv.push::<u64>(&ar_in);
                iv.push::<f32>(&rs_in);
                iv.push::<f32>(&empty_in);
                let mut ov = IoViewMut::new();
                ov.push::<f32>(&mut ag_out);
                ov.push::<u64>(&mut ar_out);
                ov.push::<f32>(&mut rs_out);
                ov.push::<f32>(&mut empty_out);
                plan.execute_view(&iv, &mut ov).unwrap();
                assert_eq!(ag_out, ag_want, "rank {r}: f32 allgather at {regions}x{ppr}");
                assert_eq!(ar_out, ar_want, "rank {r}: u64 allreduce at {regions}x{ppr}");
                assert_eq!(rs_out, rs_want, "rank {r}: f32 reduce-scatter at {regions}x{ppr}");
            }
            true
        });
        assert!(run.results.iter().all(|&ok| ok), "{regions}x{ppr}");
    }
}

/// Mixed fusion on non-power-of-two shapes, using the any-`p` algorithms
/// (ring allgather, Rabenseifner allreduce, pairwise alltoall).
#[test]
fn mixed_type_fusion_handles_non_power_of_two_shapes() {
    for &(regions, ppr) in &[(2usize, 3usize), (3, 3)] {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        let specs = vec![
            (FuseSpec::new(OpKind::Allgather, "ring", 2), ElemKind::F32),
            (FuseSpec::new(OpKind::Allreduce, "rabenseifner", 3), ElemKind::U64),
            (FuseSpec::new(OpKind::Alltoall, "pairwise", 1), ElemKind::U64),
        ];
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = c.rank();
            let ag_in: Vec<f32> = (0..2).map(|j| (r * 50 + j + 1) as f32).collect();
            let ar_in: Vec<u64> = (0..3).map(|j| (r * 8191 + j) as u64).collect();
            let a2a_in: Vec<u64> = (0..p).map(|x| (r * 1_000_003 + x) as u64).collect();

            let mut ag_want = vec![0f32; 2 * p];
            Registry::<f32>::standard()
                .plan_uniform("ring", c, Shape::elems(2))
                .unwrap()
                .execute(&ag_in, &mut ag_want)
                .unwrap();
            let mut ar_want = vec![0u64; 3];
            AllreduceRegistry::<u64>::standard()
                .plan_uniform("rabenseifner", c, Shape::elems(3))
                .unwrap()
                .execute(&ar_in, &mut ar_want)
                .unwrap();
            let mut a2a_want = vec![0u64; p];
            AlltoallRegistry::<u64>::standard()
                .plan_uniform("pairwise", c, Shape::elems(1))
                .unwrap()
                .execute(&a2a_in, &mut a2a_want)
                .unwrap();

            let mut plan = collectives::plan_fused_mixed(c, &specs).unwrap();
            let mut ag_out = vec![0f32; 2 * p];
            let mut ar_out = vec![0u64; 3];
            let mut a2a_out = vec![0u64; p];
            let mut iv = IoView::new();
            iv.push::<f32>(&ag_in);
            iv.push::<u64>(&ar_in);
            iv.push::<u64>(&a2a_in);
            let mut ov = IoViewMut::new();
            ov.push::<f32>(&mut ag_out);
            ov.push::<u64>(&mut ar_out);
            ov.push::<u64>(&mut a2a_out);
            plan.execute_view(&iv, &mut ov).unwrap();
            assert_eq!(ag_out, ag_want, "rank {r}: f32 allgather at {regions}x{ppr}");
            assert_eq!(ar_out, ar_want, "rank {r}: u64 allreduce at {regions}x{ppr}");
            assert_eq!(a2a_out, a2a_want, "rank {r}: u64 alltoall at {regions}x{ppr}");
            true
        });
        assert!(run.results.iter().all(|&ok| ok), "{regions}x{ppr}");
    }
}

/// Segment-count and element-kind mismatches are rejected up front by the
/// mixed executor (no partial execution, no panic).
#[test]
fn mixed_type_fusion_validates_views() {
    let topo = Topology::regions(2, 2);
    let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
        let p = c.size();
        let specs = vec![
            (FuseSpec::new(OpKind::Allgather, "loc-bruck", 2), ElemKind::F32),
            (FuseSpec::new(OpKind::Allreduce, "loc-aware", 1), ElemKind::U64),
        ];
        let mut plan = collectives::plan_fused_mixed(c, &specs).unwrap();
        let ag_in = vec![1f32; 2];
        let ar_in = vec![1u64; 1];
        let mut ag_out = vec![0f32; 2 * p];
        let mut ar_out = vec![0u64; 1];

        // Too few input segments.
        let mut iv = IoView::new();
        iv.push::<f32>(&ag_in);
        let mut ov = IoViewMut::new();
        ov.push::<f32>(&mut ag_out);
        ov.push::<u64>(&mut ar_out);
        assert!(plan.execute_view(&iv, &mut ov).is_err(), "missing input segment accepted");

        // Wrong element kind on the allreduce segment (same byte width,
        // so only the kind check can catch it).
        let wrong = vec![1i64; 1];
        let mut iv = IoView::new();
        iv.push::<f32>(&ag_in);
        iv.push::<i64>(&wrong);
        let mut ov = IoViewMut::new();
        ov.push::<f32>(&mut ag_out);
        ov.push::<u64>(&mut ar_out);
        assert!(plan.execute_view(&iv, &mut ov).is_err(), "wrong element kind accepted");

        // The valid call still succeeds afterwards (no poisoned state)
        // and both ranks of a pair see identical gathers.
        let mut iv = IoView::new();
        iv.push::<f32>(&ag_in);
        iv.push::<u64>(&ar_in);
        let mut ov = IoViewMut::new();
        ov.push::<f32>(&mut ag_out);
        ov.push::<u64>(&mut ar_out);
        plan.execute_view(&iv, &mut ov).unwrap();
        assert_eq!(ag_out, vec![1f32; 2 * p]);
        assert_eq!(ar_out, vec![p as u64]);
        true
    });
    assert!(run.results.iter().all(|&ok| ok));
}
