//! Property tests for the mini-MPI substrate itself: matching, ordering,
//! datatype round-trips, sub-communicator isolation and clock semantics.

use locag::comm::{self, CommWorld, Timing};
use locag::model::MachineParams;
use locag::testkit::{check, Config};
use locag::topology::Topology;

/// Random many-to-many tagged exchanges deliver exactly the sent payloads
/// (no loss, no duplication, no cross-matching).
#[test]
fn prop_random_exchange_delivers_exactly() {
    check(Config::default().cases(16).named("exchange"), |g| {
        let p = g.usize_in(2, 12);
        let rounds = g.usize_in(1, 5);
        let topo = Topology::regions(1, p);
        // Precompute a random communication plan: per round, a permutation.
        let mut plans: Vec<Vec<usize>> = Vec::new();
        for _ in 0..rounds {
            let mut perm: Vec<usize> = (0..p).collect();
            // Fisher-Yates with the testkit generator
            for i in (1..p).rev() {
                let j = g.usize_in(0, i);
                perm.swap(i, j);
            }
            plans.push(perm);
        }
        let plans = &plans;
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let me = c.rank();
            let mut got = Vec::new();
            for (round, perm) in plans.iter().enumerate() {
                // send to perm[me]; receive from the inverse
                let dst = perm[me];
                let src = perm.iter().position(|&x| x == me).unwrap();
                let payload = vec![(me * 1000 + round) as u64];
                c.send(&payload, dst, round as u64).unwrap();
                let r: Vec<u64> = c.recv(src, round as u64).unwrap();
                got.push((src, r[0]));
            }
            got
        });
        for (me, rounds_got) in run.results.iter().enumerate() {
            for (round, &(src, val)) in rounds_got.iter().enumerate() {
                assert_eq!(val, (src * 1000 + round) as u64, "rank {me} round {round}");
            }
        }
    });
}

/// FIFO: messages between one (src, dst, tag) stream arrive in send order.
#[test]
fn prop_fifo_per_stream() {
    check(Config::default().cases(10).named("fifo"), |g| {
        let burst = g.usize_in(1, 50);
        let topo = Topology::regions(1, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            if c.rank() == 0 {
                for i in 0..burst {
                    c.send(&[i as u64], 1, 7).unwrap();
                }
                Vec::new()
            } else {
                (0..burst)
                    .map(|_| c.recv::<u64>(0, 7).unwrap()[0])
                    .collect::<Vec<u64>>()
            }
        });
        assert_eq!(run.results[1], (0..burst as u64).collect::<Vec<_>>());
    });
}

/// Sub-communicators never leak messages across contexts even with
/// identical tags and overlapping memberships.
#[test]
fn prop_subcomm_isolation() {
    check(Config::default().cases(10).named("subcomm-isolation"), |g| {
        let half = g.usize_in(1, 4) * 2;
        let p = half * 2;
        let topo = Topology::regions(2, half);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let local = c.split_regions().unwrap();
            let ls = local.size();
            // same tag 3 on both comms: world ring at distance `half`,
            // local ring at distance 1
            let world_peer = (c.rank() + half) % p;
            c.send(&[c.rank() as u64], world_peer, 3).unwrap();
            local
                .send(&[1000 + c.world_rank() as u64], (local.rank() + 1) % ls, 3)
                .unwrap();
            let w: Vec<u64> = c.recv((c.rank() + p - half) % p, 3).unwrap();
            let local_src = (local.rank() + ls - 1) % ls;
            let l: Vec<u64> = local.recv(local_src, 3).unwrap();
            let expected_local = 1000 + local.world_rank_of(local_src) as u64;
            (w[0], l[0], expected_local)
        });
        for (rank, &(w, l, want_l)) in run.results.iter().enumerate() {
            assert_eq!(w as usize, (rank + p - half) % p, "world leak at {rank}");
            assert_eq!(l, want_l, "local leak at {rank}");
        }
    });
}

/// Clock semantics: a send chain of k hops on an α-only machine advances
/// the final clock by exactly k·α; barrier then equalizes everyone at max.
#[test]
fn prop_clock_chain_and_barrier() {
    check(Config::default().cases(10).named("clock-chain"), |g| {
        let p = g.usize_in(2, 10);
        let alpha = 1.0 + g.usize_in(0, 5) as f64;
        let topo = Topology::regions(1, p);
        let m = MachineParams::uniform(alpha, 0.0);
        let run = CommWorld::run(&topo, Timing::Virtual(m), |c| {
            let r = c.rank();
            if r > 0 {
                c.recv::<u8>(r - 1, 1).unwrap();
            }
            if r < p - 1 {
                c.send(&[0u8], r + 1, 1).unwrap();
            }
            c.barrier().unwrap();
            c.clock()
        });
        let expect = (p - 1) as f64 * alpha;
        for (r, &t) in run.results.iter().enumerate() {
            assert!(
                (t - expect).abs() < 1e-9,
                "rank {r}: clock {t} vs expected {expect}"
            );
        }
    });
}

/// Datatype round-trips: arbitrary u64 payloads survive the byte layer for
/// every Pod width.
#[test]
fn prop_datatype_roundtrip() {
    check(Config::default().cases(20).named("datatypes"), |g| {
        let len = g.usize_in(0, 200);
        let xs: Vec<u64> = (0..len).map(|_| g.u64()).collect();
        let bytes = comm::to_bytes(&xs);
        assert_eq!(comm::from_bytes::<u64>(&bytes).unwrap(), xs);
        // reinterpret as u8 and back preserves content
        let as_u8: Vec<u8> = comm::from_bytes::<u8>(&bytes).unwrap();
        assert_eq!(comm::to_bytes(&as_u8), bytes);
        // f64 bit patterns survive (NaN-safe: compare bits)
        let fs: Vec<f64> = xs.iter().map(|&x| f64::from_bits(x)).collect();
        let back: Vec<f64> = comm::from_bytes(&comm::to_bytes(&fs)).unwrap();
        assert_eq!(
            back.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
            xs
        );
    });
}

/// reset_stats always yields a clean slate regardless of prior traffic.
#[test]
fn prop_reset_stats_clean() {
    check(Config::default().cases(8).named("reset"), |g| {
        let p = g.usize_in(2, 8);
        let msgs = g.usize_in(0, 10);
        let topo = Topology::regions(1, p);
        let m = MachineParams::uniform(1.0, 1e-9);
        let run = CommWorld::run(&topo, Timing::Virtual(m), |c| {
            for i in 0..msgs {
                let dst = (c.rank() + 1) % p;
                let src = (c.rank() + p - 1) % p;
                c.send(&[i as u64], dst, i as u64).unwrap();
                c.recv::<u64>(src, i as u64).unwrap();
            }
            c.reset_stats().unwrap();
            (c.clock(), c.trace_snapshot().total_msgs())
        });
        for &(t, n) in &run.results {
            assert_eq!(t, 0.0);
            assert_eq!(n, 0);
        }
    });
}
