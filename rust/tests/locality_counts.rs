//! Integration: the paper's message/byte accounting claims, asserted from
//! real execution traces (§2–§4).

use locag::collectives::{Algorithm, Counts};
use locag::model::MachineParams;
use locag::sim;
use locag::topology::{Placement, RegionKind, Topology};
use locag::util::{ilog2_ceil, ilog_ceil};

fn run(algo: Algorithm, regions: usize, ppr: usize, n: usize) -> sim::AllgatherReport {
    let topo = Topology::regions(regions, ppr);
    sim::run_allgather(algo, &topo, &MachineParams::lassen(), n)
}

#[test]
fn bruck_sends_log2_p_messages_total() {
    for (regions, ppr) in [(4usize, 4usize), (8, 4), (16, 2), (8, 8)] {
        let p = regions * ppr;
        let rep = run(Algorithm::Bruck, regions, ppr, 2);
        assert!(rep.verified);
        // every rank sends exactly ⌈log2 p⌉ messages, all counted
        assert_eq!(rep.trace.max_total_msgs(), ilog2_ceil(p) as u64);
        for t in &rep.trace.per_rank {
            assert_eq!(t.total_msgs(), ilog2_ceil(p) as u64);
        }
    }
}

#[test]
fn bruck_worst_rank_sends_m_minus_1_values_nonlocal() {
    // Example 2.1: p=16, 1 value per rank: worst rank sends 15 values and
    // no local messages (paper §4).
    let rep = run(Algorithm::Bruck, 4, 4, 1);
    assert_eq!(rep.trace.max_nonlocal_bytes(), 15 * 4);
    let worst = rep
        .trace
        .per_rank
        .iter()
        .max_by_key(|t| t.nonlocal_bytes)
        .unwrap();
    assert_eq!(worst.local_msgs, 0, "paper: the worst rank communicates nothing locally");
}

#[test]
fn loc_bruck_nonlocal_messages_bounded_by_log_ppr_regions() {
    for (regions, ppr) in [
        (4usize, 4usize),
        (16, 4),
        (64, 4),
        (8, 8),
        (64, 8),
        (6, 4),
        (10, 4),
        (3, 8),
    ] {
        let rep = run(Algorithm::LocalityBruck, regions, ppr, 2);
        assert!(rep.verified, "{regions}x{ppr}");
        let bound = ilog_ceil(ppr.max(2), regions) as u64;
        assert!(
            rep.trace.max_nonlocal_msgs() <= bound,
            "{regions}x{ppr}: {} > {bound}",
            rep.trace.max_nonlocal_msgs()
        );
    }
}

#[test]
fn loc_bruck_power_cases_hit_bound_exactly() {
    for (regions, ppr, expect) in [(4usize, 4usize, 1u64), (16, 4, 2), (64, 4, 3), (8, 8, 1)] {
        let rep = run(Algorithm::LocalityBruck, regions, ppr, 2);
        assert_eq!(rep.trace.max_nonlocal_msgs(), expect, "{regions}x{ppr}");
    }
}

#[test]
fn loc_bruck_nonlocal_bytes_are_a_ppr_fraction() {
    // paper §4: non-local bytes ≈ b/pℓ vs bruck's ≈ b. Exact on aligned
    // configs (r a power of pℓ); non-aligned shapes pay ceiling slack for
    // the wrap-around groups, so we assert on r = pℓ².
    let (regions, ppr, n) = (64usize, 8usize, 2usize);
    let std = run(Algorithm::Bruck, regions, ppr, n);
    let loc = run(Algorithm::LocalityBruck, regions, ppr, n);
    let ratio =
        std.trace.max_nonlocal_bytes() as f64 / loc.trace.max_nonlocal_bytes() as f64;
    // expect roughly pℓ (8); allow slack for the wrap/group effects
    assert!(ratio > ppr as f64 * 0.5, "ratio {ratio} too small");
}

#[test]
fn loc_bruck_local_rank_zero_idles_nonlocally() {
    let rep = run(Algorithm::LocalityBruck, 8, 4, 2);
    for (rank, t) in rep.trace.per_rank.iter().enumerate() {
        if rank % 4 == 0 {
            assert_eq!(t.nonlocal_msgs, 0, "rank {rank}");
        }
    }
}

#[test]
fn hierarchical_leaves_workers_idle() {
    // paper §2.2: "the majority of processes per node sit idle" during
    // non-local communication.
    let rep = run(Algorithm::Hierarchical, 8, 8, 2);
    let idle = rep
        .trace
        .per_rank
        .iter()
        .filter(|t| t.nonlocal_msgs == 0)
        .count();
    assert_eq!(idle, 8 * 8 - 8); // all but the 8 masters
}

#[test]
fn multilane_all_ranks_inject() {
    // paper §2.2: multi-lane utilizes all processes per node.
    let rep = run(Algorithm::Multilane, 8, 4, 2);
    for t in &rep.trace.per_rank {
        assert!(t.nonlocal_msgs > 0);
    }
    // but still log2(r) messages per rank — no reduction vs hierarchical
    assert_eq!(rep.trace.max_nonlocal_msgs(), 3);
}

#[test]
fn placement_invariance_of_loc_bruck() {
    let mk = |pl| {
        Topology::machine(8, 1, 8, RegionKind::Node, pl).unwrap()
    };
    let m = MachineParams::quartz();
    let base = sim::run_allgather(Algorithm::LocalityBruck, &mk(Placement::Block), &m, 2);
    for pl in [Placement::RoundRobin, Placement::Random { seed: 1 }, Placement::Random { seed: 2 }] {
        let rep = sim::run_allgather(Algorithm::LocalityBruck, &mk(pl), &m, 2);
        assert!(rep.verified);
        assert_eq!(
            rep.trace.max_nonlocal_msgs(),
            base.trace.max_nonlocal_msgs()
        );
        assert_eq!(
            rep.trace.max_nonlocal_bytes(),
            base.trace.max_nonlocal_bytes()
        );
        assert_eq!(rep.trace.total_nonlocal_bytes(), base.trace.total_nonlocal_bytes());
        // modeled time identical too (same schedule in logical space)
        assert!((rep.vtime - base.vtime).abs() < 1e-12);
    }
}

#[test]
fn standard_bruck_is_placement_sensitive() {
    // The contrast claim: bruck's non-local traffic *does* change when
    // ranks are scattered.
    let m = MachineParams::quartz();
    let block = sim::run_allgather(
        Algorithm::Bruck,
        &Topology::machine(8, 1, 8, RegionKind::Node, Placement::Block).unwrap(),
        &m,
        2,
    );
    let rr = sim::run_allgather(
        Algorithm::Bruck,
        &Topology::machine(8, 1, 8, RegionKind::Node, Placement::RoundRobin).unwrap(),
        &m,
        2,
    );
    assert_ne!(
        block.trace.total_nonlocal_bytes(),
        rr.trace.total_nonlocal_bytes()
    );
}

#[test]
fn loc_allreduce_nonlocal_messages_bounded_by_log_ppr_regions() {
    // Documented bound: ⌈log_pℓ(r)⌉ non-local messages per rank, one per
    // exchange round (local rank 0 idles throughout).
    for (regions, ppr) in [(4usize, 4usize), (8, 4), (16, 4), (8, 8), (16, 2)] {
        let topo = Topology::regions(regions, ppr);
        let rep = sim::run_allreduce("loc-aware", &topo, &MachineParams::lassen(), 2);
        assert!(rep.verified, "{regions}x{ppr}: {:?}", rep.errors);
        let bound = ilog_ceil(ppr.max(2), regions) as u64;
        assert!(
            rep.trace.max_nonlocal_msgs() <= bound,
            "{regions}x{ppr}: {} > {bound}",
            rep.trace.max_nonlocal_msgs()
        );
        // local rank 0 of every region sends nothing non-locally
        for (rank, t) in rep.trace.per_rank.iter().enumerate() {
            if rank % ppr == 0 {
                assert_eq!(t.nonlocal_msgs, 0, "rank {rank} @ {regions}x{ppr}");
            }
        }
    }
}

#[test]
fn loc_allreduce_strictly_beats_recursive_doubling_on_tracer() {
    // With pℓ ≥ 4, ⌈log_pℓ(r)⌉ < the non-local share of log2(p) exchanges.
    for (regions, ppr) in [(4usize, 4usize), (16, 4), (8, 4), (8, 8)] {
        let topo = Topology::regions(regions, ppr);
        let m = MachineParams::lassen();
        let std = sim::run_allreduce("recursive-doubling", &topo, &m, 2);
        let loc = sim::run_allreduce("loc-aware", &topo, &m, 2);
        assert!(std.verified && loc.verified, "{regions}x{ppr}");
        assert!(
            loc.trace.max_nonlocal_msgs() < std.trace.max_nonlocal_msgs(),
            "{regions}x{ppr}: loc {} !< std {}",
            loc.trace.max_nonlocal_msgs(),
            std.trace.max_nonlocal_msgs()
        );
        assert!(
            loc.trace.total_nonlocal_bytes() < std.trace.total_nonlocal_bytes(),
            "{regions}x{ppr}: loc {} !< std {}",
            loc.trace.total_nonlocal_bytes(),
            std.trace.total_nonlocal_bytes()
        );
    }
}

#[test]
fn loc_alltoall_nonlocal_messages_bounded_by_owned_regions() {
    // Documented bound: each rank sends one aggregated non-local message
    // per owned remote region — at most ⌈r/pℓ⌉ — of exactly pℓ²·n
    // elements each.
    for (regions, ppr) in [(4usize, 4usize), (8, 4), (16, 4), (6, 2), (3, 4)] {
        let topo = Topology::regions(regions, ppr);
        let n = 2usize;
        let rep = sim::run_alltoall("loc-aware", &topo, &MachineParams::lassen(), n);
        assert!(rep.verified, "{regions}x{ppr}: {:?}", rep.errors);
        let owned_bound = regions.div_ceil(ppr) as u64;
        assert!(
            rep.trace.max_nonlocal_msgs() <= owned_bound,
            "{regions}x{ppr}: {} > {owned_bound}",
            rep.trace.max_nonlocal_msgs()
        );
        // aggregated transfers: pℓ²·n u64 values per non-local message
        let per_msg_bytes = (ppr * ppr * n * 8) as u64;
        assert!(
            rep.trace.max_nonlocal_bytes() <= owned_bound * per_msg_bytes,
            "{regions}x{ppr}: {} > {}",
            rep.trace.max_nonlocal_bytes(),
            owned_bound * per_msg_bytes
        );
    }
}

#[test]
fn loc_alltoall_strictly_beats_bruck_on_tracer() {
    for (regions, ppr) in [(8usize, 4usize), (16, 4), (8, 8)] {
        let topo = Topology::regions(regions, ppr);
        let m = MachineParams::lassen();
        let std = sim::run_alltoall("bruck", &topo, &m, 2);
        let loc = sim::run_alltoall("loc-aware", &topo, &m, 2);
        assert!(std.verified && loc.verified, "{regions}x{ppr}");
        assert!(
            loc.trace.max_nonlocal_msgs() < std.trace.max_nonlocal_msgs(),
            "{regions}x{ppr}: loc {} !< bruck {}",
            loc.trace.max_nonlocal_msgs(),
            std.trace.max_nonlocal_msgs()
        );
        // no duplicate forwarding: strictly fewer total non-local bytes
        assert!(
            loc.trace.total_nonlocal_bytes() < std.trace.total_nonlocal_bytes(),
            "{regions}x{ppr}: loc {} !< bruck {}",
            loc.trace.total_nonlocal_bytes(),
            std.trace.total_nonlocal_bytes()
        );
    }
}

#[test]
fn loc_reduce_scatter_nonlocal_messages_bounded_by_log_regions() {
    // Documented bound: the lane exchange is the only non-local phase —
    // ⌈log2(r)⌉ aggregated messages per rank for power-of-two region
    // counts (lane recursive halving), r−1 otherwise (lane ring).
    for (regions, ppr) in [(4usize, 4usize), (8, 4), (16, 4), (8, 8), (16, 2), (3, 4), (5, 2)] {
        let topo = Topology::regions(regions, ppr);
        let rep = sim::run_reduce_scatter("loc-aware", &topo, &MachineParams::lassen(), 2);
        assert!(rep.verified, "{regions}x{ppr}: {:?}", rep.errors);
        let bound = if regions.is_power_of_two() {
            ilog2_ceil(regions) as u64
        } else {
            (regions - 1) as u64
        };
        assert!(
            rep.trace.max_nonlocal_msgs() <= bound,
            "{regions}x{ppr}: {} > {bound}",
            rep.trace.max_nonlocal_msgs()
        );
    }
}

#[test]
fn loc_reduce_scatter_strictly_beats_ring_on_4x4() {
    // The paper's aggregated-transfer win, inverted: on the (4x4) world
    // the boundary ranks of the ring forward every partial non-locally
    // (p−1 = 15 messages), while the loc-aware lanes send exactly
    // ⌈log2 4⌉ = 2 aggregated non-local messages — strictly fewer
    // messages AND strictly fewer non-local bytes.
    let topo = Topology::regions(4, 4);
    let m = MachineParams::lassen();
    let ring = sim::run_reduce_scatter("ring", &topo, &m, 2);
    let loc = sim::run_reduce_scatter("loc-aware", &topo, &m, 2);
    assert!(ring.verified && loc.verified);
    assert_eq!(loc.trace.max_nonlocal_msgs(), 2);
    assert_eq!(ring.trace.max_nonlocal_msgs(), 15);
    assert!(
        loc.trace.max_nonlocal_bytes() < ring.trace.max_nonlocal_bytes(),
        "loc {} !< ring {}",
        loc.trace.max_nonlocal_bytes(),
        ring.trace.max_nonlocal_bytes()
    );
    assert!(
        loc.trace.total_nonlocal_bytes() < ring.trace.total_nonlocal_bytes(),
        "loc {} !< ring {} (total)",
        loc.trace.total_nonlocal_bytes(),
        ring.trace.total_nonlocal_bytes()
    );
    // and the modeled completion follows the traffic on the skewed machine
    assert!(loc.vtime < ring.vtime, "loc {} !< ring {}", loc.vtime, ring.vtime);
}

#[test]
fn fused_nonlocal_traffic_bounded_by_sum_of_constituents() {
    // Fusion can only merge messages, never add them: for every rank the
    // traced non-local message count of a fused schedule is at most the
    // sum of its constituents' counts (executed sequentially).
    use locag::collectives::{FuseSpec, OpKind};
    let m = MachineParams::lassen();
    let combos: Vec<(usize, usize, Vec<FuseSpec>)> = vec![
        (
            4,
            4,
            vec![
                FuseSpec::new(OpKind::Allgather, "loc-bruck", 2),
                FuseSpec::new(OpKind::Allreduce, "loc-aware", 2),
            ],
        ),
        (
            2,
            8,
            vec![
                FuseSpec::new(OpKind::Allgather, "loc-bruck", 4),
                FuseSpec::new(OpKind::Allreduce, "loc-aware", 2),
            ],
        ),
        (
            8,
            4,
            vec![
                FuseSpec::new(OpKind::Allgather, "bruck", 2),
                FuseSpec::new(OpKind::Allgather, "bruck", 2),
            ],
        ),
        (
            4,
            4,
            vec![
                FuseSpec::new(OpKind::Allgather, "ring", 2),
                FuseSpec::new(OpKind::Alltoall, "pairwise", 1),
            ],
        ),
    ];
    for (regions, ppr, specs) in combos {
        let topo = Topology::regions(regions, ppr);
        let rep = sim::run_fused(&specs, &topo, &m);
        assert!(rep.verified, "{regions}x{ppr}: {:?}", rep.errors);
        assert_eq!(rep.fused_trace.per_rank.len(), rep.seq_trace.per_rank.len());
        for (rank, (f, s)) in
            rep.fused_trace.per_rank.iter().zip(&rep.seq_trace.per_rank).enumerate()
        {
            assert!(
                f.nonlocal_msgs <= s.nonlocal_msgs,
                "{regions}x{ppr} rank {rank}: fused {} > sequential {}",
                f.nonlocal_msgs,
                s.nonlocal_msgs
            );
            assert!(
                f.total_msgs() <= s.total_msgs(),
                "{regions}x{ppr} rank {rank}: fused {} > sequential {} total",
                f.total_msgs(),
                s.total_msgs()
            );
        }
    }
}

#[test]
fn fused_coalescing_strictly_reduces_nonlocal_messages() {
    // The strict case: loc-bruck allgather ⊕ loc-aware allreduce align
    // their non-local exchange slots with identical peers, so coalescing
    // merges them — strictly fewer non-local messages than sequential.
    use locag::collectives::{FuseSpec, OpKind};
    let m = MachineParams::lassen();
    for (regions, ppr) in [(2usize, 8usize), (4, 4)] {
        let topo = Topology::regions(regions, ppr);
        let specs = vec![
            FuseSpec::new(OpKind::Allgather, "loc-bruck", 2),
            FuseSpec::new(OpKind::Allreduce, "loc-aware", 2),
        ];
        let rep = sim::run_fused(&specs, &topo, &m);
        assert!(rep.verified, "{regions}x{ppr}: {:?}", rep.errors);
        assert!(
            rep.fused_trace.max_nonlocal_msgs() < rep.seq_trace.max_nonlocal_msgs(),
            "{regions}x{ppr}: fused {} !< sequential {}",
            rep.fused_trace.max_nonlocal_msgs(),
            rep.seq_trace.max_nonlocal_msgs()
        );
        assert!(
            rep.fused_trace.total_nonlocal_msgs() < rep.seq_trace.total_nonlocal_msgs(),
            "{regions}x{ppr}: fused {} !< sequential {} (total)",
            rep.fused_trace.total_nonlocal_msgs(),
            rep.seq_trace.total_nonlocal_msgs()
        );
        // and the merged messages carry the combined payloads, so bytes
        // never grow either
        assert!(rep.fused_trace.total_nonlocal_bytes() <= rep.seq_trace.total_nonlocal_bytes());
    }
}

#[test]
fn pat_nonlocal_messages_bounded_by_log2_regions() {
    // PAT's aggregated trees run ⌈log₂ p⌉ sendrecv rounds, so no rank
    // ever sends more than ⌈log₂ r⌉ non-local messages on a flat shape
    // (one rank per region, every peer remote) — where a ring sends r−1.
    // The bound is tight there: every round's message crosses regions.
    let m = MachineParams::lassen();
    for regions in [4usize, 5, 6, 8, 16] {
        let bound = ilog2_ceil(regions) as u64;
        let ag = run(Algorithm::Pat, regions, 1, 2);
        assert!(ag.verified, "allgather {regions}x1: {:?}", ag.errors);
        for (rank, t) in ag.trace.per_rank.iter().enumerate() {
            assert!(
                t.nonlocal_msgs <= bound,
                "pat allgather rank {rank} @ {regions}x1: {} > {bound}",
                t.nonlocal_msgs
            );
        }
        assert_eq!(ag.trace.max_nonlocal_msgs(), bound, "allgather @ {regions}x1");
        let topo = Topology::regions(regions, 1);
        let rs = sim::run_reduce_scatter("pat", &topo, &m, 2);
        assert!(rs.verified, "reduce-scatter {regions}x1: {:?}", rs.errors);
        for (rank, t) in rs.trace.per_rank.iter().enumerate() {
            assert!(
                t.nonlocal_msgs <= bound,
                "pat reduce-scatter rank {rank} @ {regions}x1: {} > {bound}",
                t.nonlocal_msgs
            );
        }
        assert_eq!(rs.trace.max_nonlocal_msgs(), bound, "reduce-scatter @ {regions}x1");
    }
}

#[test]
fn loc_rabenseifner_moves_fewer_nonlocal_bytes_than_rabenseifner() {
    // Bienz et al.: an allreduce with BOTH Rabenseifner phases
    // locality-aware beats the single-level ladder. On (4x4) the plain
    // version's two largest halving/doubling exchanges cross regions
    // (n/2 + n/4 each way per rank); the hierarchical version only
    // leaves the region for the per-lane allreduce of one n/ppr chunk.
    let topo = Topology::regions(4, 4);
    let m = MachineParams::lassen();
    let n = 64usize;
    let plain = sim::run_allreduce("rabenseifner", &topo, &m, n);
    let loc = sim::run_allreduce("loc-rabenseifner", &topo, &m, n);
    assert!(plain.verified, "{:?}", plain.errors);
    assert!(loc.verified, "{:?}", loc.errors);
    assert!(
        loc.trace.total_nonlocal_bytes() < plain.trace.total_nonlocal_bytes(),
        "loc {} !< plain {} (total non-local bytes)",
        loc.trace.total_nonlocal_bytes(),
        plain.trace.total_nonlocal_bytes()
    );
    // strict on every rank, not just in aggregate
    for (rank, (l, p)) in loc.trace.per_rank.iter().zip(&plain.trace.per_rank).enumerate() {
        assert!(
            l.nonlocal_bytes < p.nonlocal_bytes,
            "rank {rank}: loc {} !< plain {}",
            l.nonlocal_bytes,
            p.nonlocal_bytes
        );
    }
}

#[test]
fn improvement_grows_with_ppr_in_measured_runs() {
    // paper Figs. 9/10: "performance improvements are increased with the
    // number of processes per region" — aligned configs, fixed regions.
    let mut prev = 0.0;
    for ppr in [4usize, 8, 64] {
        let std = run(Algorithm::Bruck, 64, ppr, 2);
        let loc = run(Algorithm::LocalityBruck, 64, ppr, 2);
        let ratio = std.vtime / loc.vtime;
        assert!(ratio > prev, "ppr={ppr}: {ratio} <= {prev}");
        prev = ratio;
    }
    assert!(prev > 1.0);
}

/// Skewed per-rank counts with zero-count ranks mixed in: `(r·3) mod 7`.
fn skewed_counts(p: usize) -> Counts {
    Counts::new((0..p).map(|r| (r * 3) % 7).collect())
}

#[test]
fn loc_allgatherv_keeps_uniform_nonlocal_bound_under_skew() {
    // Ragged doc claim (collectives::allgatherv): raggedness changes
    // payload lengths, never the exchange structure — loc-aware
    // allgatherv sends at most ⌈log_pℓ(r)⌉ non-local messages per rank
    // on arbitrarily skewed counts, zero-count ranks included.
    let m = MachineParams::lassen();
    for (regions, ppr) in [(4usize, 4usize), (2, 8)] {
        let topo = Topology::regions(regions, ppr);
        let counts = skewed_counts(regions * ppr);
        let rep = sim::run_allgatherv("loc-aware", &topo, &m, &counts);
        assert!(rep.verified, "{regions}x{ppr}: {:?}", rep.errors);
        let bound = ilog_ceil(ppr.max(2), regions) as u64;
        for (rank, t) in rep.trace.per_rank.iter().enumerate() {
            assert!(
                t.nonlocal_msgs <= bound,
                "rank {rank} @ {regions}x{ppr}: {} > {bound}",
                t.nonlocal_msgs
            );
        }
    }
}

#[test]
fn loc_allgatherv_strictly_beats_ring_on_skewed_counts() {
    // The ring pays p−1 non-local messages from region-edge ranks (every
    // step forwards a block across the boundary link) and its worst rank
    // moves nearly the whole gathered payload non-locally; the loc-aware
    // builder's worst rank sends one aggregated region sum per non-local
    // step — strictly fewer messages, strictly fewer worst-rank bytes,
    // and a strictly smaller modeled completion on the skewed machine.
    let m = MachineParams::lassen();
    for (regions, ppr) in [(4usize, 4usize), (2, 8)] {
        let topo = Topology::regions(regions, ppr);
        let counts = skewed_counts(regions * ppr);
        let ring = sim::run_allgatherv("ring", &topo, &m, &counts);
        let loc = sim::run_allgatherv("loc-aware", &topo, &m, &counts);
        assert!(ring.verified && loc.verified, "{regions}x{ppr}");
        assert!(
            loc.trace.max_nonlocal_msgs() < ring.trace.max_nonlocal_msgs(),
            "{regions}x{ppr}: loc {} !< ring {}",
            loc.trace.max_nonlocal_msgs(),
            ring.trace.max_nonlocal_msgs()
        );
        assert!(
            loc.trace.max_nonlocal_bytes() < ring.trace.max_nonlocal_bytes(),
            "{regions}x{ppr}: loc {} !< ring {} (max non-local bytes)",
            loc.trace.max_nonlocal_bytes(),
            ring.trace.max_nonlocal_bytes()
        );
        assert!(
            loc.vtime < ring.vtime,
            "{regions}x{ppr}: loc {} !< ring {}",
            loc.vtime,
            ring.vtime
        );
    }
}

#[test]
fn loc_reduce_scatter_v_nonlocal_messages_bounded_by_regions_minus_1() {
    // Documented bound (collectives::reduce_scatter_v): phase 1 is
    // all-local pre-reduction, so the lane ring's r−1 aggregated
    // non-local messages per rank survive arbitrary skew — where the
    // plain ragged ring pays p−1 from region-edge ranks.
    let m = MachineParams::lassen();
    for (regions, ppr) in [(4usize, 4usize), (2, 8)] {
        let p = regions * ppr;
        let topo = Topology::regions(regions, ppr);
        let counts = skewed_counts(p);
        let loc = sim::run_reduce_scatter_v("loc-aware", &topo, &m, &counts);
        assert!(loc.verified, "{regions}x{ppr}: {:?}", loc.errors);
        let bound = (regions - 1) as u64;
        for (rank, t) in loc.trace.per_rank.iter().enumerate() {
            assert!(
                t.nonlocal_msgs <= bound,
                "rank {rank} @ {regions}x{ppr}: {} > {bound}",
                t.nonlocal_msgs
            );
        }
        let ring = sim::run_reduce_scatter_v("ring", &topo, &m, &counts);
        assert!(ring.verified, "{regions}x{ppr}: {:?}", ring.errors);
        assert_eq!(ring.trace.max_nonlocal_msgs(), (p - 1) as u64, "{regions}x{ppr}");
        assert!(
            loc.trace.max_nonlocal_msgs() < ring.trace.max_nonlocal_msgs(),
            "{regions}x{ppr}: loc {} !< ring {}",
            loc.trace.max_nonlocal_msgs(),
            ring.trace.max_nonlocal_msgs()
        );
    }
}
