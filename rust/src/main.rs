//! `locag` binary: the Layer-3 entry point.
//!
//! See `locag help` (or [`locag::cli::usage`]) for the command set.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match locag::cli::run(argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
