//! Worker-side pool protocol + byte-level schedule interpretation.
//!
//! A pool worker (spawned by [`super::pool::ProcPool`], dispatched on the
//! hidden `__worker` argv) performs the channel handshake exactly once —
//! `HELLO` (up, listener bound) → `GO` (connect the full data mesh) →
//! `READY` — then serves a command loop over its control socket:
//!
//! * `LOAD [sid][spec]` — rebuild this rank's [`Schedule`] from the job
//!   spec (builders are pure SPMD functions, so no IR crosses the wire),
//!   preallocate every buffer an execute needs, reply `LOADED [sid]`. A
//!   rejected load reports `ERR` and leaves the worker serving.
//! * `EXEC [sid][flags][input?]` — run the loaded schedule over the
//!   persistent channels and buffers, reply `OK [sid][nanos][output?]`.
//!   The interpret loop is allocation-free: wire frames stage through one
//!   persistent buffer sized to the schedule's largest message, and local
//!   steps stage through another, so repeat executes cost only the
//!   memcpys the schedule itself demands.
//! * `SHUTDOWN` — ack and exit cleanly.
//!
//! The interpreter keeps the exact semantics of the in-process executor:
//! eager sends, blocking receives with FIFO matching per (source, tag),
//! pad bytes zero-filled on send and stripped on receive, and the same
//! local copy/reduce/rotate step definitions — which is what makes
//! outputs bit-identical across backends.

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use super::chan::{
    accept_deadline, connect_deadline, ctl_recv, ctl_send, ring_capacity, ChanResult, Deadline,
    PeerChan, ShmRing, CTL_ERR, CTL_EXEC, CTL_GO, CTL_HELLO, CTL_LOAD, CTL_LOADED, CTL_OK,
    CTL_READY, CTL_SHUTDOWN,
};
use super::{
    canonical_fused_mixed_input_bytes, canonical_input_bytes, canonical_input_bytes_v, DType,
    DEFAULT_POOL_RING_BYTES,
};
use crate::cli::args::Args;
use crate::collectives::fuse::{self, FuseSpec};
use crate::collectives::schedule::WorldView;
use crate::collectives::{BufId, Counts, ElemKind, OpKind, Schedule, Slice, Step};
use crate::model::params::MachineParams;
use crate::topology::{Locality, Topology};

/// `EXEC` flags bit 0: ship the output back in the `OK` reply.
pub(super) const EXEC_FLAG_OUTPUT: u64 = 1;
/// `EXEC` flags bit 1: an input delta follows the flags word.
pub(super) const EXEC_FLAG_INPUT: u64 = 2;

/// How long an idle worker waits for the next command. Effectively
/// forever — the parent closing the control socket (EOF) is what ends the
/// loop; this bound only keeps a truly orphaned worker from outliving the
/// host's patience.
const IDLE_SECS: u64 = 24 * 3600;

/// A worker-side failure with the context the parent's typed error needs.
struct WErr {
    round: usize,
    peer: usize,
    what: String,
}

impl WErr {
    fn setup(peer: usize, what: impl Into<String>) -> WErr {
        WErr { round: 0, peer, what: what.into() }
    }
}

/// Per-peer receive buffering: frames arrive in channel order; receives
/// match by tag, queueing earlier frames of other tags — FIFO per
/// (source, tag), exactly like the in-process mailboxes.
enum Mailbox {
    Chan { chan: PeerChan, pending: HashMap<u64, VecDeque<Vec<u8>>> },
    /// Self-sends never leave the process.
    Loopback { pending: HashMap<u64, VecDeque<Vec<u8>>> },
}

impl Mailbox {
    fn send_bytes(&mut self, tag: u64, payload: &[u8], dl: &Deadline) -> ChanResult<()> {
        match self {
            Mailbox::Chan { chan, .. } => chan.send_frame(tag, payload, dl),
            Mailbox::Loopback { pending } => {
                // The queue needs ownership; loopback volumes are tiny.
                pending.entry(tag).or_default().push_back(payload.to_vec());
                Ok(())
            }
        }
    }

    /// Receive the frame matching `tag` into `buf[..len]`, queueing frames
    /// of other tags. Same-sized repeats allocate nothing.
    fn recv_into(&mut self, tag: u64, buf: &mut Vec<u8>, dl: &Deadline) -> ChanResult<usize> {
        let pending = match self {
            Mailbox::Chan { pending, .. } => pending,
            Mailbox::Loopback { pending } => pending,
        };
        if let Some(m) = pending.get_mut(&tag).and_then(VecDeque::pop_front) {
            if buf.len() < m.len() {
                buf.resize(m.len(), 0);
            }
            buf[..m.len()].copy_from_slice(&m);
            return Ok(m.len());
        }
        match self {
            Mailbox::Chan { chan, pending } => loop {
                let (t, len) = chan.recv_frame_into(buf, dl)?;
                if t == tag {
                    return Ok(len);
                }
                pending.entry(t).or_default().push_back(buf[..len].to_vec());
            },
            Mailbox::Loopback { .. } => {
                Err("self-receive posted before the matching self-send".to_string())
            }
        }
    }
}

/// The set of peer ranks a schedule actually communicates with.
fn peer_set(sched: &Schedule) -> BTreeSet<usize> {
    let mut peers = BTreeSet::new();
    for step in sched.steps() {
        match step {
            Step::Send { to, .. } => {
                peers.insert(*to);
            }
            Step::Recv { from, .. } => {
                peers.insert(*from);
            }
            Step::SendRecv { to, from, .. } => {
                peers.insert(*to);
                peers.insert(*from);
            }
            _ => {}
        }
    }
    peers
}

/// Largest wire message (bytes, incl. pad) this schedule sends to `q`.
fn max_wire_to(sched: &Schedule, q: usize) -> usize {
    let mut max = 0;
    for step in sched.steps() {
        let (len, pad) = match step {
            Step::Send { to, src, pad, .. } if *to == q => (src.len, *pad),
            Step::SendRecv { to, src, pad, .. } if *to == q => (src.len, *pad),
            _ => continue,
        };
        max = max.max(sched.wire_bytes(len, pad));
    }
    max
}

/// Largest wire message (bytes, incl. pad) this schedule receives from `q`.
fn max_wire_from(sched: &Schedule, q: usize) -> usize {
    let mut max = 0;
    for step in sched.steps() {
        let (len, pad) = match step {
            Step::Recv { from, dst, pad, .. } if *from == q => (dst.len, *pad),
            Step::SendRecv { from, dst, pad, .. } if *from == q => (dst.len, *pad),
            _ => continue,
        };
        max = max.max(sched.wire_bytes(len, pad));
    }
    max
}

/// Largest wire frame (bytes, incl. pad) across every send/receive step.
/// Unlike `Schedule::max_padded_wire`, unpadded messages count too — the
/// worker stages every frame through one persistent buffer.
fn max_wire_any(sched: &Schedule) -> usize {
    let mut max = 0;
    for step in sched.steps() {
        match step {
            Step::Send { src, pad, .. } => max = max.max(sched.wire_bytes(src.len, *pad)),
            Step::Recv { dst, pad, .. } => max = max.max(sched.wire_bytes(dst.len, *pad)),
            Step::SendRecv { src, dst, pad, .. } => {
                max = max.max(sched.wire_bytes(src.len, *pad));
                max = max.max(sched.wire_bytes(dst.len, *pad));
            }
            _ => {}
        }
    }
    max
}

/// Largest local-step source (bytes) — sizes the staging buffer.
fn max_stage(sched: &Schedule) -> usize {
    let mut max = 0;
    for step in sched.steps() {
        let len = match step {
            Step::CopyLocal { src, .. } | Step::Reduce { src, .. } | Step::Rotate { src, .. } => {
                src.len
            }
            _ => continue,
        };
        max = max.max(len * sched.elem_bytes);
    }
    max
}

/// Static per-worker state, parsed from argv once at spawn.
struct WorkerCfg {
    dir: PathBuf,
    rank: usize,
    topo: Topology,
    machine: MachineParams,
    ring_bytes: u64,
    listener: Option<UnixListener>,
}

fn parse_fuse_label(s: &str) -> std::result::Result<FuseSpec, String> {
    let (head, n) = s.rsplit_once('@').ok_or_else(|| format!("bad fuse spec '{s}'"))?;
    let (op, algo) = head.split_once('/').ok_or_else(|| format!("bad fuse spec '{s}'"))?;
    let op = OpKind::parse_or_err(op).map_err(|e| e.to_string())?;
    // Ragged constituents spell their per-rank counts as `@[c0,c1,...]`.
    if let Some(list) = n.strip_prefix('[').and_then(|r| r.strip_suffix(']')) {
        let counts = Counts::parse(list).map_err(|e| e.to_string())?;
        return Ok(FuseSpec::ragged(op, algo, counts));
    }
    let n: usize = n.parse().map_err(|_| format!("bad fuse spec '{s}'"))?;
    Ok(FuseSpec::new(op, algo, n))
}

/// Parse one `dtype:op/algo@n` constituent of a `fusedmix` job spec.
fn parse_mixed_label(s: &str) -> std::result::Result<(FuseSpec, DType), String> {
    let (dt, rest) = s.split_once(':').ok_or_else(|| format!("bad fusedmix spec '{s}'"))?;
    let dt = DType::parse_or_err(dt).map_err(|e| e.to_string())?;
    Ok((parse_fuse_label(rest)?, dt))
}

fn build_worker_cfg(args: &Args) -> std::result::Result<WorkerCfg, String> {
    let dir = PathBuf::from(args.get_str("dir", ""));
    let rank = args.get_usize("rank", 0).map_err(|e| e.to_string())?;
    let regions = args.get_usize("regions", 1).map_err(|e| e.to_string())?;
    let ppr = args.get_usize("ppr", 1).map_err(|e| e.to_string())?;
    let topo = Topology::regions(regions, ppr);
    if rank >= topo.size() {
        return Err(format!("rank {rank} out of range for a {}-rank world", topo.size()));
    }
    let machine = MachineParams::by_name_or_path(&args.get_str("machine", "lassen"))
        .map_err(|e| e.to_string())?;
    let ring_bytes = args
        .get_usize("ring-bytes", DEFAULT_POOL_RING_BYTES as usize)
        .map_err(|e| e.to_string())? as u64;

    // Bind the listener for lower-rank inter-node peers *before* HELLO, so
    // every listener exists by the time GO releases the connectors.
    let needs_listener =
        (0..rank).any(|q| topo.classify(rank, q) == Locality::InterNode);
    let listener = if needs_listener {
        let l = UnixListener::bind(dir.join(format!("sock-{rank}")))
            .map_err(|e| format!("bind data listener: {e}"))?;
        l.set_nonblocking(true).map_err(|e| e.to_string())?;
        Some(l)
    } else {
        None
    };
    Ok(WorkerCfg { dir, rank, topo, machine, ring_bytes, listener })
}

/// Open data channels to every other rank in the world. The mesh is
/// schedule-independent, so it is built once at spawn and every loaded
/// schedule runs over it. Shm rings use the pool's fixed capacity (both
/// endpoints pass the same `--ring-bytes`); for socket pairs the lower
/// rank connects to the higher rank's listener and identifies itself with
/// an 8-byte rank hello.
fn connect_mesh(
    cfg: &WorkerCfg,
    dl: &Deadline,
) -> std::result::Result<BTreeMap<usize, Mailbox>, WErr> {
    let me = cfg.rank;
    let p = cfg.topo.size();
    let mut chans = BTreeMap::new();
    chans.insert(me, Mailbox::Loopback { pending: HashMap::new() });
    let mut expect_accept = 0usize;
    for q in 0..p {
        if q == me {
            continue;
        }
        if cfg.topo.classify(me, q) != Locality::InterNode {
            let tx = ShmRing::open(&cfg.dir.join(format!("shm-{me}-{q}")), cfg.ring_bytes)
                .map_err(|e| WErr::setup(q, e))?;
            let rx = ShmRing::open(&cfg.dir.join(format!("shm-{q}-{me}")), cfg.ring_bytes)
                .map_err(|e| WErr::setup(q, e))?;
            chans.insert(
                q,
                Mailbox::Chan { chan: PeerChan::Shm { tx, rx }, pending: HashMap::new() },
            );
        } else if q > me {
            let s = connect_deadline(&cfg.dir.join(format!("sock-{q}")), dl)
                .map_err(|e| WErr::setup(q, e))?;
            super::chan::sock_write_all(&s, &(me as u64).to_le_bytes(), dl)
                .map_err(|e| WErr::setup(q, e))?;
            chans.insert(q, Mailbox::Chan { chan: PeerChan::Sock(s), pending: HashMap::new() });
        } else {
            expect_accept += 1;
        }
    }
    if expect_accept > 0 {
        let listener = cfg
            .listener
            .as_ref()
            .ok_or_else(|| WErr::setup(me, "internal: accepting peers but no listener bound"))?;
        for _ in 0..expect_accept {
            let s = accept_deadline(listener, dl).map_err(|e| WErr::setup(me, e))?;
            let mut hello = [0u8; 8];
            super::chan::sock_read_exact(&s, &mut hello, dl).map_err(|e| WErr::setup(me, e))?;
            let q = u64::from_le_bytes(hello) as usize;
            if q >= p || chans.contains_key(&q) {
                return Err(WErr::setup(q.min(p - 1), "unexpected data-channel hello"));
            }
            chans.insert(q, Mailbox::Chan { chan: PeerChan::Sock(s), pending: HashMap::new() });
        }
    }
    Ok(chans)
}

// --- byte-level schedule interpreter ---------------------------------------

fn slice_bytes(s: &Slice, eb: usize) -> std::ops::Range<usize> {
    s.off * eb..(s.off + s.len) * eb
}

fn write_slice(
    output: &mut [u8],
    scratch: &mut [Vec<u8>],
    d: &Slice,
    eb: usize,
    bytes: &[u8],
) -> std::result::Result<(), String> {
    let r = slice_bytes(d, eb);
    let dst = match d.buf {
        BufId::Output => &mut output[r],
        BufId::Scratch(i) => &mut scratch[i][r],
        BufId::Input => return Err("schedule writes into the input buffer".into()),
    };
    if dst.len() != bytes.len() {
        return Err(format!("local step size mismatch: {} vs {}", dst.len(), bytes.len()));
    }
    dst.copy_from_slice(bytes);
    Ok(())
}

/// `dst[i] += src[i]` elementwise at `dtype`, matching the in-process
/// `add_assign` reducer (wrapping integer adds, IEEE f32 adds) applied in
/// the same schedule order — which keeps reductions bit-identical.
fn reduce_bytes(dtype: DType, src: &[u8], dst: &mut [u8]) {
    match dtype {
        DType::U64 => {
            for (d, s) in dst.chunks_exact_mut(8).zip(src.chunks_exact(8)) {
                let v = u64::from_ne_bytes(d[..].try_into().unwrap())
                    .wrapping_add(u64::from_ne_bytes(s.try_into().unwrap()));
                d.copy_from_slice(&v.to_ne_bytes());
            }
        }
        DType::U32 => {
            for (d, s) in dst.chunks_exact_mut(4).zip(src.chunks_exact(4)) {
                let v = u32::from_ne_bytes(d[..].try_into().unwrap())
                    .wrapping_add(u32::from_ne_bytes(s.try_into().unwrap()));
                d.copy_from_slice(&v.to_ne_bytes());
            }
        }
        DType::F32 => {
            for (d, s) in dst.chunks_exact_mut(4).zip(src.chunks_exact(4)) {
                let v = f32::from_ne_bytes(d[..].try_into().unwrap())
                    + f32::from_ne_bytes(s.try_into().unwrap());
                d.copy_from_slice(&v.to_ne_bytes());
            }
        }
    }
}

/// Byte-level `rotate_down_into`: block `j` of `src` lands in block
/// `(j + shift) mod w` of `dst`.
fn rotate_bytes(src: &[u8], block_bytes: usize, shift: usize, dst: &mut [u8]) {
    debug_assert_eq!(src.len(), dst.len());
    debug_assert!(block_bytes > 0 && src.len() % block_bytes == 0);
    let w = src.len() / block_bytes;
    for k in 0..w {
        let j = (k + w - shift % w) % w;
        dst[k * block_bytes..(k + 1) * block_bytes]
            .copy_from_slice(&src[j * block_bytes..(j + 1) * block_bytes]);
    }
}

/// Copy the source slice of a local step into the staging buffer and
/// return its byte length. Staging decouples the read from the write, so
/// overlapping src/dst ranges behave like the in-process executor's
/// value-semantics copies — without a per-step allocation.
fn stage_copy(
    input: &[u8],
    output: &[u8],
    scratch: &[Vec<u8>],
    stage: &mut [u8],
    s: &Slice,
    eb: usize,
) -> usize {
    let r = slice_bytes(s, eb);
    let len = r.len();
    let src = match s.buf {
        BufId::Input => &input[r],
        BufId::Output => &output[r],
        BufId::Scratch(i) => &scratch[i][r],
    };
    stage[..len].copy_from_slice(src);
    len
}

#[allow(clippy::too_many_arguments)]
fn send_step(
    chans: &mut BTreeMap<usize, Mailbox>,
    input: &[u8],
    output: &[u8],
    scratch: &[Vec<u8>],
    wire: &mut [u8],
    eb: usize,
    to: usize,
    src: &Slice,
    tag: u64,
    pad: usize,
    round: usize,
    dl: &Deadline,
) -> std::result::Result<(), WErr> {
    let r = slice_bytes(src, eb);
    let total = pad + r.len();
    wire[..pad].fill(0);
    let payload = match src.buf {
        BufId::Input => &input[r],
        BufId::Output => &output[r],
        BufId::Scratch(i) => &scratch[i][r],
    };
    wire[pad..total].copy_from_slice(payload);
    chans
        .get_mut(&to)
        .ok_or_else(|| WErr { round, peer: to, what: "no channel to peer".into() })?
        .send_bytes(tag, &wire[..total], dl)
        .map_err(|what| WErr { round, peer: to, what })
}

#[allow(clippy::too_many_arguments)]
fn recv_step(
    chans: &mut BTreeMap<usize, Mailbox>,
    output: &mut [u8],
    scratch: &mut [Vec<u8>],
    wire: &mut Vec<u8>,
    eb: usize,
    from: usize,
    dst: &Slice,
    tag: u64,
    pad: usize,
    round: usize,
    dl: &Deadline,
) -> std::result::Result<(), WErr> {
    let got = chans
        .get_mut(&from)
        .ok_or_else(|| WErr { round, peer: from, what: "no channel to peer".into() })?
        .recv_into(tag, wire, dl)
        .map_err(|what| WErr { round, peer: from, what })?;
    let want = pad + dst.len * eb;
    if got != want {
        return Err(WErr {
            round,
            peer: from,
            what: format!("wire message of {got} bytes, expected {want}"),
        });
    }
    write_slice(output, scratch, dst, eb, &wire[pad..got])
        .map_err(|what| WErr { round, peer: from, what })
}

/// How `Reduce` steps resolve their arithmetic type.
#[derive(Debug, Clone, PartialEq)]
enum ReduceDtype {
    /// Single-type plans: every buffer holds one dtype.
    Uniform(DType),
    /// Mixed fused plans (byte-scaled schedules): an output target takes
    /// the dtype of the constituent window `(start, end, dtype)` its byte
    /// range lands in; scratch `i` takes `scratch[i]` (`None` marks the
    /// coalescing staging scratches, which are never `Reduce` targets).
    Mixed { out_windows: Vec<(usize, usize, DType)>, scratch: Vec<Option<DType>> },
}

impl ReduceDtype {
    /// Arithmetic dtype for a `Reduce` step writing `dst`.
    fn for_target(&self, dst: &Slice, eb: usize) -> std::result::Result<DType, String> {
        match self {
            ReduceDtype::Uniform(dt) => Ok(*dt),
            ReduceDtype::Mixed { out_windows, scratch } => match dst.buf {
                BufId::Scratch(i) => scratch
                    .get(i)
                    .copied()
                    .flatten()
                    .ok_or_else(|| format!("reduce into untyped scratch buffer {i}")),
                BufId::Output => {
                    let r = slice_bytes(dst, eb);
                    out_windows
                        .iter()
                        .find(|(s, e, _)| *s <= r.start && r.end <= *e)
                        .map(|(_, _, dt)| *dt)
                        .ok_or_else(|| {
                            format!(
                                "reduce target {}..{} spans constituent output windows",
                                r.start, r.end
                            )
                        })
                }
                BufId::Input => Err("schedule reduces into the input buffer".into()),
            },
        }
    }
}

/// One loaded schedule plus every buffer its executes reuse. Built once
/// per `LOAD`; [`PlanState::execute_bytes`] then runs allocation-free.
struct PlanState {
    sched: Option<Schedule>,
    rdtype: ReduceDtype,
    input: Vec<u8>,
    output: Vec<u8>,
    scratch: Vec<Vec<u8>>,
    /// Staging for wire frames (largest send/recv message).
    wire: Vec<u8>,
    /// Staging for local-step sources (largest copy/reduce/rotate).
    stage: Vec<u8>,
}

impl PlanState {
    /// Build a plan from a pool job spec — `single {op} {algo} {n} {eb}`,
    /// `singlev {op} {algo} {c0,c1,...} {eb}` (ragged per-rank counts),
    /// `fused {dtype} {label;label;...}` or
    /// `fusedmix {dtype:label;dtype:label;...}` — seeding the input buffer
    /// with the canonical payload and admission-checking the schedule's
    /// largest shm frame against the pool's fixed ring capacity.
    fn build(cfg: &WorkerCfg, spec: &str) -> std::result::Result<PlanState, String> {
        let me = cfg.rank;
        let p = cfg.topo.size();
        let view = WorldView::world(&cfg.topo);
        let toks: Vec<&str> = spec.split_whitespace().collect();
        let (sched, input, rdtype) = match toks.as_slice() {
            ["single", op, algo, n, eb] => {
                let op = OpKind::parse_or_err(op).map_err(|e| e.to_string())?;
                let n: usize =
                    n.parse().map_err(|_| format!("bad element count in job spec '{spec}'"))?;
                let eb: usize =
                    eb.parse().map_err(|_| format!("bad element size in job spec '{spec}'"))?;
                let dtype = DType::for_elem_bytes(eb).map_err(|e| e.to_string())?;
                if n == 0 {
                    // Uniform zero-length contract: no traffic, empty output.
                    (None, Vec::new(), ReduceDtype::Uniform(dtype))
                } else {
                    let sched =
                        super::build_rank_schedule(op, algo, &view, me, n, eb, &cfg.machine)
                            .map_err(|e| e.to_string())?;
                    (
                        Some(sched),
                        canonical_input_bytes(op, me, p, n, eb),
                        ReduceDtype::Uniform(dtype),
                    )
                }
            }
            ["singlev", op, algo, counts, eb] => {
                let op = OpKind::parse_or_err(op).map_err(|e| e.to_string())?;
                let counts = Counts::parse(counts).map_err(|e| e.to_string())?;
                if counts.len() != p {
                    return Err(format!(
                        "job spec lists {} counts for a {p}-rank world",
                        counts.len()
                    ));
                }
                let eb: usize =
                    eb.parse().map_err(|_| format!("bad element size in job spec '{spec}'"))?;
                let dtype = DType::for_elem_bytes(eb).map_err(|e| e.to_string())?;
                if counts.total() == 0 {
                    // Ragged zero-length contract: no traffic, empty output.
                    (None, Vec::new(), ReduceDtype::Uniform(dtype))
                } else {
                    let sched = super::build_rank_schedule_v(
                        op,
                        algo,
                        &view,
                        me,
                        counts.as_slice(),
                        eb,
                        &cfg.machine,
                    )
                    .map_err(|e| e.to_string())?;
                    (
                        Some(sched),
                        canonical_input_bytes_v(op, me, counts.as_slice(), eb),
                        ReduceDtype::Uniform(dtype),
                    )
                }
            }
            ["fused", dt, labels] => {
                let dtype = DType::parse_or_err(dt).map_err(|e| e.to_string())?;
                let specs: Vec<FuseSpec> = labels
                    .split(';')
                    .filter(|s| !s.is_empty())
                    .map(parse_fuse_label)
                    .collect::<std::result::Result<_, _>>()?;
                let (mut scheds, _) =
                    fuse::fuse_world(&specs, &view, dtype.bytes(), &cfg.machine)
                        .map_err(|e| e.to_string())?;
                let sched = scheds.swap_remove(me);
                let mut input = Vec::new();
                for s in &specs {
                    input.extend_from_slice(&super::encode_dtype(
                        &super::canonical_fuse_elems(s, me, p),
                        dtype,
                    ));
                }
                (Some(sched), input, ReduceDtype::Uniform(dtype))
            }
            ["fusedmix", labels] => {
                let specs: Vec<(FuseSpec, DType)> = labels
                    .split(';')
                    .filter(|s| !s.is_empty())
                    .map(parse_mixed_label)
                    .collect::<std::result::Result<_, _>>()?;
                let kspecs: Vec<(FuseSpec, ElemKind)> =
                    specs.iter().map(|(s, dt)| (s.clone(), dt.kind())).collect();
                let (mut scheds, _, mut kind_tables) =
                    fuse::fuse_world_mixed(&kspecs, &view, &cfg.machine)
                        .map_err(|e| e.to_string())?;
                let sched = scheds.swap_remove(me);
                let kinds = kind_tables.swap_remove(me);
                let input = canonical_fused_mixed_input_bytes(&specs, me, p);
                // Constituent output windows as composite byte ranges, in
                // spec order (mixed schedules are byte-scaled, so slice
                // offsets are byte offsets). Zero-length windows are
                // dropped: they would sit ambiguously on a boundary.
                let mut out_windows = Vec::new();
                let mut off = 0usize;
                for (s, dt) in &specs {
                    let (_, so) = s.io_elems(me, p);
                    let bytes = so * dt.bytes();
                    if bytes > 0 {
                        out_windows.push((off, off + bytes, *dt));
                    }
                    off += bytes;
                }
                let scratch: Vec<Option<DType>> =
                    kinds.iter().map(|k| DType::from_kind(*k).ok()).collect();
                (Some(sched), input, ReduceDtype::Mixed { out_windows, scratch })
            }
            _ => return Err(format!("bad job spec '{spec}'")),
        };

        let (output, scratch, wire, stage) = match &sched {
            Some(s) => {
                s.validate().map_err(|e| e.to_string())?;
                let eb = s.elem_bytes;
                let (in_elems, out_elems) = s.io_lens();
                if input.len() != in_elems * eb {
                    return Err(
                        "canonical input does not match the schedule's input length".into()
                    );
                }
                // Rings were sized at spawn, before this schedule existed;
                // reject frames the fixed capacity cannot pass.
                let mut max_frame = 0usize;
                for q in peer_set(s) {
                    if q != me && cfg.topo.classify(me, q) != Locality::InterNode {
                        max_frame =
                            max_frame.max(max_wire_to(s, q)).max(max_wire_from(s, q));
                    }
                }
                if max_frame > 0 && ring_capacity(max_frame + 16) > cfg.ring_bytes {
                    return Err(format!(
                        "schedule frame of {max_frame} bytes needs shm rings of {} bytes but \
                         the pool was spawned with ring_bytes = {}; respawn with a larger \
                         ProcConfig::ring_bytes",
                        ring_capacity(max_frame + 16),
                        cfg.ring_bytes
                    ));
                }
                let output = vec![0u8; out_elems * eb];
                let scratch: Vec<Vec<u8>> =
                    s.scratch.iter().map(|&l| vec![0u8; l * eb]).collect();
                let wire = vec![0u8; max_wire_any(s)];
                let stage = vec![0u8; max_stage(s)];
                (output, scratch, wire, stage)
            }
            None => (Vec::new(), Vec::new(), Vec::new(), Vec::new()),
        };
        Ok(PlanState { sched, rdtype, input, output, scratch, wire, stage })
    }

    /// Interpret the schedule over the persistent channels and buffers.
    /// Allocation-free: wire frames and local-step sources stage through
    /// the preallocated buffers.
    fn execute_bytes(
        &mut self,
        me: usize,
        chans: &mut BTreeMap<usize, Mailbox>,
        dl: &Deadline,
    ) -> std::result::Result<(), WErr> {
        let PlanState { sched, rdtype, input, output, scratch, wire, stage } = self;
        let Some(sched) = sched else { return Ok(()) };
        let eb = sched.elem_bytes;
        // Every execute starts from zeroed result buffers, like the
        // in-process executor's fresh allocations (Reduce accumulates).
        output.fill(0);
        for s in scratch.iter_mut() {
            s.fill(0);
        }
        for (ri, round) in sched.rounds.iter().enumerate() {
            let rno = ri + 1;
            for step in &round.steps {
                match step {
                    Step::Send { to, src, tag, pad } => {
                        send_step(
                            chans, input, output, scratch, wire, eb, *to, src, *tag, *pad,
                            rno, dl,
                        )?;
                    }
                    Step::Recv { from, dst, tag, pad } => {
                        recv_step(
                            chans, output, scratch, wire, eb, *from, dst, *tag, *pad, rno, dl,
                        )?;
                    }
                    Step::SendRecv { to, src, from, dst, tag, pad } => {
                        send_step(
                            chans, input, output, scratch, wire, eb, *to, src, *tag, *pad,
                            rno, dl,
                        )?;
                        recv_step(
                            chans, output, scratch, wire, eb, *from, dst, *tag, *pad, rno, dl,
                        )?;
                    }
                    Step::CopyLocal { src, dst } => {
                        let len = stage_copy(input, output, scratch, stage, src, eb);
                        write_slice(output, scratch, dst, eb, &stage[..len])
                            .map_err(|w| WErr { round: rno, peer: me, what: w })?;
                    }
                    Step::Reduce { src, dst } => {
                        let len = stage_copy(input, output, scratch, stage, src, eb);
                        let dt = rdtype
                            .for_target(dst, eb)
                            .map_err(|w| WErr { round: rno, peer: me, what: w })?;
                        let r = slice_bytes(dst, eb);
                        let target = match dst.buf {
                            BufId::Output => &mut output[r],
                            BufId::Scratch(i) => &mut scratch[i][r],
                            BufId::Input => {
                                return Err(WErr {
                                    round: rno,
                                    peer: me,
                                    what: "schedule reduces into the input buffer".into(),
                                })
                            }
                        };
                        if target.len() != len {
                            return Err(WErr {
                                round: rno,
                                peer: me,
                                what: format!(
                                    "local step size mismatch: {} vs {len}",
                                    target.len()
                                ),
                            });
                        }
                        reduce_bytes(dt, &stage[..len], target);
                    }
                    Step::Rotate { src, dst, block, shift } => {
                        let len = stage_copy(input, output, scratch, stage, src, eb);
                        let r = slice_bytes(dst, eb);
                        let target = match dst.buf {
                            BufId::Output => &mut output[r],
                            BufId::Scratch(i) => &mut scratch[i][r],
                            BufId::Input => {
                                return Err(WErr {
                                    round: rno,
                                    peer: me,
                                    what: "schedule rotates into the input buffer".into(),
                                })
                            }
                        };
                        if target.len() != len {
                            return Err(WErr {
                                round: rno,
                                peer: me,
                                what: format!(
                                    "local step size mismatch: {} vs {len}",
                                    target.len()
                                ),
                            });
                        }
                        rotate_bytes(&stage[..len], block * eb, *shift, target);
                    }
                }
            }
        }
        Ok(())
    }
}

// --- worker entry ----------------------------------------------------------

fn send_err(ctl: &UnixStream, rank: usize, we: &WErr, dl: &Deadline) {
    let mut payload = Vec::with_capacity(16 + we.what.len());
    payload.extend_from_slice(&(we.round as u64).to_le_bytes());
    payload.extend_from_slice(&(we.peer as u64).to_le_bytes());
    payload.extend_from_slice(we.what.as_bytes());
    let _ = ctl_send(ctl, CTL_ERR, rank as u64, &payload, dl);
}

fn wait_ctl(ctl: &UnixStream, expect: u8, dl: &Deadline) -> ChanResult<()> {
    let (ty, _, _) = ctl_recv(ctl, dl)?;
    if ty == expect {
        Ok(())
    } else {
        Err(format!("expected control frame {expect}, got {ty}"))
    }
}

/// Serve `LOAD`/`EXEC`/`SHUTDOWN` commands until the parent shuts the pool
/// down or disappears. Returns the process exit code.
fn command_loop(
    ctl: &UnixStream,
    cfg: &WorkerCfg,
    chans: &mut BTreeMap<usize, Mailbox>,
    cmd_deadline: Duration,
) -> i32 {
    let rank = cfg.rank;
    let mut plans: BTreeMap<u64, PlanState> = BTreeMap::new();
    loop {
        let idle = Deadline::after(Duration::from_secs(IDLE_SECS));
        let (ty, _, payload) = match ctl_recv(ctl, &idle) {
            Ok(f) => f,
            // Parent gone (EOF) or the idle bound ran out: exit quietly.
            Err(_) => return 0,
        };
        let dl = Deadline::after(cmd_deadline);
        match ty {
            CTL_LOAD => {
                if payload.len() < 8 {
                    send_err(ctl, rank, &WErr::setup(rank, "malformed LOAD frame"), &dl);
                    continue;
                }
                let sid = u64::from_le_bytes(payload[..8].try_into().unwrap());
                let spec = String::from_utf8_lossy(&payload[8..]);
                // A rejected load keeps the worker serving: nothing has
                // touched the data channels yet.
                match PlanState::build(cfg, &spec) {
                    Ok(st) => {
                        plans.insert(sid, st);
                        if ctl_send(ctl, CTL_LOADED, rank as u64, &sid.to_le_bytes(), &dl)
                            .is_err()
                        {
                            return 2;
                        }
                    }
                    Err(what) => send_err(ctl, rank, &WErr::setup(rank, what), &dl),
                }
            }
            CTL_EXEC => {
                if payload.len() < 16 {
                    send_err(ctl, rank, &WErr::setup(rank, "malformed EXEC frame"), &dl);
                    continue;
                }
                let sid = u64::from_le_bytes(payload[..8].try_into().unwrap());
                let flags = u64::from_le_bytes(payload[8..16].try_into().unwrap());
                let Some(st) = plans.get_mut(&sid) else {
                    send_err(
                        ctl,
                        rank,
                        &WErr::setup(rank, format!("stale schedule id {sid}: not loaded")),
                        &dl,
                    );
                    continue;
                };
                if flags & EXEC_FLAG_INPUT != 0 {
                    let delta = &payload[16..];
                    if delta.len() != st.input.len() {
                        send_err(
                            ctl,
                            rank,
                            &WErr::setup(
                                rank,
                                format!(
                                    "input delta of {} bytes, schedule expects {}",
                                    delta.len(),
                                    st.input.len()
                                ),
                            ),
                            &dl,
                        );
                        continue;
                    }
                    st.input.copy_from_slice(delta);
                }
                let t0 = Instant::now();
                match st.execute_bytes(rank, chans, &dl) {
                    Ok(()) => {
                        let nanos = t0.elapsed().as_nanos() as u64;
                        let want_out = flags & EXEC_FLAG_OUTPUT != 0;
                        let out_len = if want_out { st.output.len() } else { 0 };
                        let mut reply = Vec::with_capacity(16 + out_len);
                        reply.extend_from_slice(&sid.to_le_bytes());
                        reply.extend_from_slice(&nanos.to_le_bytes());
                        if want_out {
                            reply.extend_from_slice(&st.output);
                        }
                        if ctl_send(ctl, CTL_OK, rank as u64, &reply, &dl).is_err() {
                            return 2;
                        }
                    }
                    // A failed execute leaves the data channels in an
                    // unknown state; report and exit rather than serve
                    // more commands over poisoned channels.
                    Err(we) => {
                        send_err(ctl, rank, &we, &dl);
                        return 1;
                    }
                }
            }
            CTL_SHUTDOWN => {
                let _ = ctl_send(ctl, CTL_OK, rank as u64, &[], &dl);
                return 0;
            }
            other => {
                send_err(
                    ctl,
                    rank,
                    &WErr::setup(rank, format!("unexpected control frame {other}")),
                    &dl,
                );
            }
        }
    }
}

/// Worker-process entry point, dispatched on the hidden `__worker` argv by
/// the `locag` CLI and by the `proc_backend` test harness. Returns the
/// process exit code. `args` holds everything after `__worker`.
pub fn worker_main(args: &Args) -> i32 {
    if !args.get_str("pingpong", "").is_empty() {
        return super::fit::pingpong_worker(args);
    }
    let rank = args.get_usize("rank", 0).unwrap_or(0);
    let deadline_ms = args.get_usize("deadline-ms", 30_000).unwrap_or(30_000);
    let cmd_deadline = Duration::from_millis(deadline_ms as u64);
    let dl = Deadline::after(cmd_deadline);
    let dir = PathBuf::from(args.get_str("dir", ""));

    let cfg = build_worker_cfg(args);
    let ctl = match connect_deadline(&dir.join("ctl.sock"), &dl) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("locag worker {rank}: cannot reach parent: {e}");
            return 2;
        }
    };
    if ctl_send(&ctl, CTL_HELLO, rank as u64, &[], &dl).is_err() {
        return 2;
    }
    let cfg = match cfg {
        Ok(c) => c,
        Err(what) => {
            send_err(&ctl, rank, &WErr::setup(rank, what), &dl);
            return 1;
        }
    };
    if wait_ctl(&ctl, CTL_GO, &dl).is_err() {
        return 2;
    }
    let mut chans = match connect_mesh(&cfg, &dl) {
        Ok(c) => c,
        Err(we) => {
            send_err(&ctl, rank, &we, &dl);
            return 1;
        }
    };
    if ctl_send(&ctl, CTL_READY, rank as u64, &[], &dl).is_err() {
        return 2;
    }
    command_loop(&ctl, &cfg, &mut chans, cmd_deadline)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::schedule::build_allgather;
    use crate::collectives::Algorithm;

    fn test_cfg(regions: usize, ppr: usize, rank: usize, ring_bytes: u64) -> WorkerCfg {
        WorkerCfg {
            dir: PathBuf::new(),
            rank,
            topo: Topology::regions(regions, ppr),
            machine: MachineParams::lassen(),
            ring_bytes,
            listener: None,
        }
    }

    #[test]
    fn rotate_bytes_matches_element_rotation() {
        // 4 blocks of 2 u16-sized cells (block_bytes = 4), shift by 1:
        // dst[(j + 1) % 4] = src[j].
        let src: Vec<u8> = (0..16).collect();
        let mut dst = vec![0u8; 16];
        rotate_bytes(&src, 4, 1, &mut dst);
        assert_eq!(&dst[4..8], &src[0..4]);
        assert_eq!(&dst[0..4], &src[12..16]);
    }

    #[test]
    fn reduce_bytes_sums_elementwise() {
        let a = 7u64.to_ne_bytes();
        let mut d = 5u64.to_ne_bytes().to_vec();
        reduce_bytes(DType::U64, &a, &mut d);
        assert_eq!(d, 12u64.to_ne_bytes());
        let f = 1.5f32.to_ne_bytes();
        let mut g = 2.25f32.to_ne_bytes().to_vec();
        reduce_bytes(DType::F32, &f, &mut g);
        assert_eq!(g, 3.75f32.to_ne_bytes());
    }

    #[test]
    fn peer_set_and_message_bounds_cover_the_bruck_schedule() {
        let topo = Topology::regions(2, 2);
        let view = WorldView::world(&topo);
        let sched = build_allgather(Algorithm::Bruck, &view, 0, 3, 8).unwrap();
        let peers = peer_set(&sched);
        assert!(!peers.is_empty());
        for &q in &peers {
            assert!(q < 4);
            // Every peer we send to has a positive message bound.
            assert!(max_wire_to(&sched, q) > 0 || max_wire_from(&sched, q) > 0);
        }
        // The any-step bound dominates the per-peer bounds and, unlike
        // `max_padded_wire`, covers unpadded messages too.
        let all = max_wire_any(&sched);
        for &q in &peers {
            assert!(all >= max_wire_to(&sched, q));
            assert!(all >= max_wire_from(&sched, q));
        }
        assert!(all > 0);
    }

    #[test]
    fn fuse_labels_roundtrip() {
        let spec = FuseSpec::new(OpKind::ReduceScatter, "loc-aware", 7);
        let parsed = parse_fuse_label(&spec.label()).unwrap();
        assert_eq!(parsed.op, OpKind::ReduceScatter);
        assert_eq!(parsed.algo, "loc-aware");
        assert_eq!(parsed.n, 7);
        assert!(parse_fuse_label("nonsense").is_err());
    }

    #[test]
    fn plan_state_builds_from_spec_strings() {
        let cfg = test_cfg(2, 2, 0, DEFAULT_POOL_RING_BYTES);
        let st = PlanState::build(&cfg, "single allgather bruck 3 8").unwrap();
        assert_eq!(st.rdtype, ReduceDtype::Uniform(DType::U64));
        assert_eq!(st.input.len(), 3 * 8);
        assert_eq!(st.output.len(), 3 * 4 * 8);
        assert!(!st.wire.is_empty());

        let st = PlanState::build(&cfg, "fused u64 allgather/bruck@2;allreduce/loc-aware@4")
            .unwrap();
        assert_eq!(st.input.len(), (2 + 4) * 8);
        assert_eq!(st.output.len(), (2 * 4 + 4) * 8);

        // Zero-length jobs have no schedule and empty buffers.
        let st = PlanState::build(&cfg, "single alltoall pairwise 0 8").unwrap();
        assert!(st.sched.is_none());
        assert!(st.input.is_empty() && st.output.is_empty());

        assert!(PlanState::build(&cfg, "single allgather bruck 3").is_err());
        assert!(PlanState::build(&cfg, "fused i8 allgather/bruck@2").is_err());
        assert!(PlanState::build(&cfg, "warble").is_err());
    }

    #[test]
    fn ragged_fuse_labels_roundtrip() {
        let spec =
            FuseSpec::ragged(OpKind::Allgatherv, "bruck", Counts::new(vec![4, 0, 7, 2]));
        let parsed = parse_fuse_label(&spec.label()).unwrap();
        assert_eq!(parsed.op, OpKind::Allgatherv);
        assert_eq!(parsed.algo, "bruck");
        assert_eq!(parsed.counts, Some(Counts::new(vec![4, 0, 7, 2])));
        assert!(parse_fuse_label("allgatherv/bruck@[4,0,x]").is_err());
        assert!(parse_fuse_label("allgatherv/bruck@[4,0,7,2").is_err());
    }

    #[test]
    fn plan_state_builds_ragged_specs() {
        let cfg = test_cfg(2, 2, 0, DEFAULT_POOL_RING_BYTES);
        let st = PlanState::build(&cfg, "singlev allgatherv ring 3,0,2,1 8").unwrap();
        assert_eq!(st.rdtype, ReduceDtype::Uniform(DType::U64));
        assert_eq!(st.input.len(), 3 * 8);
        assert_eq!(st.output.len(), 6 * 8);

        let st =
            PlanState::build(&cfg, "singlev reduce-scatter-v loc-aware 3,0,2,1 8").unwrap();
        assert_eq!(st.input.len(), 6 * 8);
        assert_eq!(st.output.len(), 3 * 8);

        // All-zero counts have no schedule and empty buffers.
        let st = PlanState::build(&cfg, "singlev allgatherv ring 0,0,0,0 8").unwrap();
        assert!(st.sched.is_none());
        assert!(st.input.is_empty() && st.output.is_empty());

        // Rejections: count-list length, bad token, a flat operation.
        assert!(PlanState::build(&cfg, "singlev allgatherv ring 3,0,2 8").is_err());
        assert!(PlanState::build(&cfg, "singlev allgatherv ring 3,x,2,1 8").is_err());
        assert!(PlanState::build(&cfg, "singlev allgather ring 3,0,2,1 8").is_err());
    }

    #[test]
    fn plan_state_builds_mixed_specs() {
        let cfg = test_cfg(2, 2, 0, DEFAULT_POOL_RING_BYTES);
        let st =
            PlanState::build(&cfg, "fusedmix f32:allgather/bruck@2;u64:allreduce/loc-aware@4")
                .unwrap();
        // f32 allgather: 2 elems in, 8 out; u64 allreduce: 4 in, 4 out.
        assert_eq!(st.input.len(), 2 * 4 + 4 * 8);
        assert_eq!(st.output.len(), 2 * 4 * 4 + 4 * 8);
        match &st.rdtype {
            ReduceDtype::Mixed { out_windows, scratch } => {
                assert_eq!(out_windows.as_slice(), &[(0, 32, DType::F32), (32, 64, DType::U64)]);
                // One kind entry per composite scratch buffer.
                assert_eq!(scratch.len(), st.scratch.len());
            }
            other => panic!("expected a mixed reduce dtype, got {other:?}"),
        }
        assert!(PlanState::build(&cfg, "fusedmix i8:allgather/bruck@2").is_err());
        assert!(PlanState::build(&cfg, "fusedmix allgather/bruck@2").is_err());
    }

    #[test]
    fn mixed_reduce_dtype_resolves_windows_and_scratch() {
        let rd = ReduceDtype::Mixed {
            out_windows: vec![(0, 32, DType::F32), (32, 64, DType::U64)],
            scratch: vec![Some(DType::F32), None],
        };
        // Byte-scaled schedules: eb == 1, slice offsets are byte offsets.
        assert_eq!(rd.for_target(&Slice::output(4, 8), 1).unwrap(), DType::F32);
        assert_eq!(rd.for_target(&Slice::output(32, 16), 1).unwrap(), DType::U64);
        assert!(rd.for_target(&Slice::output(28, 8), 1).is_err());
        assert_eq!(rd.for_target(&Slice::at(BufId::Scratch(0), 0, 4), 1).unwrap(), DType::F32);
        assert!(rd.for_target(&Slice::at(BufId::Scratch(1), 0, 4), 1).is_err());
    }

    #[test]
    fn load_rejects_frames_the_fixed_rings_cannot_pass() {
        // A tiny ring cannot admit a schedule with ~MiB frames; the load
        // must fail with advice rather than deadlock at execute time.
        let cfg = test_cfg(1, 4, 0, super::super::chan::MIN_RING_CAP);
        let err = PlanState::build(&cfg, "single allgather bruck 100000 8").unwrap_err();
        assert!(err.contains("ring_bytes"), "{err}");
        // The same schedule is admitted at the default capacity.
        let big = test_cfg(1, 4, 0, DEFAULT_POOL_RING_BYTES);
        assert!(PlanState::build(&big, "single allgather bruck 100000 8").is_ok());
    }
}
