//! Parent orchestration + worker-side schedule interpretation.
//!
//! The parent ([`run_proc`]) spawns one worker process per rank and
//! coordinates them over a Unix control socket with a fixed handshake:
//! `HELLO` (worker up, its listener bound) → `GO` (connect data channels)
//! → `READY` (channels up) → `START` (execute) → `OK`/`ERR`. Every phase
//! is deadline-bounded, and worker death at any point surfaces as a typed
//! [`Error::Transport`] instead of a hang.
//!
//! The worker side rebuilds its rank's [`Schedule`] from argv (builders
//! are pure SPMD functions) and interprets it over [`PeerChan`]s with the
//! exact semantics of the in-process executor: eager sends, blocking
//! receives with FIFO matching per (source, tag), pad bytes zero-filled on
//! send and stripped on receive, and the same local copy/reduce/rotate
//! step definitions — which is what makes outputs bit-identical across
//! backends.

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::io::ErrorKind;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::chan::{
    accept_deadline, connect_deadline, ctl_recv, ctl_send, ring_capacity, ChanResult, Deadline,
    PeerChan, ShmRing, CTL_ERR, CTL_GO, CTL_HELLO, CTL_OK, CTL_READY, CTL_START,
};
use super::{canonical_input_bytes, ProcConfig, ProcJob, ProcReport};
use crate::cli::args::Args;
use crate::collectives::fuse::{self, FuseSpec};
use crate::collectives::schedule::WorldView;
use crate::collectives::{BufId, OpKind, Schedule, Slice, Step};
use crate::error::{Error, Result};
use crate::model::params::MachineParams;
use crate::topology::{Locality, Topology};

// ---------------------------------------------------------------------------
// parent side
// ---------------------------------------------------------------------------

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// A per-run rendezvous directory, preferably on tmpfs so the "shared
/// memory" rings really live in memory.
pub(super) fn scratch_dir() -> PathBuf {
    let base = if Path::new("/dev/shm").is_dir() {
        PathBuf::from("/dev/shm")
    } else {
        std::env::temp_dir()
    };
    base.join(format!(
        "locag-{}-{}",
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Kills and reaps every remaining child on all exit paths.
struct Reaper {
    kids: Vec<Child>,
}

impl Drop for Reaper {
    fn drop(&mut self) {
        for c in &mut self.kids {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

fn transport_err(rank: usize, round: usize, what: impl Into<String>) -> Error {
    Error::Transport { rank, round, what: what.into() }
}

/// Decode a worker's `CTL_ERR` payload: `[round u64][peer u64][message]`.
fn decode_worker_err(sender: usize, payload: &[u8]) -> Error {
    if payload.len() < 16 {
        return transport_err(sender, 0, "worker sent a malformed error report");
    }
    let round = u64::from_le_bytes(payload[..8].try_into().unwrap()) as usize;
    let peer = u64::from_le_bytes(payload[8..16].try_into().unwrap()) as usize;
    let msg = String::from_utf8_lossy(&payload[16..]).into_owned();
    let what =
        if peer == sender { msg } else { format!("{msg} (reported by rank {sender})") };
    transport_err(peer, round, what)
}

/// Send a parent→worker control frame; when the worker is already gone,
/// prefer its queued `CTL_ERR` report (it may have failed setup and
/// exited) over the broken-pipe symptom.
fn send_or_err(s: &UnixStream, ty: u8, rank: usize, dl: &Deadline) -> Result<()> {
    if let Err(e) = ctl_send(s, ty, 0, &[], dl) {
        if let Ok((CTL_ERR, _, payload)) = ctl_recv(s, dl) {
            return Err(decode_worker_err(rank, &payload));
        }
        return Err(transport_err(rank, 0, e));
    }
    Ok(())
}

fn job_args(job: &ProcJob) -> Vec<String> {
    match job {
        ProcJob::Single { op, algo, n, elem_bytes } => vec![
            "--op".into(),
            op.name().to_string(),
            "--algo".into(),
            algo.clone(),
            "--n".into(),
            n.to_string(),
            "--elem-bytes".into(),
            elem_bytes.to_string(),
        ],
        ProcJob::Fused { specs } => {
            let labels: Vec<String> = specs.iter().map(|s| s.label()).collect();
            vec!["--fused".into(), labels.join(";")]
        }
    }
}

/// Execute `job` once over `regions × ppr` worker processes and return the
/// per-rank output bytes plus the max worker execute-phase wall time.
///
/// The current executable must dispatch a leading `__worker` argument to
/// [`worker_main`] (the `locag` CLI does; so does the `proc_backend` test
/// harness). `machine` is a preset name or a fitted-params file path, used
/// for model-tuned and fused planning inside the workers.
pub fn run_proc(
    regions: usize,
    ppr: usize,
    job: &ProcJob,
    machine: &str,
    cfg: &ProcConfig,
) -> Result<ProcReport> {
    let dir = scratch_dir();
    std::fs::create_dir_all(&dir)?;
    let out = run_proc_in(&dir, regions, ppr, job, machine, cfg);
    let _ = std::fs::remove_dir_all(&dir);
    out
}

fn run_proc_in(
    dir: &Path,
    regions: usize,
    ppr: usize,
    job: &ProcJob,
    machine: &str,
    cfg: &ProcConfig,
) -> Result<ProcReport> {
    let p = regions * ppr;
    if p == 0 {
        return Err(Error::Precondition("proc backend needs at least one rank".into()));
    }
    if let Some(k) = cfg.kill_rank {
        if k >= p {
            return Err(Error::RankOutOfRange { rank: k, size: p });
        }
    }
    // The parent outlives the workers' deadline slightly so their typed
    // error reports win races against the parent's own timeout.
    let dl = Deadline::after(cfg.deadline + Duration::from_secs(2));
    let ctl_path = dir.join("ctl.sock");
    let listener = UnixListener::bind(&ctl_path)?;
    listener.set_nonblocking(true)?;

    let exe = std::env::current_exe()?;
    let mut kids = Vec::with_capacity(p);
    for rank in 0..p {
        let mut cmd = Command::new(&exe);
        cmd.arg("__worker")
            .arg("--dir")
            .arg(dir)
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--regions")
            .arg(regions.to_string())
            .arg("--ppr")
            .arg(ppr.to_string())
            .arg("--machine")
            .arg(machine)
            .arg("--deadline-ms")
            .arg(cfg.deadline.as_millis().to_string())
            .args(job_args(job))
            .stdin(Stdio::null())
            .stdout(Stdio::null());
        kids.push(cmd.spawn()?);
    }
    let mut reaper = Reaper { kids };

    // Phase 1: accept one HELLO per rank, watching for early child deaths.
    let mut streams: Vec<Option<UnixStream>> = (0..p).map(|_| None).collect();
    let mut connected = 0usize;
    while connected < p {
        for (rank, child) in reaper.kids.iter_mut().enumerate() {
            if streams[rank].is_none() {
                if let Ok(Some(status)) = child.try_wait() {
                    return Err(transport_err(
                        rank,
                        0,
                        format!("worker process exited during setup ({status})"),
                    ));
                }
            }
        }
        match listener.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false)?;
                let (ty, rank, _) = ctl_recv(&s, &dl)
                    .map_err(|e| transport_err(0, 0, format!("control handshake: {e}")))?;
                let rank = rank as usize;
                if ty != CTL_HELLO || rank >= p || streams[rank].is_some() {
                    return Err(transport_err(rank.min(p - 1), 0, "bad control handshake"));
                }
                streams[rank] = Some(s);
                connected += 1;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if dl.expired() {
                    let missing =
                        (0..p).find(|&r| streams[r].is_none()).unwrap_or(0);
                    return Err(transport_err(
                        missing,
                        0,
                        "deadline exceeded waiting for workers to start",
                    ));
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => return Err(e.into()),
        }
    }
    let streams: Vec<UnixStream> = streams.into_iter().map(Option::unwrap).collect();

    // Phase 2: GO — all listeners are bound, data channels may connect.
    for (rank, s) in streams.iter().enumerate() {
        send_or_err(s, CTL_GO, rank, &dl)?;
    }
    if let Some(k) = cfg.kill_rank {
        let _ = reaper.kids[k].kill();
        let _ = reaper.kids[k].wait();
    }

    // Phase 3: one READY per rank (a worker that failed setup reports ERR
    // here; a dead worker's stream reports EOF).
    for (rank, s) in streams.iter().enumerate() {
        match ctl_recv(s, &dl) {
            Ok((CTL_READY, _, _)) => {}
            Ok((CTL_ERR, _, payload)) => return Err(decode_worker_err(rank, &payload)),
            Ok((ty, ..)) => {
                return Err(transport_err(rank, 0, format!("unexpected control frame {ty}")))
            }
            Err(e) => return Err(transport_err(rank, 0, e)),
        }
    }

    // Phase 4: START, then collect one result per rank.
    for (rank, s) in streams.iter().enumerate() {
        send_or_err(s, CTL_START, rank, &dl)?;
    }
    let mut outputs: Vec<Vec<u8>> = vec![Vec::new(); p];
    let mut wall = 0f64;
    for (rank, s) in streams.iter().enumerate() {
        match ctl_recv(s, &dl) {
            Ok((CTL_OK, _, payload)) if payload.len() >= 8 => {
                let nanos = u64::from_le_bytes(payload[..8].try_into().unwrap());
                wall = wall.max(nanos as f64 / 1e9);
                outputs[rank] = payload[8..].to_vec();
            }
            Ok((CTL_ERR, _, payload)) => return Err(decode_worker_err(rank, &payload)),
            Ok((ty, ..)) => {
                return Err(transport_err(rank, 0, format!("unexpected control frame {ty}")))
            }
            Err(e) => return Err(transport_err(rank, 0, e)),
        }
    }

    // Workers exit right after reporting; reap them gracefully (the Reaper
    // would kill stragglers, but a clean wait avoids racing their exit).
    let reap_dl = Deadline::after(Duration::from_secs(5));
    for child in &mut reaper.kids {
        loop {
            match child.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if reap_dl.expired() => break,
                Ok(None) => std::thread::sleep(Duration::from_millis(1)),
                Err(_) => break,
            }
        }
    }
    Ok(ProcReport { outputs, wall })
}

// ---------------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------------

/// A worker-side failure with the context the parent's typed error needs.
struct WErr {
    round: usize,
    peer: usize,
    what: String,
}

impl WErr {
    fn setup(peer: usize, what: impl Into<String>) -> WErr {
        WErr { round: 0, peer, what: what.into() }
    }
}

/// Per-peer receive buffering: frames arrive in channel order; receives
/// match by tag, queueing earlier frames of other tags — FIFO per
/// (source, tag), exactly like the in-process mailboxes.
enum Mailbox {
    Chan { chan: PeerChan, pending: HashMap<u64, VecDeque<Vec<u8>>> },
    /// Self-sends never leave the process.
    Loopback { pending: HashMap<u64, VecDeque<Vec<u8>>> },
}

impl Mailbox {
    fn send(&mut self, tag: u64, payload: Vec<u8>, dl: &Deadline) -> ChanResult<()> {
        match self {
            Mailbox::Chan { chan, .. } => chan.send_frame(tag, &payload, dl),
            Mailbox::Loopback { pending } => {
                pending.entry(tag).or_default().push_back(payload);
                Ok(())
            }
        }
    }

    fn recv(&mut self, tag: u64, dl: &Deadline) -> ChanResult<Vec<u8>> {
        match self {
            Mailbox::Chan { chan, pending } => {
                if let Some(m) = pending.get_mut(&tag).and_then(VecDeque::pop_front) {
                    return Ok(m);
                }
                loop {
                    let (t, m) = chan.recv_frame(dl)?;
                    if t == tag {
                        return Ok(m);
                    }
                    pending.entry(t).or_default().push_back(m);
                }
            }
            Mailbox::Loopback { pending } => pending
                .get_mut(&tag)
                .and_then(VecDeque::pop_front)
                .ok_or_else(|| "self-receive posted before the matching self-send".to_string()),
        }
    }
}

/// The set of peer ranks a schedule actually communicates with.
fn peer_set(sched: &Schedule) -> BTreeSet<usize> {
    let mut peers = BTreeSet::new();
    for step in sched.steps() {
        match step {
            Step::Send { to, .. } => {
                peers.insert(*to);
            }
            Step::Recv { from, .. } => {
                peers.insert(*from);
            }
            Step::SendRecv { to, from, .. } => {
                peers.insert(*to);
                peers.insert(*from);
            }
            _ => {}
        }
    }
    peers
}

/// Largest wire message (bytes, incl. pad) this schedule sends to `q`.
fn max_wire_to(sched: &Schedule, q: usize) -> usize {
    let mut max = 0;
    for step in sched.steps() {
        let (len, pad) = match step {
            Step::Send { to, src, pad, .. } if *to == q => (src.len, *pad),
            Step::SendRecv { to, src, pad, .. } if *to == q => (src.len, *pad),
            _ => continue,
        };
        max = max.max(sched.wire_bytes(len, pad));
    }
    max
}

/// Largest wire message (bytes, incl. pad) this schedule receives from `q`.
fn max_wire_from(sched: &Schedule, q: usize) -> usize {
    let mut max = 0;
    for step in sched.steps() {
        let (len, pad) = match step {
            Step::Recv { from, dst, pad, .. } if *from == q => (dst.len, *pad),
            Step::SendRecv { from, dst, pad, .. } if *from == q => (dst.len, *pad),
            _ => continue,
        };
        max = max.max(sched.wire_bytes(len, pad));
    }
    max
}

struct WorkerSetup {
    dir: PathBuf,
    rank: usize,
    topo: Topology,
    sched: Option<Schedule>,
    input: Vec<u8>,
    listener: Option<UnixListener>,
}

fn parse_fuse_label(s: &str) -> std::result::Result<FuseSpec, String> {
    let (head, n) = s.rsplit_once('@').ok_or_else(|| format!("bad fuse spec '{s}'"))?;
    let (op, algo) = head.split_once('/').ok_or_else(|| format!("bad fuse spec '{s}'"))?;
    let op = OpKind::parse_or_err(op).map_err(|e| e.to_string())?;
    let n: usize = n.parse().map_err(|_| format!("bad fuse spec '{s}'"))?;
    Ok(FuseSpec::new(op, algo, n))
}

fn build_setup(args: &Args) -> std::result::Result<WorkerSetup, String> {
    let dir = PathBuf::from(args.get_str("dir", ""));
    let rank = args.get_usize("rank", 0).map_err(|e| e.to_string())?;
    let regions = args.get_usize("regions", 1).map_err(|e| e.to_string())?;
    let ppr = args.get_usize("ppr", 1).map_err(|e| e.to_string())?;
    let topo = Topology::regions(regions, ppr);
    let p = topo.size();
    let view = WorldView::world(&topo);
    let machine = MachineParams::by_name_or_path(&args.get_str("machine", "lassen"))
        .map_err(|e| e.to_string())?;

    let fused_arg = args.get_str("fused", "");
    let (sched, input) = if !fused_arg.is_empty() {
        let specs: Vec<FuseSpec> = fused_arg
            .split(';')
            .filter(|s| !s.is_empty())
            .map(parse_fuse_label)
            .collect::<std::result::Result<_, _>>()?;
        let (mut scheds, _) =
            fuse::fuse_world(&specs, &view, 8, &machine).map_err(|e| e.to_string())?;
        let sched = scheds.swap_remove(rank);
        let mut input = Vec::new();
        for s in &specs {
            input.extend_from_slice(&canonical_input_bytes(s.op, rank, p, s.n, 8));
        }
        (Some(sched), input)
    } else {
        let op = OpKind::parse_or_err(&args.get_str("op", "allgather"))
            .map_err(|e| e.to_string())?;
        let algo = args.get_str("algo", "bruck");
        let n = args.get_usize("n", 1).map_err(|e| e.to_string())?;
        let eb = args.get_usize("elem-bytes", 8).map_err(|e| e.to_string())?;
        if n == 0 {
            // Uniform zero-length contract: no traffic, empty output.
            (None, Vec::new())
        } else {
            let sched = super::build_rank_schedule(op, &algo, &view, rank, n, eb, &machine)
                .map_err(|e| e.to_string())?;
            (Some(sched), canonical_input_bytes(op, rank, p, n, eb))
        }
    };

    // Bind the listener for lower-rank inter-node peers *before* HELLO, so
    // every listener exists by the time GO releases the connectors.
    let needs_listener = sched
        .as_ref()
        .map(|s| {
            peer_set(s).iter().any(|&q| {
                q < rank && topo.classify(rank, q) == Locality::InterNode
            })
        })
        .unwrap_or(false);
    let listener = if needs_listener {
        let l = UnixListener::bind(dir.join(format!("sock-{rank}")))
            .map_err(|e| format!("bind data listener: {e}"))?;
        l.set_nonblocking(true).map_err(|e| e.to_string())?;
        Some(l)
    } else {
        None
    };
    Ok(WorkerSetup { dir, rank, topo, sched, input, listener })
}

/// Open every data channel this rank's schedule needs. Lower ranks connect
/// to higher ranks' listeners for socket pairs; shm rings just open their
/// files (both endpoints derive the same capacity from the matching
/// send/recv message bounds).
fn connect_peers(setup: &WorkerSetup, dl: &Deadline) -> std::result::Result<BTreeMap<usize, Mailbox>, WErr> {
    let mut chans = BTreeMap::new();
    let Some(sched) = &setup.sched else { return Ok(chans) };
    let me = setup.rank;
    let peers = peer_set(sched);
    let mut expect_accept = 0usize;
    for &q in &peers {
        if q == me {
            chans.insert(q, Mailbox::Loopback { pending: HashMap::new() });
            continue;
        }
        if setup.topo.classify(me, q) != Locality::InterNode {
            let tx = ShmRing::open(
                &setup.dir.join(format!("shm-{me}-{q}")),
                ring_capacity(max_wire_to(sched, q) + 16),
            )
            .map_err(|e| WErr::setup(q, e))?;
            let rx = ShmRing::open(
                &setup.dir.join(format!("shm-{q}-{me}")),
                ring_capacity(max_wire_from(sched, q) + 16),
            )
            .map_err(|e| WErr::setup(q, e))?;
            chans.insert(q, Mailbox::Chan { chan: PeerChan::Shm { tx, rx }, pending: HashMap::new() });
        } else if q > me {
            let s = connect_deadline(&setup.dir.join(format!("sock-{q}")), dl)
                .map_err(|e| WErr::setup(q, e))?;
            super::chan::sock_write_all(&s, &(me as u64).to_le_bytes(), dl)
                .map_err(|e| WErr::setup(q, e))?;
            chans.insert(q, Mailbox::Chan { chan: PeerChan::Sock(s), pending: HashMap::new() });
        } else {
            expect_accept += 1;
        }
    }
    if expect_accept > 0 {
        let listener = setup.listener.as_ref().ok_or_else(|| {
            WErr::setup(me, "internal: accepting peers but no listener bound")
        })?;
        for _ in 0..expect_accept {
            let s = accept_deadline(listener, dl).map_err(|e| WErr::setup(me, e))?;
            let mut hello = [0u8; 8];
            super::chan::sock_read_exact(&s, &mut hello, dl)
                .map_err(|e| WErr::setup(me, e))?;
            let q = u64::from_le_bytes(hello) as usize;
            if !peers.contains(&q) || chans.contains_key(&q) {
                return Err(WErr::setup(q, "unexpected data-channel hello"));
            }
            chans.insert(q, Mailbox::Chan { chan: PeerChan::Sock(s), pending: HashMap::new() });
        }
    }
    Ok(chans)
}

// --- byte-level schedule interpreter ---------------------------------------

fn slice_bytes(s: &Slice, eb: usize) -> std::ops::Range<usize> {
    s.off * eb..(s.off + s.len) * eb
}

fn read_slice(
    input: &[u8],
    output: &[u8],
    scratch: &[Vec<u8>],
    s: &Slice,
    eb: usize,
) -> Vec<u8> {
    let r = slice_bytes(s, eb);
    match s.buf {
        BufId::Input => input[r].to_vec(),
        BufId::Output => output[r].to_vec(),
        BufId::Scratch(i) => scratch[i][r].to_vec(),
    }
}

fn write_slice(
    output: &mut [u8],
    scratch: &mut [Vec<u8>],
    d: &Slice,
    eb: usize,
    bytes: &[u8],
) -> std::result::Result<(), String> {
    let r = slice_bytes(d, eb);
    let dst = match d.buf {
        BufId::Output => &mut output[r],
        BufId::Scratch(i) => &mut scratch[i][r],
        BufId::Input => return Err("schedule writes into the input buffer".into()),
    };
    if dst.len() != bytes.len() {
        return Err(format!("local step size mismatch: {} vs {}", dst.len(), bytes.len()));
    }
    dst.copy_from_slice(bytes);
    Ok(())
}

/// `dst[i] += src[i]` elementwise, matching the in-process `add_assign`
/// reducer for the integer element types the canonical payloads use.
fn reduce_bytes(eb: usize, src: &[u8], dst: &mut [u8]) -> std::result::Result<(), String> {
    match eb {
        8 => {
            for (d, s) in dst.chunks_exact_mut(8).zip(src.chunks_exact(8)) {
                let v = u64::from_ne_bytes(d[..].try_into().unwrap())
                    .wrapping_add(u64::from_ne_bytes(s.try_into().unwrap()));
                d.copy_from_slice(&v.to_ne_bytes());
            }
            Ok(())
        }
        4 => {
            for (d, s) in dst.chunks_exact_mut(4).zip(src.chunks_exact(4)) {
                let v = u32::from_ne_bytes(d[..].try_into().unwrap())
                    .wrapping_add(u32::from_ne_bytes(s.try_into().unwrap()));
                d.copy_from_slice(&v.to_ne_bytes());
            }
            Ok(())
        }
        other => Err(format!("unsupported element size {other} for Reduce on the proc backend")),
    }
}

/// Byte-level `rotate_down_into`: block `j` of `src` lands in block
/// `(j + shift) mod w` of `dst`.
fn rotate_bytes(src: &[u8], block_bytes: usize, shift: usize, dst: &mut [u8]) {
    debug_assert_eq!(src.len(), dst.len());
    debug_assert!(block_bytes > 0 && src.len() % block_bytes == 0);
    let w = src.len() / block_bytes;
    for k in 0..w {
        let j = (k + w - shift % w) % w;
        dst[k * block_bytes..(k + 1) * block_bytes]
            .copy_from_slice(&src[j * block_bytes..(j + 1) * block_bytes]);
    }
}

fn execute_bytes(
    sched: &Schedule,
    me: usize,
    input: &[u8],
    chans: &mut BTreeMap<usize, Mailbox>,
    dl: &Deadline,
) -> std::result::Result<Vec<u8>, WErr> {
    let eb = sched.elem_bytes;
    let (in_elems, out_elems) = sched.io_lens();
    if input.len() != in_elems * eb {
        return Err(WErr::setup(me, "canonical input does not match the schedule's input length"));
    }
    let mut output = vec![0u8; out_elems * eb];
    let mut scratch: Vec<Vec<u8>> = sched.scratch.iter().map(|&l| vec![0u8; l * eb]).collect();

    let send = |chans: &mut BTreeMap<usize, Mailbox>,
                output: &[u8],
                scratch: &[Vec<u8>],
                to: usize,
                src: &Slice,
                tag: u64,
                pad: usize,
                round: usize|
     -> std::result::Result<(), WErr> {
        let payload = read_slice(input, output, scratch, src, eb);
        let mut wire = vec![0u8; pad + payload.len()];
        wire[pad..].copy_from_slice(&payload);
        chans
            .get_mut(&to)
            .ok_or_else(|| WErr { round, peer: to, what: "no channel to peer".into() })?
            .send(tag, wire, dl)
            .map_err(|what| WErr { round, peer: to, what })
    };
    let recv = |chans: &mut BTreeMap<usize, Mailbox>,
                output: &mut [u8],
                scratch: &mut [Vec<u8>],
                from: usize,
                dst: &Slice,
                tag: u64,
                pad: usize,
                round: usize|
     -> std::result::Result<(), WErr> {
        let wire = chans
            .get_mut(&from)
            .ok_or_else(|| WErr { round, peer: from, what: "no channel to peer".into() })?
            .recv(tag, dl)
            .map_err(|what| WErr { round, peer: from, what })?;
        if wire.len() != pad + dst.len * eb {
            return Err(WErr {
                round,
                peer: from,
                what: format!("wire message of {} bytes, expected {}", wire.len(), pad + dst.len * eb),
            });
        }
        write_slice(output, scratch, dst, eb, &wire[pad..])
            .map_err(|what| WErr { round, peer: from, what })
    };

    for (ri, round) in sched.rounds.iter().enumerate() {
        let rno = ri + 1;
        let werr = |peer: usize, what: String| WErr { round: rno, peer, what };
        for step in &round.steps {
            match step {
                Step::Send { to, src, tag, pad } => {
                    send(chans, &output, &scratch, *to, src, *tag, *pad, rno)?;
                }
                Step::Recv { from, dst, tag, pad } => {
                    recv(chans, &mut output, &mut scratch, *from, dst, *tag, *pad, rno)?;
                }
                Step::SendRecv { to, src, from, dst, tag, pad } => {
                    send(chans, &output, &scratch, *to, src, *tag, *pad, rno)?;
                    recv(chans, &mut output, &mut scratch, *from, dst, *tag, *pad, rno)?;
                }
                Step::CopyLocal { src, dst } => {
                    let bytes = read_slice(input, &output, &scratch, src, eb);
                    write_slice(&mut output, &mut scratch, dst, eb, &bytes)
                        .map_err(|w| werr(me, w))?;
                }
                Step::Reduce { src, dst } => {
                    let bytes = read_slice(input, &output, &scratch, src, eb);
                    let r = slice_bytes(dst, eb);
                    let target = match dst.buf {
                        BufId::Output => &mut output[r],
                        BufId::Scratch(i) => &mut scratch[i][r],
                        BufId::Input => {
                            return Err(werr(me, "schedule reduces into the input buffer".into()))
                        }
                    };
                    reduce_bytes(eb, &bytes, target).map_err(|w| werr(me, w))?;
                }
                Step::Rotate { src, dst, block, shift } => {
                    let bytes = read_slice(input, &output, &scratch, src, eb);
                    let mut rotated = vec![0u8; bytes.len()];
                    rotate_bytes(&bytes, block * eb, *shift, &mut rotated);
                    write_slice(&mut output, &mut scratch, dst, eb, &rotated)
                        .map_err(|w| werr(me, w))?;
                }
            }
        }
    }
    Ok(output)
}

// --- worker entry ----------------------------------------------------------

fn send_err(ctl: &UnixStream, rank: usize, we: &WErr, dl: &Deadline) {
    let mut payload = Vec::with_capacity(16 + we.what.len());
    payload.extend_from_slice(&(we.round as u64).to_le_bytes());
    payload.extend_from_slice(&(we.peer as u64).to_le_bytes());
    payload.extend_from_slice(we.what.as_bytes());
    let _ = ctl_send(ctl, CTL_ERR, rank as u64, &payload, dl);
}

fn wait_ctl(ctl: &UnixStream, expect: u8, dl: &Deadline) -> ChanResult<()> {
    let (ty, _, _) = ctl_recv(ctl, dl)?;
    if ty == expect {
        Ok(())
    } else {
        Err(format!("expected control frame {expect}, got {ty}"))
    }
}

/// Worker-process entry point, dispatched on the hidden `__worker` argv by
/// the `locag` CLI and by the `proc_backend` test harness. Returns the
/// process exit code. `args` holds everything after `__worker`.
pub fn worker_main(args: &Args) -> i32 {
    if !args.get_str("pingpong", "").is_empty() {
        return super::fit::pingpong_worker(args);
    }
    let rank = args.get_usize("rank", 0).unwrap_or(0);
    let deadline_ms = args.get_usize("deadline-ms", 30_000).unwrap_or(30_000);
    let dl = Deadline::after(Duration::from_millis(deadline_ms as u64));
    let dir = PathBuf::from(args.get_str("dir", ""));

    let setup = build_setup(args);
    let ctl = match connect_deadline(&dir.join("ctl.sock"), &dl) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("locag worker {rank}: cannot reach parent: {e}");
            return 2;
        }
    };
    if ctl_send(&ctl, CTL_HELLO, rank as u64, &[], &dl).is_err() {
        return 2;
    }
    let setup = match setup {
        Ok(s) => s,
        Err(what) => {
            send_err(&ctl, rank, &WErr::setup(rank, what), &dl);
            return 1;
        }
    };
    if wait_ctl(&ctl, CTL_GO, &dl).is_err() {
        return 2;
    }
    let mut chans = match connect_peers(&setup, &dl) {
        Ok(c) => c,
        Err(we) => {
            send_err(&ctl, rank, &we, &dl);
            return 1;
        }
    };
    if ctl_send(&ctl, CTL_READY, rank as u64, &[], &dl).is_err() {
        return 2;
    }
    if wait_ctl(&ctl, CTL_START, &dl).is_err() {
        return 2;
    }
    let t0 = Instant::now();
    let result = match &setup.sched {
        Some(sched) => execute_bytes(sched, rank, &setup.input, &mut chans, &dl),
        None => Ok(Vec::new()),
    };
    match result {
        Ok(out) => {
            let wall_nanos = t0.elapsed().as_nanos() as u64;
            let mut payload = Vec::with_capacity(8 + out.len());
            payload.extend_from_slice(&wall_nanos.to_le_bytes());
            payload.extend_from_slice(&out);
            if ctl_send(&ctl, CTL_OK, rank as u64, &payload, &dl).is_err() {
                return 2;
            }
            0
        }
        Err(we) => {
            send_err(&ctl, rank, &we, &dl);
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::schedule::build_allgather;
    use crate::collectives::Algorithm;

    #[test]
    fn rotate_bytes_matches_element_rotation() {
        // 4 blocks of 2 u16-sized cells (block_bytes = 4), shift by 1:
        // dst[(j + 1) % 4] = src[j].
        let src: Vec<u8> = (0..16).collect();
        let mut dst = vec![0u8; 16];
        rotate_bytes(&src, 4, 1, &mut dst);
        assert_eq!(&dst[4..8], &src[0..4]);
        assert_eq!(&dst[0..4], &src[12..16]);
    }

    #[test]
    fn reduce_bytes_sums_elementwise() {
        let a = 7u64.to_ne_bytes();
        let mut d = 5u64.to_ne_bytes().to_vec();
        reduce_bytes(8, &a, &mut d).unwrap();
        assert_eq!(d, 12u64.to_ne_bytes());
        assert!(reduce_bytes(2, &[0, 0], &mut [0, 0]).is_err());
    }

    #[test]
    fn peer_set_and_message_bounds_cover_the_bruck_schedule() {
        let topo = Topology::regions(2, 2);
        let view = WorldView::world(&topo);
        let sched = build_allgather(Algorithm::Bruck, &view, 0, 3, 8).unwrap();
        let peers = peer_set(&sched);
        assert!(!peers.is_empty());
        for &q in &peers {
            assert!(q < 4);
            // Every peer we send to has a positive message bound.
            assert!(max_wire_to(&sched, q) > 0 || max_wire_from(&sched, q) > 0);
        }
    }

    #[test]
    fn fuse_labels_roundtrip() {
        let spec = FuseSpec::new(OpKind::ReduceScatter, "loc-aware", 7);
        let parsed = parse_fuse_label(&spec.label()).unwrap();
        assert_eq!(parsed.op, OpKind::ReduceScatter);
        assert_eq!(parsed.algo, "loc-aware");
        assert_eq!(parsed.n, 7);
        assert!(parse_fuse_label("nonsense").is_err());
    }

    #[test]
    fn worker_err_decodes_with_peer_attribution() {
        let mut payload = Vec::new();
        payload.extend_from_slice(&3u64.to_le_bytes());
        payload.extend_from_slice(&2u64.to_le_bytes());
        payload.extend_from_slice(b"deadline exceeded while receiving");
        let e = decode_worker_err(1, &payload);
        match e {
            Error::Transport { rank, round, what } => {
                assert_eq!((rank, round), (2, 3));
                assert!(what.contains("reported by rank 1"), "{what}");
            }
            other => panic!("wrong error: {other}"),
        }
    }
}
