//! Parent-side persistent worker pool: spawn and handshake once, then
//! load schedules and serve executes over the same channels.
//!
//! [`ProcPool`] is the plan-once/execute-many face of the process
//! backend. [`ProcPool::spawn`] forks one worker per rank and completes
//! the channel handshake; [`ProcPool::load`] ships a job description a
//! single time; [`ProcPool::execute`] (and friends) then runs the loaded
//! schedule repeatedly with only input deltas and outputs crossing the
//! control path. [`run_proc`] wraps one full cycle for single-shot
//! callers.
//!
//! # Failure contract
//!
//! * Failures *between* executes — a rejected load, an unknown schedule
//!   id — leave the pool fully usable.
//! * Failures *during* an execute — worker death, deadline expiry, a
//!   protocol violation — leave the data channels in an unknown state:
//!   the pool marks itself poisoned, every later call fails fast with a
//!   typed [`Error::Transport`], and a fresh [`ProcPool::spawn`] is the
//!   recovery path. Dropping the poisoned pool reaps its workers and
//!   removes its rendezvous directory, so nothing is left to wedge the
//!   replacement.

use std::collections::BTreeMap;
use std::io::ErrorKind;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};
use std::time::Duration;

use super::chan::{
    ctl_recv, ctl_send, Deadline, CTL_ERR, CTL_EXEC, CTL_GO, CTL_HELLO, CTL_LOAD, CTL_LOADED,
    CTL_OK, CTL_READY, CTL_SHUTDOWN,
};
use super::proc_exec::{EXEC_FLAG_INPUT, EXEC_FLAG_OUTPUT};
use super::{ProcConfig, ProcJob, ProcReport};
use crate::error::{Error, Result};

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// A per-pool rendezvous directory, preferably on tmpfs so the "shared
/// memory" rings really live in memory.
pub(super) fn scratch_dir() -> PathBuf {
    let base = if Path::new("/dev/shm").is_dir() {
        PathBuf::from("/dev/shm")
    } else {
        std::env::temp_dir()
    };
    base.join(format!(
        "locag-{}-{}",
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Kills and reaps every remaining child on all exit paths.
pub(super) struct Reaper {
    pub(super) kids: Vec<Child>,
}

impl Drop for Reaper {
    fn drop(&mut self) {
        for c in &mut self.kids {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

pub(super) fn transport_err(rank: usize, round: usize, what: impl Into<String>) -> Error {
    Error::Transport { rank, round, what: what.into() }
}

/// Decode a worker's `CTL_ERR` payload: `[round u64][peer u64][message]`.
fn decode_worker_err(sender: usize, payload: &[u8]) -> Error {
    if payload.len() < 16 {
        return transport_err(sender, 0, "worker sent a malformed error report");
    }
    let round = u64::from_le_bytes(payload[..8].try_into().unwrap()) as usize;
    let peer = u64::from_le_bytes(payload[8..16].try_into().unwrap()) as usize;
    let msg = String::from_utf8_lossy(&payload[16..]).into_owned();
    let what = if peer == sender { msg } else { format!("{msg} (reported by rank {sender})") };
    transport_err(peer, round, what)
}

/// Send a parent→worker control frame; when the worker is already gone,
/// prefer its queued `CTL_ERR` report (it may have failed setup and
/// exited) over the broken-pipe symptom.
fn send_or_err(s: &UnixStream, ty: u8, rank: usize, dl: &Deadline) -> Result<()> {
    if let Err(e) = ctl_send(s, ty, 0, &[], dl) {
        if let Ok((CTL_ERR, _, payload)) = ctl_recv(s, dl) {
            return Err(decode_worker_err(rank, &payload));
        }
        return Err(transport_err(rank, 0, e));
    }
    Ok(())
}

/// Wire spelling of a job, parsed back by the worker's `LOAD` handler.
fn job_spec(job: &ProcJob) -> String {
    match job {
        ProcJob::Single { op, algo, n, elem_bytes } => {
            format!("single {} {} {} {}", op.name(), algo, n, elem_bytes)
        }
        ProcJob::SingleV { op, algo, counts, elem_bytes } => {
            let counts: Vec<String> = counts.iter().map(usize::to_string).collect();
            format!("singlev {} {} {} {}", op.name(), algo, counts.join(","), elem_bytes)
        }
        ProcJob::Fused { specs, dtype } => {
            let labels: Vec<String> = specs.iter().map(|s| s.label()).collect();
            format!("fused {} {}", dtype.name(), labels.join(";"))
        }
        ProcJob::FusedMixed { specs } => {
            let labels: Vec<String> =
                specs.iter().map(|(s, dt)| format!("{}:{}", dt.name(), s.label())).collect();
            format!("fusedmix {}", labels.join(";"))
        }
    }
}

/// Lifecycle counters proving the plan-once/execute-many contract: tests
/// assert `workers_spawned` and `handshakes` stay at the world size while
/// `executes` grows.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Worker processes forked over this pool's lifetime.
    pub workers_spawned: usize,
    /// Control handshakes completed (one per worker, at spawn).
    pub handshakes: usize,
    /// Schedules shipped via [`ProcPool::load`].
    pub loads: usize,
    /// Executes served.
    pub executes: usize,
}

/// A persistent pool of worker processes serving repeated schedule
/// executes — see the module docs for lifecycle and failure contract.
pub struct ProcPool {
    dir: PathBuf,
    reaper: Reaper,
    streams: Vec<UnixStream>,
    p: usize,
    deadline: Duration,
    next_sid: u64,
    /// Per-schedule, per-rank input byte sizes for delta validation
    /// (ragged jobs size each rank by its own count).
    loaded: BTreeMap<u64, Vec<usize>>,
    /// Schedule id of a begun-but-not-finished execute, if any.
    in_flight: Option<u64>,
    poisoned: Option<String>,
    stats: PoolStats,
}

impl ProcPool {
    /// Spawn `regions × ppr` workers and complete the channel handshake.
    /// When this returns, every shm ring and socket of the rank mesh is
    /// connected and the pool is ready to [`ProcPool::load`] schedules.
    ///
    /// The current executable must dispatch a leading `__worker` argument
    /// to [`super::worker_main`] (the `locag` CLI does; so does the
    /// `proc_backend` test harness). `machine` is a preset name or a
    /// fitted-params file path, used for model-tuned and fused planning
    /// inside the workers.
    pub fn spawn(regions: usize, ppr: usize, machine: &str, cfg: &ProcConfig) -> Result<ProcPool> {
        let p = regions * ppr;
        if p == 0 {
            return Err(Error::Precondition("proc backend needs at least one rank".into()));
        }
        if let Some(k) = cfg.kill_rank {
            if k >= p {
                return Err(Error::RankOutOfRange { rank: k, size: p });
            }
        }
        let dir = scratch_dir();
        std::fs::create_dir_all(&dir)?;
        match Self::spawn_in(&dir, regions, ppr, machine, cfg) {
            Ok((reaper, streams)) => Ok(ProcPool {
                dir,
                reaper,
                streams,
                p,
                deadline: cfg.deadline,
                next_sid: 1,
                loaded: BTreeMap::new(),
                in_flight: None,
                poisoned: None,
                stats: PoolStats { workers_spawned: p, handshakes: p, loads: 0, executes: 0 },
            }),
            Err(e) => {
                let _ = std::fs::remove_dir_all(&dir);
                Err(e)
            }
        }
    }

    fn spawn_in(
        dir: &Path,
        regions: usize,
        ppr: usize,
        machine: &str,
        cfg: &ProcConfig,
    ) -> Result<(Reaper, Vec<UnixStream>)> {
        let p = regions * ppr;
        // The parent outlives the workers' deadline slightly so their
        // typed error reports win races against the parent's own timeout.
        let dl = Deadline::after(cfg.deadline + Duration::from_secs(2));
        let ctl_path = dir.join("ctl.sock");
        let listener = UnixListener::bind(&ctl_path)?;
        listener.set_nonblocking(true)?;

        let exe = std::env::current_exe()?;
        let mut kids = Vec::with_capacity(p);
        for rank in 0..p {
            let mut cmd = Command::new(&exe);
            cmd.arg("__worker")
                .arg("--dir")
                .arg(dir)
                .arg("--rank")
                .arg(rank.to_string())
                .arg("--regions")
                .arg(regions.to_string())
                .arg("--ppr")
                .arg(ppr.to_string())
                .arg("--machine")
                .arg(machine)
                .arg("--deadline-ms")
                .arg(cfg.deadline.as_millis().to_string())
                .arg("--ring-bytes")
                .arg(cfg.ring_bytes.to_string())
                .stdin(Stdio::null())
                .stdout(Stdio::null());
            kids.push(cmd.spawn()?);
        }
        let mut reaper = Reaper { kids };

        // Phase 1: accept one HELLO per rank, watching for early deaths.
        let mut streams: Vec<Option<UnixStream>> = (0..p).map(|_| None).collect();
        let mut connected = 0usize;
        while connected < p {
            for (rank, child) in reaper.kids.iter_mut().enumerate() {
                if streams[rank].is_none() {
                    if let Ok(Some(status)) = child.try_wait() {
                        return Err(transport_err(
                            rank,
                            0,
                            format!("worker process exited during setup ({status})"),
                        ));
                    }
                }
            }
            match listener.accept() {
                Ok((s, _)) => {
                    s.set_nonblocking(false)?;
                    let (ty, rank, _) = ctl_recv(&s, &dl)
                        .map_err(|e| transport_err(0, 0, format!("control handshake: {e}")))?;
                    let rank = rank as usize;
                    if ty != CTL_HELLO || rank >= p || streams[rank].is_some() {
                        return Err(transport_err(rank.min(p - 1), 0, "bad control handshake"));
                    }
                    streams[rank] = Some(s);
                    connected += 1;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if dl.expired() {
                        let missing = (0..p).find(|&r| streams[r].is_none()).unwrap_or(0);
                        return Err(transport_err(
                            missing,
                            0,
                            "deadline exceeded waiting for workers to start",
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => return Err(e.into()),
            }
        }
        let streams: Vec<UnixStream> = streams.into_iter().map(Option::unwrap).collect();

        // Phase 2: GO — all listeners are bound, the data mesh may connect.
        for (rank, s) in streams.iter().enumerate() {
            send_or_err(s, CTL_GO, rank, &dl)?;
        }
        if let Some(k) = cfg.kill_rank {
            let _ = reaper.kids[k].kill();
            let _ = reaper.kids[k].wait();
        }

        // Phase 3: one READY per rank (a worker that failed mesh setup
        // reports ERR here; a dead worker's stream reports EOF).
        for (rank, s) in streams.iter().enumerate() {
            match ctl_recv(s, &dl) {
                Ok((CTL_READY, _, _)) => {}
                Ok((CTL_ERR, _, payload)) => return Err(decode_worker_err(rank, &payload)),
                Ok((ty, ..)) => {
                    return Err(transport_err(rank, 0, format!("unexpected control frame {ty}")))
                }
                Err(e) => return Err(transport_err(rank, 0, e)),
            }
        }
        Ok((reaper, streams))
    }

    /// Ship `job` to every worker once and return its schedule id. Any
    /// number of schedules can be resident; executes pick one by id.
    /// Rejections (a bad spec, frames too large for the fixed rings)
    /// surface as typed errors and leave the pool fully usable.
    pub fn load(&mut self, job: &ProcJob) -> Result<u64> {
        self.check_usable()?;
        let sid = self.next_sid;
        self.next_sid += 1;
        let spec = job_spec(job);
        let mut payload = Vec::with_capacity(8 + spec.len());
        payload.extend_from_slice(&sid.to_le_bytes());
        payload.extend_from_slice(spec.as_bytes());
        let dl = Deadline::after(self.deadline + Duration::from_secs(2));
        for (rank, s) in self.streams.iter().enumerate() {
            if let Err(e) = ctl_send(s, CTL_LOAD, 0, &payload, &dl) {
                return Err(self.poison(transport_err(rank, 0, e)));
            }
        }
        let replies = match collect_replies(&self.streams, &dl) {
            Ok(r) => r,
            Err(e) => return Err(self.poison(e)),
        };
        for (rank, (ty, payload)) in replies.into_iter().enumerate() {
            match ty {
                CTL_LOADED if payload.len() >= 8 => {
                    let echo = u64::from_le_bytes(payload[..8].try_into().unwrap());
                    if echo != sid {
                        return Err(self.poison(transport_err(
                            rank,
                            0,
                            format!("schedule id mismatch: sent {sid}, worker acked {echo}"),
                        )));
                    }
                }
                // Workers reject loads without touching the data
                // channels, so the pool stays usable.
                CTL_ERR => return Err(decode_worker_err(rank, &payload)),
                _ => {
                    return Err(self.poison(transport_err(
                        rank,
                        0,
                        format!("unexpected control frame {ty}"),
                    )))
                }
            }
        }
        self.loaded.insert(sid, (0..self.p).map(|r| job.io_bytes_rank(r, self.p).0).collect());
        self.stats.loads += 1;
        Ok(sid)
    }

    /// Execute a loaded schedule with its canonical inputs and ship the
    /// outputs back.
    pub fn execute(&mut self, sid: u64) -> Result<ProcReport> {
        self.execute_opts(sid, None, true)
    }

    /// Execute with explicit per-rank input bytes — the input delta is
    /// the only payload that crosses the control path.
    pub fn execute_with_inputs(&mut self, sid: u64, inputs: &[Vec<u8>]) -> Result<ProcReport> {
        self.execute_opts(sid, Some(inputs), true)
    }

    /// Execute without shipping outputs back — the timing-only path the
    /// bench loops use. Returns max per-worker execute-phase seconds.
    pub fn execute_timed(&mut self, sid: u64) -> Result<f64> {
        Ok(self.execute_opts(sid, None, false)?.wall)
    }

    fn execute_opts(
        &mut self,
        sid: u64,
        inputs: Option<&[Vec<u8>]>,
        want_outputs: bool,
    ) -> Result<ProcReport> {
        self.execute_begin(sid, inputs, want_outputs)?;
        self.execute_finish(sid)
    }

    /// First half of an execute: validate the inputs and ship the `EXEC`
    /// command (with input deltas) to every worker **without waiting for
    /// replies**. The workers run the collective while the caller does
    /// local work; [`ProcPool::execute_finish`] collects the results.
    /// Exactly one execute can be in flight per pool.
    pub fn execute_begin(
        &mut self,
        sid: u64,
        inputs: Option<&[Vec<u8>]>,
        want_outputs: bool,
    ) -> Result<()> {
        self.check_usable()?;
        if let Some(pending) = self.in_flight {
            return Err(transport_err(
                0,
                0,
                format!("an execute of schedule {pending} is already in flight on this pool"),
            ));
        }
        let Some(in_bytes) = self.loaded.get(&sid) else {
            // Caught parent-side, before anything crosses the control
            // path — a stale id never poisons the pool.
            return Err(transport_err(
                0,
                0,
                format!("stale schedule id {sid}: not loaded on this pool"),
            ));
        };
        if let Some(ins) = inputs {
            if ins.len() != self.p {
                return Err(Error::Precondition(format!(
                    "got {} input buffers for a {}-rank pool",
                    ins.len(),
                    self.p
                )));
            }
            for (rank, b) in ins.iter().enumerate() {
                if b.len() != in_bytes[rank] {
                    return Err(Error::Precondition(format!(
                        "rank {rank} input is {} bytes, schedule {sid} expects {}",
                        b.len(),
                        in_bytes[rank]
                    )));
                }
            }
        }
        let mut flags = 0u64;
        if want_outputs {
            flags |= EXEC_FLAG_OUTPUT;
        }
        if inputs.is_some() {
            flags |= EXEC_FLAG_INPUT;
        }
        let dl = Deadline::after(self.deadline + Duration::from_secs(2));
        for (rank, s) in self.streams.iter().enumerate() {
            let input = inputs.map(|v| v[rank].as_slice()).unwrap_or(&[]);
            let mut payload = Vec::with_capacity(16 + input.len());
            payload.extend_from_slice(&sid.to_le_bytes());
            payload.extend_from_slice(&flags.to_le_bytes());
            payload.extend_from_slice(input);
            if let Err(e) = ctl_send(s, CTL_EXEC, 0, &payload, &dl) {
                return Err(self.poison(transport_err(rank, 0, e)));
            }
        }
        self.in_flight = Some(sid);
        Ok(())
    }

    /// Second half of an execute: collect one reply per worker for the
    /// in-flight schedule `sid` and return the report. The outputs are
    /// present only when the matching [`ProcPool::execute_begin`] asked
    /// for them.
    pub fn execute_finish(&mut self, sid: u64) -> Result<ProcReport> {
        self.check_usable()?;
        if self.in_flight != Some(sid) {
            return Err(transport_err(
                0,
                0,
                format!("no execute of schedule {sid} is in flight on this pool"),
            ));
        }
        self.in_flight = None;
        let dl = Deadline::after(self.deadline + Duration::from_secs(2));
        let replies = match collect_replies(&self.streams, &dl) {
            Ok(r) => r,
            Err(e) => return Err(self.poison(e)),
        };
        let mut outputs: Vec<Vec<u8>> = vec![Vec::new(); self.p];
        let mut wall = 0f64;
        for (rank, (ty, payload)) in replies.into_iter().enumerate() {
            match ty {
                CTL_OK if payload.len() >= 16 => {
                    let echo = u64::from_le_bytes(payload[..8].try_into().unwrap());
                    if echo != sid {
                        return Err(self.poison(transport_err(
                            rank,
                            0,
                            format!("schedule id mismatch: sent {sid}, worker answered {echo}"),
                        )));
                    }
                    let nanos = u64::from_le_bytes(payload[8..16].try_into().unwrap());
                    wall = wall.max(nanos as f64 / 1e9);
                    outputs[rank] = payload[16..].to_vec();
                }
                CTL_ERR => return Err(self.poison(decode_worker_err(rank, &payload))),
                _ => {
                    return Err(self.poison(transport_err(
                        rank,
                        0,
                        format!("unexpected control frame {ty}"),
                    )))
                }
            }
        }
        self.stats.executes += 1;
        Ok(ProcReport { outputs, wall })
    }

    /// Graceful shutdown: `SHUTDOWN` is acked by every live worker, then
    /// all are reaped. The pool is unusable afterwards; dropping it also
    /// cleans up, so calling this is optional.
    pub fn shutdown(&mut self) -> Result<()> {
        self.check_usable()?;
        if let Some(pending) = self.in_flight {
            return Err(transport_err(
                0,
                0,
                format!("cannot shut down with an execute of schedule {pending} in flight"),
            ));
        }
        let dl = Deadline::after(Duration::from_secs(5));
        for (rank, s) in self.streams.iter().enumerate() {
            if let Err(e) = ctl_send(s, CTL_SHUTDOWN, 0, &[], &dl) {
                return Err(self.poison(transport_err(rank, 0, e)));
            }
        }
        for (rank, s) in self.streams.iter().enumerate() {
            match ctl_recv(s, &dl) {
                Ok((CTL_OK, ..)) => {}
                Ok((ty, ..)) => {
                    return Err(self.poison(transport_err(
                        rank,
                        0,
                        format!("unexpected control frame {ty}"),
                    )))
                }
                Err(e) => return Err(self.poison(transport_err(rank, 0, e))),
            }
        }
        // Workers exit right after acking; reap them gracefully (Drop
        // would kill stragglers, but a clean wait avoids racing their
        // exit).
        let reap_dl = Deadline::after(Duration::from_secs(5));
        for child in &mut self.reaper.kids {
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if reap_dl.expired() => break,
                    Ok(None) => std::thread::sleep(Duration::from_millis(1)),
                    Err(_) => break,
                }
            }
        }
        self.poisoned = Some("pool was shut down".into());
        Ok(())
    }

    /// World size (`regions × ppr` at spawn).
    pub fn size(&self) -> usize {
        self.p
    }

    /// Lifecycle counters (spawns, handshakes, loads, executes).
    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// Test hook: kill one worker process outright, as if it crashed
    /// between executes.
    pub fn kill_worker(&mut self, rank: usize) -> Result<()> {
        if rank >= self.p {
            return Err(Error::RankOutOfRange { rank, size: self.p });
        }
        let _ = self.reaper.kids[rank].kill();
        let _ = self.reaper.kids[rank].wait();
        Ok(())
    }

    /// Record a fatal error: the data channels are in an unknown state,
    /// so every later call fails fast until a fresh pool is spawned.
    fn poison(&mut self, e: Error) -> Error {
        if self.poisoned.is_none() {
            self.poisoned = Some(e.to_string());
        }
        e
    }

    fn check_usable(&self) -> Result<()> {
        match &self.poisoned {
            Some(what) => Err(Error::Transport {
                rank: 0,
                round: 0,
                what: format!("pool is poisoned ({what}); spawn a fresh ProcPool"),
            }),
            None => Ok(()),
        }
    }
}

impl Drop for ProcPool {
    fn drop(&mut self) {
        // Close control sockets first so idle workers exit on EOF, then
        // reap before the rendezvous directory goes away.
        self.streams.clear();
        for c in &mut self.reaper.kids {
            let _ = c.kill();
            let _ = c.wait();
        }
        self.reaper.kids.clear();
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

/// Collect one reply frame per rank, failing fast when any worker dies
/// (EOF on its control socket) instead of waiting out the deadline.
fn collect_replies(streams: &[UnixStream], dl: &Deadline) -> Result<Vec<(u8, Vec<u8>)>> {
    let mut got: Vec<Option<(u8, Vec<u8>)>> = (0..streams.len()).map(|_| None).collect();
    let mut done = 0usize;
    while done < streams.len() {
        let mut progressed = false;
        for (rank, s) in streams.iter().enumerate() {
            if got[rank].is_some() {
                continue;
            }
            s.set_nonblocking(true).map_err(|e| transport_err(rank, 0, e.to_string()))?;
            let mut probe = [0u8; 1];
            let peeked = s.peek(&mut probe);
            // Read timeouts only apply in blocking mode; restore it
            // before any actual receive.
            s.set_nonblocking(false).map_err(|e| transport_err(rank, 0, e.to_string()))?;
            match peeked {
                Ok(0) => {
                    return Err(transport_err(
                        rank,
                        0,
                        "worker process died between pool commands (EOF on control socket)",
                    ));
                }
                Ok(_) => {
                    let (ty, _, payload) =
                        ctl_recv(s, dl).map_err(|e| transport_err(rank, 0, e))?;
                    got[rank] = Some((ty, payload));
                    done += 1;
                    progressed = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                Err(e) => return Err(transport_err(rank, 0, e.to_string())),
            }
        }
        if done < streams.len() && !progressed {
            if dl.expired() {
                let missing = got.iter().position(Option::is_none).unwrap_or(0);
                return Err(transport_err(
                    missing,
                    0,
                    "deadline exceeded waiting for worker replies",
                ));
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    Ok(got.into_iter().map(Option::unwrap).collect())
}

/// Execute `job` once over `regions × ppr` worker processes: one
/// spawn → load → execute → shutdown cycle on a fresh [`ProcPool`].
/// Single-shot callers (the conformance tests, one-off CLI runs) use
/// this; anything iterating should hold a pool and call
/// [`ProcPool::execute`] repeatedly.
pub fn run_proc(
    regions: usize,
    ppr: usize,
    job: &ProcJob,
    machine: &str,
    cfg: &ProcConfig,
) -> Result<ProcReport> {
    let mut pool = ProcPool::spawn(regions, ppr, machine, cfg)?;
    let sid = pool.load(job)?;
    let report = pool.execute(sid)?;
    let _ = pool.shutdown();
    Ok(report)
}

/// Load `job` on `pool`, run `warmup` discarded executes, then `iters`
/// timed ones, and return the median execute-phase wall seconds — the
/// measurement loop `locag bench` and `locag figure` share.
pub fn pool_median_wall(
    pool: &mut ProcPool,
    job: &ProcJob,
    warmup: usize,
    iters: usize,
) -> Result<f64> {
    let sid = pool.load(job)?;
    for _ in 0..warmup {
        pool.execute_timed(sid)?;
    }
    let mut walls = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        walls.push(pool.execute_timed(sid)?);
    }
    walls.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    Ok(walls[walls.len() / 2])
}

/// Shares one pool across thread-per-rank code (the coordinator's serving
/// loop): each thread deposits its rank's input, the barrier leader runs
/// one pooled execute, and every thread picks up its rank's output. A
/// pool failure surfaces on every rank and sticks for later exchanges.
pub struct PoolGate {
    barrier: Barrier,
    inner: Mutex<GateInner>,
}

struct GateInner {
    pool: ProcPool,
    sid: u64,
    inputs: Vec<Vec<u8>>,
    outputs: Vec<Vec<u8>>,
    error: Option<String>,
}

impl PoolGate {
    /// Wrap a pool and a loaded schedule id; `exchange` expects exactly
    /// `pool.size()` participating threads.
    pub fn new(pool: ProcPool, sid: u64) -> PoolGate {
        let p = pool.size();
        PoolGate {
            barrier: Barrier::new(p),
            inner: Mutex::new(GateInner {
                pool,
                sid,
                inputs: vec![Vec::new(); p],
                outputs: vec![Vec::new(); p],
                error: None,
            }),
        }
    }

    /// Run one collective: deposit `input` for `rank`, execute once all
    /// ranks have arrived, and write this rank's output into `output`.
    pub fn exchange(&self, rank: usize, input: &[u8], output: &mut Vec<u8>) -> Result<()> {
        self.begin_exchange(rank, input)?;
        self.finish_exchange(rank, output)
    }

    /// First half of [`PoolGate::exchange`]: deposit this rank's input
    /// (reusing the gate's per-rank buffer) and, once every rank has
    /// arrived, ship the execute to the workers without waiting for
    /// replies. Callers overlap local work between this and
    /// [`PoolGate::finish_exchange`]; a leader-side failure is sticky and
    /// surfaces to every rank at the finish.
    pub fn begin_exchange(&self, rank: usize, input: &[u8]) -> Result<()> {
        {
            let mut g = self.inner.lock().expect("gate lock");
            if let Some(e) = &g.error {
                return Err(Error::Transport { rank, round: 0, what: e.clone() });
            }
            let dst = &mut g.inputs[rank];
            dst.clear();
            dst.extend_from_slice(input);
        }
        if self.barrier.wait().is_leader() {
            let mut g = self.inner.lock().expect("gate lock");
            let GateInner { pool, sid, inputs, error, .. } = &mut *g;
            if let Err(e) = pool.execute_begin(*sid, Some(inputs.as_slice()), true) {
                *error = Some(e.to_string());
            }
        }
        Ok(())
    }

    /// Second half of [`PoolGate::exchange`]: collect the workers'
    /// replies and write this rank's output into `output` (reusing its
    /// capacity).
    pub fn finish_exchange(&self, rank: usize, output: &mut Vec<u8>) -> Result<()> {
        if self.barrier.wait().is_leader() {
            let mut g = self.inner.lock().expect("gate lock");
            if g.error.is_none() {
                let GateInner { pool, sid, outputs, error, .. } = &mut *g;
                match pool.execute_finish(*sid) {
                    Ok(rep) => *outputs = rep.outputs,
                    Err(e) => *error = Some(e.to_string()),
                }
            }
        }
        self.barrier.wait();
        let g = self.inner.lock().expect("gate lock");
        if let Some(e) = &g.error {
            return Err(Error::Transport { rank, round: 0, what: e.clone() });
        }
        output.clear();
        output.extend_from_slice(&g.outputs[rank]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::fuse::FuseSpec;
    use crate::collectives::OpKind;
    use crate::transport::DType;

    #[test]
    fn job_specs_have_the_wire_spelling_workers_parse() {
        let single = ProcJob::Single {
            op: OpKind::Allgather,
            algo: "loc-aware".into(),
            n: 16,
            elem_bytes: 4,
        };
        assert_eq!(job_spec(&single), "single allgather loc-aware 16 4");
        let ragged = ProcJob::SingleV {
            op: OpKind::Allgatherv,
            algo: "loc-aware".into(),
            counts: vec![4, 0, 7, 2],
            elem_bytes: 8,
        };
        assert_eq!(job_spec(&ragged), "singlev allgatherv loc-aware 4,0,7,2 8");
        let fused = ProcJob::Fused {
            specs: vec![
                FuseSpec::new(OpKind::Allgather, "bruck", 2),
                FuseSpec::new(OpKind::ReduceScatter, "loc-aware", 3),
            ],
            dtype: DType::F32,
        };
        assert_eq!(job_spec(&fused), "fused f32 allgather/bruck@2;reduce-scatter/loc-aware@3");
        let mixed = ProcJob::FusedMixed {
            specs: vec![
                (FuseSpec::new(OpKind::Allgather, "bruck", 2), DType::F32),
                (FuseSpec::new(OpKind::Allreduce, "loc-aware", 4), DType::U64),
            ],
        };
        assert_eq!(job_spec(&mixed), "fusedmix f32:allgather/bruck@2;u64:allreduce/loc-aware@4");
    }

    #[test]
    fn worker_err_decodes_with_peer_attribution() {
        let mut payload = Vec::new();
        payload.extend_from_slice(&3u64.to_le_bytes());
        payload.extend_from_slice(&2u64.to_le_bytes());
        payload.extend_from_slice(b"deadline exceeded while receiving");
        let e = decode_worker_err(1, &payload);
        match e {
            Error::Transport { rank, round, what } => {
                assert_eq!((rank, round), (2, 3));
                assert!(what.contains("reported by rank 1"), "{what}");
            }
            other => panic!("wrong error: {other}"),
        }
    }
}
