//! Byte channels between worker processes.
//!
//! Two channel kinds, chosen per peer pair by the topology's locality
//! class (see the [module docs](super)):
//!
//! * [`ShmRing`] — a single-producer single-consumer ring buffer backed by
//!   a file on `/dev/shm` (tmpfs), i.e. plain shared memory addressed with
//!   `pread`/`pwrite`. One ring per *directed* intra-node pair.
//! * Unix-domain stream sockets — one full-duplex stream per *unordered*
//!   inter-node pair, plus one control stream from every worker to the
//!   parent.
//!
//! Everything here is deadline-bounded: every blocking wait takes a
//! [`Deadline`] and fails with a descriptive `String` instead of hanging.
//! Callers wrap those strings into [`crate::error::Error::Transport`] with
//! the rank/round context only they know.

use std::fs::{File, OpenOptions};
use std::io::{ErrorKind, Read, Write};
use std::os::unix::fs::FileExt;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::time::{Duration, Instant};

/// A wall-clock deadline shared by every blocking operation of one worker
/// (or of the parent's collection loop).
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    at: Instant,
}

impl Deadline {
    /// A deadline `d` from now.
    pub fn after(d: Duration) -> Deadline {
        Deadline { at: Instant::now() + d }
    }

    /// Time left, or `None` once expired.
    pub fn remaining(&self) -> Option<Duration> {
        let now = Instant::now();
        if now >= self.at {
            None
        } else {
            Some(self.at - now)
        }
    }

    /// True once the deadline has passed.
    pub fn expired(&self) -> bool {
        self.remaining().is_none()
    }
}

/// Channel-level result: the error is a bare description; rank/round
/// context is attached by the interpreter.
pub type ChanResult<T> = Result<T, String>;

/// Sleep briefly between polls, or fail once the deadline has passed.
fn pause(dl: &Deadline, what: &str) -> ChanResult<()> {
    if dl.expired() {
        return Err(format!("deadline exceeded while {what}"));
    }
    std::thread::sleep(Duration::from_micros(50));
    Ok(())
}

// ---------------------------------------------------------------------------
// shared-memory ring
// ---------------------------------------------------------------------------

const HEAD_OFF: u64 = 0;
const TAIL_OFF: u64 = 64;
const DATA_OFF: u64 = 128;

/// Minimum ring capacity; [`ring_capacity`] grows it for large messages.
pub const MIN_RING_CAP: u64 = 1 << 20;

/// Ring capacity for a channel whose largest single message is
/// `max_msg_bytes` (payload + frame header). Both endpoints must compute
/// the same value, so it is a pure function of the message bound.
pub fn ring_capacity(max_msg_bytes: usize) -> u64 {
    MIN_RING_CAP.max(4 * (max_msg_bytes as u64 + 16))
}

/// One direction of an intra-node byte stream over a tmpfs-backed file.
///
/// Layout: byte 0 holds the head counter (total bytes ever written, owned
/// by the writer), byte 64 the tail counter (total bytes ever read, owned
/// by the reader), and `cap` data bytes start at byte 128. Counters are
/// absolute, so `head - tail` is the number of unread bytes and wrap-around
/// is plain modular arithmetic. Exactly one process calls
/// [`ShmRing::write_all`] on a given file and exactly one calls
/// [`ShmRing::read_exact`]; `pos` caches that endpoint's own
/// counter so only the *other* side's counter is ever re-read from the
/// file.
pub struct ShmRing {
    file: File,
    cap: u64,
    pos: u64,
}

impl ShmRing {
    /// Open (creating if needed) the ring file at `path` with `cap` data
    /// bytes. Both endpoints call this with the same `cap`; `set_len` is
    /// idempotent and tmpfs allocates pages lazily.
    pub fn open(path: &Path, cap: u64) -> ChanResult<ShmRing> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)
            .map_err(|e| format!("open shm ring {}: {e}", path.display()))?;
        file.set_len(DATA_OFF + cap).map_err(|e| format!("size shm ring: {e}"))?;
        Ok(ShmRing { file, cap, pos: 0 })
    }

    fn load_u64(&self, off: u64) -> ChanResult<u64> {
        let mut b = [0u8; 8];
        self.file.read_exact_at(&mut b, off).map_err(|e| format!("shm ring read: {e}"))?;
        Ok(u64::from_le_bytes(b))
    }

    fn store_u64(&self, off: u64, v: u64) -> ChanResult<()> {
        self.file
            .write_all_at(&v.to_le_bytes(), off)
            .map_err(|e| format!("shm ring write: {e}"))
    }

    fn store(&self, off: u64, buf: &[u8]) -> ChanResult<()> {
        self.file.write_all_at(buf, off).map_err(|e| format!("shm ring write: {e}"))
    }

    fn load(&self, off: u64, buf: &mut [u8]) -> ChanResult<()> {
        self.file.read_exact_at(buf, off).map_err(|e| format!("shm ring read: {e}"))
    }

    /// Writer side: append `buf`, waiting (bounded by `dl`) for the reader
    /// to drain the ring when full.
    pub fn write_all(&mut self, mut buf: &[u8], dl: &Deadline) -> ChanResult<()> {
        while !buf.is_empty() {
            let tail = self.load_u64(TAIL_OFF)?;
            let free = self.cap - (self.pos - tail);
            if free == 0 {
                pause(dl, "waiting for shm-ring space (receiver stalled)")?;
                continue;
            }
            let take = (buf.len() as u64).min(free) as usize;
            let start = (self.pos % self.cap) as usize;
            let first = take.min(self.cap as usize - start);
            self.store(DATA_OFF + start as u64, &buf[..first])?;
            if take > first {
                self.store(DATA_OFF, &buf[first..take])?;
            }
            self.pos += take as u64;
            self.store_u64(HEAD_OFF, self.pos)?;
            buf = &buf[take..];
        }
        Ok(())
    }

    /// Reader side: fill `buf`, waiting (bounded by `dl`) for the writer
    /// to produce enough bytes.
    pub fn read_exact(&mut self, mut buf: &mut [u8], dl: &Deadline) -> ChanResult<()> {
        while !buf.is_empty() {
            let head = self.load_u64(HEAD_OFF)?;
            let avail = head - self.pos;
            if avail == 0 {
                pause(dl, "waiting for shm-ring data")?;
                continue;
            }
            let take = (buf.len() as u64).min(avail) as usize;
            let start = (self.pos % self.cap) as usize;
            let first = take.min(self.cap as usize - start);
            self.load(DATA_OFF + start as u64, &mut buf[..first])?;
            if take > first {
                self.load(DATA_OFF, &mut buf[first..take])?;
            }
            self.pos += take as u64;
            self.store_u64(TAIL_OFF, self.pos)?;
            let rest = buf;
            buf = &mut rest[take..];
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Unix-domain sockets, deadline-bounded
// ---------------------------------------------------------------------------

fn with_timeout<T>(
    set: impl Fn(Option<Duration>) -> std::io::Result<()>,
    dl: &Deadline,
    io: impl FnOnce() -> std::io::Result<T>,
    what: &str,
) -> ChanResult<T> {
    let left = dl.remaining().ok_or_else(|| format!("deadline exceeded while {what}"))?;
    set(Some(left)).map_err(|e| format!("set socket timeout: {e}"))?;
    io().map_err(|e| match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => {
            format!("deadline exceeded while {what}")
        }
        ErrorKind::UnexpectedEof | ErrorKind::BrokenPipe | ErrorKind::ConnectionReset => {
            format!("peer closed socket while {what} (EOF)")
        }
        _ => format!("socket error while {what}: {e}"),
    })
}

/// `write_all` on a Unix stream, bounded by `dl`.
pub fn sock_write_all(s: &UnixStream, buf: &[u8], dl: &Deadline) -> ChanResult<()> {
    let mut w = s;
    with_timeout(|t| s.set_write_timeout(t), dl, move || w.write_all(buf), "sending")
}

/// `read_exact` on a Unix stream, bounded by `dl`.
pub fn sock_read_exact(s: &UnixStream, buf: &mut [u8], dl: &Deadline) -> ChanResult<()> {
    let mut r = s;
    with_timeout(|t| s.set_read_timeout(t), dl, move || r.read_exact(buf), "receiving")
}

/// Accept one connection, bounded by `dl`. The listener must be in
/// non-blocking mode; the accepted stream is switched back to blocking.
pub fn accept_deadline(l: &UnixListener, dl: &Deadline) -> ChanResult<UnixStream> {
    loop {
        match l.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false).map_err(|e| format!("accept: {e}"))?;
                return Ok(s);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                pause(dl, "waiting for a peer to connect")?;
            }
            Err(e) => return Err(format!("accept: {e}")),
        }
    }
}

/// Connect to `path`, retrying until the listener appears, bounded by `dl`.
pub fn connect_deadline(path: &Path, dl: &Deadline) -> ChanResult<UnixStream> {
    loop {
        match UnixStream::connect(path) {
            Ok(s) => return Ok(s),
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::NotFound | ErrorKind::ConnectionRefused | ErrorKind::AddrNotAvailable
                ) =>
            {
                pause(dl, &format!("connecting to {}", path.display()))?;
            }
            Err(e) => return Err(format!("connect {}: {e}", path.display())),
        }
    }
}

// ---------------------------------------------------------------------------
// framed peer channel
// ---------------------------------------------------------------------------

/// A bidirectional framed byte channel to one peer rank. Frames are
/// `[tag u64 LE][len u64 LE][len payload bytes]`; per-channel frame order
/// is FIFO, which gives the per-(src, tag) FIFO matching the in-process
/// mailboxes guarantee.
pub enum PeerChan {
    /// Intra-node: one ring per direction.
    Shm { tx: ShmRing, rx: ShmRing },
    /// Inter-node: one full-duplex stream.
    Sock(UnixStream),
}

impl PeerChan {
    /// Send one frame.
    pub fn send_frame(&mut self, tag: u64, payload: &[u8], dl: &Deadline) -> ChanResult<()> {
        let mut hdr = [0u8; 16];
        hdr[..8].copy_from_slice(&tag.to_le_bytes());
        hdr[8..].copy_from_slice(&(payload.len() as u64).to_le_bytes());
        match self {
            PeerChan::Shm { tx, .. } => {
                tx.write_all(&hdr, dl)?;
                tx.write_all(payload, dl)
            }
            PeerChan::Sock(s) => {
                sock_write_all(s, &hdr, dl)?;
                sock_write_all(s, payload, dl)
            }
        }
    }

    /// Receive the next frame in channel order.
    pub fn recv_frame(&mut self, dl: &Deadline) -> ChanResult<(u64, Vec<u8>)> {
        let mut buf = Vec::new();
        let (tag, len) = self.recv_frame_into(&mut buf, dl)?;
        buf.truncate(len);
        Ok((tag, buf))
    }

    /// Receive the next frame into a caller-owned buffer, growing it only
    /// when the payload is larger than any seen before. The payload lands
    /// in `buf[..len]`; repeat receives of same-sized messages allocate
    /// nothing, which is what keeps the pool's execute loop memcpy-only.
    pub fn recv_frame_into(&mut self, buf: &mut Vec<u8>, dl: &Deadline) -> ChanResult<(u64, usize)> {
        let mut hdr = [0u8; 16];
        match self {
            PeerChan::Shm { rx, .. } => rx.read_exact(&mut hdr, dl)?,
            PeerChan::Sock(s) => sock_read_exact(s, &mut hdr, dl)?,
        }
        let tag = u64::from_le_bytes(hdr[..8].try_into().unwrap());
        let len = u64::from_le_bytes(hdr[8..].try_into().unwrap()) as usize;
        if buf.len() < len {
            buf.resize(len, 0);
        }
        match self {
            PeerChan::Shm { rx, .. } => rx.read_exact(&mut buf[..len], dl)?,
            PeerChan::Sock(s) => sock_read_exact(s, &mut buf[..len], dl)?,
        }
        Ok((tag, len))
    }
}

// ---------------------------------------------------------------------------
// control frames (worker ⇄ parent)
// ---------------------------------------------------------------------------

/// Worker → parent: "I exist, my listener (if any) is bound".
pub const CTL_HELLO: u8 = 1;
/// Worker → parent: "all data channels are connected".
pub const CTL_READY: u8 = 2;
/// Worker → parent: success; payload = `[wall_nanos u64][output bytes]`.
pub const CTL_OK: u8 = 3;
/// Worker → parent: failure; payload = `[round u64][peer u64][utf-8 message]`.
pub const CTL_ERR: u8 = 4;
/// Parent → worker: every worker said hello, connect data channels now.
pub const CTL_GO: u8 = 5;
/// Parent → worker: every worker is ready, start executing now.
pub const CTL_START: u8 = 6;
/// Parent → pool worker: build and cache a schedule; payload =
/// `[schedule id u64][utf-8 job spec]`.
pub const CTL_LOAD: u8 = 7;
/// Pool worker → parent: schedule built and cached; payload =
/// `[schedule id u64]`.
pub const CTL_LOADED: u8 = 8;
/// Parent → pool worker: execute a cached schedule; payload =
/// `[schedule id u64][flags u64][input delta bytes when flags bit 1]`.
/// Flags: bit 0 = ship the output back in `CTL_OK`, bit 1 = an input
/// delta is attached and replaces the worker's current input.
pub const CTL_EXEC: u8 = 9;
/// Parent → pool worker: leave the command loop and exit cleanly (the
/// worker acks with an empty `CTL_OK` first).
pub const CTL_SHUTDOWN: u8 = 10;

/// Send one control frame: `[ty u8][rank u64 LE][len u64 LE][payload]`.
pub fn ctl_send(s: &UnixStream, ty: u8, rank: u64, payload: &[u8], dl: &Deadline) -> ChanResult<()> {
    let mut hdr = [0u8; 17];
    hdr[0] = ty;
    hdr[1..9].copy_from_slice(&rank.to_le_bytes());
    hdr[9..].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    sock_write_all(s, &hdr, dl)?;
    sock_write_all(s, payload, dl)
}

/// Receive one control frame.
pub fn ctl_recv(s: &UnixStream, dl: &Deadline) -> ChanResult<(u8, u64, Vec<u8>)> {
    let mut hdr = [0u8; 17];
    sock_read_exact(s, &mut hdr, dl)?;
    let ty = hdr[0];
    let rank = u64::from_le_bytes(hdr[1..9].try_into().unwrap());
    let len = u64::from_le_bytes(hdr[9..].try_into().unwrap()) as usize;
    let mut payload = vec![0u8; len];
    sock_read_exact(s, &mut payload, dl)?;
    Ok((ty, rank, payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_ring(name: &str, cap: u64) -> (std::path::PathBuf, ShmRing, ShmRing) {
        let path = std::env::temp_dir().join(format!("locag-chan-test-{}-{name}", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let tx = ShmRing::open(&path, cap).unwrap();
        let rx = ShmRing::open(&path, cap).unwrap();
        (path, tx, rx)
    }

    #[test]
    fn shm_ring_roundtrip_with_wraparound() {
        // Capacity far below the total traffic forces many wrap-arounds and
        // exercises the writer-waits-for-reader path.
        let (path, mut tx, mut rx) = tmp_ring("wrap", 256);
        let dl = Deadline::after(Duration::from_secs(10));
        let msgs: Vec<Vec<u8>> =
            (0..40u8).map(|i| (0..97u8).map(|j| i.wrapping_mul(7) ^ j).collect()).collect();
        let writer = std::thread::spawn({
            let msgs = msgs.clone();
            move || {
                for m in &msgs {
                    tx.write_all(m, &dl).unwrap();
                }
            }
        });
        for m in &msgs {
            let mut got = vec![0u8; m.len()];
            rx.read_exact(&mut got, &dl).unwrap();
            assert_eq!(&got, m);
        }
        writer.join().unwrap();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn shm_ring_read_times_out_without_writer() {
        let (path, _tx, mut rx) = tmp_ring("timeout", 256);
        let dl = Deadline::after(Duration::from_millis(50));
        let mut buf = [0u8; 4];
        let err = rx.read_exact(&mut buf, &dl).unwrap_err();
        assert!(err.contains("deadline exceeded"), "{err}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn peer_chan_frames_over_shm() {
        let (path_ab, tx_ab, rx_ab) = tmp_ring("frames-ab", 512);
        let (path_ba, tx_ba, rx_ba) = tmp_ring("frames-ba", 512);
        let dl = Deadline::after(Duration::from_secs(10));
        let mut a = PeerChan::Shm { tx: tx_ab, rx: rx_ba };
        let mut b = PeerChan::Shm { tx: tx_ba, rx: rx_ab };
        a.send_frame(7, b"hello", &dl).unwrap();
        a.send_frame(9, &[], &dl).unwrap();
        let (t1, p1) = b.recv_frame(&dl).unwrap();
        let (t2, p2) = b.recv_frame(&dl).unwrap();
        assert_eq!((t1, p1.as_slice()), (7, b"hello".as_slice()));
        assert_eq!((t2, p2.len()), (9, 0));
        let big = vec![0xAB_u8; 300];
        b.send_frame(1, &big, &dl).unwrap();
        let (t3, p3) = a.recv_frame(&dl).unwrap();
        assert_eq!(t3, 1);
        assert_eq!(p3, big);
        let _ = std::fs::remove_file(path_ab);
        let _ = std::fs::remove_file(path_ba);
    }

    #[test]
    fn recv_frame_into_reuses_the_buffer() {
        let (path_ab, tx_ab, rx_ab) = tmp_ring("into-ab", 512);
        let (path_ba, tx_ba, rx_ba) = tmp_ring("into-ba", 512);
        let dl = Deadline::after(Duration::from_secs(10));
        let mut a = PeerChan::Shm { tx: tx_ab, rx: rx_ba };
        let mut b = PeerChan::Shm { tx: tx_ba, rx: rx_ab };
        a.send_frame(1, &[7u8; 100], &dl).unwrap();
        a.send_frame(2, &[9u8; 40], &dl).unwrap();
        let mut buf = Vec::new();
        let (t1, l1) = b.recv_frame_into(&mut buf, &dl).unwrap();
        assert_eq!((t1, l1), (1, 100));
        assert!(buf[..100].iter().all(|&x| x == 7));
        let cap = buf.capacity();
        // The smaller second frame must not shrink or reallocate the buffer.
        let (t2, l2) = b.recv_frame_into(&mut buf, &dl).unwrap();
        assert_eq!((t2, l2), (2, 40));
        assert!(buf[..40].iter().all(|&x| x == 9));
        assert_eq!(buf.capacity(), cap);
        let _ = std::fs::remove_file(path_ab);
        let _ = std::fs::remove_file(path_ba);
    }

    #[test]
    fn ctl_frames_roundtrip_over_socketpair() {
        let (a, b) = UnixStream::pair().unwrap();
        let dl = Deadline::after(Duration::from_secs(5));
        ctl_send(&a, CTL_ERR, 3, b"boom", &dl).unwrap();
        let (ty, rank, payload) = ctl_recv(&b, &dl).unwrap();
        assert_eq!((ty, rank, payload.as_slice()), (CTL_ERR, 3, b"boom".as_slice()));
    }

    #[test]
    fn sock_read_reports_eof() {
        let (a, b) = UnixStream::pair().unwrap();
        drop(a);
        let dl = Deadline::after(Duration::from_secs(1));
        let mut buf = [0u8; 1];
        let err = sock_read_exact(&b, &mut buf, &dl).unwrap_err();
        assert!(err.contains("EOF") || err.contains("closed"), "{err}");
    }

    #[test]
    fn ring_capacity_covers_large_messages() {
        assert_eq!(ring_capacity(0), MIN_RING_CAP);
        let big = 10 << 20;
        assert!(ring_capacity(big) >= 4 * big as u64);
    }
}
