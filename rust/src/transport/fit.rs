//! Measured α/β calibration (`locag fit`).
//!
//! Two worker processes ping-pong messages of increasing size over each
//! physical channel kind the proc backend uses — a shared-memory ring
//! (the *local* message class) and a Unix-domain socket (the *non-local*
//! class) — and the parent least-squares fits `t(s) = α + β·s` per
//! protocol segment (eager below [`DEFAULT_EAGER_CUTOFF`], rendezvous at
//! or above it), mirroring the paper's Fig. 3 methodology of measuring
//! each locality class separately instead of assuming constants.
//!
//! Everything runs on one host, so there is no real network: the
//! inter-node class reuses the socket measurement (the most expensive
//! channel available) and the fitted file says so in its provenance
//! field. The point of `fit` is the *workflow* — measured parameters flow
//! into [`MachineParams`] and from there into `model-tuned` dispatch —
//! with honest relative asymmetry between shm and socket transports.

use std::io::ErrorKind;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use super::chan::{
    accept_deadline, connect_deadline, ctl_recv, ctl_send, ring_capacity, Deadline, PeerChan,
    ShmRing, CTL_GO, CTL_HELLO, CTL_OK, CTL_READY, CTL_START,
};
use crate::cli::args::Args;
use crate::error::{Error, Result};
use crate::model::params::{ClassParams, MachineParams, Postal, DEFAULT_EAGER_CUTOFF};

/// Frame tag that tells the echo side to stop.
const DONE_TAG: u64 = u64::MAX;

/// Message sizes for the full calibration sweep (bytes). Spans both
/// protocol segments with several points each, reaching into multi-MiB
/// rendezvous territory so the large-message β is fitted at sizes the
/// proc backend actually ships.
pub const FIT_SIZES: [usize; 12] =
    [8, 64, 512, 2048, 4096, 8192, 16384, 65536, 262144, 1_048_576, 2_097_152, 4_194_304];

/// Reduced sweep for `--quick` smoke runs (still ≥2 points per segment).
pub const FIT_SIZES_QUICK: [usize; 7] = [8, 512, 4096, 16384, 65536, 262144, 1_048_576];

/// Discarded warm-up round trips per (channel, size) before the timed
/// iterations — absorbs page faults on fresh shm rings and socket
/// buffer growth that would otherwise bias α upward.
pub const FIT_WARMUP_ROUNDS: usize = 5;

/// Timed iterations for one message size: the `base` rep count at and
/// below 16 KiB, scaled down inversely with size so the multi-MiB tail
/// doesn't dominate the sweep's wall time, floored at 3 so the
/// min-of-reps filter still rejects outliers.
pub fn reps_for_size(size: usize, base: usize) -> usize {
    (base.saturating_mul(16_384) / size.max(1)).clamp(3, base.max(3))
}

// ---------------------------------------------------------------------------
// least-squares fitting
// ---------------------------------------------------------------------------

/// A calibration defect worth telling the user about: the fitted
/// machine is still usable, but the flagged segment's line is
/// underdetermined and should not be silently trusted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FitWarning {
    /// A protocol segment had fewer than 2 sweep points, so its line was
    /// fitted from the whole sweep instead of the segment alone.
    ThinSegment {
        /// Locality class the segment belongs to ("intra-socket", …).
        class: &'static str,
        /// Protocol segment ("eager" or "rendezvous").
        segment: &'static str,
        /// Sweep points the segment actually had.
        points: usize,
    },
    /// The points used for a segment had no size spread, so α collapsed
    /// to the mean sample time and β to the clamp floor.
    DegenerateFit {
        /// Locality class the segment belongs to.
        class: &'static str,
        /// Protocol segment ("eager" or "rendezvous").
        segment: &'static str,
        /// Points that went into the degenerate fit.
        points: usize,
    },
}

impl std::fmt::Display for FitWarning {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FitWarning::ThinSegment { class, segment, points } => write!(
                f,
                "{class}/{segment}: only {points} sweep point(s) fall in this segment; \
                 fitted from the full sweep instead (extend the size sweep to cover it)"
            ),
            FitWarning::DegenerateFit { class, segment, points } => write!(
                f,
                "{class}/{segment}: {points} point(s) with no size spread cannot determine \
                 a line; α collapsed to the mean and β to the floor"
            ),
        }
    }
}

/// Ordinary least squares for `t = α + β·s` over `(bytes, seconds)`
/// samples. α is clamped to a positive floor (a fitted negative latency is
/// measurement noise, and the cost model requires `cost(0) > 0`); β is
/// clamped likewise so larger messages never model as free. The flag is
/// true when the points could not determine a line (fewer than 2, or no
/// size spread) and the fit collapsed to mean-α/zero-β.
fn fit_line(pts: &[(usize, f64)]) -> (Postal, bool) {
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|(s, _)| *s as f64).sum();
    let sy: f64 = pts.iter().map(|(_, t)| *t).sum();
    let sxx: f64 = pts.iter().map(|(s, _)| (*s as f64) * (*s as f64)).sum();
    let sxy: f64 = pts.iter().map(|(s, t)| (*s as f64) * t).sum();
    let denom = n * sxx - sx * sx;
    let (alpha, beta, degenerate) = if pts.len() < 2 || denom.abs() < f64::EPSILON {
        (if n > 0.0 { sy / n } else { 0.0 }, 0.0, true)
    } else {
        let beta = (n * sxy - sx * sy) / denom;
        ((sy - beta * sx) / n, beta, false)
    };
    (Postal { alpha: alpha.max(1e-9), beta: beta.max(1e-13) }, degenerate)
}

/// Fit one protocol segment, falling back to the whole sweep when the
/// segment has too few points — recording a typed warning whenever the
/// line came out underdetermined instead of silently collapsing.
fn fit_segment(
    class: &'static str,
    segment: &'static str,
    seg_pts: &[(usize, f64)],
    all_pts: &[(usize, f64)],
    warnings: &mut Vec<FitWarning>,
) -> Postal {
    let pts = if seg_pts.len() < 2 {
        warnings.push(FitWarning::ThinSegment { class, segment, points: seg_pts.len() });
        all_pts
    } else {
        seg_pts
    };
    let (line, degenerate) = fit_line(pts);
    if degenerate {
        warnings.push(FitWarning::DegenerateFit { class, segment, points: pts.len() });
    }
    line
}

/// Fit one locality class from a ping-pong sweep: separate α/β per
/// protocol segment, plus typed warnings for any segment whose line was
/// underdetermined.
fn fit_class(class: &'static str, pts: &[(usize, f64)]) -> (ClassParams, Vec<FitWarning>) {
    let mut warnings = Vec::new();
    let eager_pts: Vec<(usize, f64)> =
        pts.iter().copied().filter(|(s, _)| *s < DEFAULT_EAGER_CUTOFF).collect();
    let rend_pts: Vec<(usize, f64)> =
        pts.iter().copied().filter(|(s, _)| *s >= DEFAULT_EAGER_CUTOFF).collect();
    let eager = fit_segment(class, "eager", &eager_pts, pts, &mut warnings);
    let rendezvous = fit_segment(class, "rendezvous", &rend_pts, pts, &mut warnings);
    (ClassParams { eager, rendezvous, eager_cutoff: DEFAULT_EAGER_CUTOFF }, warnings)
}

// ---------------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------------

/// Ping-pong worker entry, dispatched from `worker_main` on `--pingpong`.
/// Side 0 drives and measures; side 1 echoes every frame until the DONE
/// tag. Side 0's `CTL_OK` payload is `[size u64][half_rtt_nanos u64]` per
/// measured size.
pub fn pingpong_worker(args: &Args) -> i32 {
    match pingpong_inner(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("locag fit worker: {e}");
            1
        }
    }
}

fn pingpong_inner(args: &Args) -> std::result::Result<(), String> {
    let kind = args.get_str("pingpong", "shm");
    let side = args.get_usize("side", 0).map_err(|e| e.to_string())?;
    let dir = PathBuf::from(args.get_str("dir", ""));
    let reps = args.get_usize("reps", 50).map_err(|e| e.to_string())?.max(1);
    let deadline_ms = args.get_usize("deadline-ms", 30_000).map_err(|e| e.to_string())?;
    let dl = Deadline::after(Duration::from_millis(deadline_ms as u64));
    let sizes: Vec<usize> = args
        .get_str("sizes", "")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.parse().map_err(|_| format!("bad size '{s}'")))
        .collect::<std::result::Result<_, _>>()?;
    let max_size = sizes.iter().copied().max().unwrap_or(8);

    // The accepting side's listener must exist before HELLO so the
    // connecting side cannot race it after GO.
    let listener = if kind == "uds" && side == 1 {
        let l = UnixListener::bind(dir.join("pp.sock")).map_err(|e| e.to_string())?;
        l.set_nonblocking(true).map_err(|e| e.to_string())?;
        Some(l)
    } else {
        None
    };

    let ctl = connect_deadline(&dir.join("ctl.sock"), &dl)?;
    ctl_send(&ctl, CTL_HELLO, side as u64, &[], &dl)?;
    expect_ctl(&ctl, CTL_GO, &dl)?;

    let other = 1 - side;
    let mut chan = match kind.as_str() {
        "shm" => {
            let cap = ring_capacity(max_size + 16);
            let tx = ShmRing::open(&dir.join(format!("pp-{side}-{other}")), cap)?;
            let rx = ShmRing::open(&dir.join(format!("pp-{other}-{side}")), cap)?;
            PeerChan::Shm { tx, rx }
        }
        "uds" => {
            if side == 0 {
                PeerChan::Sock(connect_deadline(&dir.join("pp.sock"), &dl)?)
            } else {
                PeerChan::Sock(accept_deadline(listener.as_ref().unwrap(), &dl)?)
            }
        }
        other => return Err(format!("unknown pingpong channel kind '{other}'")),
    };

    ctl_send(&ctl, CTL_READY, side as u64, &[], &dl)?;
    expect_ctl(&ctl, CTL_START, &dl)?;

    if side == 1 {
        loop {
            let (tag, payload) = chan.recv_frame(&dl)?;
            if tag == DONE_TAG {
                break;
            }
            chan.send_frame(tag, &payload, &dl)?;
        }
        ctl_send(&ctl, CTL_OK, side as u64, &[], &dl)?;
        return Ok(());
    }

    // Channel pre-touch: one max-size round trip faults in every ring
    // page and grows socket buffers before anything is timed.
    let touch = vec![0u8; max_size];
    chan.send_frame(max_size as u64, &touch, &dl)?;
    chan.recv_frame(&dl)?;
    drop(touch);

    let mut out = Vec::with_capacity(sizes.len() * 16);
    for &s in &sizes {
        let msg = vec![0u8; s];
        for _ in 0..FIT_WARMUP_ROUNDS {
            chan.send_frame(s as u64, &msg, &dl)?;
            chan.recv_frame(&dl)?;
        }
        let mut best = u64::MAX;
        for _ in 0..reps_for_size(s, reps) {
            let t0 = Instant::now();
            chan.send_frame(s as u64, &msg, &dl)?;
            chan.recv_frame(&dl)?;
            best = best.min(t0.elapsed().as_nanos() as u64);
        }
        out.extend_from_slice(&(s as u64).to_le_bytes());
        out.extend_from_slice(&(best / 2).to_le_bytes());
    }
    chan.send_frame(DONE_TAG, &[], &dl)?;
    ctl_send(&ctl, CTL_OK, side as u64, &out, &dl)?;
    Ok(())
}

fn expect_ctl(ctl: &UnixStream, expect: u8, dl: &Deadline) -> std::result::Result<(), String> {
    let (ty, _, _) = ctl_recv(ctl, dl)?;
    if ty == expect {
        Ok(())
    } else {
        Err(format!("expected control frame {expect}, got {ty}"))
    }
}

// ---------------------------------------------------------------------------
// parent side
// ---------------------------------------------------------------------------

struct Reap2(Vec<Child>);

impl Drop for Reap2 {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

fn fit_err(what: impl Into<String>) -> Error {
    Error::Transport { rank: 0, round: 0, what: what.into() }
}

/// One ping-pong sweep over `kind` ("shm" or "uds"): spawn a measuring
/// and an echoing worker, return `(bytes, seconds)` one-way samples.
fn run_pingpong(
    kind: &str,
    sizes: &[usize],
    reps: usize,
    deadline: Duration,
) -> Result<Vec<(usize, f64)>> {
    let dir = super::pool::scratch_dir();
    std::fs::create_dir_all(&dir)?;
    let out = run_pingpong_in(&dir, kind, sizes, reps, deadline);
    let _ = std::fs::remove_dir_all(&dir);
    out
}

fn run_pingpong_in(
    dir: &Path,
    kind: &str,
    sizes: &[usize],
    reps: usize,
    deadline: Duration,
) -> Result<Vec<(usize, f64)>> {
    let dl = Deadline::after(deadline + Duration::from_secs(2));
    let listener = UnixListener::bind(dir.join("ctl.sock"))?;
    listener.set_nonblocking(true)?;
    let csv = sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(",");

    let exe = std::env::current_exe()?;
    let mut kids = Vec::new();
    for side in 0..2usize {
        let mut cmd = Command::new(&exe);
        cmd.arg("__worker")
            .arg("--pingpong")
            .arg(kind)
            .arg("--side")
            .arg(side.to_string())
            .arg("--dir")
            .arg(dir)
            .arg("--sizes")
            .arg(&csv)
            .arg("--reps")
            .arg(reps.to_string())
            .arg("--deadline-ms")
            .arg(deadline.as_millis().to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null());
        kids.push(cmd.spawn()?);
    }
    let mut reaper = Reap2(kids);

    let mut streams: [Option<UnixStream>; 2] = [None, None];
    let mut connected = 0;
    while connected < 2 {
        for (side, child) in reaper.0.iter_mut().enumerate() {
            if streams[side].is_none() {
                if let Ok(Some(status)) = child.try_wait() {
                    return Err(fit_err(format!(
                        "ping-pong worker {side} exited during setup ({status})"
                    )));
                }
            }
        }
        match listener.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false)?;
                let (ty, side, _) = ctl_recv(&s, &dl).map_err(fit_err)?;
                let side = side as usize;
                if ty != CTL_HELLO || side > 1 || streams[side].is_some() {
                    return Err(fit_err("bad ping-pong handshake"));
                }
                streams[side] = Some(s);
                connected += 1;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if dl.expired() {
                    return Err(fit_err("deadline exceeded waiting for ping-pong workers"));
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => return Err(e.into()),
        }
    }
    let streams: Vec<UnixStream> = streams.into_iter().map(Option::unwrap).collect();

    for s in &streams {
        ctl_send(s, CTL_GO, 0, &[], &dl).map_err(fit_err)?;
    }
    for s in &streams {
        match ctl_recv(s, &dl).map_err(fit_err)? {
            (CTL_READY, ..) => {}
            (ty, ..) => return Err(fit_err(format!("unexpected control frame {ty}"))),
        }
    }
    for s in &streams {
        ctl_send(s, CTL_START, 0, &[], &dl).map_err(fit_err)?;
    }

    let mut samples = Vec::with_capacity(sizes.len());
    for (side, s) in streams.iter().enumerate() {
        match ctl_recv(s, &dl).map_err(fit_err)? {
            (CTL_OK, _, payload) => {
                if side == 0 {
                    for pair in payload.chunks_exact(16) {
                        let size = u64::from_le_bytes(pair[..8].try_into().unwrap()) as usize;
                        let nanos = u64::from_le_bytes(pair[8..].try_into().unwrap());
                        samples.push((size, nanos as f64 / 1e9));
                    }
                }
            }
            (ty, ..) => return Err(fit_err(format!("unexpected control frame {ty}"))),
        }
    }
    if samples.len() != sizes.len() {
        return Err(fit_err(format!(
            "ping-pong returned {} samples for {} sizes",
            samples.len(),
            sizes.len()
        )));
    }
    Ok(samples)
}

/// Calibration report: the fitted machine and the raw sweeps behind it.
pub struct FitReport {
    pub machine: MachineParams,
    /// Shared-memory ring sweep: `(bytes, one-way seconds)`.
    pub shm: Vec<(usize, f64)>,
    /// Unix-domain socket sweep.
    pub uds: Vec<(usize, f64)>,
    /// Typed calibration warnings (thin or degenerate segments). The
    /// fitted machine is still usable; callers should print these.
    pub warnings: Vec<FitWarning>,
}

/// Run the full calibration: ping-pong both channel kinds, fit per-class
/// α/β, and return the machine. `quick` uses the reduced sweep.
///
/// Class mapping on a single host: intra-socket ← shm ring, inter-socket
/// ← Unix socket, inter-node ← Unix socket as well (no real network is
/// available; the JSON records this provenance).
pub fn run_fit(quick: bool, deadline: Duration) -> Result<FitReport> {
    let sizes: Vec<usize> = if quick { FIT_SIZES_QUICK.to_vec() } else { FIT_SIZES.to_vec() };
    let reps = if quick { 20 } else { 50 };
    let shm = run_pingpong("shm", &sizes, reps, deadline)?;
    let uds = run_pingpong("uds", &sizes, reps, deadline)?;
    let mut warnings = Vec::new();
    let (intra_socket, w) = fit_class("intra-socket", &shm);
    warnings.extend(w);
    let (inter_socket, w) = fit_class("inter-socket", &uds);
    warnings.extend(w);
    // inter-node reuses the socket fit verbatim, so repeating its
    // warnings under a third class name would only add noise.
    let machine =
        MachineParams { name: "fitted", intra_socket, inter_socket, inter_node: inter_socket };
    Ok(FitReport { machine, shm, uds, warnings })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_line_recovers_affine_relation() {
        let pts: Vec<(usize, f64)> =
            [8usize, 64, 512, 4096].iter().map(|&s| (s, 2e-6 + 3e-9 * s as f64)).collect();
        let (p, degenerate) = fit_line(&pts);
        assert!(!degenerate);
        assert!((p.alpha - 2e-6).abs() < 1e-9, "alpha {}", p.alpha);
        assert!((p.beta - 3e-9).abs() < 1e-12, "beta {}", p.beta);
    }

    #[test]
    fn fit_line_clamps_nonphysical_fits() {
        // Decreasing time with size would fit β < 0: clamp to the floor.
        let pts = vec![(8usize, 5e-6), (65536usize, 1e-6)];
        let (p, degenerate) = fit_line(&pts);
        assert!(!degenerate);
        assert!(p.alpha >= 1e-9 && p.beta >= 1e-13);
    }

    #[test]
    fn fit_line_flags_underdetermined_point_sets() {
        // Fewer than 2 points, or no size spread: the fit collapses to a
        // mean-α/zero-β line and must say so.
        let (_, d) = fit_line(&[(4096usize, 2e-6)]);
        assert!(d);
        let (_, d) = fit_line(&[(4096usize, 2e-6), (4096usize, 2.2e-6)]);
        assert!(d);
    }

    #[test]
    fn fit_class_splits_protocol_segments() {
        // Eager segment is steep, rendezvous is flat: the two fitted betas
        // must differ, and the cutoff must be the standard one.
        let mut pts = Vec::new();
        for s in [8usize, 512, 2048, 4096] {
            pts.push((s, 1e-6 + 5e-9 * s as f64));
        }
        for s in [8192usize, 65536, 262144] {
            pts.push((s, 4e-6 + 1e-10 * s as f64));
        }
        let (c, warnings) = fit_class("intra-socket", &pts);
        assert_eq!(c.eager_cutoff, DEFAULT_EAGER_CUTOFF);
        assert!(c.eager.beta > c.rendezvous.beta * 10.0);
        assert!(warnings.is_empty(), "clean sweep warned: {warnings:?}");
    }

    #[test]
    fn fit_class_warns_when_a_segment_is_thin() {
        // Only one point above the cutoff: rendezvous reuses the full fit
        // instead of producing a degenerate line, and the collapse is
        // reported as a typed warning rather than silent.
        let pts = vec![(8usize, 1e-6), (64usize, 1.1e-6), (512usize, 1.5e-6), (16384usize, 3e-6)];
        let (c, warnings) = fit_class("inter-socket", &pts);
        assert!(c.rendezvous.alpha > 0.0 && c.rendezvous.beta > 0.0);
        assert_eq!(
            warnings,
            vec![FitWarning::ThinSegment {
                class: "inter-socket",
                segment: "rendezvous",
                points: 1
            }]
        );
        let shown = warnings[0].to_string();
        assert!(shown.contains("inter-socket") && shown.contains("rendezvous"), "{shown}");
    }

    #[test]
    fn fit_class_warns_on_degenerate_segments() {
        // All points share one size: neither segment can determine a
        // line, and each collapse surfaces as a DegenerateFit.
        let pts = vec![(4096usize, 2e-6), (4096usize, 2.1e-6)];
        let (_, warnings) = fit_class("intra-socket", &pts);
        assert!(warnings.contains(&FitWarning::DegenerateFit {
            class: "intra-socket",
            segment: "eager",
            points: 2
        }));
        assert!(warnings.contains(&FitWarning::ThinSegment {
            class: "intra-socket",
            segment: "rendezvous",
            points: 0
        }));
    }

    #[test]
    fn sweep_sizes_cover_the_multi_mib_tail() {
        assert!(*FIT_SIZES.last().unwrap() >= 4 << 20);
        assert!(FIT_SIZES.windows(2).all(|w| w[0] < w[1]));
        // Both sweeps keep ≥2 points per protocol segment so no thin-
        // segment fallback fires on a healthy run.
        for sizes in [&FIT_SIZES[..], &FIT_SIZES_QUICK[..]] {
            assert!(sizes.iter().filter(|&&s| s < DEFAULT_EAGER_CUTOFF).count() >= 2);
            assert!(sizes.iter().filter(|&&s| s >= DEFAULT_EAGER_CUTOFF).count() >= 2);
        }
    }

    #[test]
    fn reps_scale_down_with_size_but_stay_bounded() {
        // Small messages run the full base count; the count never grows
        // with size and never drops below the floor of 3.
        assert_eq!(reps_for_size(8, 50), 50);
        assert_eq!(reps_for_size(16_384, 50), 50);
        assert_eq!(reps_for_size(4 << 20, 50), 3);
        let mut prev = usize::MAX;
        for &s in &FIT_SIZES {
            let r = reps_for_size(s, 50);
            assert!((3..=50).contains(&r), "reps {r} for size {s}");
            assert!(r <= prev, "reps not monotone at size {s}");
            prev = r;
        }
        // Degenerate bases stay within the clamp's contract.
        assert_eq!(reps_for_size(8, 0), 3);
        assert_eq!(reps_for_size(1 << 30, 1), 3);
    }
}
