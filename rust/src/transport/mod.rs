//! Multi-process transport backend: execute schedules over OS processes.
//!
//! The in-process backend ([`crate::comm`] + [`crate::sim`]) interprets a
//! [`Schedule`] over FIFO mailboxes on a *virtual* postal clock. This
//! module is the second interpreter backend: the same schedules run across
//! real OS processes, so wall-clock numbers reflect actual transport-cost
//! asymmetries instead of modeled ones.
//!
//! # Mapping to the paper's message classes
//!
//! The paper's cost model (Eq. 2) splits traffic into *local* messages —
//! within a region, charged `(α_ℓ, β_ℓ)` — and *non-local* messages across
//! regions, charged `(α, β)`. The process backend realizes that split
//! physically, keyed by the same two-level [`Topology`] the schedule
//! builders use:
//!
//! * **local** (intra-node by [`Topology::classify`]) — a pair of
//!   single-producer single-consumer **shared-memory rings**
//!   ([`chan::ShmRing`]) on `/dev/shm`, one per direction. This is the
//!   cheap channel: a memory copy plus polling, no kernel socket path.
//! * **non-local** (inter-node) — a **Unix-domain stream socket** per
//!   pair, standing in for the network link between nodes. On a single
//!   host this is the expensive channel class; `locag fit` measures just
//!   how much more expensive.
//!
//! The process→node mapping comes from [`Topology::coord`], so a schedule
//! built for `R×ppr` regions runs with `ppr` workers per "node" talking
//! over shm and only region leaders' traffic crossing sockets — exactly
//! the traffic split the locality-aware algorithms optimize.
//!
//! # Execution model: persistent pools (plan once, execute many)
//!
//! The backend honors the same persistent-plan contract as the in-process
//! layer (`MPI_Allgather_init`-style). A [`ProcPool`] owns the expensive
//! parts and pays them exactly once:
//!
//! 1. **spawn** — [`ProcPool::spawn`] forks one worker process per rank
//!    (re-executing the current binary with a hidden `__worker` argv — the
//!    `locag` CLI and the `proc_backend` test harness both dispatch it)
//!    and completes the full channel handshake: every shm ring and Unix
//!    socket of the rank mesh is connected before `spawn` returns.
//! 2. **load** — [`ProcPool::load`] ships a job description once; each
//!    worker rebuilds its own rank's [`Schedule`] from it (builders are
//!    pure SPMD functions of `(WorldView, rank, n, elem_bytes)`, so no IR
//!    crosses the wire) and preallocates input/output/scratch/wire
//!    buffers. Any number of schedules can be resident per pool, keyed by
//!    the returned schedule id.
//! 3. **execute ×N** — [`ProcPool::execute`] (and friends) runs a loaded
//!    schedule over the existing channels. Only input deltas and outputs
//!    cross the control path; the interpreter runs allocation-free over
//!    the persistent buffers. `ProcReport::wall` times this phase alone,
//!    so repeat executes measure the algorithm, not process startup.
//! 4. **shutdown** — [`ProcPool::shutdown`] (or drop) reaps the workers.
//!
//! [`run_proc`] wraps one spawn → load → execute → shutdown cycle for
//! single-shot callers like the conformance tests.
//!
//! Ragged collectives ship as [`ProcJob::SingleV`]: the job spec carries
//! the full per-rank `counts` vector (zeros allowed), every worker
//! rebuilds its own counts-aware schedule from it, and buffer sizes
//! follow the ragged contract — `counts[rank]` elements in and the total
//! out for allgatherv, the transpose for reduce-scatter-v — so the
//! pool validates input deltas per rank ([`ProcJob::io_bytes_rank`])
//! instead of against one uniform size.
//!
//! Workers interpret schedules step-for-step with the exact semantics of
//! the in-process executor (eager sends, FIFO matching per (source, tag),
//! identical pad-byte framing), which keeps outputs **bit-identical**
//! across backends; `tests/proc_backend.rs` asserts it over the
//! conformance grid and across repeated pool executes.
//!
//! Every blocking wait is bounded by [`ProcConfig::deadline`]; worker
//! death, socket EOF, shm-ring stalls, and stale schedule ids surface as
//! [`Error::Transport`](crate::error::Error::Transport) with the failing
//! rank and round instead of a hang. Failures that happen *between*
//! executes (a load rejected, an unknown schedule id) leave the pool
//! fully usable; failures *during* an execute leave channels in an
//! unknown state, so the pool fails fast afterwards and a fresh
//! [`ProcPool::spawn`] is the recovery path — nothing (scratch dirs,
//! children, sockets) is left behind to wedge it.
//!
//! # Calibration (`locag fit`)
//!
//! [`fit`] ping-pongs each channel class and least-squares-fits per-class
//! `(α, β)` pairs (eager and rendezvous segments split at the configured
//! cutoff), writing a params file that
//! [`MachineParams::by_name_or_path`](crate::model::params::MachineParams::by_name_or_path)
//! loads back for the cost model and the `model-tuned` dispatcher.

pub mod chan;
pub mod fit;
pub mod pool;
pub mod proc_exec;

pub use pool::{pool_median_wall, run_proc, PoolGate, PoolStats, ProcPool};
pub use proc_exec::worker_main;

use crate::collectives::fuse::FuseSpec;
use crate::collectives::plan::Summable;
use crate::collectives::schedule::{execute_schedule, SchedPlan, WorldView};
use crate::collectives::{model_tuned, Algorithm, ElemKind, OpKind, Schedule};
use crate::comm::datatype::{from_bytes, to_bytes};
use crate::comm::{Comm, CommWorld, Timing};
use crate::error::{Error, Result};
use crate::model::params::MachineParams;
use crate::topology::Topology;

/// Which interpreter executes a schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// In-process threads + virtual postal clock (the default).
    Sim,
    /// One OS process per rank over shm rings and localhost sockets.
    Proc,
}

impl Backend {
    /// Parse a CLI backend name.
    pub fn parse_or_err(s: &str) -> Result<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "sim" => Ok(Backend::Sim),
            "proc" => Ok(Backend::Proc),
            _ => Err(Error::Precondition(format!("unknown backend '{s}' (valid: sim, proc)"))),
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Sim => "sim",
            Backend::Proc => "proc",
        }
    }
}

/// Element type of a proc-backend job. Workers move raw bytes, so the
/// dtype only matters where arithmetic happens (`Reduce` steps) and for
/// sizing; both backends apply the same wrapping/IEEE semantics in the
/// same schedule order, which keeps outputs bit-identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit unsigned integers (wrapping sums).
    U32,
    /// 64-bit unsigned integers (wrapping sums).
    U64,
    /// 32-bit IEEE-754 floats.
    F32,
}

impl DType {
    /// Size of one element in bytes.
    pub fn bytes(&self) -> usize {
        match self {
            DType::U32 | DType::F32 => 4,
            DType::U64 => 8,
        }
    }

    /// Display name (also the wire spelling in pool job specs).
    pub fn name(&self) -> &'static str {
        match self {
            DType::U32 => "u32",
            DType::U64 => "u64",
            DType::F32 => "f32",
        }
    }

    /// Parse a dtype name.
    pub fn parse_or_err(s: &str) -> Result<DType> {
        match s.to_ascii_lowercase().as_str() {
            "u32" => Ok(DType::U32),
            "u64" => Ok(DType::U64),
            "f32" => Ok(DType::F32),
            _ => Err(Error::Precondition(format!("unknown dtype '{s}' (valid: u32, u64, f32)"))),
        }
    }

    /// The [`ElemKind`] this dtype maps to in the segmented-view
    /// execution layer.
    pub fn kind(&self) -> ElemKind {
        match self {
            DType::U32 => ElemKind::U32,
            DType::U64 => ElemKind::U64,
            DType::F32 => ElemKind::F32,
        }
    }

    /// The proc-backend dtype for a view-layer element kind. Errors for
    /// kinds the worker interpreter has no reduce arithmetic for.
    pub fn from_kind(kind: ElemKind) -> Result<DType> {
        match kind {
            ElemKind::U32 => Ok(DType::U32),
            ElemKind::U64 => Ok(DType::U64),
            ElemKind::F32 => Ok(DType::F32),
            other => Err(Error::Precondition(format!(
                "element kind {other} is not supported by the proc backend"
            ))),
        }
    }

    /// The integer dtype of a given element width — the implicit contract
    /// of [`ProcJob::Single`], which predates explicit dtypes.
    pub fn for_elem_bytes(elem_bytes: usize) -> Result<DType> {
        match elem_bytes {
            4 => Ok(DType::U32),
            8 => Ok(DType::U64),
            other => Err(Error::Precondition(format!(
                "unsupported element size {other} for the proc backend"
            ))),
        }
    }
}

/// One collective job for the process backend, rebuilt identically by
/// every worker from the pool's job spec.
#[derive(Debug, Clone)]
pub enum ProcJob {
    /// A single (operation, algorithm) collective.
    Single { op: OpKind, algo: String, n: usize, elem_bytes: usize },
    /// A single ragged collective (`allgatherv` / `reduce-scatter-v`) at
    /// explicit per-rank `counts` (zeros allowed). Unlike every other job
    /// kind, the per-rank input/output sizes differ — see
    /// [`ProcJob::io_bytes_rank`].
    SingleV { op: OpKind, algo: String, counts: Vec<usize>, elem_bytes: usize },
    /// A fused multi-collective plan at an explicit element type.
    Fused { specs: Vec<FuseSpec>, dtype: DType },
    /// A fused plan whose constituents carry **different** element types
    /// (e.g. an `f32` allgather fused with a `u64` allreduce). Workers run
    /// it byte-scaled through the segmented-view interpreter.
    FusedMixed { specs: Vec<(FuseSpec, DType)> },
}

impl ProcJob {
    /// A fused job at the sweep default dtype (`u64`, matching
    /// [`crate::collectives::plan_fused`]'s use in the sim sweeps).
    pub fn fused(specs: Vec<FuseSpec>) -> ProcJob {
        ProcJob::Fused { specs, dtype: DType::U64 }
    }

    /// Element size on the wire. Mixed jobs run byte-scaled schedules —
    /// there is no single element size, so the wire granularity is one
    /// byte.
    pub fn elem_bytes(&self) -> usize {
        match self {
            ProcJob::Single { elem_bytes, .. } | ProcJob::SingleV { elem_bytes, .. } => {
                *elem_bytes
            }
            ProcJob::Fused { dtype, .. } => dtype.bytes(),
            ProcJob::FusedMixed { .. } => 1,
        }
    }

    /// Rank 0's (input, output) buffer sizes in bytes for a `p`-rank
    /// world. Every rank agrees for the uniform job kinds; for ragged
    /// jobs (`SingleV`, fused ragged constituents) use
    /// [`ProcJob::io_bytes_rank`], which this delegates to.
    pub fn io_bytes(&self, p: usize) -> (usize, usize) {
        self.io_bytes_rank(0, p)
    }

    /// One rank's (input, output) buffer sizes in bytes — the contract
    /// the pool validates input deltas against before anything crosses
    /// the control path. Ragged jobs size each rank by its own count.
    pub fn io_bytes_rank(&self, rank: usize, p: usize) -> (usize, usize) {
        let eb = self.elem_bytes();
        match self {
            ProcJob::Single { op, n, .. } => {
                let (i, o) = op.io_elems(*n, p);
                (i * eb, o * eb)
            }
            ProcJob::SingleV { op, counts, .. } => {
                let total: usize = counts.iter().sum();
                let mine = counts.get(rank).copied().unwrap_or(0);
                match op {
                    OpKind::ReduceScatterV => (total * eb, mine * eb),
                    _ => (mine * eb, total * eb),
                }
            }
            ProcJob::Fused { specs, .. } => {
                let (mut i, mut o) = (0usize, 0usize);
                for s in specs {
                    let (si, so) = s.io_elems(rank, p);
                    i += si;
                    o += so;
                }
                (i * eb, o * eb)
            }
            ProcJob::FusedMixed { specs } => {
                let (mut i, mut o) = (0usize, 0usize);
                for (s, dt) in specs {
                    let (si, so) = s.io_elems(rank, p);
                    i += si * dt.bytes();
                    o += so * dt.bytes();
                }
                (i, o)
            }
        }
    }
}

/// Default per-direction shm ring capacity for pool workers. Rings are
/// mapped at spawn time — before any schedule exists — so the pool picks
/// a fixed capacity up front and `load` rejects a schedule whose largest
/// single-message frame could not make progress through it.
pub const DEFAULT_POOL_RING_BYTES: u64 = 8 << 20;

/// Knobs of a process-backend pool.
#[derive(Debug, Clone)]
pub struct ProcConfig {
    /// Bound on every blocking wait (worker and parent side). An operation
    /// that would hang instead fails with `Error::Transport` within
    /// roughly this much time.
    pub deadline: std::time::Duration,
    /// Test hook: kill this worker right after spawn, to exercise the
    /// death-detection paths.
    pub kill_rank: Option<usize>,
    /// Per-direction shm ring capacity in bytes, fixed at spawn.
    pub ring_bytes: u64,
}

impl Default for ProcConfig {
    fn default() -> ProcConfig {
        ProcConfig {
            deadline: std::time::Duration::from_secs(30),
            kill_rank: None,
            ring_bytes: DEFAULT_POOL_RING_BYTES,
        }
    }
}

/// Result of a successful process-backend run.
#[derive(Debug)]
pub struct ProcReport {
    /// Raw per-rank output bytes (native element encoding, constituents
    /// concatenated in spec order for fused jobs).
    pub outputs: Vec<Vec<u8>>,
    /// Max per-worker wall-clock seconds for the execute phase alone
    /// (process spawn and channel setup excluded).
    pub wall: f64,
}

/// Canonical per-rank input elements for `op` — the same generators the
/// conformance suites use, shared by both backends so their outputs are
/// directly comparable.
pub fn canonical_elems(op: OpKind, rank: usize, p: usize, n: usize) -> Vec<u64> {
    match op {
        OpKind::Allgather => (0..n).map(|j| (rank * 1_000_003 + j) as u64).collect(),
        OpKind::Allreduce => (0..n).map(|j| (rank * 131_071 + j) as u64).collect(),
        OpKind::Alltoall => (0..n * p)
            .map(|x| (rank * 1_000_003 + (x / n.max(1)) * 1_009) as u64 + (x % n.max(1)) as u64)
            .collect(),
        OpKind::ReduceScatter => (0..n * p).map(|j| (rank * 131_071 + j) as u64).collect(),
        // Uniform spelling of the ragged ops: `n` elements on every rank.
        OpKind::Allgatherv | OpKind::ReduceScatterV => {
            canonical_elems_v(op, rank, &vec![n; p])
        }
    }
}

/// Canonical per-rank input elements for the ragged ops at explicit
/// per-rank `counts` — the same generators the sim-side runners and the
/// ragged conformance suites use. Allgatherv inputs are this rank's
/// `counts[rank]`-element contribution; reduce-scatter-v inputs carry one
/// `counts[b]`-element block per destination `b`.
pub fn canonical_elems_v(op: OpKind, rank: usize, counts: &[usize]) -> Vec<u64> {
    match op {
        OpKind::Allgatherv => (0..counts.get(rank).copied().unwrap_or(0))
            .map(|j| (rank * 1_000_003 + j) as u64)
            .collect(),
        OpKind::ReduceScatterV => counts
            .iter()
            .enumerate()
            .flat_map(|(b, &c)| (0..c).map(move |j| (rank * 1_000_003 + b * 1_009 + j) as u64))
            .collect(),
        other => panic!("{other} is not a ragged operation"),
    }
}

/// `elems` encoded as native bytes at `dtype` (integer values are
/// truncated or cast into the element type; both conversions are
/// deterministic, so every backend derives identical bytes).
fn encode_dtype(elems: &[u64], dtype: DType) -> Vec<u8> {
    match dtype {
        DType::U32 => to_bytes(&elems.iter().map(|&v| v as u32).collect::<Vec<u32>>()),
        DType::U64 => to_bytes(elems),
        DType::F32 => to_bytes(&elems.iter().map(|&v| v as f32).collect::<Vec<f32>>()),
    }
}

/// [`canonical_elems`] encoded as native bytes at `dtype`.
pub fn canonical_input_bytes_dtype(
    op: OpKind,
    rank: usize,
    p: usize,
    n: usize,
    dtype: DType,
) -> Vec<u8> {
    encode_dtype(&canonical_elems(op, rank, p, n), dtype)
}

/// [`canonical_elems_v`] encoded at the integer dtype implied by
/// `elem_bytes` — the [`ProcJob::SingleV`] convention.
pub fn canonical_input_bytes_v(
    op: OpKind,
    rank: usize,
    counts: &[usize],
    elem_bytes: usize,
) -> Vec<u8> {
    let dtype = match elem_bytes {
        4 => DType::U32,
        8 => DType::U64,
        other => panic!("unsupported element size {other} for the proc backend"),
    };
    encode_dtype(&canonical_elems_v(op, rank, counts), dtype)
}

/// Canonical elements for one fused constituent: ragged specs use their
/// per-rank counts, uniform specs the flat generators.
fn canonical_fuse_elems(s: &FuseSpec, rank: usize, p: usize) -> Vec<u64> {
    match &s.counts {
        Some(c) => canonical_elems_v(s.op, rank, c.as_slice()),
        None => canonical_elems(s.op, rank, p, s.n),
    }
}

/// [`canonical_input_bytes_dtype`] at the integer dtype implied by
/// `elem_bytes` — the [`ProcJob::Single`] convention.
pub fn canonical_input_bytes(
    op: OpKind,
    rank: usize,
    p: usize,
    n: usize,
    elem_bytes: usize,
) -> Vec<u8> {
    let dtype = match elem_bytes {
        4 => DType::U32,
        8 => DType::U64,
        other => panic!("unsupported element size {other} for the proc backend"),
    };
    canonical_input_bytes_dtype(op, rank, p, n, dtype)
}

/// Canonical per-rank input bytes for a mixed fused job: each
/// constituent's [`canonical_input_bytes_dtype`] truncated to its input
/// window and concatenated in spec order — exactly the segment layout a
/// mixed [`crate::collectives::schedule::IoView`] exposes.
pub fn canonical_fused_mixed_input_bytes(
    specs: &[(FuseSpec, DType)],
    rank: usize,
    p: usize,
) -> Vec<u8> {
    let mut acc = Vec::new();
    for (s, dt) in specs {
        let (take, _) = s.io_elems(rank, p);
        let bytes = encode_dtype(&canonical_fuse_elems(s, rank, p), *dt);
        acc.extend_from_slice(&bytes[..take * dt.bytes()]);
    }
    acc
}

/// Build one rank's schedule for a (possibly model-tuned) algorithm name —
/// the single source of truth both backends plan through, so a worker
/// process and the in-process reference always interpret the same IR.
pub fn build_rank_schedule(
    op: OpKind,
    algo: &str,
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
    machine: &MachineParams,
) -> Result<Schedule> {
    // The ragged ops' uniform spelling: `n` elements on every rank.
    if matches!(op, OpKind::Allgatherv | OpKind::ReduceScatterV) {
        return build_rank_schedule_v(op, algo, view, rank, &vec![n; view.p], elem_bytes, machine);
    }
    if algo.eq_ignore_ascii_case("model-tuned") {
        let (_, mut scheds) = match op {
            OpKind::Allgather => model_tuned::pick_allgather(view, machine, n, elem_bytes)?,
            OpKind::Allreduce => model_tuned::pick_allreduce(view, machine, n, elem_bytes)?,
            OpKind::Alltoall => model_tuned::pick_alltoall(view, machine, n, elem_bytes)?,
            OpKind::ReduceScatter => {
                model_tuned::pick_reduce_scatter(view, machine, n, elem_bytes)?
            }
            OpKind::Allgatherv | OpKind::ReduceScatterV => unreachable!("handled above"),
        };
        return Ok(scheds.swap_remove(rank));
    }
    match op {
        OpKind::Allgather => {
            crate::collectives::schedule::build_allgather(
                Algorithm::parse_or_err(algo)?,
                view,
                rank,
                n,
                elem_bytes,
            )
        }
        OpKind::Allreduce => {
            crate::collectives::schedule::build_allreduce(algo, view, rank, n, elem_bytes)
        }
        OpKind::Alltoall => {
            crate::collectives::schedule::build_alltoall(algo, view, rank, n, elem_bytes)
        }
        OpKind::ReduceScatter => {
            crate::collectives::schedule::build_reduce_scatter(algo, view, rank, n, elem_bytes)
        }
        OpKind::Allgatherv | OpKind::ReduceScatterV => unreachable!("handled above"),
    }
}

/// [`build_rank_schedule`]'s ragged sibling: build one rank's schedule
/// for the counts-aware operations at explicit per-rank `counts`.
pub fn build_rank_schedule_v(
    op: OpKind,
    algo: &str,
    view: &WorldView,
    rank: usize,
    counts: &[usize],
    elem_bytes: usize,
    machine: &MachineParams,
) -> Result<Schedule> {
    if algo.eq_ignore_ascii_case("model-tuned") {
        let (_, mut scheds) = match op {
            OpKind::Allgatherv => {
                model_tuned::pick_allgatherv(view, machine, counts, elem_bytes)?
            }
            OpKind::ReduceScatterV => {
                model_tuned::pick_reduce_scatter_v(view, machine, counts, elem_bytes)?
            }
            other => {
                return Err(Error::Precondition(format!("{other} is not a ragged operation")))
            }
        };
        return Ok(scheds.swap_remove(rank));
    }
    match op {
        OpKind::Allgatherv => {
            crate::collectives::allgatherv::build_allgatherv(algo, view, rank, counts, elem_bytes)
        }
        OpKind::ReduceScatterV => crate::collectives::reduce_scatter_v::build_reduce_scatter_v(
            algo, view, rank, counts, elem_bytes,
        ),
        other => Err(Error::Precondition(format!("{other} is not a ragged operation"))),
    }
}

fn sim_single<T: Summable>(
    comm: &Comm,
    op: OpKind,
    algo: &str,
    n: usize,
    machine: &MachineParams,
    input_override: Option<&[u8]>,
) -> Result<Vec<u8>> {
    let rank = comm.rank();
    let p = comm.size();
    if n == 0 {
        return Ok(Vec::new());
    }
    let eb = std::mem::size_of::<T>();
    let view = WorldView::from_comm(comm);
    let sched = build_rank_schedule(op, algo, &view, rank, n, eb, machine)?;
    let input_bytes = match input_override {
        Some(b) => b.to_vec(),
        None => canonical_input_bytes(op, rank, p, n, eb),
    };
    let input: Vec<T> = from_bytes(&input_bytes)
        .ok_or_else(|| Error::Precondition("input bytes are not whole elements".into()))?;
    let (_, out_elems) = sched.io_lens();
    let mut output = vec![T::default(); out_elems];
    let mut plan = SchedPlan::<T>::new(comm, "proc-ref", sched)?;
    match op {
        OpKind::Allgather => {
            crate::collectives::plan::AllgatherPlan::execute(&mut plan, &input, &mut output)?
        }
        OpKind::Allreduce => {
            crate::collectives::plan::AllreducePlan::execute(&mut plan, &input, &mut output)?
        }
        OpKind::Alltoall => {
            crate::collectives::plan::AlltoallPlan::execute(&mut plan, &input, &mut output)?
        }
        OpKind::ReduceScatter => {
            crate::collectives::plan::ReduceScatterPlan::execute(&mut plan, &input, &mut output)?
        }
        OpKind::Allgatherv => {
            crate::collectives::plan::AllgathervPlan::execute(&mut plan, &input, &mut output)?
        }
        OpKind::ReduceScatterV => {
            crate::collectives::plan::ReduceScattervPlan::execute(&mut plan, &input, &mut output)?
        }
    }
    Ok(to_bytes(&output))
}

fn sim_single_v<T: Summable>(
    comm: &Comm,
    op: OpKind,
    algo: &str,
    counts: &[usize],
    machine: &MachineParams,
    input_override: Option<&[u8]>,
) -> Result<Vec<u8>> {
    let rank = comm.rank();
    let p = comm.size();
    if counts.len() != p {
        return Err(Error::Precondition(format!(
            "counts list {} ranks for a {p}-rank world",
            counts.len()
        )));
    }
    if counts.iter().all(|&c| c == 0) {
        // Ragged zero-length contract: no traffic, empty output.
        return Ok(Vec::new());
    }
    let eb = std::mem::size_of::<T>();
    let view = WorldView::from_comm(comm);
    let sched = build_rank_schedule_v(op, algo, &view, rank, counts, eb, machine)?;
    let input_bytes = match input_override {
        Some(b) => b.to_vec(),
        None => canonical_input_bytes_v(op, rank, counts, eb),
    };
    let input: Vec<T> = from_bytes(&input_bytes)
        .ok_or_else(|| Error::Precondition("input bytes are not whole elements".into()))?;
    let (_, out_elems) = sched.io_lens();
    let mut output = vec![T::default(); out_elems];
    let mut plan = SchedPlan::<T>::new(comm, "proc-ref", sched)?;
    match op {
        OpKind::Allgatherv => {
            crate::collectives::plan::AllgathervPlan::execute(&mut plan, &input, &mut output)?
        }
        OpKind::ReduceScatterV => {
            crate::collectives::plan::ReduceScattervPlan::execute(&mut plan, &input, &mut output)?
        }
        other => return Err(Error::Precondition(format!("{other} is not a ragged operation"))),
    }
    Ok(to_bytes(&output))
}

fn sim_fused<T: Summable>(
    comm: &Comm,
    specs: &[FuseSpec],
    machine: &MachineParams,
    conv: fn(u64) -> T,
    input_override: Option<&[u8]>,
) -> Result<Vec<u8>> {
    use crate::collectives::fuse;
    use crate::collectives::plan::PlanCore;
    use crate::collectives::schedule::add_assign;

    let rank = comm.rank();
    let p = comm.size();
    let eb = std::mem::size_of::<T>();
    let view = WorldView::from_comm(comm);
    let (mut scheds, _) = fuse::fuse_world(specs, &view, eb, machine)?;
    let sched = scheds.swap_remove(rank);
    sched.validate()?;
    let input: Vec<T> = match input_override {
        Some(b) => from_bytes(b)
            .ok_or_else(|| Error::Precondition("input bytes are not whole elements".into()))?,
        None => {
            let mut acc: Vec<T> = Vec::new();
            for s in specs {
                let elems = canonical_fuse_elems(s, rank, p);
                let (take, _) = s.io_elems(rank, p);
                acc.extend(elems[..take].iter().map(|&v| conv(v)));
            }
            acc
        }
    };
    let (in_elems, out_elems) = sched.io_lens();
    if input.len() != in_elems {
        return Err(Error::Precondition(format!(
            "fused input has {} elements, schedule expects {in_elems}",
            input.len()
        )));
    }
    let mut output = vec![T::default(); out_elems];
    let core = PlanCore::new(comm, sched.n, sched.tags);
    let mut scratch: Vec<Vec<T>> =
        sched.scratch.iter().map(|&l| vec![T::default(); l]).collect();
    let mut wire = vec![0u8; sched.max_padded_wire()];
    execute_schedule(
        &core,
        &sched,
        &input,
        &mut output,
        &mut scratch,
        &mut wire,
        Some(add_assign::<T>),
    )?;
    Ok(to_bytes(&output))
}

fn sim_fused_mixed(
    comm: &Comm,
    specs: &[(FuseSpec, DType)],
    machine: &MachineParams,
    input_override: Option<&[u8]>,
) -> Result<Vec<u8>> {
    use crate::collectives::fuse;
    use crate::collectives::plan::PlanCore;
    use crate::collectives::schedule::{execute_schedule_view, IoView, IoViewMut, ViewReduce};

    let rank = comm.rank();
    let p = comm.size();
    let view = WorldView::from_comm(comm);
    let kspecs: Vec<(FuseSpec, ElemKind)> =
        specs.iter().map(|(s, dt)| (s.clone(), dt.kind())).collect();
    let (mut scheds, _, mut kind_tables) = fuse::fuse_world_mixed(&kspecs, &view, machine)?;
    let sched = scheds.swap_remove(rank);
    let kinds = kind_tables.swap_remove(rank);
    sched.validate()?;
    let input_bytes = match input_override {
        Some(b) => b.to_vec(),
        None => canonical_fused_mixed_input_bytes(specs, rank, p),
    };
    // Segment the composite input/output per constituent, in spec order
    // (zero-length segments for n == 0 constituents are fine: they add no
    // bytes, matching the fused schedule's filtered io contract).
    let mut iv = IoView::new();
    let mut off = 0usize;
    for (s, dt) in specs {
        let (si, _) = s.io_elems(rank, p);
        let bytes = si * dt.bytes();
        if off + bytes > input_bytes.len() {
            return Err(Error::Precondition(format!(
                "mixed fused input has {} bytes, constituents expect at least {}",
                input_bytes.len(),
                off + bytes
            )));
        }
        iv.push_bytes(&input_bytes[off..off + bytes], dt.kind());
        off += bytes;
    }
    if off != input_bytes.len() {
        return Err(Error::Precondition(format!(
            "mixed fused input has {} bytes, constituents expect {off}",
            input_bytes.len()
        )));
    }
    let mut outs: Vec<Vec<u8>> = specs
        .iter()
        .map(|(s, dt)| {
            let (_, so) = s.io_elems(rank, p);
            vec![0u8; so * dt.bytes()]
        })
        .collect();
    let mut ov = IoViewMut::new();
    for ((_, dt), buf) in specs.iter().zip(outs.iter_mut()) {
        ov.push_bytes(buf, dt.kind());
    }
    let core = PlanCore::new(comm, sched.n, sched.tags);
    let mut scratch: Vec<Vec<u8>> = sched.scratch.iter().map(|&l| vec![0u8; l]).collect();
    let mut wire = vec![0u8; sched.max_padded_wire()];
    execute_schedule_view(
        &core,
        &sched,
        &iv,
        &mut ov,
        &mut scratch,
        &mut wire,
        &ViewReduce::PerScratch(&kinds),
    )?;
    drop(ov);
    Ok(outs.concat())
}

fn run_sim(
    regions: usize,
    ppr: usize,
    job: &ProcJob,
    machine: &MachineParams,
    inputs: Option<&[Vec<u8>]>,
) -> Result<Vec<Vec<u8>>> {
    let topo = Topology::regions(regions, ppr);
    if let Some(ins) = inputs {
        if ins.len() != topo.size() {
            return Err(Error::Precondition(format!(
                "got {} input buffers for a {}-rank world",
                ins.len(),
                topo.size()
            )));
        }
    }
    let run = CommWorld::run(&topo, Timing::Virtual(machine.clone()), |comm| {
        let inp = inputs.map(|v| v[comm.rank()].as_slice());
        match job {
            ProcJob::Single { op, algo, n, elem_bytes } => match elem_bytes {
                4 => sim_single::<u32>(comm, *op, algo, *n, machine, inp),
                8 => sim_single::<u64>(comm, *op, algo, *n, machine, inp),
                other => Err(Error::Precondition(format!(
                    "unsupported element size {other} for the proc backend"
                ))),
            },
            ProcJob::SingleV { op, algo, counts, elem_bytes } => match elem_bytes {
                4 => sim_single_v::<u32>(comm, *op, algo, counts, machine, inp),
                8 => sim_single_v::<u64>(comm, *op, algo, counts, machine, inp),
                other => Err(Error::Precondition(format!(
                    "unsupported element size {other} for the proc backend"
                ))),
            },
            ProcJob::Fused { specs, dtype } => match dtype {
                DType::U32 => sim_fused::<u32>(comm, specs, machine, |v| v as u32, inp),
                DType::U64 => sim_fused::<u64>(comm, specs, machine, |v| v, inp),
                DType::F32 => sim_fused::<f32>(comm, specs, machine, |v| v as f32, inp),
            },
            ProcJob::FusedMixed { specs } => sim_fused_mixed(comm, specs, machine, inp),
        }
    });
    run.results.into_iter().collect()
}

/// Run `job` on the in-process backend with the same canonical inputs the
/// process backend uses, returning raw per-rank output bytes. This is the
/// reference side of the cross-backend conformance check.
pub fn run_sim_bytes(
    regions: usize,
    ppr: usize,
    job: &ProcJob,
    machine: &MachineParams,
) -> Result<Vec<Vec<u8>>> {
    run_sim(regions, ppr, job, machine, None)
}

/// Like [`run_sim_bytes`] but with explicit per-rank input bytes instead
/// of the canonical generators — the reference side for pool tests that
/// mutate inputs between executes.
pub fn run_sim_bytes_with_inputs(
    regions: usize,
    ppr: usize,
    job: &ProcJob,
    machine: &MachineParams,
    inputs: &[Vec<u8>],
) -> Result<Vec<Vec<u8>>> {
    run_sim(regions, ppr, job, machine, Some(inputs))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parses_and_rejects() {
        assert_eq!(Backend::parse_or_err("sim").unwrap(), Backend::Sim);
        assert_eq!(Backend::parse_or_err("PROC").unwrap(), Backend::Proc);
        assert!(Backend::parse_or_err("mpi").is_err());
        assert_eq!(Backend::Proc.name(), "proc");
    }

    #[test]
    fn canonical_inputs_distinguish_ranks_and_truncate() {
        let a = canonical_elems(OpKind::Allgather, 0, 4, 3);
        let b = canonical_elems(OpKind::Allgather, 1, 4, 3);
        assert_ne!(a, b);
        let bytes4 = canonical_input_bytes(OpKind::Allreduce, 2, 4, 3, 4);
        let bytes8 = canonical_input_bytes(OpKind::Allreduce, 2, 4, 3, 8);
        assert_eq!(bytes4.len(), 12);
        assert_eq!(bytes8.len(), 24);
    }

    #[test]
    fn dtype_round_trips_and_sizes() {
        assert_eq!(DType::parse_or_err("F32").unwrap(), DType::F32);
        assert!(DType::parse_or_err("i8").is_err());
        assert_eq!(DType::U32.bytes(), 4);
        assert_eq!(DType::U64.bytes(), 8);
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::for_elem_bytes(4).unwrap(), DType::U32);
        assert_eq!(DType::for_elem_bytes(8).unwrap(), DType::U64);
        assert!(DType::for_elem_bytes(3).is_err());
        assert_eq!(DType::F32.name(), "f32");
    }

    #[test]
    fn job_io_bytes_follow_the_op_contract() {
        let single = ProcJob::Single {
            op: OpKind::ReduceScatter,
            algo: "ring".into(),
            n: 3,
            elem_bytes: 8,
        };
        assert_eq!(single.io_bytes(4), (3 * 4 * 8, 3 * 8));
        let fused = ProcJob::fused(vec![
            FuseSpec::new(OpKind::Allgather, "bruck", 2),
            FuseSpec::new(OpKind::Allreduce, "rabenseifner", 4),
        ]);
        assert_eq!(fused.elem_bytes(), 8);
        assert_eq!(fused.io_bytes(4), ((2 + 4) * 8, (2 * 4 + 4) * 8));
    }

    #[test]
    fn ragged_job_io_bytes_follow_the_counts() {
        let job = ProcJob::SingleV {
            op: OpKind::Allgatherv,
            algo: "ring".into(),
            counts: vec![3, 0, 2, 1],
            elem_bytes: 8,
        };
        assert_eq!(job.io_bytes_rank(0, 4), (3 * 8, 6 * 8));
        assert_eq!(job.io_bytes_rank(1, 4), (0, 6 * 8));
        assert_eq!(job.io_bytes(4), job.io_bytes_rank(0, 4));
        let rsv = ProcJob::SingleV {
            op: OpKind::ReduceScatterV,
            algo: "ring".into(),
            counts: vec![3, 0, 2, 1],
            elem_bytes: 4,
        };
        assert_eq!(rsv.io_bytes_rank(2, 4), (6 * 4, 2 * 4));
        assert_eq!(rsv.io_bytes_rank(1, 4), (6 * 4, 0));
    }

    #[test]
    fn sim_reference_runs_ragged_jobs() {
        let counts = vec![3usize, 0, 2, 1];
        let job = ProcJob::SingleV {
            op: OpKind::Allgatherv,
            algo: "loc-aware".into(),
            counts: counts.clone(),
            elem_bytes: 8,
        };
        let outs = run_sim_bytes(2, 2, &job, &MachineParams::lassen()).unwrap();
        let mut gathered: Vec<u64> = Vec::new();
        for r in 0..4 {
            gathered.extend(canonical_elems_v(OpKind::Allgatherv, r, &counts));
        }
        assert_eq!(gathered.len(), 6);
        for out in &outs {
            let got: Vec<u64> = from_bytes(out).unwrap();
            assert_eq!(got, gathered);
        }
        let job = ProcJob::SingleV {
            op: OpKind::ReduceScatterV,
            algo: "ring".into(),
            counts: counts.clone(),
            elem_bytes: 8,
        };
        let outs = run_sim_bytes(2, 2, &job, &MachineParams::lassen()).unwrap();
        for (rank, out) in outs.iter().enumerate() {
            let got: Vec<u64> = from_bytes(out).unwrap();
            let expected: Vec<u64> = (0..counts[rank])
                .map(|j| (0..4).map(|r| (r * 1_000_003 + rank * 1_009 + j) as u64).sum())
                .collect();
            assert_eq!(got, expected, "rank {rank}");
        }
    }

    #[test]
    fn build_rank_schedule_v_resolves_model_tuned_and_rejects_flat_ops() {
        let topo = Topology::regions(2, 4);
        let view = WorldView::world(&topo);
        let m = MachineParams::lassen();
        let counts: Vec<usize> = (0..8).map(|r| r % 3).collect();
        let s = build_rank_schedule_v(OpKind::Allgatherv, "model-tuned", &view, 0, &counts, 8, &m)
            .unwrap();
        assert_eq!(s.p, 8);
        assert!(s.validate().is_ok());
        let s =
            build_rank_schedule_v(OpKind::ReduceScatterV, "loc-aware", &view, 3, &counts, 8, &m)
                .unwrap();
        assert!(s.validate().is_ok());
        assert!(
            build_rank_schedule_v(OpKind::Allgather, "ring", &view, 0, &counts, 8, &m).is_err()
        );
        // The uniform entry point spells a ragged op as equal counts.
        let u = build_rank_schedule(OpKind::Allgatherv, "ring", &view, 0, 2, 8, &m).unwrap();
        assert_eq!(u.io_lens(), (2, 16));
    }

    #[test]
    fn mixed_job_io_bytes_sum_per_dtype() {
        let job = ProcJob::FusedMixed {
            specs: vec![
                (FuseSpec::new(OpKind::Allgather, "bruck", 2), DType::F32),
                (FuseSpec::new(OpKind::Allreduce, "loc-aware", 4), DType::U64),
            ],
        };
        assert_eq!(job.elem_bytes(), 1);
        assert_eq!(job.io_bytes(4), (2 * 4 + 4 * 8, 2 * 4 * 4 + 4 * 8));
    }

    #[test]
    fn sim_reference_runs_mixed_fused_jobs() {
        let p = 4;
        let specs = vec![
            (FuseSpec::new(OpKind::Allgather, "bruck", 2), DType::F32),
            (FuseSpec::new(OpKind::Allreduce, "loc-aware", 4), DType::U64),
        ];
        let job = ProcJob::FusedMixed { specs };
        let outs = run_sim_bytes(2, 2, &job, &MachineParams::lassen()).unwrap();
        let mut gath: Vec<f32> = Vec::new();
        for r in 0..p {
            gath.extend(canonical_elems(OpKind::Allgather, r, p, 2).iter().map(|&v| v as f32));
        }
        let mut red = vec![0u64; 4];
        for r in 0..p {
            for (j, v) in canonical_elems(OpKind::Allreduce, r, p, 4).iter().enumerate() {
                red[j] = red[j].wrapping_add(*v);
            }
        }
        let split = 2 * p * 4; // allgather output window in bytes
        for out in &outs {
            assert_eq!(out.len(), split + 4 * 8);
            let got_g: Vec<f32> = from_bytes(&out[..split]).unwrap();
            let got_r: Vec<u64> = from_bytes(&out[split..]).unwrap();
            assert_eq!(got_g, gath);
            assert_eq!(got_r, red);
        }
    }

    #[test]
    fn sim_inputs_override_is_reflected_in_outputs() {
        let job =
            ProcJob::Single { op: OpKind::Allgather, algo: "bruck".into(), n: 1, elem_bytes: 8 };
        let inputs: Vec<Vec<u8>> = (0..4u64).map(|r| to_bytes(&[900 + r])).collect();
        let outs =
            run_sim_bytes_with_inputs(2, 2, &job, &MachineParams::lassen(), &inputs).unwrap();
        let expected: Vec<u64> = (0..4).map(|r| 900 + r).collect();
        for out in &outs {
            let got: Vec<u64> = from_bytes(out).unwrap();
            assert_eq!(got, expected);
        }
        // A wrong world size is a precondition error, not a hang.
        let short = &inputs[..3];
        assert!(run_sim_bytes_with_inputs(2, 2, &job, &MachineParams::lassen(), short).is_err());
    }

    #[test]
    fn sim_reference_matches_direct_expected_allgather() {
        // The reference runner must agree with the canonical allgather
        // semantics: output = concatenation of every rank's contribution.
        let job =
            ProcJob::Single { op: OpKind::Allgather, algo: "bruck".into(), n: 2, elem_bytes: 8 };
        let outs = run_sim_bytes(2, 2, &job, &MachineParams::lassen()).unwrap();
        assert_eq!(outs.len(), 4);
        let mut expected: Vec<u64> = Vec::new();
        for r in 0..4 {
            expected.extend(canonical_elems(OpKind::Allgather, r, 4, 2));
        }
        for out in outs {
            let got: Vec<u64> = from_bytes(&out).unwrap();
            assert_eq!(got, expected);
        }
    }

    #[test]
    fn sim_reference_handles_zero_length() {
        let job =
            ProcJob::Single { op: OpKind::Alltoall, algo: "pairwise".into(), n: 0, elem_bytes: 8 };
        let outs = run_sim_bytes(2, 2, &job, &MachineParams::lassen()).unwrap();
        assert!(outs.iter().all(Vec::is_empty));
    }

    #[test]
    fn build_rank_schedule_resolves_model_tuned() {
        let topo = Topology::regions(2, 4);
        let view = WorldView::world(&topo);
        let m = MachineParams::lassen();
        let s =
            build_rank_schedule(OpKind::Allgather, "model-tuned", &view, 0, 4, 8, &m).unwrap();
        assert_eq!(s.p, 8);
        assert!(s.validate().is_ok());
        // Dispatch is deterministic given (view, machine, shape) — the
        // SPMD property workers rely on when they rebuild from argv.
        let again =
            build_rank_schedule(OpKind::Allgather, "model-tuned", &view, 0, 4, 8, &m).unwrap();
        assert_eq!(s.label, again.label);
        assert_eq!(s.num_steps(), again.num_steps());
    }
}
