//! Multi-process transport backend: execute schedules over OS processes.
//!
//! The in-process backend ([`crate::comm`] + [`crate::sim`]) interprets a
//! [`Schedule`] over FIFO mailboxes on a *virtual* postal clock. This
//! module is the second interpreter backend: the same schedules run across
//! real OS processes, so wall-clock numbers reflect actual transport-cost
//! asymmetries instead of modeled ones.
//!
//! # Mapping to the paper's message classes
//!
//! The paper's cost model (Eq. 2) splits traffic into *local* messages —
//! within a region, charged `(α_ℓ, β_ℓ)` — and *non-local* messages across
//! regions, charged `(α, β)`. The process backend realizes that split
//! physically, keyed by the same two-level [`Topology`] the schedule
//! builders use:
//!
//! * **local** (intra-node by [`Topology::classify`]) — a pair of
//!   single-producer single-consumer **shared-memory rings**
//!   ([`chan::ShmRing`]) on `/dev/shm`, one per direction. This is the
//!   cheap channel: a memory copy plus polling, no kernel socket path.
//! * **non-local** (inter-node) — a **Unix-domain stream socket** per
//!   pair, standing in for the network link between nodes. On a single
//!   host this is the expensive channel class; `locag fit` measures just
//!   how much more expensive.
//!
//! The process→node mapping comes from [`Topology::coord`], so a schedule
//! built for `R×ppr` regions runs with `ppr` workers per "node" talking
//! over shm and only region leaders' traffic crossing sockets — exactly
//! the traffic split the locality-aware algorithms optimize.
//!
//! # Execution model
//!
//! [`run_proc`] spawns one worker process per rank (re-executing the
//! current binary with a hidden `__worker` argv — the `locag` CLI and the
//! `proc_backend` test harness both dispatch it). Schedule builders are
//! pure functions of `(WorldView, rank, n, elem_bytes)`, so each worker
//! rebuilds its own rank's schedule from the job description instead of
//! deserializing IR, then interprets it step-for-step with the same
//! semantics as the in-process executor (eager sends, FIFO matching per
//! (source, tag), identical pad-byte framing). Outputs are therefore
//! **bit-identical** across backends; `tests/proc_backend.rs` asserts it
//! over the conformance grid.
//!
//! Every blocking wait is bounded by [`ProcConfig::deadline`]; worker
//! death, socket EOF and shm-ring stalls surface as
//! [`Error::Transport`](crate::error::Error::Transport) with the failing
//! rank and round instead of a hang.
//!
//! # Calibration (`locag fit`)
//!
//! [`fit`] ping-pongs each channel class and least-squares-fits per-class
//! `(α, β)` pairs (eager and rendezvous segments split at the configured
//! cutoff), writing a params file that
//! [`MachineParams::by_name_or_path`](crate::model::params::MachineParams::by_name_or_path)
//! loads back for the cost model and the `model-tuned` dispatcher.

pub mod chan;
pub mod fit;
pub mod proc_exec;

pub use proc_exec::{run_proc, worker_main};

use crate::collectives::fuse::FuseSpec;
use crate::collectives::plan::Summable;
use crate::collectives::schedule::{execute_schedule, SchedPlan, WorldView};
use crate::collectives::{model_tuned, Algorithm, OpKind, Schedule};
use crate::comm::datatype::{from_bytes, to_bytes};
use crate::comm::{Comm, CommWorld, Timing};
use crate::error::{Error, Result};
use crate::model::params::MachineParams;
use crate::topology::Topology;

/// Which interpreter executes a schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// In-process threads + virtual postal clock (the default).
    Sim,
    /// One OS process per rank over shm rings and localhost sockets.
    Proc,
}

impl Backend {
    /// Parse a CLI backend name.
    pub fn parse_or_err(s: &str) -> Result<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "sim" => Ok(Backend::Sim),
            "proc" => Ok(Backend::Proc),
            _ => Err(Error::Precondition(format!("unknown backend '{s}' (valid: sim, proc)"))),
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Sim => "sim",
            Backend::Proc => "proc",
        }
    }
}

/// One collective job for the process backend, rebuilt identically by
/// every worker from its argv.
#[derive(Debug, Clone)]
pub enum ProcJob {
    /// A single (operation, algorithm) collective.
    Single { op: OpKind, algo: String, n: usize, elem_bytes: usize },
    /// A fused multi-collective plan (always 8-byte elements, like
    /// [`crate::collectives::plan_fused`]'s `u64` use in the sim sweeps).
    Fused { specs: Vec<FuseSpec> },
}

impl ProcJob {
    /// Element size on the wire.
    pub fn elem_bytes(&self) -> usize {
        match self {
            ProcJob::Single { elem_bytes, .. } => *elem_bytes,
            ProcJob::Fused { .. } => 8,
        }
    }
}

/// Knobs of one process-backend run.
#[derive(Debug, Clone)]
pub struct ProcConfig {
    /// Bound on every blocking wait (worker and parent side). A run that
    /// would hang instead fails with `Error::Transport` within roughly
    /// this much time.
    pub deadline: std::time::Duration,
    /// Test hook: kill this worker right after launch coordination, to
    /// exercise the death-detection paths.
    pub kill_rank: Option<usize>,
}

impl Default for ProcConfig {
    fn default() -> ProcConfig {
        ProcConfig { deadline: std::time::Duration::from_secs(30), kill_rank: None }
    }
}

/// Result of a successful process-backend run.
#[derive(Debug)]
pub struct ProcReport {
    /// Raw per-rank output bytes (native element encoding, constituents
    /// concatenated in spec order for fused jobs).
    pub outputs: Vec<Vec<u8>>,
    /// Max per-worker wall-clock seconds for the execute phase alone
    /// (process spawn and channel setup excluded).
    pub wall: f64,
}

/// Canonical per-rank input elements for `op` — the same generators the
/// conformance suites use, shared by both backends so their outputs are
/// directly comparable.
pub fn canonical_elems(op: OpKind, rank: usize, p: usize, n: usize) -> Vec<u64> {
    match op {
        OpKind::Allgather => (0..n).map(|j| (rank * 1_000_003 + j) as u64).collect(),
        OpKind::Allreduce => (0..n).map(|j| (rank * 131_071 + j) as u64).collect(),
        OpKind::Alltoall => (0..n * p)
            .map(|x| (rank * 1_000_003 + (x / n.max(1)) * 1_009) as u64 + (x % n.max(1)) as u64)
            .collect(),
        OpKind::ReduceScatter => (0..n * p).map(|j| (rank * 131_071 + j) as u64).collect(),
    }
}

/// [`canonical_elems`] encoded as native bytes at `elem_bytes` per element
/// (values are truncated into narrower element types, identically on every
/// backend).
pub fn canonical_input_bytes(
    op: OpKind,
    rank: usize,
    p: usize,
    n: usize,
    elem_bytes: usize,
) -> Vec<u8> {
    let elems = canonical_elems(op, rank, p, n);
    match elem_bytes {
        4 => to_bytes(&elems.iter().map(|&v| v as u32).collect::<Vec<u32>>()),
        8 => to_bytes(&elems),
        other => panic!("unsupported element size {other} for the proc backend"),
    }
}

/// Build one rank's schedule for a (possibly model-tuned) algorithm name —
/// the single source of truth both backends plan through, so a worker
/// process and the in-process reference always interpret the same IR.
pub fn build_rank_schedule(
    op: OpKind,
    algo: &str,
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
    machine: &MachineParams,
) -> Result<Schedule> {
    if algo.eq_ignore_ascii_case("model-tuned") {
        let (_, mut scheds) = match op {
            OpKind::Allgather => model_tuned::pick_allgather(view, machine, n, elem_bytes)?,
            OpKind::Allreduce => model_tuned::pick_allreduce(view, machine, n, elem_bytes)?,
            OpKind::Alltoall => model_tuned::pick_alltoall(view, machine, n, elem_bytes)?,
            OpKind::ReduceScatter => {
                model_tuned::pick_reduce_scatter(view, machine, n, elem_bytes)?
            }
        };
        return Ok(scheds.swap_remove(rank));
    }
    match op {
        OpKind::Allgather => {
            crate::collectives::schedule::build_allgather(
                Algorithm::parse_or_err(algo)?,
                view,
                rank,
                n,
                elem_bytes,
            )
        }
        OpKind::Allreduce => {
            crate::collectives::schedule::build_allreduce(algo, view, rank, n, elem_bytes)
        }
        OpKind::Alltoall => {
            crate::collectives::schedule::build_alltoall(algo, view, rank, n, elem_bytes)
        }
        OpKind::ReduceScatter => {
            crate::collectives::schedule::build_reduce_scatter(algo, view, rank, n, elem_bytes)
        }
    }
}

fn sim_single<T: Summable>(
    comm: &Comm,
    op: OpKind,
    algo: &str,
    n: usize,
    machine: &MachineParams,
) -> Result<Vec<u8>> {
    let rank = comm.rank();
    let p = comm.size();
    if n == 0 {
        return Ok(Vec::new());
    }
    let eb = std::mem::size_of::<T>();
    let view = WorldView::from_comm(comm);
    let sched = build_rank_schedule(op, algo, &view, rank, n, eb, machine)?;
    let input_bytes = canonical_input_bytes(op, rank, p, n, eb);
    let input: Vec<T> = from_bytes(&input_bytes).expect("canonical input is whole elements");
    let (_, out_elems) = sched.io_lens();
    let mut output = vec![T::default(); out_elems];
    let mut plan = SchedPlan::<T>::new(comm, "proc-ref", sched)?;
    match op {
        OpKind::Allgather => {
            crate::collectives::plan::AllgatherPlan::execute(&mut plan, &input, &mut output)?
        }
        OpKind::Allreduce => {
            crate::collectives::plan::AllreducePlan::execute(&mut plan, &input, &mut output)?
        }
        OpKind::Alltoall => {
            crate::collectives::plan::AlltoallPlan::execute(&mut plan, &input, &mut output)?
        }
        OpKind::ReduceScatter => {
            crate::collectives::plan::ReduceScatterPlan::execute(&mut plan, &input, &mut output)?
        }
    }
    Ok(to_bytes(&output))
}

fn sim_fused(comm: &Comm, specs: &[FuseSpec], machine: &MachineParams) -> Result<Vec<u8>> {
    use crate::collectives::fuse;
    use crate::collectives::plan::PlanCore;
    use crate::collectives::schedule::add_assign;

    let rank = comm.rank();
    let p = comm.size();
    let view = WorldView::from_comm(comm);
    let (mut scheds, _) = fuse::fuse_world(specs, &view, 8, machine)?;
    let sched = scheds.swap_remove(rank);
    sched.validate()?;
    let mut input: Vec<u64> = Vec::new();
    for s in specs {
        let elems = canonical_elems(s.op, rank, p, s.n);
        let take = match s.op {
            OpKind::Allgather | OpKind::Allreduce => s.n,
            OpKind::Alltoall | OpKind::ReduceScatter => s.n * p,
        };
        input.extend_from_slice(&elems[..take]);
    }
    let (in_elems, out_elems) = sched.io_lens();
    debug_assert_eq!(input.len(), in_elems);
    let mut output = vec![0u64; out_elems];
    let core = PlanCore::new(comm, sched.n, sched.tags);
    let mut scratch: Vec<Vec<u64>> = sched.scratch.iter().map(|&l| vec![0u64; l]).collect();
    let mut wire = vec![0u8; sched.max_padded_wire()];
    execute_schedule(
        &core,
        &sched,
        &input,
        &mut output,
        &mut scratch,
        &mut wire,
        Some(add_assign::<u64>),
    )?;
    Ok(to_bytes(&output))
}

/// Run `job` on the in-process backend with the same canonical inputs the
/// process backend uses, returning raw per-rank output bytes. This is the
/// reference side of the cross-backend conformance check.
pub fn run_sim_bytes(
    regions: usize,
    ppr: usize,
    job: &ProcJob,
    machine: &MachineParams,
) -> Result<Vec<Vec<u8>>> {
    let topo = Topology::regions(regions, ppr);
    let run = CommWorld::run(&topo, Timing::Virtual(machine.clone()), |comm| match job {
        ProcJob::Single { op, algo, n, elem_bytes } => match elem_bytes {
            4 => sim_single::<u32>(comm, *op, algo, *n, machine),
            8 => sim_single::<u64>(comm, *op, algo, *n, machine),
            other => Err(Error::Precondition(format!(
                "unsupported element size {other} for the proc backend"
            ))),
        },
        ProcJob::Fused { specs } => sim_fused(comm, specs, machine),
    });
    run.results.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parses_and_rejects() {
        assert_eq!(Backend::parse_or_err("sim").unwrap(), Backend::Sim);
        assert_eq!(Backend::parse_or_err("PROC").unwrap(), Backend::Proc);
        assert!(Backend::parse_or_err("mpi").is_err());
        assert_eq!(Backend::Proc.name(), "proc");
    }

    #[test]
    fn canonical_inputs_distinguish_ranks_and_truncate() {
        let a = canonical_elems(OpKind::Allgather, 0, 4, 3);
        let b = canonical_elems(OpKind::Allgather, 1, 4, 3);
        assert_ne!(a, b);
        let bytes4 = canonical_input_bytes(OpKind::Allreduce, 2, 4, 3, 4);
        let bytes8 = canonical_input_bytes(OpKind::Allreduce, 2, 4, 3, 8);
        assert_eq!(bytes4.len(), 12);
        assert_eq!(bytes8.len(), 24);
    }

    #[test]
    fn sim_reference_matches_direct_expected_allgather() {
        // The reference runner must agree with the canonical allgather
        // semantics: output = concatenation of every rank's contribution.
        let job =
            ProcJob::Single { op: OpKind::Allgather, algo: "bruck".into(), n: 2, elem_bytes: 8 };
        let outs = run_sim_bytes(2, 2, &job, &MachineParams::lassen()).unwrap();
        assert_eq!(outs.len(), 4);
        let mut expected: Vec<u64> = Vec::new();
        for r in 0..4 {
            expected.extend(canonical_elems(OpKind::Allgather, r, 4, 2));
        }
        for out in outs {
            let got: Vec<u64> = from_bytes(&out).unwrap();
            assert_eq!(got, expected);
        }
    }

    #[test]
    fn sim_reference_handles_zero_length() {
        let job =
            ProcJob::Single { op: OpKind::Alltoall, algo: "pairwise".into(), n: 0, elem_bytes: 8 };
        let outs = run_sim_bytes(2, 2, &job, &MachineParams::lassen()).unwrap();
        assert!(outs.iter().all(Vec::is_empty));
    }

    #[test]
    fn build_rank_schedule_resolves_model_tuned() {
        let topo = Topology::regions(2, 4);
        let view = WorldView::world(&topo);
        let m = MachineParams::lassen();
        let s =
            build_rank_schedule(OpKind::Allgather, "model-tuned", &view, 0, 4, 8, &m).unwrap();
        assert_eq!(s.p, 8);
        assert!(s.validate().is_ok());
        // Dispatch is deterministic given (view, machine, shape) — the
        // SPMD property workers rely on when they rebuild from argv.
        let again =
            build_rank_schedule(OpKind::Allgather, "model-tuned", &view, 0, 4, 8, &m).unwrap();
        assert_eq!(s.label, again.label);
        assert_eq!(s.num_steps(), again.num_steps());
    }
}
