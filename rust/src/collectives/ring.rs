//! Ring allgather (§2, ref. [8]).
//!
//! `p − 1` steps; at step `i` each rank forwards the block it received in
//! step `i − 1` (initially its own block) to rank `id − 1 (mod p)` and
//! receives a new block from `id + 1 (mod p)`. Minimizes bandwidth cost
//! per link and keeps every message between neighbours, which is why MPI
//! implementations select it for large messages (§2).

use crate::comm::{Comm, Pod};
use crate::error::Result;

/// Ring allgather of `local` (length `n`); returns `n·p` elements in rank
/// order.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    let p = comm.size();
    let id = comm.rank();
    let n = local.len();
    let tag = comm.next_coll_tag();

    let mut out = vec![T::default(); n * p];
    out[id * n..(id + 1) * n].copy_from_slice(local);

    let left = (id + p - 1) % p;
    let right = (id + 1) % p;
    // Block travelling through this rank: at step s we hold the block of
    // rank (id + s) mod p and forward it left.
    for s in 0..p.saturating_sub(1) {
        let have = (id + s) % p;
        let _req = comm.isend(&out[have * n..(have + 1) * n], left, tag + s as u64)?;
        // receive straight into the destination block (perf pass)
        let recv_block = (id + s + 1) % p;
        let req = comm.irecv(right, tag + s as u64);
        req.wait_into(comm, &mut out[recv_block * n..(recv_block + 1) * n])?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    // Cross-rank behaviour is covered by rust/tests/collectives_correctness.rs;
    // here we only check the degenerate single-rank case compiles the fast
    // path (p = 1 → no communication).
    use super::*;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    #[test]
    fn single_rank_is_identity() {
        let topo = Topology::regions(1, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[42u64, 7]).unwrap()
        });
        assert_eq!(run.results[0], vec![42, 7]);
    }
}
