//! Ring allgather (§2, ref. [8]).
//!
//! `p − 1` steps; at step `i` each rank forwards the block it received in
//! step `i − 1` (initially its own block) to rank `id − 1 (mod p)` and
//! receives a new block from `id + 1 (mod p)`. Minimizes bandwidth cost
//! per link and keeps every message between neighbours, which is why MPI
//! implementations select it for large messages (§2).
//!
//! The persistent [`RingPlan`] needs no scratch at all: blocks stream
//! directly through the caller's output buffer.

use std::marker::PhantomData;

use super::plan::{
    check_io, trivial_plan, AllgatherPlan, CollectiveAlgorithm, CollectivePlan, NamedAlgorithm,
    PlanCore, Shape,
};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// The ring algorithm (registry entry).
pub struct Ring;

impl NamedAlgorithm for Ring {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn summary(&self) -> &'static str {
        "ring allgather: p-1 neighbour steps, bandwidth-optimal large-message baseline"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for Ring {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("ring", comm, shape) {
            return Ok(p);
        }
        Ok(Box::new(RingPlan::<T>::new(comm, shape.n)))
    }
}

/// Persistent ring plan: neighbours + tag block, zero scratch.
pub struct RingPlan<T: Pod> {
    core: PlanCore,
    left: usize,
    right: usize,
    _elem: PhantomData<T>,
}

impl<T: Pod> RingPlan<T> {
    /// Collectively plan a ring allgather of `n` elements per rank.
    /// Reserves one collective tag per step on `comm`.
    pub fn new(comm: &Comm, n: usize) -> RingPlan<T> {
        let p = comm.size();
        let id = comm.rank();
        RingPlan {
            core: PlanCore::new(comm, n, p.saturating_sub(1) as u64),
            left: (id + p - 1) % p,
            right: (id + 1) % p,
            _elem: PhantomData,
        }
    }
}

impl<T: Pod> CollectivePlan for RingPlan<T> {
    fn algorithm(&self) -> &'static str {
        "ring"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.core.n }
    }

    fn comm_size(&self) -> usize {
        self.core.p
    }
}

impl<T: Pod> AllgatherPlan<T> for RingPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        let core = &self.core;
        check_io(core.n, core.p, input, output)?;
        if core.n == 0 {
            return Ok(());
        }
        let (n, p, id) = (core.n, core.p, core.id);
        output[id * n..(id + 1) * n].copy_from_slice(input);
        // Block travelling through this rank: at step s we hold the block
        // of rank (id + s) mod p and forward it left.
        for s in 0..p.saturating_sub(1) {
            let tag = core.tag(s as u64);
            let have = (id + s) % p;
            let _send = core.comm.isend(&output[have * n..(have + 1) * n], self.left, tag)?;
            let recv_block = (id + s + 1) % p;
            let req = core.comm.irecv(self.right, tag);
            req.wait_into(&core.comm, &mut output[recv_block * n..(recv_block + 1) * n])?;
        }
        Ok(())
    }
}

/// One-shot convenience wrapper: plan + single execute.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&Ring, comm, local)
}

#[cfg(test)]
mod tests {
    // Cross-rank behaviour is covered by rust/tests/collectives_correctness.rs;
    // here we only check the degenerate single-rank case compiles the fast
    // path (p = 1 → no communication).
    use super::*;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    #[test]
    fn single_rank_is_identity() {
        let topo = Topology::regions(1, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[42u64, 7]).unwrap()
        });
        assert_eq!(run.results[0], vec![42, 7]);
    }
}
