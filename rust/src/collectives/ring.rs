//! Ring allgather (§2, ref. [8]) as a schedule builder.
//!
//! `p − 1` steps; at step `i` each rank forwards the block it received in
//! step `i − 1` (initially its own block) to rank `id − 1 (mod p)` and
//! receives a new block from `id + 1 (mod p)`. Minimizes bandwidth cost
//! per link and keeps every message between neighbours, which is why MPI
//! implementations select it for large messages (§2).
//!
//! The schedule needs no scratch at all: every
//! [`Step::SendRecv`](super::schedule::Step) streams blocks directly
//! through the caller's output buffer.

use super::plan::{
    trivial_plan, AllgatherPlan, CollectiveAlgorithm, NamedAlgorithm, OpKind, PlanSpec,
};
use super::schedule::{SchedPlan, Schedule, ScheduleBuilder, Slice};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// The ring algorithm (registry entry).
pub struct Ring;

impl NamedAlgorithm for Ring {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn summary(&self) -> &'static str {
        "ring allgather: p-1 neighbour steps, bandwidth-optimal large-message baseline"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for Ring {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("ring", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("ring")?;
        let sched = build_schedule(comm.size(), comm.rank(), n, std::mem::size_of::<T>());
        Ok(SchedPlan::<T>::boxed(comm, "ring", sched)?)
    }
}

/// Build the ring allgather schedule for one rank (pure; SPMD).
pub fn build_schedule(p: usize, rank: usize, n: usize, elem_bytes: usize) -> Schedule {
    let mut sb = ScheduleBuilder::new("ring");
    let left = (rank + p - 1) % p;
    let right = (rank + 1) % p;
    sb.copy(Slice::input(0, n), Slice::output(rank * n, n));
    // Block travelling through this rank: at step s we hold the block of
    // rank (rank + s) mod p and forward it left.
    for s in 0..p.saturating_sub(1) {
        let tag = sb.tag();
        let have = (rank + s) % p;
        let recv_block = (rank + s + 1) % p;
        sb.sendrecv(
            left,
            Slice::output(have * n, n),
            right,
            Slice::output(recv_block * n, n),
            tag,
            0,
        );
    }
    sb.finish(OpKind::Allgather, p, n, elem_bytes, "ring")
}

/// One-shot convenience wrapper: plan + single execute.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&Ring, comm, local)
}

#[cfg(test)]
mod tests {
    // Cross-rank behaviour is covered by rust/tests/collectives_correctness.rs;
    // here we only check the degenerate single-rank case compiles the fast
    // path (p = 1 → no communication).
    use super::*;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    #[test]
    fn single_rank_is_identity() {
        let topo = Topology::regions(1, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[42u64, 7]).unwrap()
        });
        assert_eq!(run.results[0], vec![42, 7]);
    }

    #[test]
    fn schedule_uses_no_scratch() {
        let sched = build_schedule(5, 2, 3, 8);
        assert!(sched.scratch.is_empty());
        assert_eq!(sched.tags, 4);
        sched.validate().unwrap();
    }
}
