//! Multi-lane allgather (related work, Träff & Hunold '20 [21]).
//!
//! Every rank participates in non-local communication: local rank `j`
//! (lane `j`) of each region runs an inter-region Bruck allgather over its
//! own `n` elements, so each lane carries `1/p_ℓ` of the region's data.
//! All inter-region steps finish before a final intra-region allgather of
//! the `r·n`-element lane results. Reduces non-local *bytes* per rank to
//! `≈ b/p_ℓ` like the locality-aware Bruck, but still needs `log2(r)`
//! non-local *messages* per rank (§2.2).

use super::grouping::{group_ranks, require_uniform, GroupBy};
use super::bruck;
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// The communicator ranks of lane `j`, sorted ascending (as `sub`
/// requires), each paired with the group it represents.
fn lane_order(groups: &super::grouping::Groups, j: usize) -> Vec<(usize, usize)> {
    let mut pairs: Vec<(usize, usize)> = groups
        .members
        .iter()
        .enumerate()
        .map(|(gi, g)| (g[j], gi))
        .collect();
    pairs.sort_unstable();
    pairs
}

/// Multi-lane allgather of `local` (length `n`); returns `n·p` elements in
/// communicator rank order.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    let groups = group_ranks(comm, GroupBy::Region)?;
    let ppr = require_uniform(&groups, "multi-lane allgather")?;
    let n = local.len();
    let p = comm.size();
    let r_n = groups.count();

    // Phase 1 (non-local): Bruck over this rank's lane. Under arbitrary
    // placement the lane's comm ranks need not be ascending by group, so
    // sort for `sub` and remember which group each lane position carries.
    let my_lane = lane_order(&groups, groups.my_local);
    let lane_ranks: Vec<usize> = my_lane.iter().map(|&(r, _)| r).collect();
    let lane = comm.sub(&lane_ranks)?;
    let lane_result = bruck::allgather(&lane, local)?; // r_n blocks in lane order

    // Phase 2 (local): allgather lane results within the region.
    let local_comm = comm.sub(&groups.members[groups.mine])?;
    let all_lanes = if ppr > 1 {
        bruck::allgather(&local_comm, &lane_result)?
    } else {
        lane_result
    };
    debug_assert_eq!(all_lanes.len(), p * n);

    // all_lanes layout: [local rank j][lane-j position k] -> contribution
    // of the rank at lane_order(j)[k]. Scatter into communicator rank
    // order using each lane's own ordering (global knowledge).
    let mut out = vec![T::default(); p * n];
    for j in 0..ppr {
        let order = lane_order(&groups, j);
        for (k, &(rank, _gi)) in order.iter().enumerate() {
            let src = (j * r_n + k) * n;
            let dst = rank * n;
            out[dst..dst + n].copy_from_slice(&all_lanes[src..src + n]);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{canonical_contribution, expected_result};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::{Placement, RegionKind, Topology};

    #[test]
    fn correct_on_example_2_1() {
        let topo = Topology::regions(4, 4);
        let expect = expected_result(16, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 2)).unwrap()
        });
        for r in run.results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn every_rank_sends_log2_regions_nonlocal() {
        let topo = Topology::regions(8, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        for t in &run.trace.per_rank {
            // log2(8 regions) = 3 non-local messages per rank
            assert_eq!(t.nonlocal_msgs, 3);
        }
    }

    #[test]
    fn nonlocal_bytes_are_one_lane_share() {
        let topo = Topology::regions(4, 4);
        let n_bytes = 8u64; // one u64 per rank
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        // bruck over 4 regions sends blocks of 1 then 2 elements = 3 * 8 B
        for t in &run.trace.per_rank {
            assert_eq!(t.nonlocal_bytes, 3 * n_bytes);
        }
    }

    #[test]
    fn correct_under_round_robin_placement() {
        let topo =
            Topology::machine(4, 1, 4, RegionKind::Node, Placement::RoundRobin).unwrap();
        let expect = expected_result(16, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        for r in run.results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn correct_under_random_placement() {
        for seed in [5u64, 17, 99] {
            let topo = Topology::machine(
                4,
                1,
                4,
                RegionKind::Node,
                Placement::Random { seed },
            )
            .unwrap();
            let expect = expected_result(16, 2);
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allgather(c, &canonical_contribution(c.rank(), 2)).unwrap()
            });
            for r in run.results {
                assert_eq!(r, expect, "seed {seed}");
            }
        }
    }
}
