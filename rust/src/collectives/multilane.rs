//! Multi-lane allgather (related work, Träff & Hunold '20 [21]).
//!
//! Every rank participates in non-local communication: local rank `j`
//! (lane `j`) of each region runs an inter-region Bruck allgather over its
//! own `n` elements, so each lane carries `1/p_ℓ` of the region's data.
//! All inter-region steps finish before a final intra-region allgather of
//! the `r·n`-element lane results. Reduces non-local *bytes* per rank to
//! `≈ b/p_ℓ` like the locality-aware Bruck, but still needs `log2(r)`
//! non-local *messages* per rank (§2.2).
//!
//! The persistent [`MultilanePlan`] retains the lane and region
//! communicators inside two nested Bruck plans and precomputes the final
//! lane-order → rank-order permutation.

use super::bruck::BruckPlan;
use super::grouping::{group_ranks, require_uniform, GroupBy};
use super::plan::{
    check_io, trivial_plan, AllgatherPlan, CollectiveAlgorithm, CollectivePlan, NamedAlgorithm,
    Shape,
};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// The multi-lane algorithm (registry entry).
pub struct Multilane;

impl NamedAlgorithm for Multilane {
    fn name(&self) -> &'static str {
        "multilane"
    }

    fn summary(&self) -> &'static str {
        "per-lane inter-region Bruck then local allgather (Träff & Hunold '20)"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for Multilane {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("multilane", comm, shape) {
            return Ok(p);
        }
        Ok(Box::new(MultilanePlan::<T>::new(comm, shape.n)?))
    }
}

/// The communicator ranks of lane `j`, sorted ascending (as `sub`
/// requires), each paired with the group it represents.
fn lane_order(groups: &super::grouping::Groups, j: usize) -> Vec<(usize, usize)> {
    let mut pairs: Vec<(usize, usize)> = groups
        .members
        .iter()
        .enumerate()
        .map(|(gi, g)| (g[j], gi))
        .collect();
    pairs.sort_unstable();
    pairs
}

/// Persistent multi-lane plan.
pub struct MultilanePlan<T: Pod> {
    n: usize,
    p: usize,
    r_n: usize,
    /// Phase 1: Bruck over this rank's lane communicator.
    lane_plan: BruckPlan<T>,
    /// Lane result scratch, length `r_n · n`.
    lane_result: Vec<T>,
    /// Phase 2: Bruck over the region communicator (absent when `ppr == 1`).
    local_plan: Option<BruckPlan<T>>,
    /// All-lane scratch, length `p · n` (only used with `local_plan`).
    all_lanes: Vec<T>,
    /// Lane-major position → communicator rank.
    perm: Vec<usize>,
}

impl<T: Pod> MultilanePlan<T> {
    /// Collectively plan a multi-lane allgather of `n` elements per rank.
    pub fn new(comm: &Comm, n: usize) -> Result<MultilanePlan<T>> {
        let groups = group_ranks(comm, GroupBy::Region)?;
        let ppr = require_uniform(&groups, "multi-lane allgather")?;
        let p = comm.size();
        let r_n = groups.count();

        // Phase 1 communicator: this rank's lane. Under arbitrary placement
        // the lane's comm ranks need not be ascending by group, so sort for
        // `sub`; the permutation below remembers which rank each lane
        // position carries.
        let my_lane = lane_order(&groups, groups.my_local);
        let lane_ranks: Vec<usize> = my_lane.iter().map(|&(r, _)| r).collect();
        let lane = comm.sub(&lane_ranks)?;
        let lane_plan = BruckPlan::<T>::new(&lane, n);

        let local_plan = if ppr > 1 {
            let local_comm = comm.sub(&groups.members[groups.mine])?;
            Some(BruckPlan::<T>::new(&local_comm, r_n * n))
        } else {
            None
        };

        // all_lanes layout: [local rank j][lane-j position k] -> the
        // contribution of the rank at lane_order(j)[k].
        let mut perm = Vec::with_capacity(p);
        for j in 0..ppr {
            for (rank, _gi) in lane_order(&groups, j) {
                perm.push(rank);
            }
        }
        Ok(MultilanePlan {
            n,
            p,
            r_n,
            lane_plan,
            lane_result: vec![T::default(); r_n * n],
            local_plan,
            all_lanes: if ppr > 1 { vec![T::default(); p * n] } else { Vec::new() },
            perm,
        })
    }
}

impl<T: Pod> CollectivePlan for MultilanePlan<T> {
    fn algorithm(&self) -> &'static str {
        "multilane"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.n }
    }

    fn comm_size(&self) -> usize {
        self.p
    }
}

impl<T: Pod> AllgatherPlan<T> for MultilanePlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_io(self.n, self.p, input, output)?;
        if self.n == 0 {
            return Ok(());
        }
        let n = self.n;
        debug_assert_eq!(self.lane_result.len(), self.r_n * n);
        self.lane_plan.execute(input, &mut self.lane_result)?;
        let src: &[T] = if let Some(lp) = &mut self.local_plan {
            lp.execute(&self.lane_result, &mut self.all_lanes)?;
            &self.all_lanes
        } else {
            &self.lane_result
        };
        for (pos, &rank) in self.perm.iter().enumerate() {
            output[rank * n..(rank + 1) * n].copy_from_slice(&src[pos * n..(pos + 1) * n]);
        }
        Ok(())
    }
}

/// One-shot convenience wrapper: plan + single execute.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&Multilane, comm, local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{canonical_contribution, expected_result};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::{Placement, RegionKind, Topology};

    #[test]
    fn correct_on_example_2_1() {
        let topo = Topology::regions(4, 4);
        let expect = expected_result(16, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 2)).unwrap()
        });
        for r in run.results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn every_rank_sends_log2_regions_nonlocal() {
        let topo = Topology::regions(8, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        for t in &run.trace.per_rank {
            // log2(8 regions) = 3 non-local messages per rank
            assert_eq!(t.nonlocal_msgs, 3);
        }
    }

    #[test]
    fn nonlocal_bytes_are_one_lane_share() {
        let topo = Topology::regions(4, 4);
        let n_bytes = 8u64; // one u64 per rank
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        // bruck over 4 regions sends blocks of 1 then 2 elements = 3 * 8 B
        for t in &run.trace.per_rank {
            assert_eq!(t.nonlocal_bytes, 3 * n_bytes);
        }
    }

    #[test]
    fn correct_under_round_robin_placement() {
        let topo =
            Topology::machine(4, 1, 4, RegionKind::Node, Placement::RoundRobin).unwrap();
        let expect = expected_result(16, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        for r in run.results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn correct_under_random_placement() {
        for seed in [5u64, 17, 99] {
            let topo = Topology::machine(
                4,
                1,
                4,
                RegionKind::Node,
                Placement::Random { seed },
            )
            .unwrap();
            let expect = expected_result(16, 2);
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allgather(c, &canonical_contribution(c.rank(), 2)).unwrap()
            });
            for r in run.results {
                assert_eq!(r, expect, "seed {seed}");
            }
        }
    }

    #[test]
    fn plan_reuse_stays_correct() {
        let topo = Topology::regions(4, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan = MultilanePlan::<u64>::new(c, 1).unwrap();
            let mut out = vec![0u64; 8];
            for round in 0..5u64 {
                plan.execute(&[c.rank() as u64 + 10 * round], &mut out).unwrap();
                let expect: Vec<u64> = (0..8u64).map(|r| r + 10 * round).collect();
                assert_eq!(out, expect, "round {round}");
            }
            true
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
