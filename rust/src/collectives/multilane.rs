//! Multi-lane allgather (related work, Träff & Hunold '20 [21]) as a
//! schedule builder.
//!
//! Every rank participates in non-local communication: local rank `j`
//! (lane `j`) of each region runs an inter-region Bruck allgather over its
//! own `n` elements, so each lane carries `1/p_ℓ` of the region's data.
//! All inter-region steps finish before a final intra-region allgather of
//! the `r·n`-element lane results. Reduces non-local *bytes* per rank to
//! `≈ b/p_ℓ` like the locality-aware Bruck, but still needs `log2(r)`
//! non-local *messages* per rank (§2.2).
//!
//! Both Bruck phases are inlined onto the parent communicator by
//! [`super::schedule::emit_group_bruck`]; the final lane-order →
//! rank-order permutation is a run of `CopyLocal` steps.

use super::grouping::GroupBy;
use super::plan::{
    trivial_plan, AllgatherPlan, CollectiveAlgorithm, NamedAlgorithm, OpKind, PlanSpec,
};
use super::schedule::{
    emit_group_bruck, locate, uniform_size, SchedPlan, Schedule, ScheduleBuilder, Slice, WorldView,
};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// The multi-lane algorithm (registry entry).
pub struct Multilane;

impl NamedAlgorithm for Multilane {
    fn name(&self) -> &'static str {
        "multilane"
    }

    fn summary(&self) -> &'static str {
        "per-lane inter-region Bruck then local allgather (Träff & Hunold '20)"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for Multilane {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("multilane", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("multilane")?;
        let view = WorldView::from_comm(comm);
        let sched = build_schedule(&view, comm.rank(), n, std::mem::size_of::<T>())?;
        Ok(SchedPlan::<T>::boxed(comm, "multilane", sched)?)
    }
}

/// The communicator ranks of lane `j`, sorted ascending (stable under any
/// placement), each paired with the group it represents.
fn lane_order(groups: &[Vec<usize>], j: usize) -> Vec<(usize, usize)> {
    let mut pairs: Vec<(usize, usize)> =
        groups.iter().enumerate().map(|(gi, g)| (g[j], gi)).collect();
    pairs.sort_unstable();
    pairs
}

/// Build the multi-lane allgather schedule for one rank (pure; SPMD).
pub fn build_schedule(
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    let groups = view.split(&(0..view.p).collect::<Vec<_>>(), GroupBy::Region);
    let ppr = uniform_size(&groups, "multi-lane allgather")?;
    let (g, l) = locate(&groups, rank)?;
    let p = view.p;
    let r_n = groups.len();

    let mut sb = ScheduleBuilder::new("lane bruck");
    // Phase 1: Bruck over this rank's lane (one rank per region).
    let lane_ranks: Vec<usize> = lane_order(&groups, l).into_iter().map(|(r, _)| r).collect();
    let lane_result = sb.scratch(r_n * n);
    emit_group_bruck(
        &mut sb,
        &lane_ranks,
        rank,
        n,
        Slice::input(0, n),
        Slice::at(lane_result, 0, r_n * n),
    );

    // Phase 2: local allgather of the lane results (absent when ppr == 1).
    let src = if ppr > 1 {
        sb.round("local allgather");
        let all_lanes = sb.scratch(p * n);
        emit_group_bruck(
            &mut sb,
            &groups[g],
            rank,
            r_n * n,
            Slice::at(lane_result, 0, r_n * n),
            Slice::at(all_lanes, 0, p * n),
        );
        all_lanes
    } else {
        lane_result
    };

    // Lane-major → communicator rank order.
    sb.round("reorder");
    let mut pos = 0usize;
    for j in 0..ppr {
        for (r, _gi) in lane_order(&groups, j) {
            sb.copy(Slice::at(src, pos * n, n), Slice::output(r * n, n));
            pos += 1;
        }
    }
    Ok(sb.finish(OpKind::Allgather, p, n, elem_bytes, "multilane"))
}

/// One-shot convenience wrapper: plan + single execute.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&Multilane, comm, local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{canonical_contribution, expected_result};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::{Placement, RegionKind, Topology};

    #[test]
    fn correct_on_example_2_1() {
        let topo = Topology::regions(4, 4);
        let expect = expected_result(16, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 2)).unwrap()
        });
        for r in run.results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn every_rank_sends_log2_regions_nonlocal() {
        let topo = Topology::regions(8, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        for t in &run.trace.per_rank {
            // log2(8 regions) = 3 non-local messages per rank
            assert_eq!(t.nonlocal_msgs, 3);
        }
    }

    #[test]
    fn nonlocal_bytes_are_one_lane_share() {
        let topo = Topology::regions(4, 4);
        let n_bytes = 8u64; // one u64 per rank
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        // bruck over 4 regions sends blocks of 1 then 2 elements = 3 * 8 B
        for t in &run.trace.per_rank {
            assert_eq!(t.nonlocal_bytes, 3 * n_bytes);
        }
    }

    #[test]
    fn correct_under_round_robin_placement() {
        let topo =
            Topology::machine(4, 1, 4, RegionKind::Node, Placement::RoundRobin).unwrap();
        let expect = expected_result(16, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        for r in run.results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn correct_under_random_placement() {
        for seed in [5u64, 17, 99] {
            let topo = Topology::machine(
                4,
                1,
                4,
                RegionKind::Node,
                Placement::Random { seed },
            )
            .unwrap();
            let expect = expected_result(16, 2);
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allgather(c, &canonical_contribution(c.rank(), 2)).unwrap()
            });
            for r in run.results {
                assert_eq!(r, expect, "seed {seed}");
            }
        }
    }

    #[test]
    fn plan_reuse_stays_correct() {
        use crate::collectives::plan::{Registry, Shape};
        let topo = Topology::regions(4, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan = Registry::<u64>::standard()
                .plan_uniform("multilane", c, Shape::elems(1))
                .unwrap();
            let mut out = vec![0u64; 8];
            for round in 0..5u64 {
                plan.execute(&[c.rank() as u64 + 10 * round], &mut out).unwrap();
                let expect: Vec<u64> = (0..8u64).map(|r| r + 10 * round).collect();
                assert_eq!(out, expect, "round {round}");
            }
            true
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
