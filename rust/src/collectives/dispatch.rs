//! The "system MPI" baseline: size/shape-based algorithm selection.
//!
//! Reimplements the selection logic of MPICH/MVAPICH2 (Thakur et al. [19]),
//! which is what the paper's black dotted "MPI" lines measure. For the
//! allgather:
//!
//! * total gathered size < [`LONG_MSG_SIZE`] (80 KiB) and power-of-two
//!   ranks → recursive doubling;
//! * total gathered size < [`LONG_MSG_SIZE`] and non-power-of-two → Bruck;
//! * total gathered size ≥ [`LONG_MSG_SIZE`] (the boundary itself is
//!   "large") → ring.
//!
//! For the alltoall (MPICH `MPIR_Alltoall_intra`):
//!
//! * per-destination block ≤ [`A2A_SHORT_MSG_SIZE`] (256 B, inclusive) →
//!   Bruck (log-step, forwarding);
//! * otherwise → pairwise exchange (one direct message per peer).
//!
//! The exact boundary behavior is pinned by unit tests against the
//! constants (`boundary_*` below), so these doc comments and `select`
//! cannot drift apart. Selection inputs (`p`, `n`, element size) are all
//! known at plan time, so the planned schedule *is* the selected
//! algorithm's schedule, reported under the `system-default` name (the
//! schedule label records the choice, e.g. `system-default[ring]`).
//!
//! The adaptive counterpart — scoring candidate schedules with the
//! IR-derived cost model instead of fixed thresholds — is
//! [`super::model_tuned`].

use super::plan::{
    trivial_a2a_plan, trivial_plan, AllgatherPlan, AlltoallAlgorithm, AlltoallPlan,
    CollectiveAlgorithm, NamedAlgorithm, PlanSpec,
};
use super::schedule::{build_allgather, build_alltoall, SchedPlan, WorldView};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// MPICH's `MPIR_CVAR_ALLGATHER_LONG_MSG_SIZE` default (bytes). Totals of
/// **at least** this size select the ring algorithm.
pub const LONG_MSG_SIZE: usize = 81920;

/// MPICH's `MPIR_CVAR_ALLTOALL_SHORT_MSG_SIZE` default (bytes): blocks
/// **up to and including** this size go through Bruck, larger through
/// pairwise exchange.
pub const A2A_SHORT_MSG_SIZE: usize = 256;

/// Which algorithm the dispatcher would choose for `p` ranks of `n`
/// elements of `elem_size` bytes.
pub fn select(p: usize, n: usize, elem_size: usize) -> super::Algorithm {
    let total = p * n * elem_size;
    if total < LONG_MSG_SIZE {
        if p.is_power_of_two() {
            super::Algorithm::RecursiveDoubling
        } else {
            super::Algorithm::Bruck
        }
    } else {
        super::Algorithm::Ring
    }
}

/// The system-default allgather selector (registry entry).
pub struct SystemDefault;

impl NamedAlgorithm for SystemDefault {
    fn name(&self) -> &'static str {
        "system-default"
    }

    fn summary(&self) -> &'static str {
        "MPICH-style auto-selection: recursive doubling / Bruck small, ring large"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for SystemDefault {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("system-default", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("system-default")?;
        let view = WorldView::from_comm(comm);
        let sched = build_allgather(
            super::Algorithm::SystemDefault,
            &view,
            comm.rank(),
            n,
            std::mem::size_of::<T>(),
        )?;
        Ok(SchedPlan::<T>::boxed(comm, "system-default", sched)?)
    }
}

/// One-shot convenience wrapper: select, plan, execute once.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&SystemDefault, comm, local)
}

/// True if the alltoall dispatcher would pick Bruck for blocks of `n`
/// elements of `elem_size` bytes (MPICH short-message rule, inclusive).
pub fn select_alltoall_bruck(n: usize, elem_size: usize) -> bool {
    n * elem_size <= A2A_SHORT_MSG_SIZE
}

/// The system-default alltoall selector (registry entry).
pub struct SystemDefaultAlltoall;

impl NamedAlgorithm for SystemDefaultAlltoall {
    fn name(&self) -> &'static str {
        "system-default"
    }

    fn summary(&self) -> &'static str {
        "MPICH-style auto-selection: Bruck for short blocks, pairwise for long"
    }
}

impl<T: Pod> AlltoallAlgorithm<T> for SystemDefaultAlltoall {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AlltoallPlan<T>>> {
        if let Some(p) = trivial_a2a_plan("system-default", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("system-default")?;
        let view = WorldView::from_comm(comm);
        let sched = build_alltoall(
            "system-default",
            &view,
            comm.rank(),
            n,
            std::mem::size_of::<T>(),
        )?;
        Ok(SchedPlan::<T>::boxed(comm, "system-default", sched)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Algorithm;

    #[test]
    fn selection_matches_mpich_rules() {
        // small, power of two
        assert_eq!(select(16, 2, 4), Algorithm::RecursiveDoubling);
        // small, non power of two
        assert_eq!(select(12, 2, 4), Algorithm::Bruck);
        // large
        assert_eq!(select(16, 4096, 8), Algorithm::Ring);
        // boundary: exactly LONG_MSG_SIZE is "large"
        assert_eq!(select(10, 1024, 8), Algorithm::Ring);
    }

    #[test]
    fn boundary_allgather_exactly_80kib_is_large() {
        // The constant itself is the first "large" total: doc comments and
        // select() are pinned together here.
        assert_eq!(LONG_MSG_SIZE, 80 * 1024);
        assert_eq!(select(1, LONG_MSG_SIZE, 1), Algorithm::Ring);
        assert_eq!(select(1, LONG_MSG_SIZE - 1, 1), Algorithm::RecursiveDoubling);
        // non-power-of-two rank count: one byte under the boundary → Bruck
        assert_eq!(select(5, (LONG_MSG_SIZE - 5) / 5, 1), Algorithm::Bruck);
        assert_eq!(select(5, LONG_MSG_SIZE / 5, 1), Algorithm::Ring);
        // and in element terms: 4-byte elements at exactly the boundary
        assert_eq!(select(16, LONG_MSG_SIZE / (16 * 4), 4), Algorithm::Ring);
        assert_eq!(
            select(16, LONG_MSG_SIZE / (16 * 4) - 1, 4),
            Algorithm::RecursiveDoubling
        );
    }

    #[test]
    fn boundary_alltoall_exactly_256b_is_short() {
        // 256 B inclusive → Bruck; 257 B → pairwise.
        assert_eq!(A2A_SHORT_MSG_SIZE, 256);
        assert!(select_alltoall_bruck(A2A_SHORT_MSG_SIZE, 1));
        assert!(!select_alltoall_bruck(A2A_SHORT_MSG_SIZE + 1, 1));
        assert!(select_alltoall_bruck(A2A_SHORT_MSG_SIZE / 4, 4));
        assert!(!select_alltoall_bruck(A2A_SHORT_MSG_SIZE / 4 + 1, 4));
        assert!(select_alltoall_bruck(A2A_SHORT_MSG_SIZE / 8, 8));
    }

    #[test]
    fn boundary_selection_is_visible_in_the_planned_schedule() {
        use crate::collectives::schedule::{build_allgather, build_alltoall, WorldView};
        use crate::topology::Topology;
        let topo = Topology::regions(2, 2);
        let view = WorldView::world(&topo);
        // u32 totals: 4 ranks × n × 4 B; boundary n = 5120.
        let at = build_allgather(Algorithm::SystemDefault, &view, 0, 5120, 4).unwrap();
        assert_eq!(at.label, "system-default[ring]");
        let under = build_allgather(Algorithm::SystemDefault, &view, 0, 5119, 4).unwrap();
        assert_eq!(under.label, "system-default[recursive-doubling]");
        // alltoall: 64 × 4 B = 256 B block → bruck; 65 → pairwise.
        let short = build_alltoall("system-default", &view, 0, 64, 4).unwrap();
        assert_eq!(short.label, "system-default[bruck]");
        let long = build_alltoall("system-default", &view, 0, 65, 4).unwrap();
        assert_eq!(long.label, "system-default[pairwise]");
    }

    #[test]
    fn dispatch_runs_selected_algorithm() {
        use crate::collectives::{canonical_contribution, expected_result};
        use crate::comm::{CommWorld, Timing};
        use crate::topology::Topology;
        // small power-of-two and non-power-of-two both produce correct output
        for (regions, ppr) in [(2usize, 2usize), (3, 2)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allgather(c, &canonical_contribution(c.rank(), 2)).unwrap()
            });
            for r in &run.results {
                assert_eq!(r, &expected_result(p, 2));
            }
        }
    }

    #[test]
    fn alltoall_dispatch_selects_and_runs() {
        use crate::collectives::plan::{AlltoallRegistry, Shape};
        use crate::comm::{CommWorld, Timing};
        use crate::topology::Topology;
        let topo = Topology::regions(2, 2);
        let p = topo.size();
        // one u64 block (8 B) → bruck; 64 u64 blocks (512 B) → pairwise —
        // both report the dispatcher's name and produce the exchange.
        for n in [1usize, 64] {
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                let r = AlltoallRegistry::<u64>::standard();
                let mut plan = r.plan_uniform("system-default", c, Shape::elems(n)).unwrap();
                assert_eq!(plan.algorithm(), "system-default");
                let send: Vec<u64> = (0..n * p).map(|x| (c.rank() * 10_000 + x) as u64).collect();
                let mut out = vec![0u64; n * p];
                plan.execute(&send, &mut out).unwrap();
                // block j of our output is rank j's block destined for us
                (0..p).all(|j| out[j * n] == (j * 10_000 + c.rank() * n) as u64)
            });
            assert!(run.results.iter().all(|&ok| ok), "n={n}");
        }
    }

    #[test]
    fn plan_reports_dispatcher_name() {
        use crate::comm::{CommWorld, Timing};
        use crate::topology::Topology;
        let topo = Topology::regions(2, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let plan = <SystemDefault as CollectiveAlgorithm<u32>>::plan(
                &SystemDefault,
                c,
                &PlanSpec::uniform(2, c.size()),
            )
            .unwrap();
            plan.algorithm() == "system-default"
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
