//! The "system MPI" baseline: size/shape-based algorithm selection.
//!
//! Reimplements the selection logic of MPICH/MVAPICH2 (Thakur et al. [19]),
//! which is what the paper's black dotted "MPI" lines measure. For the
//! allgather:
//!
//! * total gathered size < 80 KiB and power-of-two ranks → recursive doubling;
//! * total gathered size < 80 KiB and non-power-of-two → Bruck;
//! * otherwise → ring.
//!
//! For the alltoall (MPICH `MPIR_Alltoall_intra`):
//!
//! * per-destination block ≤ 256 bytes → Bruck (log-step, forwarding);
//! * otherwise → pairwise exchange (one direct message per peer).
//!
//! Selection inputs (`p`, `n`, element size) are all known at plan time, so
//! the persistent plan *is* the selected algorithm's plan, reported under
//! the `system-default` name.

use super::alltoall::{BruckAlltoallPlan, PairwiseAlltoallPlan};
use super::bruck::BruckPlan;
use super::plan::{
    trivial_a2a_plan, trivial_plan, AllgatherPlan, AlltoallAlgorithm, AlltoallPlan,
    CollectiveAlgorithm, NamedAlgorithm, SelectedPlan, Shape,
};
use super::recursive_doubling::RecursiveDoublingPlan;
use super::ring::RingPlan;
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// MPICH's `MPIR_CVAR_ALLGATHER_LONG_MSG_SIZE` default (bytes).
pub const LONG_MSG_SIZE: usize = 81920;

/// MPICH's `MPIR_CVAR_ALLTOALL_SHORT_MSG_SIZE` default (bytes): blocks up
/// to this size go through Bruck, larger through pairwise exchange.
pub const A2A_SHORT_MSG_SIZE: usize = 256;

/// Which algorithm the dispatcher would choose for `p` ranks of `n`
/// elements of `elem_size` bytes.
pub fn select(p: usize, n: usize, elem_size: usize) -> super::Algorithm {
    let total = p * n * elem_size;
    if total < LONG_MSG_SIZE {
        if p.is_power_of_two() {
            super::Algorithm::RecursiveDoubling
        } else {
            super::Algorithm::Bruck
        }
    } else {
        super::Algorithm::Ring
    }
}

/// The system-default allgather selector (registry entry).
pub struct SystemDefault;

impl NamedAlgorithm for SystemDefault {
    fn name(&self) -> &'static str {
        "system-default"
    }

    fn summary(&self) -> &'static str {
        "MPICH-style auto-selection: recursive doubling / Bruck small, ring large"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for SystemDefault {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("system-default", comm, shape) {
            return Ok(p);
        }
        let inner: Box<dyn AllgatherPlan<T>> =
            match select(comm.size(), shape.n, std::mem::size_of::<T>()) {
                super::Algorithm::RecursiveDoubling => {
                    Box::new(RecursiveDoublingPlan::<T>::new(comm, shape.n)?)
                }
                super::Algorithm::Bruck => Box::new(BruckPlan::<T>::new(comm, shape.n)),
                _ => Box::new(RingPlan::<T>::new(comm, shape.n)),
            };
        Ok(Box::new(SelectedPlan { name: "system-default", inner }))
    }
}

/// One-shot convenience wrapper: select, plan, execute once.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&SystemDefault, comm, local)
}

/// True if the alltoall dispatcher would pick Bruck for blocks of `n`
/// elements of `elem_size` bytes (MPICH short-message rule).
pub fn select_alltoall_bruck(n: usize, elem_size: usize) -> bool {
    n * elem_size <= A2A_SHORT_MSG_SIZE
}

/// The system-default alltoall selector (registry entry).
pub struct SystemDefaultAlltoall;

impl NamedAlgorithm for SystemDefaultAlltoall {
    fn name(&self) -> &'static str {
        "system-default"
    }

    fn summary(&self) -> &'static str {
        "MPICH-style auto-selection: Bruck for short blocks, pairwise for long"
    }
}

impl<T: Pod> AlltoallAlgorithm<T> for SystemDefaultAlltoall {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AlltoallPlan<T>>> {
        if let Some(p) = trivial_a2a_plan("system-default", comm, shape) {
            return Ok(p);
        }
        let inner: Box<dyn AlltoallPlan<T>> =
            if select_alltoall_bruck(shape.n, std::mem::size_of::<T>()) {
                Box::new(BruckAlltoallPlan::<T>::new(comm, shape.n))
            } else {
                Box::new(PairwiseAlltoallPlan::<T>::new(comm, shape.n))
            };
        Ok(Box::new(SelectedPlan { name: "system-default", inner }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Algorithm;

    #[test]
    fn selection_matches_mpich_rules() {
        // small, power of two
        assert_eq!(select(16, 2, 4), Algorithm::RecursiveDoubling);
        // small, non power of two
        assert_eq!(select(12, 2, 4), Algorithm::Bruck);
        // large
        assert_eq!(select(16, 4096, 8), Algorithm::Ring);
        // boundary: exactly LONG_MSG_SIZE is "large"
        assert_eq!(select(10, 1024, 8), Algorithm::Ring);
    }

    #[test]
    fn dispatch_runs_selected_algorithm() {
        use crate::collectives::{canonical_contribution, expected_result};
        use crate::comm::{CommWorld, Timing};
        use crate::topology::Topology;
        // small power-of-two and non-power-of-two both produce correct output
        for (regions, ppr) in [(2usize, 2usize), (3, 2)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allgather(c, &canonical_contribution(c.rank(), 2)).unwrap()
            });
            for r in &run.results {
                assert_eq!(r, &expected_result(p, 2));
            }
        }
    }

    #[test]
    fn alltoall_selection_matches_mpich_rule() {
        assert!(select_alltoall_bruck(2, 4)); // 8 B block → bruck
        assert!(select_alltoall_bruck(64, 4)); // 256 B boundary is short
        assert!(!select_alltoall_bruck(65, 4)); // 260 B → pairwise
    }

    #[test]
    fn alltoall_dispatch_selects_and_runs() {
        use crate::collectives::plan::AlltoallRegistry;
        use crate::comm::{CommWorld, Timing};
        use crate::topology::Topology;
        let topo = Topology::regions(2, 2);
        let p = topo.size();
        // one u64 block (8 B) → bruck; 64 u64 blocks (512 B) → pairwise —
        // both report the dispatcher's name and produce the exchange.
        for n in [1usize, 64] {
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                let r = AlltoallRegistry::<u64>::standard();
                let mut plan = r.plan("system-default", c, Shape::elems(n)).unwrap();
                assert_eq!(plan.algorithm(), "system-default");
                let send: Vec<u64> = (0..n * p).map(|x| (c.rank() * 10_000 + x) as u64).collect();
                let mut out = vec![0u64; n * p];
                plan.execute(&send, &mut out).unwrap();
                // block j of our output is rank j's block destined for us
                (0..p).all(|j| out[j * n] == (j * 10_000 + c.rank() * n) as u64)
            });
            assert!(run.results.iter().all(|&ok| ok), "n={n}");
        }
    }

    #[test]
    fn plan_reports_dispatcher_name() {
        use crate::comm::{CommWorld, Timing};
        use crate::topology::Topology;
        let topo = Topology::regions(2, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let plan = <SystemDefault as CollectiveAlgorithm<u32>>::plan(
                &SystemDefault,
                c,
                Shape::elems(2),
            )
            .unwrap();
            plan.algorithm() == "system-default"
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
