//! The "system MPI" baseline: size/shape-based algorithm selection.
//!
//! Reimplements the selection logic of MPICH/MVAPICH2 (Thakur et al. [19]),
//! which is what the paper's black dotted "MPI" lines measure:
//!
//! * total gathered size < 80 KiB and power-of-two ranks → recursive doubling;
//! * total gathered size < 80 KiB and non-power-of-two → Bruck;
//! * otherwise → ring.

use super::{bruck, recursive_doubling, ring};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// MPICH's `MPIR_CVAR_ALLGATHER_LONG_MSG_SIZE` default (bytes).
pub const LONG_MSG_SIZE: usize = 81920;

/// Which algorithm the dispatcher would choose for `p` ranks of `n`
/// elements of `elem_size` bytes.
pub fn select(p: usize, n: usize, elem_size: usize) -> super::Algorithm {
    let total = p * n * elem_size;
    if total < LONG_MSG_SIZE {
        if p.is_power_of_two() {
            super::Algorithm::RecursiveDoubling
        } else {
            super::Algorithm::Bruck
        }
    } else {
        super::Algorithm::Ring
    }
}

/// System-default allgather: select and run.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    match select(comm.size(), local.len(), std::mem::size_of::<T>()) {
        super::Algorithm::RecursiveDoubling => recursive_doubling::allgather(comm, local),
        super::Algorithm::Bruck => bruck::allgather(comm, local),
        _ => ring::allgather(comm, local),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Algorithm;

    #[test]
    fn selection_matches_mpich_rules() {
        // small, power of two
        assert_eq!(select(16, 2, 4), Algorithm::RecursiveDoubling);
        // small, non power of two
        assert_eq!(select(12, 2, 4), Algorithm::Bruck);
        // large
        assert_eq!(select(16, 4096, 8), Algorithm::Ring);
        // boundary: exactly LONG_MSG_SIZE is "large"
        assert_eq!(select(10, 1024, 8), Algorithm::Ring);
    }

    #[test]
    fn dispatch_runs_selected_algorithm() {
        use crate::collectives::{canonical_contribution, expected_result};
        use crate::comm::{CommWorld, Timing};
        use crate::topology::Topology;
        // small power-of-two and non-power-of-two both produce correct output
        for (regions, ppr) in [(2usize, 2usize), (3, 2)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allgather(c, &canonical_contribution(c.rank(), 2)).unwrap()
            });
            for r in &run.results {
                assert_eq!(r, &expected_result(p, 2));
            }
        }
    }
}
