//! The "system MPI" baseline: size/shape-based algorithm selection.
//!
//! Reimplements the selection logic of MPICH/MVAPICH2 (Thakur et al. [19]),
//! which is what the paper's black dotted "MPI" lines measure:
//!
//! * total gathered size < 80 KiB and power-of-two ranks → recursive doubling;
//! * total gathered size < 80 KiB and non-power-of-two → Bruck;
//! * otherwise → ring.
//!
//! Selection inputs (`p`, `n`, element size) are all known at plan time, so
//! the persistent plan *is* the selected algorithm's plan, reported under
//! the `system-default` name.

use super::bruck::BruckPlan;
use super::plan::{trivial_plan, AllgatherPlan, CollectiveAlgorithm, SelectedPlan, Shape};
use super::recursive_doubling::RecursiveDoublingPlan;
use super::ring::RingPlan;
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// MPICH's `MPIR_CVAR_ALLGATHER_LONG_MSG_SIZE` default (bytes).
pub const LONG_MSG_SIZE: usize = 81920;

/// Which algorithm the dispatcher would choose for `p` ranks of `n`
/// elements of `elem_size` bytes.
pub fn select(p: usize, n: usize, elem_size: usize) -> super::Algorithm {
    let total = p * n * elem_size;
    if total < LONG_MSG_SIZE {
        if p.is_power_of_two() {
            super::Algorithm::RecursiveDoubling
        } else {
            super::Algorithm::Bruck
        }
    } else {
        super::Algorithm::Ring
    }
}

/// The system-default selector (registry entry).
pub struct SystemDefault;

impl<T: Pod> CollectiveAlgorithm<T> for SystemDefault {
    fn name(&self) -> &'static str {
        "system-default"
    }

    fn summary(&self) -> &'static str {
        "MPICH-style auto-selection: recursive doubling / Bruck small, ring large"
    }

    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("system-default", comm, shape) {
            return Ok(p);
        }
        let inner: Box<dyn AllgatherPlan<T>> =
            match select(comm.size(), shape.n, std::mem::size_of::<T>()) {
                super::Algorithm::RecursiveDoubling => {
                    Box::new(RecursiveDoublingPlan::<T>::new(comm, shape.n)?)
                }
                super::Algorithm::Bruck => Box::new(BruckPlan::<T>::new(comm, shape.n)),
                _ => Box::new(RingPlan::<T>::new(comm, shape.n)),
            };
        Ok(Box::new(SelectedPlan { name: "system-default", inner }))
    }
}

/// One-shot convenience wrapper: select, plan, execute once.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&SystemDefault, comm, local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Algorithm;

    #[test]
    fn selection_matches_mpich_rules() {
        // small, power of two
        assert_eq!(select(16, 2, 4), Algorithm::RecursiveDoubling);
        // small, non power of two
        assert_eq!(select(12, 2, 4), Algorithm::Bruck);
        // large
        assert_eq!(select(16, 4096, 8), Algorithm::Ring);
        // boundary: exactly LONG_MSG_SIZE is "large"
        assert_eq!(select(10, 1024, 8), Algorithm::Ring);
    }

    #[test]
    fn dispatch_runs_selected_algorithm() {
        use crate::collectives::{canonical_contribution, expected_result};
        use crate::comm::{CommWorld, Timing};
        use crate::topology::Topology;
        // small power-of-two and non-power-of-two both produce correct output
        for (regions, ppr) in [(2usize, 2usize), (3, 2)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allgather(c, &canonical_contribution(c.rank(), 2)).unwrap()
            });
            for r in &run.results {
                assert_eq!(r, &expected_result(p, 2));
            }
        }
    }

    #[test]
    fn plan_reports_dispatcher_name() {
        use crate::comm::{CommWorld, Timing};
        use crate::topology::Topology;
        let topo = Topology::regions(2, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let plan = <SystemDefault as CollectiveAlgorithm<u32>>::plan(
                &SystemDefault,
                c,
                Shape::elems(2),
            )
            .unwrap();
            plan.algorithm() == "system-default"
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
