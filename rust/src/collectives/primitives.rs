//! Collective building blocks: gather, broadcast and allgatherv.
//!
//! These are the substrates the related-work baselines are built from
//! (hierarchical = gather + Bruck + bcast) and that the non-power region
//! extension of the locality-aware Bruck needs (allgatherv for steps where
//! some local ranks hold no new data — paper §3).
//!
//! [`AllgathervPlan`] is the standalone persistent allgatherv; the planned
//! collectives themselves now emit the equivalent structure as schedule
//! steps ([`crate::collectives::schedule::emit_group_allgatherv`]) — this
//! module remains the one-shot/utility API (gather, bcast, allgatherv)
//! and the home of [`bcast_tree`], which the hierarchical schedule builder
//! reuses.

use crate::comm::{Comm, Pod};
use crate::error::{Error, Result};

/// Flat gather of equal-size contributions to `root`. Returns the
/// concatenated data (rank order) on the root, `None` elsewhere.
///
/// A flat (non-tree) gather is used deliberately: it matches the
/// master-serialization behaviour the paper ascribes to hierarchical
/// approaches ("the majority of processes per node sit idle", §2.2).
pub fn gather<T: Pod>(comm: &Comm, local: &[T], root: usize) -> Result<Option<Vec<T>>> {
    let p = comm.size();
    let id = comm.rank();
    let n = local.len();
    let tag = comm.next_coll_tag();
    if id == root {
        let mut out = vec![T::default(); n * p];
        out[root * n..(root + 1) * n].copy_from_slice(local);
        for r in (0..p).filter(|&r| r != root) {
            comm.recv_into(r, tag, &mut out[r * n..(r + 1) * n])?;
        }
        Ok(Some(out))
    } else {
        comm.send(local, root, tag)?;
        Ok(None)
    }
}

/// Binomial-tree broadcast from `root`; every rank returns the data.
pub fn bcast<T: Pod>(comm: &Comm, data: Option<Vec<T>>, root: usize) -> Result<Vec<T>> {
    let p = comm.size();
    let id = comm.rank();
    let tag = comm.next_coll_tag();
    // Standard MPICH binomial tree in root-relative coordinates: receive
    // once from the parent (the set bit found scanning up), then forward to
    // children on every lower bit.
    let vid = (id + p - root) % p;
    let mut buf: Option<Vec<T>> = if vid == 0 {
        Some(data.ok_or_else(|| Error::Precondition("bcast root has no data".into()))?)
    } else {
        None
    };
    let mut mask = 1usize;
    while mask < p {
        if vid & mask != 0 {
            let parent = ((vid ^ mask) + root) % p;
            buf = Some(comm.recv(parent, tag)?);
            break;
        }
        mask <<= 1;
    }
    mask >>= 1;
    while mask > 0 {
        if vid + mask < p {
            let dst = (vid + mask + root) % p;
            comm.send(buf.as_ref().expect("holder has data"), dst, tag)?;
        }
        mask >>= 1;
    }
    buf.ok_or_else(|| Error::Precondition("bcast finished without data".into()))
}

/// The binomial-tree coordinates of [`bcast`] for one rank, precomputed:
/// `(parent, children)` in communicator ranks, children in send order.
/// Used by persistent plans to run the identical tree without re-deriving
/// it per execution.
pub fn bcast_tree(p: usize, id: usize, root: usize) -> (Option<usize>, Vec<usize>) {
    let vid = (id + p - root) % p;
    let mut parent = None;
    let mut mask = 1usize;
    while mask < p {
        if vid & mask != 0 {
            parent = Some(((vid ^ mask) + root) % p);
            break;
        }
        mask <<= 1;
    }
    mask >>= 1;
    let mut children = Vec::new();
    while mask > 0 {
        if vid + mask < p {
            children.push((vid + mask + root) % p);
        }
        mask >>= 1;
    }
    (parent, children)
}

/// One step of the allgatherv schedule (element offsets into the flat
/// rotated scratch buffer).
struct VStep {
    send_to: usize,
    recv_from: usize,
    send_len: usize,
    recv_off: usize,
    recv_len: usize,
}

/// Persistent Bruck-structured allgatherv: rank `r` contributes
/// `counts[r]` elements; the result concatenates contributions in rank
/// order. All ranks must pass identical `counts` at plan time.
///
/// Needed by the locality-aware Bruck when the region count is not a power
/// of the region size: at the final non-local step a fraction of local
/// ranks receive nothing and contribute empty blocks to the following
/// local gather (paper §3).
pub struct AllgathervPlan<T: Pod> {
    comm: Comm,
    p: usize,
    id: usize,
    counts: Vec<usize>,
    /// Prefix sums of counts in rotated order (`rot_off[j]` = offset of the
    /// block of rank `(id + j) % p`), length `p + 1`.
    rot_off: Vec<usize>,
    /// Canonical output offset of each rank's block.
    out_off: Vec<usize>,
    steps: Vec<VStep>,
    tag_base: u64,
    total: usize,
    /// Flat working buffer in rotated order, length `total`.
    scratch: Vec<T>,
}

impl<T: Pod> AllgathervPlan<T> {
    /// Collectively plan an allgatherv for fixed per-rank `counts`.
    /// Reserves one collective tag per step on `comm`.
    pub fn new(comm: &Comm, counts: &[usize]) -> Result<AllgathervPlan<T>> {
        let p = comm.size();
        if counts.len() != p {
            return Err(Error::SizeMismatch { expected: p, got: counts.len() });
        }
        let id = comm.rank();
        let mut rot_off = vec![0usize; p + 1];
        for j in 0..p {
            rot_off[j + 1] = rot_off[j] + counts[(id + j) % p];
        }
        let total = rot_off[p];
        let mut out_off = vec![0usize; p];
        let mut acc = 0usize;
        for (r, &c) in counts.iter().enumerate() {
            out_off[r] = acc;
            acc += c;
        }
        // Bruck schedule over *blocks*; with per-rank counts the byte sizes
        // differ per rank but the schedule is identical. The blocks received
        // at distance `dist` are exactly rotated indices [dist, dist+k), so
        // they land contiguously in the flat buffer.
        let mut steps = Vec::new();
        let mut dist = 1usize;
        while dist < p {
            let nblocks = dist.min(p - dist);
            steps.push(VStep {
                send_to: (id + p - dist) % p,
                recv_from: (id + dist) % p,
                send_len: rot_off[nblocks],
                recv_off: rot_off[dist],
                recv_len: rot_off[dist + nblocks] - rot_off[dist],
            });
            dist <<= 1;
        }
        let tag_base = comm.reserve_coll_tags(steps.len() as u64);
        Ok(AllgathervPlan {
            comm: comm.retain(),
            p,
            id,
            counts: counts.to_vec(),
            rot_off,
            out_off,
            steps,
            tag_base,
            total,
            scratch: vec![T::default(); total],
        })
    }

    /// Total gathered length (`sum(counts)`).
    pub fn total(&self) -> usize {
        self.total
    }

    /// Run the exchange: `local.len()` must equal this rank's planned
    /// count; `output.len()` must equal [`AllgathervPlan::total`].
    pub fn execute(&mut self, local: &[T], output: &mut [T]) -> Result<()> {
        if local.len() != self.counts[self.id] {
            return Err(Error::SizeMismatch { expected: self.counts[self.id], got: local.len() });
        }
        if output.len() != self.total {
            return Err(Error::SizeMismatch { expected: self.total, got: output.len() });
        }
        self.scratch[..local.len()].copy_from_slice(local);
        for (i, s) in self.steps.iter().enumerate() {
            let tag = self.tag_base + i as u64;
            let _send = self.comm.isend(&self.scratch[..s.send_len], s.send_to, tag)?;
            let req = self.comm.irecv(s.recv_from, tag);
            req.wait_into(&self.comm, &mut self.scratch[s.recv_off..s.recv_off + s.recv_len])?;
        }
        // Un-rotate: rotated block j belongs to rank (id + j) % p.
        for j in 0..self.p {
            let r = (self.id + j) % self.p;
            let c = self.counts[r];
            output[self.out_off[r]..self.out_off[r] + c]
                .copy_from_slice(&self.scratch[self.rot_off[j]..self.rot_off[j] + c]);
        }
        Ok(())
    }
}

/// One-shot allgatherv: plan + single execute. Rank `r` contributes
/// `counts[r]` elements; the result concatenates contributions in rank
/// order. All ranks must pass identical `counts`.
pub fn allgatherv<T: Pod>(comm: &Comm, local: &[T], counts: &[usize]) -> Result<Vec<T>> {
    let mut plan = AllgathervPlan::<T>::new(comm, counts)?;
    let mut out = vec![T::default(); plan.total()];
    plan.execute(local, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    #[test]
    fn gather_collects_in_rank_order() {
        let topo = Topology::regions(1, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            gather(c, &[c.rank() as u64 * 10, c.rank() as u64 * 10 + 1], 2).unwrap()
        });
        assert!(run.results[0].is_none());
        assert_eq!(
            run.results[2].as_ref().unwrap(),
            &vec![0, 1, 10, 11, 20, 21, 30, 31]
        );
    }

    #[test]
    fn bcast_from_every_root() {
        for root in 0..5 {
            let topo = Topology::regions(1, 5);
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                let data = (c.rank() == root).then(|| vec![99u64, root as u64]);
                bcast(c, data, root).unwrap()
            });
            for r in run.results {
                assert_eq!(r, vec![99, root as u64]);
            }
        }
    }

    #[test]
    fn bcast_tree_matches_bcast_message_flow() {
        // Every child's parent must list it; the root has no parent; all
        // ranks are reachable from the root.
        for p in [1usize, 2, 3, 5, 8, 13] {
            for root in [0usize, p / 2] {
                let mut reached = vec![false; p];
                reached[root] = true;
                // breadth-first over the precomputed tree
                let mut frontier = vec![root];
                while let Some(r) = frontier.pop() {
                    let (_, children) = bcast_tree(p, r, root);
                    for c in children {
                        assert!(!reached[c], "p={p} root={root}: {c} reached twice");
                        reached[c] = true;
                        frontier.push(c);
                    }
                }
                assert!(reached.iter().all(|&x| x), "p={p} root={root}");
                for r in 0..p {
                    let (parent, _) = bcast_tree(p, r, root);
                    if r == root {
                        assert!(parent.is_none());
                    } else {
                        let pr = parent.unwrap();
                        let (_, pc) = bcast_tree(p, pr, root);
                        assert!(pc.contains(&r), "p={p} root={root} rank={r}");
                    }
                }
            }
        }
    }

    #[test]
    fn allgatherv_uneven_counts() {
        let topo = Topology::regions(1, 4);
        let counts = [3usize, 0, 2, 1];
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let id = c.rank();
            let mine: Vec<u64> = (0..counts[id]).map(|j| (id * 100 + j) as u64).collect();
            allgatherv(c, &mine, &counts).unwrap()
        });
        let expect: Vec<u64> = vec![0, 1, 2, 200, 201, 300];
        for r in run.results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn allgatherv_equal_counts_matches_allgather_layout() {
        let topo = Topology::regions(1, 3);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let id = c.rank() as u64;
            allgatherv(c, &[id, id + 100], &[2, 2, 2]).unwrap()
        });
        for r in run.results {
            assert_eq!(r, vec![0, 100, 1, 101, 2, 102]);
        }
    }

    #[test]
    fn allgatherv_validates_counts() {
        let topo = Topology::regions(1, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let bad_len = allgatherv(c, &[1u64], &[1]).is_err(); // counts.len() != p
            // mine != counts[me], symmetric on both ranks so no rank blocks
            let bad_count = allgatherv(c, &[1u64], &[2, 2]).is_err();
            bad_len && bad_count
        });
        assert!(run.results.iter().all(|&b| b));
    }

    #[test]
    fn allgatherv_plan_reuse() {
        let topo = Topology::regions(1, 4);
        let counts = [2usize, 0, 1, 3];
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let id = c.rank();
            let mut plan = AllgathervPlan::<u64>::new(c, &counts).unwrap();
            let mut out = vec![0u64; plan.total()];
            for round in 0..5u64 {
                let mine: Vec<u64> =
                    (0..counts[id]).map(|j| (id * 100 + j) as u64 + 1000 * round).collect();
                plan.execute(&mine, &mut out).unwrap();
                let expect: Vec<u64> = (0..4usize)
                    .flat_map(|r| (0..counts[r]).map(move |j| (r * 100 + j) as u64))
                    .map(|v| v + 1000 * round)
                    .collect();
                assert_eq!(out, expect, "round {round}");
            }
            true
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
