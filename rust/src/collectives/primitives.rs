//! Collective building blocks: gather, broadcast and allgatherv.
//!
//! These are the substrates the related-work baselines are built from
//! (hierarchical = gather + Bruck + bcast) and that the non-power region
//! extension of the locality-aware Bruck needs (allgatherv for steps where
//! some local ranks hold no new data — paper §3).

use crate::comm::{Comm, Pod};
use crate::error::{Error, Result};

/// Flat gather of equal-size contributions to `root`. Returns the
/// concatenated data (rank order) on the root, `None` elsewhere.
///
/// A flat (non-tree) gather is used deliberately: it matches the
/// master-serialization behaviour the paper ascribes to hierarchical
/// approaches ("the majority of processes per node sit idle", §2.2).
pub fn gather<T: Pod>(comm: &Comm, local: &[T], root: usize) -> Result<Option<Vec<T>>> {
    let p = comm.size();
    let id = comm.rank();
    let n = local.len();
    let tag = comm.next_coll_tag();
    if id == root {
        let mut out = vec![T::default(); n * p];
        out[root * n..(root + 1) * n].copy_from_slice(local);
        for r in (0..p).filter(|&r| r != root) {
            comm.recv_into(r, tag, &mut out[r * n..(r + 1) * n])?;
        }
        Ok(Some(out))
    } else {
        comm.send(local, root, tag)?;
        Ok(None)
    }
}

/// Binomial-tree broadcast from `root`; every rank returns the data.
pub fn bcast<T: Pod>(comm: &Comm, data: Option<Vec<T>>, root: usize) -> Result<Vec<T>> {
    let p = comm.size();
    let id = comm.rank();
    let tag = comm.next_coll_tag();
    // Standard MPICH binomial tree in root-relative coordinates: receive
    // once from the parent (the set bit found scanning up), then forward to
    // children on every lower bit.
    let vid = (id + p - root) % p;
    let mut buf: Option<Vec<T>> = if vid == 0 {
        Some(data.ok_or_else(|| Error::Precondition("bcast root has no data".into()))?)
    } else {
        None
    };
    let mut mask = 1usize;
    while mask < p {
        if vid & mask != 0 {
            let parent = ((vid ^ mask) + root) % p;
            buf = Some(comm.recv(parent, tag)?);
            break;
        }
        mask <<= 1;
    }
    mask >>= 1;
    while mask > 0 {
        if vid + mask < p {
            let dst = (vid + mask + root) % p;
            comm.send(buf.as_ref().expect("holder has data"), dst, tag)?;
        }
        mask >>= 1;
    }
    buf.ok_or_else(|| Error::Precondition("bcast finished without data".into()))
}

/// Allgatherv via the Bruck structure: rank `r` contributes `counts[r]`
/// elements; the result concatenates contributions in rank order. All
/// ranks must pass identical `counts`.
///
/// Needed by the locality-aware Bruck when the region count is not a power
/// of the region size: at the final non-local step a fraction of local
/// ranks receive nothing and contribute empty blocks to the following
/// local gather (paper §3).
pub fn allgatherv<T: Pod>(comm: &Comm, local: &[T], counts: &[usize]) -> Result<Vec<T>> {
    let p = comm.size();
    let id = comm.rank();
    if counts.len() != p {
        return Err(Error::SizeMismatch { expected: p, got: counts.len() });
    }
    if counts[id] != local.len() {
        return Err(Error::SizeMismatch { expected: counts[id], got: local.len() });
    }
    let tag = comm.next_coll_tag();

    // Rotated working set: entry j is the contribution of rank (id+j)%p.
    // Bruck steps exchange *prefixes of blocks*; with per-rank counts the
    // byte sizes differ per rank but the schedule is identical.
    let mut blocks: Vec<Vec<T>> = Vec::with_capacity(p);
    blocks.push(local.to_vec());

    let mut dist = 1usize;
    let mut step = 0u64;
    while dist < p {
        let nblocks = dist.min(p - dist);
        let send_to = (id + p - dist) % p;
        let recv_from = (id + dist) % p;
        // flatten the first nblocks blocks
        let payload: Vec<T> = blocks[..nblocks].concat();
        let _req = comm.isend(&payload, send_to, tag + step)?;
        let got: Vec<T> = comm.irecv(recv_from, tag + step).wait(comm)?;
        // split according to the counts of the origin ranks
        let mut off = 0usize;
        for j in 0..nblocks {
            let origin = (recv_from + j) % p;
            let c = counts[origin];
            if off + c > got.len() {
                return Err(Error::SizeMismatch { expected: off + c, got: got.len() });
            }
            blocks.push(got[off..off + c].to_vec());
            off += c;
        }
        if off != got.len() {
            return Err(Error::SizeMismatch { expected: off, got: got.len() });
        }
        dist <<= 1;
        step += 1;
    }
    debug_assert_eq!(blocks.len(), p);

    // Un-rotate: blocks[j] belongs to rank (id + j) % p.
    let total: usize = counts.iter().sum();
    let mut out = vec![T::default(); total];
    let offsets: Vec<usize> = counts
        .iter()
        .scan(0usize, |acc, &c| {
            let o = *acc;
            *acc += c;
            Some(o)
        })
        .collect();
    for (j, block) in blocks.iter().enumerate() {
        let r = (id + j) % p;
        out[offsets[r]..offsets[r] + counts[r]].copy_from_slice(block);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    #[test]
    fn gather_collects_in_rank_order() {
        let topo = Topology::regions(1, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            gather(c, &[c.rank() as u64 * 10, c.rank() as u64 * 10 + 1], 2).unwrap()
        });
        assert!(run.results[0].is_none());
        assert_eq!(
            run.results[2].as_ref().unwrap(),
            &vec![0, 1, 10, 11, 20, 21, 30, 31]
        );
    }

    #[test]
    fn bcast_from_every_root() {
        for root in 0..5 {
            let topo = Topology::regions(1, 5);
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                let data = (c.rank() == root).then(|| vec![99u64, root as u64]);
                bcast(c, data, root).unwrap()
            });
            for r in run.results {
                assert_eq!(r, vec![99, root as u64]);
            }
        }
    }

    #[test]
    fn allgatherv_uneven_counts() {
        let topo = Topology::regions(1, 4);
        let counts = [3usize, 0, 2, 1];
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let id = c.rank();
            let mine: Vec<u64> = (0..counts[id]).map(|j| (id * 100 + j) as u64).collect();
            allgatherv(c, &mine, &counts).unwrap()
        });
        let expect: Vec<u64> = vec![0, 1, 2, 200, 201, 300];
        for r in run.results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn allgatherv_equal_counts_matches_allgather_layout() {
        let topo = Topology::regions(1, 3);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let id = c.rank() as u64;
            allgatherv(c, &[id, id + 100], &[2, 2, 2]).unwrap()
        });
        for r in run.results {
            assert_eq!(r, vec![0, 100, 1, 101, 2, 102]);
        }
    }

    #[test]
    fn allgatherv_validates_counts() {
        let topo = Topology::regions(1, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let bad_len = allgatherv(c, &[1u64], &[1]).is_err(); // counts.len() != p
            let bad_count = allgatherv(c, &[1u64], &[2, 1]).is_err(); // mine != counts[me]
            bad_len && bad_count
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
