//! Dissemination allgather (§2, ref. [1]).
//!
//! `⌈log2(p)⌉` steps for *any* `p`: at step `i` rank `id` sends everything
//! it currently holds to `id + 2^i (mod p)` and receives from
//! `id − 2^i (mod p)`. Like Bruck it needs no power-of-two size; unlike
//! Bruck the received data is merged by absolute block index (each block
//! tagged by origin), so duplicate coverage near the end of non-power
//! cases is handled by overwriting with identical data.
//!
//! This implementation transmits `(origin, block)` pairs encoded in the
//! element stream, which costs one `u64` header per block — the classic
//! trade-off that makes Bruck (which needs no headers, only a final
//! rotation) the preferred log-step algorithm (§2).
//!
//! The persistent [`DisseminationPlan`] exploits that the held-block count
//! before step `i` is exactly `2^i`, so both pack and receive buffers have
//! statically known per-step sizes and are allocated once at plan time.

use std::marker::PhantomData;

use super::plan::{
    check_io, trivial_plan, AllgatherPlan, CollectiveAlgorithm, CollectivePlan, NamedAlgorithm,
    Shape,
};
use crate::comm::{write_bytes, Comm, Pod};
use crate::error::{Error, Result};

/// The dissemination algorithm (registry entry).
pub struct Dissemination;

impl NamedAlgorithm for Dissemination {
    fn name(&self) -> &'static str {
        "dissemination"
    }

    fn summary(&self) -> &'static str {
        "dissemination allgather: log2(p) steps with per-block origin headers"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for Dissemination {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("dissemination", comm, shape) {
            return Ok(p);
        }
        Ok(Box::new(DisseminationPlan::<T>::new(comm, shape.n)))
    }
}

/// One step of the schedule.
struct Step {
    dst: usize,
    src: usize,
    /// `(origin, block)` records exchanged: the held count `2^i`.
    records: usize,
}

/// Persistent dissemination plan with preallocated pack/unpack buffers.
pub struct DisseminationPlan<T: Pod> {
    comm: Comm,
    n: usize,
    p: usize,
    id: usize,
    tag_base: u64,
    steps: Vec<Step>,
    send_buf: Vec<u8>,
    recv_buf: Vec<u8>,
    have: Vec<bool>,
    _elem: PhantomData<T>,
}

impl<T: Pod> DisseminationPlan<T> {
    /// Collectively plan a dissemination allgather of `n` elements per
    /// rank. Reserves one collective tag per step on `comm`.
    pub fn new(comm: &Comm, n: usize) -> DisseminationPlan<T> {
        let p = comm.size();
        let id = comm.rank();
        let mut steps = Vec::new();
        let mut dist = 1usize;
        while dist < p {
            steps.push(Step { dst: (id + dist) % p, src: (id + p - dist) % p, records: dist });
            dist <<= 1;
        }
        let tag_base = comm.reserve_coll_tags(steps.len() as u64);
        let rec = 8 + n * std::mem::size_of::<T>();
        let max_records = steps.last().map(|s| s.records).unwrap_or(0);
        DisseminationPlan {
            comm: comm.retain(),
            n,
            p,
            id,
            tag_base,
            steps,
            send_buf: vec![0u8; max_records * rec],
            recv_buf: vec![0u8; max_records * rec],
            have: vec![false; p],
            _elem: PhantomData,
        }
    }
}

impl<T: Pod> CollectivePlan for DisseminationPlan<T> {
    fn algorithm(&self) -> &'static str {
        "dissemination"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.n }
    }

    fn comm_size(&self) -> usize {
        self.p
    }
}

impl<T: Pod> AllgatherPlan<T> for DisseminationPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_io(self.n, self.p, input, output)?;
        if self.n == 0 {
            return Ok(());
        }
        let n = self.n;
        let rec = 8 + n * std::mem::size_of::<T>();
        output[self.id * n..(self.id + 1) * n].copy_from_slice(input);
        self.have.fill(false);
        self.have[self.id] = true;
        for (i, s) in self.steps.iter().enumerate() {
            let tag = self.tag_base + i as u64;
            let len = s.records * rec;
            pack_blocks(output, &self.have, n, &mut self.send_buf[..len]);
            let _send = self.comm.isend(&self.send_buf[..len], s.dst, tag)?;
            self.comm.recv_into(s.src, tag, &mut self.recv_buf[..len])?;
            unpack_blocks(&self.recv_buf[..len], output, &mut self.have, n)?;
        }
        Ok(())
    }
}

/// One-shot convenience wrapper: plan + single execute.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&Dissemination, comm, local)
}

/// Encode all held blocks as `[origin: u64 | block bytes]*` into `buf`,
/// which must be sized for exactly the held count.
fn pack_blocks<T: Pod>(out: &[T], have: &[bool], n: usize, buf: &mut [u8]) {
    let esz = std::mem::size_of::<T>();
    let rec = 8 + n * esz;
    let mut off = 0usize;
    for (r, &h) in have.iter().enumerate() {
        if !h {
            continue;
        }
        buf[off..off + 8].copy_from_slice(&(r as u64).to_le_bytes());
        let ok = write_bytes(&out[r * n..(r + 1) * n], &mut buf[off + 8..off + rec]);
        debug_assert!(ok);
        off += rec;
    }
    debug_assert_eq!(off, buf.len(), "held-block count must match the schedule");
}

/// Decode `[origin | block]*` into the output array, marking coverage.
fn unpack_blocks<T: Pod>(bytes: &[u8], out: &mut [T], have: &mut [bool], n: usize) -> Result<()> {
    let esz = std::mem::size_of::<T>();
    let rec = 8 + n * esz;
    if rec == 8 || bytes.len() % rec != 0 {
        return Err(Error::DatatypeMismatch { bytes: bytes.len(), elem_size: rec.max(1) });
    }
    for chunk in bytes.chunks_exact(rec) {
        let origin = u64::from_le_bytes(chunk[0..8].try_into().expect("8-byte header")) as usize;
        if origin >= have.len() {
            return Err(Error::Precondition(format!(
                "dissemination header references rank {origin} outside communicator"
            )));
        }
        let dst = &mut out[origin * n..(origin + 1) * n];
        if !crate::comm::copy_into(&chunk[8..], dst) {
            return Err(Error::SizeMismatch { expected: n * esz, got: chunk.len() - 8 });
        }
        have[origin] = true;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let n = 2;
        let out: Vec<u64> = vec![1, 2, 0, 0, 5, 6];
        let have = vec![true, false, true];
        let mut bytes = vec![0u8; 2 * (8 + 2 * 8)];
        pack_blocks(&out, &have, n, &mut bytes);
        let mut out2 = vec![0u64; 6];
        let mut have2 = vec![false; 3];
        unpack_blocks(&bytes, &mut out2, &mut have2, n).unwrap();
        assert_eq!(out2, vec![1, 2, 0, 0, 5, 6]);
        assert_eq!(have2, vec![true, false, true]);
    }

    #[test]
    fn unpack_rejects_garbage() {
        let mut out = vec![0u64; 4];
        let mut have = vec![false; 2];
        assert!(unpack_blocks(&[1, 2, 3], &mut out, &mut have, 2).is_err());
        // valid record shape but origin out of range
        let mut bad = Vec::new();
        bad.extend_from_slice(&9u64.to_le_bytes());
        bad.extend_from_slice(&[0u8; 16]);
        assert!(unpack_blocks(&bad, &mut out, &mut have, 2).is_err());
    }
}
