//! Dissemination allgather (§2, ref. [1]).
//!
//! `⌈log2(p)⌉` steps for *any* `p`: at step `i` rank `id` sends everything
//! it currently holds to `id + 2^i (mod p)` and receives from
//! `id − 2^i (mod p)`. Like Bruck it needs no power-of-two size; unlike
//! Bruck the received data is merged by absolute block index (each block
//! tagged by origin), so duplicate coverage near the end of non-power
//! cases is handled by overwriting with identical data.
//!
//! This implementation transmits `(origin, block)` pairs encoded in the
//! element stream, which costs one `u64` header per block — the classic
//! trade-off that makes Bruck (which needs no headers, only a final
//! rotation) the preferred log-step algorithm (§2).

use crate::comm::{to_bytes, Comm, Pod};
use crate::error::{Error, Result};

/// Dissemination allgather of `local` (length `n`); returns `n·p` elements
/// in rank order.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    let p = comm.size();
    let id = comm.rank();
    let n = local.len();
    let tag = comm.next_coll_tag();

    let mut out = vec![T::default(); n * p];
    out[id * n..(id + 1) * n].copy_from_slice(local);
    let mut have: Vec<bool> = (0..p).map(|r| r == id).collect();

    let mut dist = 1usize;
    let mut step = 0u64;
    while dist < p {
        let dst = (id + dist) % p;
        let src = (id + p - dist) % p;
        let payload = pack_blocks(&out, &have, n);
        // Raw byte send: payload is already a byte vector.
        let _req = comm.isend(&payload, dst, tag + step)?;
        let bytes: Vec<u8> = comm.irecv(src, tag + step).wait(comm)?;
        unpack_blocks(&bytes, &mut out, &mut have, n)?;
        dist <<= 1;
        step += 1;
    }
    Ok(out)
}

/// Encode all held blocks as `[origin: u64 | block bytes]*`.
fn pack_blocks<T: Pod>(out: &[T], have: &[bool], n: usize) -> Vec<u8> {
    let esz = std::mem::size_of::<T>();
    let count = have.iter().filter(|&&h| h).count();
    let mut buf = Vec::with_capacity(count * (8 + n * esz));
    for (r, &h) in have.iter().enumerate() {
        if !h {
            continue;
        }
        buf.extend_from_slice(&(r as u64).to_le_bytes());
        buf.extend_from_slice(&to_bytes(&out[r * n..(r + 1) * n]));
    }
    buf
}

/// Decode `[origin | block]*` into the output array, marking coverage.
fn unpack_blocks<T: Pod>(
    bytes: &[u8],
    out: &mut [T],
    have: &mut [bool],
    n: usize,
) -> Result<()> {
    let esz = std::mem::size_of::<T>();
    let rec = 8 + n * esz;
    if rec == 8 || bytes.len() % rec != 0 {
        return Err(Error::DatatypeMismatch { bytes: bytes.len(), elem_size: rec.max(1) });
    }
    for chunk in bytes.chunks_exact(rec) {
        let origin = u64::from_le_bytes(chunk[0..8].try_into().expect("8-byte header")) as usize;
        if origin >= have.len() {
            return Err(Error::Precondition(format!(
                "dissemination header references rank {origin} outside communicator"
            )));
        }
        let dst = &mut out[origin * n..(origin + 1) * n];
        if !crate::comm::copy_into(&chunk[8..], dst) {
            return Err(Error::SizeMismatch { expected: n * esz, got: chunk.len() - 8 });
        }
        have[origin] = true;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let n = 2;
        let out: Vec<u64> = vec![1, 2, 0, 0, 5, 6];
        let have = vec![true, false, true];
        let bytes = pack_blocks(&out, &have, n);
        let mut out2 = vec![0u64; 6];
        let mut have2 = vec![false; 3];
        unpack_blocks(&bytes, &mut out2, &mut have2, n).unwrap();
        assert_eq!(out2, vec![1, 2, 0, 0, 5, 6]);
        assert_eq!(have2, vec![true, false, true]);
    }

    #[test]
    fn unpack_rejects_garbage() {
        let mut out = vec![0u64; 4];
        let mut have = vec![false; 2];
        assert!(unpack_blocks(&[1, 2, 3], &mut out, &mut have, 2).is_err());
        // valid record shape but origin out of range
        let mut bad = Vec::new();
        bad.extend_from_slice(&9u64.to_le_bytes());
        bad.extend_from_slice(&[0u8; 16]);
        assert!(unpack_blocks(&bad, &mut out, &mut have, 2).is_err());
    }
}
