//! Dissemination allgather (§2, ref. [1]) as a schedule builder.
//!
//! `⌈log2(p)⌉` steps for *any* `p`: at step `i` rank `id` sends everything
//! it currently holds to `id + 2^i (mod p)` and receives from
//! `id − 2^i (mod p)`. Like Bruck it needs no power-of-two size; unlike
//! Bruck the transmitted blocks are identified by absolute origin, which
//! classically costs one `u64` header per block — the trade-off that makes
//! Bruck (headerless, one final rotation) the preferred log-step
//! algorithm (§2).
//!
//! In the schedule IR the held-block set before step `i` is statically
//! known (`{id − j mod p : j < 2^i}`), so the pack/unpack become
//! `CopyLocal` steps and the per-block headers become wire *padding* on
//! the exchange ([`Step::SendRecv`](super::schedule::Step)'s `pad`):
//! the message carries exactly the classic `2^i · (8 + n·elem)` bytes, so
//! traced byte counts and modeled costs are unchanged — the protocol
//! overhead is preserved as data, not re-derived at run time.

use super::plan::{
    trivial_plan, AllgatherPlan, CollectiveAlgorithm, NamedAlgorithm, OpKind, PlanSpec,
};
use super::schedule::{SchedPlan, Schedule, ScheduleBuilder, Slice};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// The dissemination algorithm (registry entry).
pub struct Dissemination;

impl NamedAlgorithm for Dissemination {
    fn name(&self) -> &'static str {
        "dissemination"
    }

    fn summary(&self) -> &'static str {
        "dissemination allgather: log2(p) steps with per-block origin headers"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for Dissemination {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("dissemination", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("dissemination")?;
        let sched = build_schedule(comm.size(), comm.rank(), n, std::mem::size_of::<T>());
        Ok(SchedPlan::<T>::boxed(comm, "dissemination", sched)?)
    }
}

/// Wire overhead per transmitted block (the classic origin header).
pub(crate) const HEADER_BYTES: usize = 8;

/// Build the dissemination schedule for one rank (pure; SPMD).
pub fn build_schedule(p: usize, rank: usize, n: usize, elem_bytes: usize) -> Schedule {
    let mut sb = ScheduleBuilder::new("dissemination");
    sb.copy(Slice::input(0, n), Slice::output(rank * n, n));
    let max_records = {
        let mut last = 0usize;
        let mut dist = 1usize;
        while dist < p {
            last = dist;
            dist <<= 1;
        }
        last
    };
    if max_records > 0 {
        let pack = sb.scratch(max_records * n);
        let unpack = sb.scratch(max_records * n);
        let mut dist = 1usize;
        let mut step_no = 1usize;
        while dist < p {
            sb.round(format!("step {step_no}"));
            let tag = sb.tag();
            let dst = (rank + dist) % p;
            let src = (rank + p - dist) % p;
            // Held set before this step: blocks of ranks (rank − j) mod p
            // for j < dist; pack in that deterministic order.
            for j in 0..dist {
                let block = (rank + p - j) % p;
                sb.copy(Slice::output(block * n, n), Slice::at(pack, j * n, n));
            }
            sb.sendrecv(
                dst,
                Slice::at(pack, 0, dist * n),
                src,
                Slice::at(unpack, 0, dist * n),
                tag,
                dist * HEADER_BYTES,
            );
            // The sender's held set, shifted by dist: blocks
            // (rank − dist − j) mod p in the same order.
            for j in 0..dist {
                let block = (rank + 2 * p - (dist + j) % p) % p;
                sb.copy(Slice::at(unpack, j * n, n), Slice::output(block * n, n));
            }
            dist <<= 1;
            step_no += 1;
        }
    }
    sb.finish(OpKind::Allgather, p, n, elem_bytes, "dissemination")
}

/// One-shot convenience wrapper: plan + single execute.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&Dissemination, comm, local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::schedule::Step;

    #[test]
    fn wire_sizes_match_classic_header_format() {
        // p = 8, n = 2, u64: step i ships 2^i records of (8 + 16) bytes.
        let sched = build_schedule(8, 3, 2, 8);
        let mut wire: Vec<usize> = Vec::new();
        for s in sched.steps() {
            if let Step::SendRecv { src, pad, .. } = s {
                wire.push(sched.wire_bytes(src.len, *pad));
            }
        }
        assert_eq!(wire, vec![24, 48, 96]);
        sched.validate().unwrap();
    }

    #[test]
    fn held_set_covers_all_blocks() {
        // Simulate coverage: after step i the held set doubles.
        for p in [2usize, 3, 5, 8, 13] {
            for rank in 0..p {
                let sched = build_schedule(p, rank, 1, 8);
                let mut have = vec![false; p];
                have[rank] = true;
                for s in sched.steps() {
                    if let Step::CopyLocal { src, dst } = s {
                        // unpack copies write to the output buffer
                        if dst.buf == crate::collectives::schedule::BufId::Output
                            && src.buf != crate::collectives::schedule::BufId::Input
                        {
                            have[dst.off] = true;
                        }
                    }
                }
                assert!(have.iter().all(|&h| h), "p={p} rank={rank}");
            }
        }
    }
}
