//! Reduce-scatter — the allgather's inverse sibling — as schedule
//! builders.
//!
//! `reduce_scatter` contract (`MPI_Reduce_scatter_block` with `MPI_SUM`):
//! rank `i` holds `p` blocks of `n` elements, block `j` being its
//! contribution to rank `j`; afterwards rank `i` holds the `n`-element
//! elementwise sum over all ranks of block `i`. Jocksch et al. (*Optimised
//! allgatherv, reduce_scatter and allreduce communication*) and NCCL's PAT
//! treat it as the collective whose locality-aware scheduling mirrors the
//! allgather's: the same per-message postal terms `α_c + β_c·s` (paper
//! §4), traversed in the opposite direction with a reduction folded into
//! every hop.
//!
//! Three builders, all registered in
//! [`super::plan::ReduceScatterRegistry`] (plus the cost-model-driven
//! [`super::model_tuned::ModelTunedReduceScatter`]):
//!
//! * **`ring`** — `p−1` neighbour exchange-and-reduce steps, each carrying
//!   one `n`-element partial: the bandwidth-optimal baseline (every value
//!   crosses each link once; `(p−1)·n` elements sent per rank);
//! * **`recursive-halving`** — Rabenseifner's first phase (Jocksch et
//!   al. §2, van de Geijn's halving/doubling): `log₂(p)` exchanges of
//!   shrinking halves (`p/2·n`, `p/4·n`, …), minimal message count at the
//!   same `(p−1)·n` total volume. Power-of-two `p` only, checked at plan
//!   time;
//! * **`loc-aware`** — the paper's §4 argument applied to reduce-scatter:
//!   every rank first pre-reduces *within its region* (all-local traffic)
//!   so that local rank `ℓ` holds the region's partial sums for **lane**
//!   `ℓ` (the destination ranks with local index `ℓ` in every region);
//!   then each lane — one member per region — runs an inter-region
//!   reduce-scatter of aggregated per-region partials: `⌈log₂ r⌉`
//!   non-local messages per rank when the region count `r` is a power of
//!   two (recursive halving within the lane), `r−1` otherwise (lane
//!   ring). Every non-local message carries an aggregated partial — one
//!   message per region pair per step, never one per source rank.
//!
//! All three are pure schedule builders executed by the generic
//! [`SchedPlan`] interpreter with the [`Summable`] reducer: reductions are
//! explicit [`Step::Reduce`](super::schedule::Step) steps, schedules own
//! their tag layouts and scratch, and `execute` is pure communication +
//! summation with zero allocation and no tag consumption. Shape
//! preconditions (power-of-two size, uniform regions) surface at `plan()`
//! time; `n == 0` plans are uniform no-ops.
//!
//! **Serving shapes.** The Layer-3 serving loop
//! ([`crate::coordinator::server`]) fuses reduce-scatter constituents of
//! `n = RS_SHARD_ELEMS` into each chunk's collective (`--rs-shards` on
//! `locag e2e`): the `n·p → n` shard shape rides the same coalesced wire
//! messages as the activation allgathers, executed through zero-copy
//! segmented views. `loc-aware` is picked when it plans on the serving
//! topology (uniform regions), with a deterministic fallback to `ring`
//! otherwise — the same probe-and-downgrade contract the consensus
//! allreduce uses.

use super::grouping::GroupBy;
use super::plan::{
    trivial_rs_plan, NamedAlgorithm, OpKind, PlanSpec, ReduceScatterAlgorithm, ReduceScatterPlan,
    Summable,
};
use super::schedule::{
    ceil_log2_u64, locate, uniform_size, BufId, SchedPlan, Schedule, ScheduleBuilder, Slice,
    WorldView,
};
use crate::comm::Comm;
use crate::error::{Error, Result};

/// Ring reduce-scatter (registry entry).
pub struct RingReduceScatter;

impl NamedAlgorithm for RingReduceScatter {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn summary(&self) -> &'static str {
        "ring reduce-scatter: p-1 neighbour exchange-and-reduce steps, bandwidth-optimal"
    }
}

impl<T: Summable> ReduceScatterAlgorithm<T> for RingReduceScatter {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn ReduceScatterPlan<T>>> {
        if let Some(p) = trivial_rs_plan("ring", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("ring")?;
        let sched = build_ring_schedule(comm.size(), comm.rank(), n, std::mem::size_of::<T>());
        Ok(SchedPlan::<T>::boxed(comm, "ring", sched)?)
    }
}

/// Recursive-halving reduce-scatter (registry entry).
pub struct RecursiveHalvingReduceScatter;

impl NamedAlgorithm for RecursiveHalvingReduceScatter {
    fn name(&self) -> &'static str {
        "recursive-halving"
    }

    fn summary(&self) -> &'static str {
        "recursive halving (Rabenseifner phase 1): log2(p) shrinking exchanges, power-of-two p"
    }
}

impl<T: Summable> ReduceScatterAlgorithm<T> for RecursiveHalvingReduceScatter {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn ReduceScatterPlan<T>>> {
        if let Some(p) = trivial_rs_plan("recursive-halving", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("recursive-halving")?;
        let sched = build_rh_schedule(comm.size(), comm.rank(), n, std::mem::size_of::<T>())?;
        Ok(SchedPlan::<T>::boxed(comm, "recursive-halving", sched)?)
    }
}

/// Locality-aware reduce-scatter (registry entry).
pub struct LocAwareReduceScatter;

impl NamedAlgorithm for LocAwareReduceScatter {
    fn name(&self) -> &'static str {
        "loc-aware"
    }

    fn summary(&self) -> &'static str {
        "regional reduce-scatter (§4): local pre-reduce into lanes, aggregated lane exchanges"
    }
}

impl<T: Summable> ReduceScatterAlgorithm<T> for LocAwareReduceScatter {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn ReduceScatterPlan<T>>> {
        if let Some(p) = trivial_rs_plan("loc-aware", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("loc-aware")?;
        let view = WorldView::from_comm(comm);
        let sched = build_loc_schedule(&view, comm.rank(), n, std::mem::size_of::<T>())?;
        Ok(SchedPlan::<T>::boxed(comm, "loc-aware", sched)?)
    }
}

// ---------------------------------------------------------------------------
// group emitters (shared by the top-level builders and the lane phase)
// ---------------------------------------------------------------------------

/// Emit a ring reduce-scatter among `members` over the member-major
/// accumulator `acc` (`q·b` elements; block `k` is destined to member
/// `k`). `q−1` neighbour exchange-and-reduce steps; member `k` ends with
/// block `k` fully reduced **in place**. Ranks outside `members` allocate
/// the tag block and emit nothing (the SPMD contract).
pub(crate) fn emit_group_ring_rs(
    sb: &mut ScheduleBuilder,
    members: &[usize],
    me: usize,
    b: usize,
    acc: BufId,
) {
    let q = members.len();
    let tag0 = sb.tag_block(q.saturating_sub(1) as u64);
    let Some(k) = members.iter().position(|&r| r == me) else {
        return;
    };
    if q == 1 {
        return;
    }
    let tmp = sb.scratch(b);
    // Block `c` starts accumulating at member `c+1` and travels one
    // neighbour per step, reaching its owner after q−1 hops: at step `s`
    // member `k` forwards the partial of block `(k−1−s) mod q` and folds
    // the incoming partial into block `(k−2−s) mod q`.
    for s in 0..q - 1 {
        let right = members[(k + 1) % q];
        let left = members[(k + q - 1) % q];
        let c_send = (k + q - 1 - s) % q;
        let c_recv = (k + 2 * q - 2 - s) % q;
        sb.sendrecv(
            right,
            Slice::at(acc, c_send * b, b),
            left,
            Slice::at(tmp, 0, b),
            tag0 + s as u64,
            0,
        );
        sb.reduce(Slice::at(tmp, 0, b), Slice::at(acc, c_recv * b, b));
    }
}

/// Emit a recursive-halving reduce-scatter among `members` over the
/// member-major accumulator `acc` (see [`emit_group_ring_rs`] for the
/// layout): `log₂(q)` exchanges of shrinking block halves; member `k`
/// ends with block `k` fully reduced in place. Errors at build time
/// unless the group size is a power of two.
pub(crate) fn emit_group_rh_rs(
    sb: &mut ScheduleBuilder,
    members: &[usize],
    me: usize,
    b: usize,
    acc: BufId,
) -> Result<()> {
    let q = members.len();
    if !q.is_power_of_two() {
        return Err(Error::Precondition(format!(
            "recursive-halving reduce-scatter requires power-of-two size, got {q}"
        )));
    }
    let tag0 = sb.tag_block(ceil_log2_u64(q));
    let Some(k) = members.iter().position(|&r| r == me) else {
        return Ok(());
    };
    if q == 1 {
        return Ok(());
    }
    let tmp = sb.scratch((q / 2) * b);
    // Invariant: the aligned window [lo, lo+w) of blocks is owned by the
    // aligned member group [lo, lo+w); each step halves both, keeping the
    // half that contains `k`.
    let (mut lo, mut w, mut ti) = (0usize, q, 0u64);
    while w > 1 {
        let half = w / 2;
        let peer = members[k ^ half];
        let (keep_lo, send_lo) = if k & half == 0 { (lo, lo + half) } else { (lo + half, lo) };
        sb.sendrecv(
            peer,
            Slice::at(acc, send_lo * b, half * b),
            peer,
            Slice::at(tmp, 0, half * b),
            tag0 + ti,
            0,
        );
        sb.reduce(Slice::at(tmp, 0, half * b), Slice::at(acc, keep_lo * b, half * b));
        lo = keep_lo;
        w = half;
        ti += 1;
    }
    debug_assert_eq!(lo, k);
    Ok(())
}

// ---------------------------------------------------------------------------
// builders
// ---------------------------------------------------------------------------

/// Build the ring reduce-scatter schedule for one rank (pure; SPMD).
pub fn build_ring_schedule(p: usize, rank: usize, n: usize, elem_bytes: usize) -> Schedule {
    let mut sb = ScheduleBuilder::new("ring reduce-scatter");
    let members: Vec<usize> = (0..p).collect();
    let acc = sb.scratch(n * p);
    sb.copy(Slice::input(0, n * p), Slice::at(acc, 0, n * p));
    emit_group_ring_rs(&mut sb, &members, rank, n, acc);
    sb.copy(Slice::at(acc, rank * n, n), Slice::output(0, n));
    sb.finish(OpKind::ReduceScatter, p, n, elem_bytes, "ring")
}

/// Build the recursive-halving reduce-scatter schedule for one rank
/// (pure; SPMD). Errors on non-power-of-two communicators.
pub fn build_rh_schedule(p: usize, rank: usize, n: usize, elem_bytes: usize) -> Result<Schedule> {
    let mut sb = ScheduleBuilder::new("recursive halving");
    let members: Vec<usize> = (0..p).collect();
    let acc = sb.scratch(n * p);
    sb.copy(Slice::input(0, n * p), Slice::at(acc, 0, n * p));
    emit_group_rh_rs(&mut sb, &members, rank, n, acc)?;
    sb.copy(Slice::at(acc, rank * n, n), Slice::output(0, n));
    Ok(sb.finish(OpKind::ReduceScatter, p, n, elem_bytes, "recursive-halving"))
}

/// Build the locality-aware reduce-scatter schedule for one rank (pure;
/// SPMD).
///
/// Phase 1 (all local): every member of a region sends each local peer
/// `ℓ` the gathered input blocks destined to lane `ℓ`, and each lane
/// owner reduces the region's partial sums in place — after this, local
/// rank `ℓ` holds its region's contribution to every rank with local
/// index `ℓ`. Phase 2 (non-local): each lane — one member per region —
/// reduce-scatters those aggregated partials among the regions, by
/// recursive halving when the region count is a power of two and by a
/// lane ring otherwise. Degenerate shapes (single region, one rank per
/// region) fall back to the plain ring; non-uniform regions are rejected
/// at plan time.
pub fn build_loc_schedule(
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    let all: Vec<usize> = (0..view.p).collect();
    let groups = view.split(&all, GroupBy::Region);
    let ppr = uniform_size(&groups, "locality-aware reduce-scatter")?;
    let r_n = groups.len();
    if r_n == 1 || ppr == 1 {
        let mut sched = build_ring_schedule(view.p, rank, n, elem_bytes);
        sched.label = "loc-aware[ring]".to_string();
        return Ok(sched);
    }
    let (g, l) = locate(&groups, rank)?;

    let mut sb = ScheduleBuilder::new("local pre-reduce");
    // Lane accumulator: block j is the partial destined to groups[j][l],
    // the lane-ℓ member of region j.
    let lane_acc = sb.scratch(r_n * n);
    let tag1 = sb.tag();
    for (j, group) in groups.iter().enumerate() {
        sb.copy(Slice::input(group[l] * n, n), Slice::at(lane_acc, j * n, n));
    }
    // Send every local peer its lane's blocks, gathered into one staged
    // local message; all sends post before the first blocking receive.
    for (m, &peer) in groups[g].iter().enumerate() {
        if m == l {
            continue;
        }
        let stage = sb.scratch(r_n * n);
        for (j, group) in groups.iter().enumerate() {
            sb.copy(Slice::input(group[m] * n, n), Slice::at(stage, j * n, n));
        }
        sb.send(peer, Slice::at(stage, 0, r_n * n), tag1, 0);
    }
    let tmp = sb.scratch(r_n * n);
    for (m, &peer) in groups[g].iter().enumerate() {
        if m == l {
            continue;
        }
        sb.recv(peer, Slice::at(tmp, 0, r_n * n), tag1, 0);
        sb.reduce(Slice::at(tmp, 0, r_n * n), Slice::at(lane_acc, 0, r_n * n));
    }

    // Phase 2: aggregated inter-region exchange within the lane.
    sb.round("lane exchange");
    let lane: Vec<usize> = groups.iter().map(|group| group[l]).collect();
    if r_n.is_power_of_two() {
        emit_group_rh_rs(&mut sb, &lane, rank, n, lane_acc)?;
    } else {
        emit_group_ring_rs(&mut sb, &lane, rank, n, lane_acc);
    }
    sb.copy(Slice::at(lane_acc, g * n, n), Slice::output(0, n));
    Ok(sb.finish(OpKind::ReduceScatter, view.p, n, elem_bytes, "loc-aware"))
}

// ---------------------------------------------------------------------------
// one-shot wrappers
// ---------------------------------------------------------------------------

/// One-shot ring reduce-scatter: `send.len()` must be a multiple of the
/// communicator size (block length inferred).
pub fn ring<T: Summable>(comm: &Comm, send: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_rs(&RingReduceScatter, comm, send)
}

/// One-shot recursive-halving reduce-scatter (power-of-two size).
pub fn recursive_halving<T: Summable>(comm: &Comm, send: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_rs(&RecursiveHalvingReduceScatter, comm, send)
}

/// One-shot locality-aware reduce-scatter.
pub fn loc_aware<T: Summable>(comm: &Comm, send: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_rs(&LocAwareReduceScatter, comm, send)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::plan::{ReduceScatterRegistry, Shape};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    fn send_buf(rank: usize, p: usize, n: usize) -> Vec<u64> {
        (0..p * n)
            .map(|x| (rank * 1_000_003 + (x / n) * 1_009 + x % n) as u64)
            .collect()
    }

    fn expected(rank: usize, p: usize, n: usize) -> Vec<u64> {
        (0..n)
            .map(|j| (0..p).map(|r| (r * 1_000_003 + rank * 1_009 + j) as u64).sum())
            .collect()
    }

    #[test]
    fn ring_reduces_and_scatters() {
        for (regions, ppr) in [(1usize, 1usize), (1, 4), (4, 4), (3, 2), (5, 2)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                ring(c, &send_buf(c.rank(), p, 3)).unwrap()
            });
            for (r, out) in run.results.iter().enumerate() {
                assert_eq!(out, &expected(r, p, 3), "{regions}x{ppr} rank {r}");
            }
        }
    }

    #[test]
    fn recursive_halving_matches_ring_on_powers_of_two() {
        for (regions, ppr) in [(1usize, 1usize), (2, 2), (4, 4), (2, 8), (8, 4)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                recursive_halving(c, &send_buf(c.rank(), p, 2)).unwrap()
            });
            for (r, out) in run.results.iter().enumerate() {
                assert_eq!(out, &expected(r, p, 2), "{regions}x{ppr} rank {r}");
            }
        }
    }

    #[test]
    fn recursive_halving_rejects_non_power_of_two_at_plan_time() {
        let topo = Topology::regions(3, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = ReduceScatterRegistry::<u64>::standard();
            match r.plan_uniform("recursive-halving", c, Shape::elems(2)) {
                Err(e) => e.to_string(),
                Ok(_) => String::new(),
            }
        });
        for msg in &run.results {
            assert!(msg.contains("power-of-two"), "{msg}");
        }
        let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
        assert_eq!(total, 0, "plan-time rejection must send no messages");
    }

    #[test]
    fn loc_aware_correct_on_aligned_and_ragged_region_counts() {
        for (regions, ppr) in [(4usize, 4usize), (3, 3), (8, 4), (5, 2), (1, 4), (4, 1)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                loc_aware(c, &send_buf(c.rank(), p, 2)).unwrap()
            });
            for (r, out) in run.results.iter().enumerate() {
                assert_eq!(out, &expected(r, p, 2), "{regions}x{ppr} rank {r}");
            }
        }
    }

    #[test]
    fn loc_aware_sends_only_aggregated_nonlocal_messages() {
        // 4x4: the lane recursive halving sends ⌈log2 4⌉ = 2 non-local
        // messages per rank (of 2·n then 1·n blocks); phase 1 is all-local.
        let topo = Topology::regions(4, 4);
        let p = topo.size();
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            loc_aware(c, &send_buf(c.rank(), p, 2)).unwrap()
        });
        for (r, out) in run.results.iter().enumerate() {
            assert_eq!(out, &expected(r, p, 2), "rank {r}");
        }
        for t in &run.trace.per_rank {
            assert_eq!(t.nonlocal_msgs, 2);
        }
    }

    #[test]
    fn plan_reuse_with_shifting_inputs() {
        let topo = Topology::regions(4, 4);
        let p = topo.size();
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let reg = ReduceScatterRegistry::<u64>::standard();
            for name in reg.names() {
                let mut plan = reg.plan_uniform(name, c, Shape::elems(2)).unwrap();
                assert_eq!(plan.algorithm(), name);
                assert_eq!(plan.comm_size(), p);
                let mut out = vec![0u64; 2];
                for round in 0..5u64 {
                    let mine: Vec<u64> =
                        send_buf(c.rank(), p, 2).iter().map(|v| v + round).collect();
                    plan.execute(&mine, &mut out).unwrap();
                    let expect: Vec<u64> = expected(c.rank(), p, 2)
                        .iter()
                        .map(|v| v + round * p as u64)
                        .collect();
                    assert_eq!(out, expect, "{name} round {round}");
                }
            }
            true
        });
        assert!(run.results.iter().all(|&ok| ok));
    }
}
