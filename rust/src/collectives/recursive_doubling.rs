//! Recursive-doubling allgather (§2, ref. [1]).
//!
//! `log2(p)` steps for power-of-two `p`: at step `i` rank `id` exchanges
//! its currently-held `2^i·n` elements with rank `id XOR 2^i`. Unlike
//! Bruck, blocks stay in aligned order, so no final rotation is needed —
//! but `p` must be a power of two (MPICH falls back to Bruck otherwise;
//! see [`crate::collectives::dispatch`]).
//!
//! The persistent [`RecursiveDoublingPlan`] exchanges directly through the
//! caller's output buffer (sends are buffered eagerly by the transport, so
//! the aligned send window needs no copy).

use std::marker::PhantomData;

use super::plan::{check_io, trivial_plan, AllgatherPlan, CollectiveAlgorithm, Shape};
use crate::comm::{Comm, Pod};
use crate::error::{Error, Result};

/// The recursive-doubling algorithm (registry entry).
pub struct RecursiveDoubling;

impl<T: Pod> CollectiveAlgorithm<T> for RecursiveDoubling {
    fn name(&self) -> &'static str {
        "recursive-doubling"
    }

    fn summary(&self) -> &'static str {
        "recursive doubling: log2(p) aligned exchanges, power-of-two sizes only"
    }

    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("recursive-doubling", comm, shape) {
            return Ok(p);
        }
        Ok(Box::new(RecursiveDoublingPlan::<T>::new(comm, shape.n)?))
    }
}

/// One XOR exchange of the schedule.
struct Step {
    peer: usize,
    /// First block of the aligned window this rank currently owns.
    base: usize,
    /// First block of the peer's aligned window.
    peer_base: usize,
    /// Window width in blocks.
    dist: usize,
}

/// Persistent recursive-doubling plan.
pub struct RecursiveDoublingPlan<T: Pod> {
    comm: Comm,
    n: usize,
    p: usize,
    id: usize,
    tag_base: u64,
    steps: Vec<Step>,
    _elem: PhantomData<T>,
}

impl<T: Pod> RecursiveDoublingPlan<T> {
    /// Collectively plan the exchange schedule. Errors at plan time on
    /// non-power-of-two communicators.
    pub fn new(comm: &Comm, n: usize) -> Result<RecursiveDoublingPlan<T>> {
        let p = comm.size();
        if !p.is_power_of_two() {
            return Err(Error::Precondition(format!(
                "recursive doubling requires power-of-two size, got {p}"
            )));
        }
        let id = comm.rank();
        let mut steps = Vec::new();
        let mut dist = 1usize;
        while dist < p {
            let peer = id ^ dist;
            steps.push(Step {
                peer,
                base: (id / dist) * dist,
                peer_base: (peer / dist) * dist,
                dist,
            });
            dist <<= 1;
        }
        let tag_base = comm.reserve_coll_tags(steps.len() as u64);
        Ok(RecursiveDoublingPlan {
            comm: comm.retain(),
            n,
            p,
            id,
            tag_base,
            steps,
            _elem: PhantomData,
        })
    }
}

impl<T: Pod> AllgatherPlan<T> for RecursiveDoublingPlan<T> {
    fn algorithm(&self) -> &'static str {
        "recursive-doubling"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.n }
    }

    fn comm_size(&self) -> usize {
        self.p
    }

    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_io(self.n, self.p, input, output)?;
        if self.n == 0 {
            return Ok(());
        }
        let n = self.n;
        output[self.id * n..(self.id + 1) * n].copy_from_slice(input);
        for (i, s) in self.steps.iter().enumerate() {
            let tag = self.tag_base + i as u64;
            // The windows are disjoint (peer differs in the `dist` bit), so
            // we can send from and receive into the output buffer directly.
            let _send =
                self.comm.isend(&output[s.base * n..(s.base + s.dist) * n], s.peer, tag)?;
            let req = self.comm.irecv(s.peer, tag);
            req.wait_into(
                &self.comm,
                &mut output[s.peer_base * n..(s.peer_base + s.dist) * n],
            )?;
        }
        Ok(())
    }
}

/// One-shot convenience wrapper: plan + single execute. Errors on
/// non-power-of-two communicators (unless `local` is empty — the uniform
/// zero-length no-op applies before the precondition).
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&RecursiveDoubling, comm, local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    #[test]
    fn rejects_non_power_of_two() {
        let topo = Topology::regions(3, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).is_err()
        });
        assert!(run.results.iter().all(|&e| e));
    }

    #[test]
    fn plan_rejects_non_power_of_two_at_plan_time() {
        let topo = Topology::regions(3, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            RecursiveDoublingPlan::<u32>::new(c, 4).is_err()
        });
        assert!(run.results.iter().all(|&e| e));
    }
}
