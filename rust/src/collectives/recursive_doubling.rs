//! Recursive-doubling allgather (§2, ref. [1]).
//!
//! `log2(p)` steps for power-of-two `p`: at step `i` rank `id` exchanges
//! its currently-held `2^i·n` elements with rank `id XOR 2^i`. Unlike
//! Bruck, blocks stay in aligned order, so no final rotation is needed —
//! but `p` must be a power of two (MPICH falls back to Bruck otherwise;
//! see [`crate::collectives::dispatch`]).
//!
//! The persistent [`RecursiveDoublingPlan`] exchanges directly through the
//! caller's output buffer (sends are buffered eagerly by the transport, so
//! the aligned send window needs no copy).

use std::marker::PhantomData;

use super::plan::{
    check_io, trivial_plan, AllgatherPlan, CollectiveAlgorithm, CollectivePlan, NamedAlgorithm,
    PlanCore, Shape,
};
use crate::comm::{Comm, Pod};
use crate::error::{Error, Result};

/// The recursive-doubling algorithm (registry entry).
pub struct RecursiveDoubling;

impl NamedAlgorithm for RecursiveDoubling {
    fn name(&self) -> &'static str {
        "recursive-doubling"
    }

    fn summary(&self) -> &'static str {
        "recursive doubling: log2(p) aligned exchanges, power-of-two sizes only"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for RecursiveDoubling {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("recursive-doubling", comm, shape) {
            return Ok(p);
        }
        Ok(Box::new(RecursiveDoublingPlan::<T>::new(comm, shape.n)?))
    }
}

/// One XOR exchange of the schedule.
struct Step {
    peer: usize,
    /// First block of the aligned window this rank currently owns.
    base: usize,
    /// First block of the peer's aligned window.
    peer_base: usize,
    /// Window width in blocks.
    dist: usize,
}

/// Persistent recursive-doubling plan.
pub struct RecursiveDoublingPlan<T: Pod> {
    core: PlanCore,
    steps: Vec<Step>,
    _elem: PhantomData<T>,
}

impl<T: Pod> RecursiveDoublingPlan<T> {
    /// Collectively plan the exchange schedule. Errors at plan time on
    /// non-power-of-two communicators.
    pub fn new(comm: &Comm, n: usize) -> Result<RecursiveDoublingPlan<T>> {
        let p = comm.size();
        if !p.is_power_of_two() {
            return Err(Error::Precondition(format!(
                "recursive doubling requires power-of-two size, got {p}"
            )));
        }
        let id = comm.rank();
        let mut steps = Vec::new();
        let mut dist = 1usize;
        while dist < p {
            let peer = id ^ dist;
            steps.push(Step {
                peer,
                base: (id / dist) * dist,
                peer_base: (peer / dist) * dist,
                dist,
            });
            dist <<= 1;
        }
        Ok(RecursiveDoublingPlan {
            core: PlanCore::new(comm, n, steps.len() as u64),
            steps,
            _elem: PhantomData,
        })
    }
}

impl<T: Pod> CollectivePlan for RecursiveDoublingPlan<T> {
    fn algorithm(&self) -> &'static str {
        "recursive-doubling"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.core.n }
    }

    fn comm_size(&self) -> usize {
        self.core.p
    }
}

impl<T: Pod> AllgatherPlan<T> for RecursiveDoublingPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        let core = &self.core;
        check_io(core.n, core.p, input, output)?;
        if core.n == 0 {
            return Ok(());
        }
        let n = core.n;
        output[core.id * n..(core.id + 1) * n].copy_from_slice(input);
        for (i, s) in self.steps.iter().enumerate() {
            let tag = core.tag(i as u64);
            // The windows are disjoint (peer differs in the `dist` bit), so
            // we can send from and receive into the output buffer directly.
            let _send =
                core.comm.isend(&output[s.base * n..(s.base + s.dist) * n], s.peer, tag)?;
            let req = core.comm.irecv(s.peer, tag);
            req.wait_into(
                &core.comm,
                &mut output[s.peer_base * n..(s.peer_base + s.dist) * n],
            )?;
        }
        Ok(())
    }
}

/// One-shot convenience wrapper: plan + single execute. Errors on
/// non-power-of-two communicators (unless `local` is empty — the uniform
/// zero-length no-op applies before the precondition).
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&RecursiveDoubling, comm, local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    #[test]
    fn rejects_non_power_of_two() {
        let topo = Topology::regions(3, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).is_err()
        });
        assert!(run.results.iter().all(|&e| e));
    }

    #[test]
    fn plan_rejects_non_power_of_two_at_plan_time() {
        let topo = Topology::regions(3, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            RecursiveDoublingPlan::<u32>::new(c, 4).is_err()
        });
        assert!(run.results.iter().all(|&e| e));
    }
}
