//! Recursive-doubling allgather (§2, ref. [1]) as a schedule builder.
//!
//! `log2(p)` steps for power-of-two `p`: at step `i` rank `id` exchanges
//! its currently-held `2^i·n` elements with rank `id XOR 2^i`. Unlike
//! Bruck, blocks stay in aligned order, so no final rotation is needed —
//! but `p` must be a power of two (MPICH falls back to Bruck otherwise;
//! see [`crate::collectives::dispatch`]).
//!
//! The schedule exchanges directly through the caller's output buffer
//! (the XOR windows are disjoint, and sends are buffered eagerly).

use super::plan::{
    trivial_plan, AllgatherPlan, CollectiveAlgorithm, NamedAlgorithm, OpKind, PlanSpec,
};
use super::schedule::{SchedPlan, Schedule, ScheduleBuilder, Slice};
use crate::comm::{Comm, Pod};
use crate::error::{Error, Result};

/// The recursive-doubling algorithm (registry entry).
pub struct RecursiveDoubling;

impl NamedAlgorithm for RecursiveDoubling {
    fn name(&self) -> &'static str {
        "recursive-doubling"
    }

    fn summary(&self) -> &'static str {
        "recursive doubling: log2(p) aligned exchanges, power-of-two sizes only"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for RecursiveDoubling {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("recursive-doubling", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("recursive-doubling")?;
        let sched = build_schedule(comm.size(), comm.rank(), n, std::mem::size_of::<T>())?;
        Ok(SchedPlan::<T>::boxed(comm, "recursive-doubling", sched)?)
    }
}

/// Build the recursive-doubling schedule for one rank (pure; SPMD).
/// Errors on non-power-of-two communicators — the plan-time precondition.
pub fn build_schedule(
    p: usize,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    if !p.is_power_of_two() {
        return Err(Error::Precondition(format!(
            "recursive doubling requires power-of-two size, got {p}"
        )));
    }
    let mut sb = ScheduleBuilder::new("recursive doubling");
    sb.copy(Slice::input(0, n), Slice::output(rank * n, n));
    let mut dist = 1usize;
    while dist < p {
        let tag = sb.tag();
        let peer = rank ^ dist;
        let base = (rank / dist) * dist;
        let peer_base = (peer / dist) * dist;
        // The windows are disjoint (peer differs in the `dist` bit), so the
        // exchange runs through the output buffer directly.
        sb.sendrecv(
            peer,
            Slice::output(base * n, dist * n),
            peer,
            Slice::output(peer_base * n, dist * n),
            tag,
            0,
        );
        dist <<= 1;
    }
    Ok(sb.finish(OpKind::Allgather, p, n, elem_bytes, "recursive-doubling"))
}

/// One-shot convenience wrapper: plan + single execute. Errors on
/// non-power-of-two communicators (unless `local` is empty — the uniform
/// zero-length no-op applies before the precondition).
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&RecursiveDoubling, comm, local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    #[test]
    fn rejects_non_power_of_two() {
        let topo = Topology::regions(3, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).is_err()
        });
        assert!(run.results.iter().all(|&e| e));
    }

    #[test]
    fn schedule_rejects_non_power_of_two_at_build_time() {
        let err = build_schedule(6, 0, 4, 8).unwrap_err().to_string();
        assert!(err.contains("power-of-two"), "{err}");
    }

    #[test]
    fn schedule_is_scratch_free_with_aligned_windows() {
        let sched = build_schedule(8, 3, 2, 4).unwrap();
        assert!(sched.scratch.is_empty());
        assert_eq!(sched.tags, 3);
        sched.validate().unwrap();
    }
}
