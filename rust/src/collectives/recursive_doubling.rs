//! Recursive-doubling allgather (§2, ref. [1]).
//!
//! `log2(p)` steps for power-of-two `p`: at step `i` rank `id` exchanges
//! its currently-held `2^i·n` elements with rank `id XOR 2^i`. Unlike
//! Bruck, blocks stay in aligned order, so no final rotation is needed —
//! but `p` must be a power of two (MPICH falls back to Bruck otherwise;
//! see [`crate::collectives::dispatch`]).

use crate::comm::{Comm, Pod};
use crate::error::{Error, Result};

/// Recursive-doubling allgather of `local` (length `n`); returns `n·p`
/// elements in rank order. Errors on non-power-of-two communicators.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    let p = comm.size();
    if !p.is_power_of_two() {
        return Err(Error::Precondition(format!(
            "recursive doubling requires power-of-two size, got {p}"
        )));
    }
    let id = comm.rank();
    let n = local.len();
    let tag = comm.next_coll_tag();

    let mut out = vec![T::default(); n * p];
    out[id * n..(id + 1) * n].copy_from_slice(local);

    let mut dist = 1usize;
    let mut step = 0u64;
    while dist < p {
        let peer = id ^ dist;
        // The aligned window of 'dist' blocks this rank currently owns.
        let base = (id / dist) * dist;
        let send = out[base * n..(base + dist) * n].to_vec();
        let _req = comm.isend(&send, peer, tag + step)?;
        let got: Vec<T> = comm.irecv(peer, tag + step).wait(comm)?;
        debug_assert_eq!(got.len(), dist * n);
        let peer_base = (peer / dist) * dist;
        out[peer_base * n..(peer_base + dist) * n].copy_from_slice(&got);
        dist <<= 1;
        step += 1;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    #[test]
    fn rejects_non_power_of_two() {
        let topo = Topology::regions(3, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).is_err()
        });
        assert!(run.results.iter().all(|&e| e));
    }
}
