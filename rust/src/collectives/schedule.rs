//! The communication-schedule IR: every collective as **data**, executed
//! by one generic interpreter.
//!
//! A [`Schedule`] is an ordered list of [`Round`]s of [`Step`]s with
//! byte-exact buffer slices. Every registered (operation, algorithm) pair
//! *plans* by building a schedule — a pure function of `(topology, rank,
//! shape)` — and *executes* through the single interpreter in
//! [`SchedPlan`]. Nothing about an algorithm lives in imperative execute
//! loops anymore: locality counts, cost prediction
//! ([`crate::model::cost`]), tracing (`locag explain`) and execution all
//! read the same schedule.
//!
//! ## IR ↔ paper mapping (§4)
//!
//! The paper's cost formulas are sums of per-message postal terms
//! `α_c + β_c·s` over the steps of an algorithm (Eq. 2–4). The IR makes
//! that sum mechanical:
//!
//! * a [`Step::Send`]/[`Step::SendRecv`] of `s` bytes to a peer in
//!   locality class `c` contributes exactly one `α_c + β_c·s` term —
//!   Eq. 3's `⌈log₂ p⌉` terms are standard Bruck's `⌈log₂ p⌉` `SendRecv`
//!   steps, Eq. 4's `⌈log_pℓ(r)⌉` non-local terms are the locality-aware
//!   Bruck's non-local `SendRecv` steps;
//! * [`Step::CopyLocal`] / [`Step::Rotate`] are the un-charged data
//!   movement the paper folds into its constants (the final rotation of
//!   Algorithm 1, pack/unpack, reorders);
//! * [`Step::Recv`] synchronizes the receiver's clock to the sender's
//!   post-charge stamp, which is how per-process postal costs compose
//!   into a completion time ([`crate::model::cost::predict`]).
//!
//! ## SPMD construction
//!
//! Schedules are built rank-by-rank (SPMD, like the MPI programs they
//! model): each rank's builder runs the same control flow and therefore
//! reserves the same number of collective tags, but emits only its own
//! steps. Building the schedule of *another* rank is the same function
//! with a different `rank` argument — which is what lets the model-tuned
//! dispatcher ([`super::model_tuned`]) and [`crate::model::cost`] evaluate
//! whole-world schedules without executing them.

use std::collections::{HashMap, VecDeque};

use crate::comm::{as_bytes, as_bytes_mut, copy_into, write_bytes, Comm, Pod};
use crate::error::{Error, Result};
use crate::topology::Topology;

use super::grouping::{split_members, GroupBy};
use super::plan::{
    check_a2a_io, check_io, check_reduce_io, check_rs_io, CollectivePlan, ElemKind, OpKind,
    PlanCore, Shape, Summable, ViewElem,
};

/// Identifies one of the buffers a schedule operates on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufId {
    /// The caller's read-only input buffer.
    Input,
    /// The caller's output buffer.
    Output,
    /// The `i`-th plan-owned scratch buffer (lengths in
    /// [`Schedule::scratch`]).
    Scratch(usize),
}

/// An element range within one buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slice {
    pub buf: BufId,
    /// Element offset.
    pub off: usize,
    /// Element count.
    pub len: usize,
}

impl Slice {
    /// A slice of an arbitrary buffer.
    pub fn at(buf: BufId, off: usize, len: usize) -> Slice {
        Slice { buf, off, len }
    }

    /// A slice of the input buffer.
    pub fn input(off: usize, len: usize) -> Slice {
        Slice { buf: BufId::Input, off, len }
    }

    /// A slice of the output buffer.
    pub fn output(off: usize, len: usize) -> Slice {
        Slice { buf: BufId::Output, off, len }
    }

    fn range(&self) -> std::ops::Range<usize> {
        self.off..self.off + self.len
    }
}

/// One operation of a schedule. Peers are communicator ranks; tags are
/// indices into the plan's reserved tag block; `pad` is extra wire bytes
/// charged on the message (protocol headers, e.g. the dissemination
/// allgather's per-block origin headers).
#[derive(Debug, Clone)]
pub enum Step {
    /// Post a (buffered, eager) send of `src` to rank `to`.
    Send { to: usize, src: Slice, tag: u64, pad: usize },
    /// Blocking receive from rank `from` into `dst`.
    Recv { from: usize, dst: Slice, tag: u64, pad: usize },
    /// Post the send, then block on the receive (the `Isend`/`Recv` pair
    /// every exchange-structured algorithm is written as).
    SendRecv { to: usize, src: Slice, from: usize, dst: Slice, tag: u64, pad: usize },
    /// Local copy between two distinct buffers.
    CopyLocal { src: Slice, dst: Slice },
    /// Elementwise reduction `dst ⊕= src` (requires a reducing executor).
    Reduce { src: Slice, dst: Slice },
    /// Block rotation: writing block `j` of `src` to block
    /// `(j + shift) mod w` of `dst`, with `w = len / block` blocks — the
    /// final reorder of Bruck-structured algorithms.
    Rotate { src: Slice, dst: Slice, block: usize, shift: usize },
}

impl Step {
    /// The send half of this step, if any: `(to, payload elems, pad)`.
    pub fn send_part(&self) -> Option<(usize, usize, usize)> {
        match self {
            Step::Send { to, src, pad, .. } | Step::SendRecv { to, src, pad, .. } => {
                Some((*to, src.len, *pad))
            }
            _ => None,
        }
    }
}

/// A group of consecutive steps under one label (phase / algorithm step);
/// purely descriptive — execution and cost evaluation are per-step.
#[derive(Debug, Clone, Default)]
pub struct Round {
    pub label: String,
    pub steps: Vec<Step>,
}

/// One rank's complete communication schedule for one planned collective.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// The operation this schedule implements.
    pub op: OpKind,
    /// Communicator size.
    pub p: usize,
    /// Per-rank element count (the plan [`Shape`]).
    pub n: usize,
    /// Element size in bytes (fixed at plan time; wire sizes are
    /// `len · elem_bytes + pad`).
    pub elem_bytes: usize,
    /// Which builder produced this schedule (e.g. `"bruck"`, or
    /// `"model-tuned[ring]"` after dispatcher selection).
    pub label: String,
    pub rounds: Vec<Round>,
    /// Scratch buffer lengths, in elements.
    pub scratch: Vec<usize>,
    /// Number of collective tags the schedule needs (identical on every
    /// rank of the communicator — tag allocation is part of the SPMD
    /// builder contract).
    pub tags: u64,
    /// Explicit `(input, output)` element lengths, overriding the
    /// single-operation shapes derived from `op`/`n`. `None` for every
    /// builder-produced schedule; `Some` for composite schedules whose
    /// buffers concatenate several constituents' (see
    /// [`super::fuse::fuse`]).
    pub io: Option<(usize, usize)>,
}

impl Schedule {
    /// Total number of steps across all rounds.
    pub fn num_steps(&self) -> usize {
        self.rounds.iter().map(|r| r.steps.len()).sum()
    }

    /// Iterate over every step in execution order.
    pub fn steps(&self) -> impl Iterator<Item = &Step> + '_ {
        self.rounds.iter().flat_map(|r| r.steps.iter())
    }

    /// Wire bytes of a payload of `len` elements plus `pad` header bytes.
    pub fn wire_bytes(&self, len: usize, pad: usize) -> usize {
        len * self.elem_bytes + pad
    }

    /// Largest padded message (bytes); sizes the reusable wire buffer.
    /// A `SendRecv` counts both halves — they may differ in length.
    pub(crate) fn max_padded_wire(&self) -> usize {
        let mut max = 0usize;
        for s in self.steps() {
            let (len, pad) = match s {
                Step::Send { src, pad, .. } => (src.len, *pad),
                Step::Recv { dst, pad, .. } => (dst.len, *pad),
                Step::SendRecv { src, dst, pad, .. } => (src.len.max(dst.len), *pad),
                _ => continue,
            };
            if pad > 0 {
                max = max.max(self.wire_bytes(len, pad));
            }
        }
        max
    }

    /// Expected input/output lengths: the [`Schedule::io`] override when
    /// present (composite schedules), else this schedule's operation shape.
    pub fn io_lens(&self) -> (usize, usize) {
        if let Some(io) = self.io {
            return io;
        }
        match self.op {
            OpKind::Allgather | OpKind::Allgatherv => (self.n, self.n * self.p),
            OpKind::Allreduce => (self.n, self.n),
            OpKind::Alltoall => (self.n * self.p, self.n * self.p),
            OpKind::ReduceScatter | OpKind::ReduceScatterV => (self.n * self.p, self.n),
        }
    }

    /// Rescale this schedule to byte granularity (`elem_bytes == 1`):
    /// every slice offset/length, scratch length, rotate block size and
    /// io length is multiplied by the old `elem_bytes`. Wire sizes
    /// (`len·elem_bytes + pad`), padding, message count and tags are all
    /// unchanged — the rescaled schedule moves exactly the same bytes and
    /// costs exactly the same under the postal model. This is what lets
    /// constituents of *different* element types fuse into one
    /// byte-granular composite schedule (see
    /// [`super::fuse::fuse_world_mixed`]).
    pub fn scale_to_bytes(&self) -> Schedule {
        let eb = self.elem_bytes;
        if eb == 1 {
            return self.clone();
        }
        let sc = |s: &Slice| Slice { buf: s.buf, off: s.off * eb, len: s.len * eb };
        let rounds = self
            .rounds
            .iter()
            .map(|r| Round {
                label: r.label.clone(),
                steps: r
                    .steps
                    .iter()
                    .map(|st| match st {
                        Step::Send { to, src, tag, pad } => {
                            Step::Send { to: *to, src: sc(src), tag: *tag, pad: *pad }
                        }
                        Step::Recv { from, dst, tag, pad } => {
                            Step::Recv { from: *from, dst: sc(dst), tag: *tag, pad: *pad }
                        }
                        Step::SendRecv { to, src, from, dst, tag, pad } => Step::SendRecv {
                            to: *to,
                            src: sc(src),
                            from: *from,
                            dst: sc(dst),
                            tag: *tag,
                            pad: *pad,
                        },
                        Step::CopyLocal { src, dst } => {
                            Step::CopyLocal { src: sc(src), dst: sc(dst) }
                        }
                        Step::Reduce { src, dst } => Step::Reduce { src: sc(src), dst: sc(dst) },
                        Step::Rotate { src, dst, block, shift } => Step::Rotate {
                            src: sc(src),
                            dst: sc(dst),
                            block: block * eb,
                            shift: *shift,
                        },
                    })
                    .collect(),
            })
            .collect();
        let (il, ol) = self.io_lens();
        Schedule {
            op: self.op,
            p: self.p,
            n: self.n * eb,
            elem_bytes: 1,
            label: self.label.clone(),
            rounds,
            scratch: self.scratch.iter().map(|&l| l * eb).collect(),
            tags: self.tags,
            io: Some((il * eb, ol * eb)),
        }
    }

    /// Structural validation: slice bounds, peer ranks, tag indices,
    /// distinct buffers for local steps. Run once at plan time so the
    /// interpreter can index without re-checking.
    pub fn validate(&self) -> Result<()> {
        let (in_len, out_len) = self.io_lens();
        let buf_len = |b: BufId| -> Result<usize> {
            match b {
                BufId::Input => Ok(in_len),
                BufId::Output => Ok(out_len),
                BufId::Scratch(i) => self.scratch.get(i).copied().ok_or_else(|| {
                    Error::Precondition(format!("schedule references scratch {i} of {}",
                        self.scratch.len()))
                }),
            }
        };
        let check_slice = |s: &Slice| -> Result<()> {
            let len = buf_len(s.buf)?;
            if s.off + s.len > len {
                return Err(Error::Precondition(format!(
                    "schedule slice {:?} out of bounds (buffer len {len})",
                    s
                )));
            }
            Ok(())
        };
        let check_peer = |r: usize| -> Result<()> {
            if r >= self.p {
                return Err(Error::RankOutOfRange { rank: r, size: self.p });
            }
            Ok(())
        };
        let check_tag = |t: u64| -> Result<()> {
            if t >= self.tags {
                return Err(Error::Precondition(format!(
                    "schedule tag {t} outside reserved block of {}",
                    self.tags
                )));
            }
            Ok(())
        };
        let check_local = |src: &Slice, dst: &Slice| -> Result<()> {
            if src.buf == dst.buf {
                return Err(Error::Precondition(
                    "local schedule step must use distinct buffers".into(),
                ));
            }
            if dst.buf == BufId::Input {
                return Err(Error::Precondition("schedule writes to the input buffer".into()));
            }
            Ok(())
        };
        for s in self.steps() {
            match s {
                Step::Send { to, src, tag, .. } => {
                    check_peer(*to)?;
                    check_slice(src)?;
                    check_tag(*tag)?;
                }
                Step::Recv { from, dst, tag, .. } => {
                    check_peer(*from)?;
                    check_slice(dst)?;
                    check_tag(*tag)?;
                    if dst.buf == BufId::Input {
                        return Err(Error::Precondition(
                            "schedule receives into the input buffer".into(),
                        ));
                    }
                }
                Step::SendRecv { to, src, from, dst, tag, .. } => {
                    check_peer(*to)?;
                    check_peer(*from)?;
                    check_slice(src)?;
                    check_slice(dst)?;
                    check_tag(*tag)?;
                    if dst.buf == BufId::Input {
                        return Err(Error::Precondition(
                            "schedule receives into the input buffer".into(),
                        ));
                    }
                }
                Step::CopyLocal { src, dst } | Step::Reduce { src, dst } => {
                    check_slice(src)?;
                    check_slice(dst)?;
                    check_local(src, dst)?;
                    if src.len != dst.len {
                        return Err(Error::SizeMismatch { expected: src.len, got: dst.len });
                    }
                }
                Step::Rotate { src, dst, block, .. } => {
                    check_slice(src)?;
                    check_slice(dst)?;
                    check_local(src, dst)?;
                    if src.len != dst.len {
                        return Err(Error::SizeMismatch { expected: src.len, got: dst.len });
                    }
                    if *block == 0 || src.len % block != 0 {
                        return Err(Error::Precondition(format!(
                            "rotate block {block} does not divide slice length {}",
                            src.len
                        )));
                    }
                }
            }
        }
        Ok(())
    }
}

/// Incremental [`Schedule`] construction (used by every algorithm's
/// builder). Tag and scratch allocation go through the builder so the
/// SPMD tag-uniformity contract has a single enforcement point: helpers
/// that *may* emit nothing (non-member ranks) still allocate their tags.
pub struct ScheduleBuilder {
    rounds: Vec<Round>,
    cur_label: String,
    cur: Vec<Step>,
    scratch: Vec<usize>,
    tags: u64,
}

impl ScheduleBuilder {
    /// Start a schedule; `label` names the first round.
    pub fn new(label: &str) -> ScheduleBuilder {
        ScheduleBuilder {
            rounds: Vec::new(),
            cur_label: label.to_string(),
            cur: Vec::new(),
            scratch: Vec::new(),
            tags: 0,
        }
    }

    /// Close the current round (if non-empty) and start a new one.
    pub fn round(&mut self, label: impl Into<String>) {
        let label = label.into();
        if !self.cur.is_empty() {
            let steps = std::mem::take(&mut self.cur);
            self.rounds.push(Round { label: std::mem::replace(&mut self.cur_label, label), steps });
        } else {
            self.cur_label = label;
        }
    }

    /// Register a scratch buffer of `len` elements.
    pub fn scratch(&mut self, len: usize) -> BufId {
        self.scratch.push(len);
        BufId::Scratch(self.scratch.len() - 1)
    }

    /// Allocate one tag index.
    pub fn tag(&mut self) -> u64 {
        self.tag_block(1)
    }

    /// Allocate a block of `count` consecutive tag indices; returns the
    /// first. Must be called identically on every rank.
    pub fn tag_block(&mut self, count: u64) -> u64 {
        let t = self.tags;
        self.tags += count;
        t
    }

    /// Append a raw step.
    pub fn push(&mut self, step: Step) {
        self.cur.push(step);
    }

    /// Append a [`Step::CopyLocal`].
    pub fn copy(&mut self, src: Slice, dst: Slice) {
        self.push(Step::CopyLocal { src, dst });
    }

    /// Append a [`Step::Reduce`].
    pub fn reduce(&mut self, src: Slice, dst: Slice) {
        self.push(Step::Reduce { src, dst });
    }

    /// Append a [`Step::Rotate`].
    pub fn rotate(&mut self, src: Slice, dst: Slice, block: usize, shift: usize) {
        self.push(Step::Rotate { src, dst, block, shift });
    }

    /// Append a [`Step::Send`].
    pub fn send(&mut self, to: usize, src: Slice, tag: u64, pad: usize) {
        self.push(Step::Send { to, src, tag, pad });
    }

    /// Append a [`Step::Recv`].
    pub fn recv(&mut self, from: usize, dst: Slice, tag: u64, pad: usize) {
        self.push(Step::Recv { from, dst, tag, pad });
    }

    /// Append a [`Step::SendRecv`].
    pub fn sendrecv(
        &mut self,
        to: usize,
        src: Slice,
        from: usize,
        dst: Slice,
        tag: u64,
        pad: usize,
    ) {
        self.push(Step::SendRecv { to, src, from, dst, tag, pad });
    }

    /// Seal the schedule.
    pub fn finish(
        mut self,
        op: OpKind,
        p: usize,
        n: usize,
        elem_bytes: usize,
        label: impl Into<String>,
    ) -> Schedule {
        if !self.cur.is_empty() {
            let steps = std::mem::take(&mut self.cur);
            self.rounds.push(Round { label: self.cur_label.clone(), steps });
        }
        Schedule {
            op,
            p,
            n,
            elem_bytes,
            label: label.into(),
            rounds: self.rounds,
            scratch: self.scratch,
            tags: self.tags,
            io: None,
        }
    }
}

// ---------------------------------------------------------------------------
// world view: everything a builder needs to construct ANY rank's schedule
// ---------------------------------------------------------------------------

/// Topology-derived context for schedule builders: communicator size, the
/// comm-rank → world-rank map and the topology. Pure data — building a
/// schedule for any rank requires no communicator handle, which is what
/// lets the model-tuned dispatcher and the cost model enumerate
/// whole-world schedules at plan time.
#[derive(Debug, Clone)]
pub struct WorldView {
    pub p: usize,
    /// Communicator rank → world rank.
    pub world_of: Vec<usize>,
    pub topo: Topology,
}

impl WorldView {
    /// The view of a live communicator.
    pub fn from_comm(comm: &Comm) -> WorldView {
        WorldView {
            p: comm.size(),
            world_of: (0..comm.size()).map(|r| comm.world_rank_of(r)).collect(),
            topo: comm.topology().clone(),
        }
    }

    /// The view of a whole world (comm rank == world rank) — what the CLI
    /// and cost evaluation use.
    pub fn world(topo: &Topology) -> WorldView {
        WorldView {
            p: topo.size(),
            world_of: (0..topo.size()).collect(),
            topo: topo.clone(),
        }
    }

    /// Group a set of communicator ranks by a topology attribute; groups
    /// sorted by smallest member, members ascending.
    pub fn split(&self, ranks: &[usize], by: GroupBy) -> Vec<Vec<usize>> {
        split_members(&self.topo, &self.world_of, ranks, by)
    }

    /// Region groups of the full communicator.
    pub fn regions(&self) -> Vec<Vec<usize>> {
        let all: Vec<usize> = (0..self.p).collect();
        self.split(&all, GroupBy::Region)
    }
}

/// Locate `rank` within `groups`: `(group index, index within group)`.
pub fn locate(groups: &[Vec<usize>], rank: usize) -> Result<(usize, usize)> {
    for (gi, members) in groups.iter().enumerate() {
        if let Some(j) = members.iter().position(|&r| r == rank) {
            return Ok((gi, j));
        }
    }
    Err(Error::Precondition(format!("rank {rank} not in any group")))
}

/// Uniform group size, or a descriptive error.
pub fn uniform_size(groups: &[Vec<usize>], algo: &str) -> Result<usize> {
    let first = groups.first().map_or(0, |g| g.len());
    if first == 0 || groups.iter().any(|g| g.len() != first) {
        return Err(Error::Precondition(format!(
            "{algo} requires equal-size groups; got sizes {:?}",
            groups.iter().map(|g| g.len()).collect::<Vec<_>>()
        )));
    }
    Ok(first)
}

// ---------------------------------------------------------------------------
// shared sub-schedule emitters
// ---------------------------------------------------------------------------

/// Tag-block size of a Bruck-structured exchange over `q` members
/// (`⌈log₂ q⌉`, and 0 for the degenerate single-member group).
pub(crate) fn ceil_log2_u64(q: usize) -> u64 {
    if q <= 1 {
        0
    } else {
        crate::util::ilog2_ceil(q) as u64
    }
}

/// Emit a Bruck allgather among `members` (each contributing `b` elements)
/// into `dst` (length `b · members.len()`, member-major). Ranks outside
/// `members` allocate the tag block and emit nothing (the SPMD contract).
pub fn emit_group_bruck(
    sb: &mut ScheduleBuilder,
    members: &[usize],
    me: usize,
    b: usize,
    contrib: Slice,
    dst: Slice,
) {
    let q = members.len();
    let tag0 = sb.tag_block(ceil_log2_u64(q));
    let Some(k) = members.iter().position(|&r| r == me) else {
        return;
    };
    if q == 1 {
        sb.copy(contrib, dst);
        return;
    }
    let rot = sb.scratch(b * q);
    sb.copy(contrib, Slice::at(rot, 0, b));
    let mut filled = b;
    let mut dist = 1usize;
    let mut ti = 0u64;
    while dist < q {
        let blocks = dist.min(q - dist);
        let to = members[(k + q - dist) % q];
        let from = members[(k + dist) % q];
        sb.sendrecv(
            to,
            Slice::at(rot, 0, blocks * b),
            from,
            Slice::at(rot, filled, blocks * b),
            tag0 + ti,
            0,
        );
        filled += blocks * b;
        dist <<= 1;
        ti += 1;
    }
    // rotated block j holds member (k + j) mod q → rotate down by k.
    sb.rotate(Slice::at(rot, 0, b * q), dst, b, k);
}

/// Emit a Bruck-structured allgatherv among `members` with fixed per-member
/// `counts` into `dst` (length `Σ counts`, member-major). Mirrors the
/// classic plan: zero-length exchange messages are still sent (and
/// charged), exactly like the imperative implementation it replaces.
pub fn emit_group_allgatherv(
    sb: &mut ScheduleBuilder,
    members: &[usize],
    me: usize,
    counts: &[usize],
    contrib: Slice,
    dst: Slice,
) {
    let q = members.len();
    debug_assert_eq!(counts.len(), q);
    let tag0 = sb.tag_block(ceil_log2_u64(q));
    let Some(k) = members.iter().position(|&r| r == me) else {
        return;
    };
    if q == 1 {
        if counts[0] > 0 {
            sb.copy(contrib, dst);
        }
        return;
    }
    // Rotated offsets: rot_off[j] = offset of member (k + j) mod q's block.
    let mut rot_off = vec![0usize; q + 1];
    for j in 0..q {
        rot_off[j + 1] = rot_off[j] + counts[(k + j) % q];
    }
    let total = rot_off[q];
    let mut out_off = vec![0usize; q];
    let mut acc = 0usize;
    for (r, &c) in counts.iter().enumerate() {
        out_off[r] = acc;
        acc += c;
    }
    let rot = sb.scratch(total);
    if counts[k] > 0 {
        sb.copy(contrib, Slice::at(rot, 0, counts[k]));
    }
    let mut dist = 1usize;
    let mut ti = 0u64;
    while dist < q {
        let nblocks = dist.min(q - dist);
        let send_len = rot_off[nblocks];
        let recv_off = rot_off[dist];
        let recv_len = rot_off[dist + nblocks] - recv_off;
        sb.sendrecv(
            members[(k + q - dist) % q],
            Slice::at(rot, 0, send_len),
            members[(k + dist) % q],
            Slice::at(rot, recv_off, recv_len),
            tag0 + ti,
            0,
        );
        dist <<= 1;
        ti += 1;
    }
    for j in 0..q {
        let r = (k + j) % q;
        let c = counts[r];
        if c > 0 {
            sb.copy(Slice::at(rot, rot_off[j], c), Slice::at(dst.buf, dst.off + out_off[r], c));
        }
    }
}

/// Emit a recursive-doubling sum-allreduce among `members`, operating
/// in-place on `Output[0..n]` with a private receive scratch. Errors at
/// build time unless the group size is a power of two.
pub fn emit_group_rd_allreduce(
    sb: &mut ScheduleBuilder,
    members: &[usize],
    me: usize,
    n: usize,
) -> Result<()> {
    let q = members.len();
    if !q.is_power_of_two() {
        return Err(Error::Precondition(format!(
            "recursive-doubling allreduce requires power-of-two size, got {q}"
        )));
    }
    let tag0 = sb.tag_block(ceil_log2_u64(q));
    let Some(k) = members.iter().position(|&r| r == me) else {
        return Ok(());
    };
    if q == 1 {
        return Ok(());
    }
    let recv = sb.scratch(n);
    let mut dist = 1usize;
    let mut ti = 0u64;
    while dist < q {
        let peer = members[k ^ dist];
        sb.sendrecv(peer, Slice::output(0, n), peer, Slice::at(recv, 0, n), tag0 + ti, 0);
        sb.reduce(Slice::at(recv, 0, n), Slice::output(0, n));
        dist <<= 1;
        ti += 1;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// the generic interpreter
// ---------------------------------------------------------------------------

/// Elementwise `acc[i] = acc[i] + x[i]` — the reducer handed to the
/// interpreter by reducing operations.
pub(crate) fn add_assign<T: Summable>(acc: &mut [T], x: &[T]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, b) in acc.iter_mut().zip(x) {
        *a = *a + *b;
    }
}

/// Resolve a local two-buffer step into `(read, write)` slices and apply
/// `f`. Buffers must be distinct ([`Schedule::validate`] enforces it).
fn with_pair<T: Pod>(
    input: &[T],
    output: &mut [T],
    scratch: &mut [Vec<T>],
    src: &Slice,
    dst: &Slice,
    f: impl FnOnce(&[T], &mut [T]),
) -> Result<()> {
    match (src.buf, dst.buf) {
        (BufId::Input, BufId::Output) => f(&input[src.range()], &mut output[dst.range()]),
        (BufId::Input, BufId::Scratch(j)) => f(&input[src.range()], &mut scratch[j][dst.range()]),
        (BufId::Output, BufId::Scratch(j)) => f(&output[src.range()], &mut scratch[j][dst.range()]),
        (BufId::Scratch(i), BufId::Output) => f(&scratch[i][src.range()], &mut output[dst.range()]),
        (BufId::Scratch(i), BufId::Scratch(j)) if i < j => {
            let (lo, hi) = scratch.split_at_mut(j);
            f(&lo[i][src.range()], &mut hi[0][dst.range()]);
        }
        (BufId::Scratch(i), BufId::Scratch(j)) if i > j => {
            let (lo, hi) = scratch.split_at_mut(i);
            f(&hi[0][src.range()], &mut lo[j][dst.range()]);
        }
        _ => {
            return Err(Error::Precondition(
                "local schedule step must use distinct buffers with a writable destination".into(),
            ))
        }
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn send_slice<T: Pod>(
    core: &PlanCore,
    input: &[T],
    output: &[T],
    scratch: &[Vec<T>],
    wire: &mut [u8],
    to: usize,
    src: &Slice,
    tag: u64,
    pad: usize,
) -> Result<()> {
    let buf: &[T] = match src.buf {
        BufId::Input => &input[src.range()],
        BufId::Output => &output[src.range()],
        BufId::Scratch(i) => &scratch[i][src.range()],
    };
    let t = core.tag(tag);
    if pad == 0 {
        let _req = core.comm.isend(buf, to, t)?;
    } else {
        let total = pad + std::mem::size_of_val(buf);
        let w = &mut wire[..total];
        w[..pad].fill(0);
        let ok = write_bytes(buf, &mut w[pad..]);
        debug_assert!(ok);
        let _req = core.comm.isend(&w[..total], to, t)?;
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn recv_slice<T: Pod>(
    core: &PlanCore,
    output: &mut [T],
    scratch: &mut [Vec<T>],
    wire: &mut [u8],
    from: usize,
    dst: &Slice,
    tag: u64,
    pad: usize,
) -> Result<()> {
    let t = core.tag(tag);
    let buf: &mut [T] = match dst.buf {
        BufId::Output => &mut output[dst.range()],
        BufId::Scratch(i) => &mut scratch[i][dst.range()],
        BufId::Input => {
            return Err(Error::Precondition("schedule receives into the input buffer".into()))
        }
    };
    if pad == 0 {
        core.comm.recv_into(from, t, buf)
    } else {
        let total = pad + std::mem::size_of_val(&*buf);
        core.comm.recv_into(from, t, &mut wire[..total])?;
        if !copy_into(&wire[pad..total], buf) {
            return Err(Error::SizeMismatch {
                expected: std::mem::size_of_val(&*buf),
                got: total - pad,
            });
        }
        Ok(())
    }
}

/// The one generic executor: interpret `sched` over the plan's retained
/// communicator. `reduce` is `Some` only for reducing operations; a
/// schedule containing [`Step::Reduce`] fails cleanly without one.
/// Shared by [`SchedPlan`] and the fused executor
/// ([`super::plan::FusedPlan`]).
pub(crate) fn execute_schedule<T: Pod>(
    core: &PlanCore,
    sched: &Schedule,
    input: &[T],
    output: &mut [T],
    scratch: &mut [Vec<T>],
    wire: &mut [u8],
    reduce: Option<fn(&mut [T], &[T])>,
) -> Result<()> {
    for round in &sched.rounds {
        for step in &round.steps {
            match step {
                Step::Send { to, src, tag, pad } => {
                    send_slice(core, input, output, scratch, wire, *to, src, *tag, *pad)?;
                }
                Step::Recv { from, dst, tag, pad } => {
                    recv_slice(core, output, scratch, wire, *from, dst, *tag, *pad)?;
                }
                Step::SendRecv { to, src, from, dst, tag, pad } => {
                    send_slice(core, input, output, scratch, wire, *to, src, *tag, *pad)?;
                    recv_slice(core, output, scratch, wire, *from, dst, *tag, *pad)?;
                }
                Step::CopyLocal { src, dst } => {
                    with_pair(input, output, scratch, src, dst, |s, d| d.copy_from_slice(s))?;
                }
                Step::Reduce { src, dst } => {
                    let f = reduce.ok_or_else(|| {
                        Error::Precondition(
                            "schedule contains Reduce but the operation is not a reduction".into(),
                        )
                    })?;
                    with_pair(input, output, scratch, src, dst, |s, d| f(d, s))?;
                }
                Step::Rotate { src, dst, block, shift } => {
                    with_pair(input, output, scratch, src, dst, |s, d| {
                        super::bruck::rotate_down_into(s, *block, *shift, d)
                    })?;
                }
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// segmented buffer views + the zero-copy view executor
// ---------------------------------------------------------------------------

/// A read-only **segmented buffer view**: an ordered list of caller-owned
/// byte segments presented to the interpreter as one composite address
/// space, so a fused K-constituent execute reads each request's buffer in
/// place — no staging memcpys.
///
/// ## Segments ↔ the IR's element-exact slices
///
/// A [`Slice`] addresses `off..off+len` *elements* of a logical buffer;
/// the view executor multiplies by [`Schedule::elem_bytes`] and resolves
/// the resulting byte range against the view's segments (segment `i`
/// covers bytes `start_i..start_i+len_i` of the composite space, where
/// `start_i` is the sum of the preceding segment lengths). **A slice
/// never spans a segment boundary**: fusion windows each constituent's
/// input/output into a disjoint `[in_off, in_off+in_len)` range and remaps
/// every constituent slice inside its own window (the part maps of
/// [`super::fuse::fuse`]), so as long as view segment `i` is exactly
/// constituent `i`'s buffer, every remapped slice falls inside exactly one
/// segment. Resolution therefore returns a plain contiguous `&[u8]`; a
/// range that does cross a boundary is a caller error (wrong segment
/// list) and is reported, not silently split.
///
/// Each segment carries an [`ElemKind`] so reductions recover element
/// types per segment — that is what lets one fused plan mix `f32` and
/// `u64` constituents ([`super::plan::FusedPlanMixed`]).
#[derive(Default)]
pub struct IoView<'a> {
    segs: Vec<(&'a [u8], ElemKind)>,
    /// Cumulative byte start of each segment.
    starts: Vec<usize>,
    total: usize,
}

impl<'a> IoView<'a> {
    /// An empty view (push segments in constituent order).
    pub fn new() -> IoView<'a> {
        IoView::default()
    }

    /// Single-segment view over one typed buffer.
    pub fn of<T: ViewElem>(seg: &'a [T]) -> IoView<'a> {
        let mut v = IoView::new();
        v.push::<T>(seg);
        v
    }

    /// Append a typed segment (its [`ElemKind`] comes from `T`).
    pub fn push<T: ViewElem>(&mut self, seg: &'a [T]) {
        self.push_bytes(as_bytes(seg), T::KIND);
    }

    /// Append an untyped segment with an explicit element kind.
    pub fn push_bytes(&mut self, seg: &'a [u8], kind: ElemKind) {
        self.starts.push(self.total);
        self.total += seg.len();
        self.segs.push((seg, kind));
    }

    /// Total composite length in bytes.
    pub fn total_bytes(&self) -> usize {
        self.total
    }

    /// Number of segments.
    pub fn num_segments(&self) -> usize {
        self.segs.len()
    }

    /// Byte length of segment `i`.
    pub fn segment_bytes(&self, i: usize) -> usize {
        self.segs[i].0.len()
    }

    /// Element kind of segment `i`.
    pub fn segment_kind(&self, i: usize) -> ElemKind {
        self.segs[i].1
    }

    /// Resolve composite byte range `off..off+len` to the one segment
    /// containing it.
    fn resolve(&self, off: usize, len: usize) -> Result<&[u8]> {
        let i = locate_segment(&self.starts, |i| self.segs[i].0.len(), self.total, off, len)?;
        if len == 0 {
            return Ok(&[]);
        }
        let local = off - self.starts[i];
        Ok(&self.segs[i].0[local..local + len])
    }
}

/// The writable counterpart of [`IoView`]: composite output address space
/// over caller-owned mutable segments. See [`IoView`] for the segment ↔
/// slice mapping and the non-spanning invariant.
#[derive(Default)]
pub struct IoViewMut<'a> {
    segs: Vec<(&'a mut [u8], ElemKind)>,
    starts: Vec<usize>,
    total: usize,
}

impl<'a> IoViewMut<'a> {
    /// An empty view (push segments in constituent order).
    pub fn new() -> IoViewMut<'a> {
        IoViewMut::default()
    }

    /// Single-segment view over one typed buffer.
    pub fn of<T: ViewElem>(seg: &'a mut [T]) -> IoViewMut<'a> {
        let mut v = IoViewMut::new();
        v.push::<T>(seg);
        v
    }

    /// Append a typed segment (its [`ElemKind`] comes from `T`).
    pub fn push<T: ViewElem>(&mut self, seg: &'a mut [T]) {
        self.push_bytes(as_bytes_mut(seg), T::KIND);
    }

    /// Append an untyped segment with an explicit element kind.
    pub fn push_bytes(&mut self, seg: &'a mut [u8], kind: ElemKind) {
        self.starts.push(self.total);
        self.total += seg.len();
        self.segs.push((seg, kind));
    }

    /// Total composite length in bytes.
    pub fn total_bytes(&self) -> usize {
        self.total
    }

    /// Number of segments.
    pub fn num_segments(&self) -> usize {
        self.segs.len()
    }

    /// Byte length of segment `i`.
    pub fn segment_bytes(&self, i: usize) -> usize {
        self.segs[i].0.len()
    }

    /// Element kind of segment `i`.
    pub fn segment_kind(&self, i: usize) -> ElemKind {
        self.segs[i].1
    }

    /// Read-only resolution (the output buffer as a `CopyLocal`/`Send`
    /// source).
    fn resolve(&self, off: usize, len: usize) -> Result<&[u8]> {
        let i = locate_segment(&self.starts, |i| self.segs[i].0.len(), self.total, off, len)?;
        if len == 0 {
            return Ok(&[]);
        }
        let local = off - self.starts[i];
        Ok(&self.segs[i].0[local..local + len])
    }

    /// Writable resolution of composite byte range `off..off+len`.
    fn resolve_mut(&mut self, off: usize, len: usize) -> Result<&mut [u8]> {
        let i = locate_segment(&self.starts, |i| self.segs[i].0.len(), self.total, off, len)?;
        if len == 0 {
            return Ok(&mut []);
        }
        let local = off - self.starts[i];
        Ok(&mut self.segs[i].0[local..local + len])
    }

    /// The element kind governing composite byte offset `off` (reductions
    /// into the output recover their type from the target segment).
    fn kind_at(&self, off: usize) -> Result<ElemKind> {
        let i = locate_segment(&self.starts, |i| self.segs[i].0.len(), self.total, off, 1)?;
        Ok(self.segs[i].1)
    }
}

/// Find the segment fully containing composite byte range `off..off+len`.
/// Errors if the range is out of bounds or crosses a segment boundary
/// (the non-spanning invariant — see [`IoView`]).
fn locate_segment(
    starts: &[usize],
    seg_len: impl Fn(usize) -> usize,
    total: usize,
    off: usize,
    len: usize,
) -> Result<usize> {
    if len == 0 {
        return if off <= total {
            Ok(0)
        } else {
            Err(Error::Precondition(format!(
                "view byte offset {off} out of bounds (total {total})"
            )))
        };
    }
    // Segment counts are tiny (K constituents); a linear scan beats a
    // binary search at these sizes and keeps the hot path branch-simple.
    for i in (0..starts.len()).rev() {
        if off >= starts[i] {
            return if off + len <= starts[i] + seg_len(i) {
                Ok(i)
            } else {
                Err(Error::Precondition(format!(
                    "view byte range {off}..{} crosses a segment boundary (segment {i} is \
                     {}..{}); each IR slice must fall inside one segment",
                    off + len,
                    starts[i],
                    starts[i] + seg_len(i)
                )))
            };
        }
    }
    Err(Error::Precondition(format!("view byte range {off}..{} in empty view", off + len)))
}

/// How the view executor resolves the element type of a `Reduce` target.
pub(crate) enum ViewReduce<'a> {
    /// The operation does not reduce; any `Reduce` step is an error.
    NotReducing,
    /// Every buffer holds one element type (single-type plans).
    Uniform(ElemKind),
    /// Mixed-type fused plans: output targets take the kind of the view
    /// segment they land in; scratch target `i` takes `kinds[i]` (the
    /// fused schedule's per-rank scratch-kind table).
    PerScratch(&'a [ElemKind]),
}

/// Resolve a local two-buffer step into byte `(read, write)` slices and
/// apply `f` — the view twin of [`with_pair`]. Offsets/lengths are in
/// schedule elements; `eb` converts to bytes.
fn view_pair(
    input: &IoView<'_>,
    output: &mut IoViewMut<'_>,
    scratch: &mut [Vec<u8>],
    eb: usize,
    src: &Slice,
    dst: &Slice,
    f: impl FnOnce(&[u8], &mut [u8]),
) -> Result<()> {
    let (so, sl) = (src.off * eb, src.len * eb);
    let (do_, dl) = (dst.off * eb, dst.len * eb);
    match (src.buf, dst.buf) {
        (BufId::Input, BufId::Output) => f(input.resolve(so, sl)?, output.resolve_mut(do_, dl)?),
        (BufId::Input, BufId::Scratch(j)) => {
            f(input.resolve(so, sl)?, &mut scratch[j][do_..do_ + dl])
        }
        (BufId::Output, BufId::Scratch(j)) => {
            f(output.resolve(so, sl)?, &mut scratch[j][do_..do_ + dl])
        }
        (BufId::Scratch(i), BufId::Output) => {
            f(&scratch[i][so..so + sl], output.resolve_mut(do_, dl)?)
        }
        (BufId::Scratch(i), BufId::Scratch(j)) if i < j => {
            let (lo, hi) = scratch.split_at_mut(j);
            f(&lo[i][so..so + sl], &mut hi[0][do_..do_ + dl]);
        }
        (BufId::Scratch(i), BufId::Scratch(j)) if i > j => {
            let (lo, hi) = scratch.split_at_mut(i);
            f(&hi[0][so..so + sl], &mut lo[j][do_..do_ + dl]);
        }
        _ => {
            return Err(Error::Precondition(
                "local schedule step must use distinct buffers with a writable destination".into(),
            ))
        }
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn send_slice_view(
    core: &PlanCore,
    input: &IoView<'_>,
    output: &IoViewMut<'_>,
    scratch: &[Vec<u8>],
    wire: &mut [u8],
    eb: usize,
    to: usize,
    src: &Slice,
    tag: u64,
    pad: usize,
) -> Result<()> {
    let (off, len) = (src.off * eb, src.len * eb);
    let buf: &[u8] = match src.buf {
        BufId::Input => input.resolve(off, len)?,
        BufId::Output => output.resolve(off, len)?,
        BufId::Scratch(i) => &scratch[i][off..off + len],
    };
    let t = core.tag(tag);
    if pad == 0 {
        // A byte send of `len·elem_bytes` bytes is wire-identical to the
        // typed executor's send of `len` elements: same payload, same tag,
        // same size — so typed receivers match it and vtime is unchanged.
        let _req = core.comm.isend(buf, to, t)?;
    } else {
        let total = pad + len;
        let w = &mut wire[..total];
        w[..pad].fill(0);
        w[pad..].copy_from_slice(buf);
        let _req = core.comm.isend(&w[..total], to, t)?;
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn recv_slice_view(
    core: &PlanCore,
    output: &mut IoViewMut<'_>,
    scratch: &mut [Vec<u8>],
    wire: &mut [u8],
    eb: usize,
    from: usize,
    dst: &Slice,
    tag: u64,
    pad: usize,
) -> Result<()> {
    let t = core.tag(tag);
    let (off, len) = (dst.off * eb, dst.len * eb);
    let buf: &mut [u8] = match dst.buf {
        BufId::Output => output.resolve_mut(off, len)?,
        BufId::Scratch(i) => &mut scratch[i][off..off + len],
        BufId::Input => {
            return Err(Error::Precondition("schedule receives into the input buffer".into()))
        }
    };
    if pad == 0 {
        core.comm.recv_into(from, t, buf)
    } else {
        let total = pad + len;
        core.comm.recv_into(from, t, &mut wire[..total])?;
        buf.copy_from_slice(&wire[pad..total]);
        Ok(())
    }
}

/// The byte-level twin of [`execute_schedule`]: interpret `sched` in
/// place over segmented buffer views. Slice offsets/lengths (elements)
/// are converted to bytes with `sched.elem_bytes` and resolved against
/// the views; sends/receives move exactly the bytes the typed executor
/// would, so the two executors are wire-identical (same messages, sizes,
/// tags — and therefore identical virtual time) and bit-identical in
/// their results. Reductions recover element types through `reduce`.
pub(crate) fn execute_schedule_view(
    core: &PlanCore,
    sched: &Schedule,
    input: &IoView<'_>,
    output: &mut IoViewMut<'_>,
    scratch: &mut [Vec<u8>],
    wire: &mut [u8],
    reduce: &ViewReduce<'_>,
) -> Result<()> {
    let eb = sched.elem_bytes;
    let (in_len, out_len) = sched.io_lens();
    if input.total_bytes() != in_len * eb {
        return Err(Error::SizeMismatch { expected: in_len * eb, got: input.total_bytes() });
    }
    if output.total_bytes() != out_len * eb {
        return Err(Error::SizeMismatch { expected: out_len * eb, got: output.total_bytes() });
    }
    debug_assert_eq!(scratch.len(), sched.scratch.len());
    for round in &sched.rounds {
        for step in &round.steps {
            match step {
                Step::Send { to, src, tag, pad } => {
                    send_slice_view(core, input, output, scratch, wire, eb, *to, src, *tag, *pad)?;
                }
                Step::Recv { from, dst, tag, pad } => {
                    recv_slice_view(core, output, scratch, wire, eb, *from, dst, *tag, *pad)?;
                }
                Step::SendRecv { to, src, from, dst, tag, pad } => {
                    send_slice_view(core, input, output, scratch, wire, eb, *to, src, *tag, *pad)?;
                    recv_slice_view(core, output, scratch, wire, eb, *from, dst, *tag, *pad)?;
                }
                Step::CopyLocal { src, dst } => {
                    view_pair(input, output, scratch, eb, src, dst, |s, d| d.copy_from_slice(s))?;
                }
                Step::Reduce { src, dst } => {
                    let kind = match reduce {
                        ViewReduce::NotReducing => {
                            return Err(Error::Precondition(
                                "schedule contains Reduce but the operation is not a reduction"
                                    .into(),
                            ))
                        }
                        ViewReduce::Uniform(k) => *k,
                        ViewReduce::PerScratch(kinds) => match dst.buf {
                            BufId::Scratch(i) => *kinds.get(i).ok_or_else(|| {
                                Error::Precondition(format!(
                                    "no element kind for reduce target scratch {i}"
                                ))
                            })?,
                            BufId::Output => output.kind_at(dst.off * eb)?,
                            BufId::Input => {
                                return Err(Error::Precondition(
                                    "schedule reduces into the input buffer".into(),
                                ))
                            }
                        },
                    };
                    let mut res = Ok(());
                    view_pair(input, output, scratch, eb, src, dst, |s, d| {
                        res = kind.reduce_assign(d, s)
                    })?;
                    res?;
                }
                Step::Rotate { src, dst, block, shift } => {
                    view_pair(input, output, scratch, eb, src, dst, |s, d| {
                        super::bruck::rotate_down_into(s, block * eb, *shift, d)
                    })?;
                }
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// the generic plan
// ---------------------------------------------------------------------------

/// The universal persistent plan: a [`Schedule`] plus the retained
/// communicator, reserved tag block and plan-owned scratch. Every
/// registered (operation, algorithm) pair executes through this one type —
/// there are no per-algorithm execute loops.
pub struct SchedPlan<T: Pod> {
    core: PlanCore,
    name: &'static str,
    sched: Schedule,
    scratch: Vec<Vec<T>>,
    /// Byte-granular scratch mirror for zero-copy view execution;
    /// allocated lazily on the first `execute_view` (every schedule
    /// writes scratch before reading it, so the typed and byte executors
    /// share no state and still agree bit-for-bit).
    view_scratch: Vec<Vec<u8>>,
    /// Reusable buffer for padded (header-carrying) wire messages.
    wire: Vec<u8>,
}

impl<T: Pod> SchedPlan<T> {
    /// Validate `sched`, reserve its tag block on `comm` and allocate its
    /// scratch. Collective (every rank builds its own rank's schedule with
    /// the same tag/scratch shape).
    pub(crate) fn new(comm: &Comm, name: &'static str, sched: Schedule) -> Result<SchedPlan<T>> {
        debug_assert_eq!(sched.p, comm.size());
        debug_assert_eq!(sched.elem_bytes, std::mem::size_of::<T>());
        sched.validate()?;
        let core = PlanCore::new(comm, sched.n, sched.tags);
        let scratch = sched.scratch.iter().map(|&len| vec![T::default(); len]).collect();
        let wire = vec![0u8; sched.max_padded_wire()];
        Ok(SchedPlan { core, name, sched, scratch, view_scratch: Vec::new(), wire })
    }

    /// Boxing helper for factory `plan()` implementations.
    pub(crate) fn boxed(
        comm: &Comm,
        name: &'static str,
        sched: Schedule,
    ) -> Result<Box<SchedPlan<T>>> {
        Ok(Box::new(SchedPlan::new(comm, name, sched)?))
    }

    fn run(
        &mut self,
        input: &[T],
        output: &mut [T],
        reduce: Option<fn(&mut [T], &[T])>,
    ) -> Result<()> {
        let SchedPlan { core, sched, scratch, wire, .. } = self;
        execute_schedule(core, sched, input, output, scratch, wire, reduce)
    }

    fn run_view(
        &mut self,
        input: &IoView<'_>,
        output: &mut IoViewMut<'_>,
        reduce: &ViewReduce<'_>,
    ) -> Result<()> {
        if self.view_scratch.len() != self.sched.scratch.len() {
            let eb = self.sched.elem_bytes;
            self.view_scratch = self.sched.scratch.iter().map(|&l| vec![0u8; l * eb]).collect();
        }
        let SchedPlan { core, sched, view_scratch, wire, .. } = self;
        execute_schedule_view(core, sched, input, output, view_scratch, wire, reduce)
    }
}

impl<T: Pod> CollectivePlan for SchedPlan<T> {
    fn algorithm(&self) -> &'static str {
        self.name
    }

    fn shape(&self) -> Shape {
        Shape { n: self.core.n }
    }

    fn comm_size(&self) -> usize {
        self.core.p
    }

    fn schedule(&self) -> Option<&Schedule> {
        Some(&self.sched)
    }
}

impl<T: Pod> super::plan::AllgatherPlan<T> for SchedPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_io(self.core.n, self.core.p, input, output)?;
        self.run(input, output, None)
    }

    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        self.run_view(input, output, &ViewReduce::NotReducing)
    }
}

impl<T: Summable> super::plan::AllreducePlan<T> for SchedPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_reduce_io(self.core.n, input, output)?;
        self.run(input, output, Some(add_assign::<T>))
    }

    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        self.run_view(input, output, &ViewReduce::Uniform(T::KIND))
    }
}

impl<T: Pod> super::plan::AlltoallPlan<T> for SchedPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_a2a_io(self.core.n, self.core.p, input, output)?;
        self.run(input, output, None)
    }

    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        self.run_view(input, output, &ViewReduce::NotReducing)
    }
}

impl<T: Summable> super::plan::ReduceScatterPlan<T> for SchedPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_rs_io(self.core.n, self.core.p, input, output)?;
        self.run(input, output, Some(add_assign::<T>))
    }

    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        self.run_view(input, output, &ViewReduce::Uniform(T::KIND))
    }
}

/// Validate execute-time buffers against the schedule's exact io lengths —
/// the ragged plans' contract (ragged builders set an explicit
/// [`Schedule::io`] override, so `io_lens` is byte-exact per rank).
fn check_sched_io<T>(sched: &Schedule, input: &[T], output: &[T]) -> Result<()> {
    let (in_len, out_len) = sched.io_lens();
    if input.len() != in_len {
        return Err(Error::SizeMismatch { expected: in_len, got: input.len() });
    }
    if output.len() != out_len {
        return Err(Error::SizeMismatch { expected: out_len, got: output.len() });
    }
    Ok(())
}

impl<T: Pod> super::plan::AllgathervPlan<T> for SchedPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_sched_io(&self.sched, input, output)?;
        self.run(input, output, None)
    }

    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        self.run_view(input, output, &ViewReduce::NotReducing)
    }
}

impl<T: Summable> super::plan::ReduceScattervPlan<T> for SchedPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_sched_io(&self.sched, input, output)?;
        self.run(input, output, Some(add_assign::<T>))
    }

    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        self.run_view(input, output, &ViewReduce::Uniform(T::KIND))
    }
}

// ---------------------------------------------------------------------------
// by-name builders (shared by the registries, the model-tuned dispatcher,
// the cost model and `locag explain`)
// ---------------------------------------------------------------------------

/// Build the schedule of one allgather algorithm for `rank`. `SystemDefault`
/// resolves its size-based selection first; `ModelTuned` is *not* handled
/// here (it needs machine parameters — see
/// [`super::model_tuned::pick_allgather`]).
pub fn build_allgather(
    algo: super::Algorithm,
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    use super::Algorithm as A;
    match algo {
        A::Bruck => Ok(super::bruck::build_schedule(view.p, rank, n, elem_bytes)),
        A::Pat => Ok(super::pat::build_pat_allgather_schedule(view.p, rank, n, elem_bytes)),
        A::Ring => Ok(super::ring::build_schedule(view.p, rank, n, elem_bytes)),
        A::RecursiveDoubling => {
            super::recursive_doubling::build_schedule(view.p, rank, n, elem_bytes)
        }
        A::Dissemination => Ok(super::dissemination::build_schedule(view.p, rank, n, elem_bytes)),
        A::Hierarchical => super::hierarchical::build_schedule(view, rank, n, elem_bytes),
        A::Multilane => super::multilane::build_schedule(view, rank, n, elem_bytes),
        A::LocalityBruck => super::loc_bruck::build_schedule(
            view,
            rank,
            n,
            elem_bytes,
            GroupBy::Region,
            super::loc_bruck::Rank0::Contributes,
            "loc-bruck",
        ),
        A::LocalityBruckV => super::loc_bruck::build_schedule(
            view,
            rank,
            n,
            elem_bytes,
            GroupBy::Region,
            super::loc_bruck::Rank0::GathervSkips,
            "loc-bruck-v",
        ),
        A::LocalityBruckMultilevel => super::loc_bruck::build_schedule_multilevel(
            view,
            rank,
            n,
            elem_bytes,
        ),
        A::SystemDefault => {
            let sel = super::dispatch::select(view.p, n, elem_bytes);
            let mut sched = build_allgather(sel, view, rank, n, elem_bytes)?;
            sched.label = format!("system-default[{}]", sel.name());
            Ok(sched)
        }
        A::ModelTuned => Err(Error::Precondition(
            "model-tuned schedules are chosen by the dispatcher, not built directly".into(),
        )),
    }
}

/// Build the schedule of one allreduce algorithm (by registry name) for
/// `rank`. `model-tuned` is handled by the dispatcher.
pub fn build_allreduce(
    name: &str,
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    if name.eq_ignore_ascii_case("recursive-doubling") {
        super::allreduce::build_rd_schedule(view.p, rank, n, elem_bytes)
    } else if name.eq_ignore_ascii_case("loc-aware") {
        super::allreduce::build_loc_schedule(view, rank, n, elem_bytes)
    } else if name.eq_ignore_ascii_case("rabenseifner") {
        Ok(super::allreduce::build_rabenseifner_schedule(view.p, rank, n, elem_bytes))
    } else if name.eq_ignore_ascii_case("loc-rabenseifner") {
        super::allreduce::build_loc_rabenseifner_schedule(view, rank, n, elem_bytes)
    } else {
        Err(Error::Precondition(format!("no allreduce schedule builder for '{name}'")))
    }
}

/// Build the schedule of one reduce-scatter algorithm (by registry name)
/// for `rank`. `model-tuned` is handled by the dispatcher.
pub fn build_reduce_scatter(
    name: &str,
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    if name.eq_ignore_ascii_case("ring") {
        Ok(super::reduce_scatter::build_ring_schedule(view.p, rank, n, elem_bytes))
    } else if name.eq_ignore_ascii_case("recursive-halving") {
        super::reduce_scatter::build_rh_schedule(view.p, rank, n, elem_bytes)
    } else if name.eq_ignore_ascii_case("pat") {
        Ok(super::pat::build_pat_rs_schedule(view.p, rank, n, elem_bytes))
    } else if name.eq_ignore_ascii_case("loc-aware") {
        super::reduce_scatter::build_loc_schedule(view, rank, n, elem_bytes)
    } else {
        Err(Error::Precondition(format!("no reduce-scatter schedule builder for '{name}'")))
    }
}

/// Build the schedule of one alltoall algorithm (by registry name) for
/// `rank`. `model-tuned` is handled by the dispatcher.
pub fn build_alltoall(
    name: &str,
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    if name.eq_ignore_ascii_case("pairwise") {
        Ok(super::alltoall::build_pairwise_schedule(view.p, rank, n, elem_bytes))
    } else if name.eq_ignore_ascii_case("bruck") {
        Ok(super::alltoall::build_bruck_schedule(view.p, rank, n, elem_bytes))
    } else if name.eq_ignore_ascii_case("loc-aware") {
        super::alltoall::build_loc_schedule(view, rank, n, elem_bytes)
    } else if name.eq_ignore_ascii_case("system-default") {
        let mut sched = if super::dispatch::select_alltoall_bruck(n, elem_bytes) {
            super::alltoall::build_bruck_schedule(view.p, rank, n, elem_bytes)
        } else {
            super::alltoall::build_pairwise_schedule(view.p, rank, n, elem_bytes)
        };
        sched.label = format!("system-default[{}]", sched.label);
        Ok(sched)
    } else {
        Err(Error::Precondition(format!("no alltoall schedule builder for '{name}'")))
    }
}

// ---------------------------------------------------------------------------
// whole-world mailbox replay (shared by the cost model and fuse's verifier)
// ---------------------------------------------------------------------------

/// What one whole-world replay pass does at each communication event.
/// [`replay_world`] owns the walking — cursor per rank, send-half state of
/// in-flight `SendRecv`s, FIFO queues per `(src, dst, tag)` exactly like
/// the mailbox transport — and the handler owns the semantics: the cost
/// model's handler charges postal clocks, fuse's verifier checks wire
/// framing. One walker, two meanings; the two can never drift.
pub(crate) trait ReplayHandler {
    /// What a send enqueues and the matching receive consumes (a clock
    /// stamp for the cost model, a wire byte count for the verifier).
    type Msg: Copy;

    /// A send (or the send half of a `SendRecv`) posted by `rank`.
    fn on_send(&mut self, rank: usize, to: usize, src: &Slice, tag: u64, pad: usize) -> Self::Msg;

    /// The matching receive completing on `rank`; an error aborts the
    /// replay.
    fn on_recv(
        &mut self,
        rank: usize,
        from: usize,
        dst: &Slice,
        tag: u64,
        pad: usize,
        msg: Self::Msg,
    ) -> Result<()>;
}

/// Replay a whole world of schedules (one per rank, indexed by rank)
/// against `handler`, with FIFO matching per `(src, dst, tag)`. Local
/// steps are free. Errors if the schedules deadlock (a receive whose
/// matching send never happens) — `what` names the schedule set in the
/// message. Returns whether any sent message was never received; the
/// framing verifier treats that as a leak, the cost model ignores it.
pub(crate) fn replay_world<H: ReplayHandler>(
    scheds: &[Schedule],
    what: &str,
    handler: &mut H,
) -> Result<bool> {
    let p = scheds.len();
    let steps: Vec<Vec<&Step>> = scheds.iter().map(|s| s.steps().collect()).collect();
    let mut cursor = vec![0usize; p];
    // true while a SendRecv's send half is done but its receive is pending
    let mut half_done = vec![false; p];
    let mut queues: HashMap<(usize, usize, u64), VecDeque<H::Msg>> = HashMap::new();
    loop {
        let mut progress = false;
        let mut done = 0usize;
        for r in 0..p {
            loop {
                let Some(step) = steps[r].get(cursor[r]) else {
                    break;
                };
                match step {
                    Step::CopyLocal { .. } | Step::Reduce { .. } | Step::Rotate { .. } => {
                        cursor[r] += 1;
                        progress = true;
                    }
                    Step::Send { to, src, tag, pad } => {
                        let m = handler.on_send(r, *to, src, *tag, *pad);
                        queues.entry((r, *to, *tag)).or_default().push_back(m);
                        cursor[r] += 1;
                        progress = true;
                    }
                    Step::Recv { from, dst, tag, pad } => {
                        match queues.get_mut(&(*from, r, *tag)).and_then(|q| q.pop_front()) {
                            Some(m) => {
                                handler.on_recv(r, *from, dst, *tag, *pad, m)?;
                                cursor[r] += 1;
                                progress = true;
                            }
                            None => break,
                        }
                    }
                    Step::SendRecv { to, src, from, dst, tag, pad } => {
                        if !half_done[r] {
                            let m = handler.on_send(r, *to, src, *tag, *pad);
                            queues.entry((r, *to, *tag)).or_default().push_back(m);
                            half_done[r] = true;
                            progress = true;
                        }
                        match queues.get_mut(&(*from, r, *tag)).and_then(|q| q.pop_front()) {
                            Some(m) => {
                                handler.on_recv(r, *from, dst, *tag, *pad, m)?;
                                half_done[r] = false;
                                cursor[r] += 1;
                                progress = true;
                            }
                            None => break,
                        }
                    }
                }
            }
            if cursor[r] == steps[r].len() {
                done += 1;
            }
        }
        if done == p {
            break;
        }
        if !progress {
            return Err(Error::Precondition(format!(
                "{what} deadlocks: a receive has no matching send"
            )));
        }
    }
    Ok(queues.values().any(|q| !q.is_empty()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, Timing};

    #[test]
    fn builder_rounds_tags_and_scratch() {
        let mut sb = ScheduleBuilder::new("a");
        let s0 = sb.scratch(4);
        assert_eq!(s0, BufId::Scratch(0));
        assert_eq!(sb.tag(), 0);
        assert_eq!(sb.tag_block(3), 1);
        assert_eq!(sb.tag(), 4);
        sb.copy(Slice::input(0, 2), Slice::at(s0, 0, 2));
        sb.round("b");
        sb.copy(Slice::at(s0, 0, 2), Slice::output(0, 2));
        let sched = sb.finish(OpKind::Allgather, 1, 2, 8, "t");
        assert_eq!(sched.rounds.len(), 2);
        assert_eq!(sched.rounds[0].label, "a");
        assert_eq!(sched.rounds[1].label, "b");
        assert_eq!(sched.tags, 5);
        assert_eq!(sched.num_steps(), 2);
        sched.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_slices_and_buffers() {
        let mut sb = ScheduleBuilder::new("x");
        sb.copy(Slice::input(0, 3), Slice::output(0, 3));
        // input len for allgather with n=2 is 2 → slice 0..3 out of bounds
        let sched = sb.finish(OpKind::Allgather, 2, 2, 4, "t");
        assert!(sched.validate().is_err());

        let mut sb = ScheduleBuilder::new("x");
        sb.copy(Slice::output(0, 1), Slice::output(1, 1));
        let sched = sb.finish(OpKind::Allgather, 2, 2, 4, "t");
        assert!(sched.validate().is_err(), "same-buffer copy must be rejected");

        let mut sb = ScheduleBuilder::new("x");
        sb.send(5, Slice::input(0, 1), 0, 0);
        let sched = sb.finish(OpKind::Allgather, 2, 1, 4, "t");
        assert!(sched.validate().is_err(), "peer out of range");
    }

    #[test]
    fn wire_bytes_accounts_for_padding() {
        let mut sb = ScheduleBuilder::new("x");
        let t = sb.tag();
        sb.send(0, Slice::input(0, 2), t, 16);
        let sched = sb.finish(OpKind::Allgather, 1, 2, 8, "t");
        assert_eq!(sched.wire_bytes(2, 16), 32);
        assert_eq!(sched.max_padded_wire(), 32);
    }

    #[test]
    fn reduce_step_sums_through_reducing_entry_point() {
        let topo = Topology::regions(1, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut sb = ScheduleBuilder::new("x");
            let s = sb.scratch(1);
            sb.copy(Slice::input(0, 1), Slice::output(0, 1));
            sb.copy(Slice::input(0, 1), Slice::at(s, 0, 1));
            sb.reduce(Slice::at(s, 0, 1), Slice::output(0, 1));
            let sched = sb.finish(OpKind::Allreduce, 2, 1, 8, "t");
            let mut plan = SchedPlan::<u64>::new(c, "t", sched).unwrap();
            let mut out = [0u64; 1];
            <SchedPlan<u64> as super::super::plan::AllreducePlan<u64>>::execute(
                &mut plan,
                &[5u64],
                &mut out,
            )
            .unwrap();
            out[0]
        });
        // schedule doubles the local value (no communication involved)
        assert!(run.results.iter().all(|&v| v == 10));
    }

    #[test]
    fn locate_and_uniform_size() {
        let groups = vec![vec![0usize, 1], vec![2, 3]];
        assert_eq!(locate(&groups, 2).unwrap(), (1, 0));
        assert!(locate(&groups, 9).is_err());
        assert_eq!(uniform_size(&groups, "x").unwrap(), 2);
        let ragged = vec![vec![0usize], vec![1, 2]];
        assert!(uniform_size(&ragged, "x").is_err());
    }

    #[test]
    fn group_bruck_emitter_gathers_members() {
        let topo = Topology::regions(2, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let members: Vec<usize> = (0..4).collect();
            let mut sb = ScheduleBuilder::new("gather");
            emit_group_bruck(
                &mut sb,
                &members,
                c.rank(),
                1,
                Slice::input(0, 1),
                Slice::output(0, 4),
            );
            let sched = sb.finish(OpKind::Allgather, 4, 1, 8, "t");
            let mut plan = SchedPlan::<u64>::new(c, "t", sched).unwrap();
            let mut out = vec![0u64; 4];
            use super::super::plan::AllgatherPlan;
            plan.execute(&[10 + c.rank() as u64], &mut out).unwrap();
            out
        });
        for r in &run.results {
            assert_eq!(r, &vec![10, 11, 12, 13]);
        }
    }
}
