//! Allgather algorithms — the paper's contribution and every baseline it
//! compares against — behind a **persistent planned-collective API**.
//!
//! All algorithms are written against [`crate::comm::Comm`] using the same
//! `Isend`/`Irecv` structure as the paper's hand-written MPI implementations
//! (§5). Every implementation satisfies the same contract:
//!
//! * input: this rank's `n`-element contribution;
//! * output: `n · p` elements holding every rank's contribution **in
//!   communicator rank order** (`out[r*n..(r+1)*n]` is rank `r`'s data);
//! * `n == 0` is a uniform no-op: no messages, empty output.
//!
//! ## One-shot vs. persistent
//!
//! There are two ways to run an allgather:
//!
//! * **One-shot** — [`allgather`]`(algo, comm, local)`: plan + execute +
//!   allocate the output, every call. Use it for scripts, examples and
//!   single measurements where setup cost is irrelevant.
//! * **Persistent** — [`plan_allgather`] (or [`Registry::plan`]) returns an
//!   [`AllgatherPlan`] that amortizes *all* setup: group derivation,
//!   sub-communicator construction, step/rotation schedules, collective
//!   tag reservation and scratch allocation happen once at plan time, and
//!   [`AllgatherPlan::execute`] into caller-owned buffers does pure
//!   communication. This is the MPI-4 `MPI_Allgather_init` shape the paper
//!   implicitly measures ("communicators are created once outside the
//!   timed region", §5), and what a serving loop issuing millions of
//!   identical-shape collectives should use — see
//!   [`crate::coordinator::server`] and `examples/persistent_plan.rs`.
//!
//! Plan construction and every execution are collective: all ranks must
//! make the same calls in the same order (the usual MPI ordering rule).
//!
//! ## Implemented algorithms
//!
//! | module | registry name | algorithm | paper role |
//! |---|---|---|---|
//! | [`bruck`] | `bruck` | Bruck allgather (Alg. 1) | standard small-message baseline |
//! | [`pat`] | `pat` (allgather + reduce-scatter) | parallel aggregated trees (NCCL PAT): log-depth binomial trees, any `p` | related-work baseline |
//! | [`ring`] | `ring` | ring allgather | large-message baseline (§2) |
//! | [`recursive_doubling`] | `recursive-doubling` | recursive doubling | background §2 |
//! | [`dissemination`] | `dissemination` | dissemination allgather | background §2 |
//! | [`hierarchical`] | `hierarchical` | master-per-region gather + Bruck + bcast (Träff '06) | related-work baseline |
//! | [`multilane`] | `multilane` | per-lane inter-region Bruck + local allgather (Träff & Hunold '20) | related-work baseline |
//! | [`loc_bruck`] | `loc-bruck`, `loc-bruck-v`, `loc-bruck-2level` | **locality-aware Bruck (Alg. 2)**, incl. multilevel and non-power region counts | the contribution |
//! | [`dispatch`] | `system-default` (allgather + alltoall) | size/shape-based selection (Thakur et al.) | "system MPI" baseline |
//! | [`model_tuned`] | `model-tuned` (all three ops) | cost-model-scored schedule selection | adaptive dispatcher |
//! | [`schedule`] | — | the communication-schedule IR + the one generic executor ([`SchedPlan`]) | execution substrate |
//! | [`fuse`] | — | schedule fusion: round-merged, message-coalesced multi-plan execution ([`FusedPlan`], [`plan_fused`]) | the paper's aggregation idea, lifted across collectives |
//! | [`plan`] | — | op-generic plan framework: [`CollectivePlan`], per-op traits, [`OpRegistry`] | persistent API substrate |
//! | [`primitives`] | — | gather / bcast / allgatherv (+ [`primitives::AllgathervPlan`]) | substrate |
//! | [`allreduce`] | `recursive-doubling`, `loc-aware`, `rabenseifner`, `loc-rabenseifner` | planned allreduce (sum), incl. the fully hierarchical composition with both phases locality-aware | §6 extension |
//! | [`alltoall`] | `system-default`, `pairwise`, `bruck`, `loc-aware` | planned alltoall | §6 extension |
//! | [`reduce_scatter`] | `ring`, `recursive-halving`, `pat`, `loc-aware` | planned reduce-scatter (sum + scatter, the allgather's inverse) | §4 locality argument, inverted |
//! | [`allgatherv`](mod@allgatherv) | `ring`, `bruck`, `loc-aware` | **ragged** allgather: per-rank counts, exact ragged slices | Jocksch et al. allgatherv, locality-aware |
//! | [`reduce_scatter_v`](mod@reduce_scatter_v) | `ring`, `loc-aware` | **ragged** reduce-scatter (`MPI_Reduce_scatter` semantics) | §4 locality argument, ragged |
//!
//! Every algorithm *plans* by building a [`Schedule`] — pure data — and
//! *executes* through the single interpreter in [`SchedPlan`]; the same
//! schedule drives the cost model ([`crate::model::cost`]), the tracer
//! conformance suite and `locag explain`. No per-algorithm execute loops
//! exist.
//!
//! ## The other operations
//!
//! The same plan-once/execute-many framework covers the §6 extensions:
//! [`AllreduceRegistry`] plans [`AllreducePlan`]s (elementwise sum),
//! [`AlltoallRegistry`] plans [`AlltoallPlan`]s (personalized exchange)
//! and [`ReduceScatterRegistry`] plans [`ReduceScatterPlan`]s (sum +
//! scatter, `MPI_Reduce_scatter_block` semantics). All four registries
//! share the [`OpRegistry`] machinery and every plan implements the
//! [`CollectivePlan`] base trait; `locag algos` lists all of them and
//! `locag run --op <op>` executes any (op, algorithm) pair.
//!
//! The **ragged** variants generalise the per-rank contribution from a
//! uniform `n` to a counts vector ([`Counts`], `--counts 4,0,7,2` on the
//! CLI): [`AllgathervRegistry`] plans [`AllgathervPlan`]s and
//! [`ReduceScattervRegistry`] plans [`ReduceScattervPlan`]s from a ragged
//! [`PlanSpec`]. Ragged schedules move exact ragged slices — zero-count
//! ranks still participate in every exchange, which is precisely the
//! paper's local/non-local aggregation argument: locality determines the
//! exchange structure, the counts only size the payloads. Front doors:
//! [`plan_allgatherv`] / [`plan_reduce_scatter_v`] (persistent) and
//! [`allgatherv`](fn@allgatherv) / [`reduce_scatter_v`](fn@reduce_scatter_v)
//! (one-shot).
//!
//! New algorithms (or backend-specific overrides) implement
//! [`NamedAlgorithm`] plus the per-op factory trait
//! ([`CollectiveAlgorithm`], [`AllreduceAlgorithm`] or
//! [`AlltoallAlgorithm`]) and register themselves — no dispatch `match`
//! to touch.

pub mod allgatherv;
pub mod allreduce;
pub mod alltoall;
pub mod bruck;
pub mod dispatch;
pub mod dissemination;
pub mod fuse;
pub mod grouping;
pub mod hierarchical;
pub mod loc_bruck;
pub mod model_tuned;
pub mod multilane;
pub mod pat;
pub mod plan;
pub mod primitives;
pub mod recursive_doubling;
pub mod reduce_scatter;
pub mod reduce_scatter_v;
pub mod ring;
pub mod schedule;

pub use fuse::FuseSpec;
pub use plan::{
    reset_staging_bytes, staging_bytes_total, AllgatherPlan, AllgathervAlgorithm, AllgathervPlan,
    AllgathervRegistry, AllreduceAlgorithm, AllreducePlan, AllreduceRegistry, AlltoallAlgorithm,
    AlltoallPlan, AlltoallRegistry, CollectiveAlgorithm, CollectivePlan, Counts, ElemKind,
    FusedPlan, FusedPlanMixed, NamedAlgorithm, OpKind, OpRegistry, PlanSpec,
    ReduceScatterAlgorithm, ReduceScatterPlan, ReduceScatterRegistry, ReduceScattervAlgorithm,
    ReduceScattervPlan, ReduceScattervRegistry, Registry, Shape, Summable, ViewElem,
};
pub use schedule::{BufId, IoView, IoViewMut, Round, SchedPlan, Schedule, Slice, Step};

use crate::comm::{Comm, Pod};
use crate::error::{Error, Result};

/// Which allgather implementation to run (CLI / harness selector).
///
/// The enum enumerates the *built-in* algorithms for typed call sites
/// (figures, sweeps, CLI defaults); dispatch itself goes through the
/// [`Registry`], so registered extensions are reachable by name even
/// without an enum variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Standard Bruck (paper Algorithm 1).
    Bruck,
    /// Parallel aggregated trees (NCCL PAT): log-depth binomial trees
    /// over the ring distance, any rank count (see [`pat`]).
    Pat,
    /// Ring allgather.
    Ring,
    /// Recursive doubling (power-of-two sizes).
    RecursiveDoubling,
    /// Dissemination allgather.
    Dissemination,
    /// Hierarchical: gather → master Bruck → broadcast.
    Hierarchical,
    /// Multi-lane: per-lane inter-region Bruck, then local allgather.
    Multilane,
    /// Locality-aware Bruck (paper Algorithm 2).
    LocalityBruck,
    /// Algorithm 2 with the paper's allgatherv alternative (local rank 0
    /// contributes nothing to the post-step local gathers).
    LocalityBruckV,
    /// Two-level locality-aware Bruck (node-aware outer, socket-aware inner).
    LocalityBruckMultilevel,
    /// System-MPI style auto-selection.
    SystemDefault,
    /// Cost-model-driven auto-selection: scores every candidate's schedule
    /// under the machine's postal parameters, plans the cheapest (see
    /// [`model_tuned`]).
    ModelTuned,
}

impl Algorithm {
    /// All algorithms, in the order the figures report them.
    pub const ALL: [Algorithm; 12] = [
        Algorithm::SystemDefault,
        Algorithm::Bruck,
        Algorithm::Pat,
        Algorithm::Ring,
        Algorithm::RecursiveDoubling,
        Algorithm::Dissemination,
        Algorithm::Hierarchical,
        Algorithm::Multilane,
        Algorithm::LocalityBruck,
        Algorithm::LocalityBruckV,
        Algorithm::LocalityBruckMultilevel,
        Algorithm::ModelTuned,
    ];

    /// CLI / CSV / registry name.
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Bruck => "bruck",
            Algorithm::Pat => "pat",
            Algorithm::Ring => "ring",
            Algorithm::RecursiveDoubling => "recursive-doubling",
            Algorithm::Dissemination => "dissemination",
            Algorithm::Hierarchical => "hierarchical",
            Algorithm::Multilane => "multilane",
            Algorithm::LocalityBruck => "loc-bruck",
            Algorithm::LocalityBruckV => "loc-bruck-v",
            Algorithm::LocalityBruckMultilevel => "loc-bruck-2level",
            Algorithm::SystemDefault => "system-default",
            Algorithm::ModelTuned => "model-tuned",
        }
    }

    /// Parse a CLI name, case-insensitively.
    pub fn parse(s: &str) -> Option<Algorithm> {
        Algorithm::ALL.iter().copied().find(|a| a.name().eq_ignore_ascii_case(s))
    }

    /// Parse a CLI name; unknown names error with the full list of valid
    /// names (CLI ergonomics).
    pub fn parse_or_err(s: &str) -> Result<Algorithm> {
        Algorithm::parse(s).ok_or_else(|| {
            Error::Precondition(format!(
                "unknown algorithm '{s}' (valid: {})",
                Algorithm::ALL
                    .iter()
                    .map(|a| a.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        })
    }

    /// True if the algorithm exploits region locality.
    pub fn is_locality_aware(&self) -> bool {
        matches!(
            self,
            Algorithm::Hierarchical
                | Algorithm::Multilane
                | Algorithm::LocalityBruck
                | Algorithm::LocalityBruckV
                | Algorithm::LocalityBruckMultilevel
        )
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Collectively build a persistent plan for `algo` over `comm`.
///
/// The front door of the persistent API: resolves `algo` through the
/// standard [`Registry`] and returns a reusable [`AllgatherPlan`]. All
/// ranks must call this collectively with identical arguments.
pub fn plan_allgather<T: Pod>(
    algo: Algorithm,
    comm: &Comm,
    shape: Shape,
) -> Result<Box<dyn AllgatherPlan<T>>> {
    Registry::standard().plan_uniform(algo.name(), comm, shape)
}

/// One-shot allgather: plan, allocate the output, execute once.
///
/// Thin convenience wrapper over the registry — `examples/`, the sweep
/// engine and the CLI go through it. It rebuilds the (cheap, twelve-entry)
/// standard registry per call; hot loops should plan once via
/// [`plan_allgather`] and call [`AllgatherPlan::execute`] per iteration
/// instead, which is the entire point of the persistent API.
pub fn allgather<T: Pod>(algo: Algorithm, comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    let registry = Registry::<T>::standard();
    let a = registry.get(algo.name()).expect("every built-in algorithm is registered");
    plan::one_shot(a, comm, local)
}

/// Collectively build a persistent allreduce plan by registry name
/// (case-insensitive; see [`AllreduceRegistry::standard`] for the names).
pub fn plan_allreduce<T: Summable>(
    name: &str,
    comm: &Comm,
    shape: Shape,
) -> Result<Box<dyn AllreducePlan<T>>> {
    AllreduceRegistry::standard().plan_uniform(name, comm, shape)
}

/// Collectively build a persistent alltoall plan by registry name
/// (case-insensitive; see [`AlltoallRegistry::standard`] for the names).
pub fn plan_alltoall<T: Pod>(
    name: &str,
    comm: &Comm,
    shape: Shape,
) -> Result<Box<dyn AlltoallPlan<T>>> {
    AlltoallRegistry::standard().plan_uniform(name, comm, shape)
}

/// Collectively build a persistent reduce-scatter plan by registry name
/// (case-insensitive; see [`ReduceScatterRegistry::standard`] for the
/// names).
pub fn plan_reduce_scatter<T: Summable>(
    name: &str,
    comm: &Comm,
    shape: Shape,
) -> Result<Box<dyn ReduceScatterPlan<T>>> {
    ReduceScatterRegistry::standard().plan_uniform(name, comm, shape)
}

/// Collectively build a persistent allgatherv plan by registry name
/// (case-insensitive; see [`AllgathervRegistry::standard`] for the
/// names). Rank `r` contributes `counts[r]` elements; the plan gathers
/// `counts.total()` elements in rank order at the counts' prefix
/// offsets. All ranks must pass identical `counts`.
pub fn plan_allgatherv<T: Pod>(
    name: &str,
    comm: &Comm,
    counts: &Counts,
) -> Result<Box<dyn AllgathervPlan<T>>> {
    AllgathervRegistry::standard().plan(name, comm, &PlanSpec::ragged(counts.clone()))
}

/// Collectively build a persistent reduce-scatter-v plan by registry name
/// (case-insensitive; see [`ReduceScattervRegistry::standard`] for the
/// names). Every rank contributes `counts.total()` elements partitioned
/// by `counts`; rank `r` receives the elementwise sum of block `r`
/// (`MPI_Reduce_scatter` semantics). All ranks must pass identical
/// `counts`.
pub fn plan_reduce_scatter_v<T: Summable>(
    name: &str,
    comm: &Comm,
    counts: &Counts,
) -> Result<Box<dyn ReduceScattervPlan<T>>> {
    ReduceScattervRegistry::standard().plan(name, comm, &PlanSpec::ragged(counts.clone()))
}

/// One-shot allgatherv: plan, allocate the output, execute once.
/// `local.len()` must equal `counts[comm.rank()]`; returns the
/// `counts.total()`-element concatenation in rank order. Hot loops should
/// plan once via [`plan_allgatherv`] instead.
pub fn allgatherv<T: Pod>(
    name: &str,
    comm: &Comm,
    local: &[T],
    counts: &Counts,
) -> Result<Vec<T>> {
    let registry = AllgathervRegistry::<T>::standard();
    match registry.get(name) {
        Some(a) => plan::one_shot_agv(a, comm, local, counts),
        None => Err(registry.unknown(name)),
    }
}

/// One-shot reduce-scatter-v: plan, allocate the output, execute once.
/// `send.len()` must equal `counts.total()`; returns this rank's
/// `counts[comm.rank()]`-element summed block. Hot loops should plan once
/// via [`plan_reduce_scatter_v`] instead.
pub fn reduce_scatter_v<T: Summable>(
    name: &str,
    comm: &Comm,
    send: &[T],
    counts: &Counts,
) -> Result<Vec<T>> {
    let registry = ReduceScattervRegistry::<T>::standard();
    match registry.get(name) {
        Some(a) => plan::one_shot_rsv(a, comm, send, counts),
        None => Err(registry.unknown(name)),
    }
}

/// Collectively build a [`FusedPlan`] executing all `specs` — possibly of
/// different operations and algorithms — as one round-merged,
/// message-coalesced schedule (see [`fuse`]). All ranks must call this
/// with identical specs; constituent shape preconditions surface here.
pub fn plan_fused<T: Summable>(comm: &Comm, specs: &[FuseSpec]) -> Result<FusedPlan<T>> {
    FusedPlan::plan(comm, specs)
}

/// Collectively build a [`FusedPlanMixed`]: like [`plan_fused`], but each
/// constituent carries its own element kind (e.g. an `f32` allgather
/// fused with a `u64` allreduce). Executes over segmented buffer views
/// only ([`FusedPlanMixed::execute_view`]).
pub fn plan_fused_mixed(comm: &Comm, specs: &[(FuseSpec, ElemKind)]) -> Result<FusedPlanMixed> {
    FusedPlanMixed::plan(comm, specs)
}

/// The expected allgather result for verification: every rank's canonical
/// contribution concatenated in rank order. Used with
/// [`canonical_contribution`] by tests and the sweep engine.
pub fn expected_result(p: usize, n: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(p * n);
    for r in 0..p {
        out.extend(canonical_contribution(r, n));
    }
    out
}

/// A canonical per-rank contribution that makes misplaced blocks visible:
/// element `j` of rank `r` is `r * 1_000_003 + j`.
pub fn canonical_contribution(rank: usize, n: usize) -> Vec<u64> {
    (0..n).map(|j| (rank * 1_000_003 + j) as u64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::parse(a.name()), Some(a));
        }
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn parse_is_case_insensitive() {
        assert_eq!(Algorithm::parse("BRUCK"), Some(Algorithm::Bruck));
        assert_eq!(Algorithm::parse("Loc-Bruck"), Some(Algorithm::LocalityBruck));
        assert_eq!(
            Algorithm::parse("LOC-BRUCK-2LEVEL"),
            Some(Algorithm::LocalityBruckMultilevel)
        );
    }

    #[test]
    fn parse_error_lists_valid_names() {
        let err = Algorithm::parse_or_err("warp-drive").unwrap_err().to_string();
        assert!(err.contains("warp-drive"));
        for a in Algorithm::ALL {
            assert!(err.contains(a.name()), "error must list {}", a.name());
        }
        assert_eq!(Algorithm::parse_or_err("RING").unwrap(), Algorithm::Ring);
    }

    #[test]
    fn enum_names_match_registry_names() {
        let names = Registry::<u64>::standard().names();
        for a in Algorithm::ALL {
            assert!(names.contains(&a.name()), "{} not in registry", a.name());
        }
        assert_eq!(names.len(), Algorithm::ALL.len());
    }

    #[test]
    fn locality_awareness_flags() {
        assert!(Algorithm::LocalityBruck.is_locality_aware());
        assert!(Algorithm::Hierarchical.is_locality_aware());
        assert!(!Algorithm::Bruck.is_locality_aware());
        assert!(!Algorithm::Pat.is_locality_aware());
        assert!(!Algorithm::Ring.is_locality_aware());
    }

    #[test]
    fn canonical_data_is_unique_across_ranks() {
        let a = canonical_contribution(0, 4);
        let b = canonical_contribution(1, 4);
        assert!(a.iter().all(|x| !b.contains(x)));
        let e = expected_result(3, 2);
        assert_eq!(e.len(), 6);
        assert_eq!(&e[2..4], &canonical_contribution(1, 2)[..]);
    }

    #[test]
    fn one_shot_zero_length_is_uniform_across_algorithms() {
        use crate::comm::{CommWorld, Timing};
        use crate::topology::Topology;
        // 4x4 supports every algorithm incl. recursive doubling
        let topo = Topology::regions(4, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            for algo in Algorithm::ALL {
                let out = allgather::<u32>(algo, c, &[]).unwrap();
                assert!(out.is_empty(), "{algo} returned non-empty for n=0");
            }
            true
        });
        assert!(run.results.iter().all(|&b| b));
        // and no messages at all were sent
        let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
        assert_eq!(total, 0);
    }
}
