//! Allgather algorithms — the paper's contribution and every baseline it
//! compares against.
//!
//! All algorithms are written against [`crate::comm::Comm`] using the same
//! `Isend`/`Irecv` structure as the paper's hand-written MPI implementations
//! (§5). Every function has the same contract:
//!
//! * input: this rank's `n`-element contribution;
//! * output: a `Vec<T>` of length `n · p` holding every rank's contribution
//!   **in communicator rank order** (`out[r*n..(r+1)*n]` is rank `r`'s data).
//!
//! Implemented algorithms:
//!
//! | module | algorithm | paper role |
//! |---|---|---|
//! | [`bruck`] | Bruck allgather (Alg. 1) | standard small-message baseline |
//! | [`ring`] | ring allgather | large-message baseline (§2) |
//! | [`recursive_doubling`] | recursive doubling | background §2 |
//! | [`dissemination`] | dissemination allgather | background §2 |
//! | [`hierarchical`] | master-per-region gather + Bruck + bcast (Träff '06) | related-work baseline |
//! | [`multilane`] | per-lane inter-region Bruck + local allgather (Träff & Hunold '20) | related-work baseline |
//! | [`loc_bruck`] | **locality-aware Bruck (Alg. 2)**, incl. multilevel and non-power region counts | the contribution |
//! | [`dispatch`] | size/shape-based selection (Thakur et al.) | "system MPI" baseline |
//! | [`primitives`] | gather / bcast / allgatherv building blocks | substrate |
//! | [`allreduce`] | locality-aware allreduce | §6 future-work extension |

pub mod allreduce;
pub mod alltoall;
pub mod bruck;
pub mod dispatch;
pub mod dissemination;
pub mod grouping;
pub mod hierarchical;
pub mod loc_bruck;
pub mod multilane;
pub mod primitives;
pub mod recursive_doubling;
pub mod ring;

use crate::comm::{Comm, Pod};
use crate::error::Result;

/// Which allgather implementation to run (CLI / harness selector).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Standard Bruck (paper Algorithm 1).
    Bruck,
    /// Ring allgather.
    Ring,
    /// Recursive doubling (power-of-two sizes).
    RecursiveDoubling,
    /// Dissemination allgather.
    Dissemination,
    /// Hierarchical: gather → master Bruck → broadcast.
    Hierarchical,
    /// Multi-lane: per-lane inter-region Bruck, then local allgather.
    Multilane,
    /// Locality-aware Bruck (paper Algorithm 2).
    LocalityBruck,
    /// Algorithm 2 with the paper's allgatherv alternative (local rank 0
    /// contributes nothing to the post-step local gathers).
    LocalityBruckV,
    /// Two-level locality-aware Bruck (node-aware outer, socket-aware inner).
    LocalityBruckMultilevel,
    /// System-MPI style auto-selection.
    SystemDefault,
}

impl Algorithm {
    /// All algorithms, in the order the figures report them.
    pub const ALL: [Algorithm; 10] = [
        Algorithm::SystemDefault,
        Algorithm::Bruck,
        Algorithm::Ring,
        Algorithm::RecursiveDoubling,
        Algorithm::Dissemination,
        Algorithm::Hierarchical,
        Algorithm::Multilane,
        Algorithm::LocalityBruck,
        Algorithm::LocalityBruckV,
        Algorithm::LocalityBruckMultilevel,
    ];

    /// CLI / CSV name.
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Bruck => "bruck",
            Algorithm::Ring => "ring",
            Algorithm::RecursiveDoubling => "recursive-doubling",
            Algorithm::Dissemination => "dissemination",
            Algorithm::Hierarchical => "hierarchical",
            Algorithm::Multilane => "multilane",
            Algorithm::LocalityBruck => "loc-bruck",
            Algorithm::LocalityBruckV => "loc-bruck-v",
            Algorithm::LocalityBruckMultilevel => "loc-bruck-2level",
            Algorithm::SystemDefault => "system-default",
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Algorithm> {
        Algorithm::ALL.iter().copied().find(|a| a.name() == s)
    }

    /// True if the algorithm exploits region locality.
    pub fn is_locality_aware(&self) -> bool {
        matches!(
            self,
            Algorithm::Hierarchical
                | Algorithm::Multilane
                | Algorithm::LocalityBruck
                | Algorithm::LocalityBruckV
                | Algorithm::LocalityBruckMultilevel
        )
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Run the selected allgather on `comm`.
///
/// This is the library's front door: `examples/`, the sweep engine and the
/// coordinator all go through it.
pub fn allgather<T: Pod>(algo: Algorithm, comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    match algo {
        Algorithm::Bruck => bruck::allgather(comm, local),
        Algorithm::Ring => ring::allgather(comm, local),
        Algorithm::RecursiveDoubling => recursive_doubling::allgather(comm, local),
        Algorithm::Dissemination => dissemination::allgather(comm, local),
        Algorithm::Hierarchical => hierarchical::allgather(comm, local),
        Algorithm::Multilane => multilane::allgather(comm, local),
        Algorithm::LocalityBruck => loc_bruck::allgather(comm, local),
        Algorithm::LocalityBruckV => loc_bruck::allgather_v(comm, local),
        Algorithm::LocalityBruckMultilevel => loc_bruck::allgather_multilevel(comm, local),
        Algorithm::SystemDefault => dispatch::allgather(comm, local),
    }
}

/// The expected allgather result for verification: every rank's canonical
/// contribution concatenated in rank order. Used with
/// [`canonical_contribution`] by tests and the sweep engine.
pub fn expected_result(p: usize, n: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(p * n);
    for r in 0..p {
        out.extend(canonical_contribution(r, n));
    }
    out
}

/// A canonical per-rank contribution that makes misplaced blocks visible:
/// element `j` of rank `r` is `r * 1_000_003 + j`.
pub fn canonical_contribution(rank: usize, n: usize) -> Vec<u64> {
    (0..n).map(|j| (rank * 1_000_003 + j) as u64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::parse(a.name()), Some(a));
        }
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn locality_awareness_flags() {
        assert!(Algorithm::LocalityBruck.is_locality_aware());
        assert!(Algorithm::Hierarchical.is_locality_aware());
        assert!(!Algorithm::Bruck.is_locality_aware());
        assert!(!Algorithm::Ring.is_locality_aware());
    }

    #[test]
    fn canonical_data_is_unique_across_ranks() {
        let a = canonical_contribution(0, 4);
        let b = canonical_contribution(1, 4);
        assert!(a.iter().all(|x| !b.contains(x)));
        let e = expected_result(3, 2);
        assert_eq!(e.len(), 6);
        assert_eq!(&e[2..4], &canonical_contribution(1, 2)[..]);
    }
}
