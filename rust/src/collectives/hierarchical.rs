//! Hierarchical allgather (related work, Träff '06 [20]) as a schedule
//! builder.
//!
//! Three phases: (1) gather all region data to a per-region *master*
//! process; (2) Bruck allgather among the masters; (3) broadcast the full
//! array from each master to its region. Avoids injection-bandwidth
//! bottlenecks but leaves most ranks idle and still sends `log2(r)`
//! non-local messages of up to `b` bytes from every master (§2.2).
//!
//! The whole structure — the flat gather's `Send`/`Recv` pairs, the
//! masters' Bruck (inlined onto the parent communicator by
//! [`super::schedule::emit_group_bruck`]), the binomial broadcast tree and
//! the final group→rank permutation — is one flat [`Schedule`]; no
//! sub-communicators are built at all.

use super::grouping::GroupBy;
use super::plan::{
    trivial_plan, AllgatherPlan, CollectiveAlgorithm, NamedAlgorithm, OpKind, PlanSpec,
};
use super::primitives::bcast_tree;
use super::schedule::{
    emit_group_bruck, locate, uniform_size, SchedPlan, Schedule, ScheduleBuilder, Slice, WorldView,
};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// The hierarchical algorithm (registry entry).
pub struct Hierarchical;

impl NamedAlgorithm for Hierarchical {
    fn name(&self) -> &'static str {
        "hierarchical"
    }

    fn summary(&self) -> &'static str {
        "gather to region master, Bruck among masters, local broadcast (Träff '06)"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for Hierarchical {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("hierarchical", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("hierarchical")?;
        let view = WorldView::from_comm(comm);
        let sched = build_schedule(&view, comm.rank(), n, std::mem::size_of::<T>())?;
        Ok(SchedPlan::<T>::boxed(comm, "hierarchical", sched)?)
    }
}

/// Build the hierarchical allgather schedule for one rank (pure; SPMD).
pub fn build_schedule(
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    let groups = view.split(&(0..view.p).collect::<Vec<_>>(), GroupBy::Region);
    let ppr = uniform_size(&groups, "hierarchical allgather")?;
    let (g, l) = locate(&groups, rank)?;
    let p = view.p;

    let mut sb = ScheduleBuilder::new("gather to master");
    let tag_gather = sb.tag();
    let tag_bcast = sb.tag();
    let full = sb.scratch(n * p);

    // Phase 1: flat gather at the master (local rank 0).
    let region = if l == 0 {
        let region = sb.scratch(ppr * n);
        sb.copy(Slice::input(0, n), Slice::at(region, 0, n));
        for r in 1..ppr {
            sb.recv(groups[g][r], Slice::at(region, r * n, n), tag_gather, 0);
        }
        Some(region)
    } else {
        sb.send(groups[g][0], Slice::input(0, n), tag_gather, 0);
        None
    };

    // Phase 2: Bruck among the masters (non-masters only account tags).
    sb.round("master bruck");
    let masters: Vec<usize> = groups.iter().map(|m| m[0]).collect();
    let contrib = match region {
        Some(rb) => Slice::at(rb, 0, ppr * n),
        None => Slice::input(0, 0),
    };
    emit_group_bruck(&mut sb, &masters, rank, ppr * n, contrib, Slice::at(full, 0, n * p));

    // Phase 3: binomial broadcast of the full array inside the region.
    sb.round("broadcast");
    let (parent, children) = bcast_tree(ppr, l, 0);
    if let Some(par) = parent {
        sb.recv(groups[g][par], Slice::at(full, 0, n * p), tag_bcast, 0);
    }
    for child in children {
        sb.send(groups[g][child], Slice::at(full, 0, n * p), tag_bcast, 0);
    }

    // The master Bruck produced data ordered by (group, local rank); put
    // it back into communicator rank order.
    sb.round("reorder");
    let mut pos = 0usize;
    for members in &groups {
        for &r in members {
            sb.copy(Slice::at(full, pos * n, n), Slice::output(r * n, n));
            pos += 1;
        }
    }
    Ok(sb.finish(OpKind::Allgather, p, n, elem_bytes, "hierarchical"))
}

/// One-shot convenience wrapper: plan + single execute.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&Hierarchical, comm, local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{canonical_contribution, expected_result};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::{Placement, RegionKind, Topology};

    #[test]
    fn correct_on_example_2_1() {
        let topo = Topology::regions(4, 4);
        let expect = expected_result(16, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        for r in run.results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn correct_under_random_placement() {
        let topo = Topology::machine(
            4,
            1,
            4,
            RegionKind::Node,
            Placement::Random { seed: 17 },
        )
        .unwrap();
        let expect = expected_result(16, 3);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 3)).unwrap()
        });
        for r in run.results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn only_masters_send_nonlocal() {
        let topo = Topology::regions(4, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        for (rank, t) in run.trace.per_rank.iter().enumerate() {
            if rank % 4 == 0 {
                // master: log2(4) = 2 non-local sends in the masters' bruck
                assert_eq!(t.nonlocal_msgs, 2, "master {rank}");
            } else {
                assert_eq!(t.nonlocal_msgs, 0, "worker {rank}");
            }
        }
    }

    #[test]
    fn plan_reuse_stays_correct() {
        use crate::collectives::plan::{Registry, Shape};
        let topo = Topology::regions(2, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan = Registry::<u64>::standard()
                .plan_uniform("hierarchical", c, Shape::elems(2))
                .unwrap();
            let mut out = vec![0u64; 16];
            for round in 0..4u64 {
                let mine = [c.rank() as u64 + round, c.rank() as u64 + round + 30];
                plan.execute(&mine, &mut out).unwrap();
                let expect: Vec<u64> =
                    (0..8u64).flat_map(|r| [r + round, r + round + 30]).collect();
                assert_eq!(out, expect, "round {round}");
            }
            true
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
