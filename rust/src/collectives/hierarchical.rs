//! Hierarchical allgather (related work, Träff '06 [20]).
//!
//! Three phases: (1) gather all region data to a per-region *master*
//! process; (2) Bruck allgather among the masters; (3) broadcast the full
//! array from each master to its region. Avoids injection-bandwidth
//! bottlenecks but leaves most ranks idle and still sends `log2(r)`
//! non-local messages of up to `b` bytes from every master (§2.2).
//!
//! The persistent [`HierarchicalPlan`] retains the region communicator and
//! (on masters) the masters sub-communicator plus an inner Bruck plan; the
//! flat gather, the binomial broadcast tree and the final group→rank
//! permutation are all precomputed.

use super::grouping::{group_ranks, require_uniform, GroupBy};
use super::bruck::BruckPlan;
use super::plan::{
    check_io, trivial_plan, AllgatherPlan, CollectiveAlgorithm, CollectivePlan, NamedAlgorithm,
    Shape,
};
use super::primitives::bcast_tree;
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// The hierarchical algorithm (registry entry).
pub struct Hierarchical;

impl NamedAlgorithm for Hierarchical {
    fn name(&self) -> &'static str {
        "hierarchical"
    }

    fn summary(&self) -> &'static str {
        "gather to region master, Bruck among masters, local broadcast (Träff '06)"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for Hierarchical {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("hierarchical", comm, shape) {
            return Ok(p);
        }
        Ok(Box::new(HierarchicalPlan::<T>::new(comm, shape.n)?))
    }
}

/// Master-only state: the masters' communicator plan plus the gathered
/// region buffer.
struct MasterState<T: Pod> {
    plan: BruckPlan<T>,
    /// Gather target, length `ppr · n`.
    region: Vec<T>,
}

/// Persistent hierarchical plan.
pub struct HierarchicalPlan<T: Pod> {
    local_comm: Comm,
    n: usize,
    p: usize,
    ppr: usize,
    tag_gather: u64,
    tag_bcast: u64,
    masters: Option<MasterState<T>>,
    /// Broadcast-tree parent of this rank within its region (local ranks).
    parent: Option<usize>,
    /// Broadcast-tree children, in send order.
    children: Vec<usize>,
    /// The group-ordered full array, length `n · p`.
    full: Vec<T>,
    /// Block position in group order → communicator rank.
    perm: Vec<usize>,
}

impl<T: Pod> HierarchicalPlan<T> {
    /// Collectively plan a hierarchical allgather of `n` elements per rank.
    pub fn new(comm: &Comm, n: usize) -> Result<HierarchicalPlan<T>> {
        let groups = group_ranks(comm, GroupBy::Region)?;
        let ppr = require_uniform(&groups, "hierarchical allgather")?;
        let p = comm.size();
        let local_comm = comm.sub(&groups.members[groups.mine])?;
        let tag_gather = local_comm.reserve_coll_tags(1);
        let tag_bcast = local_comm.reserve_coll_tags(1);
        // Masters are local rank 0 of each group; only they construct the
        // masters' communicator (the member-subset `sub` consumes no parent
        // state, so non-masters stay consistent).
        let masters = if groups.my_local == 0 {
            let master_ranks: Vec<usize> = groups.members.iter().map(|g| g[0]).collect();
            let mcomm = comm.sub(&master_ranks)?;
            Some(MasterState {
                plan: BruckPlan::<T>::new(&mcomm, ppr * n),
                region: vec![T::default(); ppr * n],
            })
        } else {
            None
        };
        let (parent, children) = bcast_tree(ppr, groups.my_local, 0);
        let perm: Vec<usize> =
            groups.members.iter().flat_map(|g| g.iter().copied()).collect();
        Ok(HierarchicalPlan {
            local_comm,
            n,
            p,
            ppr,
            tag_gather,
            tag_bcast,
            masters,
            parent,
            children,
            full: vec![T::default(); n * p],
            perm,
        })
    }
}

impl<T: Pod> CollectivePlan for HierarchicalPlan<T> {
    fn algorithm(&self) -> &'static str {
        "hierarchical"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.n }
    }

    fn comm_size(&self) -> usize {
        self.p
    }
}

impl<T: Pod> AllgatherPlan<T> for HierarchicalPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_io(self.n, self.p, input, output)?;
        if self.n == 0 {
            return Ok(());
        }
        let n = self.n;
        // Phase 1 + 2: flat gather on the master, then Bruck among masters
        // into the group-ordered full buffer.
        if let Some(ms) = &mut self.masters {
            ms.region[..n].copy_from_slice(input);
            for r in 1..self.ppr {
                self.local_comm.recv_into(r, self.tag_gather, &mut ms.region[r * n..(r + 1) * n])?;
            }
            ms.plan.execute(&ms.region, &mut self.full)?;
        } else {
            self.local_comm.send(input, 0, self.tag_gather)?;
        }
        // Phase 3: binomial broadcast of the full array inside the region.
        if let Some(parent) = self.parent {
            self.local_comm.recv_into(parent, self.tag_bcast, &mut self.full)?;
        }
        for &child in &self.children {
            self.local_comm.send(&self.full, child, self.tag_bcast)?;
        }
        // The master-Bruck produced data ordered by (group, local rank);
        // put it back into communicator rank order.
        for (pos, &rank) in self.perm.iter().enumerate() {
            output[rank * n..(rank + 1) * n].copy_from_slice(&self.full[pos * n..(pos + 1) * n]);
        }
        Ok(())
    }
}

/// One-shot convenience wrapper: plan + single execute.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&Hierarchical, comm, local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{canonical_contribution, expected_result};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::{Placement, RegionKind, Topology};

    #[test]
    fn correct_on_example_2_1() {
        let topo = Topology::regions(4, 4);
        let expect = expected_result(16, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        for r in run.results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn correct_under_random_placement() {
        let topo = Topology::machine(
            4,
            1,
            4,
            RegionKind::Node,
            Placement::Random { seed: 17 },
        )
        .unwrap();
        let expect = expected_result(16, 3);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 3)).unwrap()
        });
        for r in run.results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn only_masters_send_nonlocal() {
        let topo = Topology::regions(4, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        for (rank, t) in run.trace.per_rank.iter().enumerate() {
            if rank % 4 == 0 {
                // master: log2(4) = 2 non-local sends in the masters' bruck
                assert_eq!(t.nonlocal_msgs, 2, "master {rank}");
            } else {
                assert_eq!(t.nonlocal_msgs, 0, "worker {rank}");
            }
        }
    }

    #[test]
    fn plan_reuse_stays_correct() {
        let topo = Topology::regions(2, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan = HierarchicalPlan::<u64>::new(c, 2).unwrap();
            let mut out = vec![0u64; 16];
            for round in 0..4u64 {
                let mine = [c.rank() as u64 + round, c.rank() as u64 + round + 30];
                plan.execute(&mine, &mut out).unwrap();
                let expect: Vec<u64> =
                    (0..8u64).flat_map(|r| [r + round, r + round + 30]).collect();
                assert_eq!(out, expect, "round {round}");
            }
            true
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
