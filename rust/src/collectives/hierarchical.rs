//! Hierarchical allgather (related work, Träff '06 [20]).
//!
//! Three phases: (1) gather all region data to a per-region *master*
//! process; (2) Bruck allgather among the masters; (3) broadcast the full
//! array from each master to its region. Avoids injection-bandwidth
//! bottlenecks but leaves most ranks idle and still sends `log2(r)`
//! non-local messages of up to `b` bytes from every master (§2.2).

use super::grouping::{group_ranks, require_uniform, GroupBy, Groups};
use super::{bruck, primitives};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// Hierarchical allgather of `local` (length `n`); returns `n·p` elements
/// in communicator rank order.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    let groups = group_ranks(comm, GroupBy::Region)?;
    require_uniform(&groups, "hierarchical allgather")?;
    allgather_grouped(comm, local, &groups)
}

/// Hierarchical allgather over explicit groups (exposed for tests and the
/// multilevel composition).
pub fn allgather_grouped<T: Pod>(comm: &Comm, local: &[T], groups: &Groups) -> Result<Vec<T>> {
    let n = local.len();
    let p = comm.size();
    let local_comm = comm.sub(&groups.members[groups.mine])?;

    // Phase 1: gather region data on the master (local rank 0).
    let gathered = primitives::gather(&local_comm, local, 0)?;

    // Phase 2: Bruck among masters. Masters are local rank 0 of each group.
    let master_ranks: Vec<usize> = groups.members.iter().map(|g| g[0]).collect();
    let is_master = groups.my_local == 0;
    let mut full_grouped: Option<Vec<T>> = None;
    if is_master {
        let masters = comm.sub(&master_ranks)?;
        let mine = gathered.expect("master holds gathered data");
        full_grouped = Some(bruck::allgather(&masters, &mine)?);
    }

    // Phase 3: broadcast the group-ordered array inside each region.
    let full_grouped = primitives::bcast(&local_comm, full_grouped, 0)?;
    debug_assert_eq!(full_grouped.len(), n * p);

    // The master-Bruck produced data ordered by (group, local rank); put it
    // back into communicator rank order.
    let mut out = vec![T::default(); n * p];
    let mut pos = 0usize;
    for g in &groups.members {
        for &r in g {
            out[r * n..(r + 1) * n].copy_from_slice(&full_grouped[pos..pos + n]);
            pos += n;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{canonical_contribution, expected_result};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::{Placement, RegionKind, Topology};

    #[test]
    fn correct_on_example_2_1() {
        let topo = Topology::regions(4, 4);
        let expect = expected_result(16, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        for r in run.results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn correct_under_random_placement() {
        let topo = Topology::machine(
            4,
            1,
            4,
            RegionKind::Node,
            Placement::Random { seed: 17 },
        )
        .unwrap();
        let expect = expected_result(16, 3);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 3)).unwrap()
        });
        for r in run.results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn only_masters_send_nonlocal() {
        let topo = Topology::regions(4, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        for (rank, t) in run.trace.per_rank.iter().enumerate() {
            if rank % 4 == 0 {
                // master: log2(4) = 2 non-local sends in the masters' bruck
                assert_eq!(t.nonlocal_msgs, 2, "master {rank}");
            } else {
                assert_eq!(t.nonlocal_msgs, 0, "worker {rank}");
            }
        }
    }
}
