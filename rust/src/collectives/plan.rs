//! Persistent planned collectives — the crate's analogue of the MPI-4
//! `MPI_*_init` persistent-collective family, generalized over operations.
//!
//! The framework has three layers:
//!
//! 1. **A shared core.** [`CollectivePlan`] is the operation-independent
//!    face of every plan (algorithm name, communicator size, planned shape,
//!    and the [`Schedule`](super::schedule::Schedule) it executes);
//!    `PlanCore` is the state the generic
//!    [`SchedPlan`](super::schedule::SchedPlan) embeds — a retained
//!    communicator handle, the planned shape, and a pre-reserved block of
//!    collective tags. Shape validation (`check_io` and friends) and the
//!    uniform zero-length short-circuit (`EmptyPlan`) are shared.
//! 2. **Per-operation traits.** [`AllgatherPlan`], [`AllreducePlan`] and
//!    [`AlltoallPlan`] extend [`CollectivePlan`] with the operation's
//!    `execute` contract; [`CollectiveAlgorithm`], [`AllreduceAlgorithm`]
//!    and [`AlltoallAlgorithm`] are the matching algorithm factories, all
//!    sharing [`NamedAlgorithm`] for registry identity.
//! 3. **Per-operation registries.** [`OpRegistry`] maps case-insensitive
//!    names to factories for one operation; [`Registry`] (allgather),
//!    [`AllreduceRegistry`] and [`AlltoallRegistry`] are its concrete
//!    instantiations, each with a `standard()` catalog and a `plan()`
//!    front door.
//!
//! A plan owns everything the hot path needs — retained (sub-)communicator
//! handles, rotation/step schedules, pre-reserved collective tag blocks
//! and scratch buffers — so that `execute` performs **zero setup work and
//! zero output/scratch allocation**: no group derivation, no
//! sub-communicator construction, no tag allocation, no `Vec` growth.
//!
//! ## Contract (all operations)
//!
//! * Planning is collective: every rank of the communicator must call
//!   `plan` with the same algorithm and [`Shape`], in the same program
//!   order relative to other collectives (exactly like `MPI_*_init`).
//! * Shape preconditions (power-of-two sizes, uniform groups, …) are
//!   checked **at plan time** — a successfully built plan never fails an
//!   execute for a shape reason. Buffer-length mismatches are still
//!   reported per execute.
//! * Executions are collective and must be issued in the same order on
//!   every rank. Interleaving executions of *different* plans is safe as
//!   long as that global order holds (tag blocks are disjoint per plan;
//!   matching is FIFO per `(src, ctx, tag)`).
//! * **Zero-length shapes** (`shape.n == 0`) are uniform across all
//!   operations and algorithms: planning yields a no-op plan (bypassing
//!   even shape preconditions) whose `execute` sends no messages and
//!   succeeds with an empty output.
//! * A plan never consumes communicator state after planning: the parent's
//!   [`crate::comm::Comm::next_coll_tag`] sequence is unaffected by any
//!   number of executions.
//!
//! ## Per-operation buffer contracts
//!
//! With `p = comm_size()` and `n = shape().n`:
//!
//! | operation | input | output |
//! |---|---|---|
//! | allgather | this rank's `n` elements | `n·p`; block `r` is rank `r`'s data |
//! | allreduce | this rank's `n` elements | `n`; elementwise sum over ranks |
//! | alltoall | `n·p`; block `j` goes to rank `j` | `n·p`; block `r` came from rank `r` |
//! | reduce_scatter | `n·p`; block `j` is this rank's contribution to rank `j` | `n`; elementwise sum over ranks of block `i` (this rank's block) |

use crate::comm::{Comm, Pod};
use crate::error::{Error, Result};
use crate::model::MachineParams;

use super::fuse::{fuse_world, FuseSpec};
use super::schedule::{add_assign, execute_schedule, Schedule, WorldView};
use super::{allreduce, alltoall, bruck, dispatch, dissemination, hierarchical};
use super::{loc_bruck, model_tuned, multilane, recursive_doubling, reduce_scatter, ring};

/// Element types that can be summed — the reduction of the allreduce
/// operation (the paper's allreduce reference [4] reduces with `MPI_SUM`).
pub trait Summable: Pod + std::ops::Add<Output = Self> {}
impl Summable for u32 {}
impl Summable for u64 {}
impl Summable for i32 {}
impl Summable for i64 {}
impl Summable for f32 {}
impl Summable for f64 {}

/// The collective operations the planned framework covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Gather every rank's contribution everywhere (the paper's subject).
    Allgather,
    /// Elementwise sum across ranks, result everywhere (§6 extension).
    Allreduce,
    /// Personalized exchange: block `j` of rank `i` moves to rank `j`
    /// (§6 extension; the op Bruck '97 was designed for).
    Alltoall,
    /// Elementwise sum across ranks, block `i` scattered to rank `i` —
    /// the allgather's inverse sibling (Jocksch et al.; NCCL PAT).
    ReduceScatter,
}

impl OpKind {
    /// All operations, in presentation order.
    pub const ALL: [OpKind; 4] =
        [OpKind::Allgather, OpKind::Allreduce, OpKind::Alltoall, OpKind::ReduceScatter];

    /// CLI / CSV name.
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Allgather => "allgather",
            OpKind::Allreduce => "allreduce",
            OpKind::Alltoall => "alltoall",
            OpKind::ReduceScatter => "reduce-scatter",
        }
    }

    /// Parse a CLI name, case-insensitively (`reduce_scatter` and
    /// `reduce-scatter` both resolve).
    pub fn parse(s: &str) -> Option<OpKind> {
        let s = s.replace('_', "-");
        OpKind::ALL.iter().copied().find(|o| o.name().eq_ignore_ascii_case(&s))
    }

    /// Parse a CLI name; unknown names error with the valid list.
    pub fn parse_or_err(s: &str) -> Result<OpKind> {
        OpKind::parse(s).ok_or_else(|| {
            Error::Precondition(format!(
                "unknown operation '{s}' (valid: {})",
                OpKind::ALL.iter().map(|o| o.name()).collect::<Vec<_>>().join(", ")
            ))
        })
    }

    /// Input/output element counts for one collective of `n` elements over
    /// `p` ranks — the per-operation buffer contract `Schedule::io_lens`
    /// enforces, exposed here so transport-level callers (the proc pool's
    /// input-delta validation, fused-buffer layout) can size and check
    /// buffers without building a schedule first.
    pub fn io_elems(&self, n: usize, p: usize) -> (usize, usize) {
        match self {
            OpKind::Allgather => (n, n * p),
            OpKind::Allreduce => (n, n),
            OpKind::Alltoall => (n * p, n * p),
            OpKind::ReduceScatter => (n * p, n),
        }
    }
}

impl std::fmt::Display for OpKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Shape of one planned collective: the per-rank element count `n` (see
/// the module docs for what `n` means per operation — contribution length
/// for allgather/allreduce, per-destination block length for alltoall).
/// The rank count comes from the communicator at plan time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    /// Elements per rank (per destination block, for alltoall).
    pub n: usize,
}

impl Shape {
    /// Shape for `n` elements per rank.
    pub fn elems(n: usize) -> Shape {
        Shape { n }
    }
}

/// Registry identity shared by every algorithm factory, whatever the
/// operation: the case-insensitive lookup name and a one-line summary.
pub trait NamedAlgorithm: Send + Sync {
    /// Registry / CLI / CSV name.
    fn name(&self) -> &'static str;

    /// One-line human description (shown by `locag algos`).
    fn summary(&self) -> &'static str {
        ""
    }
}

/// The operation-independent face of a prepared collective: identity and
/// planned geometry. Per-operation `execute` methods live on the
/// sub-traits ([`AllgatherPlan`], [`AllreducePlan`], [`AlltoallPlan`]).
pub trait CollectivePlan {
    /// Registry name of the algorithm that produced this plan.
    fn algorithm(&self) -> &'static str;

    /// The planned per-rank shape.
    fn shape(&self) -> Shape;

    /// Rank count of the planned communicator.
    fn comm_size(&self) -> usize;

    /// The communication-schedule IR this plan executes, if any (`None`
    /// only for the zero-length no-op plan). One source of truth for
    /// execution, tracing and cost prediction — see
    /// [`super::schedule`] and [`crate::model::cost`].
    fn schedule(&self) -> Option<&super::schedule::Schedule> {
        None
    }
}

/// A prepared allgather: gather `input` (length `shape().n`) from every
/// rank into `output` (length `shape().n * comm_size()`), in communicator
/// rank order. `shape().n == 0` plans are no-ops (empty output, no
/// messages). See the [module docs](self) for the full contract.
pub trait AllgatherPlan<T: Pod>: CollectivePlan {
    /// Run the communication. No allocation, no sub-communicator
    /// construction, no tag consumption.
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()>;
}

/// A prepared allreduce: elementwise-sum `input` (length `shape().n`)
/// across all ranks into `output` (length `shape().n`) on every rank.
/// `shape().n == 0` plans are no-ops (empty output, no messages). See the
/// [module docs](self) for the full contract.
pub trait AllreducePlan<T: Summable>: CollectivePlan {
    /// Run the communication + reduction. No allocation, no
    /// sub-communicator construction, no tag consumption.
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()>;
}

/// A prepared alltoall: `input` holds `comm_size()` blocks of `shape().n`
/// elements, block `j` destined for rank `j`; on success `output` block
/// `r` holds the block rank `r` sent here (`MPI_Alltoall` semantics).
/// `shape().n == 0` plans are no-ops (empty output, no messages). See the
/// [module docs](self) for the full contract.
pub trait AlltoallPlan<T: Pod>: CollectivePlan {
    /// Run the exchange. No allocation, no sub-communicator construction,
    /// no tag consumption.
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()>;
}

/// A prepared reduce-scatter: `input` holds `comm_size()` blocks of
/// `shape().n` elements, block `j` being this rank's contribution to rank
/// `j`; on success `output` (length `shape().n`) holds the elementwise
/// sum over all ranks of this rank's block
/// (`MPI_Reduce_scatter_block` + `MPI_SUM` semantics). `shape().n == 0`
/// plans are no-ops (empty output, no messages). See the
/// [module docs](self) for the full contract.
pub trait ReduceScatterPlan<T: Summable>: CollectivePlan {
    /// Run the communication + reduction. No allocation, no
    /// sub-communicator construction, no tag consumption.
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()>;
}

/// An allgather algorithm that can produce persistent plans.
pub trait CollectiveAlgorithm<T: Pod>: NamedAlgorithm {
    /// Collectively build a plan for `shape` over `comm`.
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>>;
}

/// An allreduce (sum) algorithm that can produce persistent plans.
pub trait AllreduceAlgorithm<T: Summable>: NamedAlgorithm {
    /// Collectively build a plan for `shape` over `comm`.
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllreducePlan<T>>>;
}

/// An alltoall algorithm that can produce persistent plans.
pub trait AlltoallAlgorithm<T: Pod>: NamedAlgorithm {
    /// Collectively build a plan for `shape` over `comm`.
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AlltoallPlan<T>>>;
}

/// A reduce-scatter (sum) algorithm that can produce persistent plans.
pub trait ReduceScatterAlgorithm<T: Summable>: NamedAlgorithm {
    /// Collectively build a plan for `shape` over `comm`.
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn ReduceScatterPlan<T>>>;
}

/// The state every concrete plan embeds: a retained communicator handle,
/// the planned geometry and a pre-reserved collective tag block. Building
/// a `PlanCore` is collective (all ranks must reserve the same `tags`
/// count at the same point, like all plan construction).
pub(crate) struct PlanCore {
    /// Retained handle; valid for the pre-reserved tags only.
    pub comm: Comm,
    /// Planned per-rank element count.
    pub n: usize,
    /// Communicator size at plan time.
    pub p: usize,
    /// This rank within the planned communicator.
    pub id: usize,
    tag_base: u64,
}

impl PlanCore {
    /// Retain `comm` and reserve a block of `tags` collective tags.
    pub fn new(comm: &Comm, n: usize, tags: u64) -> PlanCore {
        PlanCore {
            tag_base: comm.reserve_coll_tags(tags),
            comm: comm.retain(),
            n,
            p: comm.size(),
            id: comm.rank(),
        }
    }

    /// The `i`-th tag of the reserved block.
    pub fn tag(&self, i: u64) -> u64 {
        self.tag_base + i
    }
}

/// Validate the allgather execute-time buffer contract
/// (`input: n`, `output: n·p`).
pub(crate) fn check_io<T: Pod>(n: usize, p: usize, input: &[T], output: &[T]) -> Result<()> {
    if input.len() != n {
        return Err(Error::SizeMismatch { expected: n, got: input.len() });
    }
    if output.len() != n * p {
        return Err(Error::SizeMismatch { expected: n * p, got: output.len() });
    }
    Ok(())
}

/// Validate the allreduce execute-time buffer contract
/// (`input: n`, `output: n`).
pub(crate) fn check_reduce_io<T: Pod>(n: usize, input: &[T], output: &[T]) -> Result<()> {
    if input.len() != n {
        return Err(Error::SizeMismatch { expected: n, got: input.len() });
    }
    if output.len() != n {
        return Err(Error::SizeMismatch { expected: n, got: output.len() });
    }
    Ok(())
}

/// Validate the alltoall execute-time buffer contract
/// (`input: n·p`, `output: n·p`).
pub(crate) fn check_a2a_io<T: Pod>(n: usize, p: usize, input: &[T], output: &[T]) -> Result<()> {
    if input.len() != n * p {
        return Err(Error::SizeMismatch { expected: n * p, got: input.len() });
    }
    if output.len() != n * p {
        return Err(Error::SizeMismatch { expected: n * p, got: output.len() });
    }
    Ok(())
}

/// Validate the reduce-scatter execute-time buffer contract
/// (`input: n·p`, `output: n`).
pub(crate) fn check_rs_io<T: Pod>(n: usize, p: usize, input: &[T], output: &[T]) -> Result<()> {
    if input.len() != n * p {
        return Err(Error::SizeMismatch { expected: n * p, got: input.len() });
    }
    if output.len() != n {
        return Err(Error::SizeMismatch { expected: n, got: output.len() });
    }
    Ok(())
}

/// The uniform `n == 0` plan for every operation: no communication, empty
/// output. One struct serves all four ops (all buffers are empty).
pub(crate) struct EmptyPlan {
    pub name: &'static str,
    pub p: usize,
}

impl CollectivePlan for EmptyPlan {
    fn algorithm(&self) -> &'static str {
        self.name
    }

    fn shape(&self) -> Shape {
        Shape { n: 0 }
    }

    fn comm_size(&self) -> usize {
        self.p
    }
}

impl<T: Pod> AllgatherPlan<T> for EmptyPlan {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_io(0, self.p, input, output)
    }
}

impl<T: Summable> AllreducePlan<T> for EmptyPlan {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_reduce_io(0, input, output)
    }
}

impl<T: Pod> AlltoallPlan<T> for EmptyPlan {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_a2a_io(0, self.p, input, output)
    }
}

impl<T: Summable> ReduceScatterPlan<T> for EmptyPlan {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_rs_io(0, self.p, input, output)
    }
}

/// Factory helper: the shared zero-length short-circuit for allgather
/// factories. Every algorithm's `plan` starts with this so the `n == 0`
/// contract is uniform.
pub(crate) fn trivial_plan<T: Pod>(
    name: &'static str,
    comm: &Comm,
    shape: Shape,
) -> Option<Box<dyn AllgatherPlan<T>>> {
    if shape.n == 0 {
        Some(Box::new(EmptyPlan { name, p: comm.size() }))
    } else {
        None
    }
}

/// Zero-length short-circuit for allreduce factories.
pub(crate) fn trivial_reduce_plan<T: Summable>(
    name: &'static str,
    comm: &Comm,
    shape: Shape,
) -> Option<Box<dyn AllreducePlan<T>>> {
    if shape.n == 0 {
        Some(Box::new(EmptyPlan { name, p: comm.size() }))
    } else {
        None
    }
}

/// Zero-length short-circuit for alltoall factories.
pub(crate) fn trivial_a2a_plan<T: Pod>(
    name: &'static str,
    comm: &Comm,
    shape: Shape,
) -> Option<Box<dyn AlltoallPlan<T>>> {
    if shape.n == 0 {
        Some(Box::new(EmptyPlan { name, p: comm.size() }))
    } else {
        None
    }
}

/// Zero-length short-circuit for reduce-scatter factories.
pub(crate) fn trivial_rs_plan<T: Summable>(
    name: &'static str,
    comm: &Comm,
    shape: Shape,
) -> Option<Box<dyn ReduceScatterPlan<T>>> {
    if shape.n == 0 {
        Some(Box::new(EmptyPlan { name, p: comm.size() }))
    } else {
        None
    }
}

/// Shared body of every allgather one-shot wrapper: plan once, allocate
/// the output, execute once. The `n == 0` no-op contract is inherited from
/// the algorithm's factory (every factory starts with [`trivial_plan`]).
pub(crate) fn one_shot<T: Pod>(
    algo: &dyn CollectiveAlgorithm<T>,
    comm: &Comm,
    local: &[T],
) -> Result<Vec<T>> {
    let mut plan = algo.plan(comm, Shape::elems(local.len()))?;
    let mut out = vec![T::default(); local.len() * plan.comm_size()];
    plan.execute(local, &mut out)?;
    Ok(out)
}

/// Shared body of every allreduce one-shot wrapper.
pub(crate) fn one_shot_reduce<T: Summable>(
    algo: &dyn AllreduceAlgorithm<T>,
    comm: &Comm,
    local: &[T],
) -> Result<Vec<T>> {
    let mut plan = algo.plan(comm, Shape::elems(local.len()))?;
    let mut out = vec![T::default(); local.len()];
    plan.execute(local, &mut out)?;
    Ok(out)
}

/// Shared body of every alltoall one-shot wrapper: `send.len()` must be a
/// multiple of the communicator size (block length inferred).
pub(crate) fn one_shot_a2a<T: Pod>(
    algo: &dyn AlltoallAlgorithm<T>,
    comm: &Comm,
    send: &[T],
) -> Result<Vec<T>> {
    let p = comm.size();
    if send.len() % p != 0 {
        return Err(Error::SizeMismatch {
            expected: (send.len() / p.max(1)) * p,
            got: send.len(),
        });
    }
    let mut plan = algo.plan(comm, Shape::elems(send.len() / p))?;
    let mut out = vec![T::default(); send.len()];
    plan.execute(send, &mut out)?;
    Ok(out)
}

/// Shared body of every reduce-scatter one-shot wrapper: `send.len()`
/// must be a multiple of the communicator size (block length inferred).
pub(crate) fn one_shot_rs<T: Summable>(
    algo: &dyn ReduceScatterAlgorithm<T>,
    comm: &Comm,
    send: &[T],
) -> Result<Vec<T>> {
    let p = comm.size();
    if send.len() % p != 0 {
        return Err(Error::SizeMismatch {
            expected: (send.len() / p.max(1)) * p,
            got: send.len(),
        });
    }
    let mut plan = algo.plan(comm, Shape::elems(send.len() / p))?;
    let mut out = vec![T::default(); send.len() / p];
    plan.execute(send, &mut out)?;
    Ok(out)
}

/// Name → algorithm-factory registry for one operation.
///
/// Lookup is case-insensitive; the *last* registration of a name wins so
/// callers can override built-ins (e.g. swap in a backend-specific
/// implementation) without touching dispatch code. [`Registry`],
/// [`AllreduceRegistry`] and [`AlltoallRegistry`] are the concrete
/// per-operation instantiations.
pub struct OpRegistry<A: ?Sized + NamedAlgorithm> {
    op: OpKind,
    entries: Vec<Box<A>>,
}

impl<A: ?Sized + NamedAlgorithm> OpRegistry<A> {
    /// An empty registry for `op`.
    pub fn new(op: OpKind) -> OpRegistry<A> {
        OpRegistry { op, entries: Vec::new() }
    }

    /// The operation this registry plans.
    pub fn op(&self) -> OpKind {
        self.op
    }

    /// Add (or override) an algorithm.
    pub fn register(&mut self, algo: Box<A>) {
        self.entries.push(algo);
    }

    /// Registered names, registration order, overrides collapsed.
    pub fn names(&self) -> Vec<&'static str> {
        let mut seen: Vec<&'static str> = Vec::new();
        for e in &self.entries {
            if !seen.iter().any(|n| n.eq_ignore_ascii_case(e.name())) {
                seen.push(e.name());
            }
        }
        seen
    }

    /// Look up an algorithm by case-insensitive name (latest wins).
    pub fn get(&self, name: &str) -> Option<&A> {
        self.entries
            .iter()
            .rev()
            .find(|a| a.name().eq_ignore_ascii_case(name))
            .map(|b| b.as_ref())
    }

    /// `(name, summary)` pairs for listings.
    pub fn catalog(&self) -> Vec<(&'static str, &'static str)> {
        self.names()
            .into_iter()
            .map(|n| (n, self.get(n).expect("name came from names()").summary()))
            .collect()
    }

    /// The unknown-name error, listing every valid name for this op.
    fn unknown(&self, name: &str) -> Error {
        Error::Precondition(format!(
            "unknown {} algorithm '{name}' (valid: {})",
            self.op,
            self.names().join(", ")
        ))
    }
}

/// The allgather registry (kept under its PR-1 name: the allgather is the
/// paper's subject and the crate's original registry).
pub type Registry<T> = OpRegistry<dyn CollectiveAlgorithm<T>>;

/// The allreduce registry.
pub type AllreduceRegistry<T> = OpRegistry<dyn AllreduceAlgorithm<T>>;

/// The alltoall registry.
pub type AlltoallRegistry<T> = OpRegistry<dyn AlltoallAlgorithm<T>>;

/// The reduce-scatter registry.
pub type ReduceScatterRegistry<T> = OpRegistry<dyn ReduceScatterAlgorithm<T>>;

impl<T: Pod> Registry<T> {
    /// An empty allgather registry.
    pub fn empty() -> Registry<T> {
        OpRegistry::new(OpKind::Allgather)
    }

    /// The built-in allgathers, in the order the figures report them
    /// (the ten classic algorithms plus the model-tuned dispatcher).
    pub fn standard() -> Registry<T> {
        let mut r = Registry::empty();
        r.register(Box::new(dispatch::SystemDefault));
        r.register(Box::new(bruck::Bruck));
        r.register(Box::new(ring::Ring));
        r.register(Box::new(recursive_doubling::RecursiveDoubling));
        r.register(Box::new(dissemination::Dissemination));
        r.register(Box::new(hierarchical::Hierarchical));
        r.register(Box::new(multilane::Multilane));
        r.register(Box::new(loc_bruck::LocalityBruck));
        r.register(Box::new(loc_bruck::LocalityBruckV));
        r.register(Box::new(loc_bruck::LocalityBruckMultilevel));
        r.register(Box::new(model_tuned::ModelTuned));
        r
    }

    /// Plan by name. Unknown names report the full list of valid names.
    pub fn plan(&self, name: &str, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>> {
        match self.get(name) {
            Some(a) => a.plan(comm, shape),
            None => Err(self.unknown(name)),
        }
    }
}

impl<T: Summable> AllreduceRegistry<T> {
    /// An empty allreduce registry.
    pub fn empty() -> AllreduceRegistry<T> {
        OpRegistry::new(OpKind::Allreduce)
    }

    /// The built-in allreduces: recursive doubling, the §6 locality-aware
    /// regional variant, the any-size Rabenseifner composition and the
    /// model-tuned dispatcher.
    pub fn standard() -> AllreduceRegistry<T> {
        let mut r = AllreduceRegistry::empty();
        r.register(Box::new(allreduce::RecursiveDoublingAllreduce));
        r.register(Box::new(allreduce::LocalityAwareAllreduce));
        r.register(Box::new(allreduce::RabenseifnerAllreduce));
        r.register(Box::new(model_tuned::ModelTunedAllreduce));
        r
    }

    /// Plan by name. Unknown names report the full list of valid names.
    pub fn plan(&self, name: &str, comm: &Comm, shape: Shape) -> Result<Box<dyn AllreducePlan<T>>> {
        match self.get(name) {
            Some(a) => a.plan(comm, shape),
            None => Err(self.unknown(name)),
        }
    }
}

impl<T: Pod> AlltoallRegistry<T> {
    /// An empty alltoall registry.
    pub fn empty() -> AlltoallRegistry<T> {
        OpRegistry::new(OpKind::Alltoall)
    }

    /// The built-in alltoalls: MPICH-style dispatch, pairwise, Bruck, the
    /// §6 locality-aware aggregation variant and the model-tuned
    /// dispatcher.
    pub fn standard() -> AlltoallRegistry<T> {
        let mut r = AlltoallRegistry::empty();
        r.register(Box::new(dispatch::SystemDefaultAlltoall));
        r.register(Box::new(alltoall::PairwiseAlltoall));
        r.register(Box::new(alltoall::BruckAlltoall));
        r.register(Box::new(alltoall::LocAwareAlltoall));
        r.register(Box::new(model_tuned::ModelTunedAlltoall));
        r
    }

    /// Plan by name. Unknown names report the full list of valid names.
    pub fn plan(&self, name: &str, comm: &Comm, shape: Shape) -> Result<Box<dyn AlltoallPlan<T>>> {
        match self.get(name) {
            Some(a) => a.plan(comm, shape),
            None => Err(self.unknown(name)),
        }
    }
}

impl<T: Summable> ReduceScatterRegistry<T> {
    /// An empty reduce-scatter registry.
    pub fn empty() -> ReduceScatterRegistry<T> {
        OpRegistry::new(OpKind::ReduceScatter)
    }

    /// The built-in reduce-scatters: ring (bandwidth-optimal baseline),
    /// recursive halving (Rabenseifner's first phase), the locality-aware
    /// lane variant and the model-tuned dispatcher.
    pub fn standard() -> ReduceScatterRegistry<T> {
        let mut r = ReduceScatterRegistry::empty();
        r.register(Box::new(reduce_scatter::RingReduceScatter));
        r.register(Box::new(reduce_scatter::RecursiveHalvingReduceScatter));
        r.register(Box::new(reduce_scatter::LocAwareReduceScatter));
        r.register(Box::new(model_tuned::ModelTunedReduceScatter));
        r
    }

    /// Plan by name. Unknown names report the full list of valid names.
    pub fn plan(
        &self,
        name: &str,
        comm: &Comm,
        shape: Shape,
    ) -> Result<Box<dyn ReduceScatterPlan<T>>> {
        match self.get(name) {
            Some(a) => a.plan(comm, shape),
            None => Err(self.unknown(name)),
        }
    }
}

impl<T: Pod> Default for Registry<T> {
    fn default() -> Self {
        Registry::standard()
    }
}

impl<T: Summable> Default for AllreduceRegistry<T> {
    fn default() -> Self {
        AllreduceRegistry::standard()
    }
}

impl<T: Pod> Default for AlltoallRegistry<T> {
    fn default() -> Self {
        AlltoallRegistry::standard()
    }
}

impl<T: Summable> Default for ReduceScatterRegistry<T> {
    fn default() -> Self {
        ReduceScatterRegistry::standard()
    }
}

// ---------------------------------------------------------------------------
// fused multi-plan execution
// ---------------------------------------------------------------------------

/// IO geometry of one constituent inside a [`FusedPlan`].
struct FusedPart {
    in_off: usize,
    in_len: usize,
    out_off: usize,
    out_len: usize,
}

/// A persistent plan that executes **several** collectives — possibly of
/// different operations and algorithms — as **one** round-merged,
/// message-coalesced [`Schedule`] through the same generic interpreter
/// that runs every single-op plan ([`super::schedule::SchedPlan`]'s
/// executor).
///
/// Built collectively by [`FusedPlan::plan`] (or the front door
/// [`super::plan_fused`]) from [`FuseSpec`]s; the fusion itself is
/// [`super::fuse::fuse_world`]. Like every plan, everything is owned up
/// front: retained communicator, one composite tag block, composite
/// input/output staging and scratch — `execute` does pure communication
/// plus the staging copies, with zero allocation and no tag consumption.
///
/// Constituents with `n == 0` take part with empty buffers and no
/// communication (the uniform zero-length contract). `T` must be
/// [`Summable`] because a fused schedule may contain the reduction steps
/// of an allreduce constituent.
pub struct FusedPlan<T: Summable> {
    core: PlanCore,
    sched: Schedule,
    parts: Vec<FusedPart>,
    /// Composite staging buffers (constituent windows, in spec order).
    input: Vec<T>,
    output: Vec<T>,
    scratch: Vec<Vec<T>>,
    wire: Vec<u8>,
}

impl<T: Summable> FusedPlan<T> {
    /// Collectively build a fused plan for `specs` over `comm`. All ranks
    /// must call this with identical specs, like all plan construction.
    /// Constituent shape preconditions surface here, not at execute.
    pub fn plan(comm: &Comm, specs: &[FuseSpec]) -> Result<FusedPlan<T>> {
        let elem_bytes = std::mem::size_of::<T>();
        let view = WorldView::from_comm(comm);
        let machine = comm.machine().cloned().unwrap_or_else(MachineParams::lassen);
        let (mut fused, _) = fuse_world(specs, &view, elem_bytes, &machine)?;
        let sched = fused.swap_remove(comm.rank());
        sched.validate()?;
        let p = comm.size();
        let mut parts = Vec::with_capacity(specs.len());
        let (mut in_off, mut out_off) = (0usize, 0usize);
        for s in specs {
            let (il, ol) = match s.op {
                OpKind::Allgather => (s.n, s.n * p),
                OpKind::Allreduce => (s.n, s.n),
                OpKind::Alltoall => (s.n * p, s.n * p),
                OpKind::ReduceScatter => (s.n * p, s.n),
            };
            parts.push(FusedPart { in_off, in_len: il, out_off, out_len: ol });
            in_off += il;
            out_off += ol;
        }
        debug_assert_eq!(sched.io_lens(), (in_off, out_off));
        let core = PlanCore::new(comm, sched.n, sched.tags);
        let scratch = sched.scratch.iter().map(|&len| vec![T::default(); len]).collect();
        let wire = vec![0u8; sched.max_padded_wire()];
        Ok(FusedPlan {
            core,
            sched,
            parts,
            input: vec![T::default(); in_off],
            output: vec![T::default(); out_off],
            scratch,
            wire,
        })
    }

    /// Number of constituent collectives (including `n == 0` no-ops).
    pub fn num_parts(&self) -> usize {
        self.parts.len()
    }

    /// Execute every constituent as one fused schedule. `inputs[i]` /
    /// `outputs[i]` follow constituent `i`'s per-op buffer contract
    /// (see the [module docs](self)); both slices must be given for every
    /// constituent, in spec order.
    pub fn execute(&mut self, inputs: &[&[T]], outputs: &mut [&mut [T]]) -> Result<()> {
        if inputs.len() != self.parts.len() {
            return Err(Error::SizeMismatch { expected: self.parts.len(), got: inputs.len() });
        }
        if outputs.len() != self.parts.len() {
            return Err(Error::SizeMismatch { expected: self.parts.len(), got: outputs.len() });
        }
        for (i, part) in self.parts.iter().enumerate() {
            if inputs[i].len() != part.in_len {
                return Err(Error::SizeMismatch { expected: part.in_len, got: inputs[i].len() });
            }
            if outputs[i].len() != part.out_len {
                return Err(Error::SizeMismatch {
                    expected: part.out_len,
                    got: outputs[i].len(),
                });
            }
            self.input[part.in_off..part.in_off + part.in_len].copy_from_slice(inputs[i]);
        }
        {
            let FusedPlan { core, sched, input, output, scratch, wire, .. } = self;
            execute_schedule(core, sched, input, output, scratch, wire, Some(add_assign::<T>))?;
        }
        for (i, part) in self.parts.iter().enumerate() {
            outputs[i].copy_from_slice(&self.output[part.out_off..part.out_off + part.out_len]);
        }
        Ok(())
    }
}

impl<T: Summable> CollectivePlan for FusedPlan<T> {
    fn algorithm(&self) -> &'static str {
        "fused"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.core.n }
    }

    fn comm_size(&self) -> usize {
        self.core.p
    }

    fn schedule(&self) -> Option<&Schedule> {
        Some(&self.sched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{canonical_contribution, expected_result, Algorithm};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    #[test]
    fn standard_registry_matches_algorithm_enum() {
        let r = Registry::<u64>::standard();
        let names = r.names();
        assert_eq!(names.len(), Algorithm::ALL.len());
        for a in Algorithm::ALL {
            assert!(names.contains(&a.name()), "missing {}", a.name());
        }
        for (name, summary) in r.catalog() {
            assert!(!name.is_empty());
            assert!(!summary.is_empty(), "{name} has no summary");
        }
    }

    #[test]
    fn allreduce_and_alltoall_registries_have_catalogs() {
        let r = AllreduceRegistry::<u64>::standard();
        assert_eq!(r.op(), OpKind::Allreduce);
        assert_eq!(
            r.names(),
            vec!["recursive-doubling", "loc-aware", "rabenseifner", "model-tuned"]
        );
        for (name, summary) in r.catalog() {
            assert!(!summary.is_empty(), "{name} has no summary");
        }
        let r = AlltoallRegistry::<u64>::standard();
        assert_eq!(r.op(), OpKind::Alltoall);
        assert_eq!(
            r.names(),
            vec!["system-default", "pairwise", "bruck", "loc-aware", "model-tuned"]
        );
        for (name, summary) in r.catalog() {
            assert!(!summary.is_empty(), "{name} has no summary");
        }
        let r = ReduceScatterRegistry::<u64>::standard();
        assert_eq!(r.op(), OpKind::ReduceScatter);
        assert_eq!(r.names(), vec!["ring", "recursive-halving", "loc-aware", "model-tuned"]);
        for (name, summary) in r.catalog() {
            assert!(!summary.is_empty(), "{name} has no summary");
        }
    }

    #[test]
    fn op_kind_names_roundtrip() {
        for op in OpKind::ALL {
            assert_eq!(OpKind::parse(op.name()), Some(op));
            assert_eq!(OpKind::parse(&op.name().to_uppercase()), Some(op));
        }
        assert_eq!(OpKind::parse("reduce_scatter"), Some(OpKind::ReduceScatter));
        assert_eq!(OpKind::parse("Reduce_Scatter"), Some(OpKind::ReduceScatter));
        assert_eq!(OpKind::parse("nope"), None);
        let err = OpKind::parse_or_err("warp").unwrap_err().to_string();
        assert!(err.contains("allgather") && err.contains("reduce-scatter"), "{err}");
    }

    #[test]
    fn lookup_is_case_insensitive() {
        let r = Registry::<u32>::standard();
        assert!(r.get("LOC-BRUCK").is_some());
        assert!(r.get("Bruck").is_some());
        assert!(r.get("nope").is_none());
        let r = AlltoallRegistry::<u32>::standard();
        assert!(r.get("PAIRWISE").is_some());
    }

    #[test]
    fn unknown_name_error_lists_valid_names() {
        let topo = Topology::regions(1, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = Registry::<u32>::standard();
            let ag = match r.plan("warp-drive", c, Shape::elems(1)) {
                Err(e) => e.to_string(),
                Ok(_) => String::new(),
            };
            let r = AllreduceRegistry::<u32>::standard();
            let ar = match r.plan("warp-drive", c, Shape::elems(1)) {
                Err(e) => e.to_string(),
                Ok(_) => String::new(),
            };
            (ag, ar)
        });
        for (ag, ar) in &run.results {
            assert!(ag.contains("warp-drive"), "{ag}");
            assert!(ag.contains("allgather"), "{ag}");
            assert!(ag.contains("loc-bruck"), "{ag}");
            assert!(ag.contains("ring"), "{ag}");
            assert!(ar.contains("allreduce"), "{ar}");
            assert!(ar.contains("recursive-doubling"), "{ar}");
        }
    }

    #[test]
    fn every_builtin_plans_and_executes_by_name() {
        let topo = Topology::regions(4, 4);
        let p = topo.size();
        let n = 2usize;
        let expect = expected_result(p, n);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = Registry::<u64>::standard();
            let mine = canonical_contribution(c.rank(), n);
            let mut out = vec![0u64; n * p];
            for name in r.names() {
                let mut plan = r.plan(name, c, Shape::elems(n)).unwrap();
                assert_eq!(plan.algorithm(), name);
                assert_eq!(plan.shape(), Shape::elems(n));
                assert_eq!(plan.comm_size(), p);
                out.fill(0);
                plan.execute(&mine, &mut out).unwrap();
                assert_eq!(out, expect, "{name}");
            }
            true
        });
        assert!(run.results.iter().all(|&ok| ok));
    }

    #[test]
    fn late_registration_overrides_builtin() {
        struct Fake;
        impl NamedAlgorithm for Fake {
            fn name(&self) -> &'static str {
                "ring"
            }
            fn summary(&self) -> &'static str {
                "fake ring"
            }
        }
        impl CollectiveAlgorithm<u32> for Fake {
            fn plan(&self, comm: &Comm, _shape: Shape) -> Result<Box<dyn AllgatherPlan<u32>>> {
                Ok(Box::new(EmptyPlan { name: "ring", p: comm.size() }))
            }
        }
        let mut r = Registry::<u32>::standard();
        r.register(Box::new(Fake));
        assert_eq!(r.get("ring").unwrap().summary(), "fake ring");
        // names() still lists ring once
        assert_eq!(r.names().iter().filter(|n| **n == "ring").count(), 1);
    }

    #[test]
    fn io_elems_matches_the_per_op_buffer_contract() {
        assert_eq!(OpKind::Allgather.io_elems(3, 4), (3, 12));
        assert_eq!(OpKind::Allreduce.io_elems(3, 4), (3, 3));
        assert_eq!(OpKind::Alltoall.io_elems(3, 4), (12, 12));
        assert_eq!(OpKind::ReduceScatter.io_elems(3, 4), (12, 3));
        // n = 0 is the uniform empty contract on every op.
        for op in OpKind::ALL {
            assert_eq!(op.io_elems(0, 4), (0, 0));
        }
    }

    #[test]
    fn execute_validates_buffer_lengths() {
        let topo = Topology::regions(2, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = Registry::<u32>::standard();
            let mut plan = r.plan("bruck", c, Shape::elems(3)).unwrap();
            let bad_in = plan.execute(&[1u32; 2], &mut [0u32; 12]).is_err();
            let bad_out = plan.execute(&[1u32; 3], &mut [0u32; 11]).is_err();
            bad_in && bad_out
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
