//! Persistent planned collectives — the crate's analogue of MPI-4
//! `MPI_Allgather_init`.
//!
//! A [`CollectiveAlgorithm`] is a stateless algorithm description that can
//! *plan* an allgather for a concrete `(communicator, shape)` pair. The
//! resulting [`AllgatherPlan`] owns everything the hot path needs —
//! retained (sub-)communicator handles, rotation/step schedules,
//! pre-reserved collective tag blocks and scratch buffers — so that
//! [`AllgatherPlan::execute`] performs **zero setup work and zero
//! output/scratch allocation**: no group derivation, no sub-communicator
//! construction, no tag allocation, no `Vec` growth.
//!
//! ## Contract
//!
//! * Planning is collective: every rank of the communicator must call
//!   `plan` with the same algorithm and [`Shape`], in the same program
//!   order relative to other collectives (exactly like
//!   `MPI_Allgather_init`).
//! * `execute(input, output)` requires `input.len() == shape.n` and
//!   `output.len() == shape.n * p`; on success `output[r*n..(r+1)*n]`
//!   holds rank `r`'s contribution for every `r` (communicator rank
//!   order). Both buffers are caller-owned.
//! * Executions are collective and must be issued in the same order on
//!   every rank. Interleaving executions of *different* plans is safe as
//!   long as that global order holds (tag blocks are disjoint per plan;
//!   matching is FIFO per `(src, ctx, tag)`).
//! * **Zero-length contributions** (`shape.n == 0`) are uniform across all
//!   algorithms: planning yields a no-op plan whose `execute` sends no
//!   messages and succeeds with an empty output.
//! * A plan never consumes communicator state after planning: the parent's
//!   [`crate::comm::Comm::next_coll_tag`] sequence is unaffected by any
//!   number of executions.
//!
//! ## Registry
//!
//! [`Registry`] maps case-insensitive names to algorithm factories. New
//! algorithms (or alternative backends) register without touching any
//! dispatch `match`; the last registration of a name wins, so a backend
//! can override a built-in.

use crate::comm::{Comm, Pod};
use crate::error::{Error, Result};

use super::{bruck, dispatch, dissemination, hierarchical, loc_bruck, multilane};
use super::{recursive_doubling, ring};

/// Shape of one allgather: the per-rank contribution length in elements.
/// (The rank count comes from the communicator at plan time.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    /// Elements contributed by every rank.
    pub n: usize,
}

impl Shape {
    /// Shape for `n` elements per rank.
    pub fn elems(n: usize) -> Shape {
        Shape { n }
    }
}

/// A prepared allgather: setup amortized at plan time, executed many times.
///
/// See the [module docs](self) for the full contract (collectivity,
/// buffer lengths, zero-length handling).
pub trait AllgatherPlan<T: Pod> {
    /// Registry name of the algorithm that produced this plan.
    fn algorithm(&self) -> &'static str;

    /// The planned per-rank contribution shape.
    fn shape(&self) -> Shape;

    /// Rank count of the planned communicator.
    fn comm_size(&self) -> usize;

    /// Run the communication: gather `input` (length `shape().n`) from
    /// every rank into `output` (length `shape().n * comm_size()`), in
    /// communicator rank order. No allocation, no sub-communicator
    /// construction, no tag consumption.
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()>;
}

/// An allgather algorithm that can produce persistent plans.
pub trait CollectiveAlgorithm<T: Pod>: Send + Sync {
    /// Registry / CLI / CSV name.
    fn name(&self) -> &'static str;

    /// One-line human description (shown by `locag algos`).
    fn summary(&self) -> &'static str {
        ""
    }

    /// Collectively build a plan for `shape` over `comm`.
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>>;
}

/// Validate the execute-time buffer contract.
pub(crate) fn check_io<T: Pod>(n: usize, p: usize, input: &[T], output: &[T]) -> Result<()> {
    if input.len() != n {
        return Err(Error::SizeMismatch { expected: n, got: input.len() });
    }
    if output.len() != n * p {
        return Err(Error::SizeMismatch { expected: n * p, got: output.len() });
    }
    Ok(())
}

/// The uniform `n == 0` plan: no communication, empty output.
pub(crate) struct EmptyPlan {
    pub name: &'static str,
    pub p: usize,
}

impl<T: Pod> AllgatherPlan<T> for EmptyPlan {
    fn algorithm(&self) -> &'static str {
        self.name
    }

    fn shape(&self) -> Shape {
        Shape { n: 0 }
    }

    fn comm_size(&self) -> usize {
        self.p
    }

    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_io(0, self.p, input, output)
    }
}

/// Factory helper: the shared zero-length short-circuit. Every algorithm's
/// `plan` starts with this so the `n == 0` contract is uniform.
pub(crate) fn trivial_plan<T: Pod>(
    name: &'static str,
    comm: &Comm,
    shape: Shape,
) -> Option<Box<dyn AllgatherPlan<T>>> {
    if shape.n == 0 {
        Some(Box::new(EmptyPlan { name, p: comm.size() }))
    } else {
        None
    }
}

/// Shared body of every one-shot wrapper: plan once, allocate the output,
/// execute once. The `n == 0` no-op contract is inherited from the
/// algorithm's factory (every factory starts with [`trivial_plan`]).
pub(crate) fn one_shot<T: Pod>(
    algo: &dyn CollectiveAlgorithm<T>,
    comm: &Comm,
    local: &[T],
) -> Result<Vec<T>> {
    let mut plan = algo.plan(comm, Shape::elems(local.len()))?;
    let mut out = vec![T::default(); local.len() * plan.comm_size()];
    plan.execute(local, &mut out)?;
    Ok(out)
}

/// A plan delegating to another plan under a different reported name
/// (dispatch selection, degenerate-topology fallbacks).
pub(crate) struct SelectedPlan<T: Pod> {
    pub name: &'static str,
    pub inner: Box<dyn AllgatherPlan<T>>,
}

impl<T: Pod> AllgatherPlan<T> for SelectedPlan<T> {
    fn algorithm(&self) -> &'static str {
        self.name
    }

    fn shape(&self) -> Shape {
        self.inner.shape()
    }

    fn comm_size(&self) -> usize {
        self.inner.comm_size()
    }

    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        self.inner.execute(input, output)
    }
}

/// Name → algorithm-factory registry.
///
/// Lookup is case-insensitive; the *last* registration of a name wins so
/// callers can override built-ins (e.g. swap in a backend-specific
/// implementation) without touching dispatch code.
pub struct Registry<T: Pod> {
    entries: Vec<Box<dyn CollectiveAlgorithm<T>>>,
}

impl<T: Pod> Registry<T> {
    /// An empty registry.
    pub fn empty() -> Registry<T> {
        Registry { entries: Vec::new() }
    }

    /// The ten built-in algorithms, in the order the figures report them.
    pub fn standard() -> Registry<T> {
        let mut r = Registry::empty();
        r.register(Box::new(dispatch::SystemDefault));
        r.register(Box::new(bruck::Bruck));
        r.register(Box::new(ring::Ring));
        r.register(Box::new(recursive_doubling::RecursiveDoubling));
        r.register(Box::new(dissemination::Dissemination));
        r.register(Box::new(hierarchical::Hierarchical));
        r.register(Box::new(multilane::Multilane));
        r.register(Box::new(loc_bruck::LocalityBruck));
        r.register(Box::new(loc_bruck::LocalityBruckV));
        r.register(Box::new(loc_bruck::LocalityBruckMultilevel));
        r
    }

    /// Add (or override) an algorithm.
    pub fn register(&mut self, algo: Box<dyn CollectiveAlgorithm<T>>) {
        self.entries.push(algo);
    }

    /// Registered names, registration order, overrides collapsed.
    pub fn names(&self) -> Vec<&'static str> {
        let mut seen: Vec<&'static str> = Vec::new();
        for e in &self.entries {
            if !seen.iter().any(|n| n.eq_ignore_ascii_case(e.name())) {
                seen.push(e.name());
            }
        }
        seen
    }

    /// Look up an algorithm by case-insensitive name (latest wins).
    pub fn get(&self, name: &str) -> Option<&dyn CollectiveAlgorithm<T>> {
        self.entries
            .iter()
            .rev()
            .find(|a| a.name().eq_ignore_ascii_case(name))
            .map(|b| b.as_ref())
    }

    /// `(name, summary)` pairs for listings.
    pub fn catalog(&self) -> Vec<(&'static str, &'static str)> {
        self.names()
            .into_iter()
            .map(|n| (n, self.get(n).expect("name came from names()").summary()))
            .collect()
    }

    /// Plan by name. Unknown names report the full list of valid names.
    pub fn plan(&self, name: &str, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>> {
        match self.get(name) {
            Some(a) => a.plan(comm, shape),
            None => Err(Error::Precondition(format!(
                "unknown algorithm '{name}' (valid: {})",
                self.names().join(", ")
            ))),
        }
    }
}

impl<T: Pod> Default for Registry<T> {
    fn default() -> Self {
        Registry::standard()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{canonical_contribution, expected_result, Algorithm};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    #[test]
    fn standard_registry_lists_all_ten() {
        let r = Registry::<u64>::standard();
        let names = r.names();
        assert_eq!(names.len(), Algorithm::ALL.len());
        for a in Algorithm::ALL {
            assert!(names.contains(&a.name()), "missing {}", a.name());
        }
        for (name, summary) in r.catalog() {
            assert!(!name.is_empty());
            assert!(!summary.is_empty(), "{name} has no summary");
        }
    }

    #[test]
    fn lookup_is_case_insensitive() {
        let r = Registry::<u32>::standard();
        assert!(r.get("LOC-BRUCK").is_some());
        assert!(r.get("Bruck").is_some());
        assert!(r.get("nope").is_none());
    }

    #[test]
    fn unknown_name_error_lists_valid_names() {
        let topo = Topology::regions(1, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = Registry::<u32>::standard();
            match r.plan("warp-drive", c, Shape::elems(1)) {
                Err(e) => e.to_string(),
                Ok(_) => String::new(),
            }
        });
        for msg in &run.results {
            assert!(msg.contains("warp-drive"), "{msg}");
            assert!(msg.contains("loc-bruck"), "{msg}");
            assert!(msg.contains("ring"), "{msg}");
        }
    }

    #[test]
    fn every_builtin_plans_and_executes_by_name() {
        let topo = Topology::regions(4, 4);
        let p = topo.size();
        let n = 2usize;
        let expect = expected_result(p, n);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = Registry::<u64>::standard();
            let mine = canonical_contribution(c.rank(), n);
            let mut out = vec![0u64; n * p];
            for name in r.names() {
                let mut plan = r.plan(name, c, Shape::elems(n)).unwrap();
                assert_eq!(plan.algorithm(), name);
                assert_eq!(plan.shape(), Shape::elems(n));
                assert_eq!(plan.comm_size(), p);
                out.fill(0);
                plan.execute(&mine, &mut out).unwrap();
                assert_eq!(out, expect, "{name}");
            }
            true
        });
        assert!(run.results.iter().all(|&ok| ok));
    }

    #[test]
    fn late_registration_overrides_builtin() {
        struct Fake;
        impl CollectiveAlgorithm<u32> for Fake {
            fn name(&self) -> &'static str {
                "ring"
            }
            fn summary(&self) -> &'static str {
                "fake ring"
            }
            fn plan(&self, comm: &Comm, _shape: Shape) -> Result<Box<dyn AllgatherPlan<u32>>> {
                Ok(Box::new(EmptyPlan { name: "ring", p: comm.size() }))
            }
        }
        let mut r = Registry::<u32>::standard();
        r.register(Box::new(Fake));
        assert_eq!(r.get("ring").unwrap().summary(), "fake ring");
        // names() still lists ring once
        assert_eq!(r.names().iter().filter(|n| **n == "ring").count(), 1);
    }

    #[test]
    fn execute_validates_buffer_lengths() {
        let topo = Topology::regions(2, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = Registry::<u32>::standard();
            let mut plan = r.plan("bruck", c, Shape::elems(3)).unwrap();
            let bad_in = plan.execute(&[1u32; 2], &mut [0u32; 12]).is_err();
            let bad_out = plan.execute(&[1u32; 3], &mut [0u32; 11]).is_err();
            bad_in && bad_out
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
