//! Persistent planned collectives — the crate's analogue of the MPI-4
//! `MPI_*_init` persistent-collective family, generalized over operations.
//!
//! The framework has three layers:
//!
//! 1. **A shared core.** [`CollectivePlan`] is the operation-independent
//!    face of every plan (algorithm name, communicator size, planned shape,
//!    and the [`Schedule`](super::schedule::Schedule) it executes);
//!    `PlanCore` is the state the generic
//!    [`SchedPlan`](super::schedule::SchedPlan) embeds — a retained
//!    communicator handle, the planned shape, and a pre-reserved block of
//!    collective tags. Shape validation (`check_io` and friends) and the
//!    uniform zero-length short-circuit (`EmptyPlan`) are shared.
//! 2. **Per-operation traits.** [`AllgatherPlan`], [`AllreducePlan`] and
//!    [`AlltoallPlan`] extend [`CollectivePlan`] with the operation's
//!    `execute` contract; [`CollectiveAlgorithm`], [`AllreduceAlgorithm`]
//!    and [`AlltoallAlgorithm`] are the matching algorithm factories, all
//!    sharing [`NamedAlgorithm`] for registry identity.
//! 3. **Per-operation registries.** [`OpRegistry`] maps case-insensitive
//!    names to factories for one operation; [`Registry`] (allgather),
//!    [`AllreduceRegistry`] and [`AlltoallRegistry`] are its concrete
//!    instantiations, each with a `standard()` catalog and a `plan()`
//!    front door.
//!
//! A plan owns everything the hot path needs — retained (sub-)communicator
//! handles, rotation/step schedules, pre-reserved collective tag blocks
//! and scratch buffers — so that `execute` performs **zero setup work and
//! zero output/scratch allocation**: no group derivation, no
//! sub-communicator construction, no tag allocation, no `Vec` growth.
//!
//! ## Contract (all operations)
//!
//! * Planning is collective: every rank of the communicator must call
//!   `plan` with the same algorithm and [`Shape`], in the same program
//!   order relative to other collectives (exactly like `MPI_*_init`).
//! * Shape preconditions (power-of-two sizes, uniform groups, …) are
//!   checked **at plan time** — a successfully built plan never fails an
//!   execute for a shape reason. Buffer-length mismatches are still
//!   reported per execute.
//! * Executions are collective and must be issued in the same order on
//!   every rank. Interleaving executions of *different* plans is safe as
//!   long as that global order holds (tag blocks are disjoint per plan;
//!   matching is FIFO per `(src, ctx, tag)`).
//! * **Zero-length shapes** (`shape.n == 0`) are uniform across all
//!   operations and algorithms: planning yields a no-op plan (bypassing
//!   even shape preconditions) whose `execute` sends no messages and
//!   succeeds with an empty output.
//! * A plan never consumes communicator state after planning: the parent's
//!   [`crate::comm::Comm::next_coll_tag`] sequence is unaffected by any
//!   number of executions.
//!
//! ## Per-operation buffer contracts
//!
//! With `p = comm_size()` and `n = shape().n`:
//!
//! | operation | input | output |
//! |---|---|---|
//! | allgather | this rank's `n` elements | `n·p`; block `r` is rank `r`'s data |
//! | allreduce | this rank's `n` elements | `n`; elementwise sum over ranks |
//! | alltoall | `n·p`; block `j` goes to rank `j` | `n·p`; block `r` came from rank `r` |
//! | reduce_scatter | `n·p`; block `j` is this rank's contribution to rank `j` | `n`; elementwise sum over ranks of block `i` (this rank's block) |
//! | allgatherv | this rank's `counts[me]` elements | `counts.total()`; block `r` is rank `r`'s `counts[r]` elements |
//! | reduce_scatter_v | `counts.total()`; block `j` (`counts[j]` elements) is this rank's contribution to rank `j` | `counts[me]`; elementwise sum over ranks of block `me` |
//!
//! ## Counts-aware plan specs (the allgatherv / reduce_scatter_v redesign)
//!
//! Plan-time geometry is a [`PlanSpec`] — a [`Shape`] plus per-rank
//! [`Counts`]. The uniform operations require uniform counts (`plan`
//! reports a typed [`Error::Precondition`] otherwise); the ragged
//! operations (allgatherv, reduce-scatter-v) consume the counts directly,
//! so raggedness is a **plan-time** property: schedules are built over
//! exact ragged slices and the generic executor never changes.
//!
//! Migrating from the bare-`Shape` plan API:
//!
//! * `registry.plan(name, comm, shape)` became either
//!   `registry.plan_uniform(name, comm, shape)` — the source-compatible
//!   convenience that builds `PlanSpec::uniform(shape.n, comm.size())` —
//!   or `registry.plan(name, comm, &spec)` with an explicit spec.
//! * `*Algorithm::plan(&self, comm, shape)` implementations now take
//!   `spec: &PlanSpec`; uniform algorithms start with
//!   `let n = spec.uniform_n(name)?`, which rejects ragged counts with a
//!   pointer at the allgatherv / reduce-scatter-v registries.
//! * Ragged counts map onto the paper's local/non-local aggregation
//!   exactly like the uniform case: a region's aggregated contribution is
//!   the **sum** of its members' counts, so the loc-aware builders keep
//!   their ⌈log⌉-style non-local message bounds with unequal payloads.

use crate::comm::{Comm, Pod};
use crate::error::{Error, Result};
use crate::model::MachineParams;

use super::fuse::{fuse_world, fuse_world_mixed, FuseSpec};
use super::schedule::{
    add_assign, execute_schedule, execute_schedule_view, IoView, IoViewMut, Schedule, ViewReduce,
    WorldView,
};
use super::{allgatherv, allreduce, alltoall, bruck, dispatch, dissemination, hierarchical};
use super::{loc_bruck, model_tuned, multilane, pat, recursive_doubling, reduce_scatter};
use super::{reduce_scatter_v, ring};

/// Runtime element-type tag for byte-level (view-based) execution.
///
/// The segmented-view interpreter ([`execute_schedule_view`]) runs
/// schedules over untyped byte buffers; `ElemKind` carries the one piece
/// of type information that still matters at runtime — how to reduce two
/// byte slices elementwise. It is the dynamic mirror of the static
/// [`ViewElem`] trait, and the bridge to the proc backend's wire dtypes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElemKind {
    /// 32-bit unsigned integers (wrapping sum).
    U32,
    /// 64-bit unsigned integers (wrapping sum).
    U64,
    /// 32-bit signed integers (wrapping sum).
    I32,
    /// 64-bit signed integers (wrapping sum).
    I64,
    /// IEEE-754 single precision (native-order float sum).
    F32,
    /// IEEE-754 double precision (native-order float sum).
    F64,
    /// Opaque bytes: movable (copy/gather/scatter) but not reducible.
    /// Coalescing scratch buffers introduced by fusion are `Raw` — they
    /// are only ever `CopyLocal` sources/targets, never `Reduce` targets.
    Raw,
}

impl ElemKind {
    /// Element width in bytes (`Raw` is byte-granular: 1).
    pub fn bytes(&self) -> usize {
        match self {
            ElemKind::U32 | ElemKind::I32 | ElemKind::F32 => 4,
            ElemKind::U64 | ElemKind::I64 | ElemKind::F64 => 8,
            ElemKind::Raw => 1,
        }
    }

    /// Display / spec-grammar name.
    pub fn name(&self) -> &'static str {
        match self {
            ElemKind::U32 => "u32",
            ElemKind::U64 => "u64",
            ElemKind::I32 => "i32",
            ElemKind::I64 => "i64",
            ElemKind::F32 => "f32",
            ElemKind::F64 => "f64",
            ElemKind::Raw => "raw",
        }
    }

    /// Elementwise `dst += src` over raw bytes. Integer kinds use wrapping
    /// addition and float kinds native-endian IEEE addition — exactly the
    /// semantics of the typed interpreter's [`add_assign`] (release mode)
    /// and of the proc backend's byte reducer, so every executor produces
    /// bit-identical reductions.
    pub fn reduce_assign(&self, dst: &mut [u8], src: &[u8]) -> Result<()> {
        if dst.len() != src.len() {
            return Err(Error::SizeMismatch { expected: dst.len(), got: src.len() });
        }
        let eb = self.bytes();
        if *self == ElemKind::Raw {
            return Err(Error::Precondition(
                "cannot reduce raw (untyped) bytes — a Reduce step targeted a buffer \
                 with no element kind"
                    .into(),
            ));
        }
        if dst.len() % eb != 0 {
            return Err(Error::Precondition(format!(
                "reduce length {} is not a multiple of {} ({} elements)",
                dst.len(),
                eb,
                self.name()
            )));
        }
        macro_rules! reduce_as {
            ($ty:ty, $w:expr, $combine:expr) => {
                for (d, s) in dst.chunks_exact_mut($w).zip(src.chunks_exact($w)) {
                    let a = <$ty>::from_ne_bytes(d.try_into().expect("chunk width"));
                    let b = <$ty>::from_ne_bytes(s.try_into().expect("chunk width"));
                    d.copy_from_slice(&($combine(a, b)).to_ne_bytes());
                }
            };
        }
        match self {
            ElemKind::U32 => reduce_as!(u32, 4, |a: u32, b: u32| a.wrapping_add(b)),
            ElemKind::U64 => reduce_as!(u64, 8, |a: u64, b: u64| a.wrapping_add(b)),
            ElemKind::I32 => reduce_as!(i32, 4, |a: i32, b: i32| a.wrapping_add(b)),
            ElemKind::I64 => reduce_as!(i64, 8, |a: i64, b: i64| a.wrapping_add(b)),
            ElemKind::F32 => reduce_as!(f32, 4, |a: f32, b: f32| a + b),
            ElemKind::F64 => reduce_as!(f64, 8, |a: f64, b: f64| a + b),
            ElemKind::Raw => unreachable!("handled above"),
        }
        Ok(())
    }
}

impl std::fmt::Display for ElemKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// `Pod` types with a runtime [`ElemKind`] tag — the element types that
/// segmented buffer views ([`IoView`]) can carry as *typed* segments.
pub trait ViewElem: Pod {
    /// The runtime tag matching `Self`.
    const KIND: ElemKind;
}

impl ViewElem for u32 {
    const KIND: ElemKind = ElemKind::U32;
}
impl ViewElem for u64 {
    const KIND: ElemKind = ElemKind::U64;
}
impl ViewElem for i32 {
    const KIND: ElemKind = ElemKind::I32;
}
impl ViewElem for i64 {
    const KIND: ElemKind = ElemKind::I64;
}
impl ViewElem for f32 {
    const KIND: ElemKind = ElemKind::F32;
}
impl ViewElem for f64 {
    const KIND: ElemKind = ElemKind::F64;
}

/// Element types that can be summed — the reduction of the allreduce
/// operation (the paper's allreduce reference [4] reduces with `MPI_SUM`).
/// Every summable type carries an [`ElemKind`] so reducing plans can also
/// execute over untyped segmented views.
pub trait Summable: ViewElem + std::ops::Add<Output = Self> {}
impl Summable for u32 {}
impl Summable for u64 {}
impl Summable for i32 {}
impl Summable for i64 {}
impl Summable for f32 {}
impl Summable for f64 {}

// ---------------------------------------------------------------------------
// staging-copy accounting
// ---------------------------------------------------------------------------

/// Process-global count of bytes memcpy'd through composite staging
/// buffers by *staged* fused executes ([`FusedPlan::execute`]). The
/// zero-copy view path ([`FusedPlan::execute_view`]) never touches it, so
/// `staging_bytes_total()` deltas prove (in tests) and report (in
/// `locag fuse`) exactly what the view layer eliminates. Diagnostic only:
/// relaxed ordering, summed across threads.
static STAGING_BYTES: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Total staging bytes copied by staged fused executes since process
/// start (or since [`reset_staging_bytes`]).
pub fn staging_bytes_total() -> u64 {
    STAGING_BYTES.load(std::sync::atomic::Ordering::Relaxed)
}

/// Reset the staging-copy counter (test isolation).
pub fn reset_staging_bytes() {
    STAGING_BYTES.store(0, std::sync::atomic::Ordering::Relaxed)
}

fn note_staging(bytes: usize) {
    STAGING_BYTES.fetch_add(bytes as u64, std::sync::atomic::Ordering::Relaxed);
}

/// The collective operations the planned framework covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Gather every rank's contribution everywhere (the paper's subject).
    Allgather,
    /// Elementwise sum across ranks, result everywhere (§6 extension).
    Allreduce,
    /// Personalized exchange: block `j` of rank `i` moves to rank `j`
    /// (§6 extension; the op Bruck '97 was designed for).
    Alltoall,
    /// Elementwise sum across ranks, block `i` scattered to rank `i` —
    /// the allgather's inverse sibling (Jocksch et al.; NCCL PAT).
    ReduceScatter,
    /// Ragged allgather: rank `r` contributes `counts[r]` elements
    /// (`MPI_Allgatherv` semantics; Jocksch et al.'s optimised
    /// allgatherv).
    Allgatherv,
    /// Ragged reduce-scatter: rank `r` receives the elementwise sum of
    /// every rank's `counts[r]`-element block `r`
    /// (`MPI_Reduce_scatter` with per-rank counts).
    ReduceScatterV,
}

impl OpKind {
    /// All operations, in presentation order.
    pub const ALL: [OpKind; 6] = [
        OpKind::Allgather,
        OpKind::Allreduce,
        OpKind::Alltoall,
        OpKind::ReduceScatter,
        OpKind::Allgatherv,
        OpKind::ReduceScatterV,
    ];

    /// CLI / CSV name.
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Allgather => "allgather",
            OpKind::Allreduce => "allreduce",
            OpKind::Alltoall => "alltoall",
            OpKind::ReduceScatter => "reduce-scatter",
            OpKind::Allgatherv => "allgatherv",
            OpKind::ReduceScatterV => "reduce-scatter-v",
        }
    }

    /// Parse a CLI name, case-insensitively (`reduce_scatter` and
    /// `reduce-scatter` both resolve).
    pub fn parse(s: &str) -> Option<OpKind> {
        let s = s.replace('_', "-");
        OpKind::ALL.iter().copied().find(|o| o.name().eq_ignore_ascii_case(&s))
    }

    /// Parse a CLI name; unknown names error with the valid list.
    pub fn parse_or_err(s: &str) -> Result<OpKind> {
        OpKind::parse(s).ok_or_else(|| {
            Error::Precondition(format!(
                "unknown operation '{s}' (valid: {})",
                OpKind::ALL.iter().map(|o| o.name()).collect::<Vec<_>>().join(", ")
            ))
        })
    }

    /// Input/output element counts for one collective of `n` elements over
    /// `p` ranks — the per-operation buffer contract `Schedule::io_lens`
    /// enforces, exposed here so transport-level callers (the proc pool's
    /// input-delta validation, fused-buffer layout) can size and check
    /// buffers without building a schedule first.
    ///
    /// For the ragged operations this is the **uniform interpretation**
    /// (`counts = Counts::uniform(n, p)`); ragged schedules always carry
    /// an explicit io override, and ragged call sites size buffers from
    /// [`Counts`] directly.
    pub fn io_elems(&self, n: usize, p: usize) -> (usize, usize) {
        match self {
            OpKind::Allgather | OpKind::Allgatherv => (n, n * p),
            OpKind::Allreduce => (n, n),
            OpKind::Alltoall => (n * p, n * p),
            OpKind::ReduceScatter | OpKind::ReduceScatterV => (n * p, n),
        }
    }
}

impl std::fmt::Display for OpKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Shape of one planned collective: the per-rank element count `n` (see
/// the module docs for what `n` means per operation — contribution length
/// for allgather/allreduce, per-destination block length for alltoall).
/// The rank count comes from the communicator at plan time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    /// Elements per rank (per destination block, for alltoall).
    pub n: usize,
}

impl Shape {
    /// Shape for `n` elements per rank.
    pub fn elems(n: usize) -> Shape {
        Shape { n }
    }
}

/// Per-rank element counts of one ragged collective — the plan-time
/// carrier of `MPI_Allgatherv`-style raggedness. `counts[r]` is the number
/// of elements rank `r` contributes (allgatherv) or receives
/// (reduce-scatter-v); prefix offsets give every rank the exact slice
/// layout of the concatenated result, so schedules are built over exact
/// ragged slices and nothing changes at execute time.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Counts(Vec<usize>);

impl Counts {
    /// Counts from an explicit per-rank vector.
    pub fn new(per_rank: Vec<usize>) -> Counts {
        Counts(per_rank)
    }

    /// The degenerate uniform case: `n` elements on each of `p` ranks.
    pub fn uniform(n: usize, p: usize) -> Counts {
        Counts(vec![n; p])
    }

    /// Number of ranks the counts describe.
    pub fn len(&self) -> usize {
        self.0.len()
    }

    /// True when no ranks are described.
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Rank `r`'s element count (0 if out of range — registries validate
    /// `len() == comm.size()` before any builder sees the counts).
    pub fn get(&self, rank: usize) -> usize {
        self.0.get(rank).copied().unwrap_or(0)
    }

    /// The raw per-rank slice.
    pub fn as_slice(&self) -> &[usize] {
        &self.0
    }

    /// Total element count over all ranks — the concatenated result
    /// length (allgatherv output, reduce-scatter-v input).
    pub fn total(&self) -> usize {
        self.0.iter().sum()
    }

    /// Exclusive prefix sums, `len() + 1` entries: `offsets()[r]` is where
    /// rank `r`'s block starts in the concatenated layout, and the last
    /// entry equals [`Counts::total`].
    pub fn offsets(&self) -> Vec<usize> {
        let mut offs = Vec::with_capacity(self.0.len() + 1);
        let mut acc = 0usize;
        offs.push(0);
        for &c in &self.0 {
            acc += c;
            offs.push(acc);
        }
        offs
    }

    /// Where rank `r`'s block starts in the concatenated layout.
    pub fn offset_of(&self, rank: usize) -> usize {
        self.0.iter().take(rank).sum()
    }

    /// The largest per-rank count (0 when empty).
    pub fn max(&self) -> usize {
        self.0.iter().copied().max().unwrap_or(0)
    }

    /// `Some(n)` iff every rank's count is the same `n` (None when empty
    /// or ragged) — the gate uniform algorithms use to accept a spec.
    pub fn uniform_n(&self) -> Option<usize> {
        let first = *self.0.first()?;
        if self.0.iter().all(|&c| c == first) {
            Some(first)
        } else {
            None
        }
    }

    /// Parse the CLI spelling `"4,0,7,2"` (whitespace around commas
    /// tolerated). Junk reports a typed [`Error::Precondition`].
    pub fn parse(s: &str) -> Result<Counts> {
        let mut per_rank = Vec::new();
        for tok in s.split(',') {
            let tok = tok.trim();
            let c: usize = tok.parse().map_err(|_| {
                Error::Precondition(format!(
                    "invalid counts '{s}': '{tok}' is not a non-negative integer"
                ))
            })?;
            per_rank.push(c);
        }
        Ok(Counts(per_rank))
    }
}

impl std::fmt::Display for Counts {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut first = true;
        for c in &self.0 {
            if !first {
                f.write_str(",")?;
            }
            write!(f, "{c}")?;
            first = false;
        }
        Ok(())
    }
}

/// Plan-time geometry of one collective: the per-rank [`Shape`] plus the
/// per-rank [`Counts`]. Every `*Algorithm::plan` and `OpRegistry::plan`
/// takes a `&PlanSpec`; uniform call sites go through the
/// `plan_uniform` conveniences, which build `PlanSpec::uniform` so they
/// stay source-compatible with the old bare-`Shape` API (see the
/// [module docs](self) for the migration map).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanSpec {
    /// The uniform per-rank element count (for ragged specs: a sizing
    /// hint — the largest per-rank count; the counts are authoritative).
    pub shape: Shape,
    /// Per-rank element counts; uniform specs carry
    /// `Counts::uniform(shape.n, p)`.
    pub counts: Counts,
}

impl PlanSpec {
    /// The uniform spec: `n` elements on each of `p` ranks.
    pub fn uniform(n: usize, p: usize) -> PlanSpec {
        PlanSpec { shape: Shape::elems(n), counts: Counts::uniform(n, p) }
    }

    /// A ragged spec from explicit per-rank counts (`shape.n` becomes the
    /// largest per-rank count, as a sizing hint).
    pub fn ragged(counts: Counts) -> PlanSpec {
        PlanSpec { shape: Shape::elems(counts.max()), counts }
    }

    /// Total element count over all ranks.
    pub fn total(&self) -> usize {
        self.counts.total()
    }

    /// The uniform per-rank count, or a typed precondition error when the
    /// counts are ragged — every uniform algorithm's first line, so a
    /// ragged spec handed to a uniform op fails at plan time with a
    /// pointer at the ragged registries.
    pub fn uniform_n(&self, algo: &str) -> Result<usize> {
        self.counts.uniform_n().ok_or_else(|| {
            Error::Precondition(format!(
                "{algo} plans a uniform collective but got ragged counts [{}] — \
                 use the allgatherv / reduce-scatter-v registries for per-rank counts",
                self.counts
            ))
        })
    }
}

/// Registry identity shared by every algorithm factory, whatever the
/// operation: the case-insensitive lookup name and a one-line summary.
pub trait NamedAlgorithm: Send + Sync {
    /// Registry / CLI / CSV name.
    fn name(&self) -> &'static str;

    /// One-line human description (shown by `locag algos`).
    fn summary(&self) -> &'static str {
        ""
    }
}

/// The operation-independent face of a prepared collective: identity and
/// planned geometry. Per-operation `execute` methods live on the
/// sub-traits ([`AllgatherPlan`], [`AllreducePlan`], [`AlltoallPlan`]).
pub trait CollectivePlan {
    /// Registry name of the algorithm that produced this plan.
    fn algorithm(&self) -> &'static str;

    /// The planned per-rank shape.
    fn shape(&self) -> Shape;

    /// Rank count of the planned communicator.
    fn comm_size(&self) -> usize;

    /// The communication-schedule IR this plan executes, if any (`None`
    /// only for the zero-length no-op plan). One source of truth for
    /// execution, tracing and cost prediction — see
    /// [`super::schedule`] and [`crate::model::cost`].
    fn schedule(&self) -> Option<&super::schedule::Schedule> {
        None
    }
}

/// A prepared allgather: gather `input` (length `shape().n`) from every
/// rank into `output` (length `shape().n * comm_size()`), in communicator
/// rank order. `shape().n == 0` plans are no-ops (empty output, no
/// messages). See the [module docs](self) for the full contract.
pub trait AllgatherPlan<T: Pod>: CollectivePlan {
    /// Run the communication. No allocation, no sub-communicator
    /// construction, no tag consumption.
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()>;

    /// Zero-copy variant: run over segmented buffer views (total byte
    /// lengths must match the contract above). Plans that don't support
    /// view execution report a precondition error.
    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        let _ = (input, output);
        Err(Error::Precondition("this plan does not support segmented-view execution".into()))
    }
}

/// A prepared allreduce: elementwise-sum `input` (length `shape().n`)
/// across all ranks into `output` (length `shape().n`) on every rank.
/// `shape().n == 0` plans are no-ops (empty output, no messages). See the
/// [module docs](self) for the full contract.
pub trait AllreducePlan<T: Summable>: CollectivePlan {
    /// Run the communication + reduction. No allocation, no
    /// sub-communicator construction, no tag consumption.
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()>;

    /// Zero-copy variant: run over segmented buffer views (total byte
    /// lengths must match the contract above). Plans that don't support
    /// view execution report a precondition error.
    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        let _ = (input, output);
        Err(Error::Precondition("this plan does not support segmented-view execution".into()))
    }
}

/// A prepared alltoall: `input` holds `comm_size()` blocks of `shape().n`
/// elements, block `j` destined for rank `j`; on success `output` block
/// `r` holds the block rank `r` sent here (`MPI_Alltoall` semantics).
/// `shape().n == 0` plans are no-ops (empty output, no messages). See the
/// [module docs](self) for the full contract.
pub trait AlltoallPlan<T: Pod>: CollectivePlan {
    /// Run the exchange. No allocation, no sub-communicator construction,
    /// no tag consumption.
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()>;

    /// Zero-copy variant: run over segmented buffer views (total byte
    /// lengths must match the contract above). Plans that don't support
    /// view execution report a precondition error.
    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        let _ = (input, output);
        Err(Error::Precondition("this plan does not support segmented-view execution".into()))
    }
}

/// A prepared reduce-scatter: `input` holds `comm_size()` blocks of
/// `shape().n` elements, block `j` being this rank's contribution to rank
/// `j`; on success `output` (length `shape().n`) holds the elementwise
/// sum over all ranks of this rank's block
/// (`MPI_Reduce_scatter_block` + `MPI_SUM` semantics). `shape().n == 0`
/// plans are no-ops (empty output, no messages). See the
/// [module docs](self) for the full contract.
pub trait ReduceScatterPlan<T: Summable>: CollectivePlan {
    /// Run the communication + reduction. No allocation, no
    /// sub-communicator construction, no tag consumption.
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()>;

    /// Zero-copy variant: run over segmented buffer views (total byte
    /// lengths must match the contract above). Plans that don't support
    /// view execution report a precondition error.
    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        let _ = (input, output);
        Err(Error::Precondition("this plan does not support segmented-view execution".into()))
    }
}

/// A prepared allgatherv: gather `input` (length `counts[me]`) from every
/// rank into `output` (length `counts.total()`), blocks laid out at the
/// counts' prefix offsets in rank order. All-zero counts plan as no-ops.
/// See the [module docs](self) for the full contract.
pub trait AllgathervPlan<T: Pod>: CollectivePlan {
    /// Run the communication. No allocation, no sub-communicator
    /// construction, no tag consumption.
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()>;

    /// Zero-copy variant: run over segmented buffer views (total byte
    /// lengths must match the contract above). Plans that don't support
    /// view execution report a precondition error.
    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        let _ = (input, output);
        Err(Error::Precondition("this plan does not support segmented-view execution".into()))
    }
}

/// A prepared reduce-scatter-v: `input` holds `counts.total()` elements —
/// block `j` (`counts[j]` elements, at the counts' prefix offset) being
/// this rank's contribution to rank `j`; on success `output` (length
/// `counts[me]`) holds the elementwise sum over all ranks of this rank's
/// block (`MPI_Reduce_scatter` + `MPI_SUM` semantics with per-rank
/// counts). All-zero counts plan as no-ops. See the [module docs](self)
/// for the full contract.
pub trait ReduceScattervPlan<T: Summable>: CollectivePlan {
    /// Run the communication + reduction. No allocation, no
    /// sub-communicator construction, no tag consumption.
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()>;

    /// Zero-copy variant: run over segmented buffer views (total byte
    /// lengths must match the contract above). Plans that don't support
    /// view execution report a precondition error.
    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        let _ = (input, output);
        Err(Error::Precondition("this plan does not support segmented-view execution".into()))
    }
}

/// An allgather algorithm that can produce persistent plans.
pub trait CollectiveAlgorithm<T: Pod>: NamedAlgorithm {
    /// Collectively build a plan for `spec` over `comm`.
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgatherPlan<T>>>;
}

/// An allreduce (sum) algorithm that can produce persistent plans.
pub trait AllreduceAlgorithm<T: Summable>: NamedAlgorithm {
    /// Collectively build a plan for `spec` over `comm`.
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllreducePlan<T>>>;
}

/// An alltoall algorithm that can produce persistent plans.
pub trait AlltoallAlgorithm<T: Pod>: NamedAlgorithm {
    /// Collectively build a plan for `spec` over `comm`.
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AlltoallPlan<T>>>;
}

/// A reduce-scatter (sum) algorithm that can produce persistent plans.
pub trait ReduceScatterAlgorithm<T: Summable>: NamedAlgorithm {
    /// Collectively build a plan for `spec` over `comm`.
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn ReduceScatterPlan<T>>>;
}

/// An allgatherv algorithm that can produce persistent plans. The spec's
/// counts are authoritative (`spec.counts`); registries validate
/// `counts.len() == comm.size()` before any factory runs.
pub trait AllgathervAlgorithm<T: Pod>: NamedAlgorithm {
    /// Collectively build a plan for `spec` over `comm`.
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgathervPlan<T>>>;
}

/// A reduce-scatter-v (sum) algorithm that can produce persistent plans.
pub trait ReduceScattervAlgorithm<T: Summable>: NamedAlgorithm {
    /// Collectively build a plan for `spec` over `comm`.
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn ReduceScattervPlan<T>>>;
}

/// The state every concrete plan embeds: a retained communicator handle,
/// the planned geometry and a pre-reserved collective tag block. Building
/// a `PlanCore` is collective (all ranks must reserve the same `tags`
/// count at the same point, like all plan construction).
pub(crate) struct PlanCore {
    /// Retained handle; valid for the pre-reserved tags only.
    pub comm: Comm,
    /// Planned per-rank element count.
    pub n: usize,
    /// Communicator size at plan time.
    pub p: usize,
    /// This rank within the planned communicator.
    pub id: usize,
    tag_base: u64,
}

impl PlanCore {
    /// Retain `comm` and reserve a block of `tags` collective tags.
    pub fn new(comm: &Comm, n: usize, tags: u64) -> PlanCore {
        PlanCore {
            tag_base: comm.reserve_coll_tags(tags),
            comm: comm.retain(),
            n,
            p: comm.size(),
            id: comm.rank(),
        }
    }

    /// The `i`-th tag of the reserved block.
    pub fn tag(&self, i: u64) -> u64 {
        self.tag_base + i
    }
}

/// Validate the allgather execute-time buffer contract
/// (`input: n`, `output: n·p`).
pub(crate) fn check_io<T: Pod>(n: usize, p: usize, input: &[T], output: &[T]) -> Result<()> {
    if input.len() != n {
        return Err(Error::SizeMismatch { expected: n, got: input.len() });
    }
    if output.len() != n * p {
        return Err(Error::SizeMismatch { expected: n * p, got: output.len() });
    }
    Ok(())
}

/// Validate the allreduce execute-time buffer contract
/// (`input: n`, `output: n`).
pub(crate) fn check_reduce_io<T: Pod>(n: usize, input: &[T], output: &[T]) -> Result<()> {
    if input.len() != n {
        return Err(Error::SizeMismatch { expected: n, got: input.len() });
    }
    if output.len() != n {
        return Err(Error::SizeMismatch { expected: n, got: output.len() });
    }
    Ok(())
}

/// Validate the alltoall execute-time buffer contract
/// (`input: n·p`, `output: n·p`).
pub(crate) fn check_a2a_io<T: Pod>(n: usize, p: usize, input: &[T], output: &[T]) -> Result<()> {
    if input.len() != n * p {
        return Err(Error::SizeMismatch { expected: n * p, got: input.len() });
    }
    if output.len() != n * p {
        return Err(Error::SizeMismatch { expected: n * p, got: output.len() });
    }
    Ok(())
}

/// Validate the reduce-scatter execute-time buffer contract
/// (`input: n·p`, `output: n`).
pub(crate) fn check_rs_io<T: Pod>(n: usize, p: usize, input: &[T], output: &[T]) -> Result<()> {
    if input.len() != n * p {
        return Err(Error::SizeMismatch { expected: n * p, got: input.len() });
    }
    if output.len() != n {
        return Err(Error::SizeMismatch { expected: n, got: output.len() });
    }
    Ok(())
}

/// The uniform `n == 0` plan for every operation: no communication, empty
/// output. One struct serves all four ops (all buffers are empty).
pub(crate) struct EmptyPlan {
    pub name: &'static str,
    pub p: usize,
}

impl CollectivePlan for EmptyPlan {
    fn algorithm(&self) -> &'static str {
        self.name
    }

    fn shape(&self) -> Shape {
        Shape { n: 0 }
    }

    fn comm_size(&self) -> usize {
        self.p
    }
}

/// View-contract check for the `n == 0` plan: both views must be empty.
fn check_empty_views(input: &IoView<'_>, output: &IoViewMut<'_>) -> Result<()> {
    if input.total_bytes() != 0 {
        return Err(Error::SizeMismatch { expected: 0, got: input.total_bytes() });
    }
    if output.total_bytes() != 0 {
        return Err(Error::SizeMismatch { expected: 0, got: output.total_bytes() });
    }
    Ok(())
}

impl<T: Pod> AllgatherPlan<T> for EmptyPlan {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_io(0, self.p, input, output)
    }

    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        check_empty_views(input, output)
    }
}

impl<T: Summable> AllreducePlan<T> for EmptyPlan {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_reduce_io(0, input, output)
    }

    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        check_empty_views(input, output)
    }
}

impl<T: Pod> AlltoallPlan<T> for EmptyPlan {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_a2a_io(0, self.p, input, output)
    }

    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        check_empty_views(input, output)
    }
}

impl<T: Summable> ReduceScatterPlan<T> for EmptyPlan {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_rs_io(0, self.p, input, output)
    }

    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        check_empty_views(input, output)
    }
}

/// Exact-length check shared by the ragged plans' empty short-circuit.
fn check_empty_slices<T>(input: &[T], output: &[T]) -> Result<()> {
    if !input.is_empty() {
        return Err(Error::SizeMismatch { expected: 0, got: input.len() });
    }
    if !output.is_empty() {
        return Err(Error::SizeMismatch { expected: 0, got: output.len() });
    }
    Ok(())
}

impl<T: Pod> AllgathervPlan<T> for EmptyPlan {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_empty_slices(input, output)
    }

    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        check_empty_views(input, output)
    }
}

impl<T: Summable> ReduceScattervPlan<T> for EmptyPlan {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_empty_slices(input, output)
    }

    fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        check_empty_views(input, output)
    }
}

/// Factory helper: the shared zero-length short-circuit for allgather
/// factories. Every algorithm's `plan` starts with this so the
/// zero-length contract (`counts.total() == 0` — for uniform specs,
/// `n == 0`) is uniform and bypasses even shape preconditions.
pub(crate) fn trivial_plan<T: Pod>(
    name: &'static str,
    comm: &Comm,
    spec: &PlanSpec,
) -> Option<Box<dyn AllgatherPlan<T>>> {
    if spec.total() == 0 {
        Some(Box::new(EmptyPlan { name, p: comm.size() }))
    } else {
        None
    }
}

/// Zero-length short-circuit for allreduce factories.
pub(crate) fn trivial_reduce_plan<T: Summable>(
    name: &'static str,
    comm: &Comm,
    spec: &PlanSpec,
) -> Option<Box<dyn AllreducePlan<T>>> {
    if spec.total() == 0 {
        Some(Box::new(EmptyPlan { name, p: comm.size() }))
    } else {
        None
    }
}

/// Zero-length short-circuit for alltoall factories.
pub(crate) fn trivial_a2a_plan<T: Pod>(
    name: &'static str,
    comm: &Comm,
    spec: &PlanSpec,
) -> Option<Box<dyn AlltoallPlan<T>>> {
    if spec.total() == 0 {
        Some(Box::new(EmptyPlan { name, p: comm.size() }))
    } else {
        None
    }
}

/// Zero-length short-circuit for reduce-scatter factories.
pub(crate) fn trivial_rs_plan<T: Summable>(
    name: &'static str,
    comm: &Comm,
    spec: &PlanSpec,
) -> Option<Box<dyn ReduceScatterPlan<T>>> {
    if spec.total() == 0 {
        Some(Box::new(EmptyPlan { name, p: comm.size() }))
    } else {
        None
    }
}

/// Zero-length short-circuit for allgatherv factories (all counts zero).
pub(crate) fn trivial_agv_plan<T: Pod>(
    name: &'static str,
    comm: &Comm,
    spec: &PlanSpec,
) -> Option<Box<dyn AllgathervPlan<T>>> {
    if spec.total() == 0 {
        Some(Box::new(EmptyPlan { name, p: comm.size() }))
    } else {
        None
    }
}

/// Zero-length short-circuit for reduce-scatter-v factories.
pub(crate) fn trivial_rsv_plan<T: Summable>(
    name: &'static str,
    comm: &Comm,
    spec: &PlanSpec,
) -> Option<Box<dyn ReduceScattervPlan<T>>> {
    if spec.total() == 0 {
        Some(Box::new(EmptyPlan { name, p: comm.size() }))
    } else {
        None
    }
}

/// Shared body of every allgather one-shot wrapper: plan once, allocate
/// the output, execute once. The `n == 0` no-op contract is inherited from
/// the algorithm's factory (every factory starts with [`trivial_plan`]).
pub(crate) fn one_shot<T: Pod>(
    algo: &dyn CollectiveAlgorithm<T>,
    comm: &Comm,
    local: &[T],
) -> Result<Vec<T>> {
    let mut plan = algo.plan(comm, &PlanSpec::uniform(local.len(), comm.size()))?;
    let mut out = vec![T::default(); local.len() * plan.comm_size()];
    plan.execute(local, &mut out)?;
    Ok(out)
}

/// Shared body of every allreduce one-shot wrapper.
pub(crate) fn one_shot_reduce<T: Summable>(
    algo: &dyn AllreduceAlgorithm<T>,
    comm: &Comm,
    local: &[T],
) -> Result<Vec<T>> {
    let mut plan = algo.plan(comm, &PlanSpec::uniform(local.len(), comm.size()))?;
    let mut out = vec![T::default(); local.len()];
    plan.execute(local, &mut out)?;
    Ok(out)
}

/// Shared body of every alltoall one-shot wrapper: `send.len()` must be a
/// multiple of the communicator size (block length inferred).
pub(crate) fn one_shot_a2a<T: Pod>(
    algo: &dyn AlltoallAlgorithm<T>,
    comm: &Comm,
    send: &[T],
) -> Result<Vec<T>> {
    let p = comm.size();
    if send.len() % p != 0 {
        return Err(Error::SizeMismatch {
            expected: (send.len() / p.max(1)) * p,
            got: send.len(),
        });
    }
    let mut plan = algo.plan(comm, &PlanSpec::uniform(send.len() / p, p))?;
    let mut out = vec![T::default(); send.len()];
    plan.execute(send, &mut out)?;
    Ok(out)
}

/// Shared body of every reduce-scatter one-shot wrapper: `send.len()`
/// must be a multiple of the communicator size (block length inferred).
pub(crate) fn one_shot_rs<T: Summable>(
    algo: &dyn ReduceScatterAlgorithm<T>,
    comm: &Comm,
    send: &[T],
) -> Result<Vec<T>> {
    let p = comm.size();
    if send.len() % p != 0 {
        return Err(Error::SizeMismatch {
            expected: (send.len() / p.max(1)) * p,
            got: send.len(),
        });
    }
    let mut plan = algo.plan(comm, &PlanSpec::uniform(send.len() / p, p))?;
    let mut out = vec![T::default(); send.len() / p];
    plan.execute(send, &mut out)?;
    Ok(out)
}

/// Shared body of the allgatherv one-shot wrapper: `local.len()` must
/// equal this rank's count; the output is the counts' total.
pub(crate) fn one_shot_agv<T: Pod>(
    algo: &dyn AllgathervAlgorithm<T>,
    comm: &Comm,
    local: &[T],
    counts: &Counts,
) -> Result<Vec<T>> {
    check_counts_len(counts, comm.size())?;
    if local.len() != counts.get(comm.rank()) {
        return Err(Error::SizeMismatch { expected: counts.get(comm.rank()), got: local.len() });
    }
    let mut plan = algo.plan(comm, &PlanSpec::ragged(counts.clone()))?;
    let mut out = vec![T::default(); counts.total()];
    plan.execute(local, &mut out)?;
    Ok(out)
}

/// Shared body of the reduce-scatter-v one-shot wrapper: `send.len()`
/// must equal the counts' total; the output is this rank's count.
pub(crate) fn one_shot_rsv<T: Summable>(
    algo: &dyn ReduceScattervAlgorithm<T>,
    comm: &Comm,
    send: &[T],
    counts: &Counts,
) -> Result<Vec<T>> {
    check_counts_len(counts, comm.size())?;
    if send.len() != counts.total() {
        return Err(Error::SizeMismatch { expected: counts.total(), got: send.len() });
    }
    let mut plan = algo.plan(comm, &PlanSpec::ragged(counts.clone()))?;
    let mut out = vec![T::default(); counts.get(comm.rank())];
    plan.execute(send, &mut out)?;
    Ok(out)
}

/// The counts-arity precondition every ragged entry point enforces:
/// one count per rank, rejected at plan time with a typed error.
pub(crate) fn check_counts_len(counts: &Counts, p: usize) -> Result<()> {
    if counts.len() != p {
        return Err(Error::Precondition(format!(
            "counts length {} does not match communicator size {p}",
            counts.len()
        )));
    }
    Ok(())
}

/// Name → algorithm-factory registry for one operation.
///
/// Lookup is case-insensitive; the *last* registration of a name wins so
/// callers can override built-ins (e.g. swap in a backend-specific
/// implementation) without touching dispatch code. [`Registry`],
/// [`AllreduceRegistry`] and [`AlltoallRegistry`] are the concrete
/// per-operation instantiations.
pub struct OpRegistry<A: ?Sized + NamedAlgorithm> {
    op: OpKind,
    entries: Vec<Box<A>>,
}

impl<A: ?Sized + NamedAlgorithm> OpRegistry<A> {
    /// An empty registry for `op`.
    pub fn new(op: OpKind) -> OpRegistry<A> {
        OpRegistry { op, entries: Vec::new() }
    }

    /// The operation this registry plans.
    pub fn op(&self) -> OpKind {
        self.op
    }

    /// Add (or override) an algorithm.
    pub fn register(&mut self, algo: Box<A>) {
        self.entries.push(algo);
    }

    /// Registered names, registration order, overrides collapsed.
    pub fn names(&self) -> Vec<&'static str> {
        let mut seen: Vec<&'static str> = Vec::new();
        for e in &self.entries {
            if !seen.iter().any(|n| n.eq_ignore_ascii_case(e.name())) {
                seen.push(e.name());
            }
        }
        seen
    }

    /// Look up an algorithm by case-insensitive name (latest wins).
    pub fn get(&self, name: &str) -> Option<&A> {
        self.entries
            .iter()
            .rev()
            .find(|a| a.name().eq_ignore_ascii_case(name))
            .map(|b| b.as_ref())
    }

    /// `(name, summary)` pairs for listings.
    pub fn catalog(&self) -> Vec<(&'static str, &'static str)> {
        self.names()
            .into_iter()
            .map(|n| (n, self.get(n).expect("name came from names()").summary()))
            .collect()
    }

    /// The unknown-name error, listing every valid name for this op.
    pub(crate) fn unknown(&self, name: &str) -> Error {
        Error::Precondition(format!(
            "unknown {} algorithm '{name}' (valid: {})",
            self.op,
            self.names().join(", ")
        ))
    }
}

/// The allgather registry (kept under its PR-1 name: the allgather is the
/// paper's subject and the crate's original registry).
pub type Registry<T> = OpRegistry<dyn CollectiveAlgorithm<T>>;

/// The allreduce registry.
pub type AllreduceRegistry<T> = OpRegistry<dyn AllreduceAlgorithm<T>>;

/// The alltoall registry.
pub type AlltoallRegistry<T> = OpRegistry<dyn AlltoallAlgorithm<T>>;

/// The reduce-scatter registry.
pub type ReduceScatterRegistry<T> = OpRegistry<dyn ReduceScatterAlgorithm<T>>;

/// The allgatherv (ragged allgather) registry.
pub type AllgathervRegistry<T> = OpRegistry<dyn AllgathervAlgorithm<T>>;

/// The reduce-scatter-v (ragged reduce-scatter) registry.
pub type ReduceScattervRegistry<T> = OpRegistry<dyn ReduceScattervAlgorithm<T>>;

impl<T: Pod> Registry<T> {
    /// An empty allgather registry.
    pub fn empty() -> Registry<T> {
        OpRegistry::new(OpKind::Allgather)
    }

    /// The built-in allgathers, in the order the figures report them
    /// (the eleven classic algorithms plus the model-tuned dispatcher).
    pub fn standard() -> Registry<T> {
        let mut r = Registry::empty();
        r.register(Box::new(dispatch::SystemDefault));
        r.register(Box::new(bruck::Bruck));
        r.register(Box::new(pat::PatAllgather));
        r.register(Box::new(ring::Ring));
        r.register(Box::new(recursive_doubling::RecursiveDoubling));
        r.register(Box::new(dissemination::Dissemination));
        r.register(Box::new(hierarchical::Hierarchical));
        r.register(Box::new(multilane::Multilane));
        r.register(Box::new(loc_bruck::LocalityBruck));
        r.register(Box::new(loc_bruck::LocalityBruckV));
        r.register(Box::new(loc_bruck::LocalityBruckMultilevel));
        r.register(Box::new(model_tuned::ModelTuned));
        r
    }

    /// Plan by name. Unknown names report the full list of valid names;
    /// counts whose length differs from the communicator size are a typed
    /// precondition error before any factory runs.
    pub fn plan(
        &self,
        name: &str,
        comm: &Comm,
        spec: &PlanSpec,
    ) -> Result<Box<dyn AllgatherPlan<T>>> {
        check_counts_len(&spec.counts, comm.size())?;
        match self.get(name) {
            Some(a) => a.plan(comm, spec),
            None => Err(self.unknown(name)),
        }
    }

    /// Uniform-counts convenience: plan `shape.n` elements per rank (the
    /// source-compatible face of the old bare-`Shape` API).
    pub fn plan_uniform(
        &self,
        name: &str,
        comm: &Comm,
        shape: Shape,
    ) -> Result<Box<dyn AllgatherPlan<T>>> {
        self.plan(name, comm, &PlanSpec::uniform(shape.n, comm.size()))
    }
}

impl<T: Summable> AllreduceRegistry<T> {
    /// An empty allreduce registry.
    pub fn empty() -> AllreduceRegistry<T> {
        OpRegistry::new(OpKind::Allreduce)
    }

    /// The built-in allreduces: recursive doubling, the §6 locality-aware
    /// regional variant, the any-size Rabenseifner composition, the fully
    /// hierarchical Rabenseifner (both phases locality-aware) and the
    /// model-tuned dispatcher.
    pub fn standard() -> AllreduceRegistry<T> {
        let mut r = AllreduceRegistry::empty();
        r.register(Box::new(allreduce::RecursiveDoublingAllreduce));
        r.register(Box::new(allreduce::LocalityAwareAllreduce));
        r.register(Box::new(allreduce::RabenseifnerAllreduce));
        r.register(Box::new(allreduce::LocRabenseifnerAllreduce));
        r.register(Box::new(model_tuned::ModelTunedAllreduce));
        r
    }

    /// Plan by name. Unknown names report the full list of valid names.
    pub fn plan(
        &self,
        name: &str,
        comm: &Comm,
        spec: &PlanSpec,
    ) -> Result<Box<dyn AllreducePlan<T>>> {
        check_counts_len(&spec.counts, comm.size())?;
        match self.get(name) {
            Some(a) => a.plan(comm, spec),
            None => Err(self.unknown(name)),
        }
    }

    /// Uniform-counts convenience (see [`Registry::plan_uniform`]).
    pub fn plan_uniform(
        &self,
        name: &str,
        comm: &Comm,
        shape: Shape,
    ) -> Result<Box<dyn AllreducePlan<T>>> {
        self.plan(name, comm, &PlanSpec::uniform(shape.n, comm.size()))
    }
}

impl<T: Pod> AlltoallRegistry<T> {
    /// An empty alltoall registry.
    pub fn empty() -> AlltoallRegistry<T> {
        OpRegistry::new(OpKind::Alltoall)
    }

    /// The built-in alltoalls: MPICH-style dispatch, pairwise, Bruck, the
    /// §6 locality-aware aggregation variant and the model-tuned
    /// dispatcher.
    pub fn standard() -> AlltoallRegistry<T> {
        let mut r = AlltoallRegistry::empty();
        r.register(Box::new(dispatch::SystemDefaultAlltoall));
        r.register(Box::new(alltoall::PairwiseAlltoall));
        r.register(Box::new(alltoall::BruckAlltoall));
        r.register(Box::new(alltoall::LocAwareAlltoall));
        r.register(Box::new(model_tuned::ModelTunedAlltoall));
        r
    }

    /// Plan by name. Unknown names report the full list of valid names.
    pub fn plan(
        &self,
        name: &str,
        comm: &Comm,
        spec: &PlanSpec,
    ) -> Result<Box<dyn AlltoallPlan<T>>> {
        check_counts_len(&spec.counts, comm.size())?;
        match self.get(name) {
            Some(a) => a.plan(comm, spec),
            None => Err(self.unknown(name)),
        }
    }

    /// Uniform-counts convenience (see [`Registry::plan_uniform`]).
    pub fn plan_uniform(
        &self,
        name: &str,
        comm: &Comm,
        shape: Shape,
    ) -> Result<Box<dyn AlltoallPlan<T>>> {
        self.plan(name, comm, &PlanSpec::uniform(shape.n, comm.size()))
    }
}

impl<T: Summable> ReduceScatterRegistry<T> {
    /// An empty reduce-scatter registry.
    pub fn empty() -> ReduceScatterRegistry<T> {
        OpRegistry::new(OpKind::ReduceScatter)
    }

    /// The built-in reduce-scatters: ring (bandwidth-optimal baseline),
    /// recursive halving (Rabenseifner's first phase), the PAT aggregated
    /// trees (log-depth at any size), the locality-aware lane variant and
    /// the model-tuned dispatcher.
    pub fn standard() -> ReduceScatterRegistry<T> {
        let mut r = ReduceScatterRegistry::empty();
        r.register(Box::new(reduce_scatter::RingReduceScatter));
        r.register(Box::new(reduce_scatter::RecursiveHalvingReduceScatter));
        r.register(Box::new(pat::PatReduceScatter));
        r.register(Box::new(reduce_scatter::LocAwareReduceScatter));
        r.register(Box::new(model_tuned::ModelTunedReduceScatter));
        r
    }

    /// Plan by name. Unknown names report the full list of valid names.
    pub fn plan(
        &self,
        name: &str,
        comm: &Comm,
        spec: &PlanSpec,
    ) -> Result<Box<dyn ReduceScatterPlan<T>>> {
        check_counts_len(&spec.counts, comm.size())?;
        match self.get(name) {
            Some(a) => a.plan(comm, spec),
            None => Err(self.unknown(name)),
        }
    }

    /// Uniform-counts convenience (see [`Registry::plan_uniform`]).
    pub fn plan_uniform(
        &self,
        name: &str,
        comm: &Comm,
        shape: Shape,
    ) -> Result<Box<dyn ReduceScatterPlan<T>>> {
        self.plan(name, comm, &PlanSpec::uniform(shape.n, comm.size()))
    }
}

impl<T: Pod> AllgathervRegistry<T> {
    /// An empty allgatherv registry.
    pub fn empty() -> AllgathervRegistry<T> {
        OpRegistry::new(OpKind::Allgatherv)
    }

    /// The built-in allgathervs: ring (neighbour exchange over ragged
    /// blocks), Bruck with per-partner recv counts (the sst-macro
    /// `bruck_allgatherv` shape, extra-round trick for non-power-of-two
    /// p), the locality-aware regional aggregation and the model-tuned
    /// dispatcher.
    pub fn standard() -> AllgathervRegistry<T> {
        let mut r = AllgathervRegistry::empty();
        r.register(Box::new(allgatherv::RingAllgatherv));
        r.register(Box::new(allgatherv::BruckAllgatherv));
        r.register(Box::new(allgatherv::LocAwareAllgatherv));
        r.register(Box::new(model_tuned::ModelTunedAllgatherv));
        r
    }

    /// Plan by name; the spec's counts are authoritative (one count per
    /// rank, validated here).
    pub fn plan(
        &self,
        name: &str,
        comm: &Comm,
        spec: &PlanSpec,
    ) -> Result<Box<dyn AllgathervPlan<T>>> {
        check_counts_len(&spec.counts, comm.size())?;
        match self.get(name) {
            Some(a) => a.plan(comm, spec),
            None => Err(self.unknown(name)),
        }
    }

    /// Uniform-counts convenience: `shape.n` elements on every rank (the
    /// degenerate `MPI_Allgather` case of allgatherv).
    pub fn plan_uniform(
        &self,
        name: &str,
        comm: &Comm,
        shape: Shape,
    ) -> Result<Box<dyn AllgathervPlan<T>>> {
        self.plan(name, comm, &PlanSpec::uniform(shape.n, comm.size()))
    }
}

impl<T: Summable> ReduceScattervRegistry<T> {
    /// An empty reduce-scatter-v registry.
    pub fn empty() -> ReduceScattervRegistry<T> {
        OpRegistry::new(OpKind::ReduceScatterV)
    }

    /// The built-in reduce-scatter-vs: ring (exchange-and-reduce over
    /// ragged blocks), the locality-aware lane variant and the
    /// model-tuned dispatcher.
    pub fn standard() -> ReduceScattervRegistry<T> {
        let mut r = ReduceScattervRegistry::empty();
        r.register(Box::new(reduce_scatter_v::RingReduceScatterv));
        r.register(Box::new(reduce_scatter_v::LocAwareReduceScatterv));
        r.register(Box::new(model_tuned::ModelTunedReduceScatterv));
        r
    }

    /// Plan by name; the spec's counts are authoritative (one count per
    /// rank, validated here).
    pub fn plan(
        &self,
        name: &str,
        comm: &Comm,
        spec: &PlanSpec,
    ) -> Result<Box<dyn ReduceScattervPlan<T>>> {
        check_counts_len(&spec.counts, comm.size())?;
        match self.get(name) {
            Some(a) => a.plan(comm, spec),
            None => Err(self.unknown(name)),
        }
    }

    /// Uniform-counts convenience: `shape.n` elements for every rank.
    pub fn plan_uniform(
        &self,
        name: &str,
        comm: &Comm,
        shape: Shape,
    ) -> Result<Box<dyn ReduceScattervPlan<T>>> {
        self.plan(name, comm, &PlanSpec::uniform(shape.n, comm.size()))
    }
}

impl<T: Pod> Default for Registry<T> {
    fn default() -> Self {
        Registry::standard()
    }
}

impl<T: Summable> Default for AllreduceRegistry<T> {
    fn default() -> Self {
        AllreduceRegistry::standard()
    }
}

impl<T: Pod> Default for AlltoallRegistry<T> {
    fn default() -> Self {
        AlltoallRegistry::standard()
    }
}

impl<T: Summable> Default for ReduceScatterRegistry<T> {
    fn default() -> Self {
        ReduceScatterRegistry::standard()
    }
}

impl<T: Pod> Default for AllgathervRegistry<T> {
    fn default() -> Self {
        AllgathervRegistry::standard()
    }
}

impl<T: Summable> Default for ReduceScattervRegistry<T> {
    fn default() -> Self {
        ReduceScattervRegistry::standard()
    }
}

// ---------------------------------------------------------------------------
// fused multi-plan execution
// ---------------------------------------------------------------------------

/// IO geometry of one constituent inside a [`FusedPlan`].
struct FusedPart {
    in_off: usize,
    in_len: usize,
    out_off: usize,
    out_len: usize,
}

/// A persistent plan that executes **several** collectives — possibly of
/// different operations and algorithms — as **one** round-merged,
/// message-coalesced [`Schedule`] through the same generic interpreter
/// that runs every single-op plan ([`super::schedule::SchedPlan`]'s
/// executor).
///
/// Built collectively by [`FusedPlan::plan`] (or the front door
/// [`super::plan_fused`]) from [`FuseSpec`]s; the fusion itself is
/// [`super::fuse::fuse_world`]. Like every plan, everything is owned up
/// front: retained communicator, one composite tag block, composite
/// input/output staging and scratch — `execute` does pure communication
/// plus the staging copies, with zero allocation and no tag consumption.
///
/// Constituents with `n == 0` take part with empty buffers and no
/// communication (the uniform zero-length contract). `T` must be
/// [`Summable`] because a fused schedule may contain the reduction steps
/// of an allreduce constituent.
pub struct FusedPlan<T: Summable> {
    core: PlanCore,
    sched: Schedule,
    parts: Vec<FusedPart>,
    /// Composite staging buffers (constituent windows, in spec order).
    input: Vec<T>,
    output: Vec<T>,
    scratch: Vec<Vec<T>>,
    /// Byte-granular scratch mirror for the zero-copy view executor;
    /// allocated lazily on the first `execute_view` (scratch is
    /// written-before-read by every schedule, so the two executors can
    /// share nothing and still agree bit-for-bit).
    view_scratch: Vec<Vec<u8>>,
    wire: Vec<u8>,
}

impl<T: Summable> FusedPlan<T> {
    /// Collectively build a fused plan for `specs` over `comm`. All ranks
    /// must call this with identical specs, like all plan construction.
    /// Constituent shape preconditions surface here, not at execute.
    pub fn plan(comm: &Comm, specs: &[FuseSpec]) -> Result<FusedPlan<T>> {
        let elem_bytes = std::mem::size_of::<T>();
        let view = WorldView::from_comm(comm);
        let machine = comm.machine().cloned().unwrap_or_else(MachineParams::lassen);
        let (mut fused, _) = fuse_world(specs, &view, elem_bytes, &machine)?;
        let sched = fused.swap_remove(comm.rank());
        sched.validate()?;
        let p = comm.size();
        let mut parts = Vec::with_capacity(specs.len());
        let (mut in_off, mut out_off) = (0usize, 0usize);
        for s in specs {
            let (il, ol) = s.io_elems(comm.rank(), p);
            parts.push(FusedPart { in_off, in_len: il, out_off, out_len: ol });
            in_off += il;
            out_off += ol;
        }
        debug_assert_eq!(sched.io_lens(), (in_off, out_off));
        let core = PlanCore::new(comm, sched.n, sched.tags);
        let scratch = sched.scratch.iter().map(|&len| vec![T::default(); len]).collect();
        let wire = vec![0u8; sched.max_padded_wire()];
        Ok(FusedPlan {
            core,
            sched,
            parts,
            input: vec![T::default(); in_off],
            output: vec![T::default(); out_off],
            scratch,
            view_scratch: Vec::new(),
            wire,
        })
    }

    /// Number of constituent collectives (including `n == 0` no-ops).
    pub fn num_parts(&self) -> usize {
        self.parts.len()
    }

    /// Shared arity + per-constituent length validation of both fused
    /// entry points.
    fn check_parts(&self, inputs: &[&[T]], outputs: &[&mut [T]]) -> Result<()> {
        if inputs.len() != self.parts.len() {
            return Err(Error::SizeMismatch { expected: self.parts.len(), got: inputs.len() });
        }
        if outputs.len() != self.parts.len() {
            return Err(Error::SizeMismatch { expected: self.parts.len(), got: outputs.len() });
        }
        for (i, part) in self.parts.iter().enumerate() {
            if inputs[i].len() != part.in_len {
                return Err(Error::SizeMismatch { expected: part.in_len, got: inputs[i].len() });
            }
            if outputs[i].len() != part.out_len {
                return Err(Error::SizeMismatch {
                    expected: part.out_len,
                    got: outputs[i].len(),
                });
            }
        }
        Ok(())
    }

    /// Execute every constituent as one fused schedule. `inputs[i]` /
    /// `outputs[i]` follow constituent `i`'s per-op buffer contract
    /// (see the [module docs](self)); both slices must be given for every
    /// constituent, in spec order.
    ///
    /// This is the **staged** path: constituent buffers are memcpy'd
    /// through the composite staging windows on the way in and out (the
    /// copies are tallied in [`staging_bytes_total`]). It doubles as the
    /// conformance oracle for the zero-copy [`FusedPlan::execute_view`].
    pub fn execute(&mut self, inputs: &[&[T]], outputs: &mut [&mut [T]]) -> Result<()> {
        self.check_parts(inputs, outputs)?;
        for (i, part) in self.parts.iter().enumerate() {
            self.input[part.in_off..part.in_off + part.in_len].copy_from_slice(inputs[i]);
        }
        {
            let FusedPlan { core, sched, input, output, scratch, wire, .. } = self;
            execute_schedule(core, sched, input, output, scratch, wire, Some(add_assign::<T>))?;
        }
        for (i, part) in self.parts.iter().enumerate() {
            outputs[i].copy_from_slice(&self.output[part.out_off..part.out_off + part.out_len]);
        }
        note_staging((self.input.len() + self.output.len()) * std::mem::size_of::<T>());
        Ok(())
    }

    /// Zero-copy execute: identical contract and results as
    /// [`FusedPlan::execute`], but each constituent's caller-owned buffer
    /// becomes one segment of a composite [`IoView`] and the schedule runs
    /// in place over those segments — no staging memcpys at all.
    pub fn execute_view(&mut self, inputs: &[&[T]], outputs: &mut [&mut [T]]) -> Result<()> {
        self.check_parts(inputs, outputs)?;
        let mut iv = IoView::new();
        for seg in inputs {
            iv.push::<T>(seg);
        }
        let mut ov = IoViewMut::new();
        for seg in outputs.iter_mut() {
            ov.push::<T>(seg);
        }
        if self.view_scratch.len() != self.sched.scratch.len() {
            let eb = std::mem::size_of::<T>();
            self.view_scratch = self.sched.scratch.iter().map(|&l| vec![0u8; l * eb]).collect();
        }
        let FusedPlan { core, sched, view_scratch, wire, .. } = self;
        execute_schedule_view(
            core,
            sched,
            &iv,
            &mut ov,
            view_scratch,
            wire,
            &ViewReduce::Uniform(T::KIND),
        )
    }
}

impl<T: Summable> CollectivePlan for FusedPlan<T> {
    fn algorithm(&self) -> &'static str {
        "fused"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.core.n }
    }

    fn comm_size(&self) -> usize {
        self.core.p
    }

    fn schedule(&self) -> Option<&Schedule> {
        Some(&self.sched)
    }
}

/// IO geometry + element kind of one constituent inside a
/// [`FusedPlanMixed`], in **bytes** (the mixed schedule is byte-scaled).
struct MixedPart {
    in_bytes: usize,
    out_bytes: usize,
    kind: ElemKind,
}

/// A fused plan whose constituents have **different element types** —
/// e.g. an `f32` activation allgather fused with a `u64` counter
/// allreduce. Views are typed per-segment, so no common `T` exists;
/// the plan is execute-by-view only (there is no composite typed staging
/// buffer a staged path could even use).
///
/// Internally every constituent schedule is scaled to byte granularity
/// ([`Schedule::scale_to_bytes`](super::schedule::Schedule::scale_to_bytes))
/// before fusion, which preserves wire framing, padding and therefore the
/// cost model exactly; reductions recover their element type from the
/// per-segment [`ElemKind`]s (outputs) and the fused schedule's per-rank
/// scratch-kind table (scratch).
pub struct FusedPlanMixed {
    core: PlanCore,
    sched: Schedule,
    parts: Vec<MixedPart>,
    scratch: Vec<Vec<u8>>,
    scratch_kinds: Vec<ElemKind>,
    wire: Vec<u8>,
}

impl FusedPlanMixed {
    /// Collectively build a mixed-type fused plan: each spec carries its
    /// own element kind. All ranks must call with identical `specs`.
    pub fn plan(comm: &Comm, specs: &[(FuseSpec, ElemKind)]) -> Result<FusedPlanMixed> {
        let view = WorldView::from_comm(comm);
        let machine = comm.machine().cloned().unwrap_or_else(MachineParams::lassen);
        let (mut fused, _stats, mut kinds) = fuse_world_mixed(specs, &view, &machine)?;
        let sched = fused.swap_remove(comm.rank());
        sched.validate()?;
        let scratch_kinds = kinds.swap_remove(comm.rank());
        debug_assert_eq!(scratch_kinds.len(), sched.scratch.len());
        let p = comm.size();
        let mut parts = Vec::with_capacity(specs.len());
        for (s, k) in specs {
            let (il, ol) = s.io_elems(comm.rank(), p);
            parts.push(MixedPart {
                in_bytes: il * k.bytes(),
                out_bytes: ol * k.bytes(),
                kind: *k,
            });
        }
        let core = PlanCore::new(comm, sched.n, sched.tags);
        let scratch = sched.scratch.iter().map(|&len| vec![0u8; len]).collect();
        let wire = vec![0u8; sched.max_padded_wire()];
        Ok(FusedPlanMixed { core, sched, parts, scratch, scratch_kinds, wire })
    }

    /// Number of constituent collectives (including `n == 0` no-ops).
    pub fn num_parts(&self) -> usize {
        self.parts.len()
    }

    /// Execute every constituent in place: view segment `i` must be
    /// constituent `i`'s buffer, with matching byte length **and**
    /// element kind (a typed push via [`IoView::push`] gets both right).
    pub fn execute_view(&mut self, input: &IoView<'_>, output: &mut IoViewMut<'_>) -> Result<()> {
        if input.num_segments() != self.parts.len() {
            return Err(Error::SizeMismatch {
                expected: self.parts.len(),
                got: input.num_segments(),
            });
        }
        if output.num_segments() != self.parts.len() {
            return Err(Error::SizeMismatch {
                expected: self.parts.len(),
                got: output.num_segments(),
            });
        }
        for (i, part) in self.parts.iter().enumerate() {
            if input.segment_bytes(i) != part.in_bytes {
                return Err(Error::SizeMismatch {
                    expected: part.in_bytes,
                    got: input.segment_bytes(i),
                });
            }
            if output.segment_bytes(i) != part.out_bytes {
                return Err(Error::SizeMismatch {
                    expected: part.out_bytes,
                    got: output.segment_bytes(i),
                });
            }
            if input.segment_kind(i) != part.kind || output.segment_kind(i) != part.kind {
                return Err(Error::Precondition(format!(
                    "constituent {i} expects {} segments (got input {}, output {})",
                    part.kind,
                    input.segment_kind(i),
                    output.segment_kind(i)
                )));
            }
        }
        let FusedPlanMixed { core, sched, scratch, scratch_kinds, wire, .. } = self;
        execute_schedule_view(
            core,
            sched,
            input,
            output,
            scratch,
            wire,
            &ViewReduce::PerScratch(scratch_kinds),
        )
    }
}

impl CollectivePlan for FusedPlanMixed {
    fn algorithm(&self) -> &'static str {
        "fused-mixed"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.core.n }
    }

    fn comm_size(&self) -> usize {
        self.core.p
    }

    fn schedule(&self) -> Option<&Schedule> {
        Some(&self.sched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{canonical_contribution, expected_result, Algorithm};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    #[test]
    fn standard_registry_matches_algorithm_enum() {
        let r = Registry::<u64>::standard();
        let names = r.names();
        assert_eq!(names.len(), Algorithm::ALL.len());
        for a in Algorithm::ALL {
            assert!(names.contains(&a.name()), "missing {}", a.name());
        }
        for (name, summary) in r.catalog() {
            assert!(!name.is_empty());
            assert!(!summary.is_empty(), "{name} has no summary");
        }
    }

    #[test]
    fn allreduce_and_alltoall_registries_have_catalogs() {
        let r = AllreduceRegistry::<u64>::standard();
        assert_eq!(r.op(), OpKind::Allreduce);
        assert_eq!(
            r.names(),
            vec![
                "recursive-doubling",
                "loc-aware",
                "rabenseifner",
                "loc-rabenseifner",
                "model-tuned"
            ]
        );
        for (name, summary) in r.catalog() {
            assert!(!summary.is_empty(), "{name} has no summary");
        }
        let r = AlltoallRegistry::<u64>::standard();
        assert_eq!(r.op(), OpKind::Alltoall);
        assert_eq!(
            r.names(),
            vec!["system-default", "pairwise", "bruck", "loc-aware", "model-tuned"]
        );
        for (name, summary) in r.catalog() {
            assert!(!summary.is_empty(), "{name} has no summary");
        }
        let r = ReduceScatterRegistry::<u64>::standard();
        assert_eq!(r.op(), OpKind::ReduceScatter);
        assert_eq!(
            r.names(),
            vec!["ring", "recursive-halving", "pat", "loc-aware", "model-tuned"]
        );
        for (name, summary) in r.catalog() {
            assert!(!summary.is_empty(), "{name} has no summary");
        }
    }

    #[test]
    fn ragged_registries_have_catalogs() {
        let r = AllgathervRegistry::<u64>::standard();
        assert_eq!(r.op(), OpKind::Allgatherv);
        assert_eq!(r.names(), vec!["ring", "bruck", "loc-aware", "model-tuned"]);
        for (name, summary) in r.catalog() {
            assert!(!summary.is_empty(), "{name} has no summary");
        }
        let r = ReduceScattervRegistry::<u64>::standard();
        assert_eq!(r.op(), OpKind::ReduceScatterV);
        assert_eq!(r.names(), vec!["ring", "loc-aware", "model-tuned"]);
        for (name, summary) in r.catalog() {
            assert!(!summary.is_empty(), "{name} has no summary");
        }
    }

    #[test]
    fn counts_helpers_cover_the_ragged_layout() {
        let c = Counts::new(vec![4, 0, 7, 2]);
        assert_eq!(c.len(), 4);
        assert!(!c.is_empty());
        assert_eq!(c.total(), 13);
        assert_eq!(c.offsets(), vec![0, 4, 4, 11, 13]);
        assert_eq!(c.offset_of(2), 4);
        assert_eq!(c.get(1), 0);
        assert_eq!(c.get(99), 0);
        assert_eq!(c.max(), 7);
        assert_eq!(c.uniform_n(), None);
        assert_eq!(c.to_string(), "4,0,7,2");
        assert_eq!(Counts::parse("4, 0,7 ,2").unwrap(), c);
        assert!(Counts::parse("4,x,2").is_err());
        assert!(Counts::parse("").is_err());
        let u = Counts::uniform(3, 4);
        assert_eq!(u.uniform_n(), Some(3));
        assert_eq!(u.total(), 12);
        assert_eq!(PlanSpec::uniform(3, 4).counts, u);
        let ragged = PlanSpec::ragged(c.clone());
        assert_eq!(ragged.shape.n, 7);
        assert_eq!(ragged.total(), 13);
        assert!(ragged.uniform_n("bruck").is_err());
        assert_eq!(PlanSpec::uniform(3, 4).uniform_n("bruck").unwrap(), 3);
    }

    #[test]
    fn ragged_counts_reject_on_uniform_ops_and_wrong_length() {
        let topo = Topology::regions(2, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = Registry::<u64>::standard();
            // ragged counts on a uniform op: typed precondition
            let ragged = PlanSpec::ragged(Counts::new(vec![1, 2, 3, 4]));
            let e1 = matches!(r.plan("bruck", c, &ragged), Err(Error::Precondition(_)));
            // counts length != p: typed precondition, even for ragged ops
            let short = PlanSpec::ragged(Counts::new(vec![1, 2]));
            let agv = AllgathervRegistry::<u64>::standard();
            let e2 = matches!(agv.plan("ring", c, &short), Err(Error::Precondition(_)));
            let rsv = ReduceScattervRegistry::<u64>::standard();
            let e3 = matches!(rsv.plan("ring", c, &short), Err(Error::Precondition(_)));
            e1 && e2 && e3
        });
        assert!(run.results.iter().all(|&b| b));
    }

    #[test]
    fn op_kind_names_roundtrip() {
        for op in OpKind::ALL {
            assert_eq!(OpKind::parse(op.name()), Some(op));
            assert_eq!(OpKind::parse(&op.name().to_uppercase()), Some(op));
        }
        assert_eq!(OpKind::parse("reduce_scatter"), Some(OpKind::ReduceScatter));
        assert_eq!(OpKind::parse("Reduce_Scatter"), Some(OpKind::ReduceScatter));
        assert_eq!(OpKind::parse("reduce_scatter_v"), Some(OpKind::ReduceScatterV));
        assert_eq!(OpKind::parse("Allgatherv"), Some(OpKind::Allgatherv));
        assert_eq!(OpKind::parse("nope"), None);
        let err = OpKind::parse_or_err("warp").unwrap_err().to_string();
        assert!(err.contains("allgather") && err.contains("reduce-scatter"), "{err}");
    }

    #[test]
    fn lookup_is_case_insensitive() {
        let r = Registry::<u32>::standard();
        assert!(r.get("LOC-BRUCK").is_some());
        assert!(r.get("Bruck").is_some());
        assert!(r.get("nope").is_none());
        let r = AlltoallRegistry::<u32>::standard();
        assert!(r.get("PAIRWISE").is_some());
    }

    #[test]
    fn unknown_name_error_lists_valid_names() {
        let topo = Topology::regions(1, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = Registry::<u32>::standard();
            let ag = match r.plan_uniform("warp-drive", c, Shape::elems(1)) {
                Err(e) => e.to_string(),
                Ok(_) => String::new(),
            };
            let r = AllreduceRegistry::<u32>::standard();
            let ar = match r.plan_uniform("warp-drive", c, Shape::elems(1)) {
                Err(e) => e.to_string(),
                Ok(_) => String::new(),
            };
            (ag, ar)
        });
        for (ag, ar) in &run.results {
            assert!(ag.contains("warp-drive"), "{ag}");
            assert!(ag.contains("allgather"), "{ag}");
            assert!(ag.contains("loc-bruck"), "{ag}");
            assert!(ag.contains("ring"), "{ag}");
            assert!(ar.contains("allreduce"), "{ar}");
            assert!(ar.contains("recursive-doubling"), "{ar}");
        }
    }

    #[test]
    fn every_builtin_plans_and_executes_by_name() {
        let topo = Topology::regions(4, 4);
        let p = topo.size();
        let n = 2usize;
        let expect = expected_result(p, n);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = Registry::<u64>::standard();
            let mine = canonical_contribution(c.rank(), n);
            let mut out = vec![0u64; n * p];
            for name in r.names() {
                let mut plan = r.plan_uniform(name, c, Shape::elems(n)).unwrap();
                assert_eq!(plan.algorithm(), name);
                assert_eq!(plan.shape(), Shape::elems(n));
                assert_eq!(plan.comm_size(), p);
                out.fill(0);
                plan.execute(&mine, &mut out).unwrap();
                assert_eq!(out, expect, "{name}");
            }
            true
        });
        assert!(run.results.iter().all(|&ok| ok));
    }

    #[test]
    fn late_registration_overrides_builtin() {
        struct Fake;
        impl NamedAlgorithm for Fake {
            fn name(&self) -> &'static str {
                "ring"
            }
            fn summary(&self) -> &'static str {
                "fake ring"
            }
        }
        impl CollectiveAlgorithm<u32> for Fake {
            fn plan(&self, comm: &Comm, _spec: &PlanSpec) -> Result<Box<dyn AllgatherPlan<u32>>> {
                Ok(Box::new(EmptyPlan { name: "ring", p: comm.size() }))
            }
        }
        let mut r = Registry::<u32>::standard();
        r.register(Box::new(Fake));
        assert_eq!(r.get("ring").unwrap().summary(), "fake ring");
        // names() still lists ring once
        assert_eq!(r.names().iter().filter(|n| **n == "ring").count(), 1);
    }

    #[test]
    fn io_elems_matches_the_per_op_buffer_contract() {
        assert_eq!(OpKind::Allgather.io_elems(3, 4), (3, 12));
        assert_eq!(OpKind::Allreduce.io_elems(3, 4), (3, 3));
        assert_eq!(OpKind::Alltoall.io_elems(3, 4), (12, 12));
        assert_eq!(OpKind::ReduceScatter.io_elems(3, 4), (12, 3));
        // the ragged ops' uniform interpretation mirrors their flat twins
        assert_eq!(OpKind::Allgatherv.io_elems(3, 4), (3, 12));
        assert_eq!(OpKind::ReduceScatterV.io_elems(3, 4), (12, 3));
        // n = 0 is the uniform empty contract on every op.
        for op in OpKind::ALL {
            assert_eq!(op.io_elems(0, 4), (0, 0));
        }
    }

    #[test]
    fn execute_validates_buffer_lengths() {
        let topo = Topology::regions(2, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = Registry::<u32>::standard();
            let mut plan = r.plan_uniform("bruck", c, Shape::elems(3)).unwrap();
            let bad_in = plan.execute(&[1u32; 2], &mut [0u32; 12]).is_err();
            let bad_out = plan.execute(&[1u32; 3], &mut [0u32; 11]).is_err();
            bad_in && bad_out
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
