//! Reduce-scatter-v — the ragged reduce-scatter — as schedule builders.
//!
//! `reduce_scatter_v` contract (`MPI_Reduce_scatter` with `MPI_SUM` and
//! per-rank counts): every rank holds `Σ counts` elements partitioned by
//! `counts` — block `j` (at the counts' prefix offset) being its
//! contribution to rank `j` — and afterwards rank `i` holds the
//! `counts[i]`-element elementwise sum over all ranks of block `i`.
//! Jocksch et al. (*Optimised allgatherv, reduce_scatter and allreduce
//! communication*) treat the ragged reduce-scatter as the allgatherv's
//! inverse: the same per-message postal terms `α_c + β_c·s` (paper §4)
//! traversed in the opposite direction with a reduction folded into every
//! hop, and the same rule that zero-count ranks still participate in
//! every exchange (a zero-length message costs its latency term —
//! dropping it would desynchronise the SPMD schedules).
//!
//! Two builders, both registered in
//! [`super::plan::ReduceScattervRegistry`] (plus the cost-model-driven
//! [`super::model_tuned::ModelTunedReduceScatterv`]):
//!
//! * **`ring`** — `p−1` neighbour exchange-and-reduce steps over the
//!   ragged accumulator: step `s` forwards the partial of one ragged
//!   block and folds the incoming partial in place, so every value still
//!   crosses each link exactly once (`Σ counts − counts[rank]` elements
//!   sent per rank);
//! * **`loc-aware`** — the paper's §4 argument over ragged lanes: every
//!   rank pre-reduces *within its region* (all-local traffic) so local
//!   rank `ℓ` holds the region's partials for **lane** `ℓ` (the
//!   destination ranks with local index `ℓ` in every region), then each
//!   lane runs an inter-region ragged ring reduce-scatter of aggregated
//!   per-region partials — `r−1` non-local messages per rank, each an
//!   aggregated partial, independent of the counts' skew. The lane
//!   exchange is *always* the ragged ring (never per-shape recursive
//!   halving): the exchange structure must be a plan-time function of the
//!   topology alone so every rank reserves the same tag block.
//!
//! Both are pure schedule builders over exact ragged slices: every
//! schedule carries an explicit [`Schedule::io`] override
//! (`(Σ counts, counts[rank])`), executes through the generic
//! [`SchedPlan`] interpreter with the [`Summable`] reducer, and is costed
//! by [`crate::model::cost`] with no ragged special-casing.

use super::grouping::GroupBy;
use super::plan::{
    check_counts_len, trivial_rsv_plan, Counts, NamedAlgorithm, OpKind, PlanSpec,
    ReduceScattervAlgorithm, ReduceScattervPlan, Summable,
};
use super::schedule::{
    locate, uniform_size, BufId, SchedPlan, Schedule, ScheduleBuilder, Slice, WorldView,
};
use crate::comm::Comm;
use crate::error::{Error, Result};

/// Ring reduce-scatter-v (registry entry).
pub struct RingReduceScatterv;

impl NamedAlgorithm for RingReduceScatterv {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn summary(&self) -> &'static str {
        "ring reduce-scatter-v: p-1 exchange-and-reduce steps over ragged blocks"
    }
}

impl<T: Summable> ReduceScattervAlgorithm<T> for RingReduceScatterv {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn ReduceScattervPlan<T>>> {
        if let Some(p) = trivial_rsv_plan("ring", comm, spec) {
            return Ok(p);
        }
        check_counts_len(&spec.counts, comm.size())?;
        let sched = build_ring_schedule(
            comm.size(),
            comm.rank(),
            spec.counts.as_slice(),
            std::mem::size_of::<T>(),
        );
        Ok(SchedPlan::<T>::boxed(comm, "ring", sched)?)
    }
}

/// Locality-aware reduce-scatter-v (registry entry).
pub struct LocAwareReduceScatterv;

impl NamedAlgorithm for LocAwareReduceScatterv {
    fn name(&self) -> &'static str {
        "loc-aware"
    }

    fn summary(&self) -> &'static str {
        "regional reduce-scatter-v (§4): local pre-reduce into ragged lanes, lane ring"
    }
}

impl<T: Summable> ReduceScattervAlgorithm<T> for LocAwareReduceScatterv {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn ReduceScattervPlan<T>>> {
        if let Some(p) = trivial_rsv_plan("loc-aware", comm, spec) {
            return Ok(p);
        }
        check_counts_len(&spec.counts, comm.size())?;
        let view = WorldView::from_comm(comm);
        let sched = build_loc_schedule(
            &view,
            comm.rank(),
            spec.counts.as_slice(),
            std::mem::size_of::<T>(),
        )?;
        Ok(SchedPlan::<T>::boxed(comm, "loc-aware", sched)?)
    }
}

/// Exclusive prefix sums with the total appended (`len + 1` entries).
fn prefix_offsets(counts: &[usize]) -> Vec<usize> {
    let mut offs = Vec::with_capacity(counts.len() + 1);
    let mut acc = 0usize;
    offs.push(0);
    for &c in counts {
        acc += c;
        offs.push(acc);
    }
    offs
}

fn max_count(counts: &[usize]) -> usize {
    counts.iter().copied().max().unwrap_or(0)
}

// ---------------------------------------------------------------------------
// group emitter (shared by the top-level builder and the lane phase)
// ---------------------------------------------------------------------------

/// Emit a ragged ring reduce-scatter among `members` over the
/// member-major accumulator `acc` (`Σ counts` elements; block `k`, of
/// `counts[k]` elements at the counts' prefix offset, is destined to
/// member `k`). `q−1` neighbour exchange-and-reduce steps; member `k`
/// ends with block `k` fully reduced **in place**. Zero-count blocks are
/// still forwarded as zero-length messages (the SPMD schedules stay in
/// lockstep); ranks outside `members` allocate the tag block and emit
/// nothing.
pub(crate) fn emit_group_ring_rs_v(
    sb: &mut ScheduleBuilder,
    members: &[usize],
    me: usize,
    counts: &[usize],
    acc: BufId,
) {
    let q = members.len();
    debug_assert_eq!(counts.len(), q);
    let tag0 = sb.tag_block(q.saturating_sub(1) as u64);
    let Some(k) = members.iter().position(|&r| r == me) else {
        return;
    };
    if q == 1 {
        return;
    }
    let offs = prefix_offsets(counts);
    let tmp = sb.scratch(max_count(counts));
    // Same traversal as the uniform ring: block `c` starts accumulating
    // at member `c+1` and travels one neighbour per step, reaching its
    // owner after q−1 hops — only the payload lengths follow the counts.
    for s in 0..q - 1 {
        let right = members[(k + 1) % q];
        let left = members[(k + q - 1) % q];
        let c_send = (k + q - 1 - s) % q;
        let c_recv = (k + 2 * q - 2 - s) % q;
        sb.sendrecv(
            right,
            Slice::at(acc, offs[c_send], counts[c_send]),
            left,
            Slice::at(tmp, 0, counts[c_recv]),
            tag0 + s as u64,
            0,
        );
        if counts[c_recv] > 0 {
            sb.reduce(
                Slice::at(tmp, 0, counts[c_recv]),
                Slice::at(acc, offs[c_recv], counts[c_recv]),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// builders
// ---------------------------------------------------------------------------

/// Build the ring reduce-scatter-v schedule for one rank (pure; SPMD).
pub fn build_ring_schedule(
    p: usize,
    rank: usize,
    counts: &[usize],
    elem_bytes: usize,
) -> Schedule {
    debug_assert_eq!(counts.len(), p);
    let offs = prefix_offsets(counts);
    let total = offs[p];
    let members: Vec<usize> = (0..p).collect();
    let mut sb = ScheduleBuilder::new("ring reduce-scatter-v");
    let acc = sb.scratch(total);
    if total > 0 {
        sb.copy(Slice::input(0, total), Slice::at(acc, 0, total));
    }
    emit_group_ring_rs_v(&mut sb, &members, rank, counts, acc);
    if counts[rank] > 0 {
        sb.copy(Slice::at(acc, offs[rank], counts[rank]), Slice::output(0, counts[rank]));
    }
    let mut sched = sb.finish(OpKind::ReduceScatterV, p, max_count(counts), elem_bytes, "ring");
    sched.io = Some((total, counts[rank]));
    sched
}

/// Build the locality-aware reduce-scatter-v schedule for one rank (pure;
/// SPMD).
///
/// Phase 1 (all local): every member of a region sends each local peer
/// `ℓ` its gathered ragged input blocks destined to lane `ℓ`, and each
/// lane owner reduces the region's partials in place — after this, local
/// rank `ℓ` holds its region's contribution to every rank with local
/// index `ℓ`, laid out region-major at the lane counts' prefix offsets.
/// Phase 2 (non-local): each lane — one member per region — runs the
/// ragged ring reduce-scatter of those aggregated partials among the
/// regions. Degenerate shapes (single region, one rank per region) fall
/// back to the plain ragged ring; non-uniform regions are rejected at
/// plan time.
pub fn build_loc_schedule(
    view: &WorldView,
    rank: usize,
    counts: &[usize],
    elem_bytes: usize,
) -> Result<Schedule> {
    debug_assert_eq!(counts.len(), view.p);
    let all: Vec<usize> = (0..view.p).collect();
    let groups = view.split(&all, GroupBy::Region);
    let ppr = uniform_size(&groups, "locality-aware reduce-scatter-v")?;
    let r_n = groups.len();
    if r_n == 1 || ppr == 1 {
        let mut sched = build_ring_schedule(view.p, rank, counts, elem_bytes);
        sched.label = "loc-aware[ring]".to_string();
        return Ok(sched);
    }
    let (g, l) = locate(&groups, rank)?;
    let offs = prefix_offsets(counts);
    let total = offs[view.p];

    let mut sb = ScheduleBuilder::new("local pre-reduce");
    // Lane accumulator: block j is the ragged partial destined to
    // groups[j][l], the lane-ℓ member of region j.
    let lane_counts: Vec<usize> = groups.iter().map(|group| counts[group[l]]).collect();
    let lane_offs = prefix_offsets(&lane_counts);
    let lane_total = lane_offs[r_n];
    let lane_acc = sb.scratch(lane_total);
    let tag1 = sb.tag();
    for (j, group) in groups.iter().enumerate() {
        let c = counts[group[l]];
        if c > 0 {
            sb.copy(Slice::input(offs[group[l]], c), Slice::at(lane_acc, lane_offs[j], c));
        }
    }
    // Send every local peer its lane's ragged blocks, gathered into one
    // staged local message; all sends post before the first blocking
    // receive. Peer m's lane total may differ from ours — each side
    // computes the other's layout from the shared counts.
    for (m, &peer) in groups[g].iter().enumerate() {
        if m == l {
            continue;
        }
        let peer_total: usize = groups.iter().map(|group| counts[group[m]]).sum();
        let stage = sb.scratch(peer_total);
        let mut soff = 0usize;
        for group in groups.iter() {
            let c = counts[group[m]];
            if c > 0 {
                sb.copy(Slice::input(offs[group[m]], c), Slice::at(stage, soff, c));
            }
            soff += c;
        }
        sb.send(peer, Slice::at(stage, 0, peer_total), tag1, 0);
    }
    let tmp = sb.scratch(lane_total);
    for (m, &peer) in groups[g].iter().enumerate() {
        if m == l {
            continue;
        }
        sb.recv(peer, Slice::at(tmp, 0, lane_total), tag1, 0);
        if lane_total > 0 {
            sb.reduce(Slice::at(tmp, 0, lane_total), Slice::at(lane_acc, 0, lane_total));
        }
    }

    // Phase 2: aggregated inter-region exchange within the lane — always
    // the ragged ring (see the module docs: the exchange structure is a
    // plan-time function of the topology alone).
    sb.round("lane exchange");
    let lane: Vec<usize> = groups.iter().map(|group| group[l]).collect();
    emit_group_ring_rs_v(&mut sb, &lane, rank, &lane_counts, lane_acc);
    if counts[rank] > 0 {
        sb.copy(Slice::at(lane_acc, lane_offs[g], counts[rank]), Slice::output(0, counts[rank]));
    }
    let mut sched =
        sb.finish(OpKind::ReduceScatterV, view.p, max_count(counts), elem_bytes, "loc-aware");
    sched.io = Some((total, counts[rank]));
    Ok(sched)
}

/// Build the schedule of one reduce-scatter-v algorithm (by registry
/// name) for `rank`. `model-tuned` is handled by the dispatcher
/// ([`super::model_tuned::pick_reduce_scatter_v`]).
pub fn build_reduce_scatter_v(
    name: &str,
    view: &WorldView,
    rank: usize,
    counts: &[usize],
    elem_bytes: usize,
) -> Result<Schedule> {
    if counts.len() != view.p {
        return Err(Error::Precondition(format!(
            "counts length {} does not match communicator size {}",
            counts.len(),
            view.p
        )));
    }
    if name.eq_ignore_ascii_case("ring") {
        Ok(build_ring_schedule(view.p, rank, counts, elem_bytes))
    } else if name.eq_ignore_ascii_case("loc-aware") {
        build_loc_schedule(view, rank, counts, elem_bytes)
    } else {
        Err(Error::Precondition(format!("no reduce-scatter-v schedule builder for '{name}'")))
    }
}

// ---------------------------------------------------------------------------
// one-shot wrappers
// ---------------------------------------------------------------------------

/// One-shot ring reduce-scatter-v: `send.len()` must equal
/// `counts.total()`.
pub fn ring<T: Summable>(comm: &Comm, send: &[T], counts: &Counts) -> Result<Vec<T>> {
    super::plan::one_shot_rsv(&RingReduceScatterv, comm, send, counts)
}

/// One-shot locality-aware reduce-scatter-v.
pub fn loc_aware<T: Summable>(comm: &Comm, send: &[T], counts: &Counts) -> Result<Vec<T>> {
    super::plan::one_shot_rsv(&LocAwareReduceScatterv, comm, send, counts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::plan::ReduceScattervRegistry;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    /// Canonical ragged send buffer: block `b` of rank `r` is
    /// `r·1_000_003 + b·1_009 + j` for `j < counts[b]`, concatenated.
    fn send_buf(rank: usize, counts: &[usize]) -> Vec<u64> {
        let mut v = Vec::new();
        for (b, &c) in counts.iter().enumerate() {
            v.extend((0..c).map(|j| (rank * 1_000_003 + b * 1_009 + j) as u64));
        }
        v
    }

    fn expected(rank: usize, p: usize, counts: &[usize]) -> Vec<u64> {
        (0..counts[rank])
            .map(|j| (0..p).map(|r| (r * 1_000_003 + rank * 1_009 + j) as u64).sum())
            .collect()
    }

    fn check_all(topo: &Topology, counts: Vec<usize>) {
        let p = topo.size();
        let cts = Counts::new(counts.clone());
        for algo in ["ring", "loc-aware"] {
            let run = CommWorld::run(topo, Timing::Wallclock, |c| {
                let reg = ReduceScattervRegistry::<u64>::standard();
                let mut plan = reg.plan(algo, c, &PlanSpec::ragged(cts.clone())).unwrap();
                let mut out = vec![0u64; cts.get(c.rank())];
                plan.execute(&send_buf(c.rank(), cts.as_slice()), &mut out).unwrap();
                out
            });
            for (rank, r) in run.results.iter().enumerate() {
                assert_eq!(r, &expected(rank, p, &counts), "{algo} rank {rank} counts {counts:?}");
            }
        }
    }

    #[test]
    fn ragged_counts_across_shapes() {
        check_all(&Topology::regions(2, 2), vec![4, 0, 7, 2]);
        check_all(&Topology::regions(4, 4), (0..16).map(|r| r % 5).collect());
        check_all(&Topology::regions(2, 8), (0..16).map(|r| (r * 3) % 7).collect());
        check_all(&Topology::regions(3, 2), vec![1, 0, 3, 0, 2, 5]);
    }

    #[test]
    fn single_rank_receives_everything() {
        let mut counts = vec![0usize; 8];
        counts[3] = 9;
        check_all(&Topology::regions(4, 2), counts);
        let mut counts = vec![0usize; 6];
        counts[5] = 4;
        check_all(&Topology::regions(3, 2), counts);
    }

    #[test]
    fn non_power_of_two_world() {
        check_all(&Topology::regions(5, 1), vec![2, 0, 1, 4, 3]);
        check_all(&Topology::regions(7, 1), (0..7).map(|r| r % 3).collect());
        check_all(&Topology::regions(3, 3), (0..9).map(|r| (r * 7) % 4).collect());
    }

    #[test]
    fn uniform_counts_degenerate_to_reduce_scatter() {
        check_all(&Topology::regions(4, 4), vec![2; 16]);
        check_all(&Topology::regions(1, 8), vec![3; 8]);
        check_all(&Topology::regions(8, 1), vec![1; 8]);
    }

    #[test]
    fn loc_aware_lane_ring_bounds_nonlocal_messages() {
        // (4×4) skewed: phase 1 is all-local, the lane ring sends
        // r−1 = 3 aggregated non-local messages per rank regardless of
        // the counts; the plain ring sends p−1 = 15 from region-edge
        // ranks.
        let topo = Topology::regions(4, 4);
        let counts: Vec<usize> = (0..16).map(|r| r % 5).collect();
        let cts = Counts::new(counts.clone());
        let loc = CommWorld::run(&topo, Timing::Wallclock, |c| {
            loc_aware(c, &send_buf(c.rank(), &counts), &cts).unwrap();
        });
        assert_eq!(loc.trace.max_nonlocal_msgs(), 3);
        let plain = CommWorld::run(&topo, Timing::Wallclock, |c| {
            ring(c, &send_buf(c.rank(), &counts), &cts).unwrap();
        });
        assert_eq!(plain.trace.max_nonlocal_msgs(), 15);
    }

    #[test]
    fn one_shot_rejects_wrong_send_length() {
        let topo = Topology::regions(2, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let cts = Counts::new(vec![1, 2, 3, 4]);
            ring(c, &[0u64; 3], &cts).is_err()
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
