//! **The locality-aware Bruck allgather — paper Algorithm 2 — as a
//! schedule builder.**
//!
//! Phases:
//!
//! 1. *Local allgather*: every region gathers its own data with a Bruck
//!    allgather on the region's ranks.
//! 2. `⌈log_pℓ(r)⌉` *non-local steps*: before step `i` every rank holds the
//!    data of a contiguous group of `w = pℓ^i` regions starting at its own
//!    region `g` (`[g, g+w) mod r`). At step `i`, local rank `ℓ ≥ 1` sends
//!    the whole held group to the rank with the same local index in region
//!    `g − ℓ·w` and receives the group `[g + ℓ·w, g + (ℓ+1)·w)` from region
//!    `g + ℓ·w`; **local rank 0 stays idle**, preserving power-of-pℓ
//!    exchanges (§3). Each step ends with a local allgather of the received
//!    groups, growing the held window to `w·pℓ`.
//!
//! Every rank therefore sends at most `⌈log_pℓ(r)⌉` non-local messages and
//! `≈ b/pℓ` non-local bytes — the paper's headline improvement over the
//! `log2(p)` messages / `≈ b` bytes of standard Bruck. In the IR those are
//! literally the schedule's non-local `SendRecv` steps, which is how
//! [`crate::model::cost`] recovers Eq. 4 mechanically.
//!
//! **Non-power region counts** (paper §3, Fig. 6): when `r` is not a power
//! of `pℓ`, local ranks with `ℓ·w ≥ r` idle through the step and contribute
//! nothing to the following local gather, which becomes an *allgatherv*
//! ([`super::schedule::emit_group_allgatherv`]); the final received group
//! may wrap past region `r − 1` and re-cover already-held regions, which
//! the absolute-indexed scatter absorbs.
//!
//! **Multilevel hierarchy** (§3): [`LocalityBruckMultilevel`] groups by
//! *node* at the outer level and emits socket-aware locality-aware inner
//! gathers — the emitter recurses, exactly as the paper prescribes.
//!
//! **Placement independence** (§3): all group structure is derived from
//! the topology, not from rank numbering, so non-local message counts are
//! identical under block, round-robin or random placement — asserted in
//! `rust/tests/locality_counts.rs`.
//!
//! The whole algorithm — nested local gathers included — flattens into one
//! [`Schedule`] over the parent communicator: no sub-communicators are
//! constructed, and the generic [`SchedPlan`] interpreter executes it.

use super::grouping::GroupBy;
use super::plan::{
    trivial_plan, AllgatherPlan, CollectiveAlgorithm, NamedAlgorithm, OpKind, PlanSpec,
};
use super::schedule::{
    emit_group_allgatherv, emit_group_bruck, locate, uniform_size, SchedPlan, Schedule,
    ScheduleBuilder, Slice, WorldView,
};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// Which allgather runs inside regions.
#[derive(Debug, Clone, Copy)]
enum Inner {
    /// Plain Bruck (single-level Algorithm 2).
    Bruck,
    /// Socket-aware locality-aware Bruck (two-level Algorithm 2).
    SocketAware,
}

/// How local rank 0's redundant contribution is handled in the post-step
/// local gathers (paper §3 gives both options).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rank0 {
    /// "this process will contribute the original data for simplicity" —
    /// uniform counts, plain Bruck local gathers (the paper's default).
    Contributes,
    /// "Alternatively, an MPI_Allgatherv operation could be utilized with
    /// the first local process contributing no data" — saves `w·pℓ·n`
    /// local bytes per step at the cost of allgatherv bookkeeping.
    GathervSkips,
}

/// Algorithm 2, single level (registry entry).
pub struct LocalityBruck;

impl NamedAlgorithm for LocalityBruck {
    fn name(&self) -> &'static str {
        "loc-bruck"
    }

    fn summary(&self) -> &'static str {
        "locality-aware Bruck (paper Alg. 2): log_ppr(r) non-local steps"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for LocalityBruck {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("loc-bruck", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("loc-bruck")?;
        let view = WorldView::from_comm(comm);
        let sched = build_schedule(
            &view,
            comm.rank(),
            n,
            std::mem::size_of::<T>(),
            GroupBy::Region,
            Rank0::Contributes,
            "loc-bruck",
        )?;
        Ok(SchedPlan::<T>::boxed(comm, "loc-bruck", sched)?)
    }
}

/// Algorithm 2 with the paper's allgatherv alternative (registry entry).
pub struct LocalityBruckV;

impl NamedAlgorithm for LocalityBruckV {
    fn name(&self) -> &'static str {
        "loc-bruck-v"
    }

    fn summary(&self) -> &'static str {
        "Alg. 2 with allgatherv local gathers (rank 0 contributes nothing)"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for LocalityBruckV {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("loc-bruck-v", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("loc-bruck-v")?;
        let view = WorldView::from_comm(comm);
        let sched = build_schedule(
            &view,
            comm.rank(),
            n,
            std::mem::size_of::<T>(),
            GroupBy::Region,
            Rank0::GathervSkips,
            "loc-bruck-v",
        )?;
        Ok(SchedPlan::<T>::boxed(comm, "loc-bruck-v", sched)?)
    }
}

/// Two-level Algorithm 2: node-aware outer, socket-aware inner (registry
/// entry).
pub struct LocalityBruckMultilevel;

impl NamedAlgorithm for LocalityBruckMultilevel {
    fn name(&self) -> &'static str {
        "loc-bruck-2level"
    }

    fn summary(&self) -> &'static str {
        "two-level Alg. 2: node-aware outer, socket-aware local gathers"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for LocalityBruckMultilevel {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("loc-bruck-2level", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("loc-bruck-2level")?;
        let view = WorldView::from_comm(comm);
        let sched = build_schedule_multilevel(&view, comm.rank(), n, std::mem::size_of::<T>())?;
        Ok(SchedPlan::<T>::boxed(comm, "loc-bruck-2level", sched)?)
    }
}

/// Build the single-level Algorithm 2 schedule for one rank (pure; SPMD).
pub fn build_schedule(
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
    by: GroupBy,
    rank0: Rank0,
    label: &str,
) -> Result<Schedule> {
    build_with_inner(view, rank, n, elem_bytes, by, Inner::Bruck, rank0, label)
}

/// Build the two-level (node outer, socket inner) schedule for one rank.
pub fn build_schedule_multilevel(
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    build_with_inner(
        view,
        rank,
        n,
        elem_bytes,
        GroupBy::Node,
        Inner::SocketAware,
        Rank0::Contributes,
        "loc-bruck-2level",
    )
}

#[allow(clippy::too_many_arguments)]
fn build_with_inner(
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
    by: GroupBy,
    inner: Inner,
    rank0: Rank0,
    label: &str,
) -> Result<Schedule> {
    let all: Vec<usize> = (0..view.p).collect();
    let groups = view.split(&all, by);
    uniform_size(&groups, "locality-aware bruck")?;
    let mut sb = ScheduleBuilder::new("local allgather");
    emit_loc_bruck(
        &mut sb,
        view,
        &groups,
        rank,
        n,
        Slice::input(0, n),
        Slice::output(0, n * view.p),
        inner,
        rank0,
    )?;
    Ok(sb.finish(OpKind::Allgather, view.p, n, elem_bytes, label))
}

/// Emit the configured inner (within-region) allgather: plain Bruck, or a
/// recursive socket-aware Algorithm 2 for the multilevel variant.
fn emit_inner(
    sb: &mut ScheduleBuilder,
    view: &WorldView,
    region: &[usize],
    me: usize,
    b: usize,
    contrib: Slice,
    dst: Slice,
    inner: Inner,
) -> Result<()> {
    match inner {
        Inner::Bruck => {
            emit_group_bruck(sb, region, me, b, contrib, dst);
            Ok(())
        }
        Inner::SocketAware => {
            let socks = view.split(region, GroupBy::Socket);
            if socks.len() == 1 {
                // single socket: plain Bruck is the whole story
                emit_group_bruck(sb, region, me, b, contrib, dst);
                Ok(())
            } else {
                emit_loc_bruck(
                    sb,
                    view,
                    &socks,
                    me,
                    b,
                    contrib,
                    dst,
                    Inner::Bruck,
                    Rank0::Contributes,
                )
            }
        }
    }
}

/// Emit Algorithm 2 over explicit `groups` of ranks, each contributing `b`
/// elements, gathering into `dst` ordered by ascending member rank.
/// Degrades to a plain group Bruck when there is one rank per group (no
/// locality to exploit). Ranks outside `groups` are not supported — every
/// caller passes a partition of the ranks it emits for.
#[allow(clippy::too_many_arguments)]
fn emit_loc_bruck(
    sb: &mut ScheduleBuilder,
    view: &WorldView,
    groups: &[Vec<usize>],
    me: usize,
    b: usize,
    contrib: Slice,
    dst: Slice,
    inner: Inner,
    rank0: Rank0,
) -> Result<()> {
    let r_n = groups.len();
    let ppr = uniform_size(groups, "locality-aware bruck")?;
    let mut sorted: Vec<usize> = groups.iter().flatten().copied().collect();
    sorted.sort_unstable();
    if ppr == 1 {
        // One rank per region: Algorithm 2's non-local phase would make no
        // progress (only local rank 0 exists and it idles). Degrade to the
        // standard Bruck over the member set.
        emit_group_bruck(sb, &sorted, me, b, contrib, dst);
        return Ok(());
    }
    let (g, l) = locate(groups, me)?;
    let re = ppr * b; // elements held per region
    let contributes = rank0 == Rank0::Contributes;

    // Region-major working buffer: region ri's data (in local-rank order)
    // lives at buf[ri·re ..]. Assembly is by absolute region index, which
    // makes wrap-around duplicates benign.
    let buf = sb.scratch(r_n * re);

    // Phase 1: local allgather of the initial blocks, straight into this
    // rank's region slot.
    emit_inner(sb, view, &groups[g], me, b, contrib, Slice::at(buf, g * re, re), inner)?;

    // Non-local phase. Invariant: every rank of group gi holds exactly the
    // regions [gi, gi+width) mod r_n.
    let mut width = 1usize;
    let mut step_no = 1usize;
    while width < r_n {
        sb.round(format!("non-local step {step_no}"));
        let tag = sb.tag();
        let active_j = |j: usize| j > 0 && j * width < r_n;
        let active = active_j(l);
        // Contribution convention: local rank j contributes the group
        // starting at region (g + j·width) — rank 0 re-contributes the
        // currently-held group (the paper's "contribute the original data
        // for simplicity"); inactive ranks contribute nothing.
        let counts: Vec<usize> = (0..ppr)
            .map(|j| if (j == 0 && contributes) || active_j(j) { width * re } else { 0 })
            .collect();
        let need_send = active || (l == 0 && contributes);
        let send_buf = if need_send { Some(sb.scratch(width * re)) } else { None };
        let recv_buf = if active { Some(sb.scratch(width * re)) } else { None };
        if let Some(sbuf) = send_buf {
            // collect the held ring [g, g+width) into a contiguous payload
            for k in 0..width {
                let ri = (g + k) % r_n;
                sb.copy(Slice::at(buf, ri * re, re), Slice::at(sbuf, k * re, re));
            }
        }
        if let (true, Some(rbuf)) = (active, recv_buf) {
            let dist = (l * width) % r_n;
            let to = groups[(g + r_n - dist) % r_n][l];
            let from = groups[(g + dist) % r_n][l];
            sb.sendrecv(
                to,
                Slice::at(send_buf.expect("active ranks have a send buffer"), 0, width * re),
                from,
                Slice::at(rbuf, 0, width * re),
                tag,
                0,
            );
        }
        // Local allgather of the received groups.
        let total: usize = counts.iter().sum();
        let gathered = sb.scratch(total);
        let my_contrib = if l == 0 {
            match send_buf {
                Some(sbuf) if contributes => Slice::at(sbuf, 0, width * re),
                _ => Slice::input(0, 0),
            }
        } else if active {
            Slice::at(recv_buf.expect("active"), 0, width * re)
        } else {
            Slice::input(0, 0)
        };
        let uniform = counts.iter().all(|&c| c == counts[0]);
        if uniform {
            emit_inner(
                sb,
                view,
                &groups[g],
                me,
                counts[0],
                my_contrib,
                Slice::at(gathered, 0, total),
                inner,
            )?;
        } else {
            emit_group_allgatherv(
                sb,
                &groups[g],
                me,
                &counts,
                my_contrib,
                Slice::at(gathered, 0, total),
            );
        }
        // Scatter the gathered groups by absolute region index.
        let mut off = 0usize;
        for (j, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let start = (g + j * width) % r_n;
            for k in 0..width {
                let ri = (start + k) % r_n;
                sb.copy(Slice::at(gathered, off + k * re, re), Slice::at(buf, ri * re, re));
            }
            off += c;
        }
        width = width.saturating_mul(ppr);
        step_no += 1;
    }

    // Permute the region-major buffer into ascending-member order in dst.
    sb.round("reorder");
    for (gi, members) in groups.iter().enumerate() {
        for (j, &r) in members.iter().enumerate() {
            let pos = sorted.binary_search(&r).expect("member in sorted list");
            sb.copy(
                Slice::at(buf, gi * re + j * b, b),
                Slice::at(dst.buf, dst.off + pos * b, b),
            );
        }
    }
    Ok(())
}

/// Locality-aware Bruck allgather of `local` (length `n`); returns `n·p`
/// elements in communicator rank order. Regions are the topology's
/// configured region kind. One-shot wrapper over the planned form.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&LocalityBruck, comm, local)
}

/// The allgatherv variant (paper §3's alternative; see [`Rank0`]).
pub fn allgather_v<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&LocalityBruckV, comm, local)
}

/// Two-level locality-aware Bruck: node-aware outer algorithm whose local
/// gathers are themselves socket-aware locality-aware Brucks.
pub fn allgather_multilevel<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&LocalityBruckMultilevel, comm, local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{canonical_contribution, expected_result};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::{Placement, RegionKind, Topology};

    fn check(topo: &Topology, n: usize) {
        let expect = expected_result(topo.size(), n);
        let run = CommWorld::run(topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), n)).unwrap()
        });
        for (rank, r) in run.results.iter().enumerate() {
            assert_eq!(r, &expect, "rank {rank} mismatch");
        }
    }

    #[test]
    fn example_2_1_correct_and_single_nonlocal_message() {
        let topo = Topology::regions(4, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64, 1000 + c.rank() as u64]).unwrap()
        });
        let expect = {
            let mut e = Vec::new();
            for r in 0..16u64 {
                e.push(r);
                e.push(1000 + r);
            }
            e
        };
        for r in &run.results {
            assert_eq!(r, &expect);
        }
        // Paper: each process communicates only a single non-local message
        // (vs 4 for standard Bruck) ...
        assert_eq!(run.trace.max_nonlocal_msgs(), 1);
        // ... of one region group = 4 ranks × 2 u64 = 64 B.
        assert_eq!(run.trace.max_nonlocal_bytes(), 4 * 2 * 8);
    }

    #[test]
    fn fig6_64_procs_16_regions_two_nonlocal_steps() {
        let topo = Topology::regions(16, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        let expect = expected_result(64, 1);
        for r in &run.results {
            assert_eq!(r, &expect);
        }
        assert_eq!(run.trace.max_nonlocal_msgs(), 2); // ⌈log_4(16)⌉
    }

    #[test]
    fn correct_across_shapes() {
        check(&Topology::regions(2, 2), 1);
        check(&Topology::regions(4, 2), 3);
        check(&Topology::regions(8, 8), 2);
        check(&Topology::regions(16, 4), 1);
    }

    #[test]
    fn correct_non_power_region_counts() {
        // r not a power of ppr: 6 regions of 4, 5 regions of 2, 3 of 8.
        check(&Topology::regions(6, 4), 2);
        check(&Topology::regions(5, 2), 1);
        check(&Topology::regions(3, 8), 2);
        check(&Topology::regions(7, 4), 1);
    }

    #[test]
    fn single_region_degenerates_to_local_bruck() {
        let topo = Topology::regions(1, 8);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 2)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_result(8, 2));
        }
        assert_eq!(run.trace.max_nonlocal_msgs(), 0);
    }

    #[test]
    fn one_rank_per_region_falls_back_to_bruck() {
        let topo = Topology::regions(8, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_result(8, 1));
        }
    }

    #[test]
    fn empty_contribution_is_empty() {
        let topo = Topology::regions(2, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather::<u64>(c, &[]).unwrap()
        });
        for r in &run.results {
            assert!(r.is_empty());
        }
    }

    #[test]
    fn multilevel_correct_on_two_socket_nodes() {
        let topo =
            Topology::machine(4, 2, 2, RegionKind::Node, Placement::Block).unwrap();
        let expect = expected_result(16, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather_multilevel(c, &canonical_contribution(c.rank(), 2)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expect);
        }
    }

    #[test]
    fn multilevel_single_socket_equals_single_level() {
        let topo = Topology::regions(4, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather_multilevel(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_result(16, 1));
        }
    }

    #[test]
    fn rank0_of_each_region_sends_nothing_nonlocal() {
        let topo = Topology::regions(8, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        for (rank, t) in run.trace.per_rank.iter().enumerate() {
            if rank % 4 == 0 {
                assert_eq!(t.nonlocal_msgs, 0, "local rank 0 must idle (rank {rank})");
            }
        }
    }

    #[test]
    fn correct_under_random_placement() {
        let topo = Topology::machine(
            4,
            1,
            4,
            RegionKind::Node,
            Placement::Random { seed: 23 },
        )
        .unwrap();
        check(&topo, 2);
    }

    #[test]
    fn allgatherv_variant_correct_across_shapes() {
        for (regions, ppr) in [(4usize, 4usize), (16, 4), (6, 4), (5, 2), (1, 8), (8, 1)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allgather_v(c, &canonical_contribution(c.rank(), 2)).unwrap()
            });
            for (rank, r) in run.results.iter().enumerate() {
                assert_eq!(r, &expected_result(p, 2), "{regions}x{ppr} rank {rank}");
            }
        }
    }

    #[test]
    fn allgatherv_variant_moves_fewer_local_bytes() {
        // The §3 alternative saves exactly rank 0's duplicate contribution
        // in every post-step local gather.
        let topo = Topology::regions(16, 4);
        let std = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        let v = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather_v(c, &[c.rank() as u64]).unwrap();
        });
        let std_local: u64 = std.trace.per_rank.iter().map(|t| t.local_bytes).sum();
        let v_local: u64 = v.trace.per_rank.iter().map(|t| t.local_bytes).sum();
        assert!(v_local < std_local, "v {v_local} >= std {std_local}");
        // non-local traffic identical
        assert_eq!(
            std.trace.total_nonlocal_bytes(),
            v.trace.total_nonlocal_bytes()
        );
    }

    #[test]
    fn plan_reuse_on_shifting_inputs() {
        use crate::collectives::plan::{Registry, Shape};
        let topo = Topology::regions(4, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan = Registry::<u64>::standard()
                .plan_uniform("loc-bruck", c, Shape::elems(2))
                .unwrap();
            let mut out = vec![0u64; 32];
            for round in 0..6u64 {
                let mine = [c.rank() as u64 + 777 * round, c.rank() as u64 + 777 * round + 13];
                plan.execute(&mine, &mut out).unwrap();
                let expect: Vec<u64> = (0..16u64)
                    .flat_map(|r| [r + 777 * round, r + 777 * round + 13])
                    .collect();
                assert_eq!(out, expect, "round {round}");
            }
            true
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
