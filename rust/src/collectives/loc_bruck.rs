//! **The locality-aware Bruck allgather — paper Algorithm 2.**
//!
//! Phases:
//!
//! 1. *Local allgather*: every region gathers its own data with a Bruck
//!    allgather on the region communicator.
//! 2. `⌈log_pℓ(r)⌉` *non-local steps*: before step `i` every rank holds the
//!    data of a contiguous group of `w = pℓ^i` regions starting at its own
//!    region `g` (`[g, g+w) mod r`). At step `i`, local rank `ℓ ≥ 1` sends
//!    the whole held group to the rank with the same local index in region
//!    `g − ℓ·w` and receives the group `[g + ℓ·w, g + (ℓ+1)·w)` from region
//!    `g + ℓ·w`; **local rank 0 stays idle**, preserving power-of-pℓ
//!    exchanges (§3). Each step ends with a local allgather of the received
//!    groups, growing the held window to `w·pℓ`.
//!
//! Every rank therefore sends at most `⌈log_pℓ(r)⌉` non-local messages and
//! `≈ b/pℓ` non-local bytes — the paper's headline improvement over the
//! `log2(p)` messages / `≈ b` bytes of standard Bruck.
//!
//! **Non-power region counts** (paper §3, Fig. 6): when `r` is not a power
//! of `pℓ`, local ranks with `ℓ·w ≥ r` idle through the step and contribute
//! nothing to the following local gather, which becomes an *allgatherv*;
//! the final received group may wrap past region `r − 1` and re-cover
//! already-held regions (the paper's “regions 13 through 15 as well as
//! region 0”), which the absolute-indexed assembly absorbs.
//!
//! **Multilevel hierarchy** (§3): [`LocalityBruckMultilevel`] groups by
//! *node* at the outer level and replaces the inner Bruck plans with a
//! socket-aware locality-aware plan, exactly as the paper prescribes.
//!
//! **Placement independence** (§3): all group structure is derived from
//! the topology, not from rank numbering, so non-local message counts are
//! identical under block, round-robin or random placement — asserted in
//! `rust/tests/locality_counts.rs`.
//!
//! **Persistence**: [`LocBruckPlan`] derives groups, builds the region
//! communicator, reserves the non-local tag of every step, nests inner
//! local-gather plans (Bruck or allgatherv, per step) and allocates all
//! exchange/gather scratch **once**. `execute` then runs pure
//! communication: the paper's "communicators created once outside the
//! timed region" setup, kept alive across any number of operations.

use super::bruck::BruckPlan;
use super::grouping::{group_ranks, require_uniform, GroupBy, Groups};
use super::plan::{
    check_io, trivial_plan, AllgatherPlan, CollectiveAlgorithm, CollectivePlan, NamedAlgorithm,
    SelectedPlan, Shape,
};
use super::primitives::AllgathervPlan;
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// Which allgather runs inside regions.
#[derive(Debug, Clone, Copy)]
enum Inner {
    /// Plain Bruck (single-level Algorithm 2).
    Bruck,
    /// Socket-aware locality-aware Bruck (two-level Algorithm 2).
    SocketAware,
}

/// How local rank 0's redundant contribution is handled in the post-step
/// local gathers (paper §3 gives both options).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rank0 {
    /// "this process will contribute the original data for simplicity" —
    /// uniform counts, plain Bruck local gathers (the paper's default).
    Contributes,
    /// "Alternatively, an MPI_Allgatherv operation could be utilized with
    /// the first local process contributing no data" — saves `w·pℓ·n`
    /// local bytes per step at the cost of allgatherv bookkeeping.
    GathervSkips,
}

/// Algorithm 2, single level (registry entry).
pub struct LocalityBruck;

impl NamedAlgorithm for LocalityBruck {
    fn name(&self) -> &'static str {
        "loc-bruck"
    }

    fn summary(&self) -> &'static str {
        "locality-aware Bruck (paper Alg. 2): log_ppr(r) non-local steps"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for LocalityBruck {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("loc-bruck", comm, shape) {
            return Ok(p);
        }
        let groups = group_ranks(comm, GroupBy::Region)?;
        plan_grouped(comm, shape.n, &groups, Inner::Bruck, Rank0::Contributes, "loc-bruck")
    }
}

/// Algorithm 2 with the paper's allgatherv alternative (registry entry).
pub struct LocalityBruckV;

impl NamedAlgorithm for LocalityBruckV {
    fn name(&self) -> &'static str {
        "loc-bruck-v"
    }

    fn summary(&self) -> &'static str {
        "Alg. 2 with allgatherv local gathers (rank 0 contributes nothing)"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for LocalityBruckV {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("loc-bruck-v", comm, shape) {
            return Ok(p);
        }
        let groups = group_ranks(comm, GroupBy::Region)?;
        plan_grouped(comm, shape.n, &groups, Inner::Bruck, Rank0::GathervSkips, "loc-bruck-v")
    }
}

/// Two-level Algorithm 2: node-aware outer, socket-aware inner (registry
/// entry).
pub struct LocalityBruckMultilevel;

impl NamedAlgorithm for LocalityBruckMultilevel {
    fn name(&self) -> &'static str {
        "loc-bruck-2level"
    }

    fn summary(&self) -> &'static str {
        "two-level Alg. 2: node-aware outer, socket-aware local gathers"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for LocalityBruckMultilevel {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("loc-bruck-2level", comm, shape) {
            return Ok(p);
        }
        let groups = group_ranks(comm, GroupBy::Node)?;
        plan_grouped(
            comm,
            shape.n,
            &groups,
            Inner::SocketAware,
            Rank0::Contributes,
            "loc-bruck-2level",
        )
    }
}

/// Build the generic Algorithm 2 plan over explicit groups, degrading to
/// plain Bruck when there is no locality to exploit.
fn plan_grouped<T: Pod>(
    comm: &Comm,
    n: usize,
    groups: &Groups,
    inner: Inner,
    rank0: Rank0,
    name: &'static str,
) -> Result<Box<dyn AllgatherPlan<T>>> {
    let ppr = require_uniform(groups, "locality-aware bruck")?;
    if ppr == 1 {
        // One rank per region: no locality to exploit; Algorithm 2's
        // non-local phase would make no progress (only local rank 0 exists
        // and it idles). Degrade to the standard Bruck.
        return Ok(Box::new(SelectedPlan {
            name,
            inner: Box::new(BruckPlan::<T>::new(comm, n)) as Box<dyn AllgatherPlan<T>>,
        }));
    }
    Ok(Box::new(LocBruckPlan::<T>::new(comm, n, groups, inner, rank0, name)?))
}

/// Plan the configured inner (local) allgather over a region communicator.
fn inner_plan<T: Pod>(
    local_comm: &Comm,
    block: usize,
    inner: Inner,
) -> Result<Box<dyn AllgatherPlan<T>>> {
    match inner {
        Inner::Bruck => Ok(Box::new(BruckPlan::<T>::new(local_comm, block))),
        Inner::SocketAware => {
            let groups = group_ranks(local_comm, GroupBy::Socket)?;
            if groups.count() == 1 {
                // single socket: plain Bruck is the whole story
                Ok(Box::new(BruckPlan::<T>::new(local_comm, block)))
            } else {
                plan_grouped(
                    local_comm,
                    block,
                    &groups,
                    Inner::Bruck,
                    Rank0::Contributes,
                    "loc-bruck",
                )
            }
        }
    }
}

/// The local gather closing one non-local step.
enum StepGather<T: Pod> {
    /// Power-of-pℓ step: equal counts — the configured inner allgather
    /// (paper: "replacing all calls to bruck").
    Uniform(Box<dyn AllgatherPlan<T>>),
    /// Non-power step: some ranks idle → allgatherv (§3).
    Varying(AllgathervPlan<T>),
}

/// One precomputed non-local step.
struct LocStep<T: Pod> {
    /// Held-group width in regions before this step.
    width: usize,
    /// Whether this rank exchanges non-locally (local rank ℓ ≥ 1 with
    /// ℓ·width < r).
    active: bool,
    /// Exchange peers in parent-communicator ranks (valid when `active`).
    dst: usize,
    src: usize,
    /// Pre-reserved parent-communicator tag for the exchange.
    tag: u64,
    /// Per-local-rank contribution lengths of the closing local gather.
    counts: Vec<usize>,
    gather: StepGather<T>,
    /// `(start region, offset into gathered)` of every non-empty
    /// contribution, for the absolute-indexed scatter.
    scatter: Vec<(usize, usize)>,
    /// Contiguous copy of the held group (send payload; doubles as local
    /// rank 0's re-contribution). Length `width · region_elems` when
    /// needed, else empty.
    send_buf: Vec<T>,
    /// Received group. Length `width · region_elems` when active.
    recv_buf: Vec<T>,
    /// Local-gather output, length `sum(counts)`.
    gathered: Vec<T>,
}

/// Persistent locality-aware Bruck plan (see module docs).
pub struct LocBruckPlan<T: Pod> {
    name: &'static str,
    comm: Comm,
    n: usize,
    p: usize,
    r_n: usize,
    region_elems: usize,
    g: usize,
    l: usize,
    /// Phase 1: local allgather of the initial blocks, writing directly
    /// into this rank's region slot of `buf`.
    phase1: Box<dyn AllgatherPlan<T>>,
    steps: Vec<LocStep<T>>,
    /// Region-major working buffer: region `ri`'s data (in local-rank
    /// order) lives at `buf[ri·region_elems ..]`. Assembly is by absolute
    /// region index, which makes wrap-around duplicates benign.
    buf: Vec<T>,
    /// `(buf element offset, communicator rank)` of every block, for the
    /// final region-major → rank-order permutation.
    perm: Vec<(usize, usize)>,
}

impl<T: Pod> LocBruckPlan<T> {
    fn new(
        comm: &Comm,
        n: usize,
        groups: &Groups,
        inner: Inner,
        rank0: Rank0,
        name: &'static str,
    ) -> Result<LocBruckPlan<T>> {
        let p = comm.size();
        let r_n = groups.count();
        let ppr = groups.uniform_size().expect("plan_grouped checked uniformity");
        let g = groups.mine;
        let l = groups.my_local;
        let region_elems = ppr * n;
        let local_comm = comm.sub(&groups.members[g])?;
        let phase1 = inner_plan(&local_comm, n, inner)?;
        let rank0_contributes = rank0 == Rank0::Contributes;

        let mut steps = Vec::new();
        let mut width = 1usize;
        while width < r_n {
            // reserved by ALL ranks so the parent tag sequence stays aligned
            let tag = comm.reserve_coll_tags(1);
            let active_j = |j: usize| j > 0 && j * width < r_n;
            let active = active_j(l);
            let (dst, src) = if active {
                let dist = (l * width) % r_n;
                (
                    groups.members[(g + r_n - dist) % r_n][l],
                    groups.members[(g + dist) % r_n][l],
                )
            } else {
                (0, 0)
            };
            // Contribution convention: local rank j contributes the group
            // starting at region (g + j·width) — rank 0 re-contributes the
            // currently-held group (the paper's "contribute the original
            // data for simplicity"); inactive ranks contribute nothing.
            let counts: Vec<usize> = (0..ppr)
                .map(|j| {
                    if (j == 0 && rank0_contributes) || active_j(j) {
                        width * region_elems
                    } else {
                        0
                    }
                })
                .collect();
            let uniform = counts.iter().all(|&c| c == counts[0]);
            let gather = if uniform {
                StepGather::Uniform(inner_plan(&local_comm, width * region_elems, inner)?)
            } else {
                StepGather::Varying(AllgathervPlan::<T>::new(&local_comm, &counts)?)
            };
            let mut scatter = Vec::new();
            let mut off = 0usize;
            for (j, &c) in counts.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                scatter.push(((g + j * width) % r_n, off));
                off += c;
            }
            let need_send = active || (l == 0 && rank0_contributes);
            steps.push(LocStep {
                width,
                active,
                dst,
                src,
                tag,
                gather,
                scatter,
                send_buf: if need_send { vec![T::default(); width * region_elems] } else { Vec::new() },
                recv_buf: if active { vec![T::default(); width * region_elems] } else { Vec::new() },
                gathered: vec![T::default(); off],
                counts,
            });
            width = width.saturating_mul(ppr);
        }

        let mut perm = Vec::with_capacity(p);
        for (gi, members) in groups.members.iter().enumerate() {
            for (j, &rank) in members.iter().enumerate() {
                perm.push((gi * region_elems + j * n, rank));
            }
        }
        Ok(LocBruckPlan {
            name,
            comm: comm.retain(),
            n,
            p,
            r_n,
            region_elems,
            g,
            l,
            phase1,
            steps,
            buf: vec![T::default(); r_n * region_elems],
            perm,
        })
    }
}

impl<T: Pod> CollectivePlan for LocBruckPlan<T> {
    fn algorithm(&self) -> &'static str {
        self.name
    }

    fn shape(&self) -> Shape {
        Shape { n: self.n }
    }

    fn comm_size(&self) -> usize {
        self.p
    }
}

impl<T: Pod> AllgatherPlan<T> for LocBruckPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_io(self.n, self.p, input, output)?;
        let (n, re, r_n, g, l) = (self.n, self.region_elems, self.r_n, self.g, self.l);

        // Phase 1: local allgather of the initial blocks, straight into
        // this rank's region slot.
        self.phase1.execute(input, &mut self.buf[g * re..(g + 1) * re])?;

        // Non-local phase. Invariant: every rank of group `gi` holds
        // exactly the regions [gi, gi+width) mod r_n.
        let Self { comm, buf, steps, .. } = self;
        for step in steps.iter_mut() {
            let w = step.width;
            // -- exchange ------------------------------------------------
            if step.active {
                collect_ring(buf, g, w, r_n, re, &mut step.send_buf);
                let _send = comm.isend(&step.send_buf, step.dst, step.tag)?;
                let req = comm.irecv(step.src, step.tag);
                req.wait_into(comm, &mut step.recv_buf)?;
            } else if l == 0 && !step.send_buf.is_empty() {
                // rank 0 re-contributes the currently-held group
                collect_ring(buf, g, w, r_n, re, &mut step.send_buf);
            }
            // -- local allgather of the received groups ------------------
            let contrib: &[T] = if l == 0 {
                &step.send_buf
            } else if step.active {
                &step.recv_buf
            } else {
                &[]
            };
            debug_assert_eq!(contrib.len(), step.counts[l]);
            match &mut step.gather {
                StepGather::Uniform(plan) => plan.execute(contrib, &mut step.gathered)?,
                StepGather::Varying(plan) => plan.execute(contrib, &mut step.gathered)?,
            }
            // Scatter the gathered groups by absolute region index.
            for &(start, off) in &step.scatter {
                scatter_ring(buf, start, w, r_n, re, &step.gathered[off..off + w * re]);
            }
        }

        // Permute the region-major buffer into communicator rank order.
        for &(src_off, rank) in &self.perm {
            output[rank * n..(rank + 1) * n].copy_from_slice(&self.buf[src_off..src_off + n]);
        }
        Ok(())
    }
}

/// Locality-aware Bruck allgather of `local` (length `n`); returns `n·p`
/// elements in communicator rank order. Regions are the topology's
/// configured region kind. One-shot wrapper over [`LocBruckPlan`].
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&LocalityBruck, comm, local)
}

/// The allgatherv variant (paper §3's alternative; see [`Rank0`]).
pub fn allgather_v<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&LocalityBruckV, comm, local)
}

/// Two-level locality-aware Bruck: node-aware outer algorithm whose local
/// gathers are themselves socket-aware locality-aware Brucks.
pub fn allgather_multilevel<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&LocalityBruckMultilevel, comm, local)
}

/// Copy regions `[start, start+width) mod r_n` out of the region-major
/// buffer, in ring order, into the preallocated `out`.
fn collect_ring<T: Pod>(
    buf: &[T],
    start: usize,
    width: usize,
    r_n: usize,
    region_elems: usize,
    out: &mut [T],
) {
    debug_assert_eq!(out.len(), width * region_elems);
    for k in 0..width {
        let ri = (start + k) % r_n;
        out[k * region_elems..(k + 1) * region_elems]
            .copy_from_slice(&buf[ri * region_elems..(ri + 1) * region_elems]);
    }
}

/// Inverse of [`collect_ring`]: write `data` into regions
/// `[start, start+width) mod r_n`. Overlapping (wrap-duplicate) regions
/// receive identical data by construction.
fn scatter_ring<T: Pod>(
    buf: &mut [T],
    start: usize,
    width: usize,
    r_n: usize,
    region_elems: usize,
    data: &[T],
) {
    debug_assert_eq!(data.len(), width * region_elems);
    for k in 0..width {
        let ri = (start + k) % r_n;
        buf[ri * region_elems..(ri + 1) * region_elems]
            .copy_from_slice(&data[k * region_elems..(k + 1) * region_elems]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{canonical_contribution, expected_result};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::{Placement, RegionKind, Topology};

    fn check(topo: &Topology, n: usize) {
        let expect = expected_result(topo.size(), n);
        let run = CommWorld::run(topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), n)).unwrap()
        });
        for (rank, r) in run.results.iter().enumerate() {
            assert_eq!(r, &expect, "rank {rank} mismatch");
        }
    }

    #[test]
    fn example_2_1_correct_and_single_nonlocal_message() {
        let topo = Topology::regions(4, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64, 1000 + c.rank() as u64]).unwrap()
        });
        let expect = {
            let mut e = Vec::new();
            for r in 0..16u64 {
                e.push(r);
                e.push(1000 + r);
            }
            e
        };
        for r in &run.results {
            assert_eq!(r, &expect);
        }
        // Paper: each process communicates only a single non-local message
        // (vs 4 for standard Bruck) ...
        assert_eq!(run.trace.max_nonlocal_msgs(), 1);
        // ... and only 4 values (8 bytes here: 2 u64 × 4 regions... the
        // paper's count is 4 values of the 16; with 2 u64 per rank the
        // non-local payload is one region group = 4 ranks × 2 u64 = 64 B.
        assert_eq!(run.trace.max_nonlocal_bytes(), 4 * 2 * 8);
    }

    #[test]
    fn fig6_64_procs_16_regions_two_nonlocal_steps() {
        let topo = Topology::regions(16, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        let expect = expected_result(64, 1);
        for r in &run.results {
            assert_eq!(r, &expect);
        }
        assert_eq!(run.trace.max_nonlocal_msgs(), 2); // ⌈log_4(16)⌉
    }

    #[test]
    fn correct_across_shapes() {
        check(&Topology::regions(2, 2), 1);
        check(&Topology::regions(4, 2), 3);
        check(&Topology::regions(8, 8), 2);
        check(&Topology::regions(16, 4), 1);
    }

    #[test]
    fn correct_non_power_region_counts() {
        // r not a power of ppr: 6 regions of 4, 5 regions of 2, 3 of 8.
        check(&Topology::regions(6, 4), 2);
        check(&Topology::regions(5, 2), 1);
        check(&Topology::regions(3, 8), 2);
        check(&Topology::regions(7, 4), 1);
    }

    #[test]
    fn single_region_degenerates_to_local_bruck() {
        let topo = Topology::regions(1, 8);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 2)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_result(8, 2));
        }
        assert_eq!(run.trace.max_nonlocal_msgs(), 0);
    }

    #[test]
    fn one_rank_per_region_falls_back_to_bruck() {
        let topo = Topology::regions(8, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_result(8, 1));
        }
    }

    #[test]
    fn empty_contribution_is_empty() {
        let topo = Topology::regions(2, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather::<u64>(c, &[]).unwrap()
        });
        for r in &run.results {
            assert!(r.is_empty());
        }
    }

    #[test]
    fn multilevel_correct_on_two_socket_nodes() {
        let topo =
            Topology::machine(4, 2, 2, RegionKind::Node, Placement::Block).unwrap();
        let expect = expected_result(16, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather_multilevel(c, &canonical_contribution(c.rank(), 2)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expect);
        }
    }

    #[test]
    fn multilevel_single_socket_equals_single_level() {
        let topo = Topology::regions(4, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather_multilevel(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_result(16, 1));
        }
    }

    #[test]
    fn rank0_of_each_region_sends_nothing_nonlocal() {
        let topo = Topology::regions(8, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        for (rank, t) in run.trace.per_rank.iter().enumerate() {
            if rank % 4 == 0 {
                assert_eq!(t.nonlocal_msgs, 0, "local rank 0 must idle (rank {rank})");
            }
        }
    }

    #[test]
    fn correct_under_random_placement() {
        let topo = Topology::machine(
            4,
            1,
            4,
            RegionKind::Node,
            Placement::Random { seed: 23 },
        )
        .unwrap();
        check(&topo, 2);
    }

    #[test]
    fn allgatherv_variant_correct_across_shapes() {
        for (regions, ppr) in [(4usize, 4usize), (16, 4), (6, 4), (5, 2), (1, 8), (8, 1)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allgather_v(c, &canonical_contribution(c.rank(), 2)).unwrap()
            });
            for (rank, r) in run.results.iter().enumerate() {
                assert_eq!(r, &expected_result(p, 2), "{regions}x{ppr} rank {rank}");
            }
        }
    }

    #[test]
    fn allgatherv_variant_moves_fewer_local_bytes() {
        // The §3 alternative saves exactly rank 0's duplicate contribution
        // in every post-step local gather.
        let topo = Topology::regions(16, 4);
        let std = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        let v = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather_v(c, &[c.rank() as u64]).unwrap();
        });
        let std_local: u64 = std.trace.per_rank.iter().map(|t| t.local_bytes).sum();
        let v_local: u64 = v.trace.per_rank.iter().map(|t| t.local_bytes).sum();
        assert!(v_local < std_local, "v {v_local} >= std {std_local}");
        // non-local traffic identical
        assert_eq!(
            std.trace.total_nonlocal_bytes(),
            v.trace.total_nonlocal_bytes()
        );
    }

    #[test]
    fn plan_reuse_on_shifting_inputs() {
        let topo = Topology::regions(4, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let groups = group_ranks(c, GroupBy::Region).unwrap();
            let mut plan =
                plan_grouped::<u64>(c, 2, &groups, Inner::Bruck, Rank0::Contributes, "loc-bruck")
                    .unwrap();
            let mut out = vec![0u64; 32];
            for round in 0..6u64 {
                let mine = [c.rank() as u64 + 777 * round, c.rank() as u64 + 777 * round + 13];
                plan.execute(&mine, &mut out).unwrap();
                let expect: Vec<u64> = (0..16u64)
                    .flat_map(|r| [r + 777 * round, r + 777 * round + 13])
                    .collect();
                assert_eq!(out, expect, "round {round}");
            }
            true
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
