//! **The locality-aware Bruck allgather — paper Algorithm 2.**
//!
//! Phases:
//!
//! 1. *Local allgather*: every region gathers its own data with a Bruck
//!    allgather on the region communicator.
//! 2. `⌈log_pℓ(r)⌉` *non-local steps*: before step `i` every rank holds the
//!    data of a contiguous group of `w = pℓ^i` regions starting at its own
//!    region `g` (`[g, g+w) mod r`). At step `i`, local rank `ℓ ≥ 1` sends
//!    the whole held group to the rank with the same local index in region
//!    `g − ℓ·w` and receives the group `[g + ℓ·w, g + (ℓ+1)·w)` from region
//!    `g + ℓ·w`; **local rank 0 stays idle**, preserving power-of-pℓ
//!    exchanges (§3). Each step ends with a local allgather of the received
//!    groups, growing the held window to `w·pℓ` regions.
//!
//! Every rank therefore sends at most `⌈log_pℓ(r)⌉` non-local messages and
//! `≈ b/pℓ` non-local bytes — the paper's headline improvement over the
//! `log2(p)` messages / `≈ b` bytes of standard Bruck.
//!
//! **Non-power region counts** (paper §3, Fig. 6): when `r` is not a power
//! of `pℓ`, local ranks with `ℓ·w ≥ r` idle through the step and contribute
//! nothing to the following local gather, which becomes an *allgatherv*;
//! the final received group may wrap past region `r − 1` and re-cover
//! already-held regions (the paper's “regions 13 through 15 as well as
//! region 0”), which the absolute-indexed assembly absorbs.
//!
//! **Multilevel hierarchy** (§3): [`allgather_multilevel`] groups by *node*
//! at the outer level and replaces the inner Bruck calls with a
//! socket-aware locality-aware Bruck, exactly as the paper prescribes.
//!
//! **Placement independence** (§3): all group structure is derived from
//! the topology, not from rank numbering, so non-local message counts are
//! identical under block, round-robin or random placement — asserted in
//! `rust/tests/locality_counts.rs`.

use super::grouping::{group_ranks, require_uniform, GroupBy, Groups};
use super::{bruck, primitives};
use crate::comm::{Comm, Pod};
use crate::error::{Error, Result};

/// Which allgather runs inside regions.
#[derive(Debug, Clone, Copy)]
enum Inner {
    /// Plain Bruck (single-level Algorithm 2).
    Bruck,
    /// Socket-aware locality-aware Bruck (two-level Algorithm 2).
    SocketAware,
}

/// How local rank 0's redundant contribution is handled in the post-step
/// local gathers (paper §3 gives both options).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rank0 {
    /// "this process will contribute the original data for simplicity" —
    /// uniform counts, plain Bruck local gathers (the paper's default).
    Contributes,
    /// "Alternatively, an MPI_Allgatherv operation could be utilized with
    /// the first local process contributing no data" — saves `w·pℓ·n`
    /// local bytes per step at the cost of allgatherv bookkeeping.
    GathervSkips,
}

/// Locality-aware Bruck allgather of `local` (length `n`); returns `n·p`
/// elements in communicator rank order. Regions are the topology's
/// configured region kind.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    let groups = group_ranks(comm, GroupBy::Region)?;
    loc_allgather(comm, local, &groups, Inner::Bruck, Rank0::Contributes)
}

/// The allgatherv variant (paper §3's alternative; see [`Rank0`]).
pub fn allgather_v<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    let groups = group_ranks(comm, GroupBy::Region)?;
    loc_allgather(comm, local, &groups, Inner::Bruck, Rank0::GathervSkips)
}

/// Two-level locality-aware Bruck: node-aware outer algorithm whose local
/// gathers are themselves socket-aware locality-aware Brucks.
pub fn allgather_multilevel<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    let groups = group_ranks(comm, GroupBy::Node)?;
    loc_allgather(comm, local, &groups, Inner::SocketAware, Rank0::Contributes)
}

/// Run the configured inner allgather on a (local) communicator.
fn inner_allgather<T: Pod>(comm: &Comm, local: &[T], inner: Inner) -> Result<Vec<T>> {
    match inner {
        Inner::Bruck => bruck::allgather(comm, local),
        Inner::SocketAware => {
            let groups = group_ranks(comm, GroupBy::Socket)?;
            if groups.count() == 1 {
                // single socket: plain Bruck is the whole story
                bruck::allgather(comm, local)
            } else {
                loc_allgather(comm, local, &groups, Inner::Bruck, Rank0::Contributes)
            }
        }
    }
}

/// The generic Algorithm 2 over explicit groups.
fn loc_allgather<T: Pod>(
    comm: &Comm,
    local: &[T],
    groups: &Groups,
    inner: Inner,
    rank0: Rank0,
) -> Result<Vec<T>> {
    let n = local.len();
    let p = comm.size();
    if n == 0 {
        return Ok(Vec::new());
    }
    let r_n = groups.count();
    let ppr = require_uniform(groups, "locality-aware bruck")?;
    if ppr == 1 {
        // One rank per region: no locality to exploit; Algorithm 2's
        // non-local phase would make no progress (only local rank 0 exists
        // and it idles). Degrade to the standard Bruck.
        return bruck::allgather(comm, local);
    }
    let g = groups.mine;
    let l = groups.my_local;
    let local_comm = comm.sub(&groups.members[g])?;
    let region_elems = ppr * n;

    // Region-major working buffer: region ri's data (in local-rank order)
    // lives at buf[ri*region_elems..]. Assembly is by absolute region
    // index, which makes wrap-around duplicates benign.
    let mut buf = vec![T::default(); r_n * region_elems];

    // Phase 1: local allgather of the initial blocks.
    let mine_region = inner_allgather(&local_comm, local, inner)?;
    debug_assert_eq!(mine_region.len(), region_elems);
    buf[g * region_elems..(g + 1) * region_elems].copy_from_slice(&mine_region);

    // Non-local phase. Invariant: every rank of group `gi` holds exactly
    // the regions [gi, gi+width) mod r_n.
    let mut width = 1usize;
    while width < r_n {
        let tag = comm.next_coll_tag(); // bumped by ALL ranks to stay aligned
        let active = |j: usize| j > 0 && j * width < r_n;

        // -- exchange --------------------------------------------------
        // The received group is NOT scattered into `buf` here: it flows to
        // every local rank (including us) through the local gather below,
        // which writes it once — avoiding a second full copy (perf pass).
        let mut received: Vec<T> = Vec::new();
        if active(l) {
            let dist = (l * width) % r_n;
            let dst_group = (g + r_n - dist) % r_n;
            let src_group = (g + dist) % r_n;
            let dst = groups.members[dst_group][l];
            let src = groups.members[src_group][l];
            let payload = collect_ring(&buf, g, width, r_n, region_elems);
            let _req = comm.isend(&payload, dst, tag)?;
            received = comm.irecv(src, tag).wait(comm)?;
            if received.len() != width * region_elems {
                return Err(Error::SizeMismatch {
                    expected: width * region_elems,
                    got: received.len(),
                });
            }
        }

        // -- local allgather of the received groups ---------------------
        // Contribution convention: local rank j contributes the group
        // starting at region (g + j*width) — rank 0 re-contributes the
        // currently-held group (the paper's "contribute the original data
        // for simplicity"); inactive ranks contribute nothing.
        let rank0_contributes = rank0 == Rank0::Contributes;
        let counts: Vec<usize> = (0..ppr)
            .map(|j| {
                if (j == 0 && rank0_contributes) || active(j) {
                    width * region_elems
                } else {
                    0
                }
            })
            .collect();
        let my_contrib: Vec<T> = if l == 0 {
            if rank0_contributes {
                collect_ring(&buf, g, width, r_n, region_elems)
            } else {
                Vec::new()
            }
        } else {
            received // moved, not cloned (perf pass)
        };

        let uniform = counts.iter().all(|&c| c == counts[0]);
        let gathered: Vec<T> = if uniform {
            // power-of-pℓ step: equal counts — use the configured inner
            // allgather (paper: "replacing all calls to bruck")
            inner_allgather(&local_comm, &my_contrib, inner)?
        } else {
            // non-power step: some ranks idle → allgatherv (§3)
            primitives::allgatherv(&local_comm, &my_contrib, &counts)?
        };

        // Scatter the gathered groups by absolute region index.
        let mut off = 0usize;
        for (j, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let start = (g + j * width) % r_n;
            scatter_ring(&mut buf, start, width, r_n, region_elems, &gathered[off..off + c]);
            off += c;
        }
        debug_assert_eq!(off, gathered.len());

        width = width.saturating_mul(ppr);
    }

    // Permute the region-major buffer into communicator rank order.
    let mut out = vec![T::default(); p * n];
    for (gi, members) in groups.members.iter().enumerate() {
        for (j, &rank) in members.iter().enumerate() {
            let src = gi * region_elems + j * n;
            out[rank * n..(rank + 1) * n].copy_from_slice(&buf[src..src + n]);
        }
    }
    Ok(out)
}

/// Copy regions `[start, start+width) mod r_n` out of the region-major
/// buffer, in ring order.
fn collect_ring<T: Pod>(
    buf: &[T],
    start: usize,
    width: usize,
    r_n: usize,
    region_elems: usize,
) -> Vec<T> {
    let mut out = Vec::with_capacity(width * region_elems);
    for k in 0..width {
        let ri = (start + k) % r_n;
        out.extend_from_slice(&buf[ri * region_elems..(ri + 1) * region_elems]);
    }
    out
}

/// Inverse of [`collect_ring`]: write `data` into regions
/// `[start, start+width) mod r_n`. Overlapping (wrap-duplicate) regions
/// receive identical data by construction.
fn scatter_ring<T: Pod>(
    buf: &mut [T],
    start: usize,
    width: usize,
    r_n: usize,
    region_elems: usize,
    data: &[T],
) {
    debug_assert_eq!(data.len(), width * region_elems);
    for k in 0..width {
        let ri = (start + k) % r_n;
        buf[ri * region_elems..(ri + 1) * region_elems]
            .copy_from_slice(&data[k * region_elems..(k + 1) * region_elems]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{canonical_contribution, expected_result};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::{Placement, RegionKind, Topology};

    fn check(topo: &Topology, n: usize) {
        let expect = expected_result(topo.size(), n);
        let run = CommWorld::run(topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), n)).unwrap()
        });
        for (rank, r) in run.results.iter().enumerate() {
            assert_eq!(r, &expect, "rank {rank} mismatch");
        }
    }

    #[test]
    fn example_2_1_correct_and_single_nonlocal_message() {
        let topo = Topology::regions(4, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64, 1000 + c.rank() as u64]).unwrap()
        });
        let expect = {
            let mut e = Vec::new();
            for r in 0..16u64 {
                e.push(r);
                e.push(1000 + r);
            }
            e
        };
        for r in &run.results {
            assert_eq!(r, &expect);
        }
        // Paper: each process communicates only a single non-local message
        // (vs 4 for standard Bruck) ...
        assert_eq!(run.trace.max_nonlocal_msgs(), 1);
        // ... and only 4 values (8 bytes here: 2 u64 × 4 regions... the
        // paper's count is 4 values of the 16; with 2 u64 per rank the
        // non-local payload is one region group = 4 ranks × 2 u64 = 64 B.
        assert_eq!(run.trace.max_nonlocal_bytes(), 4 * 2 * 8);
    }

    #[test]
    fn fig6_64_procs_16_regions_two_nonlocal_steps() {
        let topo = Topology::regions(16, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        let expect = expected_result(64, 1);
        for r in &run.results {
            assert_eq!(r, &expect);
        }
        assert_eq!(run.trace.max_nonlocal_msgs(), 2); // ⌈log_4(16)⌉
    }

    #[test]
    fn correct_across_shapes() {
        check(&Topology::regions(2, 2), 1);
        check(&Topology::regions(4, 2), 3);
        check(&Topology::regions(8, 8), 2);
        check(&Topology::regions(16, 4), 1);
    }

    #[test]
    fn correct_non_power_region_counts() {
        // r not a power of ppr: 6 regions of 4, 5 regions of 2, 3 of 8.
        check(&Topology::regions(6, 4), 2);
        check(&Topology::regions(5, 2), 1);
        check(&Topology::regions(3, 8), 2);
        check(&Topology::regions(7, 4), 1);
    }

    #[test]
    fn single_region_degenerates_to_local_bruck() {
        let topo = Topology::regions(1, 8);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 2)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_result(8, 2));
        }
        assert_eq!(run.trace.max_nonlocal_msgs(), 0);
    }

    #[test]
    fn one_rank_per_region_falls_back_to_bruck() {
        let topo = Topology::regions(8, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_result(8, 1));
        }
    }

    #[test]
    fn empty_contribution_is_empty() {
        let topo = Topology::regions(2, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather::<u64>(c, &[]).unwrap()
        });
        for r in &run.results {
            assert!(r.is_empty());
        }
    }

    #[test]
    fn multilevel_correct_on_two_socket_nodes() {
        let topo =
            Topology::machine(4, 2, 2, RegionKind::Node, Placement::Block).unwrap();
        let expect = expected_result(16, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather_multilevel(c, &canonical_contribution(c.rank(), 2)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expect);
        }
    }

    #[test]
    fn multilevel_single_socket_equals_single_level() {
        let topo = Topology::regions(4, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather_multilevel(c, &canonical_contribution(c.rank(), 1)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_result(16, 1));
        }
    }

    #[test]
    fn rank0_of_each_region_sends_nothing_nonlocal() {
        let topo = Topology::regions(8, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        for (rank, t) in run.trace.per_rank.iter().enumerate() {
            if rank % 4 == 0 {
                assert_eq!(t.nonlocal_msgs, 0, "local rank 0 must idle (rank {rank})");
            }
        }
    }

    #[test]
    fn correct_under_random_placement() {
        let topo = Topology::machine(
            4,
            1,
            4,
            RegionKind::Node,
            Placement::Random { seed: 23 },
        )
        .unwrap();
        check(&topo, 2);
    }

    #[test]
    fn allgatherv_variant_correct_across_shapes() {
        for (regions, ppr) in [(4usize, 4usize), (16, 4), (6, 4), (5, 2), (1, 8), (8, 1)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allgather_v(c, &canonical_contribution(c.rank(), 2)).unwrap()
            });
            for (rank, r) in run.results.iter().enumerate() {
                assert_eq!(r, &expected_result(p, 2), "{regions}x{ppr} rank {rank}");
            }
        }
    }

    #[test]
    fn allgatherv_variant_moves_fewer_local_bytes() {
        // The §3 alternative saves exactly rank 0's duplicate contribution
        // in every post-step local gather.
        let topo = Topology::regions(16, 4);
        let std = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather(c, &[c.rank() as u64]).unwrap();
        });
        let v = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allgather_v(c, &[c.rank() as u64]).unwrap();
        });
        let std_local: u64 = std.trace.per_rank.iter().map(|t| t.local_bytes).sum();
        let v_local: u64 = v.trace.per_rank.iter().map(|t| t.local_bytes).sum();
        assert!(v_local < std_local, "v {v_local} >= std {std_local}");
        // non-local traffic identical
        assert_eq!(
            std.trace.total_nonlocal_bytes(),
            v.trace.total_nonlocal_bytes()
        );
    }
}
