//! Allgatherv — the ragged allgather — as schedule builders.
//!
//! `allgatherv` contract (`MPI_Allgatherv` semantics): rank `r`
//! contributes `counts[r]` elements; afterwards every rank holds the
//! concatenation of all contributions in rank order, block `r` at the
//! counts' prefix offset. Jocksch et al. (*Optimised allgatherv,
//! reduce_scatter and allreduce communication*) treat the ragged gather as
//! the collective the paper's locality-aware aggregation generalises to:
//! the same per-message postal terms `α_c + β_c·s` (paper §4) over exact
//! ragged slices, with zero-count ranks still participating in every
//! exchange (a zero-length message costs its latency term — dropping it
//! would desynchronise the SPMD schedules).
//!
//! Three builders, all registered in
//! [`super::plan::AllgathervRegistry`] (plus the cost-model-driven
//! [`super::model_tuned::ModelTunedAllgatherv`]):
//!
//! * **`ring`** — `p−1` neighbour exchange steps over the output buffer at
//!   ragged offsets: step `s` forwards block `(rank+s) mod p` left and
//!   receives block `(rank+s+1) mod p` from the right. Bandwidth-optimal
//!   (`total − counts[rank]` elements received, each exactly once);
//! * **`bruck`** — the sst-macro `bruck_allgatherv` shape: `⌈log₂ p⌉`
//!   doubling exchanges with **per-partner receive counts** (rotated
//!   prefix sums of the counts vector); non-power-of-two `p` is absorbed
//!   by the final partial round sending `p − 2^⌊log₂ p⌋` blocks — the
//!   extra-round trick ([`super::schedule::emit_group_allgatherv`]);
//! * **`loc-aware`** — paper Algorithm 2 over ragged region sums: a local
//!   allgatherv per region, then the same `⌈log_pℓ(r)⌉` width-doubling
//!   non-local steps as the uniform [`super::loc_bruck`] builder, each
//!   non-local message carrying the *sum of the held regions' counts*
//!   instead of `w·pℓ·n`. Non-local message counts are exactly the uniform
//!   bound — raggedness changes payload lengths, never the exchange
//!   structure (asserted in `rust/tests/locality_counts.rs`).
//!
//! All three are pure schedule builders over exact ragged slices: every
//! schedule carries an explicit [`Schedule::io`] override
//! (`(counts[rank], Σ counts)`), executes through the generic
//! [`SchedPlan`] interpreter, and is costed by [`crate::model::cost`] with
//! no ragged special-casing — prediction replays the same slices execution
//! moves.

use super::grouping::GroupBy;
use super::plan::{
    check_counts_len, trivial_agv_plan, AllgathervAlgorithm, AllgathervPlan, Counts,
    NamedAlgorithm, OpKind, PlanSpec,
};
use super::schedule::{
    emit_group_allgatherv, locate, uniform_size, SchedPlan, Schedule, ScheduleBuilder, Slice,
    WorldView,
};
use crate::comm::{Comm, Pod};
use crate::error::{Error, Result};

/// Ring allgatherv (registry entry).
pub struct RingAllgatherv;

impl NamedAlgorithm for RingAllgatherv {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn summary(&self) -> &'static str {
        "ring allgatherv: p-1 neighbour exchanges of ragged blocks, bandwidth-optimal"
    }
}

impl<T: Pod> AllgathervAlgorithm<T> for RingAllgatherv {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgathervPlan<T>>> {
        if let Some(p) = trivial_agv_plan("ring", comm, spec) {
            return Ok(p);
        }
        check_counts_len(&spec.counts, comm.size())?;
        let sched = build_ring_schedule(
            comm.size(),
            comm.rank(),
            spec.counts.as_slice(),
            std::mem::size_of::<T>(),
        );
        Ok(SchedPlan::<T>::boxed(comm, "ring", sched)?)
    }
}

/// Bruck allgatherv with per-partner receive counts (registry entry).
pub struct BruckAllgatherv;

impl NamedAlgorithm for BruckAllgatherv {
    fn name(&self) -> &'static str {
        "bruck"
    }

    fn summary(&self) -> &'static str {
        "Bruck allgatherv: log2(p) doubling exchanges with per-partner recv counts"
    }
}

impl<T: Pod> AllgathervAlgorithm<T> for BruckAllgatherv {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgathervPlan<T>>> {
        if let Some(p) = trivial_agv_plan("bruck", comm, spec) {
            return Ok(p);
        }
        check_counts_len(&spec.counts, comm.size())?;
        let sched = build_bruck_schedule(
            comm.size(),
            comm.rank(),
            spec.counts.as_slice(),
            std::mem::size_of::<T>(),
        );
        Ok(SchedPlan::<T>::boxed(comm, "bruck", sched)?)
    }
}

/// Locality-aware allgatherv (registry entry).
pub struct LocAwareAllgatherv;

impl NamedAlgorithm for LocAwareAllgatherv {
    fn name(&self) -> &'static str {
        "loc-aware"
    }

    fn summary(&self) -> &'static str {
        "regional allgatherv (Alg. 2 over ragged region sums): log_ppr(r) non-local steps"
    }
}

impl<T: Pod> AllgathervAlgorithm<T> for LocAwareAllgatherv {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgathervPlan<T>>> {
        if let Some(p) = trivial_agv_plan("loc-aware", comm, spec) {
            return Ok(p);
        }
        check_counts_len(&spec.counts, comm.size())?;
        let view = WorldView::from_comm(comm);
        let sched = build_loc_schedule(
            &view,
            comm.rank(),
            spec.counts.as_slice(),
            std::mem::size_of::<T>(),
        )?;
        Ok(SchedPlan::<T>::boxed(comm, "loc-aware", sched)?)
    }
}

/// Exclusive prefix sums with the total appended (`len + 1` entries).
fn prefix_offsets(counts: &[usize]) -> Vec<usize> {
    let mut offs = Vec::with_capacity(counts.len() + 1);
    let mut acc = 0usize;
    offs.push(0);
    for &c in counts {
        acc += c;
        offs.push(acc);
    }
    offs
}

// ---------------------------------------------------------------------------
// builders
// ---------------------------------------------------------------------------

/// Build the ring allgatherv schedule for one rank (pure; SPMD). Blocks
/// travel through the output buffer at the counts' prefix offsets;
/// zero-count blocks are still forwarded (zero-length messages keep the
/// ring in lockstep and are charged their latency term).
pub fn build_ring_schedule(
    p: usize,
    rank: usize,
    counts: &[usize],
    elem_bytes: usize,
) -> Schedule {
    debug_assert_eq!(counts.len(), p);
    let offs = prefix_offsets(counts);
    let total = offs[p];
    let mut sb = ScheduleBuilder::new("ring allgatherv");
    let tag0 = sb.tag_block(p.saturating_sub(1) as u64);
    if counts[rank] > 0 {
        sb.copy(Slice::input(0, counts[rank]), Slice::output(offs[rank], counts[rank]));
    }
    let right = (rank + 1) % p;
    let left = (rank + p - 1) % p;
    for s in 0..p.saturating_sub(1) {
        let have = (rank + s) % p;
        let get = (rank + s + 1) % p;
        sb.sendrecv(
            left,
            Slice::output(offs[have], counts[have]),
            right,
            Slice::output(offs[get], counts[get]),
            tag0 + s as u64,
            0,
        );
    }
    let mut sched = sb.finish(OpKind::Allgatherv, p, max_count(counts), elem_bytes, "ring");
    sched.io = Some((counts[rank], total));
    sched
}

/// Build the Bruck allgatherv schedule for one rank (pure; SPMD): the
/// whole communicator as one group of
/// [`super::schedule::emit_group_allgatherv`] — `⌈log₂ p⌉` doubling
/// exchanges whose send/receive lengths are rotated prefix sums of the
/// counts, the final partial round covering non-power-of-two `p`.
pub fn build_bruck_schedule(
    p: usize,
    rank: usize,
    counts: &[usize],
    elem_bytes: usize,
) -> Schedule {
    debug_assert_eq!(counts.len(), p);
    let total: usize = counts.iter().sum();
    let members: Vec<usize> = (0..p).collect();
    let mut sb = ScheduleBuilder::new("bruck allgatherv");
    emit_group_allgatherv(
        &mut sb,
        &members,
        rank,
        counts,
        Slice::input(0, counts[rank]),
        Slice::output(0, total),
    );
    let mut sched = sb.finish(OpKind::Allgatherv, p, max_count(counts), elem_bytes, "bruck");
    sched.io = Some((counts[rank], total));
    sched
}

/// Build the locality-aware allgatherv schedule for one rank (pure; SPMD).
///
/// The uniform Algorithm 2 control flow with ragged region sums: phase 1
/// is a per-region local allgatherv into a region-major working buffer;
/// each of the `⌈log_pℓ(r)⌉` non-local steps exchanges the *held window*
/// of regions — payload the sum of the window's counts — between ranks of
/// equal local index, followed by a local allgatherv of the received
/// windows and an absolute-indexed scatter. Exchange partners, step count
/// and per-rank activity are **identical** to the uniform builder
/// ([`super::loc_bruck`]); only payload lengths follow the counts, so the
/// paper's non-local message bound survives arbitrary skew. One rank per
/// region degrades to the plain group allgatherv; non-uniform regions are
/// rejected at plan time.
pub fn build_loc_schedule(
    view: &WorldView,
    rank: usize,
    counts: &[usize],
    elem_bytes: usize,
) -> Result<Schedule> {
    debug_assert_eq!(counts.len(), view.p);
    let all: Vec<usize> = (0..view.p).collect();
    let groups = view.split(&all, GroupBy::Region);
    let ppr = uniform_size(&groups, "locality-aware allgatherv")?;
    let r_n = groups.len();
    let offs = prefix_offsets(counts);
    let total = offs[view.p];

    let mut sb = ScheduleBuilder::new("local allgatherv");
    if ppr == 1 {
        // One rank per region: the non-local phase would make no progress
        // (only local rank 0 exists and it idles) — degrade to the group
        // allgatherv over the whole communicator.
        emit_group_allgatherv(
            &mut sb,
            &all,
            rank,
            counts,
            Slice::input(0, counts[rank]),
            Slice::output(0, total),
        );
        let mut sched =
            sb.finish(OpKind::Allgatherv, view.p, max_count(counts), elem_bytes, "loc-aware");
        sched.io = Some((counts[rank], total));
        return Ok(sched);
    }
    let (g, l) = locate(&groups, rank)?;

    // Ragged region geometry: region gi's members contribute r_sum[gi]
    // elements in local-rank order, and the region-major working buffer
    // keeps region gi at the fixed absolute offset r_off[gi] — assembly by
    // absolute region index makes wrap-around duplicates benign, exactly
    // as in the uniform builder.
    let region_counts: Vec<Vec<usize>> =
        groups.iter().map(|m| m.iter().map(|&r| counts[r]).collect()).collect();
    let r_sum: Vec<usize> = region_counts.iter().map(|c| c.iter().sum()).collect();
    let r_off = prefix_offsets(&r_sum);
    let win = |start: usize, width: usize| -> usize {
        (0..width).map(|k| r_sum[(start + k) % r_n]).sum()
    };
    let buf = sb.scratch(total);

    // Phase 1: local allgatherv straight into this rank's region slot.
    emit_group_allgatherv(
        &mut sb,
        &groups[g],
        rank,
        &region_counts[g],
        Slice::input(0, counts[rank]),
        Slice::at(buf, r_off[g], r_sum[g]),
    );

    // Non-local phase. Invariant: every rank of group gi holds exactly the
    // regions [gi, gi+width) mod r_n.
    let mut width = 1usize;
    let mut step_no = 1usize;
    while width < r_n {
        sb.round(format!("non-local step {step_no}"));
        let tag = sb.tag();
        let active_j = |j: usize| j > 0 && j * width < r_n;
        let active = active_j(l);
        // Local rank j's contribution to the post-step gather is the
        // window starting at region (g + j·width): rank 0 re-contributes
        // the held window, inactive ranks contribute nothing.
        let gather_counts: Vec<usize> = (0..ppr)
            .map(|j| {
                if j == 0 || active_j(j) {
                    win((g + j * width) % r_n, width)
                } else {
                    0
                }
            })
            .collect();
        let send_len = win(g, width);
        let need_send = active || l == 0;
        let send_buf = if need_send { Some(sb.scratch(send_len)) } else { None };
        let recv_len = if active { win((g + l * width) % r_n, width) } else { 0 };
        let recv_buf = if active { Some(sb.scratch(recv_len)) } else { None };
        if let Some(sbuf) = send_buf {
            // collect the held ring [g, g+width) into a contiguous payload
            let mut off = 0usize;
            for k in 0..width {
                let ri = (g + k) % r_n;
                if r_sum[ri] > 0 {
                    sb.copy(Slice::at(buf, r_off[ri], r_sum[ri]), Slice::at(sbuf, off, r_sum[ri]));
                }
                off += r_sum[ri];
            }
        }
        if let (true, Some(rbuf)) = (active, recv_buf) {
            let dist = (l * width) % r_n;
            let to = groups[(g + r_n - dist) % r_n][l];
            let from = groups[(g + dist) % r_n][l];
            sb.sendrecv(
                to,
                Slice::at(send_buf.expect("active ranks have a send buffer"), 0, send_len),
                from,
                Slice::at(rbuf, 0, recv_len),
                tag,
                0,
            );
        }
        // Local allgatherv of the received windows.
        let gather_total: usize = gather_counts.iter().sum();
        let gathered = sb.scratch(gather_total);
        let my_contrib = if l == 0 {
            Slice::at(send_buf.expect("local rank 0 always stages its held window"), 0, send_len)
        } else if active {
            Slice::at(recv_buf.expect("active"), 0, recv_len)
        } else {
            Slice::input(0, 0)
        };
        emit_group_allgatherv(
            &mut sb,
            &groups[g],
            rank,
            &gather_counts,
            my_contrib,
            Slice::at(gathered, 0, gather_total),
        );
        // Scatter the gathered windows by absolute region index.
        let mut off = 0usize;
        for (j, &c) in gather_counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let start = (g + j * width) % r_n;
            let mut woff = off;
            for k in 0..width {
                let ri = (start + k) % r_n;
                if r_sum[ri] > 0 {
                    sb.copy(
                        Slice::at(gathered, woff, r_sum[ri]),
                        Slice::at(buf, r_off[ri], r_sum[ri]),
                    );
                }
                woff += r_sum[ri];
            }
            off += c;
        }
        width = width.saturating_mul(ppr);
        step_no += 1;
    }

    // Permute the region-major buffer into rank order at the counts'
    // global prefix offsets.
    sb.round("reorder");
    for (gi, members) in groups.iter().enumerate() {
        let mut moff = r_off[gi];
        for &r in members {
            if counts[r] > 0 {
                sb.copy(Slice::at(buf, moff, counts[r]), Slice::output(offs[r], counts[r]));
            }
            moff += counts[r];
        }
    }
    let mut sched =
        sb.finish(OpKind::Allgatherv, view.p, max_count(counts), elem_bytes, "loc-aware");
    sched.io = Some((counts[rank], total));
    Ok(sched)
}

fn max_count(counts: &[usize]) -> usize {
    counts.iter().copied().max().unwrap_or(0)
}

/// Build the schedule of one allgatherv algorithm (by registry name) for
/// `rank`. `model-tuned` is handled by the dispatcher
/// ([`super::model_tuned::pick_allgatherv`]).
pub fn build_allgatherv(
    name: &str,
    view: &WorldView,
    rank: usize,
    counts: &[usize],
    elem_bytes: usize,
) -> Result<Schedule> {
    if counts.len() != view.p {
        return Err(Error::Precondition(format!(
            "counts length {} does not match communicator size {}",
            counts.len(),
            view.p
        )));
    }
    if name.eq_ignore_ascii_case("ring") {
        Ok(build_ring_schedule(view.p, rank, counts, elem_bytes))
    } else if name.eq_ignore_ascii_case("bruck") {
        Ok(build_bruck_schedule(view.p, rank, counts, elem_bytes))
    } else if name.eq_ignore_ascii_case("loc-aware") {
        build_loc_schedule(view, rank, counts, elem_bytes)
    } else {
        Err(Error::Precondition(format!("no allgatherv schedule builder for '{name}'")))
    }
}

// ---------------------------------------------------------------------------
// one-shot wrappers
// ---------------------------------------------------------------------------

/// One-shot ring allgatherv: `local.len()` must equal `counts[rank]`.
pub fn ring<T: Pod>(comm: &Comm, local: &[T], counts: &Counts) -> Result<Vec<T>> {
    super::plan::one_shot_agv(&RingAllgatherv, comm, local, counts)
}

/// One-shot Bruck allgatherv.
pub fn bruck<T: Pod>(comm: &Comm, local: &[T], counts: &Counts) -> Result<Vec<T>> {
    super::plan::one_shot_agv(&BruckAllgatherv, comm, local, counts)
}

/// One-shot locality-aware allgatherv.
pub fn loc_aware<T: Pod>(comm: &Comm, local: &[T], counts: &Counts) -> Result<Vec<T>> {
    super::plan::one_shot_agv(&LocAwareAllgatherv, comm, local, counts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    fn contribution(rank: usize, c: usize) -> Vec<u64> {
        (0..c).map(|j| (rank * 1_000_003 + j) as u64).collect()
    }

    fn expected(counts: &[usize]) -> Vec<u64> {
        let mut e = Vec::new();
        for (r, &c) in counts.iter().enumerate() {
            e.extend(contribution(r, c));
        }
        e
    }

    fn check_all(topo: &Topology, counts: Vec<usize>) {
        let cts = Counts::new(counts.clone());
        let expect = expected(&counts);
        for algo in ["ring", "bruck", "loc-aware"] {
            let run = CommWorld::run(topo, Timing::Wallclock, |c| {
                let reg = crate::collectives::plan::AllgathervRegistry::<u64>::standard();
                let mut plan = reg.plan(algo, c, &PlanSpec::ragged(cts.clone())).unwrap();
                let mut out = vec![0u64; cts.total()];
                plan.execute(&contribution(c.rank(), cts.get(c.rank())), &mut out).unwrap();
                out
            });
            for (rank, r) in run.results.iter().enumerate() {
                assert_eq!(r, &expect, "{algo} rank {rank} counts {counts:?}");
            }
        }
    }

    #[test]
    fn ragged_counts_across_shapes() {
        check_all(&Topology::regions(2, 2), vec![4, 0, 7, 2]);
        check_all(&Topology::regions(4, 4), (0..16).map(|r| r % 5).collect());
        check_all(&Topology::regions(2, 8), (0..16).map(|r| (r * 3) % 7).collect());
        check_all(&Topology::regions(3, 2), vec![1, 0, 3, 0, 2, 5]);
    }

    #[test]
    fn single_rank_holds_everything() {
        let mut counts = vec![0usize; 8];
        counts[3] = 9;
        check_all(&Topology::regions(4, 2), counts);
        let mut counts = vec![0usize; 6];
        counts[0] = 4;
        check_all(&Topology::regions(3, 2), counts);
    }

    #[test]
    fn non_power_of_two_world() {
        check_all(&Topology::regions(5, 1), vec![2, 0, 1, 4, 3]);
        check_all(&Topology::regions(7, 1), (0..7).map(|r| r % 3).collect());
        check_all(&Topology::regions(3, 3), (0..9).map(|r| (r * 7) % 4).collect());
    }

    #[test]
    fn uniform_counts_degenerate_to_allgather() {
        check_all(&Topology::regions(4, 4), vec![2; 16]);
        check_all(&Topology::regions(1, 8), vec![3; 8]);
        check_all(&Topology::regions(8, 1), vec![1; 8]);
    }

    #[test]
    fn loc_aware_keeps_uniform_nonlocal_bound_under_skew() {
        // (4×4): uniform Algorithm 2 sends ⌈log_4(4)⌉ = 1 non-local
        // message per rank; skewed counts must not change that.
        let topo = Topology::regions(4, 4);
        let counts: Vec<usize> = (0..16).map(|r| r % 5).collect();
        let cts = Counts::new(counts);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            loc_aware(c, &contribution(c.rank(), cts.get(c.rank())), &cts).unwrap();
        });
        assert_eq!(run.trace.max_nonlocal_msgs(), 1);
    }

    #[test]
    fn one_shot_rejects_wrong_local_length() {
        let topo = Topology::regions(2, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let cts = Counts::new(vec![1, 2, 3, 4]);
            ring(c, &[0u64; 9], &cts).is_err()
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
