//! The standard Bruck allgather — paper Algorithm 1.
//!
//! `⌈log2(p)⌉` steps. Before step `i` each rank holds `min(2^i, p)` blocks,
//! beginning with its own, in “rotated” order: block `j` is the
//! contribution of rank `(id + j) mod p`. Step `i` sends the first
//! `min(2^i, p − 2^i)` blocks to rank `id − 2^i (mod p)` and receives the
//! same amount from rank `id + 2^i (mod p)`, appended after the held
//! blocks. A final rotation (“rotate data down by id positions”) restores
//! global rank order.
//!
//! The final rotation is the data-movement hot spot mirrored by the Pallas
//! kernel `python/compile/kernels/bruck_pack.py` (see DESIGN.md).

use crate::comm::{Comm, Pod};
use crate::error::Result;

/// Bruck allgather of `local` (length `n`) over `comm`; returns `n·p`
/// elements in rank order.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    let p = comm.size();
    let id = comm.rank();
    let n = local.len();
    let tag = comm.next_coll_tag();

    // Working buffer in rotated order; grows to n*p.
    let mut data: Vec<T> = Vec::with_capacity(n * p);
    data.extend_from_slice(local);

    let mut dist = 1usize;
    let mut step = 0u64;
    while dist < p {
        // number of blocks exchanged this step (partial final step for
        // non-power-of-two p)
        let blocks = dist.min(p - dist);
        let send_to = (id + p - dist) % p;
        let recv_from = (id + dist) % p;
        let _send = comm.isend(&data[0..blocks * n], send_to, tag + step)?;
        // receive straight into the working buffer's tail (perf pass:
        // avoids the intermediate Vec the generic recv path allocates)
        let old = data.len();
        data.resize(old + blocks * n, T::default());
        let req = comm.irecv(recv_from, tag + step);
        req.wait_into(comm, &mut data[old..])?;
        dist <<= 1;
        step += 1;
    }
    debug_assert_eq!(data.len(), n * p);

    Ok(rotate_down(&data, n, id))
}

/// The final reorder of Algorithm 1: the rotated buffer holds rank
/// `(id + j) mod p`'s block at position `j`; rotating *down* by `id` blocks
/// puts block of rank `r` at position `r`.
pub fn rotate_down<T: Pod>(data: &[T], n: usize, id: usize) -> Vec<T> {
    assert!(n > 0, "block size must be positive");
    assert_eq!(data.len() % n, 0);
    let p = data.len() / n;
    let mut out = Vec::with_capacity(data.len());
    // out[(id + j) % p] = data[j]  ⇔  out[k] = data[(k - id) mod p]
    for k in 0..p {
        let j = (k + p - id % p) % p;
        out.extend_from_slice(&data[j * n..(j + 1) * n]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rotate_down_identity_for_rank0() {
        let data: Vec<u64> = (0..12).collect();
        assert_eq!(rotate_down(&data, 3, 0), data);
    }

    #[test]
    fn rotate_down_moves_blocks() {
        // 3 blocks of 2, rank 1: rotated order is [b1, b2, b0]; rotating
        // down by 1 restores [b0, b1, b2].
        let rotated: Vec<u64> = vec![10, 11, 20, 21, 0, 1];
        let out = rotate_down(&rotated, 2, 1);
        assert_eq!(out, vec![0, 1, 10, 11, 20, 21]);
    }

    #[test]
    fn rotate_down_wraps_modulo_p() {
        let data: Vec<u64> = (0..8).collect(); // 4 blocks of 2
        assert_eq!(rotate_down(&data, 2, 4), data); // id == p → identity
        assert_eq!(rotate_down(&data, 2, 5), rotate_down(&data, 2, 1));
    }
}
